"""Kernel-layer benchmark: the PBS hot loops (DESIGN.md §3) at protocol scale.

No TPU in this container, so three views per kernel:
  * interpret — Pallas kernel body in interpret mode (correctness-grade);
  * ref       — the jitted pure-jnp oracle on CPU (the fastest runnable path
                here, and what the multi-round protocol actually calls);
  * tpu_est   — analytic v5e time: max(FLOP/s term, HBM term) from the
                kernel's exact op/byte counts (the number the §Roofline
                tables use).

Scale: d = 10,000 -> g = 2,000 groups, (n, t) = (127, 13) — the paper's
headline operating point where PinSketch's O(d²) decode takes seconds and
PBS's batched decode is O(d).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.bch import BCHCode, batched_decode, sketch_from_positions
from repro.kernels.ops import bch_decode_batched, pack_bits_to_field, sketch_groups
from repro.kernels.gf2_matmul import gf2_matmul
from repro.kernels.tow_sketch import tow_sketch
from repro.kernels.bin_xorsum import bin_parity_xorsum

from .common import FULL, Row, Timer, print_rows

PEAK_INT = 197e12 / 2          # int8-ish MXU ops/s (conservative: bf16 rate)
HBM = 819e9


def _time(fn, *args, reps=3):
    fn(*args)  # compile/warm
    best = float("inf")
    for _ in range(reps):
        with Timer() as t:
            r = fn(*args)
            jax.block_until_ready(r) if hasattr(r, "block_until_ready") or isinstance(r, jax.Array) else None
        best = min(best, t.us)
    return best


def run():
    rows = []
    G, n, t = (8000, 127, 13) if FULL else (2000, 127, 13)
    code = BCHCode(n, t)
    m = code.m
    rng = np.random.default_rng(3)

    # ---- gf2_matmul: G parity bitmaps -> BCH sketches (one GF(2) matmul) --
    bitmaps = jnp.asarray(rng.integers(0, 2, (G, n)), jnp.int32)
    P = jnp.asarray(code.field.syndrome_matrix(code.t))
    ref = jax.jit(lambda a, b: (a @ b) % 2)
    us_ref = _time(ref, bitmaps, P)
    flops = 2.0 * G * n * t * m
    bytes_ = (G * n + n * t * m + G * t * m) * 4
    tpu_est = max(flops / PEAK_INT, bytes_ / HBM) * 1e6
    with Timer() as ti:
        kern = gf2_matmul(bitmaps, P, interpret=True)
    ok = bool(jnp.all(kern == ref(bitmaps, P)))
    rows.append(Row("kernel/gf2_matmul_sketch", us_ref,
                    f"G={G} n={n} tm={t * m} interpret_ok={ok} "
                    f"interp_us={ti.us:.0f} tpu_est_us={tpu_est:.1f}"))

    # ---- batched BCH decode (jit vmap BM+Chien) vs numpy reference --------
    positions = [np.sort(rng.choice(n, size=rng.integers(0, t + 1), replace=False))
                 for _ in range(G)]
    sketches = np.stack([sketch_from_positions(code, p) for p in positions])
    sk = jnp.asarray(sketches)
    jfn = lambda s: bch_decode_batched(s, n=n, t=t)
    us_jax = _time(jfn, sk)
    with Timer() as tnp:
        ok_np, pos_np = batched_decode(code, sketches)
    okj, posj, cnt = jfn(sk)
    agree = bool(np.all(np.asarray(okj) == ok_np))
    rows.append(Row("kernel/bch_decode_batched", us_jax,
                    f"G={G} jax_us={us_jax:.0f} numpy_us={tnp.us:.0f} "
                    f"agree={agree} per_group_us={us_jax / G:.2f} (O(d) total)"))

    # ---- O(d) vs O(d^2): PinSketch-style single decode at same d ----------
    d_total = 5 * G
    big_code = None
    rows.append(Row("kernel/decode_scaling", 0.0,
                    f"PBS decodes d={d_total} as {G} independent t={t} units; "
                    f"one-shot BCH at t={d_total} needs O(t^2)={d_total**2:.1e} "
                    f"GF ops vs PBS {G * t * t:.1e}"))

    # ---- ToW sketch kernel -------------------------------------------------
    elems = jnp.asarray(rng.integers(1, 1 << 32, 200_000, dtype=np.uint64).astype(np.uint32))
    seeds = jnp.arange(128, dtype=jnp.uint32)
    with Timer() as ti2:
        y = tow_sketch(elems, seeds, ell=128, interpret=True)
    flops = 200_000 * 128 * 8.0
    bytes_ = 200_000 * 4 * 1.0 + 128 * 4
    tpu_est = max(flops / PEAK_INT, bytes_ / HBM) * 1e6
    rows.append(Row("kernel/tow_sketch", ti2.us,
                    f"N=200k ell=128 interp_us={ti2.us:.0f} tpu_est_us={tpu_est:.1f}"))

    # ---- bin parity/xorsum build ------------------------------------------
    elems_g = jnp.asarray(rng.integers(1, 1 << 32, 4096, dtype=np.uint64).astype(np.uint32))
    with Timer() as ti3:
        par, xb = bin_parity_xorsum(elems_g, n_bins=n, seed=7, interpret=True)
    rows.append(Row("kernel/bin_xorsum", ti3.us,
                    f"N=4096 n={n} interp_us={ti3.us:.0f}"))
    return print_rows(rows)


if __name__ == "__main__":
    run()
