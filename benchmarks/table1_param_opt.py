"""Paper Table 1 (App. H): success-probability lower-bound grid for d=1000,
delta=5 (g=200), r=3; optimal (n, t) = (127, 13) with 318 bits/group.

Reproduction stance (see EXPERIMENTS.md §Paper-validation): the paper's
printed Table 1 is *not* reproducible from its own stated App. D/F model
("Pr[x⇝0] = 0 for x > t"): under that model rows t ≤ 11 are all ≤ 0
(the Binomial tail beyond t kills alpha^200), yet the paper prints e.g.
0.927 at (127, 10).  We therefore report BOTH conventions:

* truncate — the paper's stated model; matches the paper's cells where the
  x > t path is negligible (t ≥ 16 at n = 63/127: within ~1.5%),
* split — models the §3.2 3-way-split recovery the protocol actually runs;
  upper-bounds the paper's cells everywhere,

and validate the thing that actually matters operationally: the optimizers
of the two conventions bracket the paper's 318-bit optimum, and the real
protocol meets the p0 guarantee empirically (fig1 benchmark / tests).
"""
from __future__ import annotations

import numpy as np

from repro.core.markov import bound_table, optimize_parameters

from .common import Row, Timer, print_rows

PAPER = {
    8:  (0.0,   0.255, 0.327, 0.343, 0.349, 0.350),
    9:  (0.521, 0.780, 0.842, 0.857, 0.861, 0.862),
    10: (0.751, 0.927, 0.965, 0.974, 0.976, 0.977),
    11: (0.859, 0.969, 0.991, 0.995, 0.996, 0.996),
    12: (0.913, 0.985, 0.997, 0.999, None,  None),
    13: (0.939, 0.991, 0.998, None,  None,  None),
    14: (0.951, 0.994, None,  None,  None,  None),
    15: (0.956, 0.995, None,  None,  None,  None),
    16: (0.957, 0.996, None,  None,  None,  None),
    17: (0.958, 0.996, None,  None,  None,  None),
}
NS = (63, 127, 255, 511, 1023, 2047)
HIGH_T_CELLS = [((63, 16), 0.957), ((63, 17), 0.958), ((127, 17), 0.996)]


def grid(convention: str):
    return bound_table(1000, 5.0, 3, t_values=range(8, 18), n_values=NS,
                       convention=convention)


def run():
    d, delta, r, p0 = 1000, 5.0, 3, 0.99
    with Timer() as t:
        trunc = grid("truncate")
        split = grid("split")

    # (a) high-t agreement under the paper's stated convention
    high_err = max(abs(max(trunc[c], 0.0) - ref) for c, ref in HIGH_T_CELLS)
    # (b) split dominates paper dominates nothing-below-split-minus-slack
    viol = 0
    for tv, row in PAPER.items():
        for j, n in enumerate(NS):
            ref = 0.999 if row[j] is None else row[j]
            if max(split[(n, tv)], 0.0) + 5e-3 < ref:
                viol += 1
    # (c) optimizer bracket around the paper's 318 bits/group objective
    n_s, t_s, lb_s, comm_s = optimize_parameters(d, delta, r, p0, convention="split")
    n_t, t_t, lb_t, comm_t = optimize_parameters(d, delta, r, p0, convention="truncate")

    rows = [
        Row("table1/high_t_truncate_max_err", t.us, f"{high_err:.4f} (tol 0.015)"),
        Row("table1/split_upper_bounds_paper", 0.0, f"violations={viol}/60"),
        Row("table1/opt_split", 0.0, f"(n={n_s},t={t_s}) bound={lb_s:.4f} comm={comm_s:.0f}b"),
        Row("table1/opt_truncate", 0.0, f"(n={n_t},t={t_t}) bound={lb_t:.4f} comm={comm_t:.0f}b"),
        Row("table1/paper_bracket_318", 0.0,
            f"{comm_s:.0f} <= 318 <= {comm_t:.0f}: {comm_s <= 318 <= comm_t}"),
    ]
    ok = high_err < 0.015 and viol == 0 and comm_s <= 318 <= comm_t
    rows.append(Row("table1/" + ("PASS" if ok else "FAIL"), 0.0, ""))
    return print_rows(rows)


if __name__ == "__main__":
    run()
