"""Paper Fig. 2a–d: PBS vs Graphene (protocol I, B ⊂ A — Graphene's best
case), target success rate 239/240.  Claim: PBS ~1.2–7.4× less communication
except when d approaches |A| (Graphene's BF pays off only then)."""
from __future__ import annotations

import numpy as np

from repro.core.baselines import graphene_reconcile
from repro.core.pbs import PBSConfig, reconcile, true_diff
from repro.core.simdata import make_pair
from repro.core.tow import estimate_d, planned_d, tow_sketches

from .common import D_GRID, SIZE_A, TRIALS, Row, Timer, overhead_ratio, print_rows


def run():
    rng = np.random.default_rng(11)
    rows = []
    p0 = 239.0 / 240.0
    for d in D_GRID:
        size = max(SIZE_A, 2 * d)
        succ = {"pbs": 0, "gr": 0}
        byts = {"pbs": [], "gr": []}
        us = {"pbs": [], "gr": []}
        for i in range(TRIALS):
            a, b = make_pair(size, d, rng)
            td = true_diff(a, b)
            sa, sb = tow_sketches(a, 80_000 + i), tow_sketches(b, 80_000 + i)
            d_plan = planned_d(estimate_d(sa, sb))

            with Timer() as t1:
                res = reconcile(a, b, PBSConfig(seed=i, p0=p0, max_rounds=3))
            succ["pbs"] += res.success and res.diff == td
            byts["pbs"].append(res.bytes_sent)
            us["pbs"].append(t1.us)

            with Timer() as t2:
                res_g = graphene_reconcile(a, b, d_plan, seed=i)
            succ["gr"] += res_g.success and res_g.diff == td
            # subtract the 336B estimator from Graphene per the paper's §6.2
            byts["gr"].append(max(0, res_g.bytes_sent - 336))
            us["gr"].append(t2.us)

        ratio = np.mean(byts["gr"]) / max(1.0, np.mean(byts["pbs"]))
        for k, label in (("pbs", "PBS"), ("gr", "Graphene")):
            rows.append(Row(
                f"fig2/{label}_d{d}", float(np.mean(us[k])),
                f"success={succ[k]}/{TRIALS} "
                f"overhead={overhead_ratio(float(np.mean(byts[k])), d):.2f}x",
            ))
        rows.append(Row(f"fig2/comm_ratio_d{d}", 0.0,
                        f"graphene/pbs={ratio:.2f}x (paper: 1.2-7.4x)"))
    return print_rows(rows)


if __name__ == "__main__":
    run()
