"""Shared benchmark plumbing: timing, CSV rows, scaled-down defaults.

The paper's setup is |A|=1e6, d in [10, 1e5], 1000 instances/point on an
i7-9800X; this container is a single CPU core, so the default ("quick") grid
is |A|=3e4, d in {10,100,1000}, 10 trials — the *per-distinct-element*
metrics the paper reports (bytes/d, success rate) are size-invariant, which
is what we validate.  ``REPRO_BENCH_FULL=1`` raises to |A|=2e5, d up to 1e4,
30 trials.
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass, field

import numpy as np

FULL = os.environ.get("REPRO_BENCH_FULL", "0") == "1"

SIZE_A = 200_000 if FULL else 30_000
D_GRID = (10, 100, 1000, 10_000) if FULL else (10, 100, 1000)
TRIALS = 30 if FULL else 10
TRIALS_SLOW = 10 if FULL else 3  # O(d^2) PinSketch paths (the paper's point)
KEY_BITS = 32
THEO_MIN_BITS = KEY_BITS  # information-theoretic minimum per distinct element


@dataclass
class Row:
    name: str
    us_per_call: float
    derived: str
    extra: dict = field(default_factory=dict)

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.3f},{self.derived}"


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.s = time.perf_counter() - self.t0

    @property
    def us(self) -> float:
        return self.s * 1e6


def overhead_ratio(bytes_sent: int, d: int) -> float:
    """Communication overhead as a multiple of the theoretical minimum."""
    return bytes_sent * 8.0 / (d * THEO_MIN_BITS)


def print_rows(rows):
    for r in rows:
        print(r.csv(), flush=True)
    return rows
