"""Multi-session reconciliation throughput: the repro.recon engine under load.

Sweeps a sessions × d grid (DESIGN.md §5/§7).  Each point submits S
independent Alice↔Bob pairs to ``ReconcileServer``, drives every session's
full PBS protocol through the batched accelerator path, and reports

  * sessions/sec (wall clock over the whole batch, compiles included),
  * bytes per distinct element (the paper's communication metric),
  * the maximum per-session deviation of ``bytes_sent`` from the
    single-session ``core.pbs.reconcile`` oracle — the engine is the same
    state machine, so this must be 0% (the run fails above 1%).

Runs standalone (``python benchmarks/recon_throughput.py --sessions 64
--d 50``) or via ``python -m benchmarks.run`` with the quick default grid.
On this container the kernels execute in Pallas interpret mode; on TPU the
same dataflow compiles for the MXU.
"""
from __future__ import annotations

import argparse
import pathlib
import sys
import time

if __package__ in (None, ""):  # standalone: make src/ importable
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))
    from common import Row, print_rows
else:
    from .common import Row, print_rows

import numpy as np

from repro.core.pbs import PBSConfig, reconcile
from repro.core.simdata import make_pair
from repro.recon import ReconcileServer


def bench_point(sessions: int, d: int, size: int, *, check: bool = True, seed: int = 0):
    pairs = [
        make_pair(size, d, np.random.default_rng(seed + 7919 * s + d))
        for s in range(sessions)
    ]
    server = ReconcileServer()
    for s, (a, b) in enumerate(pairs):
        server.submit(a, b, cfg=PBSConfig(seed=seed + s), d_known=d)
    t0 = time.perf_counter()
    results = server.run()
    wall = time.perf_counter() - t0

    n_ok = sum(results[s].success for s in range(sessions))
    total_bytes = sum(results[s].bytes_sent for s in range(sessions))
    total_diff = sum(len(results[s].diff) for s in range(sessions))

    max_dev = 0.0
    if check:
        for s, (a, b) in enumerate(pairs):
            oracle = reconcile(a, b, PBSConfig(seed=seed + s), d_known=d)
            dev = abs(results[s].bytes_sent - oracle.bytes_sent) / oracle.bytes_sent
            max_dev = max(max_dev, dev)
        if max_dev > 0.01:
            raise AssertionError(
                f"per-session bytes deviate {max_dev:.2%} from core.pbs (>1%)"
            )

    return Row(
        name=f"recon_throughput/S{sessions}_d{d}",
        us_per_call=wall * 1e6 / sessions,
        derived=(
            f"sessions_per_s={sessions / wall:.2f} "
            f"bytes_per_diff={total_bytes / max(1, total_diff):.2f} "
            f"success={n_ok}/{sessions} "
            + (f"max_byte_dev={max_dev:.4%}" if check else "unchecked")
        ),
    )


def run():
    """Quick grid for ``python -m benchmarks.run`` (CSV rows like the others)."""
    rows = [bench_point(8, d, size=2000, check=True) for d in (10, 50)]
    return print_rows(rows)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--sessions", type=str, default="64",
                    help="comma-separated session counts (default 64)")
    ap.add_argument("--d", type=str, default="50",
                    help="comma-separated set-difference sizes (default 50)")
    ap.add_argument("--size", type=int, default=3000, help="|A| per session")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-check", action="store_true",
                    help="skip the per-session core.pbs byte validation")
    args = ap.parse_args(argv)

    grid_s = [int(x) for x in args.sessions.split(",")]
    grid_d = [int(x) for x in args.d.split(",")]
    print("name,us_per_call,derived")
    rows = []
    for sessions in grid_s:
        for d in grid_d:
            rows.append(
                bench_point(sessions, d, args.size, check=not args.no_check,
                            seed=args.seed)
            )
            print(rows[-1].csv(), flush=True)
    return rows


if __name__ == "__main__":
    main()
