"""Multi-session reconciliation throughput: the repro.recon engine under load.

Sweeps a sessions × d grid (DESIGN.md §5/§7).  Each point submits S
independent Alice↔Bob pairs to ``ReconcileServer``, drives every session's
full PBS protocol through the device-resident batched path, and reports

  * sessions/sec and rounds/sec **warm and cold, separately**: every point
    runs twice over fresh servers — the first (cold) pass pays whatever
    jit compilation its shape buckets still need, the second (warm) pass
    must hit every cache (its ``retraces_warm`` comes from the engine's
    own counter and is asserted 0).  The headline ``sessions_per_s`` is
    the warm number — steady-state throughput is what the vectorized
    planner + overlap pipeline (DESIGN.md §12) optimize — with the cold
    pass reported alongside (``cold_sessions_per_s``); ``--min-sessions-
    per-s`` turns the warm number into a hard CI gate,
  * the host↔device transfer ledger: actual H2D bytes per round (element
    store uploaded once + small per-round overlays) vs the legacy
    re-pack-per-round equivalent, and kernel launches per round (the fused
    two-side encode halves them),
  * the host-ms vs device-ms split of the round loop,
  * phase-0 estimation time: the vectorized host ToW mirror vs the Pallas
    ``tow_sketch`` kernel the server batches submit-time estimation
    through (bit-identical numerators, asserted),
  * bytes per distinct element (the paper's communication metric),
  * the *measured* wire traffic: each point re-runs as a real
    ``repro.net`` endpoint pair over the in-memory transport, asserts the
    frame-measured ledger equals the engine's accounting per session, and
    reports ``wire_bytes_per_diff`` — framed bytes actually shipped
    (DESIGN.md §9; ``--no-wire`` skips),
  * the maximum per-session deviation of ``bytes_sent`` from the
    single-session ``core.pbs.reconcile`` oracle — the engine is the same
    state machine, so this must be 0% (the run fails above 1%),
  * with ``--epochs N --churn c``: a continuous-sync sweep (DESIGN.md
    §11) — each session-count point runs N mutation epochs over ONE set
    of delta-patched device stores, recording epochs/s and the cumulative
    delta-H2D bytes against the full-rebuild equivalent
    (``delta_h2d_frac``, gated by ``--max-delta-h2d-frac``; zero store
    rebuilds after epoch 0 and per-epoch oracle byte-identity asserted),
  * with ``--chaos SEED``: a chaos-hardening point (DESIGN.md §13) — a
    4-peer continuous hub driven through mutation epochs while scripted
    faults fire (one clean-disconnect crash-restart, one silent crash
    healed through the deadline path, one peer living behind a seeded
    lossy/duplicating/reordering ARQ channel), plus a budget-exhausted
    session completed by graceful degradation — recording
    ``peers_resumed``, ``resume_replay_bytes`` and ``sessions_degraded``
    into the JSON artifact with per-epoch oracle byte-identity asserted,
  * with ``--wrongd``: the rateless-recovery point (DESIGN.md §16) — the
    same pairs planned with a 10×-underestimated d̂ and ``rateless=True``,
    recovering every overloaded group through incremental ``MSG_PARITY``
    syndromes instead of the legacy doubled-d̂ re-plan — asserting zero
    degraded sessions, store builds unchanged vs the honest plan, warm
    ``retraces == 0`` and per-session oracle byte-identity, and recording
    the measured wire bytes/diff against the honestly-planned floor
    (``wrongd_vs_honest``, gated by ``--max-wrongd-vs-honest``; CI passes
    1.6 — before the rateless ladder this ratio was ~4.3),
  * with ``--peers N1,N2,...``: a multi-peer hub sweep (DESIGN.md §10) —
    N real ``AliceEndpoint`` peers against one ``HubEndpoint`` over
    mux-enveloped in-memory transports — recording peers/s, the fused
    cross-peer launch ledger (2 encode + 1 decode launches per
    cohort-round and one store upload per cohort, both asserted), and the
    measured hub wire bytes per distinct element (gated by
    ``--max-hub-bytes-per-diff``; looser than the pair gate because each
    peer's frames can't amortize headers across its neighbors).

The full grid is also written to ``BENCH_recon.json`` (``--json`` to move
it, ``--no-json`` to skip) so CI tracks the perf trajectory; ``--min-h2d-
ratio`` turns the transfer win into a hard gate (the CI smoke job passes
3) and ``--max-bytes-per-diff`` gates the measured wire bytes per distinct
element (CI passes 9 ≈ 2.25x the 4-byte minimum for 32-bit keys).

Runs standalone (``python benchmarks/recon_throughput.py --sessions 64
--d 50``) or via ``python -m benchmarks.run`` with the quick default grid.
On this container the kernels execute in Pallas interpret mode; on TPU the
same dataflow compiles for the MXU.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

if __package__ in (None, ""):  # standalone: make src/ importable
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))
    from common import Row, print_rows
else:
    from .common import Row, print_rows

import numpy as np

from repro.core.hashing import derive_seed
from repro.core.pbs import PBSConfig, reconcile
from repro.core.simdata import make_pair
from repro.core.tow import ELL_DEFAULT, estimate_numerator, tow_seeds, tow_sketches
from repro.net import (
    AliceEndpoint,
    BobEndpoint,
    ChaosTransport,
    FaultPlan,
    HubEndpoint,
    InMemoryDuplex,
    ReliableTransport,
    TransportError,
    run_hub,
    run_pair,
)
from repro.net.hub import _drive_hub
from repro.obs import Tracer
from repro.recon import ReconcileServer, phase0_numerators


def _phase0_times(pairs, seed):
    """Phase-0 ToW estimation over the whole batch: host numpy mirror vs
    the Pallas kernel path the server routes submit-time estimation
    through.  Both produce bit-identical numerators (asserted)."""
    seeds_list = [
        tow_seeds(derive_seed(seed + s, 0x70), ELL_DEFAULT)
        for s in range(len(pairs))
    ]
    t0 = time.perf_counter()
    host = [
        estimate_numerator(
            tow_sketches(a, derive_seed(seed + s, 0x70)),
            tow_sketches(b, derive_seed(seed + s, 0x70)),
        )
        for s, (a, b) in enumerate(pairs)
    ]
    host_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    dev = phase0_numerators(pairs, seeds_list)
    device_s = time.perf_counter() - t0
    if host != dev:
        raise AssertionError(f"phase-0 kernel diverged from host: {host} != {dev}")
    return host_s, device_s


def _wire_measurement(pairs, d, seed, results):
    """Re-run the batch as two repro.net endpoints over the in-memory
    transport and *measure* the wire traffic.  Per-session ledgers must
    equal the in-process engine's accounting exactly; the framed protocol
    bytes (ledger + structural overhead, sans the estimator/verify
    exchanges) are what the --max-bytes-per-diff gate inspects."""
    ta, tb = InMemoryDuplex.pair()
    alice, bob = AliceEndpoint(ta), BobEndpoint(tb)
    for s, (a, b) in enumerate(pairs):
        cfg = PBSConfig(seed=seed + s)
        alice.submit(a, cfg=cfg, d_known=d)
        bob.submit(b, cfg=cfg, d_known=d)
    t0 = time.perf_counter()
    wres = run_pair(alice, bob)
    wall = time.perf_counter() - t0
    for s in range(len(pairs)):
        if wres[s].bytes_per_round != results[s].bytes_per_round:
            raise AssertionError(
                f"sid {s}: measured wire ledger {wres[s].bytes_per_round} != "
                f"engine accounting {results[s].bytes_per_round}"
            )
    stats = alice.wire_stats
    ledger = sum(wres[s].bytes_sent for s in range(len(pairs)))
    return {
        "wire_wall_s": round(wall, 4),
        "wire_protocol_bytes": stats["protocol_frame_bytes"],
        "wire_overhead_bytes": stats["protocol_frame_bytes"] - ledger,
        "wire_verify_bytes": stats["verify_frame_bytes"],
    }


def _run_batch(pairs, d, *, seed, tracer=None):
    """One fresh-server pass over the pairs; (server, results, wall_s)."""
    server = ReconcileServer(tracer=tracer)
    for s, (a, b) in enumerate(pairs):
        server.submit(a, b, cfg=PBSConfig(seed=seed + s), d_known=d)
    t0 = time.perf_counter()
    results = server.run()
    return server, results, time.perf_counter() - t0


def bench_point(sessions: int, d: int, size: int, *, check: bool = True, seed: int = 0,
                wire: bool = True, trace_path: str | None = None):
    pairs = [
        make_pair(size, d, np.random.default_rng(seed + 7919 * s + d))
        for s in range(sessions)
    ]
    # cold pass: pays any compilation this point's shape buckets still
    # need; warm pass: a fresh server over the same workload, every jit
    # signature already cached — the steady-state number CI gates on
    cold_server, _, cold_wall = _run_batch(pairs, d, seed=seed)
    server, results, wall = _run_batch(pairs, d, seed=seed)
    if server.stats["retraces"]:
        raise AssertionError(
            f"warm pass recompiled {server.stats['retraces']} kernel "
            "signatures — a shape escaped its pow2 bucket"
        )

    n_ok = sum(results[s].success for s in range(sessions))
    total_bytes = sum(results[s].bytes_sent for s in range(sessions))
    total_diff = sum(len(results[s].diff) for s in range(sessions))

    max_dev = 0.0
    if check:
        for s, (a, b) in enumerate(pairs):
            oracle = reconcile(a, b, PBSConfig(seed=seed + s), d_known=d)
            dev = abs(results[s].bytes_sent - oracle.bytes_sent) / oracle.bytes_sent
            max_dev = max(max_dev, dev)
        if max_dev > 0.01:
            raise AssertionError(
                f"per-session bytes deviate {max_dev:.2%} from core.pbs (>1%)"
            )

    obs_overhead_frac = None
    trace_events = None
    if trace_path:
        # third warm pass, tracing on: the gated number above stays
        # untraced; this one exports the Chrome timeline and prices the
        # observability tax as (traced - untraced) / untraced warm wall
        tracer = Tracer()
        traced_server, _, traced_wall = _run_batch(
            pairs, d, seed=seed, tracer=tracer)
        if traced_server.stats["retraces"]:
            raise AssertionError("traced warm pass recompiled kernels")
        trace_events = tracer.export_chrome(trace_path)
        obs_overhead_frac = round((traced_wall - wall) / wall, 4)

    phase0_host_s, phase0_device_s = _phase0_times(pairs, seed)
    st = server.stats
    point = {
        "sessions": sessions,
        "d": d,
        "size": size,
        "wall_s": round(wall, 4),
        "sessions_per_s": round(sessions / wall, 3),
        "cold_wall_s": round(cold_wall, 4),
        "cold_sessions_per_s": round(sessions / cold_wall, 3),
        "retraces_cold": cold_server.stats["retraces"],
        "retraces_warm": st["retraces"],
        "rounds": st["rounds"],
        "rounds_per_s": round(st["rounds"] / wall, 3),
        "cohort_rounds": st["cohort_rounds"],
        "h2d_store_bytes": st["h2d_store_bytes"],
        "h2d_round_bytes": st["h2d_round_bytes"],
        "h2d_bytes_per_round": round(st["h2d_bytes_per_round"], 1),
        "legacy_h2d_bytes_per_round": round(st["legacy_h2d_bytes_per_round"], 1),
        "h2d_ratio": round(st["h2d_ratio"], 3),
        "kernel_launches_per_round": st["kernel_launches"] / max(1, st["rounds"]),
        "legacy_kernel_launches_per_round": st["legacy_kernel_launches"]
        / max(1, st["rounds"]),
        "host_ms": round(st["host_s"] * 1e3, 2),
        "device_ms": round(st["device_s"] * 1e3, 2),
        "phase0_host_ms": round(phase0_host_s * 1e3, 2),
        "phase0_device_ms": round(phase0_device_s * 1e3, 2),
        "bytes_per_diff": round(total_bytes / max(1, total_diff), 2),
        "success": n_ok,
        "max_byte_dev": max_dev if check else None,
    }
    if trace_path:
        point["obs_overhead_frac"] = obs_overhead_frac
        point["trace_events"] = trace_events
    if wire:
        point.update(_wire_measurement(pairs, d, seed, results))
        point["wire_bytes_per_diff"] = round(
            point["wire_protocol_bytes"] / max(1, total_diff), 2
        )
    row = Row(
        name=f"recon_throughput/S{sessions}_d{d}",
        us_per_call=wall * 1e6 / sessions,
        derived=(
            f"sessions_per_s={sessions / wall:.2f} "
            f"cold_sessions_per_s={point['cold_sessions_per_s']:.2f} "
            f"rounds_per_s={point['rounds_per_s']:.2f} "
            f"h2d_ratio={point['h2d_ratio']:.2f} "
            f"bytes_per_diff={point['bytes_per_diff']:.2f} "
            + (
                f"wire_bytes_per_diff={point['wire_bytes_per_diff']:.2f} "
                if wire else ""
            )
            + f"success={n_ok}/{sessions} "
            + (f"max_byte_dev={max_dev:.4%}" if check else "unchecked")
        ),
    )
    return row, point


def wrongd_bench_point(sessions: int, d: int, size: int, *, seed: int = 0,
                       factor: int = 10):
    """Rateless recovery under a ``factor``×-underestimated d̂
    (DESIGN.md §16).

    Every group overloads its round-1 decode budget; with
    ``rateless=True`` the receiver ships only the incremental BCH
    syndromes S_{2t+1}..S_{2t'-1} in ``MSG_PARITY`` frames and re-decodes
    the concatenation at t' — no settled bits re-sent, no store rebuilt,
    no session through the degradation ladder.  Asserts all of that (plus
    warm ``retraces == 0`` and per-session byte-identity to the
    ``core.pbs.reconcile`` oracle, whose ladder is the spec), measures
    the wire pair both wrong-d̂ and honestly planned, and reports the
    bytes/diff ratio the ``--max-wrongd-vs-honest`` gate inspects.
    """
    pairs = [
        make_pair(size, d, np.random.default_rng(seed + 7919 * s + d))
        for s in range(sessions)
    ]
    d_hat = max(1, d // factor)

    def _cfg(s):
        return PBSConfig(seed=seed + s, rateless=True)

    def _serve(dk):
        srv = ReconcileServer(degrade=True)
        for s, (a, b) in enumerate(pairs):
            srv.submit(a, b, cfg=_cfg(s), d_known=dk)
        t0 = time.perf_counter()
        return srv, srv.run(), time.perf_counter() - t0

    # the honest floor: identical pairs, exact d̂ — its store-build count
    # is the budget the recovery path must not exceed
    honest_srv, _, _ = _serve(d)
    # wrong-d̂ cold + warm passes (warm is the reported number)
    cold_srv, _, cold_wall = _serve(d_hat)
    srv, results, wall = _serve(d_hat)
    st = srv.stats
    if st["retraces"]:
        raise AssertionError(
            f"warm wrong-d̂ pass recompiled {st['retraces']} kernel signatures"
        )
    if st["sessions_degraded"]:
        raise AssertionError(
            f"{st['sessions_degraded']} sessions took the from-scratch "
            "re-plan ladder despite the rateless path"
        )
    if not st["parity_extensions"]:
        raise AssertionError("wrong-d̂ point fired no parity extensions")
    if st["store_builds"] != honest_srv.stats["store_builds"]:
        raise AssertionError(
            f"recovery rebuilt stores: {st['store_builds']} builds vs "
            f"{honest_srv.stats['store_builds']} under the honest plan"
        )
    for s, (a, b) in enumerate(pairs):
        oracle = reconcile(a, b, _cfg(s), d_known=d_hat)
        if (results[s].bytes_per_round != oracle.bytes_per_round
                or results[s].diff != oracle.diff):
            raise AssertionError(
                f"sid {s}: wrong-d̂ engine result diverged from core.pbs"
            )

    def _wire(dk):
        ta, tb = InMemoryDuplex.pair()
        alice, bob = AliceEndpoint(ta), BobEndpoint(tb)
        for s, (a, b) in enumerate(pairs):
            alice.submit(a, cfg=_cfg(s), d_known=dk)
            bob.submit(b, cfg=_cfg(s), d_known=dk)
        wres = run_pair(alice, bob)
        bpd = alice.wire_stats["protocol_frame_bytes"] / max(
            1, sum(len(wres[s].diff) for s in range(sessions)))
        return alice, wres, bpd

    alice_w, wres, wrongd_bpd = _wire(d_hat)
    for s in range(sessions):
        if wres[s].bytes_per_round != results[s].bytes_per_round:
            raise AssertionError(
                f"sid {s}: measured wrong-d̂ wire ledger diverged from "
                "the engine accounting"
            )
    if alice_w.sessions_degraded or not alice_w.parity_extensions:
        raise AssertionError("wire pair did not recover ratelessly")
    _, _, honest_bpd = _wire(d)
    ratio = wrongd_bpd / honest_bpd

    point = {
        "wrongd": True,
        "sessions": sessions,
        "d": d,
        "d_hat": d_hat,
        "size": size,
        "wall_s": round(wall, 4),
        "cold_wall_s": round(cold_wall, 4),
        "sessions_per_s": round(sessions / wall, 3),
        "retraces_cold": cold_srv.stats["retraces"],
        "retraces_warm": st["retraces"],
        "rounds": st["rounds"],
        "parity_extensions": st["parity_extensions"],
        "sessions_degraded": st["sessions_degraded"],
        "store_builds": st["store_builds"],
        "wire_bytes_per_diff": round(wrongd_bpd, 2),
        "honest_wire_bytes_per_diff": round(honest_bpd, 2),
        "wrongd_vs_honest": round(ratio, 3),
    }
    row = Row(
        name=f"recon_throughput/wrongd_S{sessions}_d{d}",
        us_per_call=wall * 1e6 / sessions,
        derived=(
            f"wire_bytes_per_diff={wrongd_bpd:.2f} "
            f"honest={honest_bpd:.2f} "
            f"wrongd_vs_honest={ratio:.2f} "
            f"parity_extensions={st['parity_extensions']} "
            f"sessions_degraded=0 store_builds={st['store_builds']}"
        ),
    )
    return row, point


def hub_bench_point(peers: int, d: int, size: int, *, seed: int = 0):
    """One multi-peer hub point: N real peers against one ``HubEndpoint``
    over in-memory transports, every frame mux-enveloped (DESIGN.md §10).

    Reports peers/s, the fused-launch ledger (2 encode kernels + 1 decode
    launch per cohort-round, shared across all peers — asserted), one store
    upload per cohort (asserted), and the measured wire bytes per distinct
    element including the mux-envelope overhead the hub adds.
    """
    hub = HubEndpoint(recv_deadline=300.0)
    alices: dict[int, AliceEndpoint] = {}
    for p in range(peers):
        a, b = make_pair(size, d, np.random.default_rng(seed + 6007 * p + d))
        cfg = PBSConfig(seed=seed + p)
        ta, tb = InMemoryDuplex.pair()
        ch = hub.add_peer(tb)
        hub.submit(ch, b, cfg=cfg, d_known=d)
        ep = AliceEndpoint(ta, channel=ch)
        ep.submit(a, cfg=cfg, d_known=d)
        alices[ch] = ep

    t0 = time.perf_counter()
    outcomes, results, errors = run_hub(hub, alices)
    wall = time.perf_counter() - t0
    if errors:
        raise AssertionError(f"hub peers failed: {errors}")
    if not all(o.ok and o.verified == [True] for o in outcomes.values()):
        raise AssertionError("hub verification failed")

    st = hub.stats
    cohorts = {s.code_key for o in outcomes.values() for s in o.sessions}
    if st["store_uploads"] != len(cohorts):
        raise AssertionError(
            f"{st['store_uploads']} store uploads for {len(cohorts)} cohorts"
        )
    if st["kernel_launches"] != 2 * st["cohort_rounds"]:
        raise AssertionError("hub encode launches not fused (2/cohort-round)")

    total_diff = sum(len(r[0].diff) for r in results.values())
    proto = sum(o.wire_stats["protocol_frame_bytes"] for o in outcomes.values())
    mux = sum(
        o.wire_stats["mux_bytes_in"] + o.wire_stats["mux_bytes_out"]
        for o in outcomes.values()
    )
    point = {
        "hub": True,
        "peers": peers,
        "d": d,
        "size": size,
        "wall_s": round(wall, 4),
        "peers_per_s": round(peers / wall, 3),
        "rounds": st["rounds"],
        "cohort_rounds": st["cohort_rounds"],
        "kernel_launches": st["kernel_launches"],
        "decode_launches": st["decode_launches"],
        "fused_launches_per_round": round(
            (st["kernel_launches"] + st["decode_launches"])
            / max(1, st["rounds"]), 2
        ),
        "store_uploads": st["store_uploads"],
        "h2d_store_bytes": st["h2d_store_bytes"],
        "h2d_round_bytes": st["h2d_round_bytes"],
        "wire_protocol_bytes": proto,
        "wire_mux_overhead_bytes": mux,
        "wire_bytes_per_diff": round(proto / max(1, total_diff), 2),
    }
    row = Row(
        name=f"recon_throughput/hub_N{peers}_d{d}",
        us_per_call=wall * 1e6 / peers,
        derived=(
            f"peers_per_s={point['peers_per_s']:.2f} "
            f"cohort_rounds={st['cohort_rounds']} "
            f"fused_launches_per_round={point['fused_launches_per_round']} "
            f"store_uploads={st['store_uploads']} "
            f"wire_bytes_per_diff={point['wire_bytes_per_diff']:.2f}"
        ),
    )
    return row, point


def epoch_bench_point(sessions: int, size: int, epochs: int, churn: float,
                      *, seed: int = 0, check: bool = True):
    """Continuous-sync sweep (DESIGN.md §11): S long-lived sessions driven
    through ``epochs`` reconciliation epochs with ``churn``·|B| elements
    replaced between epochs, all over ONE set of device-resident stores.

    Records epochs/s and the delta ledger the delta-mutable stores are
    optimizing: cumulative delta-H2D bytes vs what rebuilding (and
    re-uploading) the stores every epoch would have shipped
    (``delta_h2d_frac``, gated by ``--max-delta-h2d-frac``).  Asserts zero
    store rebuilds after epoch 0 and, with ``check``, per-epoch
    byte-identity against the ``core.pbs.reconcile`` oracle.
    """
    d = max(2, 2 * round(churn * size / 2))     # per-epoch symmetric diff
    rng = np.random.default_rng(seed + 4099)
    server = ReconcileServer(continuous=True)
    for s in range(sessions):
        a, b = make_pair(size, d, np.random.default_rng(seed + 5881 * s))
        server.submit(a, b, cfg=PBSConfig(seed=seed + s), d_known=d)
    server.run()
    store_bytes = server.stats["h2d_store_bytes"]

    delta_bytes = rounds = total_bytes = total_diff = 0
    t0 = time.perf_counter()
    for _ in range(epochs):
        muts = {}
        for s in range(sessions):
            b_cur = server.sessions[s].state.b
            k_rem = d // 2
            muts[s] = (
                np.zeros(0, np.uint32), np.zeros(0, np.uint32),
                rng.integers(1, 1 << 32, size=d - k_rem,
                             dtype=np.uint64).astype(np.uint32),
                rng.permutation(b_cur)[:k_rem],
            )
        server.advance_epoch(muts)
        results = server.run()
        st = server.stats
        if st["store_builds"]:
            raise AssertionError(
                f"{st['store_builds']} store rebuilds on the delta path"
            )
        delta_bytes += st["h2d_delta_bytes"]
        rounds += st["rounds"]
        for s in range(sessions):
            r = results[s]
            total_bytes += r.bytes_sent
            total_diff += len(r.diff)
            if check:
                sess = server.sessions[s]
                oracle = reconcile(sess.state.a, sess.state.b,
                                   PBSConfig(seed=seed + s), d_known=d)
                if (r.diff != oracle.diff
                        or r.bytes_per_round != oracle.bytes_per_round):
                    raise AssertionError(
                        f"sid {s}: epoch result diverged from core.pbs"
                    )
    wall = time.perf_counter() - t0

    rebuild_bytes = epochs * store_bytes        # the path delta replaces
    frac = delta_bytes / max(1, rebuild_bytes)
    point = {
        "epochs": epochs,
        "churn": churn,
        "sessions": sessions,
        "d": d,
        "size": size,
        "wall_s": round(wall, 4),
        "epochs_per_s": round(epochs / wall, 3),
        "rounds": rounds,
        "store_bytes": store_bytes,
        "delta_h2d_bytes": delta_bytes,
        "full_rebuild_bytes": rebuild_bytes,
        "delta_h2d_frac": round(frac, 4),
        "store_builds_after_epoch0": 0,
        "bytes_per_diff": round(total_bytes / max(1, total_diff), 2),
        "checked": check,
    }
    row = Row(
        name=f"recon_throughput/epochs{epochs}_S{sessions}_c{churn}",
        us_per_call=wall * 1e6 / epochs,
        derived=(
            f"epochs_per_s={point['epochs_per_s']:.2f} "
            f"delta_h2d_frac={frac:.3f} "
            f"delta_h2d_bytes={delta_bytes} "
            f"bytes_per_diff={point['bytes_per_diff']:.2f} "
            + ("oracle-checked" if check else "unchecked")
        ),
    )
    return row, point


def chaos_bench_point(seed: int, *, size: int = 700, d: int = 60,
                      epochs: int = 3, check: bool = True):
    """Chaos-hardening point (DESIGN.md §13): the resilience machinery
    under scripted faults, timed and ledgered.

    A 4-peer continuous hub runs ``epochs`` churn epochs: peer 0
    crash-restarts by clean disconnect and peer 1 by silent crash (both at
    the first round barrier of epoch 1, resuming mid-epoch via
    MSG_RESUME), peer 2 lives its whole life behind a seeded
    lossy/duplicating/reordering ARQ channel, peer 3 is clean.  A separate
    budget-exhausted session then completes through the degradation
    ladder.  Records ``peers_resumed``, ``resume_replay_bytes`` and
    ``sessions_degraded`` — the chaos stats CI tracks — with zero store
    rebuilds, zero peer failures and (with ``check``) per-epoch oracle
    byte-identity asserted.
    """
    cfg_kw = dict(n_override=127, t_override=7, g_override=4)
    rng = np.random.default_rng(seed)
    hub = HubEndpoint(recv_deadline=4.0, continuous=True, resume_window=60.0)
    alices: dict[int, AliceEndpoint] = {}
    cfgs: dict[int, PBSConfig] = {}
    conn: dict[int, dict] = {}
    plan2 = FaultPlan(seed=seed + 50, loss=0.08, burst_every=40, burst_len=2,
                      dup=0.06, reorder=0.06, partitions=((120, 126),))
    for p in range(4):
        a, b = make_pair(size, d, np.random.default_rng(seed + 101 * p))
        cfg = PBSConfig(seed=seed + p, **cfg_kw)
        if p == 2:
            raw_a, raw_h = InMemoryDuplex.pair()
            chaos = ChaosTransport(raw_a, plan2)
            ta = ReliableTransport(chaos, timeout=0.02, max_retries=400,
                                   seed=p)
            th = ReliableTransport(raw_h, timeout=0.02, max_retries=400,
                                   seed=100 + p)
        else:
            ta, th = InMemoryDuplex.pair()
            chaos = None
            if p == 1:
                chaos = ChaosTransport(ta, FaultPlan(crash_silent=True))
                ta = chaos
        ch = hub.add_peer(th, label=f"peer{p}")
        hub.submit(ch, b, cfg=cfg, d_known=d)
        ep = AliceEndpoint(ta, channel=ch, continuous=True)
        ep.submit(a, cfg=cfg, d_known=d)
        alices[ch] = ep
        cfgs[ch] = cfg
        conn[ch] = {"ta": ta, "chaos": chaos}
        if p == 0:
            ch0 = ch
        elif p == 1:
            ch1 = ch
        elif p == 2:
            ch2 = ch

    pending: dict = {}
    trigger = {"armed": False}

    def on_barrier(rnd):
        if trigger["armed"] and rnd >= 1:
            trigger["armed"] = False
            conn[ch0]["ta"].close()           # clean disconnect
            conn[ch1]["chaos"]._crash()       # dark peer: deadline path
        for ch in list(pending):
            if hub._peers[ch].suspended:
                hub.resume_peer(ch, pending.pop(ch))

    hub.on_barrier = on_barrier

    def _mk(ch, fn):
        def call():
            try:
                return fn()
            except TransportError:
                pass
            raw_a, nh = InMemoryDuplex.pair()
            if ch == ch1:
                chaos = ChaosTransport(raw_a, FaultPlan(crash_silent=True))
                conn[ch].update(ta=chaos, chaos=chaos)
                ta = chaos
            else:
                conn[ch].update(ta=raw_a, chaos=None)
                ta = raw_a
            pending[ch] = nh
            alices[ch].resume(ta)
            return alices[ch].resume_run()
        return call

    def _fresh(k):
        return rng.integers(1, 1 << 32, size=k,
                            dtype=np.uint64).astype(np.uint32)

    outcomes, results, errors = _drive_hub(
        hub, {ch: _mk(ch, ep.run) for ch, ep in alices.items()},
        join_timeout=120.0)
    if errors or not all(o.ok for o in outcomes.values()):
        raise AssertionError(f"chaos warmup epoch failed: {errors}")

    t0 = time.perf_counter()
    for e in range(1, epochs + 1):
        hub_muts, alice_muts = {}, {}
        for ch, ep in alices.items():
            b_cur = hub._peers[ch].sessions[0].state.b
            hub_muts[ch] = {0: (_fresh(24), rng.permutation(b_cur)[:24])}
            a_cur = ep.sessions[0].state.a
            alice_muts[ch] = {0: (_fresh(6), rng.permutation(a_cur)[:6])}
        hub.advance_epoch(hub_muts)
        for ch, ep in alices.items():
            ep.advance_epoch(alice_muts[ch])
        if e == 1:
            trigger["armed"] = True
        outcomes, results, errors = _drive_hub(
            hub, {ch: _mk(ch, ep.run_epoch) for ch, ep in alices.items()},
            join_timeout=120.0)
        if errors or not all(o.ok for o in outcomes.values()):
            raise AssertionError(f"chaos epoch {e} failed: {errors}")
        if e == 1 and not (outcomes[ch0].error_kind == "resumed"
                           and outcomes[ch1].error_kind == "resumed"):
            raise AssertionError("crashed peers did not resume")
        if check:
            for ch, ep in alices.items():
                oracle = reconcile(ep.sessions[0].state.a,
                                   hub._peers[ch].sessions[0].state.b,
                                   cfgs[ch], d_known=d)
                r = results[ch][0]
                if (r.bytes_per_round != oracle.bytes_per_round
                        or r.diff != oracle.diff):
                    raise AssertionError(
                        f"epoch {e} ch {ch}: chaos run diverged from core.pbs"
                    )
    wall = time.perf_counter() - t0

    st = hub.stats
    if st["store_builds"] or st.get("peers_failed", 0):
        raise AssertionError(f"chaos run rebuilt stores or failed peers: {st}")
    if st["peers_resumed"] < 2:
        raise AssertionError(f"expected >=2 resumptions, got {st}")
    chaos2 = conn[ch2]["chaos"]         # the lossy-ARQ peer's injector
    if chaos2.crashed or not chaos2.dropped:
        raise AssertionError("the lossy peer saw no chaos — plan inert")
    retrans = sum(ep.wire_stats.get("retransmits", 0)
                  for ep in alices.values())

    # graceful degradation: a hopeless d̂ = 250 against d = 1000 exhausts
    # the round budget; the escalation ladder completes it anyway
    rngd = np.random.default_rng(seed + 11)
    univ = rngd.choice(1 << 20, size=4000, replace=False).astype(np.uint32)
    th_a, th_h = InMemoryDuplex.pair()
    dhub = HubEndpoint(degrade=True, recv_deadline=30.0)
    dcfg = PBSConfig(seed=seed + 5, max_rounds=2)
    dch = dhub.add_peer(th_h)
    dhub.submit(dch, univ[500:], cfg=dcfg, d_known=250)
    dep = AliceEndpoint(th_a, channel=dch, degrade=True)
    dep.submit(univ[:3500], cfg=dcfg, d_known=250)
    _, dresults, derrors = run_hub(dhub, {dch: dep})
    if derrors or not dresults[dch][0].success:
        raise AssertionError(f"degradation run failed: {derrors}")
    degraded = dhub.stats["sessions_degraded"]
    if degraded < 1:
        raise AssertionError("exhausted session completed without escalating")

    point = {
        "chaos": True,
        "chaos_seed": seed,
        "peers": len(alices),
        "d": d,
        "size": size,
        "epochs": epochs,
        "wall_s": round(wall, 4),
        "epochs_per_s": round(epochs / wall, 3),
        "peers_resumed": st["peers_resumed"],
        "resume_replay_bytes": st["resume_replay_bytes"],
        "sessions_degraded": degraded,
        "peers_failed": st.get("peers_failed", 0),
        "store_builds": st["store_builds"],
        "retransmits": retrans,
        "chaos_dropped": chaos2.dropped,
        "chaos_duplicated": chaos2.duplicated,
        "chaos_reordered": chaos2.reordered,
        "checked": check,
    }
    row = Row(
        name=f"recon_throughput/chaos_seed{seed}_e{epochs}",
        us_per_call=wall * 1e6 / epochs,
        derived=(
            f"epochs_per_s={point['epochs_per_s']:.2f} "
            f"peers_resumed={st['peers_resumed']} "
            f"resume_replay_bytes={st['resume_replay_bytes']} "
            f"sessions_degraded={degraded} "
            f"retransmits={retrans} "
            + ("oracle-checked" if check else "unchecked")
        ),
    )
    return row, point


def tree_bench_point(size: int, d_frac: float, *, seed: int = 0):
    """Tree front end vs plain PBS when no sane d̂ exists (DESIGN.md §15).

    Builds a pair whose symmetric difference is ``d_frac`` of the union —
    the cold-start / long-offline regime where the ToW estimator is out of
    its envelope — and races three contenders on bytes per distinct
    element:

      * ``tree``: the recursive range-partition walk + leaf PBS sessions
        (no prior d at all; its ledger is digest frames + PBS bits),
      * ``honest``: ``core.pbs.reconcile`` told the exact d — the floor
        no oracle-less protocol can beat,
      * ``wrongd``: ``core.pbs.reconcile`` with a 10× overestimated d̂ —
        what actually happens when a stale estimate is trusted (t grows
        with d̂, so every round ships ~10× the sketch bytes).

    The tree must always beat ``wrongd`` (asserted here, not just gated)
    and ``--max-tree-vs-honest`` bounds its overhead over the floor.
    """
    from repro.tree import TreeConfig, partition_pair, tree_reconcile

    rng = np.random.default_rng(seed + 77)
    union = int(size)
    d = max(2, int(d_frac * union))
    half = d // 2
    univ = np.unique(
        rng.choice(1 << 32, size=union, replace=False).astype(np.uint32)
    )
    a = univ[: union - d + half]                      # shared + a-only
    b = np.concatenate([univ[: union - d], univ[union - d + half :]])
    cfg = PBSConfig(seed=seed)

    t0 = time.perf_counter()
    tr = tree_reconcile(a, b, cfg, TreeConfig())
    wall = time.perf_counter() - t0
    if not tr.success:
        raise AssertionError("tree walk failed to reconcile")
    n_diff = max(1, len(tr.diff))
    tree_bpd = tr.total_bytes / n_diff

    honest = reconcile(a, b, cfg, d_known=d)
    wrongd = reconcile(a, b, cfg, d_known=10 * d)
    if not (honest.success and wrongd.success):
        raise AssertionError("plain-PBS contenders failed")
    honest_bpd = honest.bytes_sent / max(1, len(honest.diff))
    wrongd_bpd = wrongd.bytes_sent / max(1, len(wrongd.diff))
    if tree_bpd >= wrongd_bpd:
        raise AssertionError(
            f"tree {tree_bpd:.2f} B/diff does not beat 10x-wrong-d̂ PBS "
            f"{wrongd_bpd:.2f} B/diff"
        )

    # warm re-walk: the digest sweep must hit every jit cache
    _, warm = partition_pair(a, b, TreeConfig())
    if warm.retraces:
        raise AssertionError(f"warm re-walk retraced {warm.retraces} kernels")

    st = tr.stats
    point = {
        "tree": True,
        "d_frac": d_frac,
        "d": d,
        "size": union,
        "wall_s": round(wall, 4),
        "tree_levels": st.levels,
        "tree_depth": st.depth,
        "tree_leaves": st.leaves,
        "tree_launches_per_level": round(st.launches / max(1, st.levels), 2),
        "tree_digest_bytes": tr.tree_bytes,
        "pbs_bytes": tr.pbs_bytes,
        "total_bytes": tr.total_bytes,
        "bytes_per_diff": round(tree_bpd, 2),
        "honest_bytes_per_diff": round(honest_bpd, 2),
        "wrongd_bytes_per_diff": round(wrongd_bpd, 2),
        "tree_vs_honest": round(tree_bpd / honest_bpd, 3),
        "retraces_warm": warm.retraces,
    }
    row = Row(
        name=f"recon_throughput/tree_f{d_frac}_U{union}",
        us_per_call=wall * 1e6,
        derived=(
            f"bytes_per_diff={tree_bpd:.2f} "
            f"honest={honest_bpd:.2f} wrongd={wrongd_bpd:.2f} "
            f"tree_vs_honest={point['tree_vs_honest']:.2f} "
            f"levels={st.levels} leaves={st.leaves} "
            f"launches_per_level={point['tree_launches_per_level']}"
        ),
    )
    return row, point


def write_json(points: list[dict], path: str) -> None:
    """BENCH_recon.json: the perf-trajectory artifact CI tracks per PR."""
    doc = {
        "bench": "recon_throughput",
        "grid": [
            {k: p[k] for k in ("sessions", "peers", "d", "d_hat") if k in p}
            for p in points
        ],
        "points": points,
    }
    pathlib.Path(path).write_text(json.dumps(doc, indent=1) + "\n")


def run():
    """Quick grid for ``python -m benchmarks.run`` (CSV rows like the others).

    The JSON artifact is anchored to the repo root (where .gitignore covers
    it) rather than the caller's cwd.
    """
    rows = []
    points = []
    for d in (10, 50):
        row, point = bench_point(8, d, size=2000, check=True)
        rows.append(row)
        points.append(point)
    row, point = wrongd_bench_point(2, 100, size=2000)
    rows.append(row)
    points.append(point)
    row, point = hub_bench_point(4, 10, size=1200)
    rows.append(row)
    points.append(point)
    row, point = epoch_bench_point(4, size=1500, epochs=3, churn=0.05)
    rows.append(row)
    points.append(point)
    write_json(points, pathlib.Path(__file__).resolve().parents[1] / "BENCH_recon.json")
    return print_rows(rows)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--sessions", type=str, default="64",
                    help="comma-separated session counts (default 64)")
    ap.add_argument("--d", type=str, default="50",
                    help="comma-separated set-difference sizes (default 50)")
    ap.add_argument("--size", type=int, default=3000, help="|A| per session")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-check", action="store_true",
                    help="skip the per-session core.pbs byte validation")
    ap.add_argument("--no-wire", action="store_true",
                    help="skip the two-endpoint wire-byte measurement")
    ap.add_argument("--peers", type=str, default="",
                    help="comma-separated hub peer counts: each N runs a "
                         "multi-peer HubEndpoint sweep (N real peers, mux "
                         "envelopes, fused cross-peer launches asserted)")
    ap.add_argument("--epochs", type=int, default=0,
                    help="continuous-sync sweep: drive each session-count "
                         "point through N mutation epochs over one set of "
                         "delta-patched device stores (0 = skip)")
    ap.add_argument("--churn", type=float, default=0.05,
                    help="fraction of |B| replaced between epochs for the "
                         "--epochs sweep (default 0.05)")
    ap.add_argument("--chaos", type=int, default=None, metavar="SEED",
                    help="run the seeded chaos-hardening point: crash-"
                         "restart + silent-crash resumption, ARQ over a "
                         "lossy channel, and the degradation ladder, "
                         "recording peers_resumed / resume_replay_bytes / "
                         "sessions_degraded (None = skip)")
    ap.add_argument("--wrongd", action="store_true",
                    help="run the rateless-recovery point (DESIGN.md §16): "
                         "each d in the grid re-planned with a 10x-under"
                         "estimated d̂ and rateless=True, asserting zero "
                         "degraded sessions / unchanged store builds / "
                         "oracle byte-identity and recording the measured "
                         "wire bytes/diff vs the honest plan")
    ap.add_argument("--max-wrongd-vs-honest", type=float, default=0.0,
                    help="fail if any --wrongd point's wire bytes/diff "
                         "exceed this multiple of the honestly-planned "
                         "floor (CI passes 1.6; the legacy re-plan ladder "
                         "sat at ~4.3)")
    ap.add_argument("--tree", action="store_true",
                    help="run the tree-front-end point (DESIGN.md §15): a "
                         "d-frac-of-the-union cold-start pair reconciled "
                         "through the range-partition walk, recording its "
                         "bytes/diff against plain PBS at honest and "
                         "10x-wrong d̂ (the tree must beat the wrong-d̂ "
                         "contender; asserted)")
    ap.add_argument("--d-frac", type=float, default=0.5,
                    help="symmetric-difference fraction of the union for "
                         "the --tree point (default 0.5)")
    ap.add_argument("--max-tree-vs-honest", type=float, default=0.0,
                    help="fail if the --tree point's bytes/diff exceed this "
                         "multiple of the honest-d̂ plain-PBS floor (CI "
                         "passes 1.5)")
    ap.add_argument("--trace", type=str, default=None, metavar="PATH",
                    help="run each pair point a third time with repro.obs "
                         "tracing on, export the Chrome trace (Perfetto-"
                         "loadable) to PATH, and record obs_overhead_frac "
                         "(traced vs untraced warm wall) into the JSON; "
                         "the gated warm numbers stay untraced")
    ap.add_argument("--json", type=str, default="BENCH_recon.json",
                    help="path for the JSON artifact (default BENCH_recon.json)")
    ap.add_argument("--no-json", action="store_true", help="skip the JSON artifact")
    ap.add_argument("--min-sessions-per-s", type=float, default=0.0,
                    help="fail if any pair point's WARM sessions/s falls "
                         "below this (the vectorized-planner throughput "
                         "gate; cold numbers are reported, not gated)")
    ap.add_argument("--min-h2d-ratio", type=float, default=0.0,
                    help="fail if any point's H2D transfer win drops below this")
    ap.add_argument("--max-bytes-per-diff", type=float, default=0.0,
                    help="fail if any pair point's MEASURED wire bytes per "
                         "distinct element exceed this (4 B/diff = the "
                         "32-bit minimum)")
    ap.add_argument("--max-hub-bytes-per-diff", type=float, default=0.0,
                    help="same gate for the hub sweep points; hub frames "
                         "don't amortize headers across a peer's neighbors "
                         "(one stream per peer), so the bound is looser")
    ap.add_argument("--max-delta-h2d-frac", type=float, default=0.0,
                    help="fail if any --epochs point's cumulative delta-H2D "
                         "bytes exceed this fraction of rebuilding the "
                         "stores every epoch (the O(churn)-vs-O(|B|) gate)")
    args = ap.parse_args(argv)

    grid_s = [int(x) for x in args.sessions.split(",")]
    grid_d = [int(x) for x in args.d.split(",")]
    print("name,us_per_call,derived")
    rows, points = [], []
    for sessions in grid_s:
        for d in grid_d:
            row, point = bench_point(sessions, d, args.size,
                                     check=not args.no_check, seed=args.seed,
                                     wire=not args.no_wire,
                                     trace_path=args.trace)
            rows.append(row)
            points.append(point)
            print(row.csv(), flush=True)
    if args.peers:
        for peers in (int(x) for x in args.peers.split(",")):
            for d in grid_d:
                row, point = hub_bench_point(peers, d, args.size,
                                             seed=args.seed)
                rows.append(row)
                points.append(point)
                print(row.csv(), flush=True)
    if args.wrongd:
        for d in grid_d:
            row, point = wrongd_bench_point(min(grid_s), d, args.size,
                                            seed=args.seed)
            rows.append(row)
            points.append(point)
            print(row.csv(), flush=True)
    if args.epochs:
        for sessions in grid_s:
            row, point = epoch_bench_point(sessions, args.size, args.epochs,
                                           args.churn, seed=args.seed,
                                           check=not args.no_check)
            rows.append(row)
            points.append(point)
            print(row.csv(), flush=True)
    if args.chaos is not None:
        row, point = chaos_bench_point(args.chaos, check=not args.no_check)
        rows.append(row)
        points.append(point)
        print(row.csv(), flush=True)
    if args.tree:
        row, point = tree_bench_point(args.size, args.d_frac, seed=args.seed)
        rows.append(row)
        points.append(point)
        print(row.csv(), flush=True)
    if not args.no_json:
        write_json(points, args.json)
        print(f"# wrote {args.json}", flush=True)
    pair_points = [
        p for p in points
        if not p.get("hub") and not p.get("chaos") and not p.get("tree")
        and not p.get("wrongd") and "delta_h2d_frac" not in p
    ]
    hub_points = [p for p in points if p.get("hub")]
    if args.min_sessions_per_s:
        worst = min(p["sessions_per_s"] for p in pair_points)
        if worst < args.min_sessions_per_s:
            raise AssertionError(
                f"warm throughput {worst:.2f} sessions/s < required "
                f"{args.min_sessions_per_s}"
            )
    if args.min_h2d_ratio:
        worst = min(p["h2d_ratio"] for p in pair_points)
        if worst < args.min_h2d_ratio:
            raise AssertionError(
                f"H2D transfer ratio {worst:.2f} < required {args.min_h2d_ratio}"
            )
    if args.max_bytes_per_diff:
        if args.no_wire:
            raise SystemExit("--max-bytes-per-diff needs the wire measurement")
        worst = max(p["wire_bytes_per_diff"] for p in pair_points)
        if worst > args.max_bytes_per_diff:
            raise AssertionError(
                f"measured wire bytes/diff {worst:.2f} > allowed "
                f"{args.max_bytes_per_diff}"
            )
    if args.max_hub_bytes_per_diff and hub_points:
        worst = max(p["wire_bytes_per_diff"] for p in hub_points)
        if worst > args.max_hub_bytes_per_diff:
            raise AssertionError(
                f"measured hub wire bytes/diff {worst:.2f} > allowed "
                f"{args.max_hub_bytes_per_diff}"
            )
    wrongd_points = [p for p in points if p.get("wrongd")]
    if args.max_wrongd_vs_honest and wrongd_points:
        worst = max(p["wrongd_vs_honest"] for p in wrongd_points)
        if worst > args.max_wrongd_vs_honest:
            raise AssertionError(
                f"wrong-d̂ wire bytes/diff {worst:.2f}x the honest floor "
                f"> allowed {args.max_wrongd_vs_honest}"
            )
    tree_points = [p for p in points if p.get("tree")]
    if args.max_tree_vs_honest and tree_points:
        worst = max(p["tree_vs_honest"] for p in tree_points)
        if worst > args.max_tree_vs_honest:
            raise AssertionError(
                f"tree bytes/diff {worst:.2f}x the honest-d̂ floor > allowed "
                f"{args.max_tree_vs_honest}"
            )
    epoch_points = [p for p in points if "delta_h2d_frac" in p]
    if args.max_delta_h2d_frac and epoch_points:
        worst = max(p["delta_h2d_frac"] for p in epoch_points)
        if worst > args.max_delta_h2d_frac:
            raise AssertionError(
                f"delta-H2D fraction {worst:.3f} of full rebuild > allowed "
                f"{args.max_delta_h2d_frac}"
            )
    return rows


if __name__ == "__main__":
    main()
