"""Paper Fig. 3 + Fig. 5 (App. J.3): PBS vs PinSketch-with-partition.

PinSketch/WP uses PBS's own grouping trick, so both are O(d) — the remaining
difference is pure communication: the BCH safety margin costs (t−δ)·log n
bits/group in PBS but (t−δ)·log|U| in PinSketch/WP (3–4× more at 32-bit
keys; 32× at 256-bit keys, Fig. 5, computed analytically from the same
counts since neither implementation depends on key width beyond accounting).
"""
from __future__ import annotations

import math

import numpy as np

from repro.core.baselines import pinsketch_wp_reconcile
from repro.core.markov import optimize_parameters
from repro.core.pbs import PBSConfig, reconcile, true_diff
from repro.core.simdata import make_pair
from repro.core.tow import estimate_d, planned_d, tow_sketches

from .common import (
    D_GRID,
    SIZE_A,
    TRIALS,
    TRIALS_SLOW,
    Row,
    Timer,
    overhead_ratio,
    print_rows,
)


def _analytic_bits(d: int, n: int, t: int, delta: float, key_bits: int, scheme: str) -> float:
    """First-round bits for g groups (paper Formula (1) and §8.3)."""
    g = max(1, round(d / delta))
    m = math.log2(n + 1)
    if scheme == "pbs":
        per = t * m + delta * m + delta * key_bits + key_bits
    else:  # PinSketch/WP: sketch costs t·|key|, positions are the elements
        per = t * key_bits + delta * key_bits + key_bits
    return per * g


def run():
    rng = np.random.default_rng(13)
    rows = []
    for d in D_GRID:
        size = max(SIZE_A, 2 * d)
        succ = {"pbs": 0, "wp": 0}
        byts = {"pbs": [], "wp": []}
        us = {"pbs": [], "wp": []}
        n_opt = t_opt = 0
        n_trials = TRIALS_SLOW if d >= 1000 else TRIALS
        for i in range(n_trials):
            a, b = make_pair(size, d, rng)
            td = true_diff(a, b)
            sa, sb = tow_sketches(a, 90_000 + i), tow_sketches(b, 90_000 + i)
            d_plan = planned_d(estimate_d(sa, sb))
            n_opt, t_opt, _, _ = optimize_parameters(d_plan)

            with Timer() as t1:
                res = reconcile(a, b, PBSConfig(seed=i, max_rounds=3))
            succ["pbs"] += res.success and res.diff == td
            byts["pbs"].append(res.bytes_sent)
            us["pbs"].append(t1.us)

            with Timer() as t2:
                res_w = pinsketch_wp_reconcile(a, b, d_plan, t_opt, seed=i)
            succ["wp"] += res_w.success and res_w.diff == td
            byts["wp"].append(res_w.bytes_sent)
            us["wp"].append(t2.us)

        for k, label in (("pbs", "PBS"), ("wp", "PinSketch/WP")):
            rows.append(Row(
                f"fig3/{label}_d{d}", float(np.mean(us[k])),
                f"success={succ[k]}/{n_trials} "
                f"overhead={overhead_ratio(float(np.mean(byts[k])), d):.2f}x",
            ))
        # Fig. 5: 256-bit signatures, analytic accounting
        pbs256 = _analytic_bits(d, n_opt, t_opt, 5.0, 256, "pbs")
        wp256 = _analytic_bits(d, n_opt, t_opt, 5.0, 256, "wp")
        pbs32 = _analytic_bits(d, n_opt, t_opt, 5.0, 32, "pbs")
        wp32 = _analytic_bits(d, n_opt, t_opt, 5.0, 32, "wp")
        rows.append(Row(
            f"fig5/margin_ratio_d{d}", 0.0,
            f"wp/pbs@32b={wp32 / pbs32:.2f}x @256b={wp256 / pbs256:.2f}x "
            f"(outperformance widens with key width, §J.3)",
        ))
    return print_rows(rows)


if __name__ == "__main__":
    run()
