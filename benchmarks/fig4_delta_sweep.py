"""Paper Fig. 4 (App. J.2): delta as the communication/computation knob.

Claim: communication overhead decreases as delta grows (fewer, bigger
groups amortize per-group costs) while encode+decode time increases
(O(delta^2) BCH per group)."""
from __future__ import annotations

import numpy as np

from repro.core.pbs import PBSConfig, reconcile, true_diff
from repro.core.simdata import make_pair

from .common import FULL, SIZE_A, TRIALS, Row, Timer, overhead_ratio, print_rows

DELTAS = (3, 5, 10, 15, 20, 30)
D = 10_000 if FULL else 1000


def run():
    rng = np.random.default_rng(17)
    rows = []
    overheads = []
    for delta in DELTAS:
        byts, us, succ = [], [], 0
        for i in range(max(3, TRIALS // 2)):
            a, b = make_pair(max(SIZE_A, 2 * D), D, rng)
            with Timer() as t:
                res = reconcile(a, b, PBSConfig(seed=i, delta=float(delta), max_rounds=6))
            succ += res.success and res.diff == true_diff(a, b)
            byts.append(res.bytes_sent)
            us.append(t.us)
        ov = overhead_ratio(float(np.mean(byts)), D)
        overheads.append(ov)
        rows.append(Row(
            f"fig4/delta{delta}_d{D}", float(np.mean(us)),
            f"success={succ} overhead={ov:.2f}x",
        ))
    monotone_comm = overheads[0] > overheads[-1]
    rows.append(Row("fig4/comm_decreases_with_delta", 0.0, str(monotone_comm)))
    return print_rows(rows)


if __name__ == "__main__":
    run()
