"""Closed-form numbers quoted in the paper's prose, recomputed exactly:

§1.3.1  d=5, n=255: ideal-case probability 0.96
§2.3    d=5, n=255: type (I) prob ≈ 0.04, type (II) ≈ 1.52e-4,
        fake pass-through ≈ 6e-7
§5.2    r=1..4 optimal comm/group = 591 / 402 / 318 / 288 bits (d=1000)
§5.3    round fractions 0.962 / 0.0380 / 3.61e-4 / 2.86e-6 at (127, 13)
§6.1    ToW estimator: unbiased, Var = (2d²−2d)/ℓ
"""
from __future__ import annotations

import math

import numpy as np

from repro.core.hashing import derive_seed
from repro.core.markov import expected_round_fractions, optimize_parameters
from repro.core.simdata import make_pair
from repro.core.tow import estimate_d, tow_sketches

from .common import Row, Timer, print_rows


def _exact_ball_bin_probs(d: int, n: int):
    """P[some bin has >=2 balls], P[type II: some bin odd >=3] for d balls."""
    p_ideal = math.prod((n - k) / n for k in range(d))
    # type II for d=5: P[some bin has 3 or 5 balls]
    # P[exactly one bin has 3, others isolated] + [5 in one bin] + [3+2]
    if d != 5:
        return 1 - p_ideal, None
    n5 = n**5
    c53, c52 = 10, 10
    p3 = c53 * n * (n - 1) * (n - 2) / n5          # 3 together, 2 isolated
    p32 = c53 * n * (n - 1) / n5                   # 3 together + 2 together
    p5 = n / n5
    p_type2 = p3 + p32 + p5
    return 1 - p_ideal, p_type2


def run():
    rows = []
    with Timer() as t:
        p_nonideal, p_t2 = _exact_ball_bin_probs(5, 255)
    rows.append(Row("analytic/ideal_case_5_255", t.us,
                    f"{1 - p_nonideal:.3f} (paper 0.96)"))
    rows.append(Row("analytic/type1_prob", 0.0,
                    f"{p_nonideal - p_t2:.4f} (paper ~0.04)"))
    rows.append(Row("analytic/type2_prob", 0.0,
                    f"{p_t2:.3e} (paper 1.52e-4)"))
    rows.append(Row("analytic/fake_passthrough", 0.0,
                    f"{p_t2 / 255:.2e} (paper ~6e-7)"))

    # §5.2 r sweep — paper: 591/402/318/288 bits; conventions bracket it
    for r, paper_bits in ((1, 591), (2, 402), (3, 318), (4, 288)):
        try:
            _, _, _, c_s = optimize_parameters(1000, 5.0, r, 0.99, convention="split")
        except ValueError:
            c_s = float("nan")
        try:
            _, _, _, c_t = optimize_parameters(1000, 5.0, r, 0.99, convention="truncate")
        except ValueError:
            c_t = float("inf")
        rows.append(Row(f"analytic/comm_r{r}", 0.0,
                        f"split={c_s:.0f}b truncate={c_t:.0f}b paper={paper_bits}b"))

    fr = expected_round_fractions(127, 13, 1000, 200)
    rows.append(Row("analytic/round_fractions", 0.0,
                    f"{fr[0]:.3f}/{fr[1]:.4f}/{fr[2]:.2e}/{fr[3]:.2e} "
                    f"(paper 0.962/0.0380/3.61e-4/2.86e-6)"))

    # ToW moments
    rng = np.random.default_rng(5)
    d, ell, trials = 64, 64, 60
    ests = []
    for i in range(trials):
        a, b = make_pair(4000, d, rng)
        ests.append(estimate_d(tow_sketches(a, derive_seed(1, i), ell),
                               tow_sketches(b, derive_seed(1, i), ell)))
    rows.append(Row("analytic/tow_mean_var", 0.0,
                    f"mean={np.mean(ests):.1f} (d={d}) var={np.var(ests):.0f} "
                    f"(theory {(2 * d * d - 2 * d) / ell:.0f})"))
    return print_rows(rows)


if __name__ == "__main__":
    run()
