"""Aggregate experiments/dryrun/*.json into the §Roofline markdown tables.

Usage: PYTHONPATH=src python -m benchmarks.roofline_report [--dir experiments/dryrun]
Writes experiments/roofline_table.md and prints a compact summary.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from .common import Row, print_rows

SHAPE_ORDER = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def load(dir_: Path, tag: str = ""):
    recs = []
    for p in sorted(dir_.glob("*.json")):
        r = json.loads(p.read_text())
        if r.get("tag", "") != tag:
            continue
        recs.append(r)
    return recs


def fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:7.2f}s "
    if x >= 1e-3:
        return f"{x * 1e3:7.2f}ms"
    return f"{x * 1e6:7.1f}µs"


def table(recs, mesh: str) -> str:
    lines = [
        f"### Mesh `{mesh}`",
        "",
        "| arch | shape | status | peak GiB/dev | compute | memory | collective | bound | useful-FLOPs ratio |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(recs, key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"]))):
        if r["mesh"] != mesh:
            continue
        if r.get("status") == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | skip | — | — | — | — | — | — |")
            continue
        ro, me = r["roofline"], r["memory"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | ok | {me['peak_bytes_per_device'] / 2**30:.2f} "
            f"| {fmt_s(ro['compute_s'])} | {fmt_s(ro['memory_s'])} | {fmt_s(ro['collective_s'])} "
            f"| {ro['bound'].replace('_s', '')} | {ro['useful_flops_ratio']:.3f} |"
        )
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default="experiments/roofline_table.md")
    args = ap.parse_args()
    recs = load(Path(args.dir), args.tag)
    md = "\n\n".join(table(recs, mesh) for mesh in ("pod", "multipod"))
    Path(args.out).write_text(md + "\n")
    ok = sum(1 for r in recs if r.get("status") == "ok")
    sk = sum(1 for r in recs if r.get("status") == "skipped")
    rows = [Row("roofline/cells", 0.0, f"ok={ok} skipped={sk} -> {args.out}")]
    for r in recs:
        if r.get("status") != "ok":
            continue
        ro = r["roofline"]
        rows.append(Row(
            f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
            0.0,
            f"bound={ro['bound'].replace('_s', '')} "
            f"c/m/n={ro['compute_s']:.3g}/{ro['memory_s']:.3g}/{ro['collective_s']:.3g}s "
            f"useful={ro['useful_flops_ratio']:.3f}",
        ))
    return print_rows(rows)


def run():
    return main()


if __name__ == "__main__":
    main()
