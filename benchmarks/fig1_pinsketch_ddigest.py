"""Paper Fig. 1a–d: PBS vs PinSketch vs Difference Digest — success rate,
communication overhead (× theoretical minimum), encode time, decode time.

Paper claims validated here (per-distinct-element metrics are size-invariant,
so the scaled-down grid still tests them):
  * all three hit their 0.99 success target (1a);
  * D.Digest ≈ 6× minimum, PBS ≈ 2.13–2.87×, PinSketch ≈ 1.38× (1b);
  * PinSketch decode explodes with d — O(d²) — while PBS stays O(d) (1d).
PinSketch is capped at d ≤ 1000 here for the same reason the paper stopped
at 30k: the quadratic decode dominates the whole benchmark.
"""
from __future__ import annotations

import numpy as np

from repro.core.baselines import ddigest_reconcile, pinsketch_reconcile
from repro.core.pbs import PBSConfig, reconcile, true_diff
from repro.core.simdata import make_pair
from repro.core.tow import estimate_d, planned_d, sketch_bytes, tow_sketches

from .common import (
    D_GRID,
    SIZE_A,
    TRIALS,
    TRIALS_SLOW,
    Row,
    Timer,
    overhead_ratio,
    print_rows,
)

PINSKETCH_D_CAP = 1000


def run():
    rng = np.random.default_rng(7)
    rows = []
    for d in D_GRID:
        size = max(SIZE_A, 2 * d)
        succ = {"pbs": 0, "pin": 0, "dd": 0}
        byts = {"pbs": [], "pin": [], "dd": []}
        enc_us = {"pbs": [], "pin": [], "dd": []}
        dec_us = {"pbs": [], "pin": [], "dd": []}
        n_pin = 0
        for i in range(TRIALS):
            a, b = make_pair(size, d, rng)
            td = true_diff(a, b)
            # shared ToW estimate (both competitors use it, paper §6.2)
            sa = tow_sketches(a, 50_000 + i)
            sb = tow_sketches(b, 50_000 + i)
            d_plan = planned_d(estimate_d(sa, sb))

            with Timer() as t_pbs:
                res = reconcile(a, b, PBSConfig(seed=i, max_rounds=3))
            succ["pbs"] += res.success and res.diff == td
            byts["pbs"].append(res.bytes_sent)
            enc_us["pbs"].append(t_pbs.us * 0.5)   # encode/decode interleave;
            dec_us["pbs"].append(t_pbs.us * 0.5)   # split 50/50 for reporting

            if d <= PINSKETCH_D_CAP and i < (TRIALS_SLOW if d >= 1000 else TRIALS):
                n_pin += 1
                t = d_plan
                with Timer() as t_enc:
                    from repro.core.baselines import pinsketch_encode
                    pinsketch_encode(b, t)
                with Timer() as t_dec:
                    res_p = pinsketch_reconcile(a, b, t)
                succ["pin"] += res_p.success and res_p.diff == td
                byts["pin"].append(res_p.bytes_sent)
                enc_us["pin"].append(t_enc.us)
                dec_us["pin"].append(t_dec.us - t_enc.us * 2)

            with Timer() as t_dd:
                res_d = ddigest_reconcile(a, b, d_plan, seed=i)
            succ["dd"] += res_d.success and res_d.diff == td
            byts["dd"].append(res_d.bytes_sent)
            enc_us["dd"].append(t_dd.us * 0.5)
            dec_us["dd"].append(t_dd.us * 0.5)

        est_b = sketch_bytes(size)
        for k, label, n_tr in (("pbs", "PBS", TRIALS), ("pin", "PinSketch", n_pin),
                               ("dd", "D.Digest", TRIALS)):
            if n_tr == 0:
                continue
            ov = overhead_ratio(float(np.mean(byts[k])), d)
            rows.append(Row(
                f"fig1/{label}_d{d}",
                float(np.mean(enc_us[k]) + np.mean(dec_us[k])),
                f"success={succ[k]}/{n_tr} overhead={ov:.2f}x "
                f"enc_us={np.mean(enc_us[k]):.0f} dec_us={np.mean(dec_us[k]):.0f} "
                f"(est {est_b}B excluded, paper conv.)",
            ))
    return print_rows(rows)


if __name__ == "__main__":
    run()
