"""Paper Table 2 (App. J.1): empirical PMF of the number of rounds PBS needs
to reconcile everything, and the implied means (1.20 / 1.81 / 2.04 / … for
d = 10 / 100 / 1000 / …).  PBS runs unbounded rounds here (max_rounds stop
is a far-away safety net), exactly like the paper's J.1 setup."""
from __future__ import annotations

import numpy as np

from repro.core.pbs import PBSConfig, reconcile, true_diff
from repro.core.simdata import make_pair

from .common import D_GRID, SIZE_A, TRIALS, Row, Timer, print_rows

PAPER_MEANS = {10: 1.20, 100: 1.81, 1000: 2.04, 10_000: 2.09, 100_000: 2.18}


def run():
    rng = np.random.default_rng(42)
    rows = []
    for d in D_GRID:
        counts = {}
        fails = 0
        with Timer() as t:
            for i in range(TRIALS):
                a, b = make_pair(max(SIZE_A, 2 * d), d, rng)
                res = reconcile(a, b, PBSConfig(seed=1000 + i, max_rounds=12))
                if not (res.success and res.diff == true_diff(a, b)):
                    fails += 1
                counts[res.rounds] = counts.get(res.rounds, 0) + 1
        mean = sum(r * c for r, c in counts.items()) / TRIALS
        pmf = {r: c / TRIALS for r, c in sorted(counts.items())}
        rows.append(Row(
            f"table2/rounds_d{d}", t.us / TRIALS,
            f"mean={mean:.2f} paper={PAPER_MEANS.get(d, float('nan')):.2f} "
            f"pmf={pmf} fails={fails}",
        ))
    return print_rows(rows)


if __name__ == "__main__":
    run()
