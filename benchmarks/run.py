"""Benchmark runner: one module per paper table/figure (DESIGN.md §7).

Prints ``name,us_per_call,derived`` CSV rows.  REPRO_BENCH_FULL=1 enables the
paper-scale grid (slower).  The dry-run / roofline benches read
experiments/dryrun/*.json (produced by launch/dryrun.py)."""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from . import (
        analytic_checks,
        fig1_pinsketch_ddigest,
        fig2_graphene,
        fig3_pinsketch_wp,
        fig4_delta_sweep,
        kernel_bench,
        recon_throughput,
        table1_param_opt,
        table2_rounds,
    )

    mods = [
        table1_param_opt, table2_rounds, analytic_checks,
        fig1_pinsketch_ddigest, fig2_graphene, fig3_pinsketch_wp,
        fig4_delta_sweep, kernel_bench, recon_throughput,
    ]
    try:
        from . import roofline_report
        import pathlib
        if pathlib.Path("experiments/dryrun").exists():
            mods.append(roofline_report)
    except Exception:
        pass

    print("name,us_per_call,derived")
    failed = 0
    for mod in mods:
        try:
            mod.run()
        except Exception:
            failed += 1
            print(f"{mod.__name__},0,FAILED", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failed:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
