"""The paper's motivating application (§1.3.4): blockchain transaction relay.

Two peers hold mempools of transaction IDs that mostly overlap (they both
receive most broadcasts).  Each relay round, a peer reconciles with a
neighbor via PBS instead of announcing every txid (the Erlay [31] setting).
We simulate a relay epoch and account bytes vs. (a) naive full announcement
and (b) per-tx INV gossip, and demonstrate *piecewise reconciliability*: the
first round already yields >95% of the missing transactions, which the peer
can start fetching while stragglers finish.

Run:  PYTHONPATH=src python examples/blockchain_relay.py
"""
import numpy as np

from repro.core.pbs import PBSConfig, reconcile, true_diff
from repro.core.simdata import random_set


def main():
    rng = np.random.default_rng(1)
    mempool_size = 60_000        # txids held by each peer
    churn = 800                  # new txs each peer saw that the other missed

    base = random_set(mempool_size + 2 * churn, rng)
    alice = np.concatenate([base[: mempool_size - churn], base[mempool_size : mempool_size + churn]])
    bob = base[:mempool_size]
    d = len(true_diff(alice, bob))
    print(f"mempools: |A|={len(alice):,} |B|={len(bob):,}, diverged by d={d}")

    res = reconcile(alice, bob, PBSConfig(seed=3))
    assert res.success

    naive = 4 * len(bob)
    inv_gossip = 4 * d  # ideal INV: only the diff, one announcement each
    print(f"PBS relay: {res.rounds} rounds, {res.bytes_sent:,} B protocol "
          f"+ {res.estimator_bytes} B estimator")
    print(f"  vs full announcement: {naive:,} B  ({naive / res.bytes_sent:.0f}x saved)")
    print(f"  vs ideal INV gossip : {inv_gossip:,} B "
          f"(PBS pays {res.bytes_sent / inv_gossip:.2f}x the minimum)")
    print(f"  round bytes: {res.bytes_per_round} "
          f"(piecewise: round 1 carries ~{100 * res.bytes_per_round[0] / max(1, res.bytes_sent):.0f}% "
          f"of the traffic and >95% of the discovered txids)")


if __name__ == "__main__":
    main()
