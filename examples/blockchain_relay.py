"""The paper's motivating application (§1.3.4): blockchain transaction relay,
now as a real multi-peer serving topology (DESIGN.md §10).

One relay node holds the canonical mempool and serves N downstream peers at
once through a ``repro.net.HubEndpoint``: every peer is a real
``AliceEndpoint`` exchanging mux-enveloped ``repro.wire`` bytes over its own
transport (three in-memory pipes and one genuine TCP loopback socket below),
and the relay fuses all peers' per-round work into shared cohort kernel
launches — one element-store upload and 2 encode + 1 decode launches per
cohort-round for the whole peer set, not per peer.

Each peer's mempool has diverged from the relay's (missed broadcasts both
ways, the Erlay [31] setting).  PBS reconciliation replaces announcing every
txid: each peer learns its full symmetric difference for ~2x the bytes of an
ideal INV gossip — per peer, byte-identical to what a dedicated pair of
endpoints would have measured.

With ``--epochs N`` (default 3) the relay then keeps serving: mempools
churn continuously — blocks mine txids out, fresh ones gossip in on both
ends — and each epoch reconciles only the drift over the SAME sessions,
channels, and device-resident stores (DESIGN.md §11): the ``MSG_EPOCH``
handshake re-syncs d̂, and the stores take an O(churn) in-place delta
patch instead of a rebuild (the per-epoch ledger below shows delta-H2D
bytes and rebuild counts).

Run:  PYTHONPATH=src python examples/blockchain_relay.py [--epochs N]
"""
import argparse
import pathlib
import sys
import time

if __name__ == "__main__":  # standalone: make src/ importable
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.core.pbs import PBSConfig, true_diff
from repro.core.simdata import random_set
from repro.net import (
    AliceEndpoint,
    HubEndpoint,
    InMemoryDuplex,
    run_hub,
    run_hub_epoch,
    tcp_loopback_pair,
)
from repro.recon.session import apply_churn

N_PEERS = 4
MEMPOOL = 12_000             # txids in the relay's canonical mempool
CHURN = 150                  # per direction, per peer (admission epoch)
EPOCH_CHURN = 75             # mempool drift per side between epochs


def diverged_mempool(relay_pool: np.ndarray, rng: np.random.Generator):
    """A peer's view: missed CHURN of the relay's txs, saw CHURN fresh ones."""
    missed = rng.permutation(len(relay_pool))[:CHURN]
    fresh = random_set(CHURN, rng)
    peer = np.concatenate([np.delete(relay_pool, missed), fresh])
    return np.unique(peer)


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--epochs", type=int, default=3,
                    help="total reconciliation epochs (1 = one-shot relay)")
    args = ap.parse_args()

    rng = np.random.default_rng(1)
    relay_pool = random_set(MEMPOOL, rng)

    hub = HubEndpoint(recv_deadline=300.0, continuous=True)
    alices, pools = {}, {}
    for p in range(N_PEERS):
        peer_pool = diverged_mempool(relay_pool, rng)
        d = len(true_diff(peer_pool, relay_pool))
        # the last peer connects over a real TCP loopback socket
        ta, tb = (
            tcp_loopback_pair() if p == N_PEERS - 1 else InMemoryDuplex.pair()
        )
        cfg = PBSConfig(seed=3 + p)
        ch = hub.add_peer(tb, label=f"peer{p}")
        hub.submit(ch, relay_pool, cfg=cfg)          # estimator path: d unknown
        ep = AliceEndpoint(ta, channel=ch, continuous=True)
        ep.submit(peer_pool, cfg=cfg)
        alices[ch] = ep
        pools[ch] = (peer_pool, d, "tcp" if p == N_PEERS - 1 else "mem")

    print(f"relay mempool |B|={MEMPOOL:,}; serving {N_PEERS} diverged peers")
    t0 = time.perf_counter()
    outcomes, results, errors = run_hub(hub, alices)
    wall = time.perf_counter() - t0
    assert not errors, errors

    print(f"\n{'ch':>3} {'link':<4} {'d':>4} {'rounds':>6} {'wire B':>7} "
          f"{'est B':>6} {'vs INV':>7}  exact")
    total_pbs = total_inv = 0
    for ch, (peer_pool, d, link) in pools.items():
        r = results[ch][0]
        assert r.success and r.diff == true_diff(peer_pool, relay_pool)
        assert outcomes[ch].ok and outcomes[ch].verified == [True]
        inv = 4 * d            # ideal INV: one 4-byte announcement per diff
        total_pbs += r.bytes_sent
        total_inv += inv
        print(f"{ch:>3} {link:<4} {d:>4} {r.rounds:>6} {r.bytes_sent:>7,} "
              f"{r.estimator_bytes:>6} {r.bytes_sent / inv:>6.2f}x  ok")

    naive = 4 * MEMPOOL * N_PEERS
    st = hub.stats
    print(f"\nrelay served {N_PEERS} peers in {wall:.1f}s "
          f"({N_PEERS / wall:.2f} peers/s)")
    print(f"  fusion: {st['store_uploads']} store upload(s) for "
          f"{st['cohort_rounds']} cohort-rounds, "
          f"{st['kernel_launches']} encode + {st['decode_launches']} decode "
          f"launches shared across all peers")
    print(f"  bytes: {total_pbs:,} B PBS vs {naive:,} B full announcement "
          f"({naive / total_pbs:.0f}x saved), {total_pbs / total_inv:.2f}x "
          f"the ideal INV minimum")
    mux = sum(
        o.wire_stats["mux_bytes_in"] + o.wire_stats["mux_bytes_out"]
        for o in outcomes.values()
    )
    print(f"  multiplexing overhead: {mux:,} B of MSG_MUX envelopes "
          f"({100 * mux / max(1, total_pbs):.1f}% of protocol bytes)")

    # ---- continuous sync: the mempool keeps churning (DESIGN.md §11) ----
    if args.epochs <= 1:
        return
    peer_churn = EPOCH_CHURN // 2
    d_nom = 2 * (EPOCH_CHURN + peer_churn)   # the relay's churn budget
    store_bytes = hub._batch.store_upload_bytes()
    print(f"\ncontinuous sync: {args.epochs - 1} more epochs of mempool "
          f"churn ({EPOCH_CHURN} txids/side relay, {peer_churn}/side peer; "
          f"resident stores = {store_bytes:,} B)")
    print(f"{'epoch':>5} {'d tot':>6} {'wire B':>8} {'B/diff':>7} "
          f"{'delta-H2D':>9} {'rebuilds':>8} {'wall s':>7}")
    for e in range(1, args.epochs):
        mined = rng.permutation(relay_pool)[:EPOCH_CHURN]
        fresh = random_set(EPOCH_CHURN, rng)
        relay_pool = apply_churn(relay_pool, fresh, mined)
        hub_muts = {}
        for ch, ep in alices.items():
            hub_muts[ch] = {0: (fresh, mined)}
            # the peer converged to the relay's previous pool, then drifts
            peer_pool = ep.sessions[0].state.a
            peer_mined = rng.permutation(peer_pool)[:peer_churn]
            peer_fresh = random_set(peer_churn, rng)
            ep.advance_epoch({0: (peer_fresh, peer_mined)},
                             d_known={0: d_nom})
        hub.advance_epoch(hub_muts, d_known={
            ch: {0: d_nom} for ch in alices
        })
        t0 = time.perf_counter()
        outcomes, results, errors = run_hub_epoch(hub, alices)
        wall = time.perf_counter() - t0
        assert not errors, errors
        st = hub.stats
        d_tot = wire = 0
        for ch, ep in alices.items():
            r = results[ch][0]
            assert r.success and outcomes[ch].verified == [True]
            assert r.diff == true_diff(ep.sessions[0].state.a, relay_pool)
            d_tot += len(r.diff)
            wire += r.bytes_sent
        print(f"{e:>5} {d_tot:>6} {wire:>8,} {wire / max(1, d_tot):>7.2f} "
              f"{st['h2d_delta_bytes']:>9,} {st['store_builds']:>8} "
              f"{wall:>7.2f}")
    print(f"  (epoch 1 re-plans the pinned churn-budget code — one counted "
          f"rebuild; every later epoch is a pure O(churn) delta patch)")


if __name__ == "__main__":
    main()
