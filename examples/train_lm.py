"""End-to-end LM training driver (deliverable b): data pipeline -> sharded
train step -> checkpoints -> resume-after-failure, via repro.launch.train.

Default: a ~10M-param qwen2-family model for 60 steps (a few minutes on this
CPU container), with a simulated failure at step 35 and a PBS-assisted
resume.  ``--full`` trains a ~100M-param model for 300 steps (the assignment
configuration; expect hours on 1 CPU core — it is the same code path).

Run:  PYTHONPATH=src python examples/train_lm.py [--full]
"""
import argparse
import sys
import tempfile

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="~100M params, 300 steps")
    args = ap.parse_args()

    ckpt = tempfile.mkdtemp(prefix="train_lm_ckpt_")
    if args.full:
        base = ["--arch", "qwen2-1.5b", "--steps", "300", "--batch", "8",
                "--seq", "512", "--ckpt-dir", ckpt, "--ckpt-every", "50"]
        # full 28L/1536d qwen2-1.5b scaled by seq/steps only: ~1.5B is beyond
        # 1 CPU core; ~100M = smoke arch widened via env-free flags is not
        # exposed, so --full uses the real config with short seq. Adjust to
        # taste on real hardware.
        train_main(base)
        return

    common = ["--arch", "qwen2-1.5b", "--smoke", "--batch", "8", "--seq", "128",
              "--ckpt-dir", ckpt, "--ckpt-every", "20", "--steps", "60"]
    print(f"== phase 1: train until simulated failure (ckpt dir {ckpt})")
    try:
        train_main(common + ["--kill-at", "35"])
    except SystemExit as e:
        if e.code != 17:
            raise
        print("== node failed (exit 17); resuming from last checkpoint")
    train_main(common + ["--resume"])
    print("== train_lm complete")


if __name__ == "__main__":
    sys.exit(main())
