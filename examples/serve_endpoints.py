"""Two-endpoint PBS reconciliation: Alice and Bob exchanging real bytes.

The same multi-session workload three ways (DESIGN.md §9):

1. **in-memory duplex** — the pure-protocol path: mixed session sizes, an
   estimator-path session (ToW phase 0 on the wire), and a deliberately
   BCH-overloaded session whose 3-way split both endpoints mirror;
2. **TCP loopback socket** — the same sessions over a real socket;
3. **lossy simulated channel** — 25% datagram loss under the stop-and-wait
   ``ReliableTransport``, forcing retransmissions.

Every session's result is asserted byte-identical to the in-process
``core.pbs.reconcile`` oracle, and the printed ledgers are *measured* from
the frames that crossed the transport.

Run:  PYTHONPATH=src python examples/serve_endpoints.py
"""
import pathlib
import sys
import time

if __name__ == "__main__":  # standalone: make src/ importable
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.core.pbs import PBSConfig, reconcile, true_diff
from repro.core.simdata import make_pair, make_pair_two_sided
from repro.net import (
    AliceEndpoint,
    BobEndpoint,
    InMemoryDuplex,
    ReliableTransport,
    SimulatedChannel,
    run_pair,
    tcp_loopback_pair,
)


def workload():
    sessions = []
    for i, (size, d) in enumerate([(2000, 5), (3000, 20), (1500, 8)]):
        a, b = make_pair(size, d, np.random.default_rng(100 + i))
        sessions.append((f"d={d}", a, b, PBSConfig(seed=i), d))
    a, b = make_pair_two_sided(2500, 18, 12, np.random.default_rng(9))
    sessions.append(("two-sided,est", a, b, PBSConfig(seed=31), None))
    a, b = make_pair(2500, 40, np.random.default_rng(17))
    cfg = PBSConfig(seed=6, n_override=255, t_override=8, g_override=1)
    sessions.append(("overload,split", a, b, cfg, 40))
    return sessions


def drive(label, sessions, ta, tb):
    alice, bob = AliceEndpoint(ta), BobEndpoint(tb)
    for _, a, b, cfg, dk in sessions:
        alice.submit(a, cfg=cfg, d_known=dk)
        bob.submit(b, cfg=cfg, d_known=dk)
    t0 = time.perf_counter()
    results = run_pair(alice, bob)
    wall = time.perf_counter() - t0

    print(f"\n[{label}] served {len(sessions)} sessions in {wall:.1f}s")
    print(f"{'sid':>3} {'label':<15} {'rounds':>6} {'wire B':>7} {'est B':>6}  exact==oracle")
    for sid, (name, a, b, cfg, dk) in enumerate(sessions):
        r = results[sid]
        oracle = reconcile(a, b, cfg, d_known=dk)
        assert r.success and r.diff == true_diff(a, b)
        assert r.bytes_per_round == oracle.bytes_per_round, "wire ledger != oracle"
        assert r.estimator_bytes == oracle.estimator_bytes
        print(f"{sid:>3} {name:<15} {r.rounds:>6} {r.bytes_sent:>7} "
              f"{r.estimator_bytes:>6}  ok")
    assert bob.verified == [True] * len(sessions)
    ws = alice.wire_stats
    print(f"    frames {ws['frames_out']}→ / ←{ws['frames_in']}, "
          f"protocol {ws['protocol_frame_bytes']} B framed "
          f"(+{ws['estimator_frame_bytes']} B estimator, "
          f"+{ws['verify_frame_bytes']} B verify)")
    return alice, bob


def main():
    sessions = workload()

    ta, tb = InMemoryDuplex.pair()
    drive("in-memory duplex", sessions, ta, tb)

    ta, tb = tcp_loopback_pair()
    try:
        alice, _ = drive("tcp loopback 127.0.0.1", sessions, ta, tb)
        ws = alice.wire_stats
        assert ws["transport_bytes_out"] == ws["frame_bytes_out"]
    finally:
        ta.close()
        tb.close()

    one = sessions[:1]
    ca, cb = SimulatedChannel.pair(loss=0.25, latency=0.001, seed=42)
    ra, rb = ReliableTransport(ca, timeout=0.02), ReliableTransport(cb, timeout=0.02)
    drive("lossy channel (25% loss, ARQ)", one, ra, rb)
    print(f"    channel dropped {ca.dropped + cb.dropped} datagrams, "
          f"ARQ retransmitted {ra.retransmits + rb.retransmits}")

    print("\nall transports: results byte-identical to core.pbs.reconcile")


if __name__ == "__main__":
    main()
