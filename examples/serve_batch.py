"""Batched reconciliation serving: mixed sessions through ``repro.recon``.

A traffic-shaped workload — many concurrent Alice↔Bob pairs of different
sizes and difference cardinalities, some with unknown d (ToW phase 0), one
deliberately BCH-overloaded so the 3-way split fires mid-batch — driven
end-to-end by ``ReconcileServer``.  Every round, the planner packs all live
units of all sessions into per-code cohorts and the jitted executor runs the
bin/sketch/decode for the whole fleet at once (DESIGN.md §5).

Run:  PYTHONPATH=src python examples/serve_batch.py
"""
import time

import numpy as np

from repro.core.pbs import PBSConfig, true_diff
from repro.core.simdata import make_pair, make_pair_two_sided
from repro.recon import ReconcileServer


def main():
    rng = np.random.default_rng(0)
    server = ReconcileServer()
    workload = []  # (sid, label, a, b)

    # a dozen plain sessions with mixed sizes / difference cardinalities
    for i, (size, d) in enumerate(
        [(2000, 5), (3000, 20), (1500, 8), (4000, 60), (2500, 12), (3500, 40)]
    ):
        a, b = make_pair(size, d, np.random.default_rng(100 + i))
        sid = server.submit(a, b, cfg=PBSConfig(seed=i), d_known=d)
        workload.append((sid, f"d={d}", a, b))

    # two-sided + estimator-path sessions (d unknown -> ToW phase 0)
    a, b = make_pair_two_sided(3000, 25, 15, rng)
    sid = server.submit(a, b, cfg=PBSConfig(seed=31))
    workload.append((sid, "two-sided,est", a, b))

    # one overloaded session: d far above t in a single group -> 3-way split
    a, b = make_pair(2500, 40, np.random.default_rng(17))
    sid = server.submit(
        a, b,
        cfg=PBSConfig(seed=6, n_override=255, t_override=8, g_override=1),
        d_known=40,
    )
    workload.append((sid, "overload,split", a, b))

    t0 = time.perf_counter()
    results = server.run()
    wall = time.perf_counter() - t0

    print(f"served {len(workload)} sessions in {wall:.1f}s "
          f"({len(workload) / wall:.2f} sessions/s incl. compiles)")
    print(f"{'sid':>3} {'label':<15} {'rounds':>6} {'bytes':>7} "
          f"{'bytes/d':>8} {'splits':>6}  exact")
    for sid, label, a, b in workload:
        r = results[sid]
        td = true_diff(a, b)
        d = max(1, len(td))
        assert r.success and r.diff == td
        print(f"{sid:>3} {label:<15} {r.rounds:>6} {r.bytes_sent:>7} "
              f"{r.bytes_sent / d:>8.1f} {r.decode_failures:>6}  ok")
    total = sum(results[s].bytes_sent for s, *_ in workload)
    print(f"total protocol bytes: {total:,}")

    # the transfer/launch ledger of the device-resident pipeline
    # (DESIGN.md §5): element stores upload once, rounds ship only small
    # gather/overlay arrays, and the fused two-side encode halves launches
    st = server.stats
    print(f"device ledger: {st['h2d_store_bytes']:,} B store upload + "
          f"{st['h2d_round_bytes']:,} B round overlays "
          f"({st['h2d_ratio']:.1f}x less H2D than re-packing per round)")
    print(f"  {st['kernel_launches']} fused kernel launches vs "
          f"{st['legacy_kernel_launches']} legacy over "
          f"{st['cohort_rounds']} cohort-rounds; "
          f"phase0 {st['phase0_s'] * 1e3:.0f} ms, "
          f"device {st['device_s'] * 1e3:.0f} ms, "
          f"host {st['host_s'] * 1e3:.0f} ms")


if __name__ == "__main__":
    main()
