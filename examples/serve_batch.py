"""Batched serving example: requests -> bucketed prefill -> decode loop.

Serves a few dozen mixed-length requests against a reduced qwen2-family
model through `repro.serve.scheduler.BatchScheduler` (the serving-side
end-to-end driver) and prints the throughput ledger.

Run:  PYTHONPATH=src python examples/serve_batch.py
"""
import numpy as np

import jax

from repro.configs import get_smoke_config
from repro.optim import OptConfig
from repro.serve.scheduler import BatchScheduler, Request
from repro.train import init_train_state, make_train_step


def main():
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    cfg = get_smoke_config("qwen2-1.5b")
    ocfg = OptConfig(warmup=2, total_steps=10)
    bundle = make_train_step(cfg, mesh, ocfg, batch=4)
    params, _ = init_train_state(bundle, cfg, mesh, ocfg)

    rng = np.random.default_rng(0)
    requests = [
        Request(rid=i,
                prompt=rng.integers(1, cfg.vocab, size=plen).tolist(),
                max_new=8)
        for i, plen in enumerate([16] * 6 + [32] * 5 + [16] * 3)
    ]
    sched = BatchScheduler(cfg, mesh, batch=4, max_len=64, eos_id=0)
    out, stats = sched.run(params, requests)

    assert len(out) == len(requests)
    done = sum(c.finished for c in out.values())
    print(f"served {stats.requests} requests in {stats.batches} batches "
          f"({stats.wall_s:.1f}s incl. compiles)")
    print(f"  prefill tokens: {stats.prefill_tokens}   decode steps: {stats.decode_steps}")
    print(f"  finished early (EOS): {done}")
    for rid in (0, 6):
        print(f"  request {rid}: prompt[:4]={requests[rid].prompt[:4]} "
              f"-> {out[rid].tokens}")


if __name__ == "__main__":
    main()
