"""Quickstart: the paper's protocol end-to-end in 40 lines.

Alice and Bob hold two large key sets differing in d elements; PBS lets
Alice learn the difference in O(d) time and ~2x the information-theoretic
minimum bytes.  Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.pbs import PBSConfig, reconcile, true_diff
from repro.core.simdata import make_pair_two_sided


def main():
    rng = np.random.default_rng(0)
    # 100k-element sets differing in 600 keys (400 only-Alice, 200 only-Bob)
    A, B = make_pair_two_sided(100_000, 400, 200, rng)
    d = len(true_diff(A, B))
    print(f"|A|={len(A):,} |B|={len(B):,} d={d}")

    res = reconcile(A, B, PBSConfig(seed=7))
    assert res.success and res.diff == true_diff(A, B)

    minimum = d * 4  # d * log|U| bits = 4 bytes per element
    print(f"reconciled in {res.rounds} round(s)")
    print(f"  protocol bytes : {res.bytes_sent:,} "
          f"({res.bytes_sent / minimum:.2f}x the theoretical minimum)")
    print(f"  estimator bytes: {res.estimator_bytes} (ToW, 128 sketches)")
    print(f"  parameters     : n={res.n} t={res.t} g={res.g} "
          f"(optimized for d_hat={res.d_est:.0f})")
    print(f"  naive transfer : {4 * len(B):,} bytes "
          f"({4 * len(B) / res.bytes_sent:.0f}x more)")


if __name__ == "__main__":
    main()
