"""Quickstart: the paper's protocol end-to-end, oracle and engine.

Alice and Bob hold two large key sets differing in d elements; PBS lets
Alice learn the difference in O(d) time and ~2x the information-theoretic
minimum bytes.  The same pair then runs through the batched
``ReconcileServer`` engine (DESIGN.md §5) to show the device transfer
ledger the accelerator path optimizes — byte-identical results, asserted.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import pathlib
import sys

if __name__ == "__main__":  # standalone: make src/ importable
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.core.pbs import PBSConfig, reconcile, true_diff
from repro.core.simdata import make_pair_two_sided
from repro.recon import ReconcileServer


def main():
    rng = np.random.default_rng(0)
    # 100k-element sets differing in 600 keys (400 only-Alice, 200 only-Bob)
    A, B = make_pair_two_sided(100_000, 400, 200, rng)
    d = len(true_diff(A, B))
    print(f"|A|={len(A):,} |B|={len(B):,} d={d}")

    res = reconcile(A, B, PBSConfig(seed=7))
    assert res.success and res.diff == true_diff(A, B)

    minimum = d * 4  # d * log|U| bits = 4 bytes per element
    print(f"reconciled in {res.rounds} round(s)")
    print(f"  protocol bytes : {res.bytes_sent:,} "
          f"({res.bytes_sent / minimum:.2f}x the theoretical minimum)")
    print(f"  estimator bytes: {res.estimator_bytes} (ToW, 128 sketches)")
    print(f"  parameters     : n={res.n} t={res.t} g={res.g} "
          f"(optimized for d_hat={res.d_est:.0f})")
    print(f"  naive transfer : {4 * len(B):,} bytes "
          f"({4 * len(B) / res.bytes_sent:.0f}x more)")

    # the same pair through the batched engine: identical bytes, plus the
    # transfer/launch ledger the device-resident pipeline optimizes
    server = ReconcileServer()
    sid = server.submit(A, B, cfg=PBSConfig(seed=7))
    engine = server.run()[sid]
    assert engine.diff == res.diff and engine.bytes_sent == res.bytes_sent
    st = server.stats
    print("batched engine (byte-identical, asserted):")
    print(f"  H2D bytes      : {st['h2d_store_bytes']:,} store (once) + "
          f"{st['h2d_round_bytes']:,}/run overlays "
          f"= {st['h2d_ratio']:.1f}x less than re-packing per round")
    print(f"  kernel launches: {st['kernel_launches']} fused "
          f"(legacy {st['legacy_kernel_launches']}) over "
          f"{st['cohort_rounds']} cohort-rounds")
    print(f"  time           : phase0 {st['phase0_s'] * 1e3:.0f} ms, "
          f"device {st['device_s'] * 1e3:.0f} ms, "
          f"host {st['host_s'] * 1e3:.0f} ms")


if __name__ == "__main__":
    main()
