"""Elastic failure recovery with PBS-reconciled state — the framework story.

A 4-node fleet trains; node 2 dies mid-run and rejoins later with a stale
checkpoint and a stale data ledger.  Recovery reconciles BOTH with PBS
(shard manifests + consumed-sample ids) and fetches only what changed,
instead of re-shipping the checkpoint and the ledger wholesale.

Run:  PYTHONPATH=src python examples/elastic_recovery.py
"""
import tempfile
from pathlib import Path

import numpy as np

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.data import DataConfig, Ledger, global_batch
from repro.launch.elastic import (
    ElasticConfig,
    Membership,
    NodeState,
    plan_recovery,
    viable_grid,
)


def main():
    root = Path(tempfile.mkdtemp(prefix="elastic_demo_"))
    rng = np.random.default_rng(0)
    dcfg = DataConfig(vocab=32_000, seq_len=64, global_batch=64)

    # a stand-in model state: 32 MB of parameters in 4 leaves
    params = {f"layer{i}": rng.standard_normal((1_000_000,)).astype(np.float32)
              for i in range(8)}

    t = [0.0]
    fleet = Membership([0, 1, 2, 3], ElasticConfig(), clock=lambda: t[0])
    fleet_ledger, node2_ledger = Ledger(), Ledger()

    # --- steps 0..199: everyone healthy; node 2 dies at step 188
    n_steps, fail_at = 200, 188
    for step in range(n_steps):
        t[0] += 1.0
        ids = global_batch(step, dcfg)["ids"]
        fleet_ledger.record(ids)
        for n in (0, 1, 3):
            fleet.heartbeat(n, step_time=1.0)
        if step < fail_at:
            node2_ledger.record(ids)
            fleet.heartbeat(2, step_time=1.0)
        if step == fail_at - 1:
            save_checkpoint(root / "node2", step + 1,
                            {"params": params, "step": np.int64(step + 1)})
        # healthy nodes keep checkpointing; params drift a little each time
        if (step + 1) % 50 == 0 or step == n_steps - 1:
            drifted = {k: (v + 0.001 * (step + 1)) if k in ("layer0", "layer5") else v
                       for k, v in params.items()}
            params = drifted
            save_checkpoint(root / "healthy", step + 1,
                            {"params": params, "step": np.int64(step + 1)})
        fleet.sweep()

    assert fleet.nodes[2].state == NodeState.DEAD
    print(f"node 2 DEAD; alive={fleet.alive()} -> grid {viable_grid(len(fleet.alive()) * 64)}")

    # --- node 2 rejoins: PBS-reconcile checkpoint manifest + data ledger
    fleet.heartbeat(2)
    plan = plan_recovery(root / "node2", root / "healthy",
                         node2_ledger, fleet_ledger, seed=11)
    fleet.admit(2)
    print(f"recovery: fetched {plan.shards_to_fetch} shards "
          f"({plan.payload_bytes / 2**20:.1f} MiB payload), "
          f"skipping {plan.samples_to_skip} already-consumed samples")
    print(f"  reconciliation cost: {plan.pbs_bytes:,} B (PBS) vs "
          f"{plan.naive_bytes:,} B naive -> {plan.naive_bytes / plan.pbs_bytes:.0f}x saved, "
          f"{plan.rounds} round(s)")

    tree, step = restore_checkpoint(root / "node2")
    assert step == 200 and np.allclose(tree["params"]["layer0"], params["layer0"])
    print(f"node 2 restored to step {step}; alive={fleet.alive()} "
          f"-> grid {viable_grid(len(fleet.alive()) * 64)}")


if __name__ == "__main__":
    main()
