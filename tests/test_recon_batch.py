"""The batched multi-session engine (repro.recon) vs the numpy oracle.

Every assertion is unit-for-unit equality with ``core.pbs.reconcile``: same
diff, same per-round byte ledger, same round count, same split/fake
counters — the engine is the same state machine with the bin/sketch/decode
tables computed by the accelerator kernels (DESIGN.md §5).
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.pbs import PBSConfig, reconcile, true_diff
from repro.core.simdata import make_pair, make_pair_two_sided
from repro.kernels import bin_parity_xorsum_units, xor_bits_to_u32
from repro.kernels import ref as kref
from repro.recon import ReconcileServer, reconcile_batch

SIZES = {5: 1500, 50: 4000, 500: 8000}


def _assert_matches_oracle(got, a, b, cfg, d_known):
    exp = reconcile(a, b, cfg, d_known=d_known)
    assert got.diff == exp.diff
    assert got.bytes_sent == exp.bytes_sent
    assert got.bytes_per_round == exp.bytes_per_round
    assert got.rounds == exp.rounds
    assert got.success == exp.success
    assert got.estimator_bytes == exp.estimator_bytes
    assert got.decode_failures == exp.decode_failures
    assert got.fake_rejections == exp.fake_rejections
    assert (got.n, got.t, got.g) == (exp.n, exp.t, exp.g)
    return exp


def test_batched_matches_oracle_across_d():
    """One mixed batch spanning d in {5, 50, 500} (several code cohorts)."""
    cases = []
    for i, d in enumerate(sorted(SIZES)):
        a, b = make_pair(SIZES[d], d, np.random.default_rng(d))
        cases.append((a, b, PBSConfig(seed=10 + i), d))
    server = ReconcileServer()
    for a, b, cfg, d in cases:
        server.submit(a, b, cfg=cfg, d_known=d)
    results = server.run()
    for i, (a, b, cfg, d) in enumerate(cases):
        exp = _assert_matches_oracle(results[i], a, b, cfg, d)
        assert exp.success and exp.diff == true_diff(a, b)


def test_estimator_and_two_sided_sessions():
    """Unknown d (ToW phase 0) and two-sided differences, batched together."""
    a1, b1 = make_pair(6000, 80, np.random.default_rng(2))
    a2, b2 = make_pair_two_sided(5000, 30, 20, np.random.default_rng(3))
    cases = [(a1, b1, PBSConfig(seed=8), None), (a2, b2, PBSConfig(seed=2), 50)]
    server = ReconcileServer()
    for a, b, cfg, dk in cases:
        server.submit(a, b, cfg=cfg, d_known=dk)
    results = server.run()
    for i, (a, b, cfg, dk) in enumerate(cases):
        exp = _assert_matches_oracle(results[i], a, b, cfg, dk)
        assert exp.success and exp.diff == true_diff(a, b)


def test_decode_failure_splits_without_perturbing_neighbors():
    """A BCH-overloaded session must 3-way split and converge while its batch
    neighbors reconcile exactly as they would alone."""
    # session 1: d=40 against t=8 in a single group -> guaranteed overload
    a_f, b_f = make_pair(5000, 40, np.random.default_rng(17))
    cfg_f = PBSConfig(seed=6, n_override=255, t_override=8, g_override=1, max_rounds=12)
    neighbors = [
        (*make_pair(2000, 10, np.random.default_rng(7)), PBSConfig(seed=21), 10),
        (*make_pair(3000, 25, np.random.default_rng(9)), PBSConfig(seed=23), 25),
    ]

    server = ReconcileServer()
    server.submit(neighbors[0][0], neighbors[0][1], cfg=neighbors[0][2], d_known=neighbors[0][3])
    server.submit(a_f, b_f, cfg=cfg_f, d_known=40)
    server.submit(neighbors[1][0], neighbors[1][1], cfg=neighbors[1][2], d_known=neighbors[1][3])
    results = server.run()

    failing = _assert_matches_oracle(results[1], a_f, b_f, cfg_f, 40)
    assert results[1].decode_failures >= 1          # the split actually fired
    assert results[1].success and results[1].diff == true_diff(a_f, b_f)
    assert failing.rounds > 1                       # re-queue spanned rounds

    # neighbors: byte-for-byte what they'd do in a batch of one
    for sid, (a, b, cfg, dk) in zip((0, 2), neighbors):
        _assert_matches_oracle(results[sid], a, b, cfg, dk)


def test_session_exceeding_max_rounds_reports_failure():
    """An undersized code that can't converge must fail identically batched."""
    a, b = make_pair(2000, 30, np.random.default_rng(5))
    cfg = PBSConfig(seed=4, n_override=63, t_override=2, g_override=1, max_rounds=2)
    server = ReconcileServer()
    server.submit(a, b, cfg=cfg, d_known=30)
    got = server.run()[0]
    exp = _assert_matches_oracle(got, a, b, cfg, 30)
    assert not exp.success  # sanity: this really is the failure path


def test_reconcile_batch_convenience_order():
    pairs = [make_pair(1200, d, np.random.default_rng(40 + d)) for d in (3, 7, 11)]
    results = reconcile_batch(
        pairs, cfgs=PBSConfig(seed=5), d_knowns=[3, 7, 11]
    )
    for (a, b), res in zip(pairs, results):
        assert res.success and res.diff == true_diff(a, b)


@pytest.mark.parametrize("n_bins", [63, 127, 8191])
def test_units_kernel_matches_mulshift_oracle(n_bins):
    """The batched bin kernel's 16-bit-split multiply-shift must equal the
    uint64 ground truth (== core.hashing.hash_to_range) bit-for-bit."""
    rng = np.random.default_rng(n_bins)
    U, E = 6, 257
    counts = rng.integers(0, E, size=U)
    counts[0], counts[1] = 0, E  # empty row + full row edges
    elems = np.zeros((U, E), np.uint32)
    valid = np.zeros((U, E), np.int32)
    for u, c in enumerate(counts):
        vals = rng.integers(1, 1 << 32, size=int(c), dtype=np.uint64).astype(np.uint32)
        elems[u, :c] = vals
        valid[u, :c] = 1
    seeds = rng.integers(0, 1 << 32, size=U, dtype=np.uint64).astype(np.uint32)

    parity, xor_bits = bin_parity_xorsum_units(
        jnp.array(elems), jnp.array(valid), jnp.array(seeds), n_bins=n_bins
    )
    p_ref, x_ref = kref.bin_parity_xorsum_units_ref(elems, valid, seeds, n_bins)
    np.testing.assert_array_equal(np.array(parity), p_ref)
    np.testing.assert_array_equal(np.array(xor_bits_to_u32(xor_bits)), x_ref)
