"""The batched multi-session engine (repro.recon) vs the numpy oracle.

Every assertion is unit-for-unit equality with ``core.pbs.reconcile``: same
diff, same per-round byte ledger, same round count, same split/fake
counters — the engine is the same state machine with the bin/sketch/decode
tables computed by the accelerator kernels (DESIGN.md §5).
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.bch import BCHCode, batched_decode, sketch_from_positions
from repro.core.pbs import PBSConfig, reconcile, true_diff
from repro.core.simdata import make_pair, make_pair_two_sided
from repro.kernels import bin_parity_xorsum_units, xor_bits_to_u32
from repro.kernels import ref as kref
from repro.kernels.ops import bch_decode_batched, sketch_groups
from repro.net import AliceEndpoint, BobEndpoint, InMemoryDuplex, run_pair, tcp_loopback_pair
from repro.recon import ReconcileServer, reconcile_batch

SIZES = {5: 1500, 50: 4000, 500: 8000}


def _assert_matches_oracle(got, a, b, cfg, d_known):
    exp = reconcile(a, b, cfg, d_known=d_known)
    assert got.diff == exp.diff
    assert got.bytes_sent == exp.bytes_sent
    assert got.bytes_per_round == exp.bytes_per_round
    assert got.rounds == exp.rounds
    assert got.success == exp.success
    assert got.estimator_bytes == exp.estimator_bytes
    assert got.decode_failures == exp.decode_failures
    assert got.fake_rejections == exp.fake_rejections
    assert (got.n, got.t, got.g) == (exp.n, exp.t, exp.g)
    return exp


def test_batched_matches_oracle_across_d():
    """One mixed batch spanning d in {5, 50, 500} (several code cohorts)."""
    cases = []
    for i, d in enumerate(sorted(SIZES)):
        a, b = make_pair(SIZES[d], d, np.random.default_rng(d))
        cases.append((a, b, PBSConfig(seed=10 + i), d))
    server = ReconcileServer()
    for a, b, cfg, d in cases:
        server.submit(a, b, cfg=cfg, d_known=d)
    results = server.run()
    for i, (a, b, cfg, d) in enumerate(cases):
        exp = _assert_matches_oracle(results[i], a, b, cfg, d)
        assert exp.success and exp.diff == true_diff(a, b)


def test_estimator_and_two_sided_sessions():
    """Unknown d (ToW phase 0) and two-sided differences, batched together."""
    a1, b1 = make_pair(6000, 80, np.random.default_rng(2))
    a2, b2 = make_pair_two_sided(5000, 30, 20, np.random.default_rng(3))
    cases = [(a1, b1, PBSConfig(seed=8), None), (a2, b2, PBSConfig(seed=2), 50)]
    server = ReconcileServer()
    for a, b, cfg, dk in cases:
        server.submit(a, b, cfg=cfg, d_known=dk)
    results = server.run()
    for i, (a, b, cfg, dk) in enumerate(cases):
        exp = _assert_matches_oracle(results[i], a, b, cfg, dk)
        assert exp.success and exp.diff == true_diff(a, b)


def test_decode_failure_splits_without_perturbing_neighbors():
    """A BCH-overloaded session must 3-way split and converge while its batch
    neighbors reconcile exactly as they would alone."""
    # session 1: d=40 against t=8 in a single group -> guaranteed overload
    a_f, b_f = make_pair(5000, 40, np.random.default_rng(17))
    cfg_f = PBSConfig(seed=6, n_override=255, t_override=8, g_override=1, max_rounds=12)
    neighbors = [
        (*make_pair(2000, 10, np.random.default_rng(7)), PBSConfig(seed=21), 10),
        (*make_pair(3000, 25, np.random.default_rng(9)), PBSConfig(seed=23), 25),
    ]

    server = ReconcileServer()
    server.submit(neighbors[0][0], neighbors[0][1], cfg=neighbors[0][2], d_known=neighbors[0][3])
    server.submit(a_f, b_f, cfg=cfg_f, d_known=40)
    server.submit(neighbors[1][0], neighbors[1][1], cfg=neighbors[1][2], d_known=neighbors[1][3])
    results = server.run()

    failing = _assert_matches_oracle(results[1], a_f, b_f, cfg_f, 40)
    assert results[1].decode_failures >= 1          # the split actually fired
    assert results[1].success and results[1].diff == true_diff(a_f, b_f)
    assert failing.rounds > 1                       # re-queue spanned rounds

    # neighbors: byte-for-byte what they'd do in a batch of one
    for sid, (a, b, cfg, dk) in zip((0, 2), neighbors):
        _assert_matches_oracle(results[sid], a, b, cfg, dk)


@pytest.mark.parametrize("transport", ["memory", "loopback"])
def test_wire_endpoints_match_engine_and_oracle_across_d(transport):
    """Acceptance gate for the wire subsystem: the full multi-session grid
    (several code cohorts) with Alice and Bob as separate repro.net
    endpoints exchanging only repro.wire-encoded bytes, over both the
    in-memory duplex and the loopback socket.  Per-session results must be
    byte-identical to ``core.pbs.reconcile`` and the *measured* wire ledger
    equal to the legacy accounting for every session in the grid."""
    cases = []
    for i, d in enumerate(sorted(SIZES)):
        a, b = make_pair(SIZES[d], d, np.random.default_rng(d))
        cases.append((a, b, PBSConfig(seed=10 + i), d))

    ta, tb = (
        InMemoryDuplex.pair() if transport == "memory" else tcp_loopback_pair()
    )
    try:
        alice, bob = AliceEndpoint(ta), BobEndpoint(tb)
        for a, b, cfg, d in cases:
            alice.submit(a, cfg=cfg, d_known=d)
            bob.submit(b, cfg=cfg, d_known=d)
        results = run_pair(alice, bob)
    finally:
        ta.close()
        tb.close()

    server = ReconcileServer()
    for a, b, cfg, d in cases:
        server.submit(a, b, cfg=cfg, d_known=d)
    engine = server.run()

    for sid, (a, b, cfg, d) in enumerate(cases):
        exp = _assert_matches_oracle(results[sid], a, b, cfg, d)
        assert exp.success and exp.diff == true_diff(a, b)
        # wire ledger (measured from frames) == batched engine's accounting
        assert results[sid].bytes_per_round == engine[sid].bytes_per_round
        assert results[sid].bytes_sent == engine[sid].bytes_sent
    assert bob.verified == [True] * len(cases)


def test_session_exceeding_max_rounds_reports_failure():
    """An undersized code that can't converge must fail identically batched."""
    a, b = make_pair(2000, 30, np.random.default_rng(5))
    cfg = PBSConfig(seed=4, n_override=63, t_override=2, g_override=1, max_rounds=2)
    server = ReconcileServer()
    server.submit(a, b, cfg=cfg, d_known=30)
    got = server.run()[0]
    exp = _assert_matches_oracle(got, a, b, cfg, 30)
    assert not exp.success  # sanity: this really is the failure path


def test_reconcile_batch_convenience_order():
    pairs = [make_pair(1200, d, np.random.default_rng(40 + d)) for d in (3, 7, 11)]
    results = reconcile_batch(
        pairs, cfgs=PBSConfig(seed=5), d_knowns=[3, 7, 11]
    )
    for (a, b), res in zip(pairs, results):
        assert res.success and res.diff == true_diff(a, b)


def _assert_decode_matches_oracle(code, sketches):
    """bch_decode_batched must agree with core.bch.batched_decode row-for-row."""
    ok_ref, pos_ref = batched_decode(code, sketches)
    ok, pos, cnt = bch_decode_batched(
        jnp.asarray(sketches, dtype=jnp.int32), n=code.n, t=code.t
    )
    ok, pos, cnt = np.asarray(ok), np.asarray(pos), np.asarray(cnt)
    np.testing.assert_array_equal(ok, ok_ref)
    for u in range(len(sketches)):
        np.testing.assert_array_equal(pos[u, : cnt[u]], pos_ref[u])
        assert np.all(pos[u, cnt[u] :] == -1)  # padding convention
    return ok, pos, cnt


def test_bch_decode_batched_t1_code():
    """t=1 codes: the degenerate single-syndrome BM path, incl. the known
    2-error aliasing (two errors can mimic one; the protocol's checksum gate
    is what catches it) — kernel and numpy oracle must agree on all of it."""
    code = BCHCode(127, 1)
    sk = np.stack([
        np.zeros(1, np.int64),
        sketch_from_positions(code, np.array([13])),
        sketch_from_positions(code, np.array([5, 97])),  # aliases to one root
        sketch_from_positions(code, np.array([0])),      # boundary positions
        sketch_from_positions(code, np.array([126])),
    ])
    ok, pos, cnt = _assert_decode_matches_oracle(code, sk)
    assert ok.all()                       # t=1 decode "succeeds" on all rows
    assert list(pos[1, :1]) == [13] and list(pos[3, :1]) == [0]
    assert list(pos[4, :1]) == [126]
    assert cnt[2] == 1                    # the 2-error alias: one fake root


def test_bch_decode_batched_zero_rows_mixed_with_overload():
    """All-zero sketches (reconciled units) interleaved with genuinely
    overloaded rows (> t differing bins) in one batch: zeros decode
    trivially-ok, overloads fail and expose no positions."""
    code = BCHCode(255, 3)
    sk = np.stack([
        np.zeros(3, np.int64),
        sketch_from_positions(code, np.array([7, 19, 200])),
        sketch_from_positions(code, np.arange(1, 9)),    # 8 errors >> t=3
        np.zeros(3, np.int64),
        sketch_from_positions(code, np.arange(11, 16)),  # 5 errors > t=3
    ])
    ok, pos, cnt = _assert_decode_matches_oracle(code, sk)
    np.testing.assert_array_equal(ok, [True, True, False, True, False])
    assert cnt[0] == cnt[3] == 0 and np.all(pos[0] == -1)
    assert list(pos[1, :3]) == [7, 19, 200]
    assert cnt[2] == cnt[4] == 0 and np.all(pos[2] == -1)  # no positions leak


def test_padded_unit_decodes_trivially_ok():
    """A valid==0 row (cohort padding unit) through the full encode→decode
    path must sketch to zero and decode trivially-ok, exactly like the
    oracle decodes an all-zero difference sketch."""
    code = BCHCode(127, 2)
    rng = np.random.default_rng(42)
    U, E = 4, 64
    elems_a = rng.integers(1, 1 << 32, size=(U, E), dtype=np.uint64).astype(np.uint32)
    elems_b = elems_a.copy()
    elems_b[0, :3] = rng.integers(1, 1 << 32, size=3)   # unit 0 differs
    valid = np.ones((U, E), np.int32)
    valid[2] = 0                                         # unit 2 is all-padding
    seeds = np.full(U, 99, np.uint32)

    def sketch(elems):
        parity, _ = bin_parity_xorsum_units(
            jnp.asarray(elems), jnp.asarray(valid), jnp.asarray(seeds), n_bins=code.n
        )
        return sketch_groups(parity, code)

    diff = np.asarray(sketch(elems_a) ^ sketch(elems_b))
    assert np.all(diff[2] == 0)                          # padding sketches to zero
    ok, pos, cnt = _assert_decode_matches_oracle(code, diff.astype(np.int64))
    assert ok[2] and cnt[2] == 0 and np.all(pos[2] == -1)
    assert ok[1] and ok[3] and cnt[1] == cnt[3] == 0     # identical rows: zero diff


def test_upload_once_store_h2d_ratio():
    """The device-resident pipeline's acceptance gate: over a multi-round
    batch, total H2D traffic (store once + per-round overlays) must be at
    least 3x smaller than the re-pack-per-round layout's, with half its
    kernel launches per round."""
    server = ReconcileServer()
    for s in range(4):
        a, b = make_pair(2500, 50, np.random.default_rng(60 + s))
        server.submit(a, b, cfg=PBSConfig(seed=s), d_known=50)
    results = server.run()
    assert all(results[s].success for s in range(4))
    stats = server.stats
    assert stats["rounds"] >= 2                      # multi-round workload
    assert stats["h2d_ratio"] >= 3.0, stats
    assert stats["kernel_launches"] == 2 * stats["cohort_rounds"]
    assert stats["legacy_kernel_launches"] == 4 * stats["cohort_rounds"]
    # overlays are small: steady-state rounds ship a tiny fraction of a
    # full re-upload
    assert stats["h2d_round_bytes"] < 0.1 * stats["legacy_h2d_round_bytes"]


@pytest.mark.parametrize("n_bins", [63, 127, 8191])
def test_units_kernel_matches_mulshift_oracle(n_bins):
    """The batched bin kernel's 16-bit-split multiply-shift must equal the
    uint64 ground truth (== core.hashing.hash_to_range) bit-for-bit."""
    rng = np.random.default_rng(n_bins)
    U, E = 6, 257
    counts = rng.integers(0, E, size=U)
    counts[0], counts[1] = 0, E  # empty row + full row edges
    elems = np.zeros((U, E), np.uint32)
    valid = np.zeros((U, E), np.int32)
    for u, c in enumerate(counts):
        vals = rng.integers(1, 1 << 32, size=int(c), dtype=np.uint64).astype(np.uint32)
        elems[u, :c] = vals
        valid[u, :c] = 1
    seeds = rng.integers(0, 1 << 32, size=U, dtype=np.uint64).astype(np.uint32)

    parity, xor_bits = bin_parity_xorsum_units(
        jnp.array(elems), jnp.array(valid), jnp.array(seeds), n_bins=n_bins
    )
    p_ref, x_ref = kref.bin_parity_xorsum_units_ref(elems, valid, seeds, n_bins)
    np.testing.assert_array_equal(np.array(parity), p_ref)
    np.testing.assert_array_equal(np.array(xor_bits_to_u32(xor_bits)), x_ref)
