"""Distribution correctness: the SAME model computed on different meshes must
produce the same losses, gradients and tokens (fp32, deterministic data).

Runs each mesh in a subprocess (the device count is locked at first jax init,
so the 8 fake host devices need a fresh process)."""
import json
import subprocess
import sys

import numpy as np
import pytest
pytestmark = pytest.mark.slow  # distribution tier: subprocess mesh sweeps, full-suite job only


SCRIPT = r"""
import os, sys, json
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
arch, data, model, zero1 = sys.argv[1], int(sys.argv[2]), int(sys.argv[3]), sys.argv[4] == "1"
import jax, jax.numpy as jnp, numpy as np

# force fp32 params so cross-mesh reduction order is the only difference
import repro.models.spec as spec_mod
import repro.train.step as ts
import repro.serve.engine as se
from repro.models.backbone import model_spec as _orig_spec
from repro.models.spec import P, tree_map_p

def f32_spec(cfg, ctx):
    return tree_map_p(
        lambda p: P(p.shape, p.axes, p.init, p.scale,
                    jnp.float32 if p.dtype == jnp.bfloat16 else p.dtype,
                    p.logical),
        _orig_spec(cfg, ctx))
ts.model_spec = f32_spec
se.model_spec = f32_spec

from repro.configs import get_smoke_config
from repro.optim import OptConfig
from repro.train import make_train_step, init_train_state
from repro.serve import make_serve_fns

mesh = jax.make_mesh((data, model), ("data", "model"),
                     devices=jax.devices()[: data * model],
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
cfg = get_smoke_config(arch)
ocfg = OptConfig(warmup=2, total_steps=10, zero1=zero1)
B, T, ENC = 4, 64, 32
bundle = make_train_step(cfg, mesh, ocfg, batch=B)
params, opt = init_train_state(bundle, cfg, mesh, ocfg, seed=0)
rng = np.random.default_rng(0)
toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, T)), jnp.int32)
batch = {"tokens": toks, "labels": toks}
if cfg.family == "encdec":
    batch["enc"] = jnp.asarray(rng.normal(size=(B, ENC, cfg.d_model)), jnp.float32)
if cfg.frontend == "patch_stub":
    batch["tokens"] = batch["tokens"].at[:, : cfg.n_frontend_tokens].set(-1)
    batch["frontend"] = jnp.asarray(rng.normal(size=(B, T, cfg.d_model)), jnp.float32)

# serve parity on the UNTRAINED params (identical across meshes -> tokens
# must match exactly; after training, params drift by fp32 reduction order
# and near-tie argmaxes flip)
sv = make_serve_fns(cfg, mesh, batch=B, max_len=T, enc_len=ENC)
inputs = {k: v for k, v in batch.items() if k in ("tokens", "enc", "frontend")}
caches, tok = sv.prefill(params, inputs)
seq = [np.asarray(tok).tolist()]
for _ in range(3):
    tok, caches = sv.decode(params, caches, tok[:, None])
    seq.append(np.asarray(tok).tolist())

losses, gnorms = [], []
for _ in range(3):
    params, opt, m = bundle.step(params, opt, batch)
    losses.append(float(m["loss"])); gnorms.append(float(m["grad_norm"]))
print("RESULT" + json.dumps({"losses": losses, "gnorms": gnorms, "tokens": seq}))
"""


def _run(arch, data, model, zero1=False):
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT, arch, str(data), str(model), "1" if zero1 else "0"],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"},
    )
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT")][-1]
    return json.loads(line[len("RESULT"):])


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["qwen2-1.5b", "deepseek-v3-671b", "mamba2-780m",
                                  "recurrentgemma-2b", "whisper-tiny"])
def test_mesh_parity(arch):
    ref = _run(arch, 1, 1)
    tp = _run(arch, 2, 4)
    # fp32 reduction order differs across meshes (LSE-combined decode,
    # chunked attention pairs, flat optimizer updates); drift compounds.
    np.testing.assert_allclose(ref["losses"], tp["losses"], rtol=5e-3, atol=5e-3)
    np.testing.assert_allclose(ref["gnorms"], tp["gnorms"], rtol=2e-2, atol=2e-2)
    # Greedy argmax over random-init logits sits on near-ties, so a 1e-6
    # cross-mesh reduction-order difference (LSE-combined decode) can flip a
    # token, after which that row's continuation legitimately diverges.  The
    # guaranteed-equal part is the prefill next-token (forward math, already
    # bounded by the loss check above); incremental-decode correctness is
    # covered exactly per-mesh by tests/test_serve_consistency.py.
    # MoE capacity dropping is topology-dependent by design (per-rank
    # dispatch buffers), so one dropped-token row may differ there.
    mism = sum(a != b for a, b in zip(ref["tokens"][0], tp["tokens"][0]))
    allow = 1 if arch.startswith("deepseek") else 0
    assert mism <= allow, (ref["tokens"][0], tp["tokens"][0])


@pytest.mark.slow
def test_zero1_matches_plain_adamw():
    plain = _run("qwen2-1.5b", 4, 2, zero1=False)
    z1 = _run("qwen2-1.5b", 4, 2, zero1=True)
    np.testing.assert_allclose(plain["losses"], z1["losses"], rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(plain["gnorms"], z1["gnorms"], rtol=2e-4, atol=2e-4)
    assert plain["tokens"] == z1["tokens"]
