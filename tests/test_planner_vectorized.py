"""Differential suite: vectorized cohort planner vs the scalar reference.

PR 6's tentpole rewrites ``SessionBatch._plan_cohort`` from per-session /
per-unit Python loops into whole-batch numpy passes (DESIGN.md §12).  The
license for that rewrite is byte-identity: these tests pin the old scalar
planner (tests/_planner_reference.py, kept verbatim) against the live
vectorized one over real reconciliation runs — every cohort, every round,
every overlay — and assert the emitted ``CohortRoundPlan``s are equal in
every array, width, seed, and byte count, while the end-to-end results stay
byte-identical to the ``core.pbs.reconcile`` oracle.

Covered planner regimes: mixed-d cohorts, estimator sessions, two-sided
diffs, BCH-overload splits (filter overlays), and continuous-sync epochs
under churn (delta-mutated stores).  Randomized variants run seeded; the
hypothesis forms engage when the ``[test]`` extra is installed.
"""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from _planner_reference import reference_plan_cohort, reference_plan_round

from repro.core.pbs import PBSConfig, reconcile, true_diff
from repro.core.simdata import make_pair, make_pair_two_sided
from repro.recon import ReconcileServer
from repro.recon.session import SessionBatch


def _assert_plans_equal(got, ref, ctx=""):
    assert got.units == ref.units, ctx
    assert got.width_a == ref.width_a, ctx
    assert got.width_b == ref.width_b, ctx
    assert got.h2d_bytes == ref.h2d_bytes, ctx
    assert got.legacy_h2d_bytes == ref.legacy_h2d_bytes, ctx
    assert got.store is ref.store, ctx
    assert set(got.arrays) == set(ref.arrays), ctx
    for k in got.arrays:
        assert got.arrays[k].dtype == ref.arrays[k].dtype, (ctx, k)
        assert got.arrays[k].shape == ref.arrays[k].shape, (ctx, k)
        assert np.array_equal(got.arrays[k], ref.arrays[k]), (ctx, k)
    assert len(got.members) == len(ref.members), ctx
    for (s1, b1, a1, sd1), (s2, b2, a2, sd2) in zip(got.members, ref.members):
        assert s1 is s2 and b1 == b2 and sd1 == sd2, ctx
        assert len(a1) == len(a2) and all(
            u1 is u2 for u1, u2 in zip(a1, a2)
        ), ctx


@pytest.fixture
def checked_planner(monkeypatch):
    """Route every live ``_plan_cohort`` call through both planners and
    assert plan equality; yields the compared-plan counter."""
    calls = {"n": 0}
    orig = SessionBatch._plan_cohort

    def checked(self, store, members, rnd):
        got = orig(self, store, members, rnd)
        ref = reference_plan_cohort(self, store, members, rnd)
        _assert_plans_equal(got, ref, ctx=f"rnd={rnd}")
        calls["n"] += 1
        return got

    monkeypatch.setattr(SessionBatch, "_plan_cohort", checked)
    return calls


def _assert_oracle(result, a, b, cfg, dk):
    exp = reconcile(a, b, cfg, d_known=dk)
    assert result.diff == exp.diff == true_diff(a, b)
    assert result.bytes_sent == exp.bytes_sent
    assert result.bytes_per_round == exp.bytes_per_round
    assert result.rounds == exp.rounds
    assert result.success and exp.success


def test_mixed_grid_every_round_identical(checked_planner):
    """Mixed-d cohorts + an estimator session + a two-sided session: every
    cohort plan of every round must match the scalar reference, and the
    results must stay oracle-byte-identical."""
    cases = [
        (*make_pair(1500, 5, np.random.default_rng(5)), PBSConfig(seed=10), 5),
        (*make_pair(4000, 50, np.random.default_rng(50)), PBSConfig(seed=11), 50),
        (*make_pair(6000, 80, np.random.default_rng(2)), PBSConfig(seed=8), None),
        (
            *make_pair_two_sided(5000, 30, 20, np.random.default_rng(3)),
            PBSConfig(seed=2),
            50,
        ),
    ]
    server = ReconcileServer()
    for a, b, cfg, dk in cases:
        server.submit(a, b, cfg=cfg, d_known=dk)
    results = server.run()
    assert checked_planner["n"] >= 2  # multiple cohort-rounds actually compared
    for i, (a, b, cfg, dk) in enumerate(cases):
        _assert_oracle(results[i], a, b, cfg, dk)


def test_split_filters_identical(checked_planner):
    """A BCH-overloaded session (guaranteed 3-way split) exercises the
    sparse filter-overlay fills; plans must still match row for row."""
    a_f, b_f = make_pair(5000, 40, np.random.default_rng(17))
    cfg_f = PBSConfig(
        seed=6, n_override=255, t_override=8, g_override=1, max_rounds=12
    )
    server = ReconcileServer()
    server.submit(a_f, b_f, cfg=cfg_f, d_known=40)
    results = server.run()
    assert checked_planner["n"] >= 2  # split spanned several rounds
    assert results[0].decode_failures >= 1
    _assert_oracle(results[0], a_f, b_f, cfg_f, 40)


def test_churn_epochs_identical(checked_planner):
    """Continuous-sync epochs over delta-mutated stores: the planner runs
    against patched (slack-lane) CSR layouts; every epoch's plans and
    results must still match reference and oracle."""
    rng = np.random.default_rng(9)
    a, b = make_pair(900, 20, np.random.default_rng(1))
    cfg = PBSConfig(seed=3, n_override=127, t_override=7, g_override=4)
    server = ReconcileServer(continuous=True)
    server.submit(a, b, cfg=cfg, d_known=20)
    server.run()
    for _ in range(2):
        add_a = rng.integers(1, 1 << 32, size=6, dtype=np.uint64).astype(np.uint32)
        add_b = rng.integers(1, 1 << 32, size=6, dtype=np.uint64).astype(np.uint32)
        st_ = server.sessions[0].state
        rem_a = rng.permutation(st_.a)[:4]
        rem_b = rng.permutation(st_.b)[:4]
        server.advance_epoch({0: (add_a, rem_a, add_b, rem_b)}, d_known={0: 20})
        results = server.run()
        st_ = server.sessions[0].state
        _assert_oracle(results[0], st_.a, st_.b, cfg, 20)
    assert checked_planner["n"] >= 3


def test_plan_round_matches_reference_direct():
    """Static check (no engine in the loop): ``plan_round`` over a fresh
    batch vs ``reference_plan_round``, cohort by cohort."""
    server = ReconcileServer()
    for i, d in enumerate((8, 60, 300)):
        a, b = make_pair(500 + 900 * i, d, np.random.default_rng(d))
        server.submit(a, b, cfg=PBSConfig(seed=30 + i), d_known=d)
    server._flush_phase0()
    batch = SessionBatch(server._sessions)
    plans_v = batch.plan_round(1)
    plans_r = reference_plan_round(batch, 1)
    assert len(plans_v) == len(plans_r) >= 2
    for got, ref in zip(plans_v, plans_r):
        _assert_plans_equal(got, ref, ctx="direct")


@pytest.mark.parametrize(
    "seed",
    # seed 0 rides the fast tier; the redundant heavier seeds run in the
    # full-suite job (same property, ~10s apiece)
    [0] + [pytest.param(s, marks=pytest.mark.slow) for s in (1, 2, 3)],
)
def test_randomized_grids_seeded(seed, checked_planner):
    """Seeded random batches (always-run stand-in for the hypothesis form):
    random sizes, diffs, and seeds across several sessions per batch."""
    rng = np.random.default_rng(1000 + seed)
    server = ReconcileServer()
    cases = []
    for _ in range(int(rng.integers(2, 5))):
        d = int(rng.integers(1, 120))
        size = int(rng.integers(max(2 * d, 50), 4000))
        a, b = make_pair(size, d, np.random.default_rng(int(rng.integers(1 << 30))))
        cfg = PBSConfig(seed=int(rng.integers(1, 1 << 16)))
        dk = d if rng.integers(2) else None
        server.submit(a, b, cfg=cfg, d_known=dk)
        cases.append((a, b, cfg, dk))
    results = server.run()
    assert checked_planner["n"] >= 1
    for i, (a, b, cfg, dk) in enumerate(cases):
        exp = reconcile(a, b, cfg, d_known=dk)
        assert results[i].diff == exp.diff
        assert results[i].bytes_sent == exp.bytes_sent
        assert results[i].success == exp.success


@given(
    d=st.integers(min_value=1, max_value=150),
    size_extra=st.integers(min_value=0, max_value=3000),
    seed=st.integers(min_value=0, max_value=1 << 16),
)
@settings(max_examples=15, deadline=None)
def test_hypothesis_single_session_plans(d, size_extra, seed):
    """Property form: for arbitrary (d, size, seed) the round-1 plan of a
    fresh batch equals the scalar reference plan exactly."""
    size = max(2 * d, 40) + size_extra
    a, b = make_pair(size, d, np.random.default_rng(seed))
    server = ReconcileServer()
    server.submit(a, b, cfg=PBSConfig(seed=seed), d_known=d)
    server._flush_phase0()
    batch = SessionBatch(server._sessions)
    plans_v = batch.plan_round(1)
    plans_r = reference_plan_round(batch, 1)
    assert len(plans_v) == len(plans_r) == 1
    _assert_plans_equal(plans_v[0], plans_r[0], ctx="hypothesis")
