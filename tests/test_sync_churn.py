"""Churn soak: a 4-peer continuous-sync hub under multi-epoch churn.

The ISSUE 5 acceptance scenario: one ``HubEndpoint(continuous=True)``
serving 4 peers (mixed known-d and estimator sessions) across many epochs
with random add/remove churn between epochs — including an epoch with
d = 0 (no churn at all) and one straggler evicted mid-epoch — where

* every *surviving* peer's per-epoch results are byte-identical to a fresh
  ``core.pbs.reconcile`` oracle over that epoch's sets (diff, rounds,
  per-round measured wire ledger, estimator bytes);
* the stats ledger proves the delta path: **zero cohort store rebuilds
  after epoch 0** and cumulative delta-H2D bytes ≤ 25% of what rebuilding
  the stores every epoch would have uploaded;
* the straggler fails alone, at its barrier deadline, without perturbing
  the other peers' epoch.

The full ≥20-epoch soak is marked ``slow`` (CI's non-blocking full-suite
job); the seeded 3-epoch variant — same machinery, same assertions, d = 0
epoch included — runs in the blocking fast tier.
"""
import numpy as np
import pytest

from repro.core.pbs import PBSConfig, reconcile, true_diff
from repro.core.simdata import make_pair
from repro.net import (
    AliceEndpoint,
    HubEndpoint,
    InMemoryDuplex,
    run_hub,
    run_hub_epoch,
)
from repro.recon.session import apply_churn

_EMPTY = np.zeros(0, dtype=np.uint32)


class _SilentMidEpoch(AliceEndpoint):
    """A straggler: completes the epoch handshake, then never sends a round
    frame — the hub must evict it at the round-barrier deadline while the
    other peers' epoch proceeds."""

    silent = False

    def _run_rounds(self):
        if self.silent:
            return {}
        return super()._run_rounds()


def _fresh_elems(rng, k):
    return rng.integers(1, 1 << 32, size=k, dtype=np.uint64).astype(np.uint32)


def _churn_soak(epochs, *, straggle_at=None, d0_at=None, seed=0,
                deadline=20.0):
    """Drive the soak; returns (hub, per-epoch delta bytes, store bytes)."""
    peers = 4
    d = 20
    rng = np.random.default_rng(seed)
    hub = HubEndpoint(recv_deadline=deadline, continuous=True)
    alices: dict[int, AliceEndpoint] = {}
    cfgs: dict[int, PBSConfig] = {}
    dks: dict[int, int | None] = {}
    for p in range(peers):
        a, b = make_pair(700, d, np.random.default_rng(seed + 101 * p))
        # peer 3 re-estimates d̂ over the wire each epoch; the pinned
        # (n, t, g) keeps every layout epoch-stable => pure delta path
        dk = None if p == 3 else d
        cfg = PBSConfig(seed=seed + p, n_override=127, t_override=7,
                        g_override=4)
        ta, tb = InMemoryDuplex.pair()
        ch = hub.add_peer(tb, label=f"peer{p}")
        hub.submit(ch, b, cfg=cfg, d_known=dk)
        cls = _SilentMidEpoch if p == 1 else AliceEndpoint
        ep = cls(ta, channel=ch, continuous=True)
        ep.submit(a, cfg=cfg, d_known=dk)
        alices[ch] = ep
        cfgs[ch], dks[ch] = cfg, dk

    outcomes, results, errors = run_hub(hub, alices)
    assert not errors and all(o.ok for o in outcomes.values())
    uploads0 = hub.stats["store_uploads"]
    assert uploads0 == 1                    # one cohort across all peers
    store_bytes = hub._batch.store_upload_bytes()
    assert store_bytes > 0
    delta_per_epoch = []

    evicted: set[int] = set()
    for e in range(1, epochs + 1):
        quiet = e == d0_at
        hub_muts: dict[int, dict] = {}
        alice_muts: dict[int, dict] = {}
        for ch, ep in alices.items():
            if ch in evicted or quiet:
                continue
            b_cur = hub._peers[ch].sessions[0].state.b
            hub_muts[ch] = {0: (_fresh_elems(rng, 8),
                                rng.permutation(b_cur)[:8])}
            a_base = ep.sessions[0].state.a
            alice_muts[ch] = {0: (_fresh_elems(rng, 2),
                                  rng.permutation(a_base)[:2])}
        hub.advance_epoch(hub_muts)
        for ch, ep in alices.items():
            if ch in evicted:
                continue
            ep.advance_epoch(alice_muts.get(ch, {}))
            if straggle_at == e and isinstance(ep, _SilentMidEpoch):
                ep.silent = True

        live = {ch: ep for ch, ep in alices.items() if ch not in evicted}
        outcomes, results, errors = run_hub_epoch(hub, live)
        st = hub.stats

        # the delta-path contract: zero rebuilds after epoch 0, O(churn)
        # scatter traffic only (and literally zero when nothing churned)
        assert st["store_builds"] == 0, (e, st)
        assert st["store_compactions"] == 0, (e, st)
        assert st["store_uploads"] == uploads0
        if quiet:
            assert st["h2d_delta_bytes"] == 0
        else:
            assert 0 < st["h2d_delta_bytes"] < store_bytes
        delta_per_epoch.append(st["h2d_delta_bytes"])

        for ch, ep in live.items():
            if straggle_at == e and isinstance(ep, _SilentMidEpoch):
                # evicted at the round barrier: clean per-peer error, its
                # sessions failed, everyone else untouched
                assert not outcomes[ch].ok
                assert outcomes[ch].error is not None
                assert all(s.failed for s in outcomes[ch].sessions)
                evicted.add(ch)
                continue
            assert ch not in errors, errors.get(ch)
            assert outcomes[ch].ok and outcomes[ch].verified == [True]
            a_e = ep.sessions[0].state.a
            b_e = hub._peers[ch].sessions[0].state.b
            r = results[ch][0]
            oracle = reconcile(a_e, b_e, cfgs[ch], d_known=dks[ch])
            td = true_diff(a_e, b_e)
            if quiet:
                assert td == set()
            assert r.success and r.diff == oracle.diff == td, (e, ch)
            assert r.rounds == oracle.rounds
            assert r.bytes_per_round == oracle.bytes_per_round, (e, ch)
            assert r.bytes_sent == oracle.bytes_sent
            assert r.estimator_bytes == oracle.estimator_bytes
            assert (r.n, r.t, r.g, r.d_est) == (
                oracle.n, oracle.t, oracle.g, oracle.d_est
            )

    # the headline acceptance gate: O(churn) H2D per epoch, not O(|B|) —
    # cumulative delta bytes ≤ 25% of rebuilding the store every epoch
    frac = sum(delta_per_epoch) / (epochs * store_bytes)
    assert frac <= 0.25, (frac, delta_per_epoch, store_bytes)
    if straggle_at is not None:
        assert evicted, "straggler epoch never ran"
    return hub


def test_churn_epochs_fast():
    """3 seeded epochs (d = 0 epoch included): the fast-tier variant."""
    _churn_soak(3, d0_at=2, seed=42)


@pytest.mark.slow
def test_churn_soak_20_epochs():
    """The full acceptance soak: ≥20 epochs at ~5% churn with a d = 0
    epoch and a mid-epoch straggler eviction."""
    _churn_soak(20, straggle_at=5, d0_at=10, seed=7, deadline=6.0)
