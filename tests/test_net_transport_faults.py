"""Fault injection for repro.net.transport and the hub's failure paths.

Covers the ARQ state machine under adversarial datagrams (duplicates,
stale ACKs), every transport's typed timeout path, endpoint behavior when
the peer closes mid-protocol (a clean ``TransportError``, never a hang),
and the hub's per-peer eviction when one of N peers drops at each protocol
phase while a healthy neighbor completes byte-identically.
"""
import threading

import numpy as np
import pytest

from repro.core.pbs import PBSConfig, reconcile, true_diff
from repro.core.simdata import make_pair
from repro.net import (
    AliceEndpoint,
    BobEndpoint,
    ChaosTransport,
    FaultPlan,
    HubEndpoint,
    InMemoryDuplex,
    ReliableTransport,
    SimulatedChannel,
    Transport,
    TransportError,
    TransportTimeout,
    run_hub,
    run_pair,
    tcp_loopback_pair,
)
from repro.net.transport import FrameStream
from repro.wire.varint import decode_uvarint, encode_uvarint

_DATA, _ACK = 0x00, 0x01


def _dgram(kind: int, seq: int, payload: bytes = b"") -> bytes:
    return bytes((kind,)) + encode_uvarint(seq) + payload


def _parse(dgram: bytes):
    kind = dgram[0]
    seq, off = decode_uvarint(dgram, 1)
    return kind, seq, dgram[off:]


# ---------------------------------------------------------------------------
# ReliableTransport vs adversarial datagrams
# ---------------------------------------------------------------------------


def test_duplicated_data_datagrams_are_suppressed_and_reacked():
    raw, side = InMemoryDuplex.pair()
    rt = ReliableTransport(side, timeout=0.05)
    raw.send(_dgram(_DATA, 0, b"hello"))
    raw.send(_dgram(_DATA, 0, b"hello"))      # duplicate of the same seq
    assert rt.recv(timeout=0.5) == b"hello"
    # the duplicate is suppressed: nothing further is delivered
    with pytest.raises(TransportTimeout):
        rt.recv(timeout=0.2)
    # but BOTH copies were ACKed (the dupe re-ACK is what heals a lost ack)
    acks = [_parse(raw.recv(timeout=0.5)) for _ in range(2)]
    assert acks == [(_ACK, 0, b""), (_ACK, 0, b"")]


def test_stale_data_seq_after_progress_is_reacked_not_delivered():
    raw, side = InMemoryDuplex.pair()
    rt = ReliableTransport(side, timeout=0.05)
    raw.send(_dgram(_DATA, 0, b"one"))
    raw.send(_dgram(_DATA, 1, b"two"))
    assert rt.recv(timeout=0.5) == b"one"
    assert rt.recv(timeout=0.5) == b"two"
    raw.send(_dgram(_DATA, 0, b"one"))        # stale retransmit from the past
    with pytest.raises(TransportTimeout):
        rt.recv(timeout=0.2)
    kinds = [_parse(raw.recv(timeout=0.5)) for _ in range(3)]
    assert kinds == [(_ACK, 0, b""), (_ACK, 1, b""), (_ACK, 0, b"")]


def test_stale_ack_does_not_complete_send():
    """An ACK for the wrong sequence number must not satisfy an in-flight
    send — the sender keeps retransmitting until the *matching* ACK."""
    raw, side = InMemoryDuplex.pair()
    rt = ReliableTransport(side, timeout=0.05, max_retries=50)
    done = threading.Event()

    def _send():
        rt.send(b"payload")
        done.set()

    th = threading.Thread(target=_send, daemon=True)
    th.start()
    kind, seq, payload = _parse(raw.recv(timeout=1.0))
    assert (kind, seq, payload) == (_DATA, 0, b"payload")
    raw.send(_dgram(_ACK, 99))                # stale/foreign ack: ignored
    # the sender must retransmit (stale ack did not complete the send)
    kind2, seq2, _ = _parse(raw.recv(timeout=1.0))
    assert (kind2, seq2) == (_DATA, 0)
    assert not done.is_set()
    raw.send(_dgram(_ACK, 0))                 # the genuine ack
    assert done.wait(1.0)
    th.join(1.0)
    assert rt.retransmits >= 1


def test_ack_exhaustion_raises_transport_error():
    raw, side = InMemoryDuplex.pair()
    rt = ReliableTransport(side, timeout=0.01, max_retries=3)
    with pytest.raises(TransportError, match="no ACK"):
        rt.send(b"into the void")


# ---------------------------------------------------------------------------
# typed timeout paths
# ---------------------------------------------------------------------------


def test_recv_timeouts_are_typed_across_transports():
    mem, _ = InMemoryDuplex.pair()
    with pytest.raises(TransportTimeout):
        mem.recv(timeout=0.05)

    ch, _ = SimulatedChannel.pair(latency=0.0)
    with pytest.raises(TransportTimeout):
        ch.recv(timeout=0.05)

    raw, side = InMemoryDuplex.pair()
    rt = ReliableTransport(side, timeout=0.05)
    with pytest.raises(TransportTimeout):
        rt.recv(timeout=0.05)

    # FrameStream propagates the typed timeout (the hub's poll signal)
    stream = FrameStream(InMemoryDuplex.pair()[0])
    with pytest.raises(TransportTimeout):
        stream.recv(timeout=0.05)


class _Trickle(Transport):
    """Delivers a frame one byte at a time with a delay per chunk — a peer
    trying to hold a recv open forever by always sending *something*."""

    def __init__(self, frame_bytes: bytes, delay: float):
        super().__init__()
        self._data = frame_bytes
        self._pos = 0
        self._delay = delay

    def send(self, data: bytes) -> None:
        pass

    def recv(self, timeout: float | None = None) -> bytes:
        import time as _time

        if timeout is not None and timeout < self._delay:
            _time.sleep(max(0.0, timeout))
            raise TransportTimeout("trickle")
        _time.sleep(self._delay)
        b = self._data[self._pos : self._pos + 1]
        self._pos += 1
        return b


def test_frame_recv_deadline_bounds_whole_frame_not_chunks():
    """A trickling peer (1 byte per 30ms, forever) must not hold
    FrameStream.recv open past its deadline — the timeout bounds the whole
    frame, and partial data stays buffered."""
    import time as _time
    from repro.wire import frames as wf

    frame = wf.encode_dhat(1 << 40)           # several bytes long
    stream = FrameStream(_Trickle(frame, delay=0.03))
    t0 = _time.monotonic()
    with pytest.raises(TransportTimeout):
        stream.recv(timeout=0.1)
    assert _time.monotonic() - t0 < 0.5       # not one-timeout-per-chunk


def test_closed_pipe_is_not_a_timeout():
    a, b = InMemoryDuplex.pair()
    b.close()
    with pytest.raises(TransportError) as ei:
        a.recv(timeout=0.5)
    assert not isinstance(ei.value, TransportTimeout)


# ---------------------------------------------------------------------------
# close mid-protocol: errors, never hangs
# ---------------------------------------------------------------------------


class _CloseAfter(Transport):
    """Pass through ``n_sends`` frames, then close and fail."""

    def __init__(self, inner: Transport, n_sends: int):
        super().__init__()
        self._inner = inner
        self._left = n_sends

    def send(self, data: bytes) -> None:
        if self._left <= 0:
            self._inner.close()
            raise TransportError("simulated mid-protocol disconnect")
        self._left -= 1
        self._inner.send(data)

    def recv(self, timeout: float | None = None) -> bytes:
        return self._inner.recv(timeout)

    def close(self) -> None:
        self._inner.close()

    @property
    def bytes_out(self) -> int:  # type: ignore[override]
        return self._inner.bytes_out

    @property
    def bytes_in(self) -> int:  # type: ignore[override]
        return self._inner.bytes_in

    @bytes_out.setter
    def bytes_out(self, v):
        pass

    @bytes_in.setter
    def bytes_in(self, v):
        pass


def test_close_mid_serve_raises_transport_error_not_hang():
    """Alice vanishing after her round-1 sketches must surface as a
    TransportError from run_pair on both sides' plumbing — not a hang."""
    a, b = make_pair(600, 6, np.random.default_rng(3))
    ta, tb = InMemoryDuplex.pair()
    alice = AliceEndpoint(_CloseAfter(ta, n_sends=1))
    bob = BobEndpoint(tb)
    alice.submit(a, cfg=PBSConfig(seed=2), d_known=6)
    bob.submit(b, cfg=PBSConfig(seed=2), d_known=6)
    with pytest.raises(TransportError):
        run_pair(alice, bob)


# ---------------------------------------------------------------------------
# hub: one of N peers drops at each protocol phase
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "mode,n_sends,phase",
    [
        ("known", 0, "before round 1"),
        ("known", 1, "after round-1 sketches, before outcome"),
        ("known", 2, "after round 1, at the verify exchange"),
        ("est", 0, "before the phase-0 ToW sketch"),
        ("est", 1, "after phase 0, before round 1"),
    ],
)
def test_hub_peer_drop_at_each_phase(mode, n_sends, phase):
    """Whatever phase a peer vanishes in, the hub fails exactly that peer
    with a TransportError outcome and the healthy neighbor reconciles
    byte-identically to the oracle."""
    hub = HubEndpoint(recv_deadline=15.0)

    ah, bh = make_pair(600, 6, np.random.default_rng(11))
    cfg_h = PBSConfig(seed=21)
    th_a, th_b = InMemoryDuplex.pair()
    ch_ok = hub.add_peer(th_b, label="healthy")
    hub.submit(ch_ok, bh, cfg=cfg_h, d_known=6)
    ep_ok = AliceEndpoint(th_a, channel=ch_ok)
    ep_ok.submit(ah, cfg=cfg_h, d_known=6)

    ad, bd = make_pair(600, 5, np.random.default_rng(13))
    cfg_d = PBSConfig(seed=31)
    td_a, td_b = InMemoryDuplex.pair()
    ch_bad = hub.add_peer(td_b, label="dropper")
    dk = 5 if mode == "known" else None
    hub.submit(ch_bad, bd, cfg=cfg_d, d_known=dk)
    ep_bad = AliceEndpoint(_CloseAfter(td_a, n_sends=n_sends), channel=ch_bad)
    ep_bad.submit(ad, cfg=cfg_d, d_known=dk)

    outcomes, results, errors = run_hub(hub, {ch_ok: ep_ok, ch_bad: ep_bad})

    exp = reconcile(ah, bh, cfg_h, d_known=6)
    got = results[ch_ok][0]
    assert got.diff == exp.diff == true_diff(ah, bh), phase
    assert got.bytes_per_round == exp.bytes_per_round, phase
    assert outcomes[ch_ok].ok and outcomes[ch_ok].verified == [True], phase

    assert not outcomes[ch_bad].ok, phase
    assert isinstance(outcomes[ch_bad].error, TransportError), phase
    assert all(s.failed for s in outcomes[ch_bad].sessions), phase
    assert isinstance(errors.get(ch_bad), TransportError), phase
    assert ch_bad in hub.stale_channels


def test_hub_admission_straggler_does_not_stall_other_joiners():
    """A silent estimator joiner must not delay the other peers' phase-0
    admission: the ToW exchanges are polled round-robin, so the healthy
    estimator peer completes while the silent one eats only its own
    deadline."""
    hub = HubEndpoint(recv_deadline=2.0)

    # silent estimator peer: registered FIRST, never sends its ToW sketch
    ts_a, ts_b = InMemoryDuplex.pair()
    ch_silent = hub.add_peer(ts_b, label="silent-est")
    a0, b0 = make_pair(500, 5, np.random.default_rng(29))
    hub.submit(ch_silent, b0, cfg=PBSConfig(seed=51))

    # healthy estimator peer registered after it
    ah, bh = make_pair(700, 9, np.random.default_rng(31))
    cfg_h = PBSConfig(seed=53)
    th_a, th_b = InMemoryDuplex.pair()
    ch_ok = hub.add_peer(th_b, label="healthy-est")
    hub.submit(ch_ok, bh, cfg=cfg_h)
    ep_ok = AliceEndpoint(th_a, channel=ch_ok)
    ep_ok.submit(ah, cfg=cfg_h)

    outcomes, results, errors = run_hub(hub, {ch_ok: ep_ok})

    exp = reconcile(ah, bh, cfg_h)
    got = results[ch_ok][0]
    assert got.diff == exp.diff == true_diff(ah, bh)
    assert got.bytes_per_round == exp.bytes_per_round
    assert got.estimator_bytes == exp.estimator_bytes
    assert outcomes[ch_ok].ok and outcomes[ch_ok].verified == [True]

    assert not outcomes[ch_silent].ok
    assert isinstance(outcomes[ch_silent].error, TransportError)
    assert "admission deadline" in str(outcomes[ch_silent].error)


def test_hub_straggler_on_lossy_simulated_channel():
    """A peer behind a 100%-loss SimulatedChannel (from round 1 on) is a
    straggler: the hub's barrier deadline evicts it; the in-memory peer is
    untouched."""
    hub = HubEndpoint(recv_deadline=2.0)

    ah, bh = make_pair(600, 6, np.random.default_rng(19))
    cfg_h = PBSConfig(seed=41)
    th_a, th_b = InMemoryDuplex.pair()
    ch_ok = hub.add_peer(th_b)
    hub.submit(ch_ok, bh, cfg=cfg_h, d_known=6)
    ep_ok = AliceEndpoint(th_a, channel=ch_ok)
    ep_ok.submit(ah, cfg=cfg_h, d_known=6)

    # the straggler's channel drops EVERY datagram: its ARQ retransmits
    # pointlessly; from the hub's side the peer is silent
    ca, cb = SimulatedChannel.pair(loss=1.0, seed=7)
    rt_hub = ReliableTransport(cb, timeout=0.02, max_retries=5)
    ch_slow = hub.add_peer(rt_hub, label="straggler")
    a2, b2 = make_pair(600, 5, np.random.default_rng(23))
    hub.submit(ch_slow, b2, cfg=PBSConfig(seed=43), d_known=5)

    outcomes, results, errors = run_hub(hub, {ch_ok: ep_ok})

    exp = reconcile(ah, bh, cfg_h, d_known=6)
    assert results[ch_ok][0].diff == exp.diff
    assert results[ch_ok][0].bytes_per_round == exp.bytes_per_round
    assert outcomes[ch_ok].ok

    assert not outcomes[ch_slow].ok
    assert isinstance(outcomes[ch_slow].error, TransportError)
    assert "deadline" in str(outcomes[ch_slow].error)

# ---------------------------------------------------------------------------
# eviction while the peer is mid-protocol: clean, prompt, no leaked thread
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ["memory", "tcp", "simulated"])
def test_evict_mid_protocol_fails_peer_cleanly_no_hang(kind):
    """Evicting a peer while it is mid-exchange (in-flight send/recv) must
    surface a clean, prompt TransportError on the peer's thread — no hang,
    no leaked thread — on every transport flavor, while a healthy neighbor
    completes byte-identically."""
    import time as _time

    if kind == "memory":
        ta, th = InMemoryDuplex.pair()
    elif kind == "tcp":
        ta, th = tcp_loopback_pair()
    else:
        ca, cb = SimulatedChannel.pair(latency=0.001)
        ta = ReliableTransport(ca, timeout=0.02, max_retries=100)
        th = ReliableTransport(cb, timeout=0.02, max_retries=100)

    # a multi-round workload so the eviction (at the round-1 barrier, via
    # the deterministic on_barrier hook) always lands mid-protocol
    cfg = PBSConfig(seed=3, n_override=127, t_override=7, g_override=4)
    av, bv = make_pair(700, 60, np.random.default_rng(5))
    hub = HubEndpoint(recv_deadline=30.0)
    ch_bad = hub.add_peer(th, label="victim")
    hub.submit(ch_bad, bv, cfg=cfg, d_known=60)
    ep_bad = AliceEndpoint(ta, channel=ch_bad)
    ep_bad.submit(av, cfg=cfg, d_known=60)

    ah, bh = make_pair(700, 60, np.random.default_rng(6))
    cfg_h = PBSConfig(seed=4, n_override=127, t_override=7, g_override=4)
    to_a, to_h = InMemoryDuplex.pair()
    ch_ok = hub.add_peer(to_h, label="healthy")
    hub.submit(ch_ok, bh, cfg=cfg_h, d_known=60)
    ep_ok = AliceEndpoint(to_a, channel=ch_ok)
    ep_ok.submit(ah, cfg=cfg_h, d_known=60)

    def on_barrier(rnd):
        peer = hub._peers[ch_bad]
        if rnd >= 1 and not peer.retired:
            hub._evict(peer, TransportError("operator eviction"))

    hub.on_barrier = on_barrier

    seen: dict = {}

    def drive_victim():
        t0 = _time.monotonic()
        try:
            ep_bad.run()
            seen["res"] = "completed"
        except TransportError as e:
            seen["err"] = e
        seen["dt"] = _time.monotonic() - t0

    ok_res: dict = {}
    th_bad = threading.Thread(target=drive_victim, daemon=True)
    th_ok = threading.Thread(
        target=lambda: ok_res.update(r=ep_ok.run()), daemon=True
    )
    th_bad.start()
    th_ok.start()
    outcomes = hub.serve()
    th_bad.join(timeout=15.0)
    th_ok.join(timeout=15.0)

    assert not th_bad.is_alive(), f"{kind}: victim thread leaked"
    assert not th_ok.is_alive(), f"{kind}: healthy thread leaked"
    assert "err" in seen, f"{kind}: victim never saw the eviction: {seen}"
    assert isinstance(seen["err"], TransportError), kind
    assert not isinstance(seen["err"], TransportTimeout), kind
    assert seen["dt"] < 15.0, f"{kind}: not prompt: {seen['dt']:.1f}s"

    assert not outcomes[ch_bad].ok
    assert outcomes[ch_bad].error_kind == "transport"
    assert ch_bad in hub.stale_channels
    exp = reconcile(ah, bh, cfg_h, d_known=60)
    got = ok_res["r"][0]
    assert outcomes[ch_ok].ok and outcomes[ch_ok].verified == [True]
    assert got.diff == exp.diff == true_diff(ah, bh)
    assert got.bytes_per_round == exp.bytes_per_round


# ---------------------------------------------------------------------------
# close/linger: the two-army tail (DESIGN.md §13)
# ---------------------------------------------------------------------------


def test_linger_delivers_final_frame_exactly_once_under_ack_loss():
    """The lost-final-ACK problem: the receiver's ACK of the last frame is
    dropped, the sender retransmits, and the receiver's linger window
    re-ACKs — the frame is delivered exactly once and the sender's send
    completes instead of exhausting its retries."""
    raw_a, raw_b = InMemoryDuplex.pair()
    rt_s = ReliableTransport(raw_a, timeout=0.03, max_retries=50,
                             rto_max=0.1)
    # the receiver's first send op IS the ACK of the final frame: drop it
    rt_r = ReliableTransport(
        ChaosTransport(raw_b, FaultPlan(partitions=((0, 1),))),
        timeout=0.03, rto_max=0.1,
    )

    done = threading.Event()

    def _send():
        rt_s.send(b"final frame")
        done.set()

    th = threading.Thread(target=_send, daemon=True)
    th.start()
    assert rt_r.recv(timeout=2.0) == b"final frame"   # its ACK was dropped
    assert not done.is_set()                          # sender still waiting
    rt_r.linger(budget=5.0)      # re-ACK the retransmitted tail until quiet
    assert done.wait(2.0), "sender never completed: final ACK not healed"
    th.join(2.0)
    assert rt_s.retransmits >= 1
    # exactly once: the retransmitted copies were suppressed, not delivered
    with pytest.raises(TransportTimeout):
        rt_r.recv(timeout=0.2)


def test_linger_budget_bounds_a_babbling_peer():
    """``linger`` must respect its budget even when the peer never goes
    quiet — a babbler cannot hold close open forever."""
    import time as _time

    raw, side = InMemoryDuplex.pair()
    rt = ReliableTransport(side, timeout=0.02, rto_max=0.05)
    stop = threading.Event()

    def _babble():
        seq = 0
        while not stop.is_set():
            raw.send(_dgram(_DATA, seq))
            seq += 1
            _time.sleep(0.005)

    th = threading.Thread(target=_babble, daemon=True)
    th.start()
    t0 = _time.monotonic()
    rt.linger(budget=0.3)
    dt = _time.monotonic() - t0
    stop.set()
    th.join(2.0)
    assert 0.25 <= dt < 1.5, f"linger ignored its budget: {dt:.2f}s"
