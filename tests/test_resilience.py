"""Peer resurrection and chaos hardening (DESIGN.md §13).

The ISSUE 7 acceptance scenarios:

* the ``MSG_RESUME`` machinery — codec strictness, the rolling transcript
  digest, and crash→reconnect→resume against a live hub in both handshake
  cases (equal barriers; hub one outcome frame behind, replayed) — with the
  resumed peer's Formula-(1) ledger byte-identical to ``core.pbs.reconcile``
  and every replayed/handshake byte ledgered as transport overhead;
* the typed failure taxonomy (``PeerOutcome.error_kind``) and the adaptive
  ARQ retry state (``retransmits``/``rto_ms``) surfaced in wire stats;
* graceful degradation: a decode-budget-exhausted session escalates
  (doubled d̂ re-plan, ``sessions_degraded``) instead of failing, and the
  server / pair / hub paths agree byte-for-byte;
* the seeded chaos soak: a 6-peer continuous-sync hub under scripted
  loss bursts, duplication, reordering, a partition window and a scripted
  corruption, where 2 peers crash-restart mid-epoch (one clean disconnect,
  one silent crash caught by the barrier deadline) and resume via
  ``MSG_RESUME`` — every peer byte-identical to the oracle, zero store
  rebuilds, zero full re-syncs, replay bytes bounded by one round barrier
  per resumption.

The ≥20-epoch soak is marked ``slow`` (CI's non-blocking chaos-soak job);
the 3-epoch variant — same machinery, same assertions — runs in the
blocking fast tier.
"""
import threading
import time

import numpy as np
import pytest

from repro.core.pbs import PBSConfig, reconcile, true_diff
from repro.core.simdata import make_pair
from repro.net import (
    AliceEndpoint,
    BobEndpoint,
    ChaosTransport,
    FaultPlan,
    HubEndpoint,
    InMemoryDuplex,
    PeerDeadline,
    ReliableTransport,
    Transport,
    TransportError,
    TransportTimeout,
    classify_error,
    run_hub,
    run_pair,
)
from repro.net.endpoint import stream_wire_stats
from repro.net.hub import _drive_hub
from repro.net.transport import FrameStream
from repro.recon.server import ReconcileServer
from repro.wire import frames as wf
from repro.wire.frames import WireError

# the replayed outcome frame of one round barrier (1 session, g <= 8 units)
# is far under this; the soak's replay ledger must stay within it per resume
_BARRIER_FRAME_BOUND = 64


# ---------------------------------------------------------------------------
# MSG_RESUME codec + transcript digest
# ---------------------------------------------------------------------------


def test_resume_codec_roundtrip_and_overhead():
    from repro.wire.varint import decode_uvarint

    f = wf.encode_resume(3, 7, 12, 0xDEADBEEFCAFEF00D, 0x0123456789ABCDEF)
    assert len(f) == wf.resume_overhead_bytes(3, 7, 12)
    # strip the frame header: uvarint(1+len) || type || payload
    _, off = decode_uvarint(f)
    assert f[off] == wf.MSG_RESUME
    ch, epoch, rnd, dig, dig_prev = wf.decode_resume(f[off + 1 :])
    assert (ch, epoch, rnd) == (3, 7, 12)
    assert dig == 0xDEADBEEFCAFEF00D and dig_prev == 0x0123456789ABCDEF


def test_resume_codec_strictness():
    with pytest.raises(WireError):
        wf.encode_resume(0, 0, 0, 0, 0)          # channel 0 is reserved
    with pytest.raises(WireError):
        wf.encode_resume(1, 0, -1, 0, 0)         # negative barrier
    from repro.wire.varint import decode_uvarint, encode_uvarint

    good = wf.encode_resume(2, 1, 3, 5, 6)
    _, off = decode_uvarint(good)
    payload = good[off + 1 :]
    with pytest.raises(WireError):
        wf.decode_resume(payload[:-1])           # truncated digest
    bad_ch = encode_uvarint(0) + payload[1:]
    with pytest.raises(WireError):
        wf.decode_resume(bad_ch)                 # channel 0 on decode too


def test_transcript_digest_determinism_and_sensitivity():
    d0 = wf.transcript_digest0(0)
    assert d0 == wf.transcript_digest0(0)
    assert d0 != wf.transcript_digest0(1)        # epoch-seeded
    frame = wf.frame(wf.MSG_ROUND_OUTCOME, b"\x01\x02\x03")
    a = wf.fold_transcript(d0, 1, frame)
    assert a == wf.fold_transcript(d0, 1, frame)
    assert a != d0
    assert a != wf.fold_transcript(d0, 2, frame)             # round-sensitive
    assert a != wf.fold_transcript(d0, 1, frame[:-1] + b"\x04")  # byte-sensitive
    # folding is ordered: (r1, f1) then (r2, f2) != (r2, f2) then (r1, f1)
    f2 = wf.frame(wf.MSG_ROUND_OUTCOME, b"\x05")
    assert (
        wf.fold_transcript(wf.fold_transcript(d0, 1, frame), 2, f2)
        != wf.fold_transcript(wf.fold_transcript(d0, 2, f2), 1, frame)
    )


# ---------------------------------------------------------------------------
# error taxonomy
# ---------------------------------------------------------------------------


def test_classify_error_taxonomy():
    assert classify_error(None) is None
    assert classify_error(PeerDeadline("x")) == "deadline"
    assert classify_error(TransportTimeout("x")) == "deadline"
    assert classify_error(WireError("x")) == "wire"
    assert classify_error(TransportError("x")) == "transport"
    assert classify_error(ValueError("x")) == "error"
    # eviction re-wraps the root failure in a TransportError; the root wins
    wrapped = TransportError("peer: bad frame")
    wrapped.__cause__ = WireError("bad frame")
    assert classify_error(wrapped) == "wire"
    expired = PeerDeadline("resume window expired")
    expired.__cause__ = PeerDeadline("missed barrier")
    assert classify_error(expired) == "deadline"
    # a transport wrapper over an unclassified cause stays transport
    plain = TransportError("closed")
    plain.__cause__ = ValueError("boom")
    assert classify_error(plain) == "transport"
    # the estimator regime guard (DESIGN.md §15) is its own class, both
    # bare and through the eviction wrapper
    from repro.core.tow import EstimateOutOfRange

    oor = EstimateOutOfRange(900, 1000, 0.5)
    assert classify_error(oor) == "estimate"
    wrapped_oor = TransportError("peer: estimate out of range")
    wrapped_oor.__cause__ = oor
    assert classify_error(wrapped_oor) == "estimate"


# ---------------------------------------------------------------------------
# adaptive ARQ retry (satellite: backoff + jitter + cap, stats surfaced)
# ---------------------------------------------------------------------------


def test_rto_backs_off_caps_and_resets_on_delivery():
    from repro.wire.varint import decode_uvarint, encode_uvarint

    raw, side = InMemoryDuplex.pair()
    rt = ReliableTransport(side, timeout=0.01, max_retries=4,
                           rto_max=0.08, backoff=2.0, jitter=0.0)
    assert rt.rto_ms == pytest.approx(10.0)
    with pytest.raises(TransportError, match="no ACK"):
        rt.send(b"void")
    # 0.01 -> 0.02 -> 0.04 -> 0.08 (capped); attempts counted as retransmits
    assert rt.rto_ms == pytest.approx(80.0)
    assert rt.retransmits == 3

    # drain the failed send's queued retransmits, then a delivered ACK
    # resets the timer to the base timeout
    while True:
        try:
            raw.recv(timeout=0.01)
        except TransportTimeout:
            break

    def _ack():
        dgram = raw.recv(timeout=2.0)
        seq, _ = decode_uvarint(dgram, 1)
        raw.send(bytes((0x01,)) + encode_uvarint(seq))

    th = threading.Thread(target=_ack, daemon=True)
    th.start()
    rt.send(b"delivered")
    th.join(2.0)
    assert rt.rto_ms == pytest.approx(10.0)

    # both counters surface through the endpoint wire-stats contract
    tally = {"estimator": 0, "protocol": 0, "verify": 0, "epoch": 0,
             "resume": 0}
    st = stream_wire_stats(FrameStream(rt), tally)
    assert st["retransmits"] == rt.retransmits >= 3
    assert st["rto_ms"] == pytest.approx(10.0)
    assert st["resume_frame_bytes"] == 0


def test_rto_jitter_is_seeded_and_bounded():
    rts = [
        ReliableTransport(InMemoryDuplex.pair()[1], timeout=0.1,
                          jitter=0.25, seed=9)
        for _ in range(2)
    ]
    waits = [[rt._attempt_wait() for _ in range(32)] for rt in rts]
    assert waits[0] == waits[1]                  # same seed, same schedule
    assert all(0.075 <= w <= 0.125 for w in waits[0])
    assert len(set(waits[0])) > 1                # actually randomized


# ---------------------------------------------------------------------------
# FaultPlan / ChaosTransport
# ---------------------------------------------------------------------------


class _Sink(Transport):
    def __init__(self):
        super().__init__()
        self.delivered: list[bytes] = []
        self.closed = False

    def send(self, data: bytes) -> None:
        self.delivered.append(bytes(data))

    def recv(self, timeout: float | None = None) -> bytes:
        raise TransportTimeout("sink")

    def close(self) -> None:
        self.closed = True


def _run_plan(plan: FaultPlan, n_ops: int = 200):
    sink = _Sink()
    ct = ChaosTransport(sink, plan)
    for i in range(n_ops):
        try:
            ct.send(bytes((0x00, i % 256)))
        except TransportError:
            break
    return ct, sink


def test_chaos_same_seed_same_faults():
    plan = FaultPlan(seed=5, loss=0.15, dup=0.1, reorder=0.1, corrupt=0.05)
    a, sink_a = _run_plan(plan)
    b, sink_b = _run_plan(plan)
    assert sink_a.delivered == sink_b.delivered
    assert (a.dropped, a.duplicated, a.reordered, a.corrupted) == (
        b.dropped, b.duplicated, b.reordered, b.corrupted
    )
    assert a.dropped > 0 and a.duplicated > 0 and a.corrupted > 0
    # a different seed yields a different fault pattern
    _, sink_c = _run_plan(
        FaultPlan(seed=6, loss=0.15, dup=0.1, reorder=0.1, corrupt=0.05)
    )
    assert sink_c.delivered != sink_a.delivered


def test_chaos_scripted_faults_are_exact():
    # partition blackholes exactly ops [2, 5); burst drops the first 2 of
    # every 10; corrupt_at garbles exactly op 7's first byte
    plan = FaultPlan(partitions=((2, 5),), burst_every=10, burst_len=2,
                     corrupt_at=(7,))
    ct, sink = _run_plan(plan, n_ops=12)
    # dropped: ops 0,1 (burst), 2,3,4 (partition), 10,11 (burst) = 7
    assert ct.dropped == 7
    delivered_ops = [5, 6, 7, 8, 9]
    assert len(sink.delivered) == len(delivered_ops)
    for dgram, op in zip(sink.delivered, delivered_ops):
        want = bytes((0x00 ^ (0x80 if op == 7 else 0x00), op))
        assert dgram == want
    assert ct.corrupted == 1


def test_chaos_scripted_crash_clean_and_silent():
    clean, sink = _run_plan(FaultPlan(crash_after_sends=3), n_ops=10)
    assert clean.crashed and clean.sends == 4 and sink.closed
    with pytest.raises(TransportError):
        clean.recv(timeout=0.01)
    silent, sink2 = _run_plan(
        FaultPlan(crash_after_sends=3, crash_silent=True), n_ops=10
    )
    # silent crash: the crashed side fails fast, but the channel is NOT
    # closed — the remote observes pure silence (the deadline path)
    assert silent.crashed and not sink2.closed
    with pytest.raises(TransportError):
        silent.send(b"x")


def test_chaos_reorder_swaps_adjacent_pairs():
    plan = FaultPlan(seed=1, reorder=1.0)     # hold every datagram
    sink = _Sink()
    ct = ChaosTransport(sink, plan)
    for i in range(4):
        ct.send(bytes((0x00, i)))
    # every odd send releases the held predecessor after itself
    assert [d[1] for d in sink.delivered] == [1, 0, 3, 2]
    assert ct.reordered == 2


# ---------------------------------------------------------------------------
# crash -> reconnect -> resume against a live hub (both handshake cases)
# ---------------------------------------------------------------------------


def _crash_resume(crash_after: int):
    """One peer crashing after ``crash_after`` sends, reconnecting and
    resuming; returns (hub, alice, outcome, result, oracle, channel)."""
    rng = np.random.default_rng(7)
    univ = rng.choice(1 << 20, size=3000, replace=False).astype(np.uint32)
    a, b = univ[:2600], univ[400:]
    cfg = PBSConfig(seed=3)
    d = len(np.setxor1d(a, b))

    t_a_raw, t_h = InMemoryDuplex.pair()
    t_a = ChaosTransport(t_a_raw, FaultPlan(crash_after_sends=crash_after))
    hub = HubEndpoint(resume_window=30.0, recv_deadline=10.0)
    ch = hub.add_peer(t_h, label="crasher")
    hub.submit(ch, b, cfg=cfg, d_known=d)
    ep = AliceEndpoint(t_a, channel=ch)
    ep.submit(a, cfg=cfg, d_known=d)

    pending: dict = {}

    def on_barrier(rnd):
        if "t" in pending and hub._peers[ch].suspended:
            hub.resume_peer(ch, pending.pop("t"))

    hub.on_barrier = on_barrier
    state: dict = {}

    def drive():
        try:
            state["res"] = ep.run()
            return
        except TransportError as e:
            state["crash"] = e
        na, nh = InMemoryDuplex.pair()
        pending["t"] = nh
        ep.resume(na)
        state["res"] = ep.resume_run()

    th = threading.Thread(target=drive, daemon=True)
    th.start()
    outcomes = hub.serve()
    th.join(timeout=60)
    assert not th.is_alive(), "peer thread leaked"
    assert "crash" in state, "scripted crash never fired"
    oracle = reconcile(a, b, cfg, d_known=d)
    return hub, ep, outcomes[ch], state["res"][0], oracle, ch


@pytest.mark.parametrize(
    "crash_after,case",
    [
        (1, "replay: outcome frame died in flight, hub one barrier behind"),
        (2, "equal barriers: crash between completed rounds"),
    ],
)
def test_crash_resume_byte_identical(crash_after, case):
    hub, ep, outcome, res, oracle, ch = _crash_resume(crash_after)
    st = hub.stats

    assert outcome.ok and outcome.verified == [True], case
    assert outcome.error_kind == "resumed", case
    assert ep.resumes == 1 and st["peers_resumed"] == 1
    assert st.get("peers_failed", 0) == 0

    # the resumed protocol's Formula-(1) ledger is byte-identical to the
    # fresh oracle: the crash cost lives only in the transport-overhead
    # resume tally, never in the protocol bits
    assert res.success and res.diff == oracle.diff
    assert res.rounds == oracle.rounds
    assert res.bytes_per_round == oracle.bytes_per_round, case
    assert res.bytes_sent == oracle.bytes_sent, case

    aw = ep.wire_stats
    hw = hub._peers[ch].wire_stats()
    # both sides ledger the same resume overhead (handshake + any replay)
    assert aw["resume_frame_bytes"] == hw["resume_frame_bytes"] > 0
    if crash_after == 1:
        # the hub missed exactly one outcome frame: it was replayed and
        # ledgered as resume overhead, bounded by one barrier frame — so
        # the hub's protocol tally is short exactly that frame (it only
        # ever received the replayed copy)
        assert 0 < st["resume_replay_bytes"] <= _BARRIER_FRAME_BOUND
        assert aw["protocol_frame_bytes"] == (
            hw["protocol_frame_bytes"] + st["resume_replay_bytes"]
        )
    else:
        assert st["resume_replay_bytes"] == 0
        assert aw["protocol_frame_bytes"] == hw["protocol_frame_bytes"]


def test_silent_crash_suspends_at_deadline_then_resumes():
    """A peer going dark (silent crash) is caught by the hub's barrier
    deadline, suspended as resumable, and resumes cleanly."""
    rng = np.random.default_rng(9)
    univ = rng.choice(1 << 20, size=2400, replace=False).astype(np.uint32)
    a, b = univ[:2100], univ[300:]
    cfg = PBSConfig(seed=4)
    d = len(np.setxor1d(a, b))

    t_a_raw, t_h = InMemoryDuplex.pair()
    t_a = ChaosTransport(
        t_a_raw, FaultPlan(crash_after_sends=2, crash_silent=True)
    )
    hub = HubEndpoint(resume_window=30.0, recv_deadline=1.0)
    ch = hub.add_peer(t_h, label="dark")
    hub.submit(ch, b, cfg=cfg, d_known=d)
    ep = AliceEndpoint(t_a, channel=ch)
    ep.submit(a, cfg=cfg, d_known=d)

    pending: dict = {}
    kinds: list = []

    def on_barrier(rnd):
        if "t" in pending and hub._peers[ch].suspended:
            kinds.append(classify_error(hub._peers[ch].suspend_err))
            hub.resume_peer(ch, pending.pop("t"))

    hub.on_barrier = on_barrier

    def drive():
        try:
            ep.run()
            return
        except TransportError:
            pass
        na, nh = InMemoryDuplex.pair()
        pending["t"] = nh
        ep.resume(na)
        ep.resume_run()

    th = threading.Thread(target=drive, daemon=True)
    th.start()
    outcomes = hub.serve()
    th.join(timeout=60)
    assert not th.is_alive()
    assert kinds == ["deadline"]         # caught by PeerDeadline, not close
    assert outcomes[ch].ok and outcomes[ch].error_kind == "resumed"
    assert hub.stats["peers_resumed"] == 1


def test_resume_rejected_on_diverged_transcript():
    """A reconnecting peer whose transcript digest diverged must be refused
    at the handshake (evicted as a wire failure), never re-attached."""
    rng = np.random.default_rng(13)
    univ = rng.choice(1 << 20, size=2400, replace=False).astype(np.uint32)
    a, b = univ[:2100], univ[300:]
    cfg = PBSConfig(seed=6)
    d = len(np.setxor1d(a, b))

    t_a_raw, t_h = InMemoryDuplex.pair()
    t_a = ChaosTransport(t_a_raw, FaultPlan(crash_after_sends=2))
    hub = HubEndpoint(resume_window=30.0, recv_deadline=5.0)
    ch = hub.add_peer(t_h, label="diverged")
    hub.submit(ch, b, cfg=cfg, d_known=d)
    ep = AliceEndpoint(t_a, channel=ch)
    ep.submit(a, cfg=cfg, d_known=d)

    pending: dict = {}
    hub_err: list = []

    def on_barrier(rnd):
        if "t" in pending and hub._peers[ch].suspended:
            try:
                hub.resume_peer(ch, pending.pop("t"))
            except WireError as e:
                hub_err.append(e)

    hub.on_barrier = on_barrier
    alice_err: list = []

    def drive():
        try:
            ep.run()
            return
        except TransportError:
            pass
        ep._digest ^= 0x1          # simulated divergence / stale snapshot
        na, nh = InMemoryDuplex.pair()
        pending["t"] = nh
        try:
            ep.resume(na)
            ep.resume_run()
        except (TransportError, WireError) as e:
            alice_err.append(e)

    th = threading.Thread(target=drive, daemon=True)
    th.start()
    outcomes = hub.serve()
    th.join(timeout=60)
    assert not th.is_alive()
    assert hub_err and "diverged" in str(hub_err[0])
    assert alice_err, "the refused peer must fail fast, not hang"
    assert not outcomes[ch].ok
    assert outcomes[ch].error_kind == "wire"
    assert hub.stats["peers_resumed"] == 0
    assert ch in hub.stale_channels


def test_suspension_expires_into_classified_eviction():
    """A suspended peer that never reconnects hardens into an eviction
    once the resume window lapses, keeping the root failure's class."""
    rng = np.random.default_rng(17)
    univ = rng.choice(1 << 20, size=2400, replace=False).astype(np.uint32)
    a, b = univ[:2100], univ[300:]
    cfg = PBSConfig(seed=8)
    d = len(np.setxor1d(a, b))

    t_a_raw, t_h = InMemoryDuplex.pair()
    t_a = ChaosTransport(t_a_raw, FaultPlan(crash_after_sends=2))
    hub = HubEndpoint(resume_window=0.3, recv_deadline=5.0)
    ch = hub.add_peer(t_h, label="gone")
    hub.submit(ch, b, cfg=cfg, d_known=d)
    ep = AliceEndpoint(t_a, channel=ch)
    ep.submit(a, cfg=cfg, d_known=d)

    def drive():
        with pytest.raises(TransportError):
            ep.run()

    th = threading.Thread(target=drive, daemon=True)
    th.start()
    outcomes = hub.serve()
    th.join(timeout=60)
    assert not th.is_alive()
    assert not outcomes[ch].ok
    assert "resume window" in str(outcomes[ch].error)
    assert outcomes[ch].error_kind == "transport"
    st = hub.stats
    assert st["peers_failed"] == 1
    assert st["peers_failed_by_kind"] == {"transport": 1}
    assert st["peers_resumed"] == 0


# ---------------------------------------------------------------------------
# graceful degradation: server / pair / hub agree
# ---------------------------------------------------------------------------


def _degradation_inputs():
    rng = np.random.default_rng(11)
    univ = rng.choice(1 << 20, size=4000, replace=False).astype(np.uint32)
    a, b = univ[:3500], univ[500:]
    # d = 1000 but the session claims d̂ = 250: the round budget exhausts
    # and only the escalation ladder (250 -> 500 -> 1000) can finish it
    return a, b, PBSConfig(seed=5, max_rounds=2), 250


def test_degradation_completes_exhausted_session_across_paths():
    a, b, cfg, dk = _degradation_inputs()
    want = true_diff(a, b)

    # the in-process server is the degradation oracle
    srv = ReconcileServer(degrade=True)
    srv.submit(a, b, cfg=cfg, d_known=dk)
    oracle = srv.run()[0]
    assert oracle.success and oracle.diff == want
    assert srv.stats["sessions_degraded"] >= 1

    # without degradation the same inputs fail (the scenario is real)
    srv0 = ReconcileServer()
    srv0.submit(a, b, cfg=cfg, d_known=dk)
    assert not srv0.run()[0].success

    # wire pair, degrade on both ends: byte-identical to the server path
    ta, tb = InMemoryDuplex.pair()
    alice, bob = AliceEndpoint(ta, degrade=True), BobEndpoint(tb, degrade=True)
    alice.submit(a, cfg=cfg, d_known=dk)
    bob.submit(b, cfg=cfg, d_known=dk)
    res = run_pair(alice, bob)[0]
    assert res.success and res.diff == want
    assert alice.sessions_degraded == bob.sessions_degraded >= 1
    assert res.bytes_per_round == oracle.bytes_per_round
    assert res.bytes_sent == oracle.bytes_sent

    # hub path: same ledger, outcome tagged "degraded"
    th_a, th_h = InMemoryDuplex.pair()
    hub = HubEndpoint(degrade=True, recv_deadline=20.0)
    ch = hub.add_peer(th_h)
    hub.submit(ch, b, cfg=cfg, d_known=dk)
    ep = AliceEndpoint(th_a, channel=ch, degrade=True)
    ep.submit(a, cfg=cfg, d_known=dk)
    outcomes, results, errors = run_hub(hub, {ch: ep})
    assert not errors, errors
    r = results[ch][0]
    assert r.success and r.diff == want
    assert r.bytes_per_round == oracle.bytes_per_round
    assert r.bytes_sent == oracle.bytes_sent
    assert hub.stats["sessions_degraded"] >= 1
    assert outcomes[ch].ok and outcomes[ch].error_kind == "degraded"


def test_degradation_ladder_is_capped():
    """Escalation stops at the cap: a hopeless d̂ still fails (bounded
    work), it just fails after the ladder instead of silently looping."""
    a, b, cfg, _ = _degradation_inputs()
    srv = ReconcileServer(degrade=True)
    srv.submit(a, b, cfg=cfg, d_known=8)   # 8 -> 16 -> 32 -> 64 << 1000
    res = srv.run()[0]
    assert not res.success
    assert srv.stats["sessions_degraded"] == 3      # the whole ladder, once


# ---------------------------------------------------------------------------
# the chaos soak
# ---------------------------------------------------------------------------

_CFG = dict(n_override=127, t_override=7, g_override=4)


def _fresh_elems(rng, k):
    return rng.integers(1, 1 << 32, size=k, dtype=np.uint64).astype(np.uint32)


def _chaos_soak(epochs, *, crash_epochs=(1,), corrupt_op=None, seed=0,
                deadline=4.0):
    """A 6-peer continuous hub under scripted chaos.

    Peer roles: 0 crash-restarts by clean disconnect and 1 by silent crash
    (both at the first round barrier of every epoch in ``crash_epochs``,
    resuming mid-epoch via MSG_RESUME); 2 runs its whole life behind a
    seeded lossy/duplicating/reordering ARQ channel with a partition
    window; 3 rides ARQ with one scripted corruption (detected, then
    healed by suspend→resume); 4 re-estimates d̂ every epoch; 5 is clean.
    """
    peers = 6
    d = 60
    rng = np.random.default_rng(seed)
    hub = HubEndpoint(recv_deadline=deadline, continuous=True,
                      resume_window=60.0)
    alices: dict[int, AliceEndpoint] = {}
    cfgs: dict[int, PBSConfig] = {}
    dks: dict[int, int | None] = {}
    conn: dict[int, dict] = {}     # per-channel live transport + chaos refs
    roles: dict[str, int] = {}

    plan2 = FaultPlan(seed=seed + 50, loss=0.08, burst_every=40, burst_len=2,
                      dup=0.06, reorder=0.06, partitions=((120, 126),))
    plan3 = (FaultPlan(seed=seed + 60, corrupt_at=(corrupt_op,))
             if corrupt_op is not None else FaultPlan(seed=seed + 60))

    for p in range(peers):
        a, b = make_pair(700, d, np.random.default_rng(seed + 101 * p))
        dk = None if p == 4 else d
        cfg = PBSConfig(seed=seed + p, **_CFG)
        if p in (2, 3):
            raw_a, raw_h = InMemoryDuplex.pair()
            chaos = ChaosTransport(raw_a, plan2 if p == 2 else plan3)
            ta = ReliableTransport(chaos, timeout=0.02, max_retries=400,
                                   seed=p)
            th = ReliableTransport(raw_h, timeout=0.02, max_retries=400,
                                   seed=100 + p)
        else:
            ta, th = InMemoryDuplex.pair()
            chaos = None
            if p == 1:
                chaos = ChaosTransport(ta, FaultPlan(crash_silent=True))
                ta = chaos
        ch = hub.add_peer(th, label=f"peer{p}")
        hub.submit(ch, b, cfg=cfg, d_known=dk)
        ep = AliceEndpoint(ta, channel=ch, continuous=True)
        ep.submit(a, cfg=cfg, d_known=dk)
        alices[ch] = ep
        cfgs[ch], dks[ch] = cfg, dk
        conn[ch] = {"ta": ta, "chaos": chaos}
        roles[f"p{p}"] = ch

    ch0, ch1 = roles["p0"], roles["p1"]
    ch2, ch3 = roles["p2"], roles["p3"]
    pending: dict[int, Transport] = {}
    suspend_kinds: dict[int, list] = {ch: [] for ch in alices}
    trigger = {"armed": False}

    def on_barrier(rnd):
        if trigger["armed"] and rnd >= 1:
            trigger["armed"] = False
            conn[ch0]["ta"].close()           # clean disconnect
            conn[ch1]["chaos"]._crash()       # dark peer: deadline path
        for ch in list(pending):
            if hub._peers[ch].suspended:
                suspend_kinds[ch].append(
                    classify_error(hub._peers[ch].suspend_err)
                )
                hub.resume_peer(ch, pending.pop(ch))

    hub.on_barrier = on_barrier

    def _mk(ch, fn):
        def call():
            try:
                return fn()
            except TransportError:
                pass
            raw_a, nh = InMemoryDuplex.pair()
            if ch == ch1:
                # the restarted dark peer re-arms its silent-crash wrapper
                chaos = ChaosTransport(raw_a, FaultPlan(crash_silent=True))
                conn[ch].update(ta=chaos, chaos=chaos)
                ta = chaos
            else:
                conn[ch].update(ta=raw_a, chaos=None)
                ta = raw_a
            pending[ch] = nh
            alices[ch].resume(ta)
            return alices[ch].resume_run()
        return call

    outcomes, results, errors = _drive_hub(
        hub, {ch: _mk(ch, ep.run) for ch, ep in alices.items()},
        join_timeout=120.0,
    )
    assert not errors, errors
    assert all(o.ok for o in outcomes.values())
    st = hub.stats
    uploads0 = st["store_uploads"]
    sess_ids = {ch: id(hub._peers[ch].sessions[0]) for ch in alices}
    resumes_expected = 0

    for e in range(1, epochs + 1):
        hub_muts: dict[int, dict] = {}
        alice_muts: dict[int, dict] = {}
        for ch, ep in alices.items():
            b_cur = hub._peers[ch].sessions[0].state.b
            hub_muts[ch] = {0: (_fresh_elems(rng, 24),
                                rng.permutation(b_cur)[:24])}
            a_base = ep.sessions[0].state.a
            alice_muts[ch] = {0: (_fresh_elems(rng, 6),
                                  rng.permutation(a_base)[:6])}
        hub.advance_epoch(hub_muts)
        for ch, ep in alices.items():
            ep.advance_epoch(alice_muts.get(ch, {}))

        crash = e in crash_epochs
        if crash:
            trigger["armed"] = True
            resumes_expected += 2

        outcomes, results, errors = _drive_hub(
            hub, {ch: _mk(ch, ep.run_epoch) for ch, ep in alices.items()},
            join_timeout=120.0,
        )
        st = hub.stats
        assert not errors, (e, errors)

        # zero store rebuilds, zero re-admissions, zero full re-syncs:
        # resumption re-binds to the resident sessions and stores
        assert st["store_builds"] == 0, (e, st)
        assert st["store_uploads"] == uploads0
        assert st.get("peers_failed", 0) == 0, (e, st)
        for ch in alices:
            assert id(hub._peers[ch].sessions[0]) == sess_ids[ch]

        if crash:
            assert outcomes[ch0].error_kind == "resumed", e
            assert outcomes[ch1].error_kind == "resumed", e
            assert suspend_kinds[ch0][-1] == "transport"
            assert suspend_kinds[ch1][-1] == "deadline"
        assert st["peers_resumed"] >= resumes_expected, (e, st)

        for ch, ep in alices.items():
            assert outcomes[ch].ok and outcomes[ch].verified == [True], (
                e, ch, outcomes[ch].error
            )
            a_e = ep.sessions[0].state.a
            b_e = hub._peers[ch].sessions[0].state.b
            r = results[ch][0]
            oracle = reconcile(a_e, b_e, cfgs[ch], d_known=dks[ch])
            if crash:
                assert oracle.rounds >= 2, "crash epoch must be multi-round"
            assert r.success and r.diff == oracle.diff == true_diff(a_e, b_e)
            assert r.rounds == oracle.rounds, (e, ch)
            assert r.bytes_per_round == oracle.bytes_per_round, (e, ch)
            assert r.bytes_sent == oracle.bytes_sent, (e, ch)
            assert r.estimator_bytes == oracle.estimator_bytes, (e, ch)

    st = hub.stats
    # every scripted crash-restart resumed; the scripted corruption (if
    # any) healed through one extra suspend->resume cycle
    extra = 1 if corrupt_op is not None else 0
    assert st["peers_resumed"] == resumes_expected + extra, st
    assert st["resume_replay_bytes"] <= _BARRIER_FRAME_BOUND * st["peers_resumed"]
    assert hub._peers[ch0].resumes == len(crash_epochs)
    assert hub._peers[ch1].resumes == len(crash_epochs)
    assert not hub.stale_channels

    # the random-chaos peer actually saw chaos and never crashed
    chaos2 = conn[ch2]["chaos"]
    assert chaos2 is not None and not chaos2.crashed
    assert chaos2.dropped > 0 and chaos2.duplicated > 0
    assert chaos2.reordered > 0
    if corrupt_op is not None:
        assert suspend_kinds[ch3] and suspend_kinds[ch3][-1] == "transport"
        assert hub._peers[ch3].resumes == 1
    return hub


def test_chaos_epochs_fast():
    """3 seeded epochs with the K=2 crash-restart in epoch 1: the
    blocking-tier variant of the chaos soak."""
    _chaos_soak(3, crash_epochs=(1,), seed=42)


@pytest.mark.slow
def test_chaos_soak_20_epochs():
    """The full acceptance soak: 20 epochs, two K=2 crash-restart epochs,
    persistent loss/dup/reorder chaos and a scripted mid-run corruption."""
    _chaos_soak(20, crash_epochs=(1, 8), corrupt_op=260, seed=7)


# ---------------------------------------------------------------------------
# tree-phase crashes (cold-start front end, DESIGN.md §15)
# ---------------------------------------------------------------------------


def _tree_pair(seed=23):
    """A pair whose walk is guaranteed multi-level (d > leaf_d); sorted
    unique, the form ``leaf_slices`` (and the walk itself) operates on."""
    a, b = make_pair(600, 120, np.random.default_rng(seed))
    return np.unique(a), np.unique(b), PBSConfig(seed=seed)


def test_mid_tree_crash_evicts_cleanly_then_fresh_channel_readmits():
    """A peer dying mid-walk is a hard eviction — the tree phase holds no
    resumption record, so even an armed resume window never suspends it —
    and the same client re-admits from scratch on a fresh channel."""
    from repro.tree import TreeConfig, partition_pair
    from repro.tree.partition import leaf_slices

    a, b, cfg = _tree_pair()
    _, stats = partition_pair(a, b, TreeConfig())
    assert stats.levels >= 2, "walk too shallow to crash mid-tree"

    t_a_raw, t_h = InMemoryDuplex.pair()
    t_a = ChaosTransport(t_a_raw, FaultPlan(crash_after_sends=1))
    hub = HubEndpoint(resume_window=30.0, recv_deadline=2.0)
    ch1 = hub.add_peer(t_h, label="treecrash")
    hub.submit_tree(ch1, b, cfg=cfg)
    ep1 = AliceEndpoint(t_a, channel=ch1)
    ep1.submit_tree(a, cfg)

    def drive():
        with pytest.raises(TransportError):
            ep1.run()

    th = threading.Thread(target=drive, daemon=True)
    th.start()
    outcomes = hub.serve()
    th.join(timeout=60)
    assert not th.is_alive()
    assert not outcomes[ch1].ok
    assert outcomes[ch1].error_kind == "transport"
    assert outcomes[ch1].tree_leaves is None     # walk never completed
    assert not hub._peers[ch1].suspended
    assert ch1 in hub.stale_channels
    st = hub.stats
    assert st["peers_resumed"] == 0
    assert st["peers_failed_by_kind"] == {"transport": 1}

    # the client reconnects on a brand-new channel and stages the tree
    # again: full admission, byte-identical to the in-process walk
    ta2, th2 = InMemoryDuplex.pair()
    ch2 = hub.add_peer(ta2 if False else th2, label="retry")
    hub.submit_tree(ch2, b, cfg=cfg)
    ep2 = AliceEndpoint(ta2, channel=ch2)
    ep2.submit_tree(a, cfg)
    state: dict = {}

    def drive2():
        state["res"] = ep2.run()

    th2d = threading.Thread(target=drive2, daemon=True)
    th2d.start()
    outcomes2 = hub.serve()
    th2d.join(timeout=60)
    assert not th2d.is_alive()
    assert outcomes2[ch2].ok
    assert outcomes2[ch2].tree_leaves == ep2.tree_leaves == len(
        partition_pair(a, b, TreeConfig())[0]
    )
    got = set().union(*(r.diff for r in state["res"].values()))
    leaves, _ = partition_pair(a, b, TreeConfig())
    want = set()
    for a_sub, b_sub, leaf in zip(
        leaf_slices(a, leaves), leaf_slices(b, leaves), leaves
    ):
        want |= reconcile(a_sub, b_sub, cfg, d_known=leaf.d_plan).diff
    assert got == want


def test_post_tree_crash_resumes_via_msg_resume():
    """Once the walk has settled into leaf PBS sessions, a crash is just
    an ordinary mid-protocol crash: the peer suspends at the barrier and
    resumes through MSG_RESUME with no re-walk and no re-admission."""
    from repro.tree import TreeConfig, partition_pair

    a, b, cfg = _tree_pair(seed=29)
    _, stats = partition_pair(a, b, TreeConfig())
    # alice's sends: one digest frame per level, then the PBS rounds —
    # crash on the second post-tree send, squarely inside the rounds
    crash_after = stats.levels + 1

    t_a_raw, t_h = InMemoryDuplex.pair()
    t_a = ChaosTransport(t_a_raw, FaultPlan(crash_after_sends=crash_after))
    hub = HubEndpoint(resume_window=30.0, recv_deadline=10.0)
    ch = hub.add_peer(t_h, label="latecrash")
    hub.submit_tree(ch, b, cfg=cfg)
    ep = AliceEndpoint(t_a, channel=ch)
    ep.submit_tree(a, cfg)

    pending: dict = {}

    def on_barrier(rnd):
        if "t" in pending and hub._peers[ch].suspended:
            hub.resume_peer(ch, pending.pop("t"))

    hub.on_barrier = on_barrier
    state: dict = {}

    def drive():
        try:
            state["res"] = ep.run()
            return
        except TransportError as e:
            state["crash"] = e
        na, nh = InMemoryDuplex.pair()
        pending["t"] = nh
        ep.resume(na)
        state["res"] = ep.resume_run()

    th = threading.Thread(target=drive, daemon=True)
    th.start()
    outcomes = hub.serve()
    th.join(timeout=60)
    assert not th.is_alive()
    assert "crash" in state, "scripted crash never fired"
    assert outcomes[ch].ok and outcomes[ch].error_kind == "resumed"
    assert ep.resumes == 1 and hub.stats["peers_resumed"] == 1
    assert hub.stats.get("peers_failed", 0) == 0
    # the walk itself never re-ran: one tree phase's worth of digest bytes
    assert outcomes[ch].tree_leaves == stats.leaves
    assert ep.wire_stats["tree_frame_bytes"] == stats.digest_bytes
    # every leaf session still byte-identical to its standalone oracle
    leaves, _ = partition_pair(a, b, TreeConfig())
    from repro.tree.partition import leaf_slices

    for sid, (a_sub, b_sub, leaf) in enumerate(
        zip(leaf_slices(a, leaves), leaf_slices(b, leaves), leaves)
    ):
        oracle = reconcile(a_sub, b_sub, cfg, d_known=leaf.d_plan)
        r = state["res"][sid]
        assert r.success and r.diff == oracle.diff
        assert r.bytes_sent == oracle.bytes_sent
        assert r.bytes_per_round == oracle.bytes_per_round
