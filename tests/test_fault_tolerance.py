"""Checkpoint manager, PBS manifest sync, data ledger, elastic membership."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.checkpoint import (
    latest_step,
    load_manifest,
    reconcile_manifests,
    restore_checkpoint,
    save_checkpoint,
    sync_checkpoint,
)
from repro.data import DataConfig, Ledger, global_batch, host_shard, step_sample_ids
from repro.launch.elastic import ElasticConfig, Membership, NodeState, viable_grid


def _tree(rng, scale=1.0):
    return {
        "emb": {"w": (rng.normal(size=(2000, 64)) * scale).astype(np.float32)},
        "layers": {"q": rng.normal(size=(3, 64, 64)).astype(np.float32)},
        "step": np.int32(7),
    }


# ---------------------------------------------------------------------------
# checkpoints
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    rng = np.random.default_rng(0)
    tree = _tree(rng)
    save_checkpoint(tmp_path, 5, tree)
    out, step = restore_checkpoint(tmp_path)
    assert step == 5
    np.testing.assert_array_equal(out["emb"]["w"], tree["emb"]["w"])
    np.testing.assert_array_equal(out["layers"]["q"], tree["layers"]["q"])
    assert out["step"] == 7


def test_checkpoint_gc_keeps_latest(tmp_path):
    rng = np.random.default_rng(0)
    for s in range(6):
        save_checkpoint(tmp_path, s, _tree(rng), keep=3)
    steps = sorted(int(p.name.split("_")[1]) for p in tmp_path.iterdir()
                   if p.name.startswith("step_"))
    assert steps == [3, 4, 5]
    assert latest_step(tmp_path) == 5


def test_checkpoint_bfloat16_leaves(tmp_path):
    import jax.numpy as jnp

    tree = {"w": np.asarray(jnp.ones((17, 5), jnp.bfloat16) * 1.5)}
    save_checkpoint(tmp_path, 1, tree)
    out, _ = restore_checkpoint(tmp_path)
    assert str(out["w"].dtype) == "bfloat16"
    np.testing.assert_array_equal(np.asarray(out["w"], np.float32), 1.5)


def test_pbs_manifest_sync_moves_only_changed_shards(tmp_path):
    rng = np.random.default_rng(1)
    tree = {"w": rng.normal(size=(4_000_000,)).astype(np.float32)}  # ~16 MB, 4 shards
    save_checkpoint(tmp_path / "src", 1, tree)
    r0 = sync_checkpoint(tmp_path / "src", tmp_path / "dst")
    assert r0.shards_fetched == 4

    tree["w"] = tree["w"].copy()
    tree["w"][0] += 1.0                      # touches exactly one 4MiB block
    save_checkpoint(tmp_path / "src", 2, tree)
    r = sync_checkpoint(tmp_path / "src", tmp_path / "dst")
    assert r.success and r.shards_fetched == 1
    assert r.payload_bytes <= 4 * 2**20 + 1024
    assert r.pbs_bytes < r.naive_bytes       # beats shipping the manifest
    out, step = restore_checkpoint(tmp_path / "dst")
    assert step == 2
    np.testing.assert_array_equal(out["w"], tree["w"])


def test_manifest_reconcile_identical_is_free(tmp_path):
    rng = np.random.default_rng(2)
    tree = _tree(rng)
    save_checkpoint(tmp_path / "a", 3, tree)
    save_checkpoint(tmp_path / "b", 3, tree)
    ma = load_manifest(tmp_path / "a", 3)
    mb = load_manifest(tmp_path / "b", 3)
    fetch, delete, res = reconcile_manifests(ma, mb)
    assert fetch == [] and delete == [] and res.success


def test_checkpoint_atomicity_no_tmp_left(tmp_path):
    rng = np.random.default_rng(3)
    save_checkpoint(tmp_path, 1, _tree(rng))
    assert not [p for p in tmp_path.iterdir() if p.name.startswith(".tmp")]


# ---------------------------------------------------------------------------
# data pipeline + ledger
# ---------------------------------------------------------------------------


def test_data_determinism_and_sharding():
    cfg = DataConfig(vocab=1000, seq_len=16, global_batch=32)
    b1, b2 = global_batch(4, cfg), global_batch(4, cfg)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].min() >= 0 and b1["tokens"].max() < 1000
    ids = step_sample_ids(4, cfg)
    parts = [host_shard(ids, h, 4) for h in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts), ids)
    # rescale: 8 hosts partition the same ids
    parts8 = [host_shard(ids, h, 8) for h in range(8)]
    np.testing.assert_array_equal(np.concatenate(parts8), ids)


def test_ledger_reconcile_exactly_once():
    cfg = DataConfig(vocab=100, seq_len=4, global_batch=64)
    fleet, node = Ledger(), Ledger()
    for s in range(30):
        ids = step_sample_ids(s, cfg)
        fleet.record(ids)
        if s < 25:
            node.record(ids)
    missing, extra, res = node.reconcile(fleet)
    assert res.success and len(missing) == 5 * 64 and not extra
    node.merge(missing)
    assert node.consumed == fleet.consumed
    assert res.bytes_sent + res.estimator_bytes < 4 * len(fleet.consumed)


@settings(max_examples=20, deadline=None)
@given(
    n_common=st.integers(0, 300),
    n_miss=st.integers(0, 40),
    seed=st.integers(0, 2**31 - 1),
)
def test_ledger_reconcile_property(n_common, n_miss, seed):
    rng = np.random.default_rng(seed)
    univ = rng.choice(np.arange(1, 1 << 20, dtype=np.uint32),
                      size=n_common + n_miss, replace=False)
    fleet, node = Ledger(), Ledger()
    fleet.record(univ)
    node.record(univ[: n_common])
    missing, extra, res = node.reconcile(fleet, seed=seed & 0xFFFF)
    assert res.success
    assert missing == set(int(x) for x in univ[n_common:])
    assert not extra


# ---------------------------------------------------------------------------
# elastic membership
# ---------------------------------------------------------------------------


def test_membership_failure_and_rejoin():
    t = [0.0]
    m = Membership([0, 1, 2, 3], ElasticConfig(), clock=lambda: t[0])
    for _ in range(12):
        t[0] += 1.0
        for n in (0, 1, 3):
            m.heartbeat(n, step_time=1.0)
        m.sweep()
    assert m.nodes[2].state == NodeState.DEAD
    assert m.alive() == [0, 1, 3]
    gen = m.generation
    m.heartbeat(2)                      # rejoins
    assert m.nodes[2].state == NodeState.JOINING
    m.admit(2)
    assert m.alive() == [0, 1, 2, 3] and m.generation == gen + 1


def test_straggler_detection():
    t = [0.0]
    m = Membership(range(8), ElasticConfig(straggler_factor=1.5), clock=lambda: t[0])
    for _ in range(10):
        t[0] += 1.0
        for n in range(8):
            m.heartbeat(n, step_time=2.0 if n == 5 else 1.0)
    assert m.stragglers() == [5]


@pytest.mark.parametrize("n,expect", [(256, (16, 16)), (255, (15, 16)), (17, (1, 16)), (8, (1, 8))])
def test_viable_grid(n, expect):
    assert viable_grid(n, 16) == expect
