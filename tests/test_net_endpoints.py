"""Two-endpoint reconciliation over real transports vs the numpy oracle.

Alice and Bob run as separate ``repro.net`` endpoints exchanging only
``repro.wire``-encoded bytes; every session's result — diff, rounds,
per-round *measured* byte ledger, split/fake counters, estimator bytes —
must be byte-identical to ``core.pbs.reconcile``, over the in-memory
duplex, the TCP loopback socket, and a lossy simulated channel that forces
the stop-and-wait retransmit path.
"""
import numpy as np
import pytest

from repro.core.pbs import PBSConfig, reconcile, true_diff
from repro.core.simdata import make_pair, make_pair_two_sided
from repro.net import (
    AliceEndpoint,
    BobEndpoint,
    InMemoryDuplex,
    ReliableTransport,
    SimulatedChannel,
    run_pair,
    tcp_loopback_pair,
)


def _mixed_cases():
    """Sessions spanning several cohorts, estimator path, two-sided diffs."""
    cases = []
    for i, d in enumerate((5, 50)):
        a, b = make_pair(1500, d, np.random.default_rng(d))
        cases.append((a, b, PBSConfig(seed=10 + i), d))
    a, b = make_pair_two_sided(2000, 20, 12, np.random.default_rng(3))
    cases.append((a, b, PBSConfig(seed=2), 32))
    a, b = make_pair(2500, 40, np.random.default_rng(8))
    cases.append((a, b, PBSConfig(seed=5), None))   # ToW phase 0 on the wire
    return cases


def _run_cases(cases, ta, tb):
    alice, bob = AliceEndpoint(ta), BobEndpoint(tb)
    for a, b, cfg, dk in cases:
        alice.submit(a, cfg=cfg, d_known=dk)
        bob.submit(b, cfg=cfg, d_known=dk)
    return alice, bob, run_pair(alice, bob)


def _assert_oracle(got, a, b, cfg, dk):
    exp = reconcile(a, b, cfg, d_known=dk)
    assert got.diff == exp.diff
    assert got.bytes_per_round == exp.bytes_per_round  # measured == Formula (1)
    assert got.bytes_sent == exp.bytes_sent
    assert got.estimator_bytes == exp.estimator_bytes
    assert got.rounds == exp.rounds
    assert got.success == exp.success
    assert got.decode_failures == exp.decode_failures
    assert got.fake_rejections == exp.fake_rejections
    return exp


def test_endpoints_in_memory_match_oracle():
    cases = _mixed_cases()
    ta, tb = InMemoryDuplex.pair()
    alice, bob, results = _run_cases(cases, ta, tb)
    for sid, (a, b, cfg, dk) in enumerate(cases):
        exp = _assert_oracle(results[sid], a, b, cfg, dk)
        assert exp.success and exp.diff == true_diff(a, b)
    # Bob verified every session end-to-end from c(A xor D_hat) == c(B)
    assert alice.verified == bob.verified == [True] * len(cases)

    # wire coherence: both ends measured the same frame traffic, and the
    # framed protocol bytes exceed the pure ledger only by bounded structure
    sa, sb = alice.wire_stats, bob.wire_stats
    assert sa["frame_bytes_out"] == sb["frame_bytes_in"]
    assert sa["frame_bytes_in"] == sb["frame_bytes_out"]
    assert sa["protocol_frame_bytes"] == sb["protocol_frame_bytes"]
    ledger = sum(results[s].bytes_sent for s in range(len(cases)))
    assert sa["protocol_frame_bytes"] >= ledger
    assert sa["protocol_frame_bytes"] - ledger < 32 * max(
        r.rounds for r in results.values()
    )
    est = sum(results[s].estimator_bytes for s in range(len(cases)))
    assert sa["estimator_frame_bytes"] == est


def test_endpoints_loopback_socket_match_oracle():
    cases = _mixed_cases()[:2]
    ta, tb = tcp_loopback_pair()
    try:
        alice, bob, results = _run_cases(cases, ta, tb)
        for sid, (a, b, cfg, dk) in enumerate(cases):
            exp = _assert_oracle(results[sid], a, b, cfg, dk)
            assert exp.success and exp.diff == true_diff(a, b)
        assert bob.verified == [True] * len(cases)
        # real sockets: the transport saw exactly the framed bytes
        assert alice.wire_stats["transport_bytes_out"] == alice.wire_stats["frame_bytes_out"]
    finally:
        ta.close()
        tb.close()


def test_endpoints_overload_split_and_budget_failure():
    """A BCH-overloaded session (3-way split on both sides of the wire) and
    an undersized-budget session (failure reported identically) mixed with
    a healthy neighbor."""
    a1, b1 = make_pair(2000, 10, np.random.default_rng(7))
    a2, b2 = make_pair(2500, 40, np.random.default_rng(17))
    cfg2 = PBSConfig(seed=6, n_override=255, t_override=8, g_override=1, max_rounds=12)
    a3, b3 = make_pair(2000, 30, np.random.default_rng(5))
    cfg3 = PBSConfig(seed=4, n_override=63, t_override=2, g_override=1, max_rounds=2)
    cases = [
        (a1, b1, PBSConfig(seed=21), 10),
        (a2, b2, cfg2, 40),
        (a3, b3, cfg3, 30),
    ]
    ta, tb = InMemoryDuplex.pair()
    alice, bob, results = _run_cases(cases, ta, tb)
    for sid, (a, b, cfg, dk) in enumerate(cases):
        _assert_oracle(results[sid], a, b, cfg, dk)
    assert results[1].decode_failures >= 1 and results[1].success
    assert not results[2].success                 # budget exhausted
    assert bob.verified == [True, True, False]
    # Bob mirrored the split queue purely from frames: same unit counts
    assert len(bob.sessions[1].state.units) == len(alice.sessions[1].state.units)


def test_endpoints_survive_lossy_channel_with_retransmits():
    a, b = make_pair(1200, 15, np.random.default_rng(11))
    cfg = PBSConfig(seed=9)
    ca, cb = SimulatedChannel.pair(loss=0.3, latency=0.001, seed=77)
    ra = ReliableTransport(ca, timeout=0.02)
    rb = ReliableTransport(cb, timeout=0.02)
    alice, bob = AliceEndpoint(ra), BobEndpoint(rb)
    alice.submit(a, cfg=cfg, d_known=15)
    bob.submit(b, cfg=cfg, d_known=15)
    results = run_pair(alice, bob)
    _assert_oracle(results[0], a, b, cfg, 15)
    assert results[0].success and results[0].diff == true_diff(a, b)
    assert ca.dropped + cb.dropped >= 1           # the channel really lost data
    assert ra.retransmits + rb.retransmits >= 1   # and ARQ really recovered
    # ARQ overhead is visible at the transport, invisible to the ledger
    assert ca.bytes_out + cb.bytes_out > (
        alice.wire_stats["frame_bytes_out"] + bob.wire_stats["frame_bytes_out"]
    )
