"""Warm-compilation guarantees: shape buckets hold, retraces hit zero.

PR 6's executor contract (DESIGN.md §12): every device entry point —
round execute/encode, batched BCH decode, phase-0 ToW — runs at
``pow2_bucket`` shape signatures, so after a warmup pass over a workload's
buckets, later runs (and later continuous-sync epochs) trigger **zero**
jit recompilations.  ``stats["retraces"]`` counts actual traced executions
of the jitted bodies, so these tests fail if anyone reintroduces an
unbucketed shape into the hot path.
"""
import numpy as np

from repro.core.pbs import PBSConfig
from repro.core.simdata import make_pair
from repro.net import AliceEndpoint, HubEndpoint, InMemoryDuplex, run_hub, run_hub_epoch
from repro.recon import ReconcileServer


def _submit_grid(server, *, seed0=0):
    for i, d in enumerate((5, 50, 500)):
        a, b = make_pair({5: 1500, 50: 4000, 500: 8000}[d], d,
                         np.random.default_rng(d))
        server.submit(a, b, cfg=PBSConfig(seed=seed0 + i), d_known=d)
    # one estimator session so the warm contract covers phase 0 too
    a, b = make_pair(6000, 80, np.random.default_rng(2))
    server.submit(a, b, cfg=PBSConfig(seed=seed0 + 8), d_known=None)


def test_second_server_run_retraces_zero():
    """A fresh server over the same shape buckets must be fully warm: its
    run reports ``retraces == 0`` (process jit caches persist; a cold
    process warms on the first run and the persistent compilation cache
    carries signatures across processes)."""
    warm_up = ReconcileServer()
    _submit_grid(warm_up, seed0=0)
    warm_up.run()
    assert warm_up.stats["retraces"] >= 0  # counter wired (cold iff first)

    server = ReconcileServer()
    _submit_grid(server, seed0=0)
    results = server.run()
    assert all(r.success for r in results.values())
    assert server.stats["retraces"] == 0, server.stats


def test_hub_epoch_soak_retraces_zero_after_warmup():
    """The ISSUE 6 acceptance soak: a 4-peer continuous-sync hub across 3
    churn epochs — epoch 1 may still warm delta-path signatures, epochs 2
    and 3 must report ``retraces == 0`` in the hub stats."""
    peers, d = 4, 20
    rng = np.random.default_rng(77)
    hub = HubEndpoint(recv_deadline=30.0, continuous=True)
    alices = {}
    for p in range(peers):
        a, b = make_pair(700, d, np.random.default_rng(77 + 101 * p))
        dk = None if p == 3 else d     # one estimator peer: warm ToW too
        cfg = PBSConfig(seed=77 + p, n_override=127, t_override=7,
                        g_override=4)
        ta, tb = InMemoryDuplex.pair()
        ch = hub.add_peer(tb, label=f"peer{p}")
        hub.submit(ch, b, cfg=cfg, d_known=dk)
        ep = AliceEndpoint(ta, channel=ch, continuous=True)
        ep.submit(a, cfg=cfg, d_known=dk)
        alices[ch] = ep

    outcomes, _, errors = run_hub(hub, alices)
    assert not errors and all(o.ok for o in outcomes.values())
    assert "retraces" in hub.stats

    retraces = []
    for _ in range(1, 4):
        hub_muts, alice_muts = {}, {}
        for ch, ep in alices.items():
            b_cur = hub._peers[ch].sessions[0].state.b
            hub_muts[ch] = {0: (
                rng.integers(1, 1 << 32, size=8, dtype=np.uint64).astype(np.uint32),
                rng.permutation(b_cur)[:8],
            )}
            a_cur = ep.sessions[0].state.a
            alice_muts[ch] = {0: (
                rng.integers(1, 1 << 32, size=2, dtype=np.uint64).astype(np.uint32),
                rng.permutation(a_cur)[:2],
            )}
        hub.advance_epoch(hub_muts)
        for ch, ep in alices.items():
            ep.advance_epoch(alice_muts[ch])
        outcomes, _, errors = run_hub_epoch(hub, alices)
        assert not errors and all(o.ok for o in outcomes.values())
        retraces.append(hub.stats["retraces"])

    # epoch 1 is warmup; from epoch 2 on, every kernel signature must
    # already be compiled — cross-round AND cross-epoch
    assert retraces[1:] == [0, 0], retraces
