"""Property-based parity for the continuous-sync delta path (DESIGN.md §11).

The delta-mutable store machinery must be *invisible*: after any trace of
epoch mutations through ``apply_mutations``/``advance_session``, the
mutated batch must plan and reconcile byte-identically to a from-scratch
rebuild over the same current sets.

Three layers:

1. **plan parity** — after each epoch advance, every cohort round plan of
   the long-lived (delta-patched) ``SessionBatch`` is compared
   field-for-field and array-for-array against a freshly built batch over
   the same session states, and every store row's *effective element set*
   (the live CSR prefix) must match the fresh pack;
2. **result parity** — each epoch's reconciliation results are
   byte-identical to the ``core.pbs.reconcile`` oracle over the epoch's
   sets, with ``stats["store_builds"] == 0`` asserting the pure delta path
   never rebuilt (layout pinned), and a layout-shifting variant asserting
   rebuilds are *counted* when d̂ swings re-plan the cohort;
3. **store-level units** — ``apply_side_mutations`` edge semantics
   (swap-remove backfill, lane append, capacity overflow -> compaction,
   absent-removal rejection) plus the direct ``SessionBatch.add_sessions``
   invalidation and ``store_builds``/``store_upload_bytes`` counter
   coverage that previously only the hub acceptance test exercised.

Seeded variants always run; hypothesis widens the trace space when the
``[test]`` extra is installed (tests/_hypothesis_compat.py).
"""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.pbs import (
    PBSConfig,
    new_session_state,
    plan_from_d_known,
    reconcile,
    true_diff,
)
from repro.core.simdata import make_pair
from repro.recon import ReconcileServer
from repro.recon.session import (
    ReconSession,
    SessionBatch,
    StoreCapacityError,
    apply_churn,
)

_EMPTY = np.zeros(0, dtype=np.uint32)


def _fresh_elems(rng, k):
    return rng.integers(1, 1 << 32, size=k, dtype=np.uint64).astype(np.uint32)


def _churn(rng, base, n_add, n_remove):
    removed = rng.permutation(base)[:n_remove]
    added = _fresh_elems(rng, n_add)
    return added, removed


def _fresh_batch_like(batch):
    """A from-scratch SessionBatch over the same current session states."""
    sessions = [
        ReconSession(
            sid=s.sid,
            plan=s.plan,
            state=new_session_state(s.state.a, s.state.b, s.plan),
            rnd0=s.rnd0,
            failed=s.failed,
        )
        for s in batch.sessions
    ]
    return SessionBatch(sessions, sides=batch.sides, mutable=batch.mutable)


def _assert_store_rows_equal(mutated, fresh):
    """Every row's live element *set* in the patched store must equal the
    freshly packed store's (slot order is free: the reductions are
    permutation-invariant)."""
    assert mutated.row_of == fresh.row_of
    for side in mutated.sides:
        ms, fs = mutated.sides[side], fresh.sides[side]
        np.testing.assert_array_equal(ms.cnt_host, fs.cnt_host)
        for row in range(len(ms.cnt_host)):
            m_row = ms.flat_host[
                ms.start_host[row] : ms.start_host[row] + ms.cnt_host[row]
            ]
            f_row = fs.flat_host[
                fs.start_host[row] : fs.start_host[row] + fs.cnt_host[row]
            ]
            np.testing.assert_array_equal(np.sort(m_row), np.sort(f_row))
            # device mirror matches the host mirror at every patched slot
            np.testing.assert_array_equal(
                np.asarray(ms.flat)[
                    ms.start_host[row] : ms.start_host[row] + ms.cnt_host[row]
                ],
                m_row,
            )


def _assert_plan_parity(batch):
    """The mutated batch's round-1 plans must be byte-identical to a
    from-scratch rebuild's: same cohorts, members, widths, and overlay
    arrays (the executor sees no difference beyond store slot order)."""
    fresh = _fresh_batch_like(batch)
    plans_m = batch.plan_round(1)
    plans_f = fresh.plan_round(1)
    assert len(plans_m) == len(plans_f)
    for pm, pf in zip(plans_m, plans_f):
        assert (pm.store.n, pm.store.t, pm.store.m) == (
            pf.store.n, pf.store.t, pf.store.m
        )
        assert pm.units == pf.units
        assert (pm.width_a, pm.width_b) == (pf.width_a, pf.width_b)
        assert [
            (s.sid, base, len(active), seed)
            for s, base, active, seed in pm.members
        ] == [
            (s.sid, base, len(active), seed)
            for s, base, active, seed in pf.members
        ]
        assert pm.arrays.keys() == pf.arrays.keys()
        for key in pm.arrays:
            np.testing.assert_array_equal(
                pm.arrays[key], pf.arrays[key], err_msg=key
            )
        _assert_store_rows_equal(pm.store, pf.store)


def _run_trace(seed, epochs, *, sessions=2, size=500, d=12, pinned=True):
    """Drive a random epoch-mutation trace through the continuous server,
    asserting plan parity, oracle result parity, and the build ledger."""
    rng = np.random.default_rng(seed)
    server = ReconcileServer(continuous=True)
    cfgs, dks = [], []
    for s in range(sessions):
        a, b = make_pair(size, d, np.random.default_rng(seed + 31 * s))
        # mix known-d and estimator sessions; pinned layouts keep the
        # delta path rebuild-free, unpinned ones re-optimize per epoch
        dk = d if s % 2 == 0 else None
        cfg = (
            PBSConfig(seed=seed + s, n_override=127, t_override=7,
                      g_override=3)
            if pinned
            else PBSConfig(seed=seed + s)
        )
        server.submit(a, b, cfg=cfg, d_known=dk)
        cfgs.append(cfg)
        dks.append(dk)
    results = server.run()
    assert server.stats["store_builds"] > 0        # epoch 0 pays the upload

    for _ in range(epochs):
        muts = {}
        for s in range(sessions):
            st = server.sessions[s].state
            muts[s] = (
                *_churn(rng, st.a, int(rng.integers(0, 6)),
                        int(rng.integers(0, 6))),
                *_churn(rng, st.b, int(rng.integers(0, 6)),
                        int(rng.integers(0, 6))),
            )
        server.advance_epoch(muts)
        if pinned:
            _assert_plan_parity(server._batch)
        results = server.run()
        stats = server.stats
        if pinned:
            # the pure delta path: zero rebuilds, only O(churn) H2D bytes
            assert stats["store_builds"] == 0, stats
            assert stats["store_compactions"] == 0, stats
            assert stats["h2d_delta_bytes"] > 0
            assert stats["h2d_store_bytes"] == 0
        for s in range(sessions):
            sess = server.sessions[s]
            a_e, b_e = sess.state.a, sess.state.b
            oracle = reconcile(a_e, b_e, cfgs[s], d_known=dks[s])
            r = results[s]
            assert r.success and r.diff == oracle.diff == true_diff(a_e, b_e)
            assert r.bytes_per_round == oracle.bytes_per_round
            assert r.bytes_sent == oracle.bytes_sent
            assert r.estimator_bytes == oracle.estimator_bytes
            assert (r.n, r.t, r.g, r.d_est) == (
                oracle.n, oracle.t, oracle.g, oracle.d_est
            )
    return server


# ---------------------------------------------------------------------------
# seeded always-run variants
# ---------------------------------------------------------------------------


def test_delta_trace_matches_rebuild_seeded():
    _run_trace(2001, epochs=3, pinned=True)


def test_delta_trace_unpinned_counts_rebuilds():
    """Without pinned layouts the estimator session re-plans per epoch;
    results must stay oracle-identical and any layout shift must surface
    as a *counted* rebuild instead of silent corruption."""
    server = _run_trace(2002, epochs=2, sessions=2, pinned=False)
    batch = server._batch
    # every store build was ledgered with its upload bytes
    assert batch.store_builds >= 1
    assert batch.store_build_bytes > 0


def test_epoch_with_zero_churn_is_d0():
    """An epoch with no mutations reconciles d = 0 byte-identically."""
    server = ReconcileServer(continuous=True)
    a, b = make_pair(400, 10, np.random.default_rng(5))
    cfg = PBSConfig(seed=3, n_override=127, t_override=7, g_override=2)
    server.submit(a, b, cfg=cfg, d_known=10)
    server.run()
    server.advance_epoch()                   # fold only: A becomes B
    results = server.run()
    sess = server.sessions[0]
    assert np.array_equal(np.sort(sess.state.a), np.sort(sess.state.b))
    oracle = reconcile(sess.state.a, sess.state.b, cfg, d_known=10)
    assert results[0].diff == oracle.diff == set()
    assert results[0].bytes_per_round == oracle.bytes_per_round
    assert server.stats["store_builds"] == 0


# ---------------------------------------------------------------------------
# hypothesis variants (skip cleanly without the [test] extra)
# ---------------------------------------------------------------------------


@settings(max_examples=3, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**20))
def test_delta_trace_matches_rebuild_hypothesis(seed):
    _run_trace(seed, epochs=2, sessions=1, size=350, d=8, pinned=True)


# ---------------------------------------------------------------------------
# store-level units: mutation lanes, compaction, counters
# ---------------------------------------------------------------------------


def _one_session_batch(size=300, d=8, seed=0, mutable=True, g=2):
    a, b = make_pair(size, d, np.random.default_rng(seed))
    cfg = PBSConfig(seed=seed, n_override=127, t_override=7, g_override=g)
    plan = plan_from_d_known(cfg, d)
    sess = ReconSession(sid=0, plan=plan, state=new_session_state(a, b, plan))
    return SessionBatch([sess], mutable=mutable), sess


def test_apply_mutations_patches_in_place():
    batch, sess = _one_session_batch()
    store = batch.store_for(sess.code_key)
    gen0 = store.generation
    flat_id = id(store.sides["a"].flat_host)
    rng = np.random.default_rng(1)
    removed = rng.permutation(sess.state.a)[:5]
    added = _fresh_elems(rng, 5)
    batch.apply_mutations(sess, "a", added, removed)
    assert batch.store_for(sess.code_key) is store     # same store object
    assert store.generation > gen0
    assert id(store.sides["a"].flat_host) == flat_id   # patched, not repacked
    assert batch.store_builds == 1
    assert batch.store_patches == 1
    assert batch.store_delta_bytes > 0
    # the live rows now hold exactly the churned set
    new_a = apply_churn(sess.state.a, added, removed)
    ss = store.sides["a"]
    live = np.concatenate([
        ss.flat_host[ss.start_host[r] : ss.start_host[r] + ss.cnt_host[r]]
        for r in range(len(ss.cnt_host))
    ])
    np.testing.assert_array_equal(np.sort(live), new_a)


def test_apply_mutations_rejects_absent_removal():
    batch, sess = _one_session_batch()
    store = batch.store_for(sess.code_key)
    absent = np.setdiff1d(
        _fresh_elems(np.random.default_rng(9), 64), sess.state.a
    )[:1]
    with pytest.raises(ValueError, match="not resident"):
        batch.apply_mutations(sess, "a", _EMPTY, absent)
    assert store.generation == 0


def test_capacity_overflow_triggers_compaction():
    batch, sess = _one_session_batch(size=64, g=1)
    store = batch.store_for(sess.code_key)
    cap = int(store.sides["a"].cap_host[0])
    # overflow row 0's lane: more additions than its free slots
    added = _fresh_elems(np.random.default_rng(2), cap)
    batch.apply_mutations(sess, "a", added, _EMPTY)
    assert batch.store_compactions == 1
    assert sess.code_key not in batch._stores          # discarded, not patched
    # next use rebuilds (a counted build) from the session state
    sess.state = new_session_state(
        apply_churn(sess.state.a, added, _EMPTY), sess.state.b, sess.plan
    )
    rebuilt = batch.store_for(sess.code_key)
    assert batch.store_builds == 2
    assert rebuilt is not store


def test_submit_after_epochs_resets_stats_marks():
    """submit() discards the batch (and its counters): the next run's
    per-epoch ledger must diff against the NEW batch — the full rebuild is
    visible as store_builds > 0 and delta bytes never go negative."""
    server = ReconcileServer(continuous=True)
    cfg = PBSConfig(seed=9, n_override=127, t_override=7, g_override=2)
    a, b = make_pair(300, 8, np.random.default_rng(8))
    server.submit(a, b, cfg=cfg, d_known=8)
    server.run()
    server.advance_epoch({0: (*_churn(np.random.default_rng(1), a, 3, 3),
                              _EMPTY, _EMPTY)})
    server.run()
    assert server.stats["h2d_delta_bytes"] > 0
    a2, b2 = make_pair(300, 8, np.random.default_rng(18))
    server.submit(a2, b2, cfg=cfg, d_known=8)
    server.run()
    st = server.stats
    assert st["store_builds"] >= 1          # the fresh batch's build shows
    assert st["h2d_delta_bytes"] == 0       # never negative after the reset


def test_cohort_round_trip_migration_rebuilds_fresh():
    """A session that migrates out of a cohort and later back in must not
    reuse the stale resident rows it left behind: both cohorts' stores are
    invalidated at each layout change, so the return rebuilds from the
    *current* state (regression for the store_for membership guard, which
    only checks presence)."""
    from repro.recon.session import advance_session

    a, b = make_pair(300, 8, np.random.default_rng(3))
    cfg1 = PBSConfig(seed=1, n_override=127, t_override=7, g_override=2)
    cfg2 = PBSConfig(seed=1, n_override=255, t_override=8, g_override=2)
    plan1, plan2 = plan_from_d_known(cfg1, 8), plan_from_d_known(cfg2, 8)
    sess = ReconSession(sid=0, plan=plan1, state=new_session_state(a, b, plan1))
    batch = SessionBatch([sess], mutable=True)
    key1, key2 = sess.code_key, (plan2.n, plan2.t)
    batch.store_for(key1)                       # epoch-0 store, elements E1

    rng = np.random.default_rng(4)
    a2 = apply_churn(a, _fresh_elems(rng, 5), rng.permutation(a)[:5])
    advance_session(batch, sess, plan2, new_a=a2)    # migrate K1 -> K2
    assert key1 not in batch._stores            # stale E1 rows dropped
    batch.store_for(key2)                       # K2 store over E2

    a3 = apply_churn(a2, _fresh_elems(rng, 5), rng.permutation(a2)[:5])
    advance_session(batch, sess, plan1, new_a=a3)    # ...and back: K2 -> K1
    assert key1 not in batch._stores and key2 not in batch._stores
    store = batch.store_for(key1)               # rebuilt from current state
    ss = store.sides["a"]
    live = np.concatenate([
        ss.flat_host[ss.start_host[r] : ss.start_host[r] + ss.cnt_host[r]]
        for r in range(len(ss.cnt_host))
    ])
    np.testing.assert_array_equal(np.sort(live), np.sort(a3))


def test_one_shot_store_has_no_mutation_lanes():
    batch, sess = _one_session_batch(mutable=False)
    store = batch.store_for(sess.code_key)
    assert store.sides["a"].flat_host is None
    with pytest.raises(StoreCapacityError, match="without mutation lanes"):
        store.apply_side_mutations("a", {0: ([1], [])})


# ---------------------------------------------------------------------------
# direct add_sessions invalidation + counter coverage (ISSUE 5 satellite)
# ---------------------------------------------------------------------------


def _session_for(sid, size, d, seed, n, t):
    cfg = PBSConfig(seed=seed, n_override=n, t_override=t, g_override=2)
    plan = plan_from_d_known(cfg, d)
    a, b = make_pair(size, d, np.random.default_rng(seed))
    return ReconSession(sid=sid, plan=plan, state=new_session_state(a, b, plan))


def test_add_sessions_invalidates_only_affected_cohorts():
    s0 = _session_for(0, 300, 8, seed=1, n=127, t=7)
    s1 = _session_for(1, 300, 8, seed=2, n=255, t=8)
    batch = SessionBatch([s0, s1])
    assert batch.store_upload_bytes() == 0      # accounting never builds
    assert batch.store_builds == 0
    store0 = batch.store_for(s0.code_key)
    store1 = batch.store_for(s1.code_key)
    assert batch.store_builds == 2
    assert batch.store_upload_bytes() == store0.h2d_bytes + store1.h2d_bytes
    assert batch.store_build_bytes == batch.store_upload_bytes()

    # a joiner in s0's cohort invalidates exactly that cohort's store
    s2 = _session_for(2, 300, 8, seed=3, n=127, t=7)
    batch.add_sessions([s2])
    assert batch.sessions == [s0, s1, s2]
    assert s1.code_key in batch._stores         # untouched cohort survives
    assert s0.code_key not in batch._stores     # affected cohort dropped
    assert batch.store_for(s1.code_key) is store1   # cached, no rebuild
    assert batch.store_builds == 2

    # the rebuild includes the joiner's rows and re-ups the counters
    rebuilt = batch.store_for(s0.code_key)
    assert rebuilt is not store0
    assert batch.store_builds == 3
    assert (s2.sid, 0) in rebuilt.row_of and (s0.sid, 0) in rebuilt.row_of
    assert batch.store_upload_bytes() == rebuilt.h2d_bytes + store1.h2d_bytes
    # build bytes accumulate across rebuilds; upload bytes track residency
    assert batch.store_build_bytes == (
        store0.h2d_bytes + store1.h2d_bytes + rebuilt.h2d_bytes
    )


def test_add_sessions_rebuild_skips_finished_sessions():
    s0 = _session_for(0, 300, 8, seed=4, n=127, t=7)
    s1 = _session_for(1, 300, 8, seed=5, n=127, t=7)
    batch = SessionBatch([s0, s1])
    batch.store_for(s0.code_key)
    for u in s1.state.units:                    # s1 finishes: all units done
        u.done = True
    s2 = _session_for(2, 300, 8, seed=6, n=127, t=7)
    batch.add_sessions([s2])
    rebuilt = batch.store_for(s0.code_key)
    assert (s0.sid, 0) in rebuilt.row_of and (s2.sid, 0) in rebuilt.row_of
    assert (s1.sid, 0) not in rebuilt.row_of    # finished rows never re-upload
