"""BCH sketch codec: roundtrip, linearity, overload detection, batched parity."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.bch import (
    BCHCode,
    batched_decode,
    decode_sketch,
    sketch_from_positions,
    sketch_xor,
)


@pytest.mark.parametrize("n,t", [(63, 8), (127, 13), (255, 11), (511, 6), (1023, 17)])
def test_roundtrip(n, t):
    rng = np.random.default_rng(n + t)
    code = BCHCode(n, t)
    for _ in range(15):
        d = int(rng.integers(0, t + 1))
        diff = rng.choice(n, size=d, replace=False)
        ok, rec = decode_sketch(code, sketch_from_positions(code, diff))
        assert ok
        assert set(rec.tolist()) == set(diff.tolist())


@pytest.mark.parametrize("n,t", [(63, 8), (127, 13)])
def test_overload_detected(n, t):
    """> t errors must be reported as failure (w.h.p.), never mis-decoded."""
    rng = np.random.default_rng(7)
    code = BCHCode(n, t)
    silent_wrong = 0
    for _ in range(30):
        diff = rng.choice(n, size=t + 2 + int(rng.integers(0, 5)), replace=False)
        ok, rec = decode_sketch(code, sketch_from_positions(code, diff))
        if ok and set(rec.tolist()) != set(diff.tolist()):
            silent_wrong += 1
    assert silent_wrong == 0


def test_linearity():
    code = BCHCode(127, 9)
    rng = np.random.default_rng(0)
    pa = rng.choice(127, size=20, replace=False)
    pb = rng.choice(127, size=20, replace=False)
    sym = np.array(sorted(set(pa.tolist()) ^ set(pb.tolist())))
    lhs = sketch_xor(sketch_from_positions(code, pa), sketch_from_positions(code, pb))
    assert (lhs == sketch_from_positions(code, sym)).all()


@given(st.integers(min_value=0, max_value=11), st.integers(min_value=0, max_value=2**31))
@settings(max_examples=60, deadline=None)
def test_roundtrip_property(d, seed):
    code = BCHCode(255, 11)
    rng = np.random.default_rng(seed)
    diff = rng.choice(255, size=d, replace=False)
    ok, rec = decode_sketch(code, sketch_from_positions(code, diff))
    assert ok and set(rec.tolist()) == set(diff.tolist())


@pytest.mark.parametrize("n,t", [(63, 8), (127, 13), (255, 9)])
def test_batched_matches_scalar(n, t):
    rng = np.random.default_rng(n)
    code = BCHCode(n, t)
    sketches, expect = [], []
    for _ in range(40):
        d = int(rng.integers(0, t + 4))  # includes overload rows
        diff = rng.choice(n, size=d, replace=False)
        sketches.append(sketch_from_positions(code, diff))
        expect.append(decode_sketch(code, sketches[-1]))
    ok, positions = batched_decode(code, np.stack(sketches))
    for i, (ok_i, pos_i) in enumerate(expect):
        assert ok[i] == ok_i
        assert set(positions[i].tolist()) == set(pos_i.tolist())


def test_zero_sketch():
    code = BCHCode(127, 13)
    ok, rec = decode_sketch(code, np.zeros(13, dtype=np.int64))
    assert ok and len(rec) == 0
    okb, posb = batched_decode(code, np.zeros((3, 13), dtype=np.int64))
    assert okb.all() and all(len(p) == 0 for p in posb)
