"""Baseline scheme correctness (PinSketch, D.Digest, Graphene, PinSketch/WP)."""
import numpy as np
import pytest

from repro.core.baselines import (
    IBF,
    ddigest_reconcile,
    graphene_reconcile,
    pinsketch_encode,
    pinsketch_decode,
    pinsketch_reconcile,
    pinsketch_wp_reconcile,
)
from repro.core.simdata import make_pair


def _td(a, b):
    return set(int(x) for x in a) ^ set(int(x) for x in b)


@pytest.mark.parametrize("d", [0, 1, 5, 20])
def test_pinsketch(d):
    rng = np.random.default_rng(d)
    a, b = make_pair(3000, d, rng)
    r = pinsketch_reconcile(a, b, t=max(d, 1) + 2)
    assert r.success and r.diff == _td(a, b)
    assert r.bytes_sent == ((max(d, 1) + 2) * 32 + 7) // 8


def test_pinsketch_overload_detected():
    rng = np.random.default_rng(5)
    a, b = make_pair(3000, 30, rng)
    r = pinsketch_reconcile(a, b, t=10)  # d > t: must not silently succeed
    assert not r.success


def test_ibf_peel_roundtrip():
    rng = np.random.default_rng(2)
    a, b = make_pair(5000, 25, rng)
    ibf_a = IBF(80, 4, seed=1)
    ibf_a.insert_all(a)
    ibf_b = IBF(80, 4, seed=1)
    ibf_b.insert_all(b)
    ok, rec = ibf_a.subtract(ibf_b).peel()
    assert ok and rec == _td(a, b)


@pytest.mark.parametrize("d", [5, 50, 300])
def test_ddigest(d):
    rng = np.random.default_rng(d)
    a, b = make_pair(20000, d, rng)
    r = ddigest_reconcile(a, b, d_plan=int(1.38 * d) + 2)
    assert r.success and r.diff == _td(a, b)


@pytest.mark.parametrize("d", [10, 100])
def test_graphene(d):
    rng = np.random.default_rng(d)
    a, b = make_pair(20000, d, rng)
    r = graphene_reconcile(a, b, d_plan=int(1.38 * d) + 2)
    assert r.success and r.diff == _td(a, b)


def test_pinsketch_wp():
    rng = np.random.default_rng(9)
    a, b = make_pair(20000, 60, rng)
    r = pinsketch_wp_reconcile(a, b, d_plan=60, t=13)
    assert r.success and r.diff == _td(a, b)
    assert r.rounds <= 3
