"""Per-architecture smoke tests: reduced configs, one train step + prefill +
decode on CPU, asserting shapes and finiteness (assignment requirement f).

The FULL configs are exercised only by the dry-run (launch/dryrun.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.launch.cells import SHAPES, cell_status
from repro.optim import OptConfig
from repro.serve import make_serve_fns
from repro.train import init_train_state, make_train_step

B, T, ENC = 2, 64, 32
pytestmark = pytest.mark.slow  # model-scaffold tier: multi-minute per-arch sweeps, full-suite job only



@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh(
        (1, 1), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )


def _batch(cfg, rng):
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, T)), jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family == "encdec":
        batch["enc"] = jnp.asarray(rng.normal(size=(B, ENC, cfg.d_model)), jnp.bfloat16)
    if cfg.frontend == "patch_stub":
        nf = cfg.n_frontend_tokens
        batch["tokens"] = batch["tokens"].at[:, :nf].set(-1)
        batch["frontend"] = jnp.asarray(rng.normal(size=(B, T, cfg.d_model)), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch, mesh):
    cfg = get_smoke_config(arch)
    ocfg = OptConfig(warmup=2, total_steps=10)
    bundle = make_train_step(cfg, mesh, ocfg, batch=B)
    params, opt = init_train_state(bundle, cfg, mesh, ocfg)
    rng = np.random.default_rng(0)
    batch = _batch(cfg, rng)
    p1, o1, m1 = bundle.step(params, opt, batch)
    assert np.isfinite(float(m1["loss"])), m1
    assert np.isfinite(float(m1["grad_norm"]))
    # loss moves after a couple of steps on the same batch
    p2, o2, m2 = bundle.step(p1, o1, batch)
    p3, _, m3 = bundle.step(p2, o2, batch)
    assert float(m3["loss"]) < float(m1["loss"]), (arch, float(m1["loss"]), float(m3["loss"]))
    # parameter shapes preserved
    flat1 = jax.tree.leaves(p3)
    flat0 = jax.tree.leaves(bundle.param_spec)
    assert len(flat1) == len(flat0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_smoke(arch, mesh):
    cfg = get_smoke_config(arch)
    ocfg = OptConfig(warmup=2, total_steps=10)
    bundle = make_train_step(cfg, mesh, ocfg, batch=B)
    params, _ = init_train_state(bundle, cfg, mesh, ocfg)
    rng = np.random.default_rng(1)
    batch = _batch(cfg, rng)
    sv = make_serve_fns(cfg, mesh, batch=B, max_len=T, enc_len=ENC)
    inputs = {k: v for k, v in batch.items() if k in ("tokens", "enc", "frontend")}
    caches, tok = sv.prefill(params, inputs)
    assert tok.shape == (B,) and tok.dtype == jnp.int32
    assert int(tok.min()) >= 0
    for _ in range(3):
        tok, caches = sv.decode(params, caches, tok[:, None])
        assert tok.shape == (B,)
        assert np.all(np.asarray(tok) >= 0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """Pin the exact assigned dimensions (guards against config drift)."""
    cfg = get_config(arch)
    expected = {
        "mamba2-780m": (48, 1536, 50280),
        "deepseek-v3-671b": (61, 7168, 129280),
        "deepseek-v2-236b": (60, 5120, 102400),
        "qwen3-14b": (40, 5120, 151936),
        "command-r-35b": (40, 8192, 256000),
        "qwen2-1.5b": (28, 1536, 151936),
        "internlm2-1.8b": (24, 2048, 92544),
        "whisper-tiny": (4, 384, 51865),
        "recurrentgemma-2b": (26, 2560, 256000),
        "pixtral-12b": (40, 5120, 131072),
    }[arch]
    assert (cfg.n_layers, cfg.d_model, cfg.vocab) == expected
    if arch == "deepseek-v3-671b":
        assert (cfg.n_experts, cfg.moe_top_k, cfg.n_shared_experts) == (256, 8, 1)
        assert (cfg.kv_lora, cfg.moe_d_ff) == (512, 2048)
    if arch == "deepseek-v2-236b":
        assert (cfg.n_experts, cfg.moe_top_k, cfg.n_shared_experts) == (160, 6, 2)
    if arch == "qwen3-14b":
        assert cfg.qk_norm and cfg.n_kv_heads == 8
    if arch == "qwen2-1.5b":
        assert cfg.qkv_bias and cfg.n_kv_heads == 2
    if arch == "recurrentgemma-2b":
        assert cfg.pattern == ("rglru", "rglru", "attn") and cfg.window == 2048
    if arch == "mamba2-780m":
        assert cfg.ssm_state == 128


def test_cell_grid_is_40_cells():
    cells = [(a, s) for a in ARCH_IDS for s in SHAPES]
    assert len(cells) == 40
    skips = [(a, s) for a, s in cells if not cell_status(a, s)[0]]
    # long_500k runs only for the sub-quadratic families (ssm + hybrid)
    assert sorted(skips) == sorted(
        (a, "long_500k") for a in ARCH_IDS if a not in ("mamba2-780m", "recurrentgemma-2b")
    )
