"""Incremental-decode correctness: decoding one token must agree with
re-prefilling the extended prompt (cache math == full forward math)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.optim import OptConfig
from repro.serve import make_serve_fns
from repro.train import init_train_state, make_train_step
pytestmark = pytest.mark.slow  # serve-scaffold tier: heavy decode sweeps, full-suite job only


B, T, ENC = 2, 32, 32


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "whisper-tiny", "mamba2-780m",
                                  "recurrentgemma-2b", "deepseek-v3-671b"])
def test_decode_matches_prefill_extension(arch):
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    cfg = get_smoke_config(arch)
    ocfg = OptConfig(warmup=2, total_steps=10)
    bundle = make_train_step(cfg, mesh, ocfg, batch=B)
    params, _ = init_train_state(bundle, cfg, mesh, ocfg)
    rng = np.random.default_rng(7)
    prompt = jnp.asarray(rng.integers(1, cfg.vocab, (B, T)), jnp.int32)
    extras = {}
    if cfg.family == "encdec":
        extras["enc"] = jnp.asarray(rng.normal(size=(B, ENC, cfg.d_model)), jnp.bfloat16)

    sv = make_serve_fns(cfg, mesh, batch=B, max_len=2 * T, enc_len=ENC)
    caches, tok_a = sv.prefill(params, {"tokens": prompt, **extras})
    tok_b_inc, _ = sv.decode(params, caches, tok_a[:, None])

    ext = jnp.concatenate([prompt, tok_a[:, None]], axis=1)  # (B, T+1)
    # re-prefill the extended prompt (pad to an even chunk if needed)
    sv2 = make_serve_fns(cfg, mesh, batch=B, max_len=2 * T, enc_len=ENC)
    _, tok_b_full = sv2.prefill(params, {"tokens": ext, **extras})

    np.testing.assert_array_equal(np.asarray(tok_b_inc), np.asarray(tok_b_full))
