"""blockwise_attention vs dense softmax reference — shapes, masks, grads.

Covers the §Perf "causal block skipping" optimization: the static pair-list
form must be exact (not approximate) vs the dense reference for every mask
regime, including the skip=False baseline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.models.layers import blockwise_attention


def ref_attn(q, k, v, kvmap, causal, window, q_off=0, k_off=0, kv_len=None):
    kg = jnp.take(k, kvmap, axis=1)
    vg = jnp.take(v, kvmap, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, kg).astype(jnp.float32) / np.sqrt(q.shape[-1])
    Tq, Tk = q.shape[2], k.shape[2]
    qp = q_off + jnp.arange(Tq)
    kp = k_off + jnp.arange(Tk)
    mask = jnp.ones((Tq, Tk), bool)
    if kv_len is not None:
        mask &= (kp < k_off + kv_len)[None, :]
    if causal:
        mask &= kp[None, :] <= qp[:, None]
    if window:
        mask &= kp[None, :] > (qp[:, None] - window)
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(vg.dtype), vg)


def _rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


CASES = [
    # Tq, Tk, qc, kc, causal, window, skip
    (64, 64, 16, 16, True, None, True),
    (64, 64, 16, 16, True, None, False),
    (64, 64, 16, 16, False, None, True),
    (100, 100, 32, 16, True, None, True),   # ragged padding
    (128, 128, 32, 32, True, 48, True),     # sliding window band
    (64, 96, 16, 16, False, None, True),    # cross-attention Tq != Tk
    (64, 64, 64, 64, True, None, True),     # single chunk
    (60, 60, 16, 16, True, 20, True),
]


@pytest.mark.parametrize("Tq,Tk,qc,kc,causal,window,skip", CASES)
def test_blockwise_matches_dense(Tq, Tk, qc, kc, causal, window, skip):
    rng = np.random.default_rng(0)
    B, H, Hkv, Dh, Dv = 2, 4, 2, 8, 8
    q = _rand(rng, B, H, Tq, Dh)
    k = _rand(rng, B, Hkv, Tk, Dh)
    v = _rand(rng, B, Hkv, Tk, Dv)
    kvmap = jnp.asarray(np.arange(H) // 2, jnp.int32)
    out = blockwise_attention(q, k, v, kvmap, causal=causal, window=window,
                              q_chunk=qc, k_chunk=kc, block_skip=skip)
    ref = ref_attn(q, k, v, kvmap, causal, window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)


def test_blockwise_gradient_matches_dense():
    rng = np.random.default_rng(1)
    B, H, Hkv, Dh = 2, 4, 2, 8
    q = _rand(rng, B, H, 64, Dh)
    k = _rand(rng, B, Hkv, 64, Dh)
    v = _rand(rng, B, Hkv, 64, Dh)
    kvmap = jnp.asarray(np.arange(H) // 2, jnp.int32)
    g1 = jax.grad(lambda q: blockwise_attention(
        q, k, v, kvmap, causal=True, q_chunk=16, k_chunk=16).sum())(q)
    g2 = jax.grad(lambda q: ref_attn(q, k, v, kvmap, True, None).sum())(q)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=3e-5)


def test_ragged_kv_len():
    rng = np.random.default_rng(2)
    B, H, Dh = 1, 2, 8
    q = _rand(rng, B, H, 32, Dh)
    k = _rand(rng, B, H, 64, Dh)
    v = _rand(rng, B, H, 64, Dh)
    kvmap = jnp.arange(H, dtype=jnp.int32)
    out = blockwise_attention(q, k, v, kvmap, causal=False, q_chunk=16,
                              k_chunk=16, kv_valid_len=jnp.int32(40))
    ref = ref_attn(q, k, v, kvmap, False, None, kv_len=jnp.int32(40))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)


@settings(max_examples=12, deadline=None)
@given(
    tq=st.integers(8, 96),
    causal=st.booleans(),
    qc=st.sampled_from([8, 16, 32]),
    kc=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 1000),
)
def test_blockwise_property(tq, causal, qc, kc, seed):
    rng = np.random.default_rng(seed)
    B, H, Dh = 1, 2, 4
    q = _rand(rng, B, H, tq, Dh)
    k = _rand(rng, B, H, tq, Dh)
    v = _rand(rng, B, H, tq, Dh)
    kvmap = jnp.arange(H, dtype=jnp.int32)
    out = blockwise_attention(q, k, v, kvmap, causal=causal, q_chunk=qc, k_chunk=kc)
    ref = ref_attn(q, k, v, kvmap, causal, None)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=5e-5)
