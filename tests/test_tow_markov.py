"""ToW estimator statistics + Markov-framework validation against the paper."""
import numpy as np
import pytest

from repro.core import markov
from repro.core.hashing import derive_seed
from repro.core.simdata import make_pair
from repro.core.tow import estimate_d, planned_d, tow_seeds, tow_sketches


def test_tow_host_mirror_matches_kernel_bitwise():
    """core.tow.tow_sketches must equal the Pallas tow_sketch kernel bit for
    bit — that identity is what lets repro.recon route phase 0 through the
    device while staying byte-identical to the numpy oracle."""
    import jax.numpy as jnp

    from repro.kernels.tow_sketch import tow_sketch

    rng = np.random.default_rng(0)
    elems = rng.integers(1, 1 << 32, size=3001, dtype=np.uint64).astype(np.uint32)
    for seed in (0, 7, 12345):
        host = tow_sketches(elems, seed, ell=64)
        dev = np.asarray(
            tow_sketch(jnp.asarray(elems), jnp.asarray(tow_seeds(seed, 64)), ell=64)
        )
        np.testing.assert_array_equal(host, dev.astype(np.int64))


def test_tow_unbiased_and_variance():
    """E[d_hat] = d, Var[d_hat] = (2d^2 - 2d)/ell (paper App. A)."""
    rng = np.random.default_rng(0)
    d, ell, trials = 64, 32, 120
    ests = []
    for i in range(trials):
        a, b = make_pair(2000, d, rng)
        sa = tow_sketches(a, derive_seed(900, i), ell)
        sb = tow_sketches(b, derive_seed(900, i), ell)
        ests.append(estimate_d(sa, sb))
    mean = float(np.mean(ests))
    var = float(np.var(ests))
    exp_var = (2 * d * d - 2 * d) / ell
    se = np.sqrt(exp_var / trials)
    assert abs(mean - d) < 5 * se, (mean, d, se)
    assert 0.4 * exp_var < var < 2.2 * exp_var, (var, exp_var)


def test_gamma_inflation_covers():
    """Pr[d <= 1.38 * d_hat] >= 0.99 with ell = 128 (paper §6.2)."""
    rng = np.random.default_rng(1)
    d, trials, covered = 100, 60, 0
    for i in range(trials):
        a, b = make_pair(3000, d, rng)
        sa = tow_sketches(a, derive_seed(7, i))
        sb = tow_sketches(b, derive_seed(7, i))
        covered += d <= planned_d(estimate_d(sa, sb))
    assert covered >= trials - 2  # ~99% coverage, allow tiny slack


def test_transition_matrix_exact_isolation_prob():
    """M(i, 0) must equal the falling-factorial isolation probability."""
    n = 127
    M = markov.transition_matrix(n, 13)
    for i in [2, 5, 8, 13]:
        exact = np.prod([(n - k) / n for k in range(i)])
        assert abs(M[i, 0] - exact) < 1e-12


def test_transition_matrix_vs_monte_carlo():
    rng = np.random.default_rng(2)
    n, x, trials = 127, 6, 40000
    M = markov.transition_matrix(n, 13)
    counts = np.zeros(14)
    for _ in range(trials):
        bins = rng.integers(0, n, size=x)
        _, c = np.unique(bins, return_counts=True)
        counts[int(c[c > 1].sum())] += 1
    emp = counts / trials
    assert np.abs(emp - M[x, :14]).max() < 0.01


def _simulate_bad_ball_chain(rng, n: int, x: int, r: int) -> bool:
    """One App. E chain trajectory: throw the bad balls into n bins each
    round; balls sharing a bin stay bad.  True iff zero bad balls within r
    rounds — the event ``success_prob`` integrates analytically."""
    state = x
    for _ in range(r):
        if state == 0:
            return True
        bins = rng.integers(0, n, size=state)
        _, counts = np.unique(bins, return_counts=True)
        state = int(counts[counts > 1].sum())
    return state == 0


def test_success_prob_vs_monte_carlo():
    """Pr[x ⇝ 0 within r rounds] from the App. E dynamic program must match
    a seeded chain simulation for small (n, t, x, r)."""
    rng = np.random.default_rng(11)
    n, t, trials = 63, 5, 3000
    for x in (2, 4, 5):
        for r in (1, 2, 3):
            analytic = markov.success_prob(n, t, x, r)
            hits = sum(
                _simulate_bad_ball_chain(rng, n, x, r) for _ in range(trials)
            )
            mc = hits / trials
            se = np.sqrt(max(analytic * (1 - analytic), 1e-4) / trials)
            assert abs(mc - analytic) < max(4 * se, 0.02), (x, r, mc, analytic)


def test_alpha_and_overall_bound_vs_monte_carlo():
    """App. F's per-group success probability alpha (X ~ Binomial(d, 1/g),
    truncated at x > t) and the overall lower bound pinned by simulation."""
    rng = np.random.default_rng(13)
    n, t, d, g, r, trials = 63, 5, 12, 3, 2, 4000
    analytic = markov.alpha(n, t, d, g, r, convention="truncate")
    hits = 0
    for _ in range(trials):
        x = int(rng.binomial(d, 1.0 / g))
        if x > t:
            continue            # the paper's truncation: x > t counts failed
        hits += _simulate_bad_ball_chain(rng, n, x, r)
    mc = hits / trials
    se = np.sqrt(max(analytic * (1 - analytic), 1e-4) / trials)
    assert abs(mc - analytic) < max(4 * se, 0.02), (mc, analytic)
    # the bound is exactly 1 - 2(1 - alpha^g) of that alpha (App. F / [29])
    bound = markov.overall_lower_bound(n, t, d, g, r, convention="truncate")
    assert abs(bound - (1.0 - 2.0 * (1.0 - analytic**g))) < 1e-12
    # and the simulated alpha reproduces it to MC accuracy
    assert abs(bound - (1.0 - 2.0 * (1.0 - mc**g))) < 0.08


def test_success_prob_degenerate_and_truncation_conventions():
    """x = 0 is certain, x > t is impossible under the paper's convention,
    and one analytic cross-check: success within 1 round == isolation."""
    assert markov.success_prob(63, 5, 0, 3) == 1.0
    assert markov.success_prob(63, 5, 6, 3) == 0.0
    n, x = 63, 4
    iso = np.prod([(n - k) / n for k in range(x)])
    assert abs(markov.success_prob(n, 5, x, 1) - iso) < 1e-12


def test_paper_ideal_case_probability():
    """§1.3.1: d=5, n=255 -> ideal case prob 0.96."""
    p = np.prod([(255 - k) / 255 for k in range(5)])
    assert round(p, 2) == 0.96
    assert abs(markov.transition_matrix(255, 5)[5, 0] - p) < 1e-12


def test_round_fractions_match_paper():
    """§5.3: fractions 0.962 / 0.0380 / 3.61e-4 / 2.86e-6 at (127, 13)."""
    f = markov.expected_round_fractions(127, 13, 1000, 200)
    assert abs(f[0] - 0.962) < 2e-3
    assert abs(f[1] - 0.0380) < 2e-3
    assert abs(f[2] - 3.61e-4) < 5e-5
    assert abs(f[3] - 2.86e-6) < 5e-7


def test_table1_high_t_cells():
    """Table 1 cells where the x > t path is negligible match within ~1.5%."""
    for (n, t), paper in [((63, 17), 0.958), ((127, 17), 0.996), ((63, 16), 0.957)]:
        ours = markov.overall_lower_bound(n, t, 1000, 200, 3)
        assert abs(ours - paper) < 0.015, ((n, t), ours, paper)


def test_split_convention_bounds_sane():
    """Split model dominates truncate and both live in [−1, 1]."""
    for n, t in [(127, 10), (255, 8), (511, 13)]:
        lo = markov.overall_lower_bound(n, t, 1000, 200, 3, "truncate")
        hi = markov.overall_lower_bound(n, t, 1000, 200, 3, "split")
        assert -1.0 <= lo <= hi <= 1.0


def test_optimizer_feasible_and_bracket():
    """r=3 optimum lands in the paper's bracket; paper reports 318 bits."""
    n_s, t_s, lb_s, comm_s = markov.optimize_parameters(1000, 5, 3, 0.99, convention="split")
    n_t, t_t, lb_t, comm_t = markov.optimize_parameters(1000, 5, 3, 0.99, convention="truncate")
    assert lb_s >= 0.99 and lb_t >= 0.99
    assert comm_s <= 318 <= comm_t  # conventions bracket the paper's value


def test_empirical_success_rate_meets_p0():
    """The guarantee the optimizer promises must hold for the real protocol."""
    from repro.core.pbs import PBSConfig, reconcile, true_diff

    rng = np.random.default_rng(3)
    ok = 0
    trials = 25
    for i in range(trials):
        a, b = make_pair(5000, 100, rng)
        res = reconcile(a, b, PBSConfig(seed=i, max_rounds=3), d_known=100)
        ok += res.success and res.diff == true_diff(a, b)
    assert ok >= trials - 1  # p0 = 0.99 target; 25 trials
