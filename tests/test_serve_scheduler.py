"""Batch scheduler: bucketing, padding, done-masks, determinism."""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.optim import OptConfig
from repro.serve.scheduler import BatchScheduler, Request
from repro.train import init_train_state, make_train_step
pytestmark = pytest.mark.slow  # serve-scaffold tier: heavy decode sweeps, full-suite job only



@pytest.fixture(scope="module")
def served():
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    cfg = get_smoke_config("internlm2-1.8b")
    ocfg = OptConfig(warmup=2, total_steps=10)
    bundle = make_train_step(cfg, mesh, ocfg, batch=2)
    params, _ = init_train_state(bundle, cfg, mesh, ocfg)
    return cfg, mesh, params


def _reqs(cfg, lens, seed=0, max_new=6):
    rng = np.random.default_rng(seed)
    return [Request(rid=i, prompt=rng.integers(1, cfg.vocab, size=p).tolist(),
                    max_new=max_new) for i, p in enumerate(lens)]


def test_mixed_lengths_and_underfull_batches(served):
    cfg, mesh, params = served
    sched = BatchScheduler(cfg, mesh, batch=2, max_len=64, eos_id=-1)
    reqs = _reqs(cfg, [8, 16, 8, 16, 8])      # 2 buckets, one underfull each
    out, stats = sched.run(params, reqs)
    assert sorted(out) == [0, 1, 2, 3, 4]
    assert stats.batches == 3                  # ceil(3/2) + ceil(2/2)
    for r in reqs:
        assert len(out[r.rid].tokens) == r.max_new  # eos_id=-1 never fires
        assert all(0 <= t < cfg.vocab for t in out[r.rid].tokens)


def test_same_prompt_same_completion(served):
    """Identical prompts in different batch slots decode identically."""
    cfg, mesh, params = served
    sched = BatchScheduler(cfg, mesh, batch=2, max_len=64, eos_id=-1)
    rng = np.random.default_rng(3)
    p = rng.integers(1, cfg.vocab, size=8).tolist()
    reqs = [Request(0, p, 5), Request(1, p, 5), Request(2, p, 5)]
    out, _ = sched.run(params, reqs)
    assert out[0].tokens == out[1].tokens == out[2].tokens


def test_max_new_respected_and_eos_stops(served):
    cfg, mesh, params = served
    reqs = _reqs(cfg, [8, 8], max_new=3)
    sched = BatchScheduler(cfg, mesh, batch=2, max_len=64, eos_id=-1)
    out, _ = sched.run(params, reqs)
    assert all(len(c.tokens) == 3 for c in out.values())
    # pick the actual first decode token as "EOS": completion stops at len 1
    first = out[0].tokens[0]
    sched2 = BatchScheduler(cfg, mesh, batch=2, max_len=64, eos_id=first)
    out2, _ = sched2.run(params, [reqs[0]])
    assert out2[0].tokens[0] == first and out2[0].finished
    assert len(out2[0].tokens) <= 3


def test_prompt_too_long_raises(served):
    cfg, mesh, params = served
    sched = BatchScheduler(cfg, mesh, batch=2, max_len=16, eos_id=0)
    with pytest.raises(ValueError):
        sched.run(params, _reqs(cfg, [16]))
