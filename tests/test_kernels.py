"""Pallas kernel validation: shape/dtype sweeps vs pure-numpy oracles
(interpret mode executes the kernel body on CPU)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.bch import BCHCode, batched_decode, sketch_from_positions
from repro.kernels import ref
from repro.kernels.bin_xorsum import bin_parity_xorsum, xor_bits_to_u32
from repro.kernels.gf2_matmul import gf2_matmul
from repro.kernels.ops import (
    bch_decode_batched,
    chien_eval_matmul,
    encode_group,
    pack_bits_to_field,
    sketch_groups,
    tow_estimate,
)
from repro.kernels.tow_sketch import tow_sketch


@pytest.mark.parametrize(
    "m,k,n",
    [
        (1, 127, 91),       # single bitmap x syndrome matrix
        (8, 255, 88),       # group batch
        (17, 511, 153),
        (64, 1023, 110),
        (3, 2047, 187),
        (130, 300, 260),    # non-power-of-two everything
        (5, 64, 640),
    ],
)
def test_gf2_matmul_sweep(m, k, n):
    rng = np.random.default_rng(m * 1000 + n)
    a = rng.integers(0, 2, (m, k)).astype(np.int32)
    b = rng.integers(0, 2, (k, n)).astype(np.int32)
    out = np.array(gf2_matmul(jnp.array(a), jnp.array(b)))
    np.testing.assert_array_equal(out, ref.gf2_matmul_ref(a, b))


@pytest.mark.parametrize("bm,bn,bk", [(8, 128, 128), (64, 256, 256), (128, 128, 512)])
def test_gf2_matmul_block_shapes(bm, bn, bk):
    rng = np.random.default_rng(bm)
    a = rng.integers(0, 2, (100, 700)).astype(np.int32)
    b = rng.integers(0, 2, (700, 200)).astype(np.int32)
    out = np.array(gf2_matmul(jnp.array(a), jnp.array(b), bm=bm, bn=bn, bk=bk))
    np.testing.assert_array_equal(out, ref.gf2_matmul_ref(a, b))


@pytest.mark.parametrize("n_bins", [63, 127, 255, 1023])
@pytest.mark.parametrize("n_elems", [1, 100, 1000, 5000])
def test_bin_parity_xorsum_sweep(n_bins, n_elems):
    rng = np.random.default_rng(n_bins + n_elems)
    elems = rng.integers(1, 1 << 32, size=n_elems, dtype=np.uint64).astype(np.uint32)
    parity, xor_bits = bin_parity_xorsum(jnp.array(elems), n_bins=n_bins, seed=42)
    p_ref, xb_ref, xors_ref = ref.bin_parity_xorsum_ref(elems, n_bins, 42)
    np.testing.assert_array_equal(np.array(parity), p_ref)
    np.testing.assert_array_equal(np.array(xor_bits), xb_ref)
    np.testing.assert_array_equal(np.array(xor_bits_to_u32(xor_bits)), xors_ref)


@pytest.mark.parametrize("tile", [256, 1024])
def test_bin_xorsum_tile_invariance(tile):
    rng = np.random.default_rng(0)
    elems = rng.integers(1, 1 << 32, size=3000, dtype=np.uint64).astype(np.uint32)
    p1, x1 = bin_parity_xorsum(jnp.array(elems), n_bins=127, seed=7, tile=tile)
    p_ref, xb_ref, _ = ref.bin_parity_xorsum_ref(elems, 127, 7)
    np.testing.assert_array_equal(np.array(p1), p_ref)
    np.testing.assert_array_equal(np.array(x1), xb_ref)


@pytest.mark.parametrize("ell", [32, 128])
@pytest.mark.parametrize("n_elems", [5, 2048, 7001])
def test_tow_sketch_sweep(ell, n_elems):
    rng = np.random.default_rng(ell + n_elems)
    elems = rng.integers(1, 1 << 32, size=n_elems, dtype=np.uint64).astype(np.uint32)
    seeds = rng.integers(0, 1 << 32, size=ell, dtype=np.uint64).astype(np.uint32)
    out = np.array(tow_sketch(jnp.array(elems), jnp.array(seeds), ell=ell))
    np.testing.assert_array_equal(out, ref.tow_sketch_ref(elems, seeds))


def test_tow_kernel_variance_contract():
    """The kernel's hash family must honour the (2d^2-2d)/ell variance bound
    the paper's analysis needs (empirical check, ~1.5x tolerance)."""
    rng = np.random.default_rng(5)
    d, ell, trials = 64, 64, 50
    ests = []
    for i in range(trials):
        uni = rng.integers(1, 1 << 32, size=3000, dtype=np.uint64).astype(np.uint32)
        uni = np.unique(uni)[: 2 * d]
        a, b = uni[:d], uni[d:]
        seeds = rng.integers(0, 1 << 32, size=ell, dtype=np.uint64).astype(np.uint32)
        est = tow_estimate(jnp.array(a), jnp.array(b), jnp.array(seeds))
        ests.append(float(est))
    mean, var = float(np.mean(ests)), float(np.var(ests))
    exp_var = (2 * (2 * d) ** 2 - 2 * (2 * d)) / ell  # diff = 2d here
    assert abs(mean - 2 * d) < 6 * np.sqrt(exp_var / trials)
    assert var < 2.5 * exp_var


@pytest.mark.parametrize("n,t", [(63, 8), (127, 13), (255, 9)])
def test_sketch_groups_matches_core(n, t):
    code = BCHCode(n, t)
    rng = np.random.default_rng(n)
    bitmaps, expected = [], []
    for _ in range(9):
        pos = rng.choice(n, size=int(rng.integers(0, t + 1)), replace=False)
        bm = np.zeros(n, dtype=np.int32)
        bm[pos] = 1
        bitmaps.append(bm)
        expected.append(sketch_from_positions(code, pos))
    out = np.array(sketch_groups(jnp.array(np.stack(bitmaps)), code))
    np.testing.assert_array_equal(out, np.stack(expected))


@pytest.mark.parametrize("n,t", [(63, 8), (127, 13), (255, 9)])
def test_bch_decode_batched_matches_numpy(n, t):
    code = BCHCode(n, t)
    rng = np.random.default_rng(t)
    sketches = []
    for _ in range(32):
        d = int(rng.integers(0, t + 4))  # include overload rows
        pos = rng.choice(n, size=d, replace=False)
        sketches.append(sketch_from_positions(code, pos))
    sk = np.stack(sketches)
    ok_np, pos_np = batched_decode(code, sk)
    ok_j, pos_j, cnt_j = jax.device_get(bch_decode_batched(jnp.array(sk), n=n, t=t))
    np.testing.assert_array_equal(np.array(ok_j), ok_np)
    for i in range(len(sk)):
        got = set(int(p) for p in pos_j[i] if p >= 0)
        assert got == set(pos_np[i].tolist()), i


def test_encode_group_end_to_end():
    code = BCHCode(127, 9)
    rng = np.random.default_rng(1)
    elems = rng.integers(1, 1 << 32, size=500, dtype=np.uint64).astype(np.uint32)
    parity, xors, sketch = encode_group(jnp.array(elems), code, seed=3)
    p_ref, _, xors_ref = ref.bin_parity_xorsum_ref(elems, 127, 3)
    np.testing.assert_array_equal(np.array(parity), p_ref)
    np.testing.assert_array_equal(np.array(xors), xors_ref)
    exp_sketch = sketch_from_positions(code, np.nonzero(p_ref)[0])
    np.testing.assert_array_equal(np.array(sketch), exp_sketch)


def test_chien_matmul_finds_roots():
    code = BCHCode(127, 7)
    gf = code.field
    rng = np.random.default_rng(2)
    pos = rng.choice(127, size=5, replace=False)
    # Lambda(x) = prod (1 - alpha^p x) has roots alpha^{-p}
    lam = np.zeros(8, dtype=np.int64)
    lam[0] = 1
    for p in pos:
        nxt = lam.copy()
        nxt[1:] ^= gf.mul(lam[:-1], gf.pow_alpha(p))
        lam = nxt
    bits = gf.to_bits(lam).reshape(-1)
    ev = np.array(chien_eval_matmul(jnp.array(bits[None, :]), code))
    roots = np.nonzero(~ev[0].any(axis=1))[0]
    assert set(roots.tolist()) == set(pos.tolist())


def test_kernel_pipeline_vs_protocol_roundtrip():
    """Kernel encode on both sides -> XOR sketches -> JAX decode -> bins match."""
    code = BCHCode(255, 11)
    rng = np.random.default_rng(3)
    base = np.unique(rng.integers(1, 1 << 32, size=4000, dtype=np.uint64).astype(np.uint32))
    a, b = base, base[:-6]  # 6 distinct elements
    pa, xa, ska = encode_group(jnp.array(a), code, seed=11)
    pb, xb, skb = encode_group(jnp.array(b), code, seed=11)
    ok, pos, cnt = jax.device_get(
        bch_decode_batched((ska ^ skb)[None, :], n=255, t=11)
    )
    assert bool(ok[0])
    recovered = set()
    xa_np, xb_np = np.array(xa), np.array(xb)
    for p in pos[0][: int(cnt[0])]:
        s = int(xa_np[p] ^ xb_np[p])
        recovered.add(s)
    diff = set(int(x) for x in a) ^ set(int(x) for x in b)
    # all-singleton bins recover exactly; collisions (rare at n=255,d=6) tolerated
    assert len(recovered & diff) >= 4
