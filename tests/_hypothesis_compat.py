"""Optional-dependency shim for hypothesis (the ``[test]`` extra).

Test modules import ``given``/``settings``/``st`` from here instead of from
``hypothesis`` directly.  When hypothesis is installed the real objects are
re-exported unchanged; when it is missing, property-based tests collect as
clean skips (instead of failing module collection) while every plain pytest
test in the same module keeps running.

The ``given`` stub replaces the decorated function with a zero-argument
skipper so pytest never tries to resolve the strategy keywords as fixtures.
"""
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:  # optional dependency: pip install -e .[test]
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            def _skipped():
                pytest.skip("hypothesis not installed (pip install -e .[test])")

            _skipped.__name__ = fn.__name__
            _skipped.__doc__ = fn.__doc__
            return _skipped

        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _Strategies:
        """Placeholder strategy factory: every attribute returns an inert stub."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _Strategies()

__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]
