"""Batched wire codecs vs the scalar bit-loop codecs, byte for byte.

PR 6 rewrote the ``repro.wire`` frame codecs to bit-pack/unpack whole
frames in numpy passes (DESIGN.md §12); the original per-bit
``BitWriter``/``BitReader`` implementations are kept as ``*_scalar``
oracles.  This suite asserts the two are interchangeable:

* on valid frames, batched and scalar encoders emit **identical bytes**
  and both decoders return identical structures (cross-decoding included:
  batched decodes scalar output and vice versa);
* on adversarial frames — truncations, nonzero padding, trailing bytes,
  out-of-range counts/positions — both raise ``WireError``;
* the envelopes (MSG_MUX, MSG_EPOCH) carry batched-encoded frames
  unchanged through ``encode_mux``/``decode_mux`` and
  ``encode_epoch``/``decode_epoch``.
"""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.wire import frames as wf
from repro.wire.frames import ReplyUnit, WireError


def _payload(buf: bytes) -> bytes:
    msg_type, payload, end = wf.split_frame(buf)
    assert end == len(buf)
    return payload


def _rand_schema(rng, max_sessions=4):
    schema = []
    for _ in range(int(rng.integers(1, max_sessions + 1))):
        m = int(rng.integers(3, 11))
        t = int(rng.integers(1, 9))
        n_units = int(rng.integers(0, 7))
        schema.append((n_units, t, m))
    return schema


def _rand_reply_entries(rng, schema):
    entries = []
    for n_units, t, m in schema:
        n = (1 << m) - 1
        ok = [bool(rng.integers(2)) for _ in range(n_units)]
        units = []
        for flag in ok:
            if not flag:
                units.append(None)
                continue
            k = int(rng.integers(0, t + 1))
            units.append(ReplyUnit(
                positions=rng.integers(0, n, size=k).astype(np.int64),
                xors=rng.integers(0, 1 << 32, size=k, dtype=np.uint64).astype(np.uint32),
                csum=int(rng.integers(0, 1 << 32)),
            ))
        entries.append((ok, units))
    return entries


# ---------------------------------------------------------------------------
# Valid-frame differentials
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(6))
def test_tow_sketch_differential(seed):
    rng = np.random.default_rng(seed)
    set_size = int(rng.integers(1, 100_000))
    ell = int(rng.integers(0, 200))
    vals = rng.integers(-set_size, set_size + 1, size=ell).astype(np.int64)
    fb = wf.encode_tow_sketch(vals, set_size)
    fs = wf.encode_tow_sketch_scalar(vals, set_size)
    assert fb == fs
    for decoder in (wf.decode_tow_sketch, wf.decode_tow_sketch_scalar):
        got_size, got = decoder(_payload(fb))
        assert got_size == set_size
        assert got.dtype == np.int64 and np.array_equal(got, vals)


@pytest.mark.parametrize("seed", range(6))
def test_round_sketches_differential(seed):
    rng = np.random.default_rng(100 + seed)
    schema = _rand_schema(rng)
    blocks = [
        (rng.integers(0, 1 << m, size=(n_units, t)).astype(np.int64), m)
        for n_units, t, m in schema
    ]
    rnd = int(rng.integers(0, 50))
    fb = wf.encode_round_sketches(rnd, blocks)
    fs = wf.encode_round_sketches_scalar(rnd, blocks)
    assert fb == fs
    for decoder in (wf.decode_round_sketches, wf.decode_round_sketches_scalar):
        got_rnd, got = decoder(_payload(fb), schema)
        assert got_rnd == rnd
        assert len(got) == len(blocks)
        for g, (sk, _) in zip(got, blocks):
            assert g.dtype == np.int64 and np.array_equal(g, sk)


@pytest.mark.parametrize("seed", range(8))
def test_round_reply_differential(seed):
    rng = np.random.default_rng(200 + seed)
    schema = _rand_schema(rng)
    entries = _rand_reply_entries(rng, schema)
    rnd = int(rng.integers(0, 50))
    fb = wf.encode_round_reply(rnd, entries, schema)
    fs = wf.encode_round_reply_scalar(rnd, entries, schema)
    assert fb == fs
    for decoder in (wf.decode_round_reply, wf.decode_round_reply_scalar):
        got_rnd, got = decoder(_payload(fb), schema)
        assert got_rnd == rnd
        for (gok, gunits), (ok, units) in zip(got, entries):
            assert gok.dtype == bool and list(gok) == ok
            for gu, u in zip(gunits, units):
                assert gu == u  # ReplyUnit __eq__ covers None too


@pytest.mark.parametrize("seed", range(4))
def test_round_outcome_differential(seed):
    rng = np.random.default_rng(300 + seed)
    counts = [int(rng.integers(0, 9)) for _ in range(int(rng.integers(1, 5)))]
    done = [rng.integers(0, 2, size=c).astype(bool) for c in counts]
    rnd = int(rng.integers(0, 50))
    fb = wf.encode_round_outcome(rnd, done)
    fs = wf.encode_round_outcome_scalar(rnd, done)
    assert fb == fs
    for decoder in (wf.decode_round_outcome, wf.decode_round_outcome_scalar):
        got_rnd, got = decoder(_payload(fb), counts)
        assert got_rnd == rnd
        assert all(np.array_equal(g, d) for g, d in zip(got, done))


@pytest.mark.parametrize("seed", range(4))
def test_verify_and_ack_differential(seed):
    rng = np.random.default_rng(400 + seed)
    n = int(rng.integers(0, 10))
    entries = [
        (bool(rng.integers(2)), int(rng.integers(0, 1 << 32))) for _ in range(n)
    ]
    fb = wf.encode_verify(entries)
    assert fb == wf.encode_verify_scalar(entries)
    assert wf.decode_verify(_payload(fb), n) == entries
    assert wf.decode_verify_scalar(_payload(fb), n) == entries

    flags = [bool(rng.integers(2)) for _ in range(n)]
    ab = wf.encode_verify_ack(flags)
    assert ab == wf.encode_verify_ack_scalar(flags)
    assert wf.decode_verify_ack(_payload(ab), n) == flags
    assert wf.decode_verify_ack_scalar(_payload(ab), n) == flags


def test_empty_frames_differential():
    """Zero sessions / zero units: batched and scalar agree on the
    degenerate frames too."""
    assert wf.encode_tow_sketch([], 10) == wf.encode_tow_sketch_scalar([], 10)
    assert wf.encode_round_sketches(1, []) == wf.encode_round_sketches_scalar(1, [])
    assert wf.encode_round_reply(1, [], []) == wf.encode_round_reply_scalar(1, [], [])
    assert wf.encode_round_outcome(1, []) == wf.encode_round_outcome_scalar(1, [])
    assert wf.encode_verify([]) == wf.encode_verify_scalar([])
    assert wf.encode_verify_ack([]) == wf.encode_verify_ack_scalar([])
    # all-units-failed reply: ok bits only, no bodies
    schema = [(3, 5, 7)]
    entries = [([False, False, False], [None, None, None])]
    fb = wf.encode_round_reply(2, entries, schema)
    assert fb == wf.encode_round_reply_scalar(2, entries, schema)
    _, got = wf.decode_round_reply(_payload(fb), schema)
    assert list(got[0][0]) == [False, False, False]
    assert got[0][1] == [None, None, None]


# ---------------------------------------------------------------------------
# Adversarial frames: both codecs must reject
# ---------------------------------------------------------------------------


def _reply_case(seed):
    rng = np.random.default_rng(seed)
    schema = [(4, 6, 8), (2, 3, 5)]
    entries = _rand_reply_entries(rng, schema)
    return schema, _payload(wf.encode_round_reply(3, entries, schema))


@pytest.mark.parametrize("seed", range(4))
def test_reply_truncation_rejected_by_both(seed):
    schema, payload = _reply_case(500 + seed)
    for cut in range(1, len(payload)):
        bad = payload[:cut]
        # either codec may classify differently at pathological cuts, but
        # both MUST reject with the WireError family
        with pytest.raises(WireError):
            wf.decode_round_reply(bad, schema)
        with pytest.raises(WireError):
            wf.decode_round_reply_scalar(bad, schema)


@pytest.mark.parametrize("seed", range(4))
def test_reply_trailing_and_padding_rejected_by_both(seed):
    schema, payload = _reply_case(600 + seed)
    for bad in (payload + b"\x00", payload + b"\xff\x01"):
        with pytest.raises(WireError):
            wf.decode_round_reply(bad, schema)
        with pytest.raises(WireError):
            wf.decode_round_reply_scalar(bad, schema)


@pytest.mark.parametrize("seed", range(6))
def test_reply_random_bitflips_agree(seed):
    """Random single-byte corruptions: the codecs must agree on accept vs
    reject, and on the decoded structure whenever both accept."""
    schema, payload = _reply_case(700 + seed)
    rng = np.random.default_rng(seed)
    for _ in range(40):
        pos = int(rng.integers(0, len(payload)))
        bad = bytearray(payload)
        bad[pos] ^= 1 << int(rng.integers(0, 8))
        bad = bytes(bad)
        try:
            got_b = wf.decode_round_reply(bad, schema)
            ok_b = True
        except WireError:
            ok_b = False
        try:
            got_s = wf.decode_round_reply_scalar(bad, schema)
            ok_s = True
        except WireError:
            ok_s = False
        assert ok_b == ok_s, (pos, bad.hex())
        if ok_b:
            for (gok, gunits), (sok, sunits) in zip(got_b[1], got_s[1]):
                assert np.array_equal(gok, sok)
                assert gunits == sunits


def test_tow_out_of_range_value_rejected_by_both():
    # value 2*set_size + 1 fits the bit width but exceeds the declared range
    set_size = 100
    bits = wf.tow_value_bits(set_size)
    good = _payload(wf.encode_tow_sketch([0], set_size))
    from repro.wire.varint import BitWriter, encode_uvarint

    w = BitWriter()
    w.write(2 * set_size + 1, bits)
    bad = encode_uvarint(set_size) + encode_uvarint(1) + w.getvalue()
    assert wf.decode_tow_sketch(good) == wf.decode_tow_sketch_scalar(good)
    with pytest.raises(WireError):
        wf.decode_tow_sketch(bad)
    with pytest.raises(WireError):
        wf.decode_tow_sketch_scalar(bad)


def test_reply_count_exceeding_t_rejected_by_both():
    schema = [(1, 3, 6)]  # cbits = 2, so count 3 is encodable but k <= 3 ok;
    # craft count field = 3 with only 2 entries present -> truncated, and
    # a full body claiming k=3 with t lowered to 2 at decode -> count error
    rng = np.random.default_rng(0)
    entries = [([True], [ReplyUnit(
        positions=rng.integers(0, 62, size=3).astype(np.int64),
        xors=rng.integers(0, 1 << 32, size=3, dtype=np.uint64).astype(np.uint32),
        csum=7,
    )])]
    payload = _payload(wf.encode_round_reply(1, entries, schema))
    tight = [(1, 2, 6)]  # same cbits (2 bits), smaller t
    with pytest.raises(WireError):
        wf.decode_round_reply(payload, tight)
    with pytest.raises(WireError):
        wf.decode_round_reply_scalar(payload, tight)


# ---------------------------------------------------------------------------
# Envelopes: batched frames ride MSG_MUX / MSG_EPOCH unchanged
# ---------------------------------------------------------------------------


def test_mux_envelope_carries_batched_frames():
    rng = np.random.default_rng(11)
    schema = _rand_schema(rng)
    entries = _rand_reply_entries(rng, schema)
    inner = wf.encode_round_reply(5, entries, schema)
    assert inner == wf.encode_round_reply_scalar(5, entries, schema)
    wrapped = wf.encode_mux(9, inner)
    ch, msg_type, payload = wf.decode_mux(_payload(wrapped))
    assert (ch, msg_type) == (9, wf.MSG_ROUND_REPLY)
    got_rnd, got = wf.decode_round_reply(payload, schema)
    _, exp = wf.decode_round_reply_scalar(payload, schema)
    assert got_rnd == 5
    for (gok, gunits), (sok, sunits) in zip(got, exp):
        assert np.array_equal(gok, sok) and gunits == sunits
    # adversarial: truncated inner frame inside the envelope
    with pytest.raises(WireError):
        wf.decode_mux(_payload(wf.encode_mux(9, inner))[:-1] )


def test_epoch_envelope_carries_batched_tow():
    vals = np.arange(-8, 9, dtype=np.int64)
    inner = wf.encode_tow_sketch(vals, 64)
    assert inner == wf.encode_tow_sketch_scalar(vals, 64)
    wrapped = wf.encode_epoch(3, inner)
    epoch, msg_type, payload = wf.decode_epoch(_payload(wrapped))
    assert (epoch, msg_type) == (3, wf.MSG_TOW_SKETCH)
    for decoder in (wf.decode_tow_sketch, wf.decode_tow_sketch_scalar):
        size, got = decoder(payload)
        assert size == 64 and np.array_equal(got, vals)
    # nested envelope must be rejected
    with pytest.raises(WireError):
        wf.decode_epoch(_payload(wf.encode_epoch(3, wrapped)))


# ---------------------------------------------------------------------------
# Hypothesis forms (engage with the [test] extra installed)
# ---------------------------------------------------------------------------


@given(
    set_size=st.integers(min_value=1, max_value=1 << 20),
    seed=st.integers(min_value=0, max_value=1 << 16),
    ell=st.integers(min_value=0, max_value=256),
)
@settings(max_examples=30, deadline=None)
def test_hypothesis_tow_differential(set_size, seed, ell):
    rng = np.random.default_rng(seed)
    vals = rng.integers(-set_size, set_size + 1, size=ell).astype(np.int64)
    fb = wf.encode_tow_sketch(vals, set_size)
    assert fb == wf.encode_tow_sketch_scalar(vals, set_size)
    size_b, got_b = wf.decode_tow_sketch(_payload(fb))
    size_s, got_s = wf.decode_tow_sketch_scalar(_payload(fb))
    assert size_b == size_s == set_size
    assert np.array_equal(got_b, vals) and np.array_equal(got_s, vals)


@given(seed=st.integers(min_value=0, max_value=1 << 16))
@settings(max_examples=30, deadline=None)
def test_hypothesis_reply_differential(seed):
    rng = np.random.default_rng(seed)
    schema = _rand_schema(rng)
    entries = _rand_reply_entries(rng, schema)
    fb = wf.encode_round_reply(7, entries, schema)
    assert fb == wf.encode_round_reply_scalar(7, entries, schema)
    got_b = wf.decode_round_reply(_payload(fb), schema)
    got_s = wf.decode_round_reply_scalar(_payload(fb), schema)
    assert got_b[0] == got_s[0] == 7
    for (gok, gunits), (sok, sunits) in zip(got_b[1], got_s[1]):
        assert np.array_equal(gok, sok) and gunits == sunits
