"""End-to-end protocol tests: correctness, multi-round behaviour, exceptions,
communication accounting, estimator integration, and hypothesis properties."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.pbs import PBSConfig, checksum, reconcile, reconcile_small, true_diff
from repro.core.simdata import make_pair, make_pair_two_sided


def test_trivial_d0_and_d1():
    rng = np.random.default_rng(0)
    a, b = make_pair(500, 0, rng)
    res = reconcile_small(a, b, 63, 3, seed=1)
    assert res.success and res.diff == set()
    a, b = make_pair(500, 1, rng)
    res = reconcile_small(a, b, 63, 3, seed=1)
    assert res.success and res.diff == true_diff(a, b)


@pytest.mark.parametrize("d", [2, 5, 9])
def test_small_d(d):
    rng = np.random.default_rng(d)
    a, b = make_pair(3000, d, rng)
    res = reconcile_small(a, b, 255, 13, seed=5)
    assert res.success and res.diff == true_diff(a, b)


@pytest.mark.parametrize("d", [10, 100, 1000])
def test_large_d_known(d):
    rng = np.random.default_rng(d)
    a, b = make_pair(50000, d, rng)
    res = reconcile(a, b, PBSConfig(seed=3), d_known=d)
    assert res.success
    assert res.diff == true_diff(a, b)
    assert res.rounds <= 4


def test_two_sided_difference():
    rng = np.random.default_rng(11)
    a, b = make_pair_two_sided(20000, 60, 40, rng)
    res = reconcile(a, b, PBSConfig(seed=2), d_known=100)
    assert res.success and res.diff == true_diff(a, b)


def test_estimator_path():
    rng = np.random.default_rng(21)
    a, b = make_pair(20000, 200, rng)
    res = reconcile(a, b, PBSConfig(seed=8))
    assert res.success and res.diff == true_diff(a, b)
    assert res.estimator_bytes > 0
    # ToW with ell=128: d_est should be within ~4 sigma of the truth
    assert abs(res.d_est - 200) < 200


def test_identical_sets():
    rng = np.random.default_rng(5)
    a, _ = make_pair(10000, 0, rng)
    res = reconcile(a, a.copy(), PBSConfig(seed=1), d_known=10)
    assert res.success and res.diff == set() and res.rounds == 1


def test_comm_accounting_matches_formula():
    """Round-1 A->B traffic must be exactly g * (t*m + 1) bits (sketch+flag)."""
    rng = np.random.default_rng(9)
    a, b = make_pair(30000, 500, rng)
    cfg = PBSConfig(seed=4, n_override=127, t_override=13)
    res = reconcile(a, b, cfg, d_known=500)
    assert res.success
    g, t, m = res.g, 13, 7
    # first round total: sketches + per-found (m + 32) + per-unit checksum 32
    d_found_bits = sum(len_pos * (m + 32) for len_pos in [])  # accounted inside
    lower = g * (t * m + 1)  # at least the sketches
    assert res.bytes_per_round[0] * 8 >= lower
    # communication is within the paper's ~2-3x of minimum for this regime
    assert res.bytes_sent * 8 < 6 * 500 * 32


def test_multiround_uses_fresh_hashes():
    """Force tiny n so collisions are common: must still converge by re-hashing."""
    rng = np.random.default_rng(13)
    a, b = make_pair(2000, 8, rng)
    res = reconcile_small(a, b, 63, 12, seed=3, max_rounds=12)
    assert res.success and res.diff == true_diff(a, b)


def test_decode_failure_splits():
    """d far above t in one group triggers BCH failure + 3-way split recovery."""
    rng = np.random.default_rng(17)
    a, b = make_pair(5000, 40, rng)
    cfg = PBSConfig(seed=6, n_override=255, t_override=8, g_override=1, max_rounds=12)
    res = reconcile(a, b, cfg, d_known=40)
    assert res.decode_failures >= 1
    assert res.success and res.diff == true_diff(a, b)


def test_checksum():
    assert checksum(np.array([1, 2, 3], dtype=np.uint32)) == 6
    assert checksum(np.array([0xFFFFFFFF, 1], dtype=np.uint32)) == 0
    assert checksum(np.zeros(0, dtype=np.uint32)) == 0


@given(
    d=st.integers(min_value=0, max_value=60),
    seed=st.integers(min_value=0, max_value=2**20),
)
@settings(max_examples=15, deadline=None)
def test_reconcile_property(d, seed):
    """Invariant: PBS always terminates with the exact symmetric difference."""
    rng = np.random.default_rng(seed)
    a, b = make_pair(4000, d, rng)
    res = reconcile(a, b, PBSConfig(seed=seed % 97), d_known=max(d, 1))
    assert res.success
    assert res.diff == true_diff(a, b)
