"""Differential conformance for the tree-partitioned front end (§15).

The ISSUE 9 acceptance harness: the recursive range-partition front end for
unknown/adversarial d must be a *pure router* — every divergent leaf range
it hands to PBS reconciles byte-identically to a standalone
``core.pbs.reconcile`` session over that range with the tree's planned d,
and the union of leaf diffs equals ``true_diff`` over the whole pair — and
the walk itself must obey its analytic contracts:

* the batched ``tree_digest`` kernel sweep matches the pure-host oracle
  (``level_digests_ref``) count-for-count, checksum-for-checksum,
  sketch-for-sketch;
* the walk terminates with depth within the analytic bound — globally
  ``KEY_BITS - floor(log2(leaf_d))`` (halving a range also halves its
  element count ceiling, so the leaf clamp must fire by then) and
  ``~log2(gamma * d / leaf_d)`` for uniformly spread difference;
* one kernel launch per level (both sides stacked), and a re-walk over the
  same pow2 buckets retraces nothing;
* the wire flow (``submit_tree`` endpoints, hub tree phase, continuous
  cold-start epochs) ships exactly the framed ``MSG_TREE`` bytes the
  in-process ``partition_pair`` ledgers, and lands in the same leaves;
* the phase-0 estimator refuses pairs outside its operating regime with a
  typed ``EstimateOutOfRange`` (``error_kind="estimate"``) instead of
  silently under-planning — the regression that motivates the tree.

Seeded variants always run; hypothesis variants skip cleanly without the
``[test]`` extra.  The adversarial multi-epoch hub soak (one cold-start
tree joiner per epoch) is marked ``slow`` for CI's non-blocking job.
"""
import math
import threading

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.pbs import PBSConfig, reconcile, true_diff
from repro.core.simdata import make_pair
from repro.core.tow import (
    ESTIMATE_LIMIT_FRAC,
    EstimateOutOfRange,
    check_estimate,
)
from repro.net import (
    AliceEndpoint,
    BobEndpoint,
    HubEndpoint,
    InMemoryDuplex,
    TransportError,
    classify_error,
    run_hub,
    run_pair,
    run_pair_epoch,
)
from repro.tree import (
    SPAN,
    TreeConfig,
    leaf_slices,
    level_digests,
    level_digests_ref,
    partition_pair,
    tree_reconcile,
)
from repro.wire.frames import KEY_BITS

_EMPTY = np.zeros(0, dtype=np.uint32)


# ---------------------------------------------------------------------------
# generators: the adversarial shape zoo
# ---------------------------------------------------------------------------


def _uniq(x):
    return np.unique(np.asarray(x, dtype=np.uint32))


def _shape_pair(shape: str, rng: np.random.Generator):
    """One (a, b) pair per adversarial shape; keys are uint32."""
    if shape == "disjoint":
        univ = rng.choice(1 << 32, size=520, replace=False).astype(np.uint32)
        return _uniq(univ[:260]), _uniq(univ[260:])
    if shape == "identical":
        a = _uniq(rng.choice(1 << 32, size=500, replace=False))
        return a, a.copy()
    if shape == "near_total":
        # d close to |A|: tiny overlap, estimator regime hopeless
        univ = rng.choice(1 << 32, size=700, replace=False).astype(np.uint32)
        return _uniq(univ[:380]), _uniq(univ[330:])
    if shape == "skewed":
        # the whole key population inside one narrow 2^16-wide band
        lo = int(rng.integers(0, (1 << 32) - (1 << 16)))
        band = lo + rng.choice(1 << 16, size=700, replace=False)
        a = band[:640].astype(np.uint32)
        b = np.concatenate([band[60:640], band[640:]]).astype(np.uint32)
        return _uniq(a), _uniq(b)
    if shape == "clustered":
        # adversarial clustering: shared keys uniform, ALL difference
        # packed into one 2^12-wide window — the worst case for a
        # fixed-split partition
        shared = rng.choice(1 << 32, size=600, replace=False).astype(np.uint64)
        lo = int(rng.integers(0, (1 << 32) - (1 << 12)))
        hot = lo + rng.choice(1 << 12, size=90, replace=False)
        a = np.concatenate([shared, hot[:45].astype(np.uint64)])
        b = np.concatenate([shared, hot[45:].astype(np.uint64)])
        return _uniq(a), _uniq(b)
    raise AssertionError(shape)


_SHAPES = ["disjoint", "identical", "near_total", "skewed", "clustered"]


def _assert_leaf_oracle(tr, a, b, cfg):
    """Every leaf session byte-identical to a standalone PBS session over
    that range at the tree's planned d (the router contract)."""
    a, b = _uniq(a), _uniq(b)
    subs_a = leaf_slices(a, tr.leaves)
    subs_b = leaf_slices(b, tr.leaves)
    assert set(tr.results) == set(range(len(tr.leaves)))
    for sid, (a_sub, b_sub, leaf) in enumerate(
        zip(subs_a, subs_b, tr.leaves)
    ):
        exp = reconcile(a_sub, b_sub, cfg, d_known=leaf.d_plan)
        got = tr.results[sid]
        assert got.diff == exp.diff == true_diff(a_sub, b_sub), sid
        assert got.bytes_per_round == exp.bytes_per_round, sid
        assert got.bytes_sent == exp.bytes_sent, sid
        assert got.estimator_bytes == exp.estimator_bytes == 0, sid
        assert got.rounds == exp.rounds, sid
        assert got.success == exp.success, sid


# ---------------------------------------------------------------------------
# kernel sweep vs pure-host oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", [0, 7])
def test_level_digests_match_host_oracle(seed):
    rng = np.random.default_rng(seed)
    elems = _uniq(rng.choice(1 << 32, size=800, replace=False))
    tcfg = TreeConfig(seed=seed)
    quarter = SPAN // 4
    frontiers = [
        [(0, SPAN)],
        [(i * quarter, (i + 1) * quarter) for i in range(4)],
        # includes ranges that hold no elements at all (zero sketch bits)
        [(i * (SPAN // 16), (i + 1) * (SPAN // 16)) for i in range(0, 16, 2)],
    ]
    for frontier in frontiers[:2]:       # these two tile the whole space
        cnt, _, _ = level_digests(elems, frontier, tcfg)
        assert int(cnt.sum()) == len(elems)
    for frontier in frontiers:
        cnt, cs, sk = level_digests(elems, frontier, tcfg)
        cnt_r, cs_r, sk_r = level_digests_ref(elems, frontier, tcfg)
        assert np.array_equal(cnt, cnt_r), frontier
        assert np.array_equal(cs, cs_r), frontier
        assert np.array_equal(sk, sk_r), frontier


# ---------------------------------------------------------------------------
# the differential core: tree + PBS vs the oracle, per adversarial shape
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", _SHAPES)
def test_tree_reconcile_matches_oracle(shape):
    rng = np.random.default_rng(11)
    a, b = _shape_pair(shape, rng)
    cfg = PBSConfig(seed=3)
    tr = tree_reconcile(a, b, cfg, TreeConfig(seed=5))
    assert tr.success
    assert tr.diff == true_diff(a, b)
    assert tr.tree_bytes == tr.stats.digest_bytes > 0
    assert tr.pbs_bytes == sum(r.bytes_sent for r in tr.results.values())
    assert tr.total_bytes == tr.tree_bytes + tr.pbs_bytes
    _assert_leaf_oracle(tr, a, b, cfg)
    if shape == "identical":
        # one level prunes the whole space: no leaves, no PBS traffic
        assert tr.stats.leaves == 0 and tr.stats.levels == 1
        assert tr.pbs_bytes == 0 and tr.diff == set()
    else:
        assert tr.stats.leaves >= 1


def test_depth_within_analytic_bounds():
    rng = np.random.default_rng(23)
    tcfg = TreeConfig(seed=1)
    # the global bound: halving a range halves its element-count ceiling,
    # so d_plan <= cnt_a + cnt_b forces the leaf clamp to fire by
    # KEY_BITS - floor(log2(leaf_d)) even under adversarial clustering
    hard_cap = KEY_BITS - int(math.floor(math.log2(tcfg.leaf_d)))
    a, b = _shape_pair("clustered", rng)
    _, stats = partition_pair(a, b, tcfg)
    assert stats.depth <= hard_cap, (stats.depth, hard_cap)
    # uniformly spread difference splits geometrically: the residual d̂
    # per range halves each level, so the walk bottoms out around
    # log2(gamma * d / leaf_d) (+ a margin for estimation noise)
    a, b = _shape_pair("disjoint", rng)
    d = len(true_diff(a, b))
    _, stats = partition_pair(a, b, tcfg)
    uniform_bound = math.log2(max(2.0, tcfg.gamma * d / tcfg.leaf_d)) + 3
    assert stats.depth <= uniform_bound, (stats.depth, uniform_bound)


def test_one_launch_per_level_and_warm_rewalk_retraces_nothing():
    rng = np.random.default_rng(31)
    a, b = _shape_pair("clustered", rng)
    tcfg = TreeConfig(seed=2)
    _, cold = partition_pair(a, b, tcfg)
    assert cold.launches == cold.levels  # both sides stacked: ONE per level
    # identical sizes land in the same pow2 buckets: zero recompilations
    _, warm = partition_pair(a, b, tcfg)
    assert warm.retraces == 0, warm
    assert warm.launches == warm.levels


@given(seed=st.integers(min_value=0, max_value=2**20))
@settings(max_examples=5, deadline=None)
def test_tree_reconcile_random_pairs_hypothesis(seed):
    # the seed-robust form of the differential contract: the tree's diff
    # equals the union of standalone PBS oracles over its own leaves (the
    # oracle itself may false-settle its sum checksum on adversarial
    # clustered keys — the tree must mirror it byte-for-byte regardless)
    rng = np.random.default_rng(seed)
    shape = _SHAPES[seed % len(_SHAPES)]
    a, b = _shape_pair(shape, rng)
    cfg = PBSConfig(seed=seed & 0xFFFF)
    tr = tree_reconcile(a, b, cfg, TreeConfig(seed=seed >> 4))
    assert tr.success
    _assert_leaf_oracle(tr, a, b, cfg)


# ---------------------------------------------------------------------------
# wire equivalence: the MSG_TREE flow is byte-identical to the in-process walk
# ---------------------------------------------------------------------------


def test_wire_pair_byte_identical_to_inprocess_walk():
    rng = np.random.default_rng(41)
    base = rng.choice(1 << 32, size=1000, replace=False).astype(np.uint32)
    a = _uniq(base[:640])
    b = _uniq(base[360:])                    # heavy divergence, d ~ 640
    oracle = true_diff(a, b)
    cfg, tcfg = PBSConfig(seed=3), TreeConfig(seed=5)

    ta, tb = InMemoryDuplex.pair()
    alice = AliceEndpoint(ta)
    bob = BobEndpoint(tb)
    alice.submit_tree(a, cfg, tcfg)
    bob.submit_tree(b, cfg, tcfg)
    res = run_pair(alice, bob)

    diff = set()
    pbs_bytes = 0
    for r in res.values():
        assert r.success
        diff |= r.diff
        pbs_bytes += r.bytes_sent
    assert diff == oracle

    # the in-process walk is the wire flow's ledger oracle: same leaves,
    # same depth, and digest_bytes == the framed MSG_TREE tally both
    # endpoints measured on the wire
    tr = tree_reconcile(a, b, cfg, tcfg)
    ws_a, ws_b = alice.wire_stats, bob.wire_stats
    assert ws_a["tree_frame_bytes"] == ws_b["tree_frame_bytes"]
    assert ws_a["tree_frame_bytes"] == tr.tree_bytes == tr.stats.digest_bytes
    assert alice.tree_leaves == bob.tree_leaves == tr.stats.leaves
    assert alice.tree_depth == bob.tree_depth == tr.stats.depth
    assert pbs_bytes == tr.pbs_bytes
    # per-session byte identity against standalone PBS at the planned d
    subs_a = leaf_slices(a, tr.leaves)
    subs_b = leaf_slices(b, tr.leaves)
    for sid, (a_sub, b_sub, leaf) in enumerate(
        zip(subs_a, subs_b, tr.leaves)
    ):
        exp = reconcile(a_sub, b_sub, cfg, d_known=leaf.d_plan)
        assert res[sid].diff == exp.diff, sid
        assert res[sid].bytes_per_round == exp.bytes_per_round, sid
        assert res[sid].bytes_sent == exp.bytes_sent, sid
        assert res[sid].rounds == exp.rounds, sid


def test_hub_tree_peer_coexists_with_plain_peers():
    rng = np.random.default_rng(51)
    hub = HubEndpoint(recv_deadline=30.0)
    alices = {}
    # peer 1: known-d; peer 2: estimator (in regime)
    cases = {}
    for i, dk in ((0, 9), (1, None)):
        a, b = make_pair(600, 9, np.random.default_rng(100 + i))
        cfg = PBSConfig(seed=10 + i)
        ta, tb = InMemoryDuplex.pair()
        ch = hub.add_peer(tb)
        hub.submit(ch, b, cfg=cfg, d_known=dk)
        ep = AliceEndpoint(ta, channel=ch)
        ep.submit(a, cfg=cfg, d_known=dk)
        alices[ch] = ep
        cases[ch] = (a, b, cfg, dk)
    # peer 3: cold start through the tree phase
    a3, b3 = _shape_pair("clustered", rng)
    cfg3, tcfg3 = PBSConfig(seed=12), TreeConfig(seed=7)
    ta, tb = InMemoryDuplex.pair()
    ch3 = hub.add_peer(tb, label="coldstart")
    hub.submit_tree(ch3, b3, cfg=cfg3, tree=tcfg3)
    ep3 = AliceEndpoint(ta, channel=ch3)
    ep3.submit_tree(a3, cfg3, tcfg3)
    alices[ch3] = ep3

    outcomes, results, errors = run_hub(hub, alices)
    assert not errors
    assert all(o.ok for o in outcomes.values())

    for ch, (a, b, cfg, dk) in cases.items():
        exp = reconcile(a, b, cfg, d_known=dk)
        got = results[ch][0]
        assert got.diff == exp.diff and got.bytes_sent == exp.bytes_sent, ch
        assert outcomes[ch].tree_leaves is None  # no tree phase ran
    # the cold-start peer: union of leaf diffs == whole-pair oracle, and
    # the walk's shape surfaces through PeerOutcome and the hub stats
    tr = tree_reconcile(a3, b3, cfg3, tcfg3)
    diff3 = set()
    for r in results[ch3].values():
        assert r.success
        diff3 |= r.diff
    assert diff3 == true_diff(a3, b3)
    assert outcomes[ch3].tree_leaves == tr.stats.leaves
    assert outcomes[ch3].tree_depth == tr.stats.depth
    st = hub.stats
    assert st["tree_leaves"] == tr.stats.leaves
    assert st["tree_digest_bytes"] == tr.stats.digest_bytes
    assert st["tree_levels"] == tr.stats.levels


def test_continuous_cold_start_rejoins_delta_mode():
    """Epoch 0 routes through the tree (no sane d̂ exists); the next epoch
    runs the ordinary delta path with per-leaf estimator rebinding."""
    rng = np.random.default_rng(62)
    a, b = _shape_pair("clustered", rng)
    cfg = PBSConfig(seed=9)

    ta, tb = InMemoryDuplex.pair()
    alice = AliceEndpoint(ta, continuous=True)
    bob = BobEndpoint(tb, continuous=True)
    alice.submit_tree(a, cfg)
    bob.submit_tree(b, cfg)
    res0 = run_pair(alice, bob)
    diff0 = set()
    for r in res0.values():
        assert r.success
        diff0 |= r.diff
    assert diff0 == true_diff(a, b)
    assert alice.tree_leaves == bob.tree_leaves >= 1

    # epoch 1: replicas converged (A <- A △ D = B per leaf), small churn on
    # the largest leaf (so the re-estimated d̂ stays inside the phase-0
    # operating regime), every leaf session rebound to wire d̂ re-estimation
    churn = rng.choice(1 << 32, size=6, replace=False).astype(np.uint32)
    sid_big = max(res0, key=lambda s: len(alice.sessions[s].state.a))
    rebind = {sid: None for sid in res0}
    alice.advance_epoch({sid_big: (churn, _EMPTY)}, d_known=rebind)
    bob.advance_epoch({}, d_known=rebind)
    res1 = run_pair_epoch(alice, bob)
    diff1 = set()
    for r in res1.values():
        assert r.success
        diff1 |= r.diff
    assert diff1 == set(int(x) for x in churn) - set(int(x) for x in b)


# ---------------------------------------------------------------------------
# the estimator's failure envelope (the regression that motivates the tree)
# ---------------------------------------------------------------------------


def test_check_estimate_envelope_unit():
    # inside the regime: silent pass; outside: typed, number-carrying raise
    check_estimate(100, 1000, ESTIMATE_LIMIT_FRAC)
    check_estimate(500, 1000, ESTIMATE_LIMIT_FRAC)   # boundary is inclusive
    with pytest.raises(EstimateOutOfRange) as ei:
        check_estimate(501, 1000, ESTIMATE_LIMIT_FRAC, sid=4)
    assert ei.value.d_plan == 501 and ei.value.total == 1000
    assert ei.value.limit_frac == ESTIMATE_LIMIT_FRAC and ei.value.sid == 4
    check_estimate(999999, 10, None)                 # None disables the guard
    # taxonomy: typed raise -> error_kind="estimate", also through the
    # eviction wrapper (TransportError with __cause__ = the root)
    err = EstimateOutOfRange(501, 1000, 0.5)
    assert classify_error(err) == "estimate"
    wrapped = TransportError("peer: evicted")
    wrapped.__cause__ = err
    assert classify_error(wrapped) == "estimate"


def test_estimator_pair_out_of_regime_raises_typed():
    rng = np.random.default_rng(71)
    a, b = _shape_pair("near_total", rng)        # d ~ |A|: d̂ >> regime
    ta, tb = InMemoryDuplex.pair()
    alice, bob = AliceEndpoint(ta), BobEndpoint(tb)
    alice.submit(a)
    bob.submit(b)
    with pytest.raises(EstimateOutOfRange) as ei:
        run_pair(alice, bob)
    assert ei.value.d_plan > ei.value.limit_frac * ei.value.total

    # the same pair with pinned d never raises: d_known opts out
    d = len(true_diff(a, b))
    ta, tb = InMemoryDuplex.pair()
    alice, bob = AliceEndpoint(ta), BobEndpoint(tb)
    alice.submit(a, d_known=d)
    bob.submit(b, d_known=d)
    res = run_pair(alice, bob)
    assert res[0].success and res[0].diff == true_diff(a, b)

    # estimate_limit=None restores the legacy unguarded behaviour: the
    # wildly wrong plan completes (degradation soaks it) instead of raising
    ta, tb = InMemoryDuplex.pair()
    alice = AliceEndpoint(ta, estimate_limit=None, degrade=True)
    bob = BobEndpoint(tb, estimate_limit=None, degrade=True)
    alice.submit(a)
    bob.submit(b)
    run_pair(alice, bob)                         # must not raise


def test_hub_evicts_out_of_regime_estimator_as_estimate():
    rng = np.random.default_rng(81)
    hub = HubEndpoint(recv_deadline=20.0)
    a_ok, b_ok = make_pair(600, 12, rng)
    a_bad, b_bad = _shape_pair("near_total", rng)

    alices = {}
    ta, tb = InMemoryDuplex.pair()
    ch_ok = hub.add_peer(tb, label="inregime")
    hub.submit(ch_ok, b_ok)
    ep = AliceEndpoint(ta, channel=ch_ok)
    ep.submit(a_ok)
    alices[ch_ok] = ep

    ta, tb = InMemoryDuplex.pair()
    ch_bad = hub.add_peer(tb, label="outofregime")
    hub.submit(ch_bad, b_bad)
    ep = AliceEndpoint(ta, channel=ch_bad)
    ep.submit(a_bad)
    alices[ch_bad] = ep

    outcomes, results, errors = run_hub(hub, alices)
    assert outcomes[ch_ok].ok and ch_ok not in errors
    assert results[ch_ok][0].diff == true_diff(a_ok, b_ok)
    assert not outcomes[ch_bad].ok
    assert outcomes[ch_bad].error_kind == "estimate"
    assert hub.stats["peers_failed_by_kind"].get("estimate") == 1


# ---------------------------------------------------------------------------
# the adversarial soak: cold-start joiners against a churning hub (slow tier)
# ---------------------------------------------------------------------------


def _drive_mixed(hub, runners):
    """One hub serve against per-channel runner callables (run/run_epoch)."""
    results, errors = {}, {}

    def drive(ch, fn):
        try:
            results[ch] = fn()
        except BaseException as e:  # noqa: BLE001 - surfaced via `errors`
            errors[ch] = e

    threads = [
        threading.Thread(target=drive, args=(ch, fn), daemon=True)
        for ch, fn in runners.items()
    ]
    for t in threads:
        t.start()
    outcomes = hub.serve()
    for t in threads:
        t.join(timeout=120)
    assert not any(t.is_alive() for t in threads)
    return outcomes, results, errors


@pytest.mark.slow
def test_adversarial_cold_start_soak():
    """10 epochs over an 8-peer continuous hub where EVERY epoch admits one
    fresh cold-start peer through the tree phase while the standing peers
    churn — survivors stay oracle-identical throughout, joiners' leaf
    unions equal their whole-pair oracle, and nobody is perturbed."""
    epochs = 10
    seed = 17
    rng = np.random.default_rng(seed)
    hub = HubEndpoint(recv_deadline=30.0, continuous=True)
    alices: dict[int, AliceEndpoint] = {}
    cfgs: dict[int, PBSConfig] = {}
    dks: dict[int, int | None] = {}
    tree_chs: set[int] = set()

    for p in range(8):
        a, b = make_pair(500, 14, np.random.default_rng(seed + 31 * p))
        dk = None if p % 3 == 0 else 14
        cfg = PBSConfig(seed=seed + p, n_override=127, t_override=7,
                        g_override=4)
        ta, tb = InMemoryDuplex.pair()
        ch = hub.add_peer(tb, label=f"peer{p}")
        hub.submit(ch, b, cfg=cfg, d_known=dk)
        ep = AliceEndpoint(ta, channel=ch, continuous=True)
        ep.submit(a, cfg=cfg, d_known=dk)
        alices[ch] = ep
        cfgs[ch], dks[ch] = cfg, dk

    outcomes, results, errors = _drive_mixed(
        hub, {ch: ep.run for ch, ep in alices.items()}
    )
    assert not errors and all(o.ok for o in outcomes.values())

    for e in range(1, epochs + 1):
        # standing peers churn; the hub's canonical B and each Alice's A
        # drift a little every epoch
        hub_muts, alice_muts = {}, {}
        for ch, ep in alices.items():
            if ch in tree_chs:
                continue                 # joiners ride their pinned leaf d
            b_cur = hub._peers[ch].sessions[0].state.b
            hub_muts[ch] = {0: (
                rng.integers(1, 1 << 32, size=4, dtype=np.uint64)
                   .astype(np.uint32),
                rng.permutation(b_cur)[:4],
            )}
            a_cur = ep.sessions[0].state.a
            alice_muts[ch] = {0: (
                rng.integers(1, 1 << 32, size=2, dtype=np.uint64)
                   .astype(np.uint32),
                rng.permutation(a_cur)[:2],
            )}
        hub.advance_epoch(hub_muts)
        for ch, ep in alices.items():
            ep.advance_epoch(alice_muts.get(ch, {}))

        # one brand-new cold-start peer joins THIS epoch through the tree
        aj, bj = _shape_pair("clustered", np.random.default_rng(seed + 997 * e))
        cfgj = PBSConfig(seed=seed + 500 + e)
        ta, tb = InMemoryDuplex.pair()
        chj = hub.add_peer(tb, label=f"cold{e}")
        hub.submit_tree(chj, bj, cfg=cfgj)
        epj = AliceEndpoint(ta, channel=chj, continuous=True)
        epj.submit_tree(aj, cfgj)

        runners = {ch: ep.run_epoch for ch, ep in alices.items()}
        runners[chj] = epj.run
        outcomes, results, errors = _drive_mixed(hub, runners)
        assert not errors, (e, errors)
        assert all(o.ok for o in outcomes.values()), e

        # the joiner: tree walk ran, and its diff equals the union of
        # standalone PBS oracles over its own leaves (byte-identical
        # router contract; robust to the oracle's own residual checksum
        # collisions on adversarially clustered keys)
        assert outcomes[chj].tree_leaves == epj.tree_leaves >= 1, e
        diff_j = set()
        for r in results[chj].values():
            assert r.success, e
            diff_j |= r.diff
        uaj, ubj = _uniq(aj), _uniq(bj)
        leaves_j, _ = partition_pair(uaj, ubj, TreeConfig())
        expected_j = set()
        for a_sub, b_sub, leaf in zip(
            leaf_slices(uaj, leaves_j), leaf_slices(ubj, leaves_j), leaves_j
        ):
            expected_j |= reconcile(
                a_sub, b_sub, cfgj, d_known=leaf.d_plan
            ).diff
        assert diff_j == expected_j, e

        # every standing survivor: byte-identical to the fresh oracle over
        # this epoch's sets
        for ch, ep in alices.items():
            if ch in tree_chs:
                continue
            a_e = ep.sessions[0].state.a
            b_e = hub._peers[ch].sessions[0].state.b
            r = results[ch][0]
            oracle = reconcile(a_e, b_e, cfgs[ch], d_known=dks[ch])
            assert r.success and r.diff == oracle.diff, (e, ch)
            assert r.bytes_sent == oracle.bytes_sent, (e, ch)
            assert r.rounds == oracle.rounds, (e, ch)

        alices[chj] = epj
        tree_chs.add(chj)

    assert hub.stats["peers_failed"] == 0
    assert len(alices) == 8 + epochs
