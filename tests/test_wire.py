"""repro.wire codec: round-trip properties, rejection paths, size mirrors.

Every message type must satisfy ``decode(encode(m)) == m`` (hypothesis
property tests over random message contents), reject truncated buffers and
corrupted frames, and — for the phase-0 messages — produce framed lengths
exactly equal to the numpy-pure mirrors in ``core.tow`` that the protocol's
byte accounting uses.
"""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.tow import dhat_bytes, sketch_bytes
from repro.wire import frames as wf
from repro.wire.frames import ReplyUnit, WireError, WireTruncated
from repro.wire.varint import (
    BitReader,
    BitWriter,
    decode_uvarint,
    encode_uvarint,
    unzigzag,
    uvarint_len,
    zigzag,
)


def _unframe(buf: bytes, expect_type: int) -> bytes:
    got = wf.split_frame(buf)
    assert got is not None, "whole frame must parse"
    msg_type, payload, consumed = got
    assert msg_type == expect_type
    assert consumed == len(buf), "no trailing bytes"
    return payload


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------


@given(v=st.integers(min_value=0, max_value=2**63 - 1))
@settings(max_examples=60, deadline=None)
def test_uvarint_roundtrip(v):
    buf = encode_uvarint(v)
    assert len(buf) == uvarint_len(v)
    got, off = decode_uvarint(buf)
    assert got == v and off == len(buf)


@given(n=st.integers(min_value=-(2**31), max_value=2**31 - 1))
@settings(max_examples=60, deadline=None)
def test_zigzag_roundtrip(n):
    z = zigzag(n)
    assert z >= 0 and unzigzag(z) == n


def test_uvarint_truncated_and_overlong():
    with pytest.raises(WireTruncated):
        decode_uvarint(b"\x80\x80")          # continuation bit, no terminator
    with pytest.raises(WireError):
        decode_uvarint(b"\xff" * 10 + b"\x01")  # > 64 bits


@given(
    fields=st.lists(
        st.tuples(st.integers(min_value=0, max_value=2**20), st.integers(1, 21)),
        max_size=40,
    )
)
@settings(max_examples=40, deadline=None)
def test_bitstream_roundtrip(fields):
    w = BitWriter()
    vals = [(v & ((1 << nb) - 1), nb) for v, nb in fields]
    for v, nb in vals:
        w.write(v, nb)
    buf = w.getvalue()
    assert len(buf) == (w.bit_length + 7) // 8
    r = BitReader(buf)
    for v, nb in vals:
        assert r.read(nb) == v
    r.finish()


def test_bitstream_rejects_nonzero_padding():
    r = BitReader(b"\x81")  # one flag bit + nonzero pad
    assert r.read(1) == 1
    with pytest.raises(WireError):
        r.finish()


# ---------------------------------------------------------------------------
# frame envelope
# ---------------------------------------------------------------------------


def test_split_frame_incomplete_and_unknown_type():
    f = wf.encode_dhat(12345)
    assert wf.split_frame(f[:1]) is None          # header only
    assert wf.split_frame(f[:-1]) is None         # body short by one byte
    bad = bytearray(f)
    bad[1] = 0x7F                                  # unknown message type
    with pytest.raises(WireError):
        wf.split_frame(bytes(bad))
    with pytest.raises(WireError):
        wf.split_frame(b"\x00")                    # zero-length body


# ---------------------------------------------------------------------------
# phase-0 frames
# ---------------------------------------------------------------------------


@given(
    set_size=st.integers(min_value=0, max_value=50_000),
    ell=st.integers(min_value=1, max_value=160),
    seed=st.integers(min_value=0, max_value=2**32 - 1),
)
@settings(max_examples=30, deadline=None)
def test_tow_sketch_roundtrip_and_size(set_size, ell, seed):
    rng = np.random.default_rng(seed)
    values = rng.integers(-set_size, set_size + 1, size=ell, dtype=np.int64)
    buf = wf.encode_tow_sketch(values, set_size)
    # the framed length is exactly what core.tow.sketch_bytes accounts
    assert len(buf) == sketch_bytes(set_size, ell)
    got_size, got_vals = wf.decode_tow_sketch(_unframe(buf, wf.MSG_TOW_SKETCH))
    assert got_size == set_size
    np.testing.assert_array_equal(got_vals, values)


def test_tow_sketch_rejects_out_of_range_and_truncation():
    with pytest.raises(WireError):
        wf.encode_tow_sketch(np.array([11]), set_size=10)
    buf = wf.encode_tow_sketch(np.arange(-3, 4), set_size=5)
    payload = _unframe(buf, wf.MSG_TOW_SKETCH)
    with pytest.raises(WireError):
        wf.decode_tow_sketch(payload[:-2])         # truncated bit stream
    with pytest.raises(WireError):
        wf.decode_tow_sketch(payload + b"\x00")    # trailing garbage


@given(num=st.integers(min_value=0, max_value=2**62))
@settings(max_examples=40, deadline=None)
def test_dhat_roundtrip_and_size(num):
    buf = wf.encode_dhat(num)
    assert len(buf) == dhat_bytes(num)
    assert wf.decode_dhat(_unframe(buf, wf.MSG_DHAT)) == num


def test_dhat_rejects_trailing_bytes():
    with pytest.raises(WireError):
        wf.decode_dhat(_unframe(wf.encode_dhat(7), wf.MSG_DHAT) + b"\x01")


# ---------------------------------------------------------------------------
# round frames (schema-driven)
# ---------------------------------------------------------------------------


def _random_schema(rng, max_sessions=4):
    schema = []
    for _ in range(rng.integers(1, max_sessions + 1)):
        m = int(rng.integers(4, 11))
        t = int(rng.integers(1, 9))
        schema.append((int(rng.integers(1, 7)), t, m))
    return schema


@given(seed=st.integers(min_value=0, max_value=2**32 - 1))
@settings(max_examples=25, deadline=None)
def test_round_sketches_roundtrip(seed):
    rng = np.random.default_rng(seed)
    schema = _random_schema(rng)
    rnd = int(rng.integers(1, 13))
    blocks = [
        (rng.integers(0, 1 << m, size=(u, t), dtype=np.int64), m)
        for u, t, m in schema
    ]
    buf = wf.encode_round_sketches(rnd, blocks)
    got_rnd, got = wf.decode_round_sketches(
        _unframe(buf, wf.MSG_ROUND_SKETCHES), schema
    )
    assert got_rnd == rnd
    for (sk, _), g, (u, t, m) in zip(blocks, got, schema):
        np.testing.assert_array_equal(g, sk)
        assert wf.sketches_ledger_bits(u, t, m) == u * t * m


def _random_reply(rng, schema):
    entries = []
    for u, t, m in schema:
        n = (1 << m) - 1
        ok = rng.random(u) < 0.8
        units = []
        for slot in range(u):
            if not ok[slot]:
                units.append(None)
                continue
            k = int(rng.integers(0, t + 1))
            units.append(
                ReplyUnit(
                    positions=np.sort(
                        rng.choice(n, size=k, replace=False)
                    ).astype(np.int64),
                    xors=rng.integers(0, 1 << 32, size=k, dtype=np.uint64).astype(
                        np.uint32
                    ),
                    csum=int(rng.integers(0, 1 << 32)),
                )
            )
        entries.append((ok, units))
    return entries


@given(seed=st.integers(min_value=0, max_value=2**32 - 1))
@settings(max_examples=25, deadline=None)
def test_round_reply_roundtrip(seed):
    rng = np.random.default_rng(seed)
    schema = _random_schema(rng)
    entries = _random_reply(rng, schema)
    rnd = int(rng.integers(1, 13))
    buf = wf.encode_round_reply(rnd, entries, schema)
    got_rnd, got = wf.decode_round_reply(_unframe(buf, wf.MSG_ROUND_REPLY), schema)
    assert got_rnd == rnd
    for (ok, units), (gok, gunits), (u, t, m) in zip(entries, got, schema):
        np.testing.assert_array_equal(gok, ok)
        assert gunits == units
        # ledger bits match Formula (1): 1/unit + k*(m+32) + 32 per decode
        exp = u + sum(
            len(x.positions) * (m + 32) + 32 for x in units if x is not None
        )
        assert wf.reply_ledger_bits(gok, gunits, m) == exp


def test_round_reply_rejects_bad_counts_and_positions():
    schema = [(1, 2, 4)]                           # n = 15
    ok = np.array([True])
    unit = ReplyUnit(
        positions=np.array([3]), xors=np.array([7], np.uint32), csum=1
    )
    buf = wf.encode_round_reply(1, [(ok, [unit])], schema)
    payload = _unframe(buf, wf.MSG_ROUND_REPLY)
    # schema mismatch: t=1 makes the stored count 1 overflow cbits
    with pytest.raises(WireError):
        wf.decode_round_reply(payload, [(2, 2, 4)])
    with pytest.raises(WireError):
        wf.decode_round_reply(payload[:-1], schema)  # truncated
    with pytest.raises(WireError):
        wf.encode_round_reply(
            1,
            [(ok, [ReplyUnit(np.array([15]), np.array([0], np.uint32), 0)])],
            schema,
        )  # position == n is out of range
    with pytest.raises(WireError):
        wf.encode_round_reply(
            1,
            [(ok, [ReplyUnit(np.array([1, 2, 3]), np.zeros(3, np.uint32), 0)])],
            schema,
        )  # k > t


@given(seed=st.integers(min_value=0, max_value=2**32 - 1))
@settings(max_examples=25, deadline=None)
def test_round_outcome_roundtrip(seed):
    rng = np.random.default_rng(seed)
    counts = [int(rng.integers(1, 9)) for _ in range(int(rng.integers(1, 5)))]
    done = [rng.random(u) < 0.5 for u in counts]
    rnd = int(rng.integers(1, 13))
    buf = wf.encode_round_outcome(rnd, done)
    got_rnd, got = wf.decode_round_outcome(_unframe(buf, wf.MSG_ROUND_OUTCOME), counts)
    assert got_rnd == rnd
    for d, g in zip(done, got):
        np.testing.assert_array_equal(g, d)


@given(seed=st.integers(min_value=0, max_value=2**32 - 1))
@settings(max_examples=25, deadline=None)
def test_verify_roundtrip(seed):
    rng = np.random.default_rng(seed)
    n_sessions = int(rng.integers(1, 9))
    entries = [
        (bool(rng.random() < 0.5), int(rng.integers(0, 1 << 32)))
        for _ in range(n_sessions)
    ]
    buf = wf.encode_verify(entries)
    assert wf.decode_verify(_unframe(buf, wf.MSG_VERIFY), n_sessions) == entries
    flags = [bool(rng.random() < 0.5) for _ in range(n_sessions)]
    buf = wf.encode_verify_ack(flags)
    assert wf.decode_verify_ack(_unframe(buf, wf.MSG_VERIFY_ACK), n_sessions) == flags


@pytest.mark.parametrize("seed", [0, 1, 2, 3, 4, 5, 6, 7])
def test_round_frames_roundtrip_seeded(seed):
    """Deterministic mirror of the hypothesis properties (always runs, even
    without the optional hypothesis dependency)."""
    rng = np.random.default_rng(seed)
    schema = _random_schema(rng)
    blocks = [
        (rng.integers(0, 1 << m, size=(u, t), dtype=np.int64), m)
        for u, t, m in schema
    ]
    rnd = int(rng.integers(1, 13))
    _, got = wf.decode_round_sketches(
        _unframe(wf.encode_round_sketches(rnd, blocks), wf.MSG_ROUND_SKETCHES),
        schema,
    )
    for (sk, _), g in zip(blocks, got):
        np.testing.assert_array_equal(g, sk)

    entries = _random_reply(rng, schema)
    _, got = wf.decode_round_reply(
        _unframe(wf.encode_round_reply(rnd, entries, schema), wf.MSG_ROUND_REPLY),
        schema,
    )
    for (ok, units), (gok, gunits) in zip(entries, got):
        np.testing.assert_array_equal(gok, ok)
        assert gunits == units

    set_size = int(rng.integers(0, 10_000))
    ell = int(rng.integers(1, 160))
    values = rng.integers(-set_size, set_size + 1, size=ell, dtype=np.int64)
    buf = wf.encode_tow_sketch(values, set_size)
    assert len(buf) == sketch_bytes(set_size, ell)
    got_size, got_vals = wf.decode_tow_sketch(_unframe(buf, wf.MSG_TOW_SKETCH))
    assert got_size == set_size
    np.testing.assert_array_equal(got_vals, values)

    num = int(rng.integers(0, 1 << 48))
    buf = wf.encode_dhat(num)
    assert len(buf) == dhat_bytes(num)
    assert wf.decode_dhat(_unframe(buf, wf.MSG_DHAT)) == num


def test_verify_rejects_wrong_session_count():
    buf = _unframe(wf.encode_verify([(True, 5), (False, 9)]), wf.MSG_VERIFY)
    with pytest.raises(WireError):
        wf.decode_verify(buf, 3)                   # wants more than encoded
    with pytest.raises(WireError):
        wf.decode_verify(buf, 1)                   # leftover bytes


# ---------------------------------------------------------------------------
# mux envelope (hub multiplexing, DESIGN.md §10)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("channel", [1, 2, 127, 128, 70000])
def test_mux_roundtrip_and_overhead(channel):
    inner = wf.encode_dhat(123456)
    buf = wf.encode_mux(channel, inner)
    payload = _unframe(buf, wf.MSG_MUX)
    ch, msg_type, inner_payload = wf.decode_mux(payload)
    assert ch == channel and msg_type == wf.MSG_DHAT
    assert wf.decode_dhat(inner_payload) == 123456
    assert len(buf) - len(inner) == wf.mux_overhead_bytes(channel, len(inner))


def test_mux_rejects_zero_channel_nesting_and_trailing():
    inner = wf.encode_dhat(7)
    with pytest.raises(WireError, match="channel 0"):
        wf.encode_mux(0, inner)
    # nested mux envelopes are rejected
    nested = wf.encode_mux(3, wf.encode_mux(2, inner))
    with pytest.raises(WireError, match="nested"):
        wf.decode_mux(_unframe(nested, wf.MSG_MUX))
    # trailing bytes after the inner frame are rejected
    buf = wf.encode_mux(3, inner)
    payload = _unframe(buf, wf.MSG_MUX) + b"\x00"
    with pytest.raises(WireError, match="trailing"):
        wf.decode_mux(payload)
    # a truncated inner frame is a truncation error
    payload = _unframe(buf, wf.MSG_MUX)
    with pytest.raises(WireTruncated):
        wf.decode_mux(payload[:-1])


# ---------------------------------------------------------------------------
# epoch envelope (continuous sync, DESIGN.md §11)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("epoch", [1, 2, 127, 128, 70000])
def test_epoch_roundtrip_and_overhead(epoch):
    # wrapped d̂ handshake frame: inner ledger bits, envelope overhead
    inner = wf.encode_dhat(4242)
    buf = wf.encode_epoch(epoch, inner)
    e, msg_type, inner_payload = wf.decode_epoch(_unframe(buf, wf.MSG_EPOCH))
    assert e == epoch and msg_type == wf.MSG_DHAT
    assert wf.decode_dhat(inner_payload) == 4242
    assert len(buf) - len(inner) == wf.epoch_overhead_bytes(epoch, len(inner))
    # bare epoch-open: no inner frame at all
    bare = wf.encode_epoch(epoch)
    assert wf.decode_epoch(_unframe(bare, wf.MSG_EPOCH)) == (epoch, None, None)
    assert len(bare) == wf.epoch_overhead_bytes(epoch, 0)


def test_epoch_rejects_zero_epoch_nesting_and_trailing():
    inner = wf.encode_dhat(9)
    # epoch 0 is the admission epoch: never carried by MSG_EPOCH
    with pytest.raises(WireError, match="epoch 0"):
        wf.encode_epoch(0, inner)
    with pytest.raises(WireError, match="epoch 0"):
        wf.decode_epoch(b"\x00" + inner)
    # nested envelopes are rejected in both flavors
    nested = wf.encode_epoch(3, wf.encode_epoch(2, inner))
    with pytest.raises(WireError, match="nested"):
        wf.decode_epoch(_unframe(nested, wf.MSG_EPOCH))
    muxed = wf.encode_epoch(3, wf.encode_mux(2, inner))
    with pytest.raises(WireError, match="nested"):
        wf.decode_epoch(_unframe(muxed, wf.MSG_EPOCH))
    # trailing bytes after the inner frame are rejected
    buf = wf.encode_epoch(3, inner)
    payload = _unframe(buf, wf.MSG_EPOCH) + b"\x00"
    with pytest.raises(WireError, match="trailing"):
        wf.decode_epoch(payload)
    # a truncated inner frame is a truncation error
    payload = _unframe(buf, wf.MSG_EPOCH)
    with pytest.raises(WireTruncated):
        wf.decode_epoch(payload[:-1])
    # the mux wrap goes outside: MSG_EPOCH inside MSG_MUX is legal
    ch, msg_type, ip = wf.decode_mux(
        _unframe(wf.encode_mux(5, buf), wf.MSG_MUX)
    )
    assert ch == 5 and msg_type == wf.MSG_EPOCH
    assert wf.decode_epoch(ip)[0] == 3


# ---------------------------------------------------------------------------
# tree-phase frames (cold-start front end, DESIGN.md §15)
# ---------------------------------------------------------------------------


def _tree_digest_case(rng):
    """Random digest-frame contents: counts include empty ranges, sketch
    values bounded by their own range count (the codec's width contract)."""
    n_ranges = int(rng.integers(1, 12))
    ell = int(rng.integers(1, 40))
    counts = rng.integers(0, 1 << 12, size=n_ranges)
    counts[rng.integers(0, n_ranges)] = 0          # always one empty range
    csums = rng.integers(0, 1 << 32, size=n_ranges)
    sketches = np.zeros((n_ranges, ell), dtype=np.int64)
    for r in range(n_ranges):
        c = int(counts[r])
        if c:
            sketches[r] = rng.integers(-c, c + 1, size=ell)
    return int(rng.integers(0, 33)), counts, csums, sketches


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_tree_digest_roundtrip_seeded(seed):
    rng = np.random.default_rng(seed)
    level, counts, csums, sketches = _tree_digest_case(rng)
    buf = wf.encode_tree_digest(level, counts, csums, sketches)
    payload = _unframe(buf, wf.MSG_TREE)
    lvl, ell, cnt, cs, sk = wf.decode_tree_digest(payload)
    assert lvl == level and ell == sketches.shape[1]
    assert np.array_equal(cnt, counts)
    assert np.array_equal(cs, csums)
    assert np.array_equal(sk, sketches)


@given(seed=st.integers(min_value=0, max_value=2**32 - 1))
@settings(max_examples=40, deadline=None)
def test_tree_digest_roundtrip_hypothesis(seed):
    rng = np.random.default_rng(seed)
    level, counts, csums, sketches = _tree_digest_case(rng)
    buf = wf.encode_tree_digest(level, counts, csums, sketches)
    lvl, ell, cnt, cs, sk = wf.decode_tree_digest(_unframe(buf, wf.MSG_TREE))
    assert (lvl, ell) == (level, sketches.shape[1])
    assert np.array_equal(cnt, counts)
    assert np.array_equal(cs, csums)
    assert np.array_equal(sk, sketches)


def test_tree_digest_strict_rejection():
    rng = np.random.default_rng(9)
    level, counts, csums, sketches = _tree_digest_case(rng)
    buf = wf.encode_tree_digest(level, counts, csums, sketches)
    payload = _unframe(buf, wf.MSG_TREE)
    # a sketch value exceeding its own range count never encodes...
    bad = sketches.copy()
    bad[0, 0] = int(counts[0]) + 1
    with pytest.raises(WireError, match="exceeds"):
        wf.encode_tree_digest(level, counts, csums, bad)
    # ...and never decodes: shrink a range's count in a re-encoded frame
    # so the payload's zigzag values overflow the tightened width contract
    shrunk = counts.copy()
    shrunk[int(np.argmax(counts))] = 0
    ok_vals = np.zeros_like(sketches)
    mixed = _unframe(
        wf.encode_tree_digest(level, counts, csums, sketches), wf.MSG_TREE
    )
    # splice the original (wider) value section after a header re-encoded
    # with the shrunk counts: decode must reject, never misread
    narrow = _unframe(
        wf.encode_tree_digest(level, shrunk, csums, ok_vals), wf.MSG_TREE
    )
    spliced = narrow[: len(narrow) - len(mixed) // 4] + mixed[-(len(mixed) // 4):]
    with pytest.raises((WireError, WireTruncated)):
        wf.decode_tree_digest(spliced)
    # flavor confusion: a verdict payload is not a digest
    vbuf = wf.encode_tree_verdict(3, [wf.TREE_PRUNE], [])
    with pytest.raises(WireError, match="flavor"):
        wf.decode_tree_digest(_unframe(vbuf, wf.MSG_TREE))
    # trailing bytes and truncation are both fatal
    with pytest.raises(WireError):
        wf.decode_tree_digest(payload + b"\x00")
    with pytest.raises((WireError, WireTruncated)):
        wf.decode_tree_digest(payload[:-1])
    # empty sketch rows are meaningless
    with pytest.raises(WireError, match="empty sketch"):
        wf.encode_tree_digest(0, [1], [0], np.zeros((1, 0), dtype=np.int64))


def _tree_verdict_case(rng):
    n_ranges = int(rng.integers(1, 24))
    verdicts = rng.integers(0, 3, size=n_ranges)    # PRUNE/RECURSE/LEAF
    leaf_ds = [int(rng.integers(1, 1 << 10))
               for _ in range(int(np.sum(verdicts == wf.TREE_LEAF)))]
    return int(rng.integers(0, 33)), verdicts, leaf_ds


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_tree_verdict_roundtrip_seeded(seed):
    rng = np.random.default_rng(seed)
    level, verdicts, leaf_ds = _tree_verdict_case(rng)
    buf = wf.encode_tree_verdict(level, verdicts, leaf_ds)
    lvl, v, ds = wf.decode_tree_verdict(_unframe(buf, wf.MSG_TREE))
    assert lvl == level
    assert np.array_equal(v, verdicts)
    assert list(ds) == leaf_ds


@given(seed=st.integers(min_value=0, max_value=2**32 - 1))
@settings(max_examples=40, deadline=None)
def test_tree_verdict_roundtrip_hypothesis(seed):
    rng = np.random.default_rng(seed)
    level, verdicts, leaf_ds = _tree_verdict_case(rng)
    buf = wf.encode_tree_verdict(level, verdicts, leaf_ds)
    lvl, v, ds = wf.decode_tree_verdict(_unframe(buf, wf.MSG_TREE))
    assert lvl == level and np.array_equal(v, verdicts)
    assert list(ds) == leaf_ds


def test_tree_verdict_strict_rejection():
    # the reserved verdict value 3 never encodes...
    with pytest.raises(WireError, match="out of range"):
        wf.encode_tree_verdict(0, [3], [])
    # ...and never decodes: craft header + a bit pair of 0b11
    crafted = (
        encode_uvarint(wf.TREE_VERDICT)
        + encode_uvarint(0)
        + encode_uvarint(1)
        + bytes([0b11000000])
    )
    with pytest.raises(WireError, match="out of range"):
        wf.decode_tree_verdict(crafted)
    # nonzero padding bits after the packed verdicts are rejected
    crafted = (
        encode_uvarint(wf.TREE_VERDICT)
        + encode_uvarint(0)
        + encode_uvarint(1)
        + bytes([0b10100000])        # verdict 2 (leaf) + a stray pad bit
        + encode_uvarint(5)
    )
    with pytest.raises(WireError, match="padding"):
        wf.decode_tree_verdict(crafted)
    # leaf d list must match the leaf verdict count, and d >= 1
    with pytest.raises(WireError, match="does not match"):
        wf.encode_tree_verdict(0, [wf.TREE_LEAF], [])
    with pytest.raises(WireError, match=">= 1"):
        wf.encode_tree_verdict(0, [wf.TREE_LEAF], [0])
    buf = wf.encode_tree_verdict(2, [wf.TREE_LEAF, wf.TREE_PRUNE], [7])
    payload = _unframe(buf, wf.MSG_TREE)
    lvl, v, ds = wf.decode_tree_verdict(payload)
    assert lvl == 2 and list(ds) == [7]
    # truncation and trailing bytes are both fatal
    with pytest.raises((WireError, WireTruncated)):
        wf.decode_tree_verdict(payload[:-1])
    with pytest.raises(WireError, match="unconsumed"):
        wf.decode_tree_verdict(payload + b"\x00")
    # flavor confusion: a digest payload is not a verdict
    dbuf = wf.encode_tree_digest(0, [1], [3], np.ones((1, 4), dtype=np.int64))
    with pytest.raises(WireError, match="flavor"):
        wf.decode_tree_verdict(_unframe(dbuf, wf.MSG_TREE))


def test_tree_envelope_nesting_legality():
    """MSG_TREE rides inside both envelopes (a hub tree phase is muxed; a
    future epoch-scoped walk is epoch-wrapped) — while envelope nesting
    rules stay intact."""
    inner = wf.encode_tree_verdict(1, [wf.TREE_PRUNE, wf.TREE_RECURSE], [])
    ch, msg_type, ip = wf.decode_mux(
        _unframe(wf.encode_mux(4, inner), wf.MSG_MUX)
    )
    assert ch == 4 and msg_type == wf.MSG_TREE
    assert wf.decode_tree_verdict(ip)[0] == 1
    e, msg_type, ip = wf.decode_epoch(
        _unframe(wf.encode_epoch(2, inner), wf.MSG_EPOCH)
    )
    assert e == 2 and msg_type == wf.MSG_TREE
    assert wf.decode_tree_verdict(ip)[0] == 1


def test_tree_digest_ledger_mirrors_partition_walk():
    """The framed MSG_TREE byte ledger ``partition_pair`` reports is the
    exact sum of the per-level digest + verdict frame lengths."""
    from repro.tree import TreeConfig, partition_pair
    from repro.tree.partition import (
        level_digests_ref,
        level_verdicts,
        split_ranges,
        SPAN,
    )

    rng = np.random.default_rng(5)
    univ = rng.choice(1 << 32, size=500, replace=False).astype(np.uint32)
    a, b = np.unique(univ[:300]), np.unique(univ[180:])
    tcfg = TreeConfig(seed=3)
    _, stats = partition_pair(a, b, tcfg)

    total = 0
    frontier = [(0, SPAN)]
    level = 0
    while frontier:
        cnt_a, cs_a, sk_a = level_digests_ref(a, frontier, tcfg)
        cnt_b, cs_b, sk_b = level_digests_ref(b, frontier, tcfg)
        verdicts, leaf_ds = level_verdicts(
            level, cnt_a, cs_a, sk_a, cnt_b, cs_b, sk_b, tcfg
        )
        total += len(wf.encode_tree_digest(level, cnt_a, cs_a, sk_a))
        total += len(wf.encode_tree_verdict(level, verdicts, leaf_ds))
        frontier = split_ranges(frontier, verdicts)
        level += 1
    assert stats.digest_bytes == total > 0


# ---------------------------------------------------------------------------
# parity extension frames (rateless recovery, DESIGN.md §16)
# ---------------------------------------------------------------------------


def _random_parity(rng, max_sessions=4):
    """Random [(n_units, dt, m)] schema + matching incremental blocks."""
    schema, blocks = [], []
    for _ in range(rng.integers(1, max_sessions + 1)):
        m = int(rng.integers(4, 11))
        dt = int(rng.integers(1, 9))
        u = int(rng.integers(1, 7))
        schema.append((u, dt, m))
        blocks.append(
            (rng.integers(0, 1 << m, size=(u, dt), dtype=np.int64), m)
        )
    return schema, blocks


@given(seed=st.integers(min_value=0, max_value=2**32 - 1))
@settings(max_examples=25, deadline=None)
def test_parity_roundtrip(seed):
    rng = np.random.default_rng(seed)
    schema, blocks = _random_parity(rng)
    rnd = int(rng.integers(1, 13))
    level = int(rng.integers(1, 5))
    buf = wf.encode_parity(rnd, level, blocks)
    # batched encoder is byte-identical to the per-bit oracle
    assert buf == wf.encode_parity_scalar(rnd, level, blocks)
    payload = _unframe(buf, wf.MSG_PARITY)
    for decode in (wf.decode_parity, wf.decode_parity_scalar):
        got_rnd, got_level, got = decode(payload, schema)
        assert (got_rnd, got_level) == (rnd, level)
        for (inc, _), g in zip(blocks, got):
            np.testing.assert_array_equal(g, inc)
    # the payload past the header is exactly the Formula-(1) ledger
    bits = sum(wf.parity_ledger_bits(u, dt, m) for u, dt, m in schema)
    header = len(encode_uvarint(rnd)) + len(encode_uvarint(level))
    assert len(payload) == header + (bits + 7) // 8


def test_parity_rejects_level_range_truncation_and_corruption():
    rng = np.random.default_rng(0)
    schema, blocks = _random_parity(rng)
    # level 0 is the base sketch, never a parity frame
    with pytest.raises(WireError, match="level"):
        wf.encode_parity(3, 0, blocks)
    buf = wf.encode_parity(3, 1, blocks)
    payload = _unframe(buf, wf.MSG_PARITY)
    # corrupt the level varint down to 0 (header is uvarint(3) uvarint(1))
    with pytest.raises(WireError, match="level"):
        wf.decode_parity(payload[:1] + b"\x00" + payload[2:], schema)
    # a syndrome outside GF(2^m) is rejected at encode time
    (inc, m) = blocks[0]
    bad = inc.copy()
    bad[0, 0] = 1 << m
    with pytest.raises(WireError, match="range"):
        wf.encode_parity(3, 1, [(bad, m)] + blocks[1:])
    # truncation: the bit field runs past the shortened buffer
    with pytest.raises(WireTruncated):
        wf.decode_parity(payload[:-1], schema)
    # trailing bytes after the bit stream are rejected
    with pytest.raises(WireError, match="unconsumed"):
        wf.decode_parity(payload + b"\x00", schema)
    # nonzero pad bits are corruption, not slack
    pschema = [(1, 1, 5)]
    pbuf = _unframe(
        wf.encode_parity(2, 1, [(np.zeros((1, 1), dtype=np.int64), 5)]),
        wf.MSG_PARITY,
    )
    with pytest.raises(WireError, match="padding"):
        wf.decode_parity(pbuf[:-1] + bytes([pbuf[-1] | 1]), pschema)


def test_parity_legal_inside_mux_and_epoch():
    """MSG_PARITY is an ordinary round frame: it rides inside the hub's
    MSG_MUX and the continuous-sync MSG_EPOCH envelopes (which reject only
    nested *envelopes*), in both nesting orders mux(epoch(parity)) never
    arises but each single wrap must pass."""
    rng = np.random.default_rng(1)
    schema, blocks = _random_parity(rng)
    inner = wf.encode_parity(2, 1, blocks)
    ch, msg_type, ip = wf.decode_mux(
        _unframe(wf.encode_mux(5, inner), wf.MSG_MUX)
    )
    assert ch == 5 and msg_type == wf.MSG_PARITY
    got_rnd, got_level, got = wf.decode_parity(ip, schema)
    assert (got_rnd, got_level) == (2, 1)
    np.testing.assert_array_equal(got[0], blocks[0][0])
    e, msg_type, ip = wf.decode_epoch(
        _unframe(wf.encode_epoch(3, inner), wf.MSG_EPOCH)
    )
    assert e == 3 and msg_type == wf.MSG_PARITY
    assert wf.decode_parity(ip, schema)[0] == 2
