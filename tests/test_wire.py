"""repro.wire codec: round-trip properties, rejection paths, size mirrors.

Every message type must satisfy ``decode(encode(m)) == m`` (hypothesis
property tests over random message contents), reject truncated buffers and
corrupted frames, and — for the phase-0 messages — produce framed lengths
exactly equal to the numpy-pure mirrors in ``core.tow`` that the protocol's
byte accounting uses.
"""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.tow import dhat_bytes, sketch_bytes
from repro.wire import frames as wf
from repro.wire.frames import ReplyUnit, WireError, WireTruncated
from repro.wire.varint import (
    BitReader,
    BitWriter,
    decode_uvarint,
    encode_uvarint,
    unzigzag,
    uvarint_len,
    zigzag,
)


def _unframe(buf: bytes, expect_type: int) -> bytes:
    got = wf.split_frame(buf)
    assert got is not None, "whole frame must parse"
    msg_type, payload, consumed = got
    assert msg_type == expect_type
    assert consumed == len(buf), "no trailing bytes"
    return payload


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------


@given(v=st.integers(min_value=0, max_value=2**63 - 1))
@settings(max_examples=60, deadline=None)
def test_uvarint_roundtrip(v):
    buf = encode_uvarint(v)
    assert len(buf) == uvarint_len(v)
    got, off = decode_uvarint(buf)
    assert got == v and off == len(buf)


@given(n=st.integers(min_value=-(2**31), max_value=2**31 - 1))
@settings(max_examples=60, deadline=None)
def test_zigzag_roundtrip(n):
    z = zigzag(n)
    assert z >= 0 and unzigzag(z) == n


def test_uvarint_truncated_and_overlong():
    with pytest.raises(WireTruncated):
        decode_uvarint(b"\x80\x80")          # continuation bit, no terminator
    with pytest.raises(WireError):
        decode_uvarint(b"\xff" * 10 + b"\x01")  # > 64 bits


@given(
    fields=st.lists(
        st.tuples(st.integers(min_value=0, max_value=2**20), st.integers(1, 21)),
        max_size=40,
    )
)
@settings(max_examples=40, deadline=None)
def test_bitstream_roundtrip(fields):
    w = BitWriter()
    vals = [(v & ((1 << nb) - 1), nb) for v, nb in fields]
    for v, nb in vals:
        w.write(v, nb)
    buf = w.getvalue()
    assert len(buf) == (w.bit_length + 7) // 8
    r = BitReader(buf)
    for v, nb in vals:
        assert r.read(nb) == v
    r.finish()


def test_bitstream_rejects_nonzero_padding():
    r = BitReader(b"\x81")  # one flag bit + nonzero pad
    assert r.read(1) == 1
    with pytest.raises(WireError):
        r.finish()


# ---------------------------------------------------------------------------
# frame envelope
# ---------------------------------------------------------------------------


def test_split_frame_incomplete_and_unknown_type():
    f = wf.encode_dhat(12345)
    assert wf.split_frame(f[:1]) is None          # header only
    assert wf.split_frame(f[:-1]) is None         # body short by one byte
    bad = bytearray(f)
    bad[1] = 0x7F                                  # unknown message type
    with pytest.raises(WireError):
        wf.split_frame(bytes(bad))
    with pytest.raises(WireError):
        wf.split_frame(b"\x00")                    # zero-length body


# ---------------------------------------------------------------------------
# phase-0 frames
# ---------------------------------------------------------------------------


@given(
    set_size=st.integers(min_value=0, max_value=50_000),
    ell=st.integers(min_value=1, max_value=160),
    seed=st.integers(min_value=0, max_value=2**32 - 1),
)
@settings(max_examples=30, deadline=None)
def test_tow_sketch_roundtrip_and_size(set_size, ell, seed):
    rng = np.random.default_rng(seed)
    values = rng.integers(-set_size, set_size + 1, size=ell, dtype=np.int64)
    buf = wf.encode_tow_sketch(values, set_size)
    # the framed length is exactly what core.tow.sketch_bytes accounts
    assert len(buf) == sketch_bytes(set_size, ell)
    got_size, got_vals = wf.decode_tow_sketch(_unframe(buf, wf.MSG_TOW_SKETCH))
    assert got_size == set_size
    np.testing.assert_array_equal(got_vals, values)


def test_tow_sketch_rejects_out_of_range_and_truncation():
    with pytest.raises(WireError):
        wf.encode_tow_sketch(np.array([11]), set_size=10)
    buf = wf.encode_tow_sketch(np.arange(-3, 4), set_size=5)
    payload = _unframe(buf, wf.MSG_TOW_SKETCH)
    with pytest.raises(WireError):
        wf.decode_tow_sketch(payload[:-2])         # truncated bit stream
    with pytest.raises(WireError):
        wf.decode_tow_sketch(payload + b"\x00")    # trailing garbage


@given(num=st.integers(min_value=0, max_value=2**62))
@settings(max_examples=40, deadline=None)
def test_dhat_roundtrip_and_size(num):
    buf = wf.encode_dhat(num)
    assert len(buf) == dhat_bytes(num)
    assert wf.decode_dhat(_unframe(buf, wf.MSG_DHAT)) == num


def test_dhat_rejects_trailing_bytes():
    with pytest.raises(WireError):
        wf.decode_dhat(_unframe(wf.encode_dhat(7), wf.MSG_DHAT) + b"\x01")


# ---------------------------------------------------------------------------
# round frames (schema-driven)
# ---------------------------------------------------------------------------


def _random_schema(rng, max_sessions=4):
    schema = []
    for _ in range(rng.integers(1, max_sessions + 1)):
        m = int(rng.integers(4, 11))
        t = int(rng.integers(1, 9))
        schema.append((int(rng.integers(1, 7)), t, m))
    return schema


@given(seed=st.integers(min_value=0, max_value=2**32 - 1))
@settings(max_examples=25, deadline=None)
def test_round_sketches_roundtrip(seed):
    rng = np.random.default_rng(seed)
    schema = _random_schema(rng)
    rnd = int(rng.integers(1, 13))
    blocks = [
        (rng.integers(0, 1 << m, size=(u, t), dtype=np.int64), m)
        for u, t, m in schema
    ]
    buf = wf.encode_round_sketches(rnd, blocks)
    got_rnd, got = wf.decode_round_sketches(
        _unframe(buf, wf.MSG_ROUND_SKETCHES), schema
    )
    assert got_rnd == rnd
    for (sk, _), g, (u, t, m) in zip(blocks, got, schema):
        np.testing.assert_array_equal(g, sk)
        assert wf.sketches_ledger_bits(u, t, m) == u * t * m


def _random_reply(rng, schema):
    entries = []
    for u, t, m in schema:
        n = (1 << m) - 1
        ok = rng.random(u) < 0.8
        units = []
        for slot in range(u):
            if not ok[slot]:
                units.append(None)
                continue
            k = int(rng.integers(0, t + 1))
            units.append(
                ReplyUnit(
                    positions=np.sort(
                        rng.choice(n, size=k, replace=False)
                    ).astype(np.int64),
                    xors=rng.integers(0, 1 << 32, size=k, dtype=np.uint64).astype(
                        np.uint32
                    ),
                    csum=int(rng.integers(0, 1 << 32)),
                )
            )
        entries.append((ok, units))
    return entries


@given(seed=st.integers(min_value=0, max_value=2**32 - 1))
@settings(max_examples=25, deadline=None)
def test_round_reply_roundtrip(seed):
    rng = np.random.default_rng(seed)
    schema = _random_schema(rng)
    entries = _random_reply(rng, schema)
    rnd = int(rng.integers(1, 13))
    buf = wf.encode_round_reply(rnd, entries, schema)
    got_rnd, got = wf.decode_round_reply(_unframe(buf, wf.MSG_ROUND_REPLY), schema)
    assert got_rnd == rnd
    for (ok, units), (gok, gunits), (u, t, m) in zip(entries, got, schema):
        np.testing.assert_array_equal(gok, ok)
        assert gunits == units
        # ledger bits match Formula (1): 1/unit + k*(m+32) + 32 per decode
        exp = u + sum(
            len(x.positions) * (m + 32) + 32 for x in units if x is not None
        )
        assert wf.reply_ledger_bits(gok, gunits, m) == exp


def test_round_reply_rejects_bad_counts_and_positions():
    schema = [(1, 2, 4)]                           # n = 15
    ok = np.array([True])
    unit = ReplyUnit(
        positions=np.array([3]), xors=np.array([7], np.uint32), csum=1
    )
    buf = wf.encode_round_reply(1, [(ok, [unit])], schema)
    payload = _unframe(buf, wf.MSG_ROUND_REPLY)
    # schema mismatch: t=1 makes the stored count 1 overflow cbits
    with pytest.raises(WireError):
        wf.decode_round_reply(payload, [(2, 2, 4)])
    with pytest.raises(WireError):
        wf.decode_round_reply(payload[:-1], schema)  # truncated
    with pytest.raises(WireError):
        wf.encode_round_reply(
            1,
            [(ok, [ReplyUnit(np.array([15]), np.array([0], np.uint32), 0)])],
            schema,
        )  # position == n is out of range
    with pytest.raises(WireError):
        wf.encode_round_reply(
            1,
            [(ok, [ReplyUnit(np.array([1, 2, 3]), np.zeros(3, np.uint32), 0)])],
            schema,
        )  # k > t


@given(seed=st.integers(min_value=0, max_value=2**32 - 1))
@settings(max_examples=25, deadline=None)
def test_round_outcome_roundtrip(seed):
    rng = np.random.default_rng(seed)
    counts = [int(rng.integers(1, 9)) for _ in range(int(rng.integers(1, 5)))]
    done = [rng.random(u) < 0.5 for u in counts]
    rnd = int(rng.integers(1, 13))
    buf = wf.encode_round_outcome(rnd, done)
    got_rnd, got = wf.decode_round_outcome(_unframe(buf, wf.MSG_ROUND_OUTCOME), counts)
    assert got_rnd == rnd
    for d, g in zip(done, got):
        np.testing.assert_array_equal(g, d)


@given(seed=st.integers(min_value=0, max_value=2**32 - 1))
@settings(max_examples=25, deadline=None)
def test_verify_roundtrip(seed):
    rng = np.random.default_rng(seed)
    n_sessions = int(rng.integers(1, 9))
    entries = [
        (bool(rng.random() < 0.5), int(rng.integers(0, 1 << 32)))
        for _ in range(n_sessions)
    ]
    buf = wf.encode_verify(entries)
    assert wf.decode_verify(_unframe(buf, wf.MSG_VERIFY), n_sessions) == entries
    flags = [bool(rng.random() < 0.5) for _ in range(n_sessions)]
    buf = wf.encode_verify_ack(flags)
    assert wf.decode_verify_ack(_unframe(buf, wf.MSG_VERIFY_ACK), n_sessions) == flags


@pytest.mark.parametrize("seed", [0, 1, 2, 3, 4, 5, 6, 7])
def test_round_frames_roundtrip_seeded(seed):
    """Deterministic mirror of the hypothesis properties (always runs, even
    without the optional hypothesis dependency)."""
    rng = np.random.default_rng(seed)
    schema = _random_schema(rng)
    blocks = [
        (rng.integers(0, 1 << m, size=(u, t), dtype=np.int64), m)
        for u, t, m in schema
    ]
    rnd = int(rng.integers(1, 13))
    _, got = wf.decode_round_sketches(
        _unframe(wf.encode_round_sketches(rnd, blocks), wf.MSG_ROUND_SKETCHES),
        schema,
    )
    for (sk, _), g in zip(blocks, got):
        np.testing.assert_array_equal(g, sk)

    entries = _random_reply(rng, schema)
    _, got = wf.decode_round_reply(
        _unframe(wf.encode_round_reply(rnd, entries, schema), wf.MSG_ROUND_REPLY),
        schema,
    )
    for (ok, units), (gok, gunits) in zip(entries, got):
        np.testing.assert_array_equal(gok, ok)
        assert gunits == units

    set_size = int(rng.integers(0, 10_000))
    ell = int(rng.integers(1, 160))
    values = rng.integers(-set_size, set_size + 1, size=ell, dtype=np.int64)
    buf = wf.encode_tow_sketch(values, set_size)
    assert len(buf) == sketch_bytes(set_size, ell)
    got_size, got_vals = wf.decode_tow_sketch(_unframe(buf, wf.MSG_TOW_SKETCH))
    assert got_size == set_size
    np.testing.assert_array_equal(got_vals, values)

    num = int(rng.integers(0, 1 << 48))
    buf = wf.encode_dhat(num)
    assert len(buf) == dhat_bytes(num)
    assert wf.decode_dhat(_unframe(buf, wf.MSG_DHAT)) == num


def test_verify_rejects_wrong_session_count():
    buf = _unframe(wf.encode_verify([(True, 5), (False, 9)]), wf.MSG_VERIFY)
    with pytest.raises(WireError):
        wf.decode_verify(buf, 3)                   # wants more than encoded
    with pytest.raises(WireError):
        wf.decode_verify(buf, 1)                   # leftover bytes


# ---------------------------------------------------------------------------
# mux envelope (hub multiplexing, DESIGN.md §10)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("channel", [1, 2, 127, 128, 70000])
def test_mux_roundtrip_and_overhead(channel):
    inner = wf.encode_dhat(123456)
    buf = wf.encode_mux(channel, inner)
    payload = _unframe(buf, wf.MSG_MUX)
    ch, msg_type, inner_payload = wf.decode_mux(payload)
    assert ch == channel and msg_type == wf.MSG_DHAT
    assert wf.decode_dhat(inner_payload) == 123456
    assert len(buf) - len(inner) == wf.mux_overhead_bytes(channel, len(inner))


def test_mux_rejects_zero_channel_nesting_and_trailing():
    inner = wf.encode_dhat(7)
    with pytest.raises(WireError, match="channel 0"):
        wf.encode_mux(0, inner)
    # nested mux envelopes are rejected
    nested = wf.encode_mux(3, wf.encode_mux(2, inner))
    with pytest.raises(WireError, match="nested"):
        wf.decode_mux(_unframe(nested, wf.MSG_MUX))
    # trailing bytes after the inner frame are rejected
    buf = wf.encode_mux(3, inner)
    payload = _unframe(buf, wf.MSG_MUX) + b"\x00"
    with pytest.raises(WireError, match="trailing"):
        wf.decode_mux(payload)
    # a truncated inner frame is a truncation error
    payload = _unframe(buf, wf.MSG_MUX)
    with pytest.raises(WireTruncated):
        wf.decode_mux(payload[:-1])


# ---------------------------------------------------------------------------
# epoch envelope (continuous sync, DESIGN.md §11)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("epoch", [1, 2, 127, 128, 70000])
def test_epoch_roundtrip_and_overhead(epoch):
    # wrapped d̂ handshake frame: inner ledger bits, envelope overhead
    inner = wf.encode_dhat(4242)
    buf = wf.encode_epoch(epoch, inner)
    e, msg_type, inner_payload = wf.decode_epoch(_unframe(buf, wf.MSG_EPOCH))
    assert e == epoch and msg_type == wf.MSG_DHAT
    assert wf.decode_dhat(inner_payload) == 4242
    assert len(buf) - len(inner) == wf.epoch_overhead_bytes(epoch, len(inner))
    # bare epoch-open: no inner frame at all
    bare = wf.encode_epoch(epoch)
    assert wf.decode_epoch(_unframe(bare, wf.MSG_EPOCH)) == (epoch, None, None)
    assert len(bare) == wf.epoch_overhead_bytes(epoch, 0)


def test_epoch_rejects_zero_epoch_nesting_and_trailing():
    inner = wf.encode_dhat(9)
    # epoch 0 is the admission epoch: never carried by MSG_EPOCH
    with pytest.raises(WireError, match="epoch 0"):
        wf.encode_epoch(0, inner)
    with pytest.raises(WireError, match="epoch 0"):
        wf.decode_epoch(b"\x00" + inner)
    # nested envelopes are rejected in both flavors
    nested = wf.encode_epoch(3, wf.encode_epoch(2, inner))
    with pytest.raises(WireError, match="nested"):
        wf.decode_epoch(_unframe(nested, wf.MSG_EPOCH))
    muxed = wf.encode_epoch(3, wf.encode_mux(2, inner))
    with pytest.raises(WireError, match="nested"):
        wf.decode_epoch(_unframe(muxed, wf.MSG_EPOCH))
    # trailing bytes after the inner frame are rejected
    buf = wf.encode_epoch(3, inner)
    payload = _unframe(buf, wf.MSG_EPOCH) + b"\x00"
    with pytest.raises(WireError, match="trailing"):
        wf.decode_epoch(payload)
    # a truncated inner frame is a truncation error
    payload = _unframe(buf, wf.MSG_EPOCH)
    with pytest.raises(WireTruncated):
        wf.decode_epoch(payload[:-1])
    # the mux wrap goes outside: MSG_EPOCH inside MSG_MUX is legal
    ch, msg_type, ip = wf.decode_mux(
        _unframe(wf.encode_mux(5, buf), wf.MSG_MUX)
    )
    assert ch == 5 and msg_type == wf.MSG_EPOCH
    assert wf.decode_epoch(ip)[0] == 3
