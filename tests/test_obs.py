"""The observability layer (DESIGN.md §14): registry, tracing, export.

What's locked down here:

* the **schema contract** — `repro.obs.SCHEMA` is self-consistent, the
  DESIGN.md §14 table is generated from it and must match it *exactly*
  (name, kind, unit, owner, description), and `Recorder` rejects any
  undeclared key with `MetricsError`, so metric names cannot drift from
  the documentation;
* **derived-snapshot parity** — the legacy dict surfaces
  (`server.stats`, `hub.stats`, endpoint `wire_stats`) are rebuilt from
  the registry and must stay value-identical to the numbers queryable by
  dotted name, including under a seeded `ChaosTransport` run
  (`sessions_degraded`, `resume_replay_bytes`, `peers_failed_by_kind`);
* the **store-mark regression** — `submit()` after `run()` discards the
  finished batch *and* the recorder's store mark, so the next run's
  per-run store ledger diffs against the new batch's zeros instead of a
  dead batch's cumulative counters;
* **tracing acceptance** — a hub chaos run with one shared tracer
  produces a Chrome trace (Perfetto-loadable: every complete event
  carries ts/dur/pid/tid) showing per-peer round spans, ARQ
  retransmits, and a resume transition; both export formats round-trip
  through `load_events`; `tools/trace_report.py` summarizes occupancy,
  per-peer traffic, and the observed-vs-`core.markov` round histogram.
"""
import json
import pathlib
import re
import sys
import threading

import numpy as np
import pytest

from repro.core.pbs import PBSConfig, reconcile
from repro.core.simdata import make_pair
from repro.net import (
    AliceEndpoint,
    ChaosTransport,
    FaultPlan,
    HubEndpoint,
    InMemoryDuplex,
    ReliableTransport,
    TransportError,
    run_hub,
)
from repro.obs import (
    NULL_TRACER,
    SCHEMA,
    MetricsError,
    Recorder,
    Tracer,
    load_events,
)
from repro.recon import ReconcileServer

ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "tools"))

import trace_report  # noqa: E402


# ---------------------------------------------------------------------------
# schema contract
# ---------------------------------------------------------------------------


def test_schema_self_consistent():
    for name, spec in SCHEMA.items():
        assert spec.name == name
        assert name.startswith(spec.owner + ".")
        assert spec.key == name[len(spec.owner) + 1:]
        assert spec.desc


_ROW_RE = re.compile(
    r"^\| `([\w.]+)` \| (\w+) \| (\w+) \| (\w+) \| (.+?) \|$", re.MULTILINE
)


def test_design_section14_table_matches_schema_exactly():
    """The §14 table IS the schema: every metric row matches its
    MetricSpec field for field, with no extras on either side."""
    text = (ROOT / "DESIGN.md").read_text()
    sect = text.split("## §14", 1)
    assert len(sect) == 2, "DESIGN.md has no §14 section"
    rows = {m.group(1): m.groups()[1:] for m in _ROW_RE.finditer(sect[1])}
    assert set(rows) == set(SCHEMA), (
        f"table/schema drift: only in table {set(rows) - set(SCHEMA)}, "
        f"only in schema {set(SCHEMA) - set(rows)}"
    )
    for name, (kind, unit, owner, desc) in rows.items():
        spec = SCHEMA[name]
        assert (kind, unit, owner) == (spec.kind, spec.unit, spec.owner), name
        assert desc == spec.desc, name


def test_recorder_rejects_undeclared_keys():
    r = Recorder()
    with pytest.raises(MetricsError):
        r.inc("server.not_a_metric")
    with pytest.raises(MetricsError):
        r.set("nowhere.rounds", 1)
    with pytest.raises(MetricsError):
        r.publish("server", {"rounds": 1, "bogus_key": 2})
    # error inherits KeyError so existing dict-shaped handling still works
    assert issubclass(MetricsError, KeyError)


def test_recorder_basics_and_views():
    r = Recorder()
    r.inc("wire.retransmits")
    r.inc("wire.retransmits", 2)
    r.set("wire.rto_ms", 12.5)
    r.set("hub.peers_failed_by_kind", {"deadline": 1})
    r.inc("hub.peers_failed_by_kind", label="transport")
    assert r.value("wire.retransmits") == 3
    assert r.value("wire.rto_ms") == 12.5
    assert r.value("hub.peers_failed_by_kind") == {
        "deadline": 1, "transport": 1
    }
    assert r.value("hub.peers_failed_by_kind", label="deadline") == 1
    assert r.value("server.rounds", default=0) == 0
    view = r.view("wire")
    assert view["retransmits"] == 3 and view["rto_ms"] == 12.5
    # views hand out copies: mutating one can't corrupt the registry
    r.view("hub")["peers_failed_by_kind"]["deadline"] = 99
    assert r.value("hub.peers_failed_by_kind", label="deadline") == 1
    snap = r.snapshot()
    assert snap["wire.retransmits"] == 3


def test_recorder_marks():
    r = Recorder()
    r.mark("store", {"store_builds": 2, "store_delta_bytes": 100})
    d = r.delta_since_mark("store", {"store_builds": 5,
                                     "store_delta_bytes": 160})
    assert d == {"store_builds": 3, "store_delta_bytes": 60}
    r.drop_mark("store")
    d = r.delta_since_mark("store", {"store_builds": 5,
                                     "store_delta_bytes": 160})
    assert d == {"store_builds": 5, "store_delta_bytes": 160}
    r.drop_mark("store")   # idempotent on a missing mark


# ---------------------------------------------------------------------------
# derived snapshots: legacy dicts == registry values
# ---------------------------------------------------------------------------


def test_server_stats_is_registry_view():
    a, b = make_pair(600, 10, np.random.default_rng(0))
    srv = ReconcileServer()
    srv.submit(a, b, cfg=PBSConfig(seed=0), d_known=10)
    res = srv.run()[0]
    assert res.success
    st = srv.stats
    assert st == srv.recorder.view("server")
    assert srv.recorder.value("server.rounds") == st["rounds"]
    assert srv.recorder.value("server.h2d_ratio") == st["h2d_ratio"]
    # kernel retrace attribution flows into the kernels owner too
    assert srv.recorder.value("kernels.retraces_total") is not None
    by_fn = srv.recorder.value("kernels.retraces_by_fn")
    assert isinstance(by_fn, dict)


def test_submit_after_run_resets_store_mark():
    """Regression: a post-run ``submit`` discards the finished batch; the
    recorder's store mark must die with it, or the next run's store
    ledger diffs against the dead batch's counters (reporting 0 builds
    for a store that was just built)."""
    a, b = make_pair(600, 10, np.random.default_rng(0))
    srv = ReconcileServer()
    srv.submit(a, b, cfg=PBSConfig(seed=0), d_known=10)
    srv.run()
    assert srv.stats["store_builds"] >= 1

    a2, b2 = make_pair(600, 10, np.random.default_rng(1))
    sid = srv.submit(a2, b2, cfg=PBSConfig(seed=1), d_known=10)
    res = srv.run()[sid]
    oracle = reconcile(a2, b2, PBSConfig(seed=1), d_known=10)
    assert res.success and res.diff == oracle.diff
    st = srv.stats
    # the fresh batch built exactly one store (only the new session has
    # live work); the dead-mark bug reported 0 here
    assert st["store_builds"] == 1
    assert st["store_compactions"] == 0 and st["h2d_delta_bytes"] == 0


def _crash_resume_hub(tracer=None, arq_peer=False, seed=23):
    """Two-peer hub under seeded chaos: peer 0 crash-resumes, peer 1
    (optionally) lives behind a lossy seeded ARQ channel.  One shared
    tracer covers hub, endpoints, transports, and injectors."""
    rng = np.random.default_rng(seed)
    univ = rng.choice(1 << 20, size=3000, replace=False).astype(np.uint32)
    cfg_kw = dict(n_override=127, t_override=7, g_override=4)
    hub = HubEndpoint(resume_window=30.0, recv_deadline=10.0, tracer=tracer)
    alices, pending = {}, {}

    a0, b0 = univ[:2600], univ[400:]
    d0 = len(np.setxor1d(a0, b0))
    cfg0 = PBSConfig(seed=seed, **cfg_kw)
    raw0, th0 = InMemoryDuplex.pair()
    t0 = ChaosTransport(raw0, FaultPlan(crash_after_sends=1), tracer=tracer)
    ch0 = hub.add_peer(th0, label="crasher")
    hub.submit(ch0, b0, cfg=cfg0, d_known=d0)
    ep0 = AliceEndpoint(t0, channel=ch0, tracer=tracer)
    ep0.submit(a0, cfg=cfg0, d_known=d0)
    alices[ch0] = ep0
    oracles = {ch0: reconcile(a0, b0, cfg0, d_known=d0)}

    ch1 = None
    if arq_peer:
        a1, b1 = make_pair(700, 60, np.random.default_rng(seed + 1))
        cfg1 = PBSConfig(seed=seed + 1, **cfg_kw)
        raw1, rawh1 = InMemoryDuplex.pair()
        chaos1 = ChaosTransport(
            raw1, FaultPlan(seed=seed + 50, loss=0.15, dup=0.05),
            tracer=tracer,
        )
        t1 = ReliableTransport(chaos1, timeout=0.02, max_retries=400,
                               seed=1, tracer=tracer)
        th1 = ReliableTransport(rawh1, timeout=0.02, max_retries=400,
                                seed=101, tracer=tracer)
        ch1 = hub.add_peer(th1, label="lossy")
        hub.submit(ch1, b1, cfg=cfg1, d_known=60)
        ep1 = AliceEndpoint(t1, channel=ch1, tracer=tracer)
        ep1.submit(a1, cfg=cfg1, d_known=60)
        alices[ch1] = ep1
        oracles[ch1] = reconcile(a1, b1, cfg1, d_known=60)

    def on_barrier(rnd):
        if "t" in pending and hub._peers[ch0].suspended:
            hub.resume_peer(ch0, pending.pop("t"))

    hub.on_barrier = on_barrier

    def drive0():
        try:
            return alices[ch0].run()
        except TransportError:
            pass
        na, nh = InMemoryDuplex.pair()
        pending["t"] = nh
        alices[ch0].resume(na)
        return alices[ch0].resume_run()

    fns = {ch0: drive0}
    if ch1 is not None:
        fns[ch1] = alices[ch1].run
    state, threads = {}, []
    for ch, fn in fns.items():
        def runner(ch=ch, fn=fn):
            state[ch] = fn()
        t = threading.Thread(target=runner, daemon=True)
        threads.append(t)
        t.start()
    outcomes = hub.serve()
    for t in threads:
        t.join(timeout=120)
        assert not t.is_alive(), "peer thread leaked"
    for ch, oracle in oracles.items():
        res = state[ch][0]
        assert res.success and res.diff == oracle.diff
        assert res.bytes_sent == oracle.bytes_sent
    return hub, alices, outcomes, ch0, ch1


def test_chaos_registry_parity_with_legacy_stats():
    """Satellite: the chaos stats read through the registry match the
    legacy dicts exactly under a seeded ChaosTransport run."""
    hub, alices, outcomes, ch0, _ = _crash_resume_hub()
    st = hub.stats
    assert outcomes[ch0].error_kind == "resumed"
    assert st["peers_resumed"] == 1 and st["resume_replay_bytes"] > 0
    rec = hub.recorder
    for key in ("peers_resumed", "resume_replay_bytes", "sessions_degraded",
                "peers_failed", "peers_failed_by_kind", "rounds", "epoch"):
        assert rec.value(f"hub.{key}") == st[key], key
    # per-peer wire stats are registry views on the peer's own recorder
    hw = hub._peers[ch0].wire_stats()
    prec = hub._peers[ch0].recorder
    for key, val in hw.items():
        assert prec.value(f"wire.{key}") == val, key
    aw = alices[ch0].wire_stats
    arec = alices[ch0].recorder
    for key, val in aw.items():
        assert arec.value(f"wire.{key}") == val, key
    assert arec.value("endpoint.resumes") == alices[ch0].resumes == 1


def test_eviction_and_degradation_registry_parity():
    """peers_failed_by_kind and sessions_degraded hold registry/legacy
    parity on the eviction and degradation-ladder paths too."""
    rng = np.random.default_rng(17)
    univ = rng.choice(1 << 20, size=2400, replace=False).astype(np.uint32)
    a, b = univ[:2100], univ[300:]
    cfg = PBSConfig(seed=8)
    d = len(np.setxor1d(a, b))
    t_a_raw, t_h = InMemoryDuplex.pair()
    t_a = ChaosTransport(t_a_raw, FaultPlan(crash_after_sends=2))
    hub = HubEndpoint(resume_window=0.3, recv_deadline=5.0)
    ch = hub.add_peer(t_h, label="gone")
    hub.submit(ch, b, cfg=cfg, d_known=d)
    ep = AliceEndpoint(t_a, channel=ch)
    ep.submit(a, cfg=cfg, d_known=d)

    def drive():
        with pytest.raises(TransportError):
            ep.run()

    th = threading.Thread(target=drive, daemon=True)
    th.start()
    hub.serve()
    th.join(timeout=60)
    st = hub.stats
    assert st["peers_failed_by_kind"] == {"transport": 1}
    assert hub.recorder.value("hub.peers_failed_by_kind") == {"transport": 1}
    assert hub.recorder.value("hub.peers_failed") == st["peers_failed"] == 1

    # degradation ladder: hopeless d̂ = 250 against d = 1000, budget 2
    rngd = np.random.default_rng(11)
    univ = rngd.choice(1 << 20, size=4000, replace=False).astype(np.uint32)
    th_a, th_h = InMemoryDuplex.pair()
    dhub = HubEndpoint(degrade=True, recv_deadline=30.0)
    dcfg = PBSConfig(seed=5, max_rounds=2)
    dch = dhub.add_peer(th_h)
    dhub.submit(dch, univ[500:], cfg=dcfg, d_known=250)
    dep = AliceEndpoint(th_a, channel=dch, degrade=True)
    dep.submit(univ[:3500], cfg=dcfg, d_known=250)
    _, dresults, derrors = run_hub(dhub, {dch: dep})
    assert not derrors and dresults[dch][0].success
    dst = dhub.stats
    assert dst["sessions_degraded"] >= 1
    assert dhub.recorder.value("hub.sessions_degraded") == dst["sessions_degraded"]
    dep.wire_stats    # the endpoint.* freeze point
    assert dep.recorder.value("endpoint.sessions_degraded") == dep.sessions_degraded


# ---------------------------------------------------------------------------
# tracing: spans, exports, acceptance trace
# ---------------------------------------------------------------------------


def test_null_tracer_is_inert_and_shared():
    assert NULL_TRACER.enabled is False
    s1 = NULL_TRACER.span("x", cat="device", anything=1)
    s2 = NULL_TRACER.annotate("y")
    with s1:
        pass
    NULL_TRACER.instant("z")
    NULL_TRACER.counter("c", 1)
    assert s1 is s2    # one shared no-op context manager, zero allocation


def test_tracer_span_structure():
    tr = Tracer()
    with tr.span("outer", cat="host", k=1):
        with tr.span("inner", cat="device"):
            pass
    tr.instant("mark", v=2)
    tr.counter("gauge", 7)
    evs = tr.events()
    by_name = {e["name"]: e for e in evs}
    assert by_name["outer"]["ph"] == "X" and by_name["outer"]["args"] == {"k": 1}
    assert by_name["inner"]["cat"] == "device"
    # inner closed first and nests within outer on the timeline
    assert by_name["inner"]["ts"] >= by_name["outer"]["ts"]
    assert (by_name["inner"]["ts"] + by_name["inner"]["dur"]
            <= by_name["outer"]["ts"] + by_name["outer"]["dur"] + 1e-6)
    assert by_name["mark"]["ph"] == "i" and by_name["mark"]["s"] == "t"
    assert by_name["gauge"]["ph"] == "C"
    assert by_name["thread_name"]["ph"] == "M"
    assert all(e["pid"] == 1 for e in evs)


def test_exports_roundtrip(tmp_path):
    tr = Tracer()
    with tr.span("a"):
        pass
    tr.instant("b", x=1)
    chrome = tmp_path / "t.json"
    jsonl = tmp_path / "t.jsonl"
    n1 = tr.export_chrome(chrome)
    n2 = tr.export_jsonl(jsonl)
    assert n1 == n2 == len(tr.events())
    assert load_events(chrome) == load_events(jsonl) == tr.events()
    doc = json.loads(chrome.read_text())
    assert doc["displayTimeUnit"] == "ms"


def test_arq_retransmit_instrumentation():
    """A seeded partition drops the first datagram: the ARQ layer
    retransmits and the tracer records it, seq- and attempt-tagged."""
    tr = Tracer()
    raw_a, raw_b = InMemoryDuplex.pair()
    chaos = ChaosTransport(raw_a, FaultPlan(partitions=((0, 1),)), tracer=tr)
    ta = ReliableTransport(chaos, timeout=0.02, max_retries=50, tracer=tr)
    tb = ReliableTransport(raw_b, timeout=0.02, max_retries=50)
    got = {}

    def receiver():
        got["data"] = tb.recv(timeout=5.0)

    th = threading.Thread(target=receiver, daemon=True)
    th.start()
    ta.send(b"payload")
    th.join(timeout=10)
    assert got.get("data") == b"payload"
    assert ta.retransmits >= 1
    names = [e["name"] for e in tr.events()]
    assert "chaos.drop" in names
    retrans = [e for e in tr.events() if e["name"] == "arq.retransmit"]
    assert len(retrans) == ta.retransmits
    assert retrans[0]["args"]["attempt"] >= 1
    sends = [e for e in tr.events() if e["name"] == "arq.send"]
    assert sends and sends[0]["cat"] == "arq" and "dur" in sends[0]


def test_hub_chaos_trace_acceptance(tmp_path):
    """The ISSUE acceptance trace: ONE shared tracer across a hub chaos
    run exports a Perfetto-loadable Chrome trace showing per-peer round
    spans, ARQ retransmits, and a resume transition."""
    tr = Tracer()
    hub, alices, outcomes, ch0, ch1 = _crash_resume_hub(
        tracer=tr, arq_peer=True)
    assert outcomes[ch0].error_kind == "resumed"
    assert outcomes[ch1].ok

    path = tmp_path / "chaos_trace.json"
    n = tr.export_chrome(path)
    evs = load_events(path)
    assert len(evs) == n > 0
    names = {e["name"] for e in evs}

    # per-peer round spans, attributed by peer label and channel
    replies = [e for e in evs if e["name"] == "peer.round.reply"]
    assert {e["args"]["peer"] for e in replies} == {"crasher", "lossy"}
    assert all(e["ph"] == "X" and e["dur"] >= 0 for e in replies)
    # ARQ retransmits fired on the lossy peer and were recorded
    retrans = sum(ep.wire_stats.get("retransmits", 0)
                  for ep in alices.values())
    assert retrans >= 1
    assert "arq.retransmit" in names
    # the resume transition, both sides
    assert "peer.suspend" in names and "peer.resume" in names
    assert "resume" in names           # the Alice-side span
    assert "chaos.crash" in names
    # Perfetto-loadable: a JSON object document, complete events carry
    # ts/dur/pid/tid, instants are scoped, metadata names the threads
    doc = json.loads(path.read_text())
    assert isinstance(doc["traceEvents"], list)
    for e in doc["traceEvents"]:
        assert "name" in e and "ph" in e and "pid" in e and "tid" in e
        if e["ph"] == "X":
            assert "ts" in e and "dur" in e
        if e["ph"] == "i":
            assert e["s"] == "t"
    assert sum(e["ph"] == "M" for e in doc["traceEvents"]) >= 2  # threads


# ---------------------------------------------------------------------------
# trace_report
# ---------------------------------------------------------------------------


def test_trace_report_sections(tmp_path):
    tr = Tracer()
    srv = ReconcileServer(tracer=tr)
    for s in range(4):
        a, b = make_pair(600, 10, np.random.default_rng(s))
        srv.submit(a, b, cfg=PBSConfig(seed=s), d_known=10)
    results = srv.run()
    assert all(r.success for r in results.values())
    path = tmp_path / "t.json"
    tr.export_chrome(path)

    rep = trace_report.build_report(load_events(path))
    occ = rep["occupancy"]
    assert occ, "no occupancy rows"
    row = next(iter(occ.values()))
    assert row["device_ms"] > 0 and row["wall_ms"] >= row["device_ms"]
    assert 0 < row["device_frac"] <= 1

    peers = rep["peers"]
    assert peers["local"]["sessions"] == 4
    assert peers["local"]["diff"] == sum(len(r.diff) for r in results.values())
    assert peers["local"]["bytes"] == sum(r.bytes_sent
                                          for r in results.values())

    hist = rep["round_histogram"]
    assert hist, "no parameter classes in the histogram"
    h = hist[0]
    assert sum(h["rounds_hist"]) == h["sessions"] == 4
    assert "markov_round_fracs" in h
    assert abs(sum(h["markov_round_fracs"]) - 1.0) < 0.1

    # the CLI wrapper runs on the same file
    assert trace_report.main([str(path)]) == 0
    assert trace_report.main([str(path), "--json"]) == 0


def test_trace_report_empty_trace_fails(tmp_path):
    path = tmp_path / "empty.jsonl"
    path.write_text("")
    assert trace_report.main([str(path)]) == 1
