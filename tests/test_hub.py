"""Multi-peer hub acceptance: N concurrent peers against one HubEndpoint.

The acceptance scenario (ISSUE 4): ≥ 8 concurrent peers — mixed known-d and
estimator sessions, one straggler that goes silent mid-protocol, one peer
that disconnects mid-protocol — over both the in-memory duplex and real TCP
loopback sockets.  Every *surviving* peer's results must be byte-identical
to ``core.pbs.reconcile`` (diff, measured per-round wire ledger, counters),
the straggler and the disconnector must fail with clean per-peer
``TransportError`` outcomes without perturbing anyone else, and the hub's
``stats`` must show the fusion contract: one store upload per cohort and
2 kernel launches + 1 decode launch per cohort-round, shared across peers.
"""
import threading

import numpy as np
import pytest

from repro.core.pbs import PBSConfig, reconcile, true_diff
from repro.core.simdata import make_pair, make_pair_two_sided
from repro.net import (
    AliceEndpoint,
    HubEndpoint,
    InMemoryDuplex,
    Transport,
    TransportError,
    run_hub,
    tcp_loopback_pair,
)


class _SilentAfterPhase0(AliceEndpoint):
    """A straggler: completes submission/phase 0, then never sends a round
    frame — the hub's round barrier must evict it at the deadline while the
    other peers' round proceeds."""

    def run(self):
        self._phase0()
        return {}


class _CloseAfter(Transport):
    """Disconnect injection: pass through ``n_sends`` frames, then close the
    underlying transport and fail — a peer vanishing mid-protocol."""

    def __init__(self, inner: Transport, n_sends: int):
        super().__init__()
        self._inner = inner
        self._left = n_sends

    def send(self, data: bytes) -> None:
        if self._left <= 0:
            self._inner.close()
            raise TransportError("simulated mid-protocol disconnect")
        self._left -= 1
        self._inner.send(data)

    def recv(self, timeout: float | None = None) -> bytes:
        return self._inner.recv(timeout)

    def close(self) -> None:
        self._inner.close()

    @property
    def bytes_out(self) -> int:  # type: ignore[override]
        return self._inner.bytes_out

    @property
    def bytes_in(self) -> int:  # type: ignore[override]
        return self._inner.bytes_in

    @bytes_out.setter
    def bytes_out(self, v):  # Transport.__init__ assigns 0
        pass

    @bytes_in.setter
    def bytes_in(self, v):
        pass


def _transport_pairs(kind: str, n: int):
    """n (alice_side, hub_side) transport pairs of the requested kind."""
    if kind == "memory":
        return [InMemoryDuplex.pair() for _ in range(n)]
    return [tcp_loopback_pair() for _ in range(n)]


@pytest.mark.parametrize(
    "kind",
    # the in-memory variant covers the protocol fast; the real-socket
    # variant (the single heaviest fast-tier test) moves to the full-suite
    # job — CI's wire-endpoints job exercises loopback end-to-end anyway
    ["memory", pytest.param("loopback", marks=pytest.mark.slow)],
)
def test_hub_eight_peers_acceptance(kind):
    rng_seed = 100
    pairs = _transport_pairs(kind, 8)
    hub = HubEndpoint(recv_deadline=20.0)
    alices: dict[int, AliceEndpoint] = {}
    cases: dict[int, tuple] = {}

    # peers 1-6: healthy, mixed known-d / estimator / two-sided / overload
    specs = [
        (make_pair(700, 5, np.random.default_rng(rng_seed)),
         PBSConfig(seed=1), 5),
        (make_pair(800, 12, np.random.default_rng(rng_seed + 1)),
         PBSConfig(seed=2), 12),
        (make_pair(900, 10, np.random.default_rng(rng_seed + 2)),
         PBSConfig(seed=3), None),                       # estimator
        (make_pair_two_sided(800, 8, 6, np.random.default_rng(rng_seed + 3)),
         PBSConfig(seed=4), 14),
        (make_pair(1000, 20, np.random.default_rng(rng_seed + 4)),
         PBSConfig(seed=5), None),                       # estimator
        (make_pair(1200, 40, np.random.default_rng(rng_seed + 5)),
         PBSConfig(seed=6, n_override=255, t_override=8, g_override=1), 40),
    ]
    for i, ((a, b), cfg, dk) in enumerate(specs):
        ta, tb = pairs[i]
        ch = hub.add_peer(tb)
        hub.submit(ch, b, cfg=cfg, d_known=dk)
        ep = AliceEndpoint(ta, channel=ch)
        ep.submit(a, cfg=cfg, d_known=dk)
        alices[ch] = ep
        cases[ch] = (a, b, cfg, dk)

    # peer 7: straggler (estimator phase 0 completes, then silence)
    a7, b7 = make_pair(800, 9, np.random.default_rng(rng_seed + 6))
    ta7, tb7 = pairs[6]
    ch7 = hub.add_peer(tb7, label="straggler")
    hub.submit(ch7, b7, cfg=PBSConfig(seed=7))
    ep7 = _SilentAfterPhase0(ta7, channel=ch7)
    ep7.submit(a7, cfg=PBSConfig(seed=7))
    alices[ch7] = ep7

    # peer 8: disconnects mid-protocol (after its round-1 sketches frame,
    # before its outcome frame)
    a8, b8 = make_pair(800, 8, np.random.default_rng(rng_seed + 7))
    ta8, tb8 = pairs[7]
    ch8 = hub.add_peer(tb8, label="dropper")
    hub.submit(ch8, b8, cfg=PBSConfig(seed=8), d_known=8)
    ep8 = AliceEndpoint(_CloseAfter(ta8, n_sends=1), channel=ch8)
    ep8.submit(a8, cfg=PBSConfig(seed=8), d_known=8)
    alices[ch8] = ep8

    outcomes, results, errors = run_hub(hub, alices)

    # every surviving peer: byte-identical to the single-pair oracle
    for ch, (a, b, cfg, dk) in cases.items():
        exp = reconcile(a, b, cfg, d_known=dk)
        got = results[ch][0]
        assert got.diff == exp.diff == true_diff(a, b), ch
        assert got.bytes_per_round == exp.bytes_per_round, ch
        assert got.bytes_sent == exp.bytes_sent, ch
        assert got.estimator_bytes == exp.estimator_bytes, ch
        assert got.rounds == exp.rounds, ch
        assert got.success == exp.success, ch
        assert got.decode_failures == exp.decode_failures, ch
        assert got.fake_rejections == exp.fake_rejections, ch
        assert outcomes[ch].ok and outcomes[ch].verified == [True], ch
    # the overload peer really exercised the 3-way split through the hub
    overload_ch = list(cases)[5]
    assert results[overload_ch][0].decode_failures >= 1

    # straggler: evicted at the barrier deadline, sessions failed, clean error
    assert not outcomes[ch7].ok
    assert isinstance(outcomes[ch7].error, TransportError)
    assert all(s.failed for s in outcomes[ch7].sessions)

    # disconnector: clean per-peer TransportError, Alice side failed too
    assert not outcomes[ch8].ok
    assert isinstance(outcomes[ch8].error, TransportError)
    assert isinstance(errors[ch8], TransportError)
    assert ch7 in hub.stale_channels and ch8 in hub.stale_channels

    # fusion ledger: one store upload per cohort that ever went live, and
    # fused launches (2 encode kernels + 1 decode) per cohort-round shared
    # across all peers
    st = hub.stats
    live_keys = {
        s.code_key
        for ch in list(cases) + [ch8]     # ch8 was live at round-1 planning
        for s in outcomes[ch].sessions
    }
    assert st["store_uploads"] == len(live_keys), (st, live_keys)
    assert st["kernel_launches"] == 2 * st["cohort_rounds"]
    assert st["decode_launches"] == st["cohort_rounds"]
    # fusion really shared launches: strictly fewer cohort-rounds than the
    # sum of every surviving peer's own (rounds x cohorts) would be
    per_peer_rounds = sum(results[ch][0].rounds for ch in cases)
    assert st["cohort_rounds"] < per_peer_rounds


def test_hub_peer_joining_between_rounds_is_byte_identical():
    """A peer admitted after global round 1 must reconcile byte-identically
    to a pair that started alone (local round numbering via rnd0)."""
    hub = HubEndpoint(recv_deadline=30.0)
    a1, b1 = make_pair(1500, 40, np.random.default_rng(17))
    cfg1 = PBSConfig(seed=6, n_override=255, t_override=8, g_override=1)
    ta, tb = InMemoryDuplex.pair()
    ch1 = hub.add_peer(tb)
    hub.submit(ch1, b1, cfg=cfg1, d_known=40)
    ep1 = AliceEndpoint(ta, channel=ch1)
    ep1.submit(a1, cfg=cfg1, d_known=40)

    a2, b2 = make_pair(900, 10, np.random.default_rng(23))
    cfg2 = PBSConfig(seed=29)
    joined: dict = {}

    def on_barrier(rnd):
        if rnd == 1 and not joined:
            ta2, tb2 = InMemoryDuplex.pair()
            ch = hub.add_peer(tb2, label="late")
            hub.submit(ch, b2, cfg=cfg2, d_known=10)
            ep = AliceEndpoint(ta2, channel=ch)
            ep.submit(a2, cfg=cfg2, d_known=10)
            res: dict = {}
            th = threading.Thread(
                target=lambda: res.update(r=ep.run()), daemon=True
            )
            th.start()
            joined.update(ch=ch, th=th, res=res)

    hub.on_barrier = on_barrier
    outcomes, results, errors = run_hub(hub, {ch1: ep1})
    joined["th"].join(60)
    assert not errors and "r" in joined["res"]

    exp1 = reconcile(a1, b1, cfg1, d_known=40)
    assert results[ch1][0].diff == exp1.diff
    assert results[ch1][0].bytes_per_round == exp1.bytes_per_round

    ch2 = joined["ch"]
    exp2 = reconcile(a2, b2, cfg2, d_known=10)
    got2 = joined["res"]["r"][0]
    assert got2.diff == exp2.diff == true_diff(a2, b2)
    assert got2.bytes_per_round == exp2.bytes_per_round
    assert got2.rounds == exp2.rounds
    assert outcomes[ch2].ok and outcomes[ch2].verified == [True]
    assert outcomes[ch2].sessions[0].rnd0 >= 1  # really joined mid-run


def test_hub_rejects_wrong_and_stale_channel_ids():
    """A frame tagged with any channel other than the peer's own — unknown,
    someone else's, or a retired (stale) one — evicts only that peer."""
    from repro.wire import frames as wf

    # wrong id on the wire -> strict rejection at the frame layer
    hub = HubEndpoint(recv_deadline=2.0)
    ta, tb = InMemoryDuplex.pair()
    ch = hub.add_peer(tb)
    a, b = make_pair(400, 4, np.random.default_rng(5))
    hub.submit(ch, b, cfg=PBSConfig(seed=3), d_known=4)
    inner = wf.encode_tow_sketch(np.zeros(128, np.int64), 400)
    ta.send(wf.encode_mux(ch + 17, inner))
    out = hub.serve()
    assert not out[ch].ok
    assert "channel" in str(out[ch].error)
    assert ch in hub.stale_channels

    # a healthy retired peer's channel is stale too (never reused)
    hub2 = HubEndpoint(recv_deadline=30.0)
    ta2, tb2 = InMemoryDuplex.pair()
    ch2 = hub2.add_peer(tb2)
    hub2.submit(ch2, b, cfg=PBSConfig(seed=3), d_known=4)
    ep = AliceEndpoint(ta2, channel=ch2)
    ep.submit(a, cfg=PBSConfig(seed=3), d_known=4)
    outcomes, results, errors = run_hub(hub2, {ch2: ep})
    assert outcomes[ch2].ok and not errors
    assert ch2 in hub2.stale_channels
    # and a later add_peer never hands the id out again
    ta3, tb3 = InMemoryDuplex.pair()
    assert hub2.add_peer(tb3) != ch2


def test_unmultiplexed_frame_on_channel_stream_rejected():
    """A bare (non-mux) frame on a channel-tagged stream is a WireError on
    the receiving side — peers cannot bypass the envelope."""
    from repro.wire import frames as wf
    from repro.wire.frames import WireError
    from repro.net.transport import FrameStream

    ta, tb = InMemoryDuplex.pair()
    stream = FrameStream(tb, channel=1)
    ta.send(wf.encode_dhat(7))            # no envelope
    with pytest.raises(WireError, match="unmultiplexed"):
        stream.recv(timeout=1.0)
    # and a correctly tagged frame round-trips
    ta.send(wf.encode_mux(1, wf.encode_dhat(7)))
    msg_type, payload = stream.recv(timeout=1.0)
    assert msg_type == wf.MSG_DHAT and wf.decode_dhat(payload) == 7
