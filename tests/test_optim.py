"""Optimizer correctness: int8 dynamic-codebook states, schedules, plans."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import get_smoke_config
from repro.optim import OptConfig, build_plan, lr_schedule
from repro.optim.adamw import QBLK, _dequantize, _pad_len, _quantize
from repro.train import init_train_state, make_train_step


@settings(max_examples=25, deadline=None)
@given(
    scale_exp=st.integers(-6, 3),
    spread=st.integers(0, 6),
    signed=st.booleans(),
    seed=st.integers(0, 10_000),
)
def test_dynamic_quantization_relative_error(scale_exp, spread, signed, seed):
    """Log-spaced codebook keeps ~7% relative error across decades, incl.
    mixed-magnitude blocks (the case linear absmax int8 fails)."""
    rng = np.random.default_rng(seed)
    n = 2 * QBLK
    mags = 10.0 ** (scale_exp - spread * rng.random(n))
    x = mags * (rng.choice([-1, 1], n) if signed else 1.0)
    xj = jnp.asarray(x, jnp.float32)
    q, s = _quantize(xj, signed=signed)
    back = np.asarray(_dequantize(q, s, signed=signed))
    rel = np.abs(back - x) / np.maximum(np.abs(x), 1e-20)
    # entries within 7 decades of their block max keep relative precision
    blk_max = np.repeat(np.abs(x).reshape(-1, QBLK).max(1), QBLK)
    covered = np.abs(x) > blk_max * 1.1e-7
    assert np.all(rel[covered] < 0.07), rel[covered].max()


@pytest.mark.slow  # needs the model-scaffold jax tier (jax.sharding.AxisType)
def test_int8_states_track_fp32():
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    cfg = get_smoke_config("internlm2-1.8b")
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (4, 64)), jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    traj = {}
    for name, ocfg in [
        ("fp32", OptConfig(warmup=2, total_steps=20)),
        ("int8", OptConfig(warmup=2, total_steps=20, state_dtype="int8")),
    ]:
        bundle = make_train_step(cfg, mesh, ocfg, batch=4)
        params, opt = init_train_state(bundle, cfg, mesh, ocfg)
        losses = []
        for _ in range(6):
            params, opt, m = bundle.step(params, opt, batch)
            losses.append(float(m["loss"]))
        traj[name] = losses
    np.testing.assert_allclose(traj["fp32"], traj["int8"], rtol=5e-3)


def test_lr_schedule_shape():
    cfg = OptConfig(lr_peak=1e-3, warmup=10, total_steps=100, lr_min_frac=0.1)
    lrs = [float(lr_schedule(cfg, jnp.int32(s))) for s in (0, 9, 10, 55, 100)]
    assert lrs[0] < lrs[1] <= cfg.lr_peak * (1 + 1e-6)  # warmup rises
    assert abs(lrs[2] - cfg.lr_peak) < 1e-6 * cfg.lr_peak  # peak after warmup
    assert lrs[2] > lrs[3] > lrs[4]                  # cosine decays
    assert abs(lrs[4] - cfg.lr_peak * 0.1) < 1e-6    # floor


def test_build_plan_axes():
    """Replication-axis complements drive grad sync (DESIGN.md §4)."""
    from repro.models.spec import P

    spec = {
        "norm": P((64,), (None,)),                       # fully replicated
        "wq": P((64, 128), (None, "model")),             # TP
        "experts": P((8, 4, 4), (("data", "model"), None, None)),  # EP
    }
    sizes = {"pod": 2, "data": 4, "model": 2}
    plan = build_plan(spec, ("pod", "data", "model"), sizes, OptConfig(zero1=False))
    assert plan["norm"].sync_axes == ("pod", "data", "model")
    assert plan["wq"].sync_axes == ("pod", "data")
    assert plan["experts"].sync_axes == ("pod",)
    planz = build_plan(spec, ("pod", "data", "model"), sizes, OptConfig(zero1=True))
    assert planz["wq"].scatter and planz["wq"].sync_axes == ("pod",)
    assert not planz["experts"].scatter               # no data replication
    assert planz["norm"].scatter                      # 64 >= D


@pytest.mark.parametrize("n", [1, QBLK - 1, QBLK, QBLK + 1, 3 * QBLK + 7])
def test_pad_len(n):
    p = _pad_len(n)
    assert p >= n and p % QBLK == 0 and p - n < QBLK
