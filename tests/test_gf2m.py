"""Field-arithmetic unit + property tests."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.gf2m import get_field, gf32_inv, gf32_mul, gf32_pow


@pytest.mark.parametrize("m", [6, 7, 8, 10, 11])
def test_field_axioms(m):
    gf = get_field(m)
    rng = np.random.default_rng(m)
    a = rng.integers(1, gf.n + 1, size=200)
    b = rng.integers(1, gf.n + 1, size=200)
    c = rng.integers(1, gf.n + 1, size=200)
    assert (gf.mul(a, gf.mul(b, c)) == gf.mul(gf.mul(a, b), c)).all()
    assert (gf.mul(a, b) == gf.mul(b, a)).all()
    assert (gf.mul(a, gf.inv(a)) == 1).all()
    assert (gf.mul(a, b ^ c) == (gf.mul(a, b) ^ gf.mul(a, c))).all()
    assert (gf.mul(a, 0) == 0).all()
    assert (gf.mul(a, 1) == a).all()


@given(st.integers(min_value=1, max_value=127), st.integers(min_value=1, max_value=127))
@settings(max_examples=200, deadline=None)
def test_mult_matrix_agrees_with_table_mul(a, b):
    gf = get_field(7)
    prod_table = int(gf.mul(a, b))
    prod_mat = int(gf.from_bits(gf.to_bits(a) @ gf.mult_matrix(b) % 2))
    assert prod_table == prod_mat


@pytest.mark.parametrize("m", [6, 8, 11])
def test_bit_roundtrip(m):
    gf = get_field(m)
    vals = np.arange(gf.n + 1)
    assert (gf.from_bits(gf.to_bits(vals)) == vals).all()


def test_gf32_axioms():
    rng = np.random.default_rng(0)
    a = rng.integers(1, 1 << 32, size=300, dtype=np.uint64)
    b = rng.integers(1, 1 << 32, size=300, dtype=np.uint64)
    c = rng.integers(1, 1 << 32, size=300, dtype=np.uint64)
    assert (gf32_mul(a, gf32_mul(b, c)) == gf32_mul(gf32_mul(a, b), c)).all()
    assert (gf32_mul(a, gf32_inv(a)) == 1).all()
    assert (gf32_pow(a, (1 << 32) - 1) == 1).all()
    assert (gf32_mul(a, b ^ c) == (gf32_mul(a, b) ^ gf32_mul(a, c))).all()


def test_syndrome_matrix_matches_direct():
    from repro.core.bch import BCHCode, sketch_from_positions

    code = BCHCode(127, 5)
    gf = code.field
    P = gf.syndrome_matrix(code.t)  # (n, t*m)
    rng = np.random.default_rng(3)
    for _ in range(10):
        pos = rng.choice(code.n, size=rng.integers(0, 9), replace=False)
        bitmap = np.zeros(code.n, dtype=np.int64)
        bitmap[pos] = 1
        via_mat = (bitmap @ P) % 2
        syn = gf.from_bits(via_mat.reshape(code.t, gf.m))
        direct = sketch_from_positions(code, pos)
        assert (syn == direct).all()
