"""Property-based protocol conformance: wire paths vs the core.pbs oracle.

Three layers of conformance, all anchored on ``core.pbs.reconcile``:

1. **generated set pairs through the real wire** — random |A △ B|,
   duplicate-free sets, random seeds/configs driven through ``run_pair``
   and through a ``HubEndpoint`` serving several peers at once; per-session
   results and per-round *measured* wire ledgers must be byte-identical to
   the oracle (the endpoints additionally self-check measured == Formula
   (1) on every frame, so a pass here pins the whole codec stack);
2. **stateful traces over the shared round state machine** — random
   (ok, checksum-settled) trace matrices pushed through Alice's
   ``apply_round_outcomes`` and Bob's frame-mirror rule
   (``queue_split`` + done flags) on two independent states: the unit
   queues must evolve identically (uid-for-uid, filter-for-filter), the
   ``session_live`` predicate must agree on both sides every round, and
   budget exhaustion must land on the same round;
3. **hypothesis-generated variants** of both (skipped cleanly when
   hypothesis is not installed; the seeded versions above always run).
"""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.pbs import (
    PBSConfig,
    apply_round_outcomes,
    plan_from_d_known,
    new_session_state,
    queue_split,
    reconcile,
    session_live,
    true_diff,
)
from repro.core.simdata import make_pair, make_pair_two_sided, random_set
from repro.net import (
    AliceEndpoint,
    BobEndpoint,
    HubEndpoint,
    InMemoryDuplex,
    run_hub,
    run_pair,
)

_EMPTY = np.zeros(0, dtype=np.uint32)


# ---------------------------------------------------------------------------
# generators
# ---------------------------------------------------------------------------


def _gen_case(rng: np.random.Generator):
    """One random session: duplicate-free sets, random d, random config."""
    size = int(rng.integers(300, 1200))
    kind = rng.integers(0, 3)
    if kind == 0:
        d = int(rng.integers(1, 30))
        a, b = make_pair(size, d, rng)
    elif kind == 1:
        da, db = int(rng.integers(1, 15)), int(rng.integers(1, 15))
        a, b = make_pair_two_sided(size, da, db, rng)
        d = da + db
    else:                       # fully independent draws (random overlap)
        base = random_set(size + 20, rng)
        a = base[: size]
        b = np.unique(np.concatenate([a[: size - 10], base[size:]]))
        d = len(true_diff(a, b))
    cfg = PBSConfig(seed=int(rng.integers(0, 1 << 16)))
    d_known = None if rng.random() < 0.3 else max(1, d)
    return a, b, cfg, d_known


def _assert_oracle(got, a, b, cfg, d_known):
    exp = reconcile(a, b, cfg, d_known=d_known)
    assert got.diff == exp.diff
    assert got.bytes_per_round == exp.bytes_per_round
    assert got.bytes_sent == exp.bytes_sent
    assert got.estimator_bytes == exp.estimator_bytes
    assert got.rounds == exp.rounds
    assert got.success == exp.success
    assert got.decode_failures == exp.decode_failures
    assert got.fake_rejections == exp.fake_rejections
    return exp


# ---------------------------------------------------------------------------
# 1) generated pairs through the real wire (always run, seeded)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", [101, 202])
def test_generated_sessions_pair_path_matches_oracle(seed):
    rng = np.random.default_rng(seed)
    cases = [_gen_case(rng) for _ in range(3)]
    ta, tb = InMemoryDuplex.pair()
    alice, bob = AliceEndpoint(ta), BobEndpoint(tb)
    for a, b, cfg, dk in cases:
        alice.submit(a, cfg=cfg, d_known=dk)
        bob.submit(b, cfg=cfg, d_known=dk)
    results = run_pair(alice, bob)
    for sid, (a, b, cfg, dk) in enumerate(cases):
        _assert_oracle(results[sid], a, b, cfg, dk)
    assert alice.verified == bob.verified


@pytest.mark.parametrize("seed", [303])
def test_generated_sessions_hub_path_matches_oracle(seed):
    """The same generated workload, but split across hub peers — per-peer
    results and measured ledgers must still be byte-identical, with the
    encode/decode launches fused across peers."""
    rng = np.random.default_rng(seed)
    hub = HubEndpoint(recv_deadline=30.0)
    alices, cases = {}, {}
    for _ in range(3):
        a, b, cfg, dk = _gen_case(rng)
        ta, tb = InMemoryDuplex.pair()
        ch = hub.add_peer(tb)
        hub.submit(ch, b, cfg=cfg, d_known=dk)
        ep = AliceEndpoint(ta, channel=ch)
        ep.submit(a, cfg=cfg, d_known=dk)
        alices[ch] = ep
        cases[ch] = (a, b, cfg, dk)
    outcomes, results, errors = run_hub(hub, alices)
    assert not errors
    for ch, (a, b, cfg, dk) in cases.items():
        exp = _assert_oracle(results[ch][0], a, b, cfg, dk)
        assert outcomes[ch].ok
        assert outcomes[ch].verified == [exp.success]
    st_ = hub.stats
    assert st_["kernel_launches"] == 2 * st_["cohort_rounds"]
    assert st_["store_uploads"] == len(
        {s.code_key for o in outcomes.values() for s in o.sessions}
    )


# ---------------------------------------------------------------------------
# 2) stateful traces over queue_split / apply_round_outcomes
# ---------------------------------------------------------------------------


def _queue_fingerprint(st_):
    return [(u.uid, u.group, u.filters, u.done) for u in st_.units]


def _run_trace(rng: np.random.Generator, cfg: PBSConfig, d: int):
    """Drive Alice's state machine and Bob's frame-mirror rule with one
    random (ok, checksum-settled) trace; their queues must stay identical.

    Alice applies the full ``apply_round_outcomes`` (empty decoded
    positions, checksums forced equal or unequal per the trace); Bob
    applies exactly what ``_handle_outcome`` does with the wire-visible
    (ok, done) — decode failure -> the same deterministic ``queue_split``,
    done flag -> retire the unit.
    """
    plan = plan_from_d_known(cfg, d)
    st_a = new_session_state(_EMPTY, _EMPTY, plan)
    st_b = new_session_state(_EMPTY, _EMPTY, plan)
    n = plan.n

    budget_hit_round = None
    for rnd in range(1, cfg.max_rounds + 3):
        live_a = session_live(st_a, cfg, rnd)
        live_b = session_live(st_b, cfg, rnd)
        assert live_a == live_b, f"liveness diverged at round {rnd}"
        if not live_a:
            budget_hit_round = rnd
            break
        active_a = st_a.active_units()
        active_b = st_b.active_units()
        k = len(active_a)
        ok = rng.random(k) > 0.3          # ~30% simulated BCH overloads
        settle = rng.random(k) > 0.4      # ~60% of decodes settle checksums

        csum_a = np.zeros(k, dtype=np.uint64)
        csum_b = np.where(settle, 0, 1).astype(np.uint64)  # equal iff settle
        _, done = apply_round_outcomes(
            st_a, active_a, ok, [np.zeros(0, dtype=np.int64)] * k,
            np.zeros((k, n), np.uint32), np.zeros((k, n), np.uint32),
            csum_a, csum_b, plan=plan,
            bin_seed=0, rnd=rnd,
        )
        # the Bob mirror: only (ok, done) crossed the wire
        for slot, u in enumerate(active_b):
            if not ok[slot]:
                queue_split(st_b, u, rnd, cfg.seed)
            elif done[slot]:
                u.done = True
        st_a.rounds = st_b.rounds = rnd

        assert _queue_fingerprint(st_a) == _queue_fingerprint(st_b), (
            f"unit queues diverged at round {rnd}"
        )
        # settled units are exactly the trace's (ok and settle) slots
        assert done == list(ok & settle)
    return budget_hit_round, st_a


@pytest.mark.parametrize("seed", [7, 42, 1234])
def test_trace_alice_and_bob_mirrors_stay_identical(seed):
    rng = np.random.default_rng(seed)
    cfg = PBSConfig(seed=seed, n_override=127, t_override=5, g_override=4,
                    max_rounds=6)
    budget_round, st_a = _run_trace(rng, cfg, d=20)
    assert budget_round is not None      # trace always terminates
    # split bookkeeping: uids unique and consecutive from g
    uids = [u.uid for u in st_a.units]
    assert len(set(uids)) == len(uids)
    assert sorted(uids) == list(range(len(uids)))
    # every split child carries a filter chain; every split appended
    # exactly 3 children, so the queue length pins the failure counter
    for u in st_a.units:
        if u.uid >= cfg.g_override:      # a split descendant
            assert len(u.filters) >= 1
    assert len(st_a.units) == cfg.g_override + 3 * st_a.decode_failures


def test_trace_budget_exhaustion_ordering():
    """A state whose trace never settles must go dead on the same round on
    both sides: max_rounds + 1, with unreconciled units still queued."""
    cfg = PBSConfig(seed=1, n_override=63, t_override=2, g_override=2,
                    max_rounds=3)
    plan = plan_from_d_known(cfg, 6)
    st_a = new_session_state(_EMPTY, _EMPTY, plan)
    st_b = new_session_state(_EMPTY, _EMPTY, plan)
    for rnd in range(1, cfg.max_rounds + 1):
        assert session_live(st_a, cfg, rnd) and session_live(st_b, cfg, rnd)
        active = st_a.active_units()
        k = len(active)
        ok = np.zeros(k, dtype=bool)      # every decode overloads
        _, done = apply_round_outcomes(
            st_a, active, ok, [np.zeros(0, dtype=np.int64)] * k,
            np.zeros((k, plan.n), np.uint32), np.zeros((k, plan.n), np.uint32),
            np.zeros(k, np.uint64), np.zeros(k, np.uint64),
            plan=plan, bin_seed=0, rnd=rnd,
        )
        assert done == [False] * k
        for slot, u in enumerate(st_b.active_units()):
            queue_split(st_b, u, rnd, cfg.seed)
    # budget exhausted on round max_rounds + 1, identically
    assert not session_live(st_a, cfg, cfg.max_rounds + 1)
    assert not session_live(st_b, cfg, cfg.max_rounds + 1)
    assert st_a.active_units() and st_b.active_units()   # work left undone
    assert _queue_fingerprint(st_a) == _queue_fingerprint(st_b)
    # every overload tripled the queue: 2 -> 6 -> 18 -> 54 active leaves
    assert len(st_a.active_units()) == 2 * 3 ** cfg.max_rounds


# ---------------------------------------------------------------------------
# 3) hypothesis variants (collected as skips when hypothesis is missing)
# ---------------------------------------------------------------------------


def _pair_roundtrip(seed: int, d: int, size: int, know_d: bool):
    """One generated session through the real wire vs the oracle — the
    shared body of the seeded and the hypothesis-driven variants."""
    d = max(1, min(d, size // 4))
    rng = np.random.default_rng(seed)
    a, b = make_pair(max(size, 4 * d), d, rng)
    cfg = PBSConfig(seed=seed & 0xFFFF)
    dk = d if know_d else None
    ta, tb = InMemoryDuplex.pair()
    alice, bob = AliceEndpoint(ta), BobEndpoint(tb)
    alice.submit(a, cfg=cfg, d_known=dk)
    bob.submit(b, cfg=cfg, d_known=dk)
    results = run_pair(alice, bob)
    _assert_oracle(results[0], a, b, cfg, dk)


@pytest.mark.parametrize(
    "seed,d,size,know_d", [(5150, 7, 500, True), (9091, 33, 700, False)]
)
def test_seeded_pair_roundtrip(seed, d, size, know_d):
    _pair_roundtrip(seed, d, size, know_d)


@settings(max_examples=8, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    d=st.integers(min_value=1, max_value=40),
    size=st.integers(min_value=200, max_value=900),
    know_d=st.booleans(),
)
def test_hypothesis_pair_matches_oracle(seed, d, size, know_d):
    _pair_roundtrip(seed, d, size, know_d)


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    g=st.integers(min_value=1, max_value=6),
    max_rounds=st.integers(min_value=1, max_value=8),
)
def test_hypothesis_trace_mirrors(seed, g, max_rounds):
    rng = np.random.default_rng(seed)
    cfg = PBSConfig(seed=seed & 0xFFFF, n_override=63, t_override=3,
                    g_override=g, max_rounds=max_rounds)
    budget_round, _ = _run_trace(rng, cfg, d=3 * g)
    assert budget_round is not None and budget_round <= max_rounds + 1
