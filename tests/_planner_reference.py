"""Pre-PR-6 *scalar* cohort planner, kept verbatim as a test-only oracle.

This is the per-session/per-unit Python-loop implementation of
``SessionBatch._plan_cohort`` exactly as it shipped before the vectorized
planner landed (DESIGN.md §12).  ``tests/test_planner_vectorized.py`` runs
both planners over identical session batches and asserts the emitted
``CohortRoundPlan``s are byte-identical — row_map, seeds, overlays, widths,
member packing — which is what licenses the numpy rewrite to claim
"same plans, orders of magnitude less host time".

Do not "optimize" this module: its value is being the old code.
"""
from __future__ import annotations

import numpy as np

from repro.core.hashing import derive_seed
from repro.core.pbs import diff_overlay, group_view, session_live
from repro.kernels.platform import pow2_bucket
from repro.recon.session import CohortRoundPlan, SessionBatch


def _by_group(vals: np.ndarray, g: int, seed_groups: int) -> dict:
    """Partition a small value array by its (round-invariant) group id,
    through the same canonical ``group_view`` the oracle partitions with."""
    if not len(vals):
        return {}
    _, order, bounds = group_view(vals, g, seed_groups)
    sv = vals[order]
    return {
        gi: sv[bounds[gi] : bounds[gi + 1]]
        for gi in range(g)
        if bounds[gi + 1] > bounds[gi]
    }


def reference_plan_cohort(
    batch: SessionBatch, store, members, rnd: int
) -> CohortRoundPlan:
    """The pre-vectorization ``_plan_cohort`` body, unchanged."""
    total = sum(len(active) for _, active in members)
    u_pad = pow2_bucket(total, batch.ROW_ALIGN)

    row_map = np.zeros(u_pad, dtype=np.int32)
    unit_valid = np.zeros(u_pad, dtype=np.int32)
    seeds = np.zeros(u_pad, dtype=np.uint32)
    removed_of: list[np.ndarray | None] = [None] * u_pad
    added_of: list[np.ndarray | None] = [None] * u_pad
    filters_of: list[tuple] = [()] * u_pad

    packed = []
    base = 0
    for s, active in members:
        st, plan = s.state, s.plan
        bin_seed = derive_seed(plan.cfg.seed, 2, rnd - s.rnd0)
        assert 0 <= bin_seed < 1 << 32, bin_seed
        removed, added = diff_overlay(st)
        rem_by_grp = _by_group(removed, plan.g, plan.seed_groups)
        add_by_grp = _by_group(added, plan.g, plan.seed_groups)
        for slot, u in enumerate(active):
            row = base + slot
            row_map[row] = store.row_of[(s.sid, u.group)]
            unit_valid[row] = 1
            seeds[row] = bin_seed
            removed_of[row] = rem_by_grp.get(u.group)
            added_of[row] = add_by_grp.get(u.group)
            filters_of[row] = u.filters
        packed.append((s, base, active, bin_seed))
        base += len(active)

    if "a" in batch.sides:
        max_r = max((len(r) for r in removed_of if r is not None), default=0)
        max_x = max((len(a) for a in added_of if a is not None), default=0)
        r_w = pow2_bucket(max_r, batch.OVERLAY_ALIGN)
        x_w = pow2_bucket(max_x, batch.OVERLAY_ALIGN)
    else:
        r_w = x_w = 0
    max_f = max((len(f) for f in filters_of), default=0)
    f_w = pow2_bucket(max_f, 1) if max_f else 0

    removed_arr = np.zeros((u_pad, r_w), dtype=np.uint32)
    removed_cnt = np.zeros(u_pad, dtype=np.int32)
    added_arr = np.zeros((u_pad, x_w), dtype=np.uint32)
    added_cnt = np.zeros(u_pad, dtype=np.int32)
    fseeds = np.zeros((u_pad, f_w), dtype=np.uint32)
    fbins = np.zeros((u_pad, f_w), dtype=np.int32)
    fcnt = np.zeros(u_pad, dtype=np.int32)
    for row in range(total):
        r = removed_of[row]
        if r is not None:
            removed_arr[row, : len(r)] = r
            removed_cnt[row] = len(r)
        a = added_of[row]
        if a is not None:
            added_arr[row, : len(a)] = a
            added_cnt[row] = len(a)
        flt = filters_of[row]
        if flt:
            fseeds[row, : len(flt)] = [fs for fs, _ in flt]
            fbins[row, : len(flt)] = [fi for _, fi in flt]
            fcnt[row] = len(flt)

    arrays = {
        "row_map": row_map,
        "unit_valid": unit_valid,
        "seeds": seeds,
        "removed": removed_arr,
        "removed_cnt": removed_cnt,
        "added": added_arr,
        "added_cnt": added_cnt,
        "fseeds": fseeds,
        "fbins": fbins,
        "fcnt": fcnt,
    }
    live_rows = row_map[:total]

    def width(side: str) -> int:
        if side not in store.sides:
            return 0
        return pow2_bucket(
            int(store.sides[side].cnt_host[live_rows].max(initial=0)),
            batch.COL_ALIGN,
        )

    return CohortRoundPlan(
        store=store,
        members=packed,
        units=total,
        width_a=width("a"),
        width_b=width("b"),
        arrays=arrays,
        h2d_bytes=sum(a.nbytes for a in arrays.values()),
        legacy_h2d_bytes=(
            batch._legacy_round_bytes(
                store, row_map[:total], removed_cnt[:total],
                added_cnt[:total], fcnt[:total],
            )
            if {"a", "b"} <= set(store.sides)
            else 0
        ),
    )


def reference_plan_round(batch: SessionBatch, rnd: int) -> list[CohortRoundPlan]:
    """The pre-vectorization ``plan_round`` body, routed through the
    reference cohort planner (store building is shared with the live code —
    the store layout contract is covered by its own tests)."""
    live: dict[tuple[int, int], list] = {}
    for s in batch.sessions:
        if s.failed or rnd <= s.rnd0:
            continue
        if not session_live(s.state, s.plan.cfg, rnd - s.rnd0):
            continue
        live.setdefault(s.code_key, []).append((s, s.state.active_units()))
    return [
        reference_plan_cohort(
            batch, batch.store_for(key, live=[s for s, _ in members]), members, rnd
        )
        for key, members in sorted(live.items())
    ]
