"""Error-feedback top-k gradient compression: mechanics + convergence."""
import json
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.optim import OptConfig
from repro.optim.compression import CompressionConfig
from repro.train import init_train_state, make_train_step

CCFG = CompressionConfig(ratio=0.1, min_leaf_size=1024, enabled=True)


def _run_steps(compression, steps=8):
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    cfg = get_smoke_config("internlm2-1.8b")
    ocfg = OptConfig(warmup=2, total_steps=40)
    bundle = make_train_step(cfg, mesh, ocfg, batch=4, compression=compression)
    params, opt = init_train_state(bundle, cfg, mesh, ocfg, compression=compression)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (4, 64)), jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    losses = []
    for _ in range(steps):
        params, opt, m = bundle.step(params, opt, batch)
        losses.append(float(m["loss"]))
    return losses, opt


@pytest.mark.slow  # needs the model-scaffold jax tier (jax.sharding.AxisType)
def test_compression_converges_and_feedback_bounded():
    dense, _ = _run_steps(None)
    comp, opt = _run_steps(CCFG)
    # compressed training still makes steady progress on the same batch
    assert comp[-1] < comp[0] - 0.3, comp
    # within a reasonable factor of the dense trajectory
    assert comp[-1] < dense[-1] + 1.0, (dense[-1], comp[-1])
    # error-feedback buffers hold the unsent mass: nonzero but bounded
    errs = [np.asarray(e) for e in jax.tree.leaves(opt["err"]) if e.size > 1]
    assert errs, "no leaf was compressed — threshold too high for smoke model"
    total = sum(float(np.abs(e).sum()) for e in errs)
    assert 0 < total < 1e6


SCRIPT = r"""
import os, sys, json
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
enabled = sys.argv[1] == "1"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_smoke_config
from repro.optim import OptConfig
from repro.optim.compression import CompressionConfig
from repro.train import make_train_step, init_train_state
mesh = jax.make_mesh((4, 2), ("data", "model"), devices=jax.devices()[:8],
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
cfg = get_smoke_config("internlm2-1.8b")
ocfg = OptConfig(warmup=2, total_steps=40)
ccfg = CompressionConfig(ratio=0.1, min_leaf_size=1024, enabled=enabled)
bundle = make_train_step(cfg, mesh, ocfg, batch=4, compression=ccfg)
params, opt = init_train_state(bundle, cfg, mesh, ocfg, compression=ccfg)
rng = np.random.default_rng(0)
toks = jnp.asarray(rng.integers(0, cfg.vocab, (4, 64)), jnp.int32)
batch = {"tokens": toks, "labels": toks}
losses = []
for _ in range(8):
    params, opt, m = bundle.step(params, opt, batch)
    losses.append(float(m["loss"]))
print("RESULT" + json.dumps(losses))
"""


@pytest.mark.slow
def test_compression_multidevice_tracks_dense():
    def run(flag):
        out = subprocess.run(
            [sys.executable, "-c", SCRIPT, flag],
            capture_output=True, text=True, timeout=900,
            env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root"},
        )
        assert out.returncode == 0, out.stderr[-2000:]
        return json.loads(
            [l for l in out.stdout.splitlines() if l.startswith("RESULT")][-1][6:]
        )

    dense = run("0")
    comp = run("1")
    assert comp[-1] < comp[0] - 0.3
    assert abs(comp[-1] - dense[-1]) < 1.0, (dense, comp)
