"""Rateless recovery acceptance (DESIGN.md §16, the MSG_PARITY ladder).

The algebraic foundation: the 2t-syndrome vector of an (n, t) BCH sketch is
a strict *prefix* of the (n, t') vector over the same GF(2^m) — syndrome
column j depends only on j, never on t.  So a group that overloads its
decode budget can be rescued by shipping ONLY the incremental columns
S_{2t+1}..S_{2t'-1} and decoding the concatenation at t', with zero re-sent
bits and zero store rebuilds — instead of the legacy degradation ladder's
from-scratch doubled-d̂ re-plan.

Covered here, bottom-up: the prefix property itself, incremental decode ==
fresh decode (hypothesis), the kernel-path incremental sketch, the
``core.pbs.reconcile`` oracle's ladder, the wire pair / in-process server /
multi-peer hub / tree front end all byte-identical to that oracle, the
endpoint's strict MSG_PARITY state machine, and the satellite regression
that an escalation (the legacy fallback) never ledgers a settled unit's
bits twice.
"""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import jax.numpy as jnp

from repro.core.bch import (
    bch_code,
    decode_extended,
    decode_sketch,
    sketch_from_positions,
    sketch_increment,
)
from repro.core.gf2m import get_field
from repro.core.pbs import (
    MAX_PARITY_EXTENSIONS,
    PBSConfig,
    parity_extension_t,
    reconcile,
    true_diff,
)
from repro.core.simdata import make_pair
from repro.kernels.ops import sketch_groups, sketch_groups_range
from repro.net import (
    AliceEndpoint,
    BobEndpoint,
    HubEndpoint,
    InMemoryDuplex,
    run_hub,
    run_pair,
)
from repro.recon.server import ReconcileServer
from repro.wire.frames import WireError


# ---------------------------------------------------------------------------
# the prefix property
# ---------------------------------------------------------------------------


@given(
    m=st.integers(min_value=4, max_value=9),
    t0=st.integers(min_value=0, max_value=12),
    dt=st.integers(min_value=0, max_value=12),
)
@settings(max_examples=30, deadline=None)
def test_syndrome_matrix_range_is_column_slice(m, t0, dt):
    """The (n, t) syndrome matrix is a strict prefix of the (n, t') one:
    the range helper returns exactly the shared matrix's column slice, so
    concatenating a sketch with its increment IS the wider sketch."""
    gf = get_field(m)
    t1 = t0 + dt
    full = gf.syndrome_matrix(t1)
    if t0:
        np.testing.assert_array_equal(
            full[:, : t0 * m], gf.syndrome_matrix(t0)
        )
    np.testing.assert_array_equal(
        full[:, t0 * m :], gf.syndrome_matrix_range(t0, t1)
    )


def _check_incremental_decode(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.choice([15, 31, 63, 127, 255]))
    cap = (n - 1) // 2
    t = int(rng.integers(1, cap))
    t1 = int(rng.integers(t + 1, cap + 1))
    d = int(rng.integers(0, min(t1 + 3, n) + 1))
    pos = rng.choice(n, size=d, replace=False).astype(np.int64)
    code1 = bch_code(n, t1)
    prefix = sketch_from_positions(bch_code(n, t), pos)
    inc = sketch_increment(code1, pos, t)
    ok_i, pos_i = decode_extended(n, prefix, inc)
    ok_f, pos_f = decode_sketch(code1, sketch_from_positions(code1, pos))
    assert ok_i == ok_f
    np.testing.assert_array_equal(np.sort(pos_i), np.sort(pos_f))
    if d <= t1:
        assert ok_i and set(pos_i.tolist()) == set(pos.tolist())


@given(seed=st.integers(min_value=0, max_value=2**32 - 1))
@settings(max_examples=25, deadline=None)
def test_incremental_decode_matches_fresh_decode(seed):
    """decode(prefix ++ increment) at t' is byte-identical to decoding a
    fresh (n, t') sketch of the same positions — across random
    (n, t -> t') pairs and random difference sets (including d > t', where
    both must fail identically)."""
    _check_incremental_decode(seed)


@pytest.mark.parametrize("seed", range(12))
def test_incremental_decode_matches_fresh_decode_seeded(seed):
    """Deterministic mirror of the hypothesis property (always runs, even
    without the optional hypothesis dependency)."""
    _check_incremental_decode(seed)
    # and the matrix prefix property at a few fixed shapes
    for m, t0, t1 in ((4, 2, 5), (7, 0, 9), (8, 6, 6 + seed % 5)):
        gf = get_field(m)
        np.testing.assert_array_equal(
            gf.syndrome_matrix(t1)[:, t0 * m :],
            gf.syndrome_matrix_range(t0, t1),
        )


def test_kernel_incremental_sketch_concat_matches_full():
    """kernels.ops.sketch_groups_range: prefix sketch ++ incremental
    columns == the full sketch at the wider t, element for element."""
    rng = np.random.default_rng(3)
    n, t0, t1 = 127, 4, 11
    bitmaps = (rng.random((6, n)) < 0.3).astype(np.int32)
    lo = np.asarray(sketch_groups(jnp.asarray(bitmaps), bch_code(n, t0)))
    inc = np.asarray(
        sketch_groups_range(jnp.asarray(bitmaps), bch_code(n, t1), t0)
    )
    full = np.asarray(sketch_groups(jnp.asarray(bitmaps), bch_code(n, t1)))
    np.testing.assert_array_equal(np.concatenate([lo, inc], axis=1), full)


def test_parity_extension_ladder_is_capped_by_code():
    """The deterministic t-ladder doubles per level and clamps at the
    (n - 1) // 2 BCH decoding cap — both wire sides derive it with zero
    negotiation."""
    n = 127
    assert parity_extension_t(5, 0, n) == 5
    assert parity_extension_t(5, 1, n) == 10
    assert parity_extension_t(5, 2, n) == 20
    assert parity_extension_t(5, 4, n) == 63       # clamped at (n-1)//2
    assert parity_extension_t(40, 1, n) == 63      # immediate clamp
    assert MAX_PARITY_EXTENSIONS >= 2


# ---------------------------------------------------------------------------
# the oracle's ladder + every serving path byte-identical to it
# ---------------------------------------------------------------------------


def _wrongd_inputs():
    """A 10x-underestimated d̂: every group overloads round 1; only the
    rateless ladder (or the legacy escalation fallback) can finish it
    without splitting progress away."""
    a, b = make_pair(3000, 100, np.random.default_rng(10))
    return a, b, PBSConfig(seed=3, rateless=True), 10


def test_oracle_rateless_recovers_wrong_dhat():
    a, b, cfg, dk = _wrongd_inputs()
    res = reconcile(a, b, cfg, d_known=dk)
    assert res.success and res.diff == true_diff(a, b)
    # the honest plan for comparison: rateless recovery must stay within
    # the CI gate's envelope of the honestly-planned ledger
    honest = reconcile(a, b, cfg, d_known=100)
    assert res.bytes_sent <= 1.6 * honest.bytes_sent


def test_pair_rateless_wrongd_recovers_without_replan():
    """Wire acceptance: under a 10x-wrong d̂ the pair reconciles through
    MSG_PARITY extensions alone — zero degraded sessions, ledger
    byte-identical to the oracle."""
    a, b, cfg, dk = _wrongd_inputs()
    oracle = reconcile(a, b, cfg, d_known=dk)
    ta, tb = InMemoryDuplex.pair()
    alice, bob = AliceEndpoint(ta), BobEndpoint(tb)
    alice.submit(a, cfg=cfg, d_known=dk)
    bob.submit(b, cfg=cfg, d_known=dk)
    res = run_pair(alice, bob)[0]
    assert res.success and res.diff == true_diff(a, b)
    assert res.bytes_per_round == oracle.bytes_per_round
    assert res.bytes_sent == oracle.bytes_sent
    assert res.decode_failures == oracle.decode_failures
    assert alice.parity_extensions == bob.parity_extensions > 0
    assert alice.sessions_degraded == bob.sessions_degraded == 0
    assert bob.verified == [True]


def test_pair_rateless_honest_path_stays_byte_identical():
    """``rateless=True`` must not perturb the honest path: same frames,
    same ledger as the oracle (which shares the ladder), and extensions
    fire only when a group actually overloads."""
    a, b = make_pair(3000, 100, np.random.default_rng(10))
    cfg = PBSConfig(seed=3, rateless=True)
    oracle = reconcile(a, b, cfg, d_known=100)
    ta, tb = InMemoryDuplex.pair()
    alice, bob = AliceEndpoint(ta), BobEndpoint(tb)
    alice.submit(a, cfg=cfg, d_known=100)
    bob.submit(b, cfg=cfg, d_known=100)
    res = run_pair(alice, bob)[0]
    assert res.success and res.diff == true_diff(a, b)
    assert res.bytes_per_round == oracle.bytes_per_round
    assert res.bytes_sent == oracle.bytes_sent
    assert alice.parity_extensions == bob.parity_extensions
    assert alice.sessions_degraded == bob.sessions_degraded == 0


def test_server_rateless_wrongd_no_replan_no_rebuild():
    """In-process server acceptance: the rateless path keeps the settled
    stores resident — store builds stay at the initial upload count, no
    session ever takes the degradation ladder, and the ledger matches the
    oracle exactly."""
    a, b, cfg, dk = _wrongd_inputs()
    oracle = reconcile(a, b, cfg, d_known=dk)
    srv = ReconcileServer(degrade=True)
    srv.submit(a, b, cfg=cfg, d_known=dk)
    res = srv.run()[0]
    assert res.success and res.diff == true_diff(a, b)
    assert res.bytes_per_round == oracle.bytes_per_round
    assert res.bytes_sent == oracle.bytes_sent
    assert srv.stats["parity_extensions"] > 0
    assert srv.stats["sessions_degraded"] == 0
    # one initial upload per side, nothing rebuilt by the recovery
    assert srv.stats["store_builds"] == 1


def test_hub_rateless_peers_match_oracle():
    """Multi-peer hub: wrong-d̂ rateless peers recover over the shared
    cohort ladder (one incremental dispatch per cohort per level, fused
    across peers) while an honest rateless peer rides along untouched."""
    hub = HubEndpoint(recv_deadline=30.0)
    alices, cases = {}, {}
    specs = [
        (make_pair(3000, 100, np.random.default_rng(10)),
         PBSConfig(seed=3, rateless=True), 10),
        (make_pair(2000, 50, np.random.default_rng(12)),
         PBSConfig(seed=5, rateless=True), 50),
    ]
    for (a, b), cfg, dk in specs:
        ta, tb = InMemoryDuplex.pair()
        ch = hub.add_peer(tb)
        hub.submit(ch, b, cfg=cfg, d_known=dk)
        ep = AliceEndpoint(ta, channel=ch)
        ep.submit(a, cfg=cfg, d_known=dk)
        alices[ch] = ep
        cases[ch] = (a, b, cfg, dk)
    outcomes, results, errors = run_hub(hub, alices)
    assert not errors, errors
    for ch, (a, b, cfg, dk) in cases.items():
        exp = reconcile(a, b, cfg, d_known=dk)
        got = results[ch][0]
        assert got.diff == exp.diff == true_diff(a, b), ch
        assert got.bytes_per_round == exp.bytes_per_round, ch
        assert got.bytes_sent == exp.bytes_sent, ch
        assert outcomes[ch].ok and outcomes[ch].verified == [True], ch
        assert outcomes[ch].error_kind is None, ch      # never "degraded"
    assert hub.stats["parity_extensions"] > 0
    assert hub.stats["sessions_degraded"] == 0
    assert alices[1].parity_extensions > 0
    assert alices[2].parity_extensions == 0             # honest peer


def test_tree_rateless_leaf_recovery():
    """Tree front end: a leaf whose level-ℓ estimate undershot recovers
    ratelessly inside its round instead of escalating — and never costs
    more than the escalation path it replaces."""
    from repro.tree.partition import TreeConfig, tree_reconcile

    a, b = make_pair(6000, 300, np.random.default_rng(42))
    want = true_diff(a, b)
    legacy = tree_reconcile(a, b, PBSConfig(seed=9), TreeConfig())
    res = tree_reconcile(
        a, b, PBSConfig(seed=9), TreeConfig(), rateless=True
    )
    assert res.success and res.diff == want == legacy.diff
    assert res.total_bytes <= legacy.total_bytes


# ---------------------------------------------------------------------------
# the endpoint's strict MSG_PARITY state machine
# ---------------------------------------------------------------------------


def test_bob_rejects_out_of_band_parity_frames():
    from repro.wire import frames as wf

    _, tb = InMemoryDuplex.pair()
    bob = BobEndpoint(tb)
    # no round in flight at all
    with pytest.raises(WireError, match="no round in flight"):
        bob._handle_parity(b"\x01\x01")
    # round in flight but nothing failing: no extension is pending
    bob._ctx = {
        "live": [], "ctx": {}, "per": {}, "plans": [], "sk_a": {},
        "fail": {}, "level": 0, "acc": {},
    }
    with pytest.raises(WireError, match="no extension pending"):
        bob._handle_parity(b"\x01\x01")
    # ladder exhausted: one frame past the cap is a protocol violation
    bob._ctx = {"fail": {0: [0]}, "level": MAX_PARITY_EXTENSIONS}
    with pytest.raises(WireError, match="cap"):
        bob._handle_parity(b"\x01" + bytes([MAX_PARITY_EXTENSIONS + 1]))


def test_bob_rejects_stale_round_parity():
    """A MSG_PARITY frame stamped with a stale round number fails the
    serve loop with a clean WireError instead of corrupting the ladder."""
    from repro.wire import frames as wf

    class _StaleParityAlice(AliceEndpoint):
        def _rateless_ladder(self, rnd, plans, per, live, ent_of):
            # derive a legitimate level-1 extension, then mis-stamp it
            from repro.net.endpoint import encode_round_rows_ext

            fail = {}
            for sid in live:
                row = per[sid]
                bad = [
                    s for s in range(len(row.active))
                    if not ent_of[sid][0][s]
                ]
                if bad:
                    fail[sid] = bad
            assert fail, "scenario must overload at least one group"
            part_plans = [
                plan for plan in plans
                if any(sess.sid in fail for sess, *_ in plan.members)
            ]
            inc_of = encode_round_rows_ext(
                part_plans, self.side, 1, self._interpret
            )
            parts = [sid for sid in live if sid in fail and sid in inc_of]
            blocks = [
                (inc_of[sid][0][fail[sid]], per[sid].plan.store.m)
                for sid in parts
            ]
            self._stream.send(wf.encode_parity(rnd + 7, 1, blocks))
            self._expect(wf.MSG_ROUND_REPLY)    # Bob dies first
            raise AssertionError("unreachable")

    a, b, cfg, dk = _wrongd_inputs()
    ta, tb = InMemoryDuplex.pair()
    alice, bob = _StaleParityAlice(ta), BobEndpoint(tb)
    alice.submit(a, cfg=cfg, d_known=dk)
    bob.submit(b, cfg=cfg, d_known=dk)
    with pytest.raises(WireError, match="parity frame for round"):
        run_pair(alice, bob)


# ---------------------------------------------------------------------------
# satellite regression: escalation (the legacy fallback) carries progress
# ---------------------------------------------------------------------------


def _escalation_inputs():
    """Tight round budget + underestimated d̂, rateless OFF: only the
    legacy degradation ladder can finish, and it must do so without
    re-transmitting settled units."""
    a, b = make_pair(4000, 1000, np.random.default_rng(7))
    return a, b, PBSConfig(seed=5, max_rounds=2), 250


def test_escalation_carries_settled_progress(monkeypatch):
    """No settled unit's bits are ledgered twice across an escalation: the
    carrying ladder's total is strictly below a no-carry ladder that
    forgets the recovered diff (forcing settled elements back onto the
    wire), and the carried ledger still sums consistently."""
    import repro.recon.session as rs

    a, b, cfg, dk = _escalation_inputs()
    want = true_diff(a, b)

    srv = ReconcileServer(degrade=True)
    srv.submit(a, b, cfg=cfg, d_known=dk)
    res = srv.run()[0]
    assert res.success and res.diff == want
    assert srv.stats["sessions_degraded"] >= 1
    assert sum(res.bytes_per_round) == res.bytes_sent

    # ablation: drop ONLY the recovered-diff carry (counters still carry
    # so the ledgers stay comparable) — settled elements re-enter the
    # effective sets and their bits are paid for again
    import repro.recon.server as rsrv

    real = rs.escalate_session

    def no_carry(batch, sess, *, rnd0):
        out = real(batch, sess, rnd0=rnd0)
        out.state.diff = set()
        return out

    monkeypatch.setattr(rs, "escalate_session", no_carry)
    monkeypatch.setattr(rsrv, "escalate_session", no_carry)
    srv0 = ReconcileServer(degrade=True)
    srv0.submit(a, b, cfg=cfg, d_known=dk)
    res0 = srv0.run()[0]
    monkeypatch.undo()
    assert res0.success and res0.diff == want
    assert res.bytes_sent < res0.bytes_sent


def test_escalation_cap_is_shared_single_source():
    """Satellite: the ladder caps are hoisted to core.pbs and threaded
    everywhere — no duplicated literals to drift apart."""
    import inspect

    from repro.core.pbs import MAX_ESCALATIONS
    from repro.recon import session as rs
    from repro.recon import server as srv_mod

    assert (
        inspect.signature(rs.degrade_exhausted)
        .parameters["max_escalations"].default is MAX_ESCALATIONS
    )
    assert (
        inspect.signature(srv_mod.ReconcileServer._escalate_exhausted)
        .parameters["max_escalations"].default is MAX_ESCALATIONS
    )


# ---------------------------------------------------------------------------
# resume safety: a crash mid-ladder never double-applies an extension
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("crash_after", [2, 3])
def test_crash_resume_mid_ladder_stays_byte_identical(crash_after):
    """The MSG_PARITY exchange is pre-barrier state: a peer crashing while
    the ladder is in flight resumes at equal barriers (the whole round —
    sketches, extensions, outcome — re-runs from scratch) or replays the
    one committed outcome frame, and either way the final Formula-(1)
    ledger is byte-identical to the rateless oracle."""
    import threading

    from repro.net import ChaosTransport, FaultPlan, TransportError

    a, b, cfg, dk = _wrongd_inputs()
    oracle = reconcile(a, b, cfg, d_known=dk)

    t_a_raw, t_h = InMemoryDuplex.pair()
    t_a = ChaosTransport(t_a_raw, FaultPlan(crash_after_sends=crash_after))
    hub = HubEndpoint(resume_window=30.0, recv_deadline=10.0)
    ch = hub.add_peer(t_h, label="ladder-crasher")
    hub.submit(ch, b, cfg=cfg, d_known=dk)
    ep = AliceEndpoint(t_a, channel=ch)
    ep.submit(a, cfg=cfg, d_known=dk)

    pending: dict = {}

    def on_barrier(rnd):
        if "t" in pending and hub._peers[ch].suspended:
            hub.resume_peer(ch, pending.pop("t"))

    hub.on_barrier = on_barrier
    state: dict = {}

    def drive():
        try:
            state["res"] = ep.run()
            return
        except TransportError as e:
            state["crash"] = e
        na, nh = InMemoryDuplex.pair()
        pending["t"] = nh
        ep.resume(na)
        state["res"] = ep.resume_run()

    th = threading.Thread(target=drive, daemon=True)
    th.start()
    outcomes = hub.serve()
    th.join(timeout=60)
    assert not th.is_alive(), "peer thread leaked"
    assert "crash" in state, "scripted crash never fired"

    res = state["res"][0]
    assert outcomes[ch].ok and outcomes[ch].verified == [True]
    assert outcomes[ch].error_kind == "resumed"
    assert res.success and res.diff == oracle.diff == true_diff(a, b)
    assert res.bytes_per_round == oracle.bytes_per_round
    assert res.bytes_sent == oracle.bytes_sent
    assert hub.stats["sessions_degraded"] == 0
    assert hub.stats["parity_extensions"] > 0
