#!/usr/bin/env python
"""Summarize a repro.obs trace: occupancy, per-peer bytes, round histogram.

Reads either export format (the Chrome trace JSON that ``--trace`` /
``Tracer.export_chrome`` writes, or JSONL from ``export_jsonl``) and prints
three sections:

* **occupancy** — wall-clock split of the traced window into host work,
  device work (``cat="device"`` spans: encode/decode dispatch and the
  ``device_get`` collect waits), and wire waits (``cat="wire"`` spans:
  round barriers, reply/outcome collection), per thread.  Overlapping
  same-category spans on a thread are unioned, so nested spans don't
  double-count.
* **per-peer traffic** — bytes, reconciled diff and rounds per session,
  grouped by peer/channel, from the ``session.result`` / ``peer.result``
  instants the endpoints emit at their freeze points.
* **round histogram** — observed completion-round distribution of the
  traced sessions against the ``core.markov`` §5.3 prediction
  (``expected_round_fractions``) for each (n, t, d̂, g) parameter class,
  so a trace directly shows whether the live system tracks the paper's
  Markov model.

Usage: python tools/trace_report.py TRACE [--kmax K] [--json]
(``--json`` emits the report as one machine-readable JSON document
instead of the text tables.)
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
from collections import defaultdict

ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

from repro.obs.trace import load_events  # noqa: E402


def _union(intervals: list[tuple[float, float]]) -> float:
    """Total covered length of possibly-overlapping [start, end) intervals."""
    total = 0.0
    end = -1.0
    for s, e in sorted(intervals):
        if s > end:
            total += e - s
            end = e
        elif e > end:
            total += e - end
            end = e
    return total


def occupancy(events: list[dict]) -> dict:
    """Host/device/wire split per thread, from the complete ("X") spans."""
    spans = [e for e in events if e.get("ph") == "X"]
    names = {
        e["tid"]: e["args"]["name"]
        for e in events
        if e.get("ph") == "M" and e.get("name") == "thread_name"
    }
    by_tid: dict = defaultdict(lambda: defaultdict(list))
    for e in spans:
        cat = e.get("cat", "host")
        by_tid[e["tid"]][cat].append((e["ts"], e["ts"] + e["dur"]))
    out = {}
    for tid, cats in by_tid.items():
        allspans = [iv for ivs in cats.values() for iv in ivs]
        t0 = min(s for s, _ in allspans)
        t1 = max(e for _, e in allspans)
        wall = t1 - t0
        device = _union(cats.get("device", []))
        wire = _union(cats.get("wire", []))
        covered = _union(allspans)
        out[names.get(tid, str(tid))] = {
            "wall_ms": wall / 1e3,
            "device_ms": device / 1e3,
            "wire_wait_ms": wire / 1e3,
            "host_ms": (covered - device - wire) / 1e3,
            "device_frac": device / wall if wall else 0.0,
        }
    return out


def per_peer(events: list[dict]) -> dict:
    """bytes / diff / rounds per peer, from session.result + peer.result."""
    peers: dict = defaultdict(
        lambda: {"sessions": 0, "bytes": 0, "diff": 0, "rounds": 0,
                 "failed": 0}
    )
    for e in events:
        if e.get("name") == "session.result":
            a = e["args"]
            key = f"channel{a['channel']}" if "channel" in a else "local"
            p = peers[key]
            p["sessions"] += 1
            p["bytes"] += a["bytes"]
            p["diff"] += a["diff"]
            p["rounds"] += a["rounds"]
            p["failed"] += 0 if a["success"] else 1
        elif e.get("name") == "peer.result":
            a = e["args"]
            p = peers[a.get("peer") or f"channel{a['channel']}"]
            p["resumes"] = a.get("resumes", 0)
            p["protocol_bytes"] = a.get("protocol_bytes", 0)
            p["resume_bytes"] = a.get("resume_bytes", 0)
            if not a.get("ok", True):
                p["failed"] += 1
    for p in peers.values():
        p["bytes_per_diff"] = round(p["bytes"] / max(1, p["diff"]), 2)
    return dict(peers)


def round_histogram(events: list[dict], kmax: int = 4) -> list[dict]:
    """Observed completion-round histogram vs the core.markov prediction,
    one entry per (n, t, d_est, g) parameter class seen in the trace."""
    classes: dict = defaultdict(list)
    for e in events:
        if e.get("name") == "session.result":
            a = e["args"]
            if "g" in a and a.get("success"):
                classes[(a["n"], a["t"], a["d_est"], a["g"])].append(
                    a["rounds"])
    out = []
    for (n, t, d, g), rounds in sorted(classes.items()):
        kmax_c = max(kmax, max(rounds))
        hist = [0] * kmax_c
        for r in rounds:
            hist[min(r, kmax_c) - 1] += 1
        entry = {
            "n": n, "t": t, "d_est": d, "g": g,
            "sessions": len(rounds),
            "rounds_hist": hist,
            "mean_rounds": round(sum(rounds) / len(rounds), 3),
        }
        try:
            from repro.core.markov import expected_round_fractions
            fracs = expected_round_fractions(n, t, d, g, kmax=kmax_c)
            entry["markov_round_fracs"] = [round(f, 4) for f in fracs]
            # the model predicts element-resolution fractions per round;
            # a session completes in round k once its last element lands,
            # so the predicted mean completion round is bounded below by
            # sum(k * frac_k) — report both for side-by-side reading
            entry["markov_mean_round"] = round(
                sum((k + 1) * f for k, f in enumerate(fracs)), 3
            )
        except Exception as exc:  # model out of range for these params
            entry["markov_error"] = str(exc)
        out.append(entry)
    return out


def build_report(events: list[dict], kmax: int = 4) -> dict:
    counts: dict = defaultdict(int)
    for e in events:
        counts[e.get("name", "?")] += 1
    return {
        "events": len(events),
        "occupancy": occupancy(events),
        "peers": per_peer(events),
        "round_histogram": round_histogram(events, kmax=kmax),
        "event_counts": dict(sorted(counts.items())),
    }


def print_report(rep: dict) -> None:
    print(f"trace: {rep['events']} events")
    print("\n== occupancy (per thread) ==")
    for name, o in rep["occupancy"].items():
        print(
            f"  {name:>24}: wall {o['wall_ms']:9.2f} ms | "
            f"host {o['host_ms']:9.2f} | device {o['device_ms']:9.2f} "
            f"({o['device_frac']:5.1%}) | wire wait {o['wire_wait_ms']:9.2f}"
        )
    if rep["peers"]:
        print("\n== per-peer traffic ==")
        for name, p in sorted(rep["peers"].items()):
            extra = ""
            if "resumes" in p:
                extra = (f" resumes={p['resumes']}"
                         f" resume_bytes={p.get('resume_bytes', 0)}")
            print(
                f"  {name:>12}: sessions={p['sessions']} bytes={p['bytes']} "
                f"diff={p['diff']} rounds={p['rounds']} "
                f"bytes/diff={p['bytes_per_diff']} failed={p['failed']}"
                + extra
            )
    if rep["round_histogram"]:
        print("\n== round histogram vs core.markov ==")
        for h in rep["round_histogram"]:
            print(
                f"  n={h['n']} t={h['t']} d_est={h['d_est']} g={h['g']} "
                f"({h['sessions']} sessions)"
            )
            print(f"    observed rounds hist: {h['rounds_hist']} "
                  f"(mean {h['mean_rounds']})")
            if "markov_round_fracs" in h:
                print(f"    markov round fracs:   {h['markov_round_fracs']} "
                      f"(mean {h['markov_mean_round']})")
            else:
                print(f"    markov: {h['markov_error']}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="Chrome trace JSON or JSONL export")
    ap.add_argument("--kmax", type=int, default=4,
                    help="rounds to model in the Markov comparison")
    ap.add_argument("--json", action="store_true",
                    help="emit the report as JSON instead of text")
    args = ap.parse_args(argv)
    events = load_events(args.trace)
    if not events:
        print("FAIL: trace holds no events", file=sys.stderr)
        return 1
    rep = build_report(events, kmax=args.kmax)
    if args.json:
        json.dump(rep, sys.stdout, indent=1)
        print()
    else:
        print_report(rep)
    return 0


if __name__ == "__main__":
    sys.exit(main())
