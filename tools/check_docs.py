#!/usr/bin/env python
"""Fail if any docstring cites a DESIGN.md section anchor that doesn't exist.

Module docstrings across the repo — and the README — cite stable anchors
like ``DESIGN.md §5``; this keeps those citations honest: every ``§N``
referenced next to a DESIGN.md mention must appear as a ``## §N`` heading
in DESIGN.md, so README links can't silently drift when sections move.

Markdown intra-document links are held to the same bar: every
``](#anchor)`` in the root docs must resolve to a heading in the same
file under GitHub's slugification (lowercase, spaces to dashes,
punctuation dropped), so ``[Observability](#observability)``-style
cross-references can't dangle when a heading is renamed.

Usage: python tools/check_docs.py   (exit 1 on dangling anchors)
"""
from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
SCAN_DIRS = ("src", "tests", "benchmarks", "examples", "tools")
SCAN_DOCS = ("README.md",)
CITE_RE = re.compile(r"DESIGN\.md[^§\n]{0,10}((?:§\d+[/,\s–—-]{0,3})+)")
SECT_RE = re.compile(r"§(\d+)")
LINK_DOCS = ("README.md", "DESIGN.md", "ROADMAP.md")
INTRA_LINK_RE = re.compile(r"\]\(#([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.+?)\s*$", re.MULTILINE)


def design_anchors() -> set[str]:
    design = ROOT / "DESIGN.md"
    if not design.exists():
        print("FAIL: DESIGN.md does not exist", file=sys.stderr)
        sys.exit(1)
    return {
        m.group(1)
        for m in re.finditer(r"^##\s+§(\d+)", design.read_text(), re.MULTILINE)
    }


def cited_anchors() -> dict[str, list[str]]:
    """anchor -> files citing it: every .py under the scan dirs plus the
    root docs (README) that deep-link DESIGN.md sections."""
    paths = [
        path
        for d in SCAN_DIRS
        for path in (ROOT / d).rglob("*.py")
        if "__pycache__" not in path.parts
    ]
    paths += [ROOT / doc for doc in SCAN_DOCS if (ROOT / doc).exists()]
    cites: dict[str, list[str]] = {}
    for path in paths:
        text = path.read_text(errors="replace")
        for cm in CITE_RE.finditer(text):
            for sm in SECT_RE.finditer(cm.group(1)):
                cites.setdefault(sm.group(1), []).append(
                    str(path.relative_to(ROOT))
                )
    return cites


def github_slug(heading: str) -> str:
    """GitHub's markdown heading slug: strip inline code/emphasis markers,
    lowercase, drop punctuation, spaces to dashes."""
    text = heading.strip().lower()
    text = re.sub(r"[`*_]", "", text)
    text = re.sub(r"[^\w\- ]", "", text, flags=re.UNICODE)
    return text.replace(" ", "-")


def check_intra_links() -> tuple[int, list[str]]:
    """Verify every ``](#anchor)`` in the root docs resolves to a heading
    slug in the same file; returns (links checked, failure messages)."""
    checked = 0
    failures = []
    for doc in LINK_DOCS:
        path = ROOT / doc
        if not path.exists():
            continue
        text = path.read_text(errors="replace")
        slugs = {github_slug(m.group(1)) for m in HEADING_RE.finditer(text)}
        # inline code and fenced blocks may *mention* link syntax; only
        # live markdown links are checked
        text = re.sub(r"```.*?```", "", text, flags=re.DOTALL)
        text = re.sub(r"`[^`\n]*`", "", text)
        for m in INTRA_LINK_RE.finditer(text):
            checked += 1
            if m.group(1) not in slugs:
                failures.append(
                    f"FAIL: {doc} links to #{m.group(1)} but has no "
                    f"matching heading"
                )
    return checked, failures


def main() -> int:
    anchors = design_anchors()
    cites = cited_anchors()
    missing = {sec: files for sec, files in cites.items() if sec not in anchors}
    n_links, link_failures = check_intra_links()
    if missing or link_failures:
        for sec in sorted(missing, key=int):
            files = ", ".join(sorted(set(missing[sec])))
            print(f"FAIL: DESIGN.md has no '## §{sec}' heading, cited by: {files}",
                  file=sys.stderr)
        for msg in link_failures:
            print(msg, file=sys.stderr)
        return 1
    total = sum(len(v) for v in cites.values())
    print(f"ok: {total} DESIGN.md citations across {len(cites)} anchors "
          f"({', '.join('§' + s for s in sorted(cites, key=int))}), all present; "
          f"{n_links} intra-doc links resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
