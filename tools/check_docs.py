#!/usr/bin/env python
"""Fail if any docstring cites a DESIGN.md section anchor that doesn't exist.

Module docstrings across the repo — and the README — cite stable anchors
like ``DESIGN.md §5``; this keeps those citations honest: every ``§N``
referenced next to a DESIGN.md mention must appear as a ``## §N`` heading
in DESIGN.md, so README links can't silently drift when sections move.

Usage: python tools/check_docs.py   (exit 1 on dangling anchors)
"""
from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
SCAN_DIRS = ("src", "tests", "benchmarks", "examples", "tools")
SCAN_DOCS = ("README.md",)
CITE_RE = re.compile(r"DESIGN\.md[^§\n]{0,10}((?:§\d+[/,\s–—-]{0,3})+)")
SECT_RE = re.compile(r"§(\d+)")


def design_anchors() -> set[str]:
    design = ROOT / "DESIGN.md"
    if not design.exists():
        print("FAIL: DESIGN.md does not exist", file=sys.stderr)
        sys.exit(1)
    return {
        m.group(1)
        for m in re.finditer(r"^##\s+§(\d+)", design.read_text(), re.MULTILINE)
    }


def cited_anchors() -> dict[str, list[str]]:
    """anchor -> files citing it: every .py under the scan dirs plus the
    root docs (README) that deep-link DESIGN.md sections."""
    paths = [
        path
        for d in SCAN_DIRS
        for path in (ROOT / d).rglob("*.py")
        if "__pycache__" not in path.parts
    ]
    paths += [ROOT / doc for doc in SCAN_DOCS if (ROOT / doc).exists()]
    cites: dict[str, list[str]] = {}
    for path in paths:
        text = path.read_text(errors="replace")
        for cm in CITE_RE.finditer(text):
            for sm in SECT_RE.finditer(cm.group(1)):
                cites.setdefault(sm.group(1), []).append(
                    str(path.relative_to(ROOT))
                )
    return cites


def main() -> int:
    anchors = design_anchors()
    cites = cited_anchors()
    missing = {sec: files for sec, files in cites.items() if sec not in anchors}
    if missing:
        for sec in sorted(missing, key=int):
            files = ", ".join(sorted(set(missing[sec])))
            print(f"FAIL: DESIGN.md has no '## §{sec}' heading, cited by: {files}",
                  file=sys.stderr)
        return 1
    total = sum(len(v) for v in cites.values())
    print(f"ok: {total} DESIGN.md citations across {len(cites)} anchors "
          f"({', '.join('§' + s for s in sorted(cites, key=int))}), all present")
    return 0


if __name__ == "__main__":
    sys.exit(main())
