"""Multi-session planning: cohort stores, round overlays, and SessionBatch.

One ``ReconSession`` is one Alice↔Bob pair running the full PBS protocol with
its own parameters, seeds, and byte ledger.  The planner's job (DESIGN.md §5)
is to turn S concurrent sessions into dense accelerator work each round while
keeping host↔device traffic off the steady-state path:

1. sessions are bucketed into **cohorts** by BCH code (n, t) — cohort
   membership is fixed at submit time, since phase 0 pins every session's
   code before the first round;
2. at the start of ``run`` each cohort builds its **element store** once:
   both sides' elements packed row-per-group in a padded ``(G, W)`` device
   matrix (grouping is round-invariant — the group hash seed never changes),
   uploaded a single time for the whole protocol;
3. per round the planner emits only small index/overlay arrays — the
   unit→store-row gather map, per-unit bin seeds, Alice's diff overlay
   (removed = A ∩ D̂, added = D̂ \\ A per unit), and the 3-way-split filter
   chains — and the fused executor rebuilds each unit's element rows *on
   device* from the resident store.

Every dynamic dimension (unit rows, store widths, overlay widths, filter
depth) is bucketed to a power of two at or above the hardware alignment
(``pow2_bucket``), so a serving loop converges to a bounded set of compiled
executor variants per cohort code.

The per-unit element *sets* the executor reconstructs are exactly the
``slot_assignment`` sets of the single-session oracle (parity/XOR/checksum
reductions are permutation-invariant), which is what keeps the batched
engine unit-for-unit identical to ``core.pbs.reconcile``.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

import jax.numpy as jnp

from repro.core.bch import bch_code
from repro.core.hashing import derive_seed
from repro.core.pbs import ProtocolPlan, SessionState, diff_overlay, group_view
from repro.kernels.platform import ceil_to as _ceil_to
from repro.kernels.platform import pow2_bucket


@dataclass
class ReconSession:
    """One submitted Alice↔Bob pair: its plan (phase 0) + mutable round state."""

    sid: int
    plan: ProtocolPlan
    state: SessionState

    @property
    def code_key(self) -> tuple[int, int]:
        return (self.plan.n, self.plan.t)


@dataclass
class CohortStore:
    """One cohort's device-resident element store, uploaded once per run.

    CSR layout — one flat element array per side plus per-row (start, count)
    — so the one-time upload is the raw element bytes with no padding waste.
    Row ``row_of[(sid, group)]`` is that session group's slice; the executor
    gathers ``flat[start + iota]`` into padded unit rows *on device* and
    derives the valid mask from the counts, so neither padded element
    matrices nor valid matrices ever cross the host↔device boundary.
    """

    n: int
    t: int
    m: int
    row_of: dict                   # (sid, group) -> store row index
    flat_a: jnp.ndarray            # (Ea_total,) uint32, device-resident
    start_a: jnp.ndarray           # (G,) int32 row offsets into flat_a
    cnt_a: jnp.ndarray             # (G,) int32 row element counts
    flat_b: jnp.ndarray            # (Eb_total,) uint32
    start_b: jnp.ndarray           # (G,) int32
    cnt_b: jnp.ndarray             # (G,) int32
    cnt_a_host: np.ndarray         # host copies: per-round gather widths +
    cnt_b_host: np.ndarray         #   legacy-traffic accounting
    h2d_bytes: int = 0             # one-time upload cost of this store


@dataclass
class CohortRoundPlan:
    """One cohort's host-side work order for one round: small arrays only.

    ``members`` maps each session to its slot range in the packed unit axis:
    (session, slot_base, active_units, bin_seed).  Unit u of session s lives
    at row ``slot_base + u`` of every per-unit array.  Rows past the true
    unit count have ``unit_valid == 0``: the executor masks them to empty,
    they sketch to zero, decode as trivially-ok, and are never mapped back.
    """

    store: CohortStore
    members: list
    units: int                     # true (unpadded) unit count
    width_a: int = 0               # this round's gather widths (pow2-bucketed
    width_b: int = 0               #   max row count among gathered units)
    arrays: dict = field(default_factory=dict)
    h2d_bytes: int = 0             # this round's overlay upload
    legacy_h2d_bytes: int = 0      # what the re-pack-per-round path would ship


def _grouped_rows(elems: np.ndarray, order: np.ndarray, bounds: np.ndarray, g: int):
    """Yield each group's elements (slot order) from a cached group view."""
    for grp in range(g):
        yield elems[order[bounds[grp] : bounds[grp + 1]]].astype(np.uint32)


def _by_group(vals: np.ndarray, g: int, seed_groups: int) -> dict:
    """Partition a small value array by its (round-invariant) group id,
    through the same canonical ``group_view`` the oracle partitions with."""
    if not len(vals):
        return {}
    _, order, bounds = group_view(vals, g, seed_groups)
    sv = vals[order]
    return {
        gi: sv[bounds[gi] : bounds[gi + 1]]
        for gi in range(g)
        if bounds[gi + 1] > bounds[gi]
    }


class SessionBatch:
    """Plans per-code cohorts: one resident store, small overlays per round."""

    # alignment floors of the packed layouts: unit rows to the sublane unit,
    # element widths to the lane unit; pow2_bucket rounds up from there.
    ROW_ALIGN = 8
    COL_ALIGN = 128
    OVERLAY_ALIGN = 8              # diff-overlay widths (removed/added cols)

    def __init__(self, sessions: list[ReconSession]):
        self.sessions = sessions
        self._stores: dict[tuple[int, int], CohortStore] = {}

    # ---- upload-once element store -------------------------------------

    def store_upload_bytes(self) -> int:
        """One-time H2D cost of the stores built so far (0 if none yet) —
        accounting only, never forces a build."""
        return sum(s.h2d_bytes for s in self._stores.values())

    def store_for(self, key: tuple[int, int]) -> CohortStore:
        """This code's store, built (and uploaded) on first live use only.

        Members are the sessions of this code that still have live units at
        build time, so a rebuilt batch never re-uploads elements for
        sessions that already finished; sessions only ever *finish*, so
        every later round's live set is a subset of the rows built here.
        """
        if key not in self._stores:
            members = [
                s for s in self.sessions
                if s.code_key == key and s.state.active_units()
            ]
            self._stores[key] = self._build_store(*key, members)
        return self._stores[key]

    def _build_store(self, n: int, t: int, members: list[ReconSession]) -> CohortStore:
        rows_a: list[np.ndarray] = []
        rows_b: list[np.ndarray] = []
        row_of: dict = {}
        for s in members:
            st, plan = s.state, s.plan
            segs_a = _grouped_rows(st.a, st.order_a, st.bounds_a, plan.g)
            segs_b = _grouped_rows(st.b, st.order_b, st.bounds_b, plan.g)
            for grp, (sa, sb) in enumerate(zip(segs_a, segs_b)):
                row_of[(s.sid, grp)] = len(rows_a)
                rows_a.append(sa)
                rows_b.append(sb)

        def pack(rows):
            cnt = np.array([len(r) for r in rows], dtype=np.int32)
            start = np.zeros(len(rows), dtype=np.int32)
            np.cumsum(cnt[:-1], out=start[1:])
            flat = (
                np.concatenate(rows).astype(np.uint32)
                if rows else np.zeros(0, np.uint32)
            )
            # lane-pad the flat tail only: the gather clamps past-end reads.
            # (No pow2 bucket here — the store shape is fixed for the whole
            # run, so it costs one executor compile per cohort, not one per
            # round; only round-varying dims need bucketing.)
            flat = np.pad(flat, (0, _ceil_to(max(len(flat), 1), self.COL_ALIGN) - len(flat)))
            return flat, start, cnt

        fa, sa, ca = pack(rows_a)
        fb, sb, cb = pack(rows_b)
        store = CohortStore(
            n=n, t=t, m=bch_code(n, t).m, row_of=row_of,
            flat_a=jnp.asarray(fa), start_a=jnp.asarray(sa), cnt_a=jnp.asarray(ca),
            flat_b=jnp.asarray(fb), start_b=jnp.asarray(sb), cnt_b=jnp.asarray(cb),
            cnt_a_host=ca, cnt_b_host=cb,
            h2d_bytes=sum(x.nbytes for x in (fa, sa, ca, fb, sb, cb)),
        )
        return store

    # ---- per-round overlay planning ------------------------------------

    def plan_round(self, rnd: int) -> list[CohortRoundPlan]:
        """All cohorts with live work in round ``rnd`` (empty list = all done)."""
        live: dict[tuple[int, int], list] = {}
        for s in self.sessions:
            if rnd > s.plan.cfg.max_rounds:
                continue  # session exhausted its budget: reported as failed
            active = s.state.active_units()
            if not active:
                continue
            live.setdefault(s.code_key, []).append((s, active))
        return [
            self._plan_cohort(self.store_for(key), members, rnd)
            for key, members in sorted(live.items())
        ]

    def _plan_cohort(self, store: CohortStore, members, rnd: int) -> CohortRoundPlan:
        total = sum(len(active) for _, active in members)
        u_pad = pow2_bucket(total, self.ROW_ALIGN)

        row_map = np.zeros(u_pad, dtype=np.int32)
        unit_valid = np.zeros(u_pad, dtype=np.int32)
        # built uint32 end-to-end: derive_seed yields uint32-range ints by
        # construction (asserted per session below), no dtype churn.
        seeds = np.zeros(u_pad, dtype=np.uint32)
        removed_of: list[np.ndarray | None] = [None] * u_pad
        added_of: list[np.ndarray | None] = [None] * u_pad
        filters_of: list[tuple] = [()] * u_pad

        packed = []
        base = 0
        for s, active in members:
            st, plan = s.state, s.plan
            bin_seed = derive_seed(plan.cfg.seed, 2, rnd)
            assert 0 <= bin_seed < 1 << 32, bin_seed
            removed, added = diff_overlay(st)
            rem_by_grp = _by_group(removed, plan.g, plan.seed_groups)
            add_by_grp = _by_group(added, plan.g, plan.seed_groups)
            for slot, u in enumerate(active):
                row = base + slot
                row_map[row] = store.row_of[(s.sid, u.group)]
                unit_valid[row] = 1
                seeds[row] = bin_seed
                removed_of[row] = rem_by_grp.get(u.group)
                added_of[row] = add_by_grp.get(u.group)
                filters_of[row] = u.filters
            packed.append((s, base, active, bin_seed))
            base += len(active)

        r_w = pow2_bucket(
            max((len(r) for r in removed_of if r is not None), default=0),
            self.OVERLAY_ALIGN,
        )
        x_w = pow2_bucket(
            max((len(a) for a in added_of if a is not None), default=0),
            self.OVERLAY_ALIGN,
        )
        # zero-width when no unit carries a split filter: the executor's
        # statically-unrolled filter loop then vanishes for the common
        # no-split round instead of hashing both (U, W) sides for nothing
        max_f = max((len(f) for f in filters_of), default=0)
        f_w = pow2_bucket(max_f, 1) if max_f else 0

        removed_arr = np.zeros((u_pad, r_w), dtype=np.uint32)
        removed_cnt = np.zeros(u_pad, dtype=np.int32)
        added_arr = np.zeros((u_pad, x_w), dtype=np.uint32)
        added_cnt = np.zeros(u_pad, dtype=np.int32)
        fseeds = np.zeros((u_pad, f_w), dtype=np.uint32)
        fbins = np.zeros((u_pad, f_w), dtype=np.int32)
        fcnt = np.zeros(u_pad, dtype=np.int32)
        for row in range(total):
            r = removed_of[row]
            if r is not None:
                removed_arr[row, : len(r)] = r
                removed_cnt[row] = len(r)
            a = added_of[row]
            if a is not None:
                added_arr[row, : len(a)] = a
                added_cnt[row] = len(a)
            flt = filters_of[row]
            if flt:
                fseeds[row, : len(flt)] = [fs for fs, _ in flt]
                fbins[row, : len(flt)] = [fi for _, fi in flt]
                fcnt[row] = len(flt)

        arrays = {
            "row_map": row_map,
            "unit_valid": unit_valid,
            "seeds": seeds,
            "removed": removed_arr,
            "removed_cnt": removed_cnt,
            "added": added_arr,
            "added_cnt": added_cnt,
            "fseeds": fseeds,
            "fbins": fbins,
            "fcnt": fcnt,
        }
        live_rows = row_map[:total]
        plan = CohortRoundPlan(
            store=store,
            members=packed,
            units=total,
            width_a=pow2_bucket(
                int(store.cnt_a_host[live_rows].max(initial=0)), self.COL_ALIGN
            ),
            width_b=pow2_bucket(
                int(store.cnt_b_host[live_rows].max(initial=0)), self.COL_ALIGN
            ),
            arrays=arrays,
            h2d_bytes=sum(a.nbytes for a in arrays.values()),
            legacy_h2d_bytes=self._legacy_round_bytes(
                store, row_map[:total], removed_cnt[:total], added_cnt[:total],
                fcnt[:total],
            ),
        )
        return plan

    def _legacy_round_bytes(self, store, row_map, removed_cnt, added_cnt, fcnt):
        """H2D bytes the re-pack-per-round layout (PR 1) would ship this round.

        That path re-uploaded per round, per side, a padded uint32 element
        matrix *and* an equally-sized int32 valid matrix plus per-unit seeds.
        Per-unit element counts are exact for plain units (store count minus
        removed plus added); split descendants hold ~count/3^depth of their
        parent — an estimate, but splits are rare and small.
        """
        if not len(row_map):
            return 0
        shrink = np.power(3.0, fcnt.astype(np.float64))
        na = (store.cnt_a_host[row_map] - removed_cnt + added_cnt) / shrink
        nb = store.cnt_b_host[row_map] / shrink
        u_old = max(self.ROW_ALIGN, _ceil_to(len(row_map), self.ROW_ALIGN))
        wa_old = max(self.COL_ALIGN, _ceil_to(int(na.max()), self.COL_ALIGN))
        wb_old = max(self.COL_ALIGN, _ceil_to(int(nb.max()), self.COL_ALIGN))
        # elems (4B) + valid (4B) per cell, both sides, + uint32 seeds
        return u_old * (wa_old + wb_old) * 8 + u_old * 4
