"""Multi-session planning: cohorts, unit packing, and the SessionBatch planner.

One ``ReconSession`` is one Alice↔Bob pair running the full PBS protocol with
its own parameters, seeds, and byte ledger.  The planner's job (DESIGN.md §5)
is to turn S concurrent sessions into dense accelerator work each round:

1. every session hash-partitions its sets into its g groups (plus any 3-way
   split descendants) exactly as `core.pbs` does — the *unit* queue;
2. sessions are bucketed into **cohorts** by BCH code (n, t), since one
   cohort shares one syndrome matrix and one vmapped decode;
3. each cohort's S×g active units are packed into one padded
   ``(units, elems)`` layout per side (rows = units, ragged element counts
   padded to a lane-aligned width, ``valid`` masking the tail), with a
   per-unit bin-seed vector so units from different sessions — which draw
   different per-round hash functions — still share a single kernel launch.

Packing is pure numpy bookkeeping over the *same* ``slot_assignment`` the
single-session oracle uses, which is what makes the batched engine
unit-for-unit identical to ``core.pbs.reconcile``.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.bch import BCHCode
from repro.core.hashing import derive_seed
from repro.core.pbs import (
    ProtocolPlan,
    SessionState,
    effective_set,
    group_view,
    slot_assignment,
)
from repro.kernels.platform import ceil_to as _ceil_to


@dataclass
class ReconSession:
    """One submitted Alice↔Bob pair: its plan (phase 0) + mutable round state."""

    sid: int
    plan: ProtocolPlan
    state: SessionState

    @property
    def code_key(self) -> tuple[int, int]:
        return (self.plan.n, self.plan.t)


@dataclass
class CohortRound:
    """One cohort's packed work for one protocol round.

    ``members`` maps each session to its slot range in the packed layout:
    (session, slot_base, active_units, bin_seed).  Unit u of session s lives
    at row ``slot_base + u`` of every array.  Rows past the true unit count
    are all-padding (valid == 0, seed == 0): they sketch to zero, decode as
    trivially-ok empty units, and are never mapped back to a session.
    """

    n: int
    t: int
    m: int
    members: list
    seeds: np.ndarray        # (U,) uint32 per-unit bin seeds
    elems_a: np.ndarray      # (U, Ea) uint32 padded Alice rows
    valid_a: np.ndarray      # (U, Ea) int32
    elems_b: np.ndarray      # (U, Eb) uint32 padded Bob rows
    valid_b: np.ndarray      # (U, Eb) int32


def _unit_rows(elems: np.ndarray, idx: np.ndarray, slot: np.ndarray, k: int):
    """Order one session's participating elements by unit slot.

    Returns (vals concatenated in slot order, per-slot counts (k,))."""
    counts = np.bincount(slot, minlength=k).astype(np.int64)
    order = np.argsort(slot, kind="stable")
    return elems[idx[order]].astype(np.uint32), counts


def _pack(vals_list, counts_list, u_pad: int, width: int):
    """Scatter slot-ordered value runs into a padded (u_pad, width) layout."""
    counts = np.concatenate(counts_list) if counts_list else np.zeros(0, np.int64)
    u = len(counts)
    out = np.zeros((u_pad, width), dtype=np.uint32)
    valid = np.zeros((u_pad, width), dtype=np.int32)
    if u:
        mask = np.arange(width)[None, :] < counts[:, None]
        out[:u][mask] = np.concatenate(vals_list)
        valid[:u][mask] = 1
    return out, valid


class SessionBatch:
    """Plans one padded cohort layout per BCH code for each protocol round."""

    # alignment of the packed layout: rows to the sublane unit, element
    # width to the lane unit, so TPU block shapes need no re-padding.
    ROW_ALIGN = 8
    COL_ALIGN = 128

    def __init__(self, sessions: list[ReconSession]):
        self.sessions = sessions

    def plan_round(self, rnd: int) -> list[CohortRound]:
        """All cohorts with live work in round ``rnd`` (empty list = all done)."""
        cohorts: dict[tuple[int, int], list] = {}
        for s in self.sessions:
            if rnd > s.plan.cfg.max_rounds:
                continue  # session exhausted its budget: reported as failed
            active = s.state.active_units()
            if not active:
                continue
            cohorts.setdefault(s.code_key, []).append((s, active))
        return [
            self._pack_cohort(n, t, members, rnd)
            for (n, t), members in sorted(cohorts.items())
        ]

    def _pack_cohort(self, n: int, t: int, members, rnd: int) -> CohortRound:
        vals_a, cnts_a, vals_b, cnts_b, seed_runs, packed = [], [], [], [], [], []
        base = 0
        for s, active in members:
            st = s.state
            plan = s.plan
            bin_seed = derive_seed(plan.cfg.seed, 2, rnd)
            k = len(active)

            eff_a = effective_set(st.a, st.diff)
            grp_a, order_a, bounds_a = group_view(eff_a, plan.g, plan.seed_groups)
            idx_a, slot_a = slot_assignment(eff_a, grp_a, active, order_a, bounds_a)
            idx_b, slot_b = slot_assignment(
                st.b, st.group_b, active, st.order_b, st.bounds_b
            )

            va, ca = _unit_rows(eff_a, idx_a, slot_a, k)
            vb, cb = _unit_rows(st.b, idx_b, slot_b, k)
            vals_a.append(va)
            cnts_a.append(ca)
            vals_b.append(vb)
            cnts_b.append(cb)
            seed_runs.append(np.full(k, bin_seed, dtype=np.uint64))
            packed.append((s, base, active, bin_seed))
            base += k

        u_pad = max(self.ROW_ALIGN, _ceil_to(base, self.ROW_ALIGN))
        wa = max(
            self.COL_ALIGN,
            _ceil_to(int(max((c.max() if len(c) else 0) for c in cnts_a)), self.COL_ALIGN),
        )
        wb = max(
            self.COL_ALIGN,
            _ceil_to(int(max((c.max() if len(c) else 0) for c in cnts_b)), self.COL_ALIGN),
        )
        elems_a, valid_a = _pack(vals_a, cnts_a, u_pad, wa)
        elems_b, valid_b = _pack(vals_b, cnts_b, u_pad, wb)
        seeds = np.zeros(u_pad, dtype=np.uint32)
        seeds[:base] = np.concatenate(seed_runs).astype(np.uint32)
        return CohortRound(
            n=n, t=t, m=BCHCode(n, t).m, members=packed, seeds=seeds,
            elems_a=elems_a, valid_a=valid_a, elems_b=elems_b, valid_b=valid_b,
        )
