"""Multi-session planning: cohort stores, round overlays, and SessionBatch.

One ``ReconSession`` is one Alice↔Bob pair running the full PBS protocol with
its own parameters, seeds, and byte ledger.  The planner's job (DESIGN.md §5)
is to turn S concurrent sessions into dense accelerator work each round while
keeping host↔device traffic off the steady-state path:

1. sessions are bucketed into **cohorts** by BCH code (n, t) — cohort
   membership is fixed at submit time, since phase 0 pins every session's
   code before the first round;
2. at the start of ``run`` each cohort builds its **element store** once:
   both sides' elements packed row-per-group in a padded ``(G, W)`` device
   matrix (grouping is round-invariant — the group hash seed never changes),
   uploaded a single time for the whole protocol;
3. per round the planner emits only small index/overlay arrays — the
   unit→store-row gather map, per-unit bin seeds, Alice's diff overlay
   (removed = A ∩ D̂, added = D̂ \\ A per unit), and the 3-way-split filter
   chains — and the fused executor rebuilds each unit's element rows *on
   device* from the resident store.

Every dynamic dimension (unit rows, store widths, overlay widths, filter
depth) is bucketed to a power of two at or above the hardware alignment
(``pow2_bucket``), so a serving loop converges to a bounded set of compiled
executor variants per cohort code.

The per-unit element *sets* the executor reconstructs are exactly the
``slot_assignment`` sets of the single-session oracle (parity/XOR/checksum
reductions are permutation-invariant), which is what keeps the batched
engine unit-for-unit identical to ``core.pbs.reconcile``.

Stores are built per *side*: the in-process server batches both sides; a
``repro.net`` wire endpoint passes ``sides=("a",)`` or ``("b",)`` and gets
the identical round plans over only its own resident elements
(DESIGN.md §9).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

import jax.numpy as jnp

from repro.core.bch import bch_code
from repro.core.hashing import derive_seed
from repro.core.pbs import (
    ProtocolPlan,
    SessionState,
    diff_overlay,
    group_view,
    session_live,
)
from repro.kernels.platform import ceil_to as _ceil_to
from repro.kernels.platform import pow2_bucket


@dataclass
class ReconSession:
    """One submitted Alice↔Bob pair: its plan (phase 0) + mutable round state.

    ``rnd0`` is the session's global-round offset: a hub peer admitted
    between global rounds runs its *local* protocol rounds 1, 2, … at global
    rounds ``rnd0 + 1, rnd0 + 2, …`` (DESIGN.md §10).  All protocol-visible
    round arithmetic — bin seeds, the round budget, frame round numbers —
    uses the local round, so a late joiner is byte-identical to a pair that
    started alone.  ``failed`` excludes a session from all future planning
    (hub eviction: straggler deadline or peer disconnect) without touching
    its cohort's device-resident store.
    """

    sid: int
    plan: ProtocolPlan
    state: SessionState
    rnd0: int = 0
    failed: bool = False

    @property
    def code_key(self) -> tuple[int, int]:
        return (self.plan.n, self.plan.t)


@dataclass
class SideStore:
    """One side's slice of a cohort store: CSR flat elements + row extents.

    A both-sides batch (the in-process ``ReconcileServer``) holds an "a" and
    a "b" SideStore per cohort; a ``repro.net`` endpoint holds only its own
    side — Alice never materializes Bob's elements and vice versa.
    """

    flat: jnp.ndarray              # (E_total,) uint32, device-resident
    start: jnp.ndarray             # (G,) int32 row offsets into flat
    cnt: jnp.ndarray               # (G,) int32 row element counts
    cnt_host: np.ndarray           # host copy: gather widths + accounting
    h2d_bytes: int                 # one-time upload cost of this side


@dataclass
class CohortStore:
    """One cohort's device-resident element store, uploaded once per run.

    CSR layout — one flat element array per resident side plus per-row
    (start, count) — so the one-time upload is the raw element bytes with no
    padding waste.  Row ``row_of[(sid, group)]`` is that session group's
    slice; the executor gathers ``flat[start + iota]`` into padded unit rows
    *on device* and derives the valid mask from the counts, so neither
    padded element matrices nor valid matrices ever cross the host↔device
    boundary.  ``sides`` holds the resident ``SideStore``s: both for the
    in-process server, exactly one for a wire endpoint.
    """

    n: int
    t: int
    m: int
    row_of: dict                   # (sid, group) -> store row index
    sides: dict                    # "a"/"b" -> SideStore

    @property
    def a(self) -> SideStore:
        return self.sides["a"]

    @property
    def b(self) -> SideStore:
        return self.sides["b"]

    @property
    def h2d_bytes(self) -> int:
        return sum(s.h2d_bytes for s in self.sides.values())


@dataclass
class CohortRoundPlan:
    """One cohort's host-side work order for one round: small arrays only.

    ``members`` maps each session to its slot range in the packed unit axis:
    (session, slot_base, active_units, bin_seed).  Unit u of session s lives
    at row ``slot_base + u`` of every per-unit array.  Rows past the true
    unit count have ``unit_valid == 0``: the executor masks them to empty,
    they sketch to zero, decode as trivially-ok, and are never mapped back.
    """

    store: CohortStore
    members: list
    units: int                     # true (unpadded) unit count
    width_a: int = 0               # this round's gather widths (pow2-bucketed
    width_b: int = 0               #   max row count among gathered units)
    arrays: dict = field(default_factory=dict)
    h2d_bytes: int = 0             # this round's overlay upload
    legacy_h2d_bytes: int = 0      # what the re-pack-per-round path would ship


def _grouped_rows(elems: np.ndarray, order: np.ndarray, bounds: np.ndarray, g: int):
    """Yield each group's elements (slot order) from a cached group view."""
    for grp in range(g):
        yield elems[order[bounds[grp] : bounds[grp + 1]]].astype(np.uint32)


def _by_group(vals: np.ndarray, g: int, seed_groups: int) -> dict:
    """Partition a small value array by its (round-invariant) group id,
    through the same canonical ``group_view`` the oracle partitions with."""
    if not len(vals):
        return {}
    _, order, bounds = group_view(vals, g, seed_groups)
    sv = vals[order]
    return {
        gi: sv[bounds[gi] : bounds[gi + 1]]
        for gi in range(g)
        if bounds[gi + 1] > bounds[gi]
    }


def pack_csr(rows: list, col_align: int) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Pack variable-length rows into (flat, start, cnt) CSR arrays.

    Lane-pads the flat tail only: the device gather clamps past-end reads.
    (No pow2 bucket — the store shape is fixed for the whole run, so it
    costs one executor compile per cohort, not one per round; only
    round-varying dims need bucketing.)
    """
    cnt = np.array([len(r) for r in rows], dtype=np.int32)
    start = np.zeros(len(rows), dtype=np.int32)
    np.cumsum(cnt[:-1], out=start[1:])
    flat = (
        np.concatenate(rows).astype(np.uint32) if rows else np.zeros(0, np.uint32)
    )
    flat = np.pad(flat, (0, _ceil_to(max(len(flat), 1), col_align) - len(flat)))
    return flat, start, cnt


class SessionBatch:
    """Plans per-code cohorts: one resident store, small overlays per round.

    ``sides`` selects which element stores this batch materializes: the
    in-process server batches both ("a", "b"); a wire endpoint passes only
    its own side, and the same planner then emits the same round arrays
    minus the other side's store/widths.
    """

    # alignment floors of the packed layouts: unit rows to the sublane unit,
    # element widths to the lane unit; pow2_bucket rounds up from there.
    ROW_ALIGN = 8
    COL_ALIGN = 128
    OVERLAY_ALIGN = 8              # diff-overlay widths (removed/added cols)

    def __init__(self, sessions: list[ReconSession], sides: tuple = ("a", "b")):
        self.sessions = sessions
        self.sides = tuple(sides)
        self._stores: dict[tuple[int, int], CohortStore] = {}
        self.store_builds = 0          # cohort-store builds incl. rebuilds
        self.store_build_bytes = 0     # cumulative H2D bytes of those builds

    # ---- upload-once element store -------------------------------------

    def store_upload_bytes(self) -> int:
        """One-time H2D cost of the stores built so far (0 if none yet) —
        accounting only, never forces a build."""
        return sum(s.h2d_bytes for s in self._stores.values())

    def add_sessions(self, new: list[ReconSession]) -> None:
        """Admit sessions mid-run (hub peers joining between global rounds).

        Appends to the shared session list and invalidates the cohort
        stores of the affected code keys: those cohorts rebuild (and
        re-upload) on next live use with the union of old live and new
        members.  Untouched cohorts keep their resident stores.
        """
        keys = {s.code_key for s in new}
        self.sessions.extend(new)
        for key in keys:
            self._stores.pop(key, None)

    def store_for(self, key: tuple[int, int]) -> CohortStore:
        """This code's store, built (and uploaded) on first live use only.

        Members are the sessions of this code that still have live units at
        build time, so a rebuilt batch never re-uploads elements for
        sessions that already finished; sessions only ever *finish*, so
        every later round's live set is a subset of the rows built here.
        """
        if key not in self._stores:
            members = [
                s for s in self.sessions
                if s.code_key == key and not s.failed and s.state.active_units()
            ]
            self._stores[key] = self._build_store(*key, members)
        return self._stores[key]

    def _build_store(self, n: int, t: int, members: list[ReconSession]) -> CohortStore:
        rows: dict[str, list[np.ndarray]] = {side: [] for side in self.sides}
        row_of: dict = {}
        nrows = 0
        for s in members:
            st, plan = s.state, s.plan
            segs = {
                side: _grouped_rows(*(
                    (st.a, st.order_a, st.bounds_a) if side == "a"
                    else (st.b, st.order_b, st.bounds_b)
                ), plan.g)
                for side in self.sides
            }
            for grp in range(plan.g):
                row_of[(s.sid, grp)] = nrows
                nrows += 1
                for side in self.sides:
                    rows[side].append(next(segs[side]))

        sides: dict[str, SideStore] = {}
        for side in self.sides:
            flat, start, cnt = pack_csr(rows[side], self.COL_ALIGN)
            sides[side] = SideStore(
                flat=jnp.asarray(flat), start=jnp.asarray(start),
                cnt=jnp.asarray(cnt), cnt_host=cnt,
                h2d_bytes=flat.nbytes + start.nbytes + cnt.nbytes,
            )
        store = CohortStore(n=n, t=t, m=bch_code(n, t).m, row_of=row_of, sides=sides)
        self.store_builds += 1
        self.store_build_bytes += store.h2d_bytes
        return store

    # ---- per-round overlay planning ------------------------------------

    def plan_round(self, rnd: int) -> list[CohortRoundPlan]:
        """All cohorts with live work in global round ``rnd`` (empty = done).

        Liveness is the shared ``core.pbs.session_live`` predicate — the
        same rule both wire endpoints apply, so their cohort plans (and
        frame schemas) line up without any membership negotiation.  Each
        session is evaluated at its *local* round ``rnd - rnd0`` (non-hub
        batches have ``rnd0 == 0`` everywhere, so local == global); failed
        (hub-evicted) sessions never plan again.
        """
        live: dict[tuple[int, int], list] = {}
        for s in self.sessions:
            if s.failed or rnd <= s.rnd0:
                continue  # evicted, or not yet admitted at this round
            if not session_live(s.state, s.plan.cfg, rnd - s.rnd0):
                continue  # budget exhausted (reported failed) or finished
            live.setdefault(s.code_key, []).append((s, s.state.active_units()))
        return [
            self._plan_cohort(self.store_for(key), members, rnd)
            for key, members in sorted(live.items())
        ]

    def _plan_cohort(self, store: CohortStore, members, rnd: int) -> CohortRoundPlan:
        total = sum(len(active) for _, active in members)
        u_pad = pow2_bucket(total, self.ROW_ALIGN)

        row_map = np.zeros(u_pad, dtype=np.int32)
        unit_valid = np.zeros(u_pad, dtype=np.int32)
        # built uint32 end-to-end: derive_seed yields uint32-range ints by
        # construction (asserted per session below), no dtype churn.
        seeds = np.zeros(u_pad, dtype=np.uint32)
        removed_of: list[np.ndarray | None] = [None] * u_pad
        added_of: list[np.ndarray | None] = [None] * u_pad
        filters_of: list[tuple] = [()] * u_pad

        packed = []
        base = 0
        for s, active in members:
            st, plan = s.state, s.plan
            bin_seed = derive_seed(plan.cfg.seed, 2, rnd - s.rnd0)
            assert 0 <= bin_seed < 1 << 32, bin_seed
            removed, added = diff_overlay(st)
            rem_by_grp = _by_group(removed, plan.g, plan.seed_groups)
            add_by_grp = _by_group(added, plan.g, plan.seed_groups)
            for slot, u in enumerate(active):
                row = base + slot
                row_map[row] = store.row_of[(s.sid, u.group)]
                unit_valid[row] = 1
                seeds[row] = bin_seed
                removed_of[row] = rem_by_grp.get(u.group)
                added_of[row] = add_by_grp.get(u.group)
                filters_of[row] = u.filters
            packed.append((s, base, active, bin_seed))
            base += len(active)

        # Overlay widths: a Bob-side batch (no "a" side) can never carry a
        # diff overlay — zero width makes the executor's overlay ops vanish
        # entirely.  An Alice-side batch keeps the aligned floor even in
        # round 1 (empty overlay), so every round shares one executor shape
        # per (U, Wa, Wb, F) instead of compiling a round-1-only variant.
        if "a" in self.sides:
            max_r = max((len(r) for r in removed_of if r is not None), default=0)
            max_x = max((len(a) for a in added_of if a is not None), default=0)
            r_w = pow2_bucket(max_r, self.OVERLAY_ALIGN)
            x_w = pow2_bucket(max_x, self.OVERLAY_ALIGN)
        else:
            r_w = x_w = 0
        # zero-width when no unit carries a split filter: the executor's
        # statically-unrolled filter loop then vanishes for the common
        # no-split round instead of hashing both (U, W) sides for nothing
        max_f = max((len(f) for f in filters_of), default=0)
        f_w = pow2_bucket(max_f, 1) if max_f else 0

        removed_arr = np.zeros((u_pad, r_w), dtype=np.uint32)
        removed_cnt = np.zeros(u_pad, dtype=np.int32)
        added_arr = np.zeros((u_pad, x_w), dtype=np.uint32)
        added_cnt = np.zeros(u_pad, dtype=np.int32)
        fseeds = np.zeros((u_pad, f_w), dtype=np.uint32)
        fbins = np.zeros((u_pad, f_w), dtype=np.int32)
        fcnt = np.zeros(u_pad, dtype=np.int32)
        for row in range(total):
            r = removed_of[row]
            if r is not None:
                removed_arr[row, : len(r)] = r
                removed_cnt[row] = len(r)
            a = added_of[row]
            if a is not None:
                added_arr[row, : len(a)] = a
                added_cnt[row] = len(a)
            flt = filters_of[row]
            if flt:
                fseeds[row, : len(flt)] = [fs for fs, _ in flt]
                fbins[row, : len(flt)] = [fi for _, fi in flt]
                fcnt[row] = len(flt)

        arrays = {
            "row_map": row_map,
            "unit_valid": unit_valid,
            "seeds": seeds,
            "removed": removed_arr,
            "removed_cnt": removed_cnt,
            "added": added_arr,
            "added_cnt": added_cnt,
            "fseeds": fseeds,
            "fbins": fbins,
            "fcnt": fcnt,
        }
        live_rows = row_map[:total]

        def width(side: str) -> int:
            if side not in store.sides:
                return 0
            return pow2_bucket(
                int(store.sides[side].cnt_host[live_rows].max(initial=0)),
                self.COL_ALIGN,
            )

        plan = CohortRoundPlan(
            store=store,
            members=packed,
            units=total,
            width_a=width("a"),
            width_b=width("b"),
            arrays=arrays,
            h2d_bytes=sum(a.nbytes for a in arrays.values()),
            legacy_h2d_bytes=(
                self._legacy_round_bytes(
                    store, row_map[:total], removed_cnt[:total],
                    added_cnt[:total], fcnt[:total],
                )
                if {"a", "b"} <= set(store.sides)
                else 0
            ),
        )
        return plan

    def _legacy_round_bytes(self, store, row_map, removed_cnt, added_cnt, fcnt):
        """H2D bytes the re-pack-per-round layout (PR 1) would ship this round.

        That path re-uploaded per round, per side, a padded uint32 element
        matrix *and* an equally-sized int32 valid matrix plus per-unit seeds.
        Per-unit element counts are exact for plain units (store count minus
        removed plus added); split descendants hold ~count/3^depth of their
        parent — an estimate, but splits are rare and small.
        """
        if not len(row_map):
            return 0
        shrink = np.power(3.0, fcnt.astype(np.float64))
        na = (store.a.cnt_host[row_map] - removed_cnt + added_cnt) / shrink
        nb = store.b.cnt_host[row_map] / shrink
        u_old = max(self.ROW_ALIGN, _ceil_to(len(row_map), self.ROW_ALIGN))
        wa_old = max(self.COL_ALIGN, _ceil_to(int(na.max()), self.COL_ALIGN))
        wb_old = max(self.COL_ALIGN, _ceil_to(int(nb.max()), self.COL_ALIGN))
        # elems (4B) + valid (4B) per cell, both sides, + uint32 seeds
        return u_old * (wa_old + wb_old) * 8 + u_old * 4
