"""Multi-session planning: cohort stores, round overlays, and SessionBatch.

One ``ReconSession`` is one Alice↔Bob pair running the full PBS protocol with
its own parameters, seeds, and byte ledger.  The planner's job (DESIGN.md §5)
is to turn S concurrent sessions into dense accelerator work each round while
keeping host↔device traffic off the steady-state path:

1. sessions are bucketed into **cohorts** by BCH code (n, t) — cohort
   membership is fixed at submit time, since phase 0 pins every session's
   code before the first round;
2. at the start of ``run`` each cohort builds its **element store** once:
   both sides' elements packed row-per-group in a padded ``(G, W)`` device
   matrix (grouping is round-invariant — the group hash seed never changes),
   uploaded a single time for the whole protocol;
3. per round the planner emits only small index/overlay arrays — the
   unit→store-row gather map, per-unit bin seeds, Alice's diff overlay
   (removed = A ∩ D̂, added = D̂ \\ A per unit), and the 3-way-split filter
   chains — and the fused executor rebuilds each unit's element rows *on
   device* from the resident store.

Every dynamic dimension (unit rows, store widths, overlay widths, filter
depth) is bucketed to a power of two at or above the hardware alignment
(``pow2_bucket``), so a serving loop converges to a bounded set of compiled
executor variants per cohort code.

The per-unit element *sets* the executor reconstructs are exactly the
``slot_assignment`` sets of the single-session oracle (parity/XOR/checksum
reductions are permutation-invariant), which is what keeps the batched
engine unit-for-unit identical to ``core.pbs.reconcile``.

Stores are built per *side*: the in-process server batches both sides; a
``repro.net`` wire endpoint passes ``sides=("a",)`` or ``("b",)`` and gets
the identical round plans over only its own resident elements
(DESIGN.md §9).

A **mutable** batch (``SessionBatch(mutable=True)``, DESIGN.md §11) is the
continuous-sync variant: rows are packed with per-row capacity slack, and
``apply_mutations`` patches the device-resident CSR *in place* between
epochs — removals back-fill each hole with the row's tail element (a
tombstone immediately reclaimed), additions append into the row's free
lane — shipping only O(churn) scatter indices/values instead of rebuilding
and re-uploading the whole store.  A row that outgrows its lane triggers a
compaction (one counted cohort rebuild with fresh slack).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

import jax.numpy as jnp

from repro.core.bch import bch_code
from repro.core.hashing import derive_seed_seeded, hash_to_range_seeded
from repro.core.pbs import (
    MAX_ESCALATIONS,
    ProtocolPlan,
    SessionState,
    diff_overlay,
    escalated_plan,
    group_view,
    new_session_state,
    session_live,
)
from repro.kernels.platform import ceil_to as _ceil_to
from repro.kernels.platform import pow2_bucket
from repro.obs.trace import NULL_TRACER


class StoreCapacityError(RuntimeError):
    """A delta mutation would overflow a row's capacity lane: the caller
    must compact (rebuild the cohort store with fresh slack)."""


@dataclass
class ReconSession:
    """One submitted Alice↔Bob pair: its plan (phase 0) + mutable round state.

    ``rnd0`` is the session's global-round offset: a hub peer admitted
    between global rounds runs its *local* protocol rounds 1, 2, … at global
    rounds ``rnd0 + 1, rnd0 + 2, …`` (DESIGN.md §10).  All protocol-visible
    round arithmetic — bin seeds, the round budget, frame round numbers —
    uses the local round, so a late joiner is byte-identical to a pair that
    started alone.  ``failed`` excludes a session from all future planning
    (hub eviction: straggler deadline or peer disconnect) without touching
    its cohort's device-resident store.

    ``suspended`` (DESIGN.md §13) parks a session whose peer disconnected
    but is still *resumable*: it plans no rounds while parked, but — unlike
    ``failed`` — it keeps its cohort-store membership, so a store rebuilt
    during the outage still carries its rows and resumption needs zero
    store work.  ``escalations`` counts the degradation-ladder rungs this
    session has climbed (``escalate_session``).
    """

    sid: int
    plan: ProtocolPlan
    state: SessionState
    rnd0: int = 0
    failed: bool = False
    suspended: bool = False
    escalations: int = 0

    @property
    def code_key(self) -> tuple[int, int]:
        return (self.plan.n, self.plan.t)


@dataclass
class SideStore:
    """One side's slice of a cohort store: CSR flat elements + row extents.

    A both-sides batch (the in-process ``ReconcileServer``) holds an "a" and
    a "b" SideStore per cohort; a ``repro.net`` endpoint holds only its own
    side — Alice never materializes Bob's elements and vice versa.

    Mutable stores (continuous sync, DESIGN.md §11) additionally keep host
    mirrors: ``flat_host`` (the element lanes), ``cap_host`` (each row's
    allocated lane capacity, ``cnt_host <= cap_host``).  The executor never
    sees the lanes — it gathers ``offs < cnt`` exactly as for a one-shot
    store, so delta mutations change *no* device code path.
    """

    flat: jnp.ndarray              # (E_total,) uint32, device-resident
    start: jnp.ndarray             # (G,) int32 row offsets into flat
    cnt: jnp.ndarray               # (G,) int32 row element counts
    cnt_host: np.ndarray           # host copy: gather widths + accounting
    h2d_bytes: int                 # one-time upload cost of this side
    start_host: np.ndarray | None = None
    flat_host: np.ndarray | None = None   # mutable stores only
    cap_host: np.ndarray | None = None    # mutable stores only


@dataclass
class CohortStore:
    """One cohort's device-resident element store, uploaded once per run.

    CSR layout — one flat element array per resident side plus per-row
    (start, count) — so the one-time upload is the raw element bytes with no
    padding waste.  Row ``row_of[(sid, group)]`` is that session group's
    slice; the executor gathers ``flat[start + iota]`` into padded unit rows
    *on device* and derives the valid mask from the counts, so neither
    padded element matrices nor valid matrices ever cross the host↔device
    boundary.  ``sides`` holds the resident ``SideStore``s: both for the
    in-process server, exactly one for a wire endpoint.
    """

    n: int
    t: int
    m: int
    row_of: dict                   # (sid, group) -> store row index
    sides: dict                    # "a"/"b" -> SideStore
    generation: int = 0            # bumped per in-place delta patch
    # rows are contiguous per member session (row_of[(sid, g)] == base + g);
    # the vectorized planner turns S×g dict lookups into one add over this
    row_base: dict = field(default_factory=dict)   # sid -> first store row

    @property
    def a(self) -> SideStore:
        return self.sides["a"]

    @property
    def b(self) -> SideStore:
        return self.sides["b"]

    @property
    def h2d_bytes(self) -> int:
        return sum(s.h2d_bytes for s in self.sides.values())

    def apply_side_mutations(self, side: str, row_updates: dict) -> int:
        """Patch one side's CSR rows in place; returns the delta-H2D bytes.

        ``row_updates`` maps store row -> (added values, removed values),
        both duplicate-free and disjoint from each other.  Removals
        back-fill each hole with an element from the row's live tail (a
        tombstone reclaimed in the same pass), additions append into the
        row's free lane, so the live elements stay a ``[start, start+cnt)``
        prefix and the executor's gather mask needs no changes.  The device
        update is two scatters (flat slots, row counts); only their index
        and value arrays cross the host↔device boundary.

        Raises ``StoreCapacityError`` (capacity overflow, the compaction
        trigger) or ``ValueError`` (removing a non-resident element) —
        both checked up front, before any mirror or device state changes.
        """
        ss = self.sides[side]
        if ss.flat_host is None or ss.cap_host is None:
            raise StoreCapacityError("store was built without mutation lanes")
        for row, (added, removed) in row_updates.items():
            if ss.cnt_host[row] - len(removed) + len(added) > ss.cap_host[row]:
                raise StoreCapacityError(
                    f"row {row}: {ss.cnt_host[row]} - {len(removed)} + "
                    f"{len(added)} elements exceed the {ss.cap_host[row]} lane"
                )
            if removed:
                seg = ss.flat_host[
                    ss.start_host[row] : ss.start_host[row] + ss.cnt_host[row]
                ]
                missing = len(removed) - int(np.isin(seg, removed).sum())
                if missing:
                    raise ValueError(
                        f"row {row}: {missing} removed elements not resident"
                    )
        idx_out: list[int] = []
        val_out: list[int] = []
        rows_out: list[int] = []
        cnt_out: list[int] = []
        for row in sorted(row_updates):
            added, removed = row_updates[row]
            s, c = int(ss.start_host[row]), int(ss.cnt_host[row])
            if len(removed):
                seg = ss.flat_host[s : s + c]
                hole = np.isin(seg, removed)
                k = len(removed)
                # holes below the new extent take the tail's live elements
                dst = np.nonzero(hole[: c - k])[0]
                src = seg[c - k :][~hole[c - k :]]
                for p, v in zip(dst, src):
                    ss.flat_host[s + p] = v
                    idx_out.append(s + int(p))
                    val_out.append(int(v))
                c -= k
            for v in added:
                ss.flat_host[s + c] = v
                idx_out.append(s + c)
                val_out.append(int(v))
                c += 1
            if c != int(ss.cnt_host[row]):
                ss.cnt_host[row] = c
                rows_out.append(row)
                cnt_out.append(c)
        delta = 0
        if idx_out:
            idx = np.asarray(idx_out, dtype=np.int32)
            val = np.asarray(val_out, dtype=np.uint32)
            ss.flat = ss.flat.at[jnp.asarray(idx)].set(jnp.asarray(val))
            delta += idx.nbytes + val.nbytes
        if rows_out:
            rows = np.asarray(rows_out, dtype=np.int32)
            cnts = np.asarray(cnt_out, dtype=np.int32)
            ss.cnt = ss.cnt.at[jnp.asarray(rows)].set(jnp.asarray(cnts))
            delta += rows.nbytes + cnts.nbytes
        self.generation += 1
        return delta


@dataclass
class CohortRoundPlan:
    """One cohort's host-side work order for one round: small arrays only.

    ``members`` maps each session to its slot range in the packed unit axis:
    (session, slot_base, active_units, bin_seed).  Unit u of session s lives
    at row ``slot_base + u`` of every per-unit array.  Rows past the true
    unit count have ``unit_valid == 0``: the executor masks them to empty,
    they sketch to zero, decode as trivially-ok, and are never mapped back.
    """

    store: CohortStore
    members: list
    units: int                     # true (unpadded) unit count
    width_a: int = 0               # this round's gather widths (pow2-bucketed
    width_b: int = 0               #   max row count among gathered units)
    arrays: dict = field(default_factory=dict)
    h2d_bytes: int = 0             # this round's overlay upload
    legacy_h2d_bytes: int = 0      # what the re-pack-per-round path would ship


def _group_overlay(parts, per_sess, g_of, gseed_of, row_key, gmax):
    """Batch-wide overlay grouping: ``_by_group`` for S sessions in one pass.

    ``parts`` holds each session's overlay values (diff_overlay output
    order), ``per_sess`` their lengths.  Group ids come from the seeded
    multiply-shift hash (exactly ``hash_to_range`` per element), and one
    stable lexsort on (session, group) reproduces every session's stable
    ``group_view`` ordering at once.  Returns ``(row_len, fill)``: row_len
    is each unit row's overlay length (0 when its (session, group) segment
    is empty — the scalar planner's ``None``), and ``fill(target)``
    scatters the grouped values into the padded overlay matrix with one
    fancy-index assignment; ``fill`` is None when no session has overlay
    values (DESIGN.md §12).
    """
    nrows = len(row_key)
    row_len = np.zeros(nrows, dtype=np.int64)
    if not int(per_sess.sum()):
        return row_len, None
    vals = np.concatenate([p for p in parts if len(p)])
    vsess = np.repeat(np.arange(len(per_sess)), per_sess)
    grp = hash_to_range_seeded(vals, g_of[vsess], gseed_of[vsess])
    order = np.lexsort((grp, vsess))  # stable: in-order within (sess, group)
    sv = vals[order]
    key = vsess[order] * gmax + grp[order]
    change = np.empty(len(key), dtype=bool)
    change[0] = True
    np.not_equal(key[1:], key[:-1], out=change[1:])
    seg_at = np.nonzero(change)[0]               # segment starts into sv
    seg_key = key[seg_at]                        # ascending by construction
    seg_len = np.diff(np.append(seg_at, len(key)))
    pos = np.searchsorted(seg_key, row_key)
    pc = np.minimum(pos, len(seg_key) - 1)
    has = seg_key[pc] == row_key
    row_len[has] = seg_len[pc[has]]
    row_src = np.where(has, seg_at[pc], 0)

    def fill(target: np.ndarray) -> None:
        rows_rep = np.repeat(np.arange(nrows), row_len)
        within = np.arange(int(row_len.sum())) - np.repeat(
            np.cumsum(row_len) - row_len, row_len
        )
        target[rows_rep, within] = sv[np.repeat(row_src, row_len) + within]

    return row_len, fill


def _by_group(vals: np.ndarray, g: int, seed_groups: int) -> dict:
    """Partition a small value array by its (round-invariant) group id,
    through the same canonical ``group_view`` the oracle partitions with."""
    if not len(vals):
        return {}
    _, order, bounds = group_view(vals, g, seed_groups)
    sv = vals[order]
    return {
        gi: sv[bounds[gi] : bounds[gi + 1]]
        for gi in range(g)
        if bounds[gi + 1] > bounds[gi]
    }


def pack_csr(
    rows: list, col_align: int, slack: bool = False
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Pack variable-length rows into (flat, start, cnt, cap) CSR arrays.

    Lane-pads the flat tail only: the device gather clamps past-end reads.
    (No pow2 bucket — the store shape is fixed for the whole run, so it
    costs one executor compile per cohort, not one per round; only
    round-varying dims need bucketing.)

    With ``slack`` (mutable stores, DESIGN.md §11) each row's allocated
    capacity ``cap`` exceeds its element count by ~25% plus an 8-slot
    floor, leaving a free lane that in-place delta mutations append into;
    without it ``cap == cnt`` and the layout is byte-identical to the
    one-shot path.
    """
    cnt = np.array([len(r) for r in rows], dtype=np.int32)
    vals = (
        np.concatenate(rows).astype(np.uint32)
        if rows else np.zeros(0, dtype=np.uint32)
    )
    return _csr_layout(vals, cnt, col_align, slack)


def _csr_layout(
    vals: np.ndarray, cnt: np.ndarray, col_align: int, slack: bool
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """``pack_csr`` over pre-concatenated row values (``vals`` holds every
    row's elements back to back, ``cnt`` the per-row lengths) — the whole
    layout, including the slack-lane scatter, is numpy passes with no
    per-row Python (DESIGN.md §12)."""
    cnt = np.asarray(cnt, dtype=np.int32)
    cap = _ceil_to(cnt + (cnt >> 2) + 8, 8).astype(np.int32) if slack else cnt
    start = np.zeros(len(cnt), dtype=np.int32)
    np.cumsum(cap[:-1], out=start[1:])
    total = int(cap.sum())
    flat = np.zeros(_ceil_to(max(total, 1), col_align), dtype=np.uint32)
    if slack:
        if len(vals):
            # scatter each row's values into its lane: start[row] + offset
            within = np.arange(len(vals)) - np.repeat(
                np.cumsum(cnt) - cnt, cnt
            )
            flat[np.repeat(start, cnt) + within] = vals
    else:
        # tight layout (cap == cnt): rows are contiguous, one vectorized fill
        flat[: len(vals)] = vals
    return flat, start, cnt, cap


class SessionBatch:
    """Plans per-code cohorts: one resident store, small overlays per round.

    ``sides`` selects which element stores this batch materializes: the
    in-process server batches both ("a", "b"); a wire endpoint passes only
    its own side, and the same planner then emits the same round arrays
    minus the other side's store/widths.

    ``mutable`` (continuous sync, DESIGN.md §11) packs stores with per-row
    capacity slack so ``apply_mutations`` can patch them in place between
    epochs; one-shot batches keep the exact tight layout.
    """

    # alignment floors of the packed layouts: unit rows to the sublane unit,
    # element widths to the lane unit; pow2_bucket rounds up from there.
    ROW_ALIGN = 8
    COL_ALIGN = 128
    OVERLAY_ALIGN = 8              # diff-overlay widths (removed/added cols)

    def __init__(
        self,
        sessions: list[ReconSession],
        sides: tuple = ("a", "b"),
        mutable: bool = False,
        tracer=None,
    ):
        self.sessions = sessions
        self.sides = tuple(sides)
        self.mutable = mutable
        self._stores: dict[tuple[int, int], CohortStore] = {}
        self.store_builds = 0          # cohort-store builds incl. rebuilds
        self.store_build_bytes = 0     # cumulative H2D bytes of those builds
        self.store_delta_bytes = 0     # cumulative delta-patch H2D bytes
        self.store_patches = 0         # apply_mutations calls that patched
        self.store_compactions = 0     # capacity overflows -> forced rebuilds
        # store-lifecycle timeline (DESIGN.md §14): builds span, compactions
        # mark instants; NULL_TRACER (the default) makes both free
        self.tracer = tracer if tracer is not None else NULL_TRACER

    # ---- upload-once element store -------------------------------------

    def store_upload_bytes(self) -> int:
        """One-time H2D cost of the stores built so far (0 if none yet) —
        accounting only, never forces a build."""
        return sum(s.h2d_bytes for s in self._stores.values())

    def counters(self) -> dict:
        """Snapshot of the cumulative store-ledger counters.  Diff two
        snapshots to attribute builds/compactions/delta bytes to one run —
        the shared mechanism behind ``ReconcileServer.stats`` and
        ``HubEndpoint.stats`` per-epoch ledgers (DESIGN.md §11)."""
        return {
            "store_builds": self.store_builds,
            "store_compactions": self.store_compactions,
            "store_delta_bytes": self.store_delta_bytes,
            "store_build_bytes": self.store_build_bytes,
        }

    def add_sessions(self, new: list[ReconSession]) -> None:
        """Admit sessions mid-run (hub peers joining between global rounds).

        Appends to the shared session list and invalidates the cohort
        stores of the affected code keys: those cohorts rebuild (and
        re-upload) on next live use with the union of old live and new
        members.  Untouched cohorts keep their resident stores.
        """
        keys = {s.code_key for s in new}
        self.sessions.extend(new)
        for key in keys:
            self._stores.pop(key, None)

    def store_for(self, key: tuple[int, int], live=None) -> CohortStore:
        """This code's store, built (and uploaded) on first live use only.

        Members are the sessions of this code that still have live units at
        build time, so a rebuilt batch never re-uploads elements for
        sessions that already finished; within one epoch sessions only ever
        *finish*, so every later round's live set is a subset of the rows
        built here.  A continuous-sync epoch *resurrects* finished
        sessions, so ``live`` (the sessions about to plan against the
        store) guards membership: a resident store missing any of them —
        e.g. a session whose plan migrated into this cohort between epochs
        — is discarded and rebuilt with the union.
        """
        store = self._stores.get(key)
        if store is not None and live is not None and any(
            (s.sid, 0) not in store.row_of for s in live
        ):
            self._stores.pop(key)
            store = None
        if store is None:
            members = [
                s for s in self.sessions
                if s.code_key == key and not s.failed and s.state.active_units()
            ]
            store = self._stores[key] = self._build_store(*key, members)
        return store

    def apply_mutations(self, sess: ReconSession, side: str, added, removed):
        """Patch one session's side of its resident cohort store in place.

        ``added``/``removed`` are the *net* element changes of that side's
        set (disjoint; ``removed`` ⊆ the resident elements).  Partitions
        them by the session's round-invariant groups, patches the affected
        CSR rows through ``CohortStore.apply_side_mutations`` (O(churn)
        H2D scatter bytes, ledgered in ``store_delta_bytes``), and bumps
        the store generation — ``_build_store`` is never on this path.  A
        capacity overflow discards the store instead (a **compaction**:
        the next live use rebuilds it, with fresh slack, from the session
        states — which the caller is about to refresh).  No-op when the
        cohort store isn't resident yet.
        """
        if side not in self.sides or not (len(added) or len(removed)):
            return
        store = self._stores.get(sess.code_key)
        if store is None:
            return                      # next store_for builds from state
        if (sess.sid, 0) not in store.row_of:
            # session not in the resident build (joined after it): compact
            self._stores.pop(sess.code_key)
            self.store_compactions += 1
            self.tracer.instant("store.compact", sid=sess.sid,
                                n=sess.code_key[0], t=sess.code_key[1],
                                reason="late-join")
            return
        plan = sess.plan
        updates: dict[int, tuple[list, list]] = {}
        for vals, lane in ((added, 0), (removed, 1)):
            grouped = _by_group(
                np.asarray(vals, dtype=np.uint32), plan.g, plan.seed_groups
            )
            for grp, gv in grouped.items():
                row = store.row_of[(sess.sid, grp)]
                updates.setdefault(row, ([], []))[lane].extend(int(v) for v in gv)
        try:
            self.store_delta_bytes += store.apply_side_mutations(side, updates)
            self.store_patches += 1
        except StoreCapacityError:
            self._stores.pop(sess.code_key, None)
            self.store_compactions += 1
            self.tracer.instant("store.compact", sid=sess.sid,
                                n=sess.code_key[0], t=sess.code_key[1],
                                reason="capacity")

    def _build_store(self, n: int, t: int, members: list[ReconSession]) -> CohortStore:
        with self.tracer.span("store.build", n=n, t=t, members=len(members)):
            return self._build_store_cold(n, t, members)

    def _build_store_cold(self, n: int, t: int, members: list[ReconSession]) -> CohortStore:
        # per member, per side: ONE gather puts the session's elements in
        # group-sorted slot order (the cached group view's stable argsort),
        # and the per-row counts are the view's bound diffs — the
        # group-by-group slicing of the scalar build collapses into a
        # concatenation (byte-identical rows: elems[order] is exactly the
        # per-group segments back to back)
        vals: dict[str, list[np.ndarray]] = {side: [] for side in self.sides}
        cnts: dict[str, list[np.ndarray]] = {side: [] for side in self.sides}
        row_of: dict = {}
        row_base: dict = {}
        nrows = 0
        for s in members:
            st, plan = s.state, s.plan
            row_base[s.sid] = nrows
            row_of.update(((s.sid, grp), nrows + grp) for grp in range(plan.g))
            nrows += plan.g
            for side in self.sides:
                elems, order, bounds = (
                    (st.a, st.order_a, st.bounds_a) if side == "a"
                    else (st.b, st.order_b, st.bounds_b)
                )
                vals[side].append(elems[order].astype(np.uint32))
                cnts[side].append(np.diff(bounds))

        sides: dict[str, SideStore] = {}
        for side in self.sides:
            flat, start, cnt, cap = _csr_layout(
                np.concatenate(vals[side]) if vals[side]
                else np.zeros(0, dtype=np.uint32),
                np.concatenate(cnts[side]) if cnts[side]
                else np.zeros(0, dtype=np.int64),
                self.COL_ALIGN, slack=self.mutable,
            )
            sides[side] = SideStore(
                flat=jnp.asarray(flat), start=jnp.asarray(start),
                cnt=jnp.asarray(cnt), cnt_host=cnt,
                h2d_bytes=flat.nbytes + start.nbytes + cnt.nbytes,
                start_host=start,
                flat_host=flat if self.mutable else None,
                cap_host=cap if self.mutable else None,
            )
        store = CohortStore(
            n=n, t=t, m=bch_code(n, t).m,
            row_of=row_of, sides=sides, row_base=row_base,
        )
        self.store_builds += 1
        self.store_build_bytes += store.h2d_bytes
        return store

    # ---- per-round overlay planning ------------------------------------

    def plan_round(self, rnd: int) -> list[CohortRoundPlan]:
        """All cohorts with live work in global round ``rnd`` (empty = done).

        Liveness is the shared ``core.pbs.session_live`` predicate — the
        same rule both wire endpoints apply, so their cohort plans (and
        frame schemas) line up without any membership negotiation.  Each
        session is evaluated at its *local* round ``rnd - rnd0`` (non-hub
        batches have ``rnd0 == 0`` everywhere, so local == global); failed
        (hub-evicted) sessions never plan again.
        """
        live: dict[tuple[int, int], list] = {}
        for s in self.sessions:
            if s.failed or s.suspended or rnd <= s.rnd0:
                continue  # evicted/parked, or not yet admitted at this round
            if not session_live(s.state, s.plan.cfg, rnd - s.rnd0):
                continue  # budget exhausted (reported failed) or finished
            live.setdefault(s.code_key, []).append((s, s.state.active_units()))
        return [
            self._plan_cohort(
                self.store_for(key, live=[s for s, _ in members]), members, rnd
            )
            for key, members in sorted(live.items())
        ]

    def plan_cohort(
        self, key: tuple[int, int], sessions, rnd: int
    ) -> CohortRoundPlan | None:
        """One cohort's plan for round ``rnd`` over its candidate sessions,
        or None when none of them are live — the per-cohort entry the
        pipelined server drives so cohort X's round r+1 can be planned and
        dispatched while other cohorts' round-r work is still on the device
        (DESIGN.md §12).  ``plan_cohort`` over a full code partition of the
        batch emits exactly the plans ``plan_round`` would."""
        members = [
            (s, s.state.active_units())
            for s in sessions
            if not s.failed and not s.suspended and rnd > s.rnd0
            and session_live(s.state, s.plan.cfg, rnd - s.rnd0)
        ]
        if not members:
            return None
        return self._plan_cohort(
            self.store_for(key, live=[s for s, _ in members]), members, rnd
        )

    def sessions_by_code(self) -> dict:
        """Current sessions partitioned by cohort code, in session order —
        the fixed cohort membership the pipelined server iterates."""
        by: dict[tuple[int, int], list] = {}
        for s in self.sessions:
            by.setdefault(s.code_key, []).append(s)
        return by

    def _plan_cohort(self, store: CohortStore, members, rnd: int) -> CohortRoundPlan:
        """Vectorized cohort planning (DESIGN.md §12): every per-unit array
        is built by whole-batch numpy passes — per-session hash chains via
        the seeded ``mix32`` forms, overlay grouping via one stable lexsort
        over (session, group) composite keys, row fills via repeat/arange
        scatters.  Byte-identical to the scalar reference planner
        (tests/_planner_reference.py, asserted by the differential suite)."""
        S = len(members)
        counts = np.fromiter(
            (len(active) for _, active in members), np.int64, count=S
        )
        total = int(counts.sum())
        u_pad = pow2_bucket(total, self.ROW_ALIGN)
        bases = np.zeros(S, dtype=np.int64)
        np.cumsum(counts[:-1], out=bases[1:])

        # per-session scalars, one derive_seed chain for the whole cohort
        cfg_seeds = np.fromiter(
            (s.plan.cfg.seed for s, _ in members), np.uint32, count=S
        )
        rloc = np.fromiter((rnd - s.rnd0 for s, _ in members), np.uint32, count=S)
        bin_seeds = derive_seed_seeded(
            cfg_seeds, np.full(S, 2, dtype=np.uint32), rloc
        )

        # per-unit metadata (one cheap attribute pass; everything numeric
        # downstream of it is vectorized)
        groups = np.fromiter(
            (u.group for _, active in members for u in active),
            np.int64, count=total,
        )
        filters_rows = [
            (int(base) + slot, u.filters)
            for (_, active), base in zip(members, bases)
            for slot, u in enumerate(active)
            if u.filters
        ]

        row_map = np.zeros(u_pad, dtype=np.int32)
        unit_valid = np.zeros(u_pad, dtype=np.int32)
        seeds = np.zeros(u_pad, dtype=np.uint32)
        sbase = np.fromiter(
            (store.row_base[s.sid] for s, _ in members), np.int64, count=S
        )
        row_map[:total] = np.repeat(sbase, counts) + groups
        unit_valid[:total] = 1
        seeds[:total] = np.repeat(bin_seeds, counts)

        # diff overlays: tiny per-session arrays, grouped/scattered batch-wide
        rem_parts, add_parts = [], []
        rem_per_s = np.zeros(S, dtype=np.int64)
        add_per_s = np.zeros(S, dtype=np.int64)
        for i, (s, _) in enumerate(members):
            removed, added = diff_overlay(s.state)
            rem_parts.append(removed)
            add_parts.append(added)
            rem_per_s[i] = len(removed)
            add_per_s[i] = len(added)
        g_of = np.fromiter((s.plan.g for s, _ in members), np.int64, count=S)
        gseed_of = np.fromiter(
            (s.plan.seed_groups for s, _ in members), np.uint32, count=S
        )
        gmax = int(g_of.max()) + 1
        row_key = np.repeat(np.arange(S), counts) * gmax + groups
        rem_len, rem_fill = _group_overlay(
            rem_parts, rem_per_s, g_of, gseed_of, row_key, gmax
        )
        add_len, add_fill = _group_overlay(
            add_parts, add_per_s, g_of, gseed_of, row_key, gmax
        )

        # Overlay widths: a Bob-side batch (no "a" side) can never carry a
        # diff overlay — zero width makes the executor's overlay ops vanish
        # entirely.  An Alice-side batch keeps the aligned floor even in
        # round 1 (empty overlay), so every round shares one executor shape
        # per (U, Wa, Wb, F) instead of compiling a round-1-only variant.
        if "a" in self.sides:
            r_w = pow2_bucket(int(rem_len.max(initial=0)), self.OVERLAY_ALIGN)
            x_w = pow2_bucket(int(add_len.max(initial=0)), self.OVERLAY_ALIGN)
        else:
            r_w = x_w = 0
        # zero-width when no unit carries a split filter: the executor's
        # statically-unrolled filter loop then vanishes for the common
        # no-split round instead of hashing both (U, W) sides for nothing
        max_f = max((len(f) for _, f in filters_rows), default=0)
        f_w = pow2_bucket(max_f, 1) if max_f else 0

        removed_arr = np.zeros((u_pad, r_w), dtype=np.uint32)
        removed_cnt = np.zeros(u_pad, dtype=np.int32)
        removed_cnt[:total] = rem_len
        if rem_fill is not None:
            rem_fill(removed_arr)
        added_arr = np.zeros((u_pad, x_w), dtype=np.uint32)
        added_cnt = np.zeros(u_pad, dtype=np.int32)
        added_cnt[:total] = add_len
        if add_fill is not None:
            add_fill(added_arr)
        fseeds = np.zeros((u_pad, f_w), dtype=np.uint32)
        fbins = np.zeros((u_pad, f_w), dtype=np.int32)
        fcnt = np.zeros(u_pad, dtype=np.int32)
        for row, flt in filters_rows:  # splits are rare: sparse scalar fills
            fseeds[row, : len(flt)] = [fs for fs, _ in flt]
            fbins[row, : len(flt)] = [fi for _, fi in flt]
            fcnt[row] = len(flt)

        packed = [
            (s, int(base), active, int(bin_seed))
            for (s, active), base, bin_seed in zip(members, bases, bin_seeds)
        ]

        arrays = {
            "row_map": row_map,
            "unit_valid": unit_valid,
            "seeds": seeds,
            "removed": removed_arr,
            "removed_cnt": removed_cnt,
            "added": added_arr,
            "added_cnt": added_cnt,
            "fseeds": fseeds,
            "fbins": fbins,
            "fcnt": fcnt,
        }
        live_rows = row_map[:total]

        def width(side: str) -> int:
            if side not in store.sides:
                return 0
            return pow2_bucket(
                int(store.sides[side].cnt_host[live_rows].max(initial=0)),
                self.COL_ALIGN,
            )

        plan = CohortRoundPlan(
            store=store,
            members=packed,
            units=total,
            width_a=width("a"),
            width_b=width("b"),
            arrays=arrays,
            h2d_bytes=sum(a.nbytes for a in arrays.values()),
            legacy_h2d_bytes=(
                self._legacy_round_bytes(
                    store, row_map[:total], removed_cnt[:total],
                    added_cnt[:total], fcnt[:total],
                )
                if {"a", "b"} <= set(store.sides)
                else 0
            ),
        )
        return plan

    def _legacy_round_bytes(self, store, row_map, removed_cnt, added_cnt, fcnt):
        """H2D bytes the re-pack-per-round layout (PR 1) would ship this round.

        That path re-uploaded per round, per side, a padded uint32 element
        matrix *and* an equally-sized int32 valid matrix plus per-unit seeds.
        Per-unit element counts are exact for plain units (store count minus
        removed plus added); split descendants hold ~count/3^depth of their
        parent — an estimate, but splits are rare and small.
        """
        if not len(row_map):
            return 0
        shrink = np.power(3.0, fcnt.astype(np.float64))
        na = (store.a.cnt_host[row_map] - removed_cnt + added_cnt) / shrink
        nb = store.b.cnt_host[row_map] / shrink
        u_old = max(self.ROW_ALIGN, _ceil_to(len(row_map), self.ROW_ALIGN))
        wa_old = max(self.COL_ALIGN, _ceil_to(int(na.max()), self.COL_ALIGN))
        wb_old = max(self.COL_ALIGN, _ceil_to(int(nb.max()), self.COL_ALIGN))
        # elems (4B) + valid (4B) per cell, both sides, + uint32 seeds
        return u_old * (wa_old + wb_old) * 8 + u_old * 4


# ---------------------------------------------------------------------------
# Continuous-sync epoch helpers (DESIGN.md §11)
# ---------------------------------------------------------------------------


def apply_churn(base: np.ndarray, added, removed) -> np.ndarray:
    """One side's next-epoch set: ``(base \\ removed) ∪ added``, unique and
    sorted like every other element array in the stack.  Removing an absent
    element or re-adding a present one is a no-op, matching set semantics."""
    out = np.setdiff1d(
        np.asarray(base, dtype=np.uint32), np.asarray(removed, dtype=np.uint32)
    )
    return np.unique(
        np.concatenate([out, np.asarray(added, dtype=np.uint32)])
    )


def advance_session(
    batch: SessionBatch,
    sess: ReconSession,
    plan: ProtocolPlan,
    *,
    new_a: np.ndarray | None = None,
    new_b: np.ndarray | None = None,
    rnd0: int = 0,
) -> ReconSession:
    """Move one session into its next epoch over the same resident store.

    Installs the epoch's plan and a fresh round state (units reset, diff
    empty — byte-identical to a session freshly submitted with the new
    sets), and delta-patches the batch's resident cohort store with each
    changed side's *net* element changes instead of rebuilding it.  When
    the new plan's store layout differs — (n, t), g, or the group seed
    changed, so the CSR grouping itself moved — the resident store can't be
    patched: the session's old cohort is invalidated (when the key is
    unchanged) and the next live use rebuilds, which the batch counts as a
    build, keeping the zero-rebuild assertion of the pure delta path
    honest.  ``new_a``/``new_b`` = None keeps that side's set unchanged.
    """
    old = sess.plan
    a = sess.state.a if new_a is None else np.unique(
        np.asarray(new_a, dtype=np.uint32)
    )
    b = sess.state.b if new_b is None else np.unique(
        np.asarray(new_b, dtype=np.uint32)
    )
    layout_same = (plan.n, plan.t, plan.g, plan.seed_groups) == (
        old.n, old.t, old.g, old.seed_groups
    )
    if layout_same:
        for side, new, cur in (("a", new_a, sess.state.a),
                               ("b", new_b, sess.state.b)):
            if new is None:
                continue
            arr = a if side == "a" else b
            batch.apply_mutations(
                sess, side, np.setdiff1d(arr, cur), np.setdiff1d(cur, arr)
            )
    else:
        # the row layout moved: the session's resident rows are stale in
        # BOTH cohorts it touches.  Drop the old key (its rows hold the
        # previous epoch's elements — a later migration back would
        # otherwise pass store_for's membership guard and reconcile over
        # them) and the new key (a resident target store has no rows for
        # this session, or stale ones from an earlier stint); both rebuild
        # on next live use from the refreshed states, as counted builds.
        batch._stores.pop((old.n, old.t), None)
        batch._stores.pop((plan.n, plan.t), None)
    sess.plan = plan
    sess.state = new_session_state(a, b, plan)
    sess.rnd0 = rnd0
    return sess


# ---------------------------------------------------------------------------
# Graceful degradation on decode exhaustion (DESIGN.md §13)
# ---------------------------------------------------------------------------


def escalate_session(
    batch: SessionBatch, sess: ReconSession, *, rnd0: int
) -> ReconSession:
    """Climb one degradation-ladder rung: install ``escalated_plan`` (d̂
    doubled again, groups reseeded) with a fresh round state over the
    session's current sets, restarting its local protocol at global round
    ``rnd0 + 1``.  The reshuffled group seed always moves the store
    layout, so — exactly like an epoch-advance layout change — both
    affected cohort keys are invalidated and rebuild on next live use as
    counted builds.  Settled progress carries over: the recovered diff
    (Alice-side; Bob's mirror never holds one) and the accumulated byte
    ledger and counters transfer into the fresh state, so elements already
    recovered are never re-transmitted — any new group whose differences
    were all settled has equal effective sets, a zero difference sketch,
    and settles in round 1 with an empty position payload.  Both endpoints
    stay byte-identical with no negotiation: the carried diff only shapes
    Alice's effective set, which Bob observes through the sketches exactly
    like any other round.  (Regression-tested: no settled unit's bits are
    ledgered twice across an escalation.)
    """
    level = sess.escalations + 1
    plan = escalated_plan(sess.plan, level)
    old_plan, old_state = sess.plan, sess.state
    batch._stores.pop((old_plan.n, old_plan.t), None)
    batch._stores.pop((plan.n, plan.t), None)
    sess.plan = plan
    sess.state = new_session_state(old_state.a, old_state.b, plan)
    sess.state.diff = old_state.diff
    sess.state.bytes_per_round = old_state.bytes_per_round
    sess.state.decode_failures = old_state.decode_failures
    sess.state.fake_rejections = old_state.fake_rejections
    sess.rnd0 = rnd0
    sess.escalations = level
    return sess


def degrade_exhausted(
    batch: SessionBatch, rnd: int, *, max_escalations: int = MAX_ESCALATIONS
) -> list[ReconSession]:
    """Escalate every session whose round budget just ran out with groups
    still undone, instead of letting it report failure (DESIGN.md §13).

    Called after global round ``rnd``'s outcomes are applied; a session is
    exhausted when its *next* local round would exceed ``cfg.max_rounds``
    while units remain undone.  Both endpoints evaluate this at the same
    global round with identical state, so they derive identical rungs with
    zero coordination traffic.  Suspended (resumable) sessions are skipped
    — their local clock is parked, not running out.  A session that has
    already climbed ``max_escalations`` rungs is left alone and fails
    exactly as it would have before degradation existed.
    """
    out: list[ReconSession] = []
    for s in batch.sessions:
        if s.failed or s.suspended or rnd <= s.rnd0:
            continue
        if s.escalations >= max_escalations:
            continue
        if rnd + 1 - s.rnd0 <= s.plan.cfg.max_rounds:
            continue                    # round budget not exhausted yet
        if not s.state.active_units():
            continue                    # finished cleanly
        out.append(escalate_session(batch, s, rnd0=rnd))
    return out
