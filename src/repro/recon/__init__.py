"""Batched multi-session set reconciliation on the accelerator path.

The single-session protocol in ``repro.core.pbs`` is the numpy oracle; this
package turns it into a traffic-serving system (DESIGN.md §5): a
``SessionBatch`` planner uploads each cohort's element store to the device
once and emits only small gather/overlay arrays per round, a fused jitted
``execute_round`` rebuilds unit rows on device and runs both sides'
bin/sketch/decode in one call, and ``ReconcileServer`` dispatches all
cohorts asynchronously while keeping per-session byte ledgers identical to
``core.pbs.reconcile``.

``ReconcileServer(continuous=True)`` extends the same machinery to
continuous epoch reconciliation (DESIGN.md §11): ``advance_epoch`` folds
learned diffs and local churn into delta-mutable stores patched in place,
so a long-lived session pays O(churn) H2D per epoch instead of a rebuild.
"""
from .engine import (
    encode_side,
    encode_side_ext,
    execute_round,
    execute_round_ext,
)
from .server import ReconcileServer, phase0_numerators, reconcile_batch
from .session import (
    CohortRoundPlan,
    CohortStore,
    ReconSession,
    SessionBatch,
    SideStore,
    StoreCapacityError,
    advance_session,
    apply_churn,
    degrade_exhausted,
    escalate_session,
)

__all__ = [
    "CohortRoundPlan",
    "CohortStore",
    "ReconSession",
    "ReconcileServer",
    "SessionBatch",
    "SideStore",
    "StoreCapacityError",
    "advance_session",
    "apply_churn",
    "degrade_exhausted",
    "escalate_session",
    "encode_side",
    "encode_side_ext",
    "execute_round",
    "execute_round_ext",
    "phase0_numerators",
    "reconcile_batch",
]
