"""Batched multi-session set reconciliation on the accelerator path.

The single-session protocol in ``repro.core.pbs`` is the numpy oracle; this
package turns it into a traffic-serving system (DESIGN.md §5): a
``SessionBatch`` planner packs the active units of S concurrent Alice↔Bob
sessions into padded per-code cohorts, a jitted ``execute_round`` runs each
round's bin/sketch/decode for every unit at once through the Pallas kernels,
and ``ReconcileServer`` keeps per-session byte ledgers identical to
``core.pbs.reconcile``.
"""
from .engine import execute_round
from .server import ReconcileServer, reconcile_batch
from .session import CohortRound, ReconSession, SessionBatch

__all__ = [
    "CohortRound",
    "ReconSession",
    "ReconcileServer",
    "SessionBatch",
    "execute_round",
    "reconcile_batch",
]
