"""ReconcileServer: the traffic-serving facade over the batched engine.

``submit`` any number of Alice↔Bob pairs, then ``run`` drives every session's
full PBS protocol concurrently.  Estimator sessions (unknown d) defer phase 0
to ``run``, which batches every pending ToW estimate through the Pallas
``tow_sketch`` kernel in one async-dispatched sweep (bit-identical to the
host mirror — same hash family).  Before round 1, each cohort's element store
uploads to the device once; each global round the SessionBatch planner emits
only small gather/overlay arrays, **all cohorts dispatch before the first
device_get** (JAX async dispatch overlaps their device work), and the host
applies the per-unit outcomes — recovery, fake rejection, checksum gating,
and the 3-way-split re-queue — through the *same* ``core.pbs`` state-machine
functions as the single-session oracle.  Decoded bin positions come back as
one vectorized unpack per cohort (no per-unit Python slicing).

Byte accounting is per session and identical to ``core.pbs.ReconcileResult``:
the sketch/flag upload counts each session's own active units, and the
Bob→Alice reply bits come from the shared ``apply_round_outcomes``, so
``run()[sid].bytes_sent`` equals what ``core.pbs.reconcile`` reports for the
same pair, seed for seed (asserted in tests/test_recon_batch.py).

``stats`` (after ``run``) reports the transfer/launch ledger the device-
resident pipeline is optimizing: actual H2D bytes (store once + overlays per
round) against the legacy re-pack-per-round equivalent, kernel launches per
round (fused two-side encode = 2 vs 4), and the host-ms vs device-ms split.
"""
from __future__ import annotations

import time
from collections import deque

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.hashing import derive_seed
from repro.core.pbs import (
    MAX_ESCALATIONS,
    MAX_PARITY_EXTENSIONS,
    PBSConfig,
    ReconcileResult,
    apply_round_outcomes,
    effective_set,
    finalize_result,
    new_session_state,
    parity_extension_t,
    plan_from_d_known,
    plan_from_estimate,
)
from repro.core.tow import (
    ESTIMATE_LIMIT_FRAC,
    EstimateOutOfRange,
    check_estimate,
    planned_d,
    tow_seeds,
)
from repro.kernels.platform import (
    enable_persistent_cache,
    pow2_bucket,
    retrace_count,
    retrace_counts,
)
from repro.kernels.tow_sketch import tow_sketch
from repro.obs import NULL_TRACER, Recorder

from repro.kernels.ops import bch_decode_batched

from .engine import execute_round, execute_round_ext
from .session import (
    CohortRoundPlan,
    ReconSession,
    SessionBatch,
    advance_session,
    apply_churn,
    escalate_session,
)

_EMPTY = np.zeros(0, dtype=np.uint32)


_TOW_TILE = 2048  # tow_sketch's tile: also the phase-0 shape-bucket floor


def _tow_bucketed(elems, seeds_j, interpret):
    """One set's ToW sketch dispatch at a warm jit signature (DESIGN.md §12).

    Pads the set to ``pow2_bucket(|S|, tile)`` with an explicit 0/1 valid
    mask, so the kernel's trace signature depends on the shape *bucket*
    instead of the exact set size — phase 0 stops retracing per distinct
    set size and the padding lanes contribute nothing to the sums.
    """
    e = np.asarray(elems, dtype=np.uint32)
    ep = pow2_bucket(len(e), _TOW_TILE)
    buf = np.zeros(ep, dtype=np.uint32)
    buf[: len(e)] = e
    valid = np.zeros(ep, dtype=np.int32)
    valid[: len(e)] = 1
    return tow_sketch(
        jnp.asarray(buf), seeds_j, jnp.asarray(valid),
        ell=seeds_j.shape[0], interpret=interpret,
    )


def phase0_dispatch(pairs, seeds_list, *, interpret: bool | None = None) -> list:
    """Enqueue every (A, B) pair's ToW sketch kernels; returns the in-flight
    device futures.  Split from the readback so callers can overlap host
    work — epoch staging, known-d session advances — with the device sweep
    (the cross-epoch half of the DESIGN.md §12 overlap pipeline)."""
    inflight = []
    for (a, b), seeds in zip(pairs, seeds_list):
        sj = jnp.asarray(seeds)
        inflight.append(
            (
                _tow_bucketed(a, sj, interpret),
                _tow_bucketed(b, sj, interpret),
            )
        )
    return inflight


def phase0_collect(inflight) -> list[int]:
    """Block on the in-flight sketches and reduce the exact integer
    numerators sum((Y_A - Y_B)^2) on the host."""
    out = []
    for ya, yb in inflight:
        diff = np.asarray(jax.device_get(ya)).astype(np.int64) - np.asarray(
            jax.device_get(yb)
        ).astype(np.int64)
        out.append(int(np.sum(diff * diff)))
    return out


def phase0_numerators(
    pairs, seeds_list, *, interpret: bool | None = None
) -> list[int]:
    """Batched phase-0 d_hat numerators through the ToW Pallas kernel.

    Dispatches every (A, B) pair's sketch kernels before the first readback
    (JAX async dispatch overlaps the device work), then reduces the exact
    integer numerator sum((Y_A - Y_B)^2) on the host.  Bit-identical to
    ``core.tow.tow_sketches`` + ``estimate_numerator`` — same hash family,
    and the shape-bucket padding is masked out — so routing estimation
    through the device changes nothing downstream.
    """
    return phase0_collect(phase0_dispatch(pairs, seeds_list, interpret=interpret))


class ReconcileServer:
    """Batched multi-session PBS reconciliation (DESIGN.md §5).

    ``interpret`` follows the kernel convention: None = derive from backend
    (interpreter off-TPU, compiled on TPU).
    """

    def __init__(
        self,
        *,
        interpret: bool | None = None,
        continuous: bool = False,
        degrade: bool = False,
        recorder: Recorder | None = None,
        tracer=None,
        estimate_limit: float | None = ESTIMATE_LIMIT_FRAC,
    ):
        enable_persistent_cache()
        self._interpret = interpret
        self._continuous = continuous
        # estimator sessions whose planned d̂ exceeds this fraction of the
        # pair's total elements raise EstimateOutOfRange instead of burning
        # the round budget (None disables; d_known sessions never raise) —
        # such pairs belong to the tree front end (repro.tree, §15)
        self._estimate_limit = estimate_limit
        # degrade=True: a session that exhausts its round budget with work
        # left re-plans at a doubled d̂ (graceful degradation, DESIGN.md §13)
        # instead of finishing with success=False; counted per escalation
        # in stats["sessions_degraded"].
        self._degrade = degrade
        self._sessions: list[ReconSession | None] = []
        self._pending: dict[int, tuple] = {}   # sid -> (a, b, cfg), d unknown
        self._d_known: dict[int, int | None] = {}
        self._batch: SessionBatch | None = None
        self._stats: dict = {}
        self._phase0_s = 0.0                   # accrued until the next run()
        self._epoch = 0
        # telemetry (DESIGN.md §14): all run ledgers publish into the
        # recorder (the `stats` view derives from it) and every phase
        # boundary is spanned through the tracer (NULL_TRACER = disabled).
        self.recorder = recorder if recorder is not None else Recorder()
        self.tracer = tracer if tracer is not None else NULL_TRACER

    def submit(
        self,
        set_a: np.ndarray,
        set_b: np.ndarray,
        cfg: PBSConfig | None = None,
        d_known: int | None = None,
    ) -> int:
        """Enqueue one session (Alice holds ``set_a``); returns its sid.

        Known-d sessions pin their (n, t, g) immediately; estimator
        sessions defer phase 0 so ``run`` can batch every pending ToW
        sketch through the Pallas kernel in one async-dispatched sweep
        instead of a per-session host loop over ell hash functions.
        """
        cfg = cfg or PBSConfig()
        a = np.unique(np.asarray(set_a, dtype=np.uint32))
        b = np.unique(np.asarray(set_b, dtype=np.uint32))
        sid = len(self._sessions)
        if d_known is not None:
            plan = plan_from_d_known(cfg, d_known)
            self._sessions.append(
                ReconSession(sid=sid, plan=plan, state=new_session_state(a, b, plan))
            )
        else:
            self._sessions.append(None)        # placeholder until phase 0
            self._pending[sid] = (a, b, cfg)
        self._d_known[sid] = d_known
        self._batch = None  # new member: cohort stores must be rebuilt
        # the discarded batch's counters die with it: drop the recorder's
        # store mark so the next run's per-epoch ledger diffs against the
        # new batch's zeros, not a dead batch's cumulative counters
        self.recorder.drop_mark("store")
        return sid

    def _flush_phase0(self) -> None:
        """Run deferred phase 0 for every estimator session (device-batched).

        Wall time accrues into the ``phase0_s`` stat of the *next* ``run``,
        so reading ``sessions`` early never drops the cost from the ledger.
        """
        if not self._pending:
            return
        t0 = time.perf_counter()
        items = sorted(self._pending.items())
        with self.tracer.span("server.phase0", sessions=len(items)):
            pairs = [(a, b) for _, (a, b, _) in items]
            seeds_list = [
                tow_seeds(derive_seed(cfg.seed, 0x70), cfg.ell)
                for _, (_, _, cfg) in items
            ]
            nums = phase0_numerators(pairs, seeds_list, interpret=self._interpret)
            for (sid, (a, b, cfg)), num in zip(items, nums):
                plan = plan_from_estimate(cfg, num, len(a))
                check_estimate(
                    planned_d(plan.d_est, cfg.gamma),
                    len(a) + len(b), self._estimate_limit, sid=sid,
                )
                self._sessions[sid] = ReconSession(
                    sid=sid, plan=plan, state=new_session_state(a, b, plan)
                )
            self._pending.clear()
        self._phase0_s += time.perf_counter() - t0

    @property
    def sessions(self) -> list[ReconSession]:
        self._flush_phase0()
        return self._sessions

    @property
    def stats(self) -> dict:
        """Transfer/launch/time ledger of the last ``run`` (DESIGN.md §5).

        A derived snapshot of the ``server.*`` metrics in the recorder —
        same keys and values as the pre-obs ad-hoc dict (DESIGN.md §14).
        """
        return self.recorder.view("server")

    def run(self) -> dict[int, ReconcileResult]:
        """Drive every submitted session to completion; sid -> result.

        The SessionBatch (and its device-resident stores) is kept across
        ``run`` calls: a second ``run`` with no new sessions re-uploads
        nothing, and stores only build when a cohort has live work.

        The round loop is a per-cohort software pipeline (DESIGN.md §12):
        each cohort's round r+1 depends only on its *own* round-r outcomes
        (cohort membership is fixed for the run and all round state is
        session-local), so as soon as cohort X's outcomes are applied, its
        next round is planned and dispatched — while the other cohorts'
        rounds are still executing on the device.  Host planning of round
        r+1 thus overlaps device execution of round r, extending the
        dispatch-before-``device_get`` pattern across rounds.
        """
        t_run = time.perf_counter()
        retrace_mark = retrace_count()
        self._flush_phase0()
        phase0_s, self._phase0_s = self._phase0_s, 0.0
        if self._batch is None:
            self._batch = SessionBatch(
                self._sessions, mutable=self._continuous, tracer=self.tracer
            )
        batch = self._batch
        prior_store_bytes = batch.store_upload_bytes()
        st = {
            "epoch": self._epoch,
            "phase0_s": phase0_s,
            "rounds": 0,
            "cohort_rounds": 0,
            "h2d_round_bytes": 0,
            "legacy_h2d_round_bytes": 0,
            "kernel_launches": 0,
            "legacy_kernel_launches": 0,
            "sessions_degraded": 0,
            "parity_extensions": 0,
            "device_s": 0.0,
        }
        by_code = batch.sessions_by_code()
        tracer = self.tracer
        while True:
            # prime the pipeline: every cohort's round 1, dispatched before
            # the first readback (JAX async dispatch overlaps device work)
            inflight: deque = deque()
            for key in sorted(by_code):
                with tracer.span("cohort.plan_dispatch", n=key[0], t=key[1], round=1):
                    plan = batch.plan_cohort(key, by_code[key], 1)
                    if plan is not None:
                        inflight.append((key, 1, plan, self._dispatch(plan)))
            while inflight:
                key, rnd, plan, fut = inflight.popleft()
                t0 = time.perf_counter()
                with tracer.span("cohort.collect", cat="device",
                                 n=key[0], t=key[1], round=rnd):
                    out = jax.device_get(fut)
                st["device_s"] += time.perf_counter() - t0
                with tracer.span("cohort.apply", n=key[0], t=key[1], round=rnd,
                                 units=len(plan.arrays["row_map"])):
                    ext = self._apply_cohort(plan, out, rnd)
                st["rounds"] = max(st["rounds"], rnd)
                st["cohort_rounds"] += 1
                st["h2d_round_bytes"] += plan.h2d_bytes
                st["legacy_h2d_round_bytes"] += plan.legacy_h2d_bytes
                st["kernel_launches"] += 2   # fused bin launch + sketch matmul
                st["kernel_launches"] += ext["kernel_launches"]
                st["parity_extensions"] += ext["parity_extensions"]
                st["legacy_kernel_launches"] += 4  # 2x bin + 2x sketch, per side
                with tracer.span("cohort.plan_dispatch", n=key[0], t=key[1],
                                 round=rnd + 1):
                    nxt = batch.plan_cohort(key, by_code[key], rnd + 1)
                    if nxt is not None:
                        inflight.append((key, rnd + 1, nxt, self._dispatch(nxt)))
            if not self._degrade:
                break
            # graceful degradation (DESIGN.md §13): any session that drained
            # its round budget with units left re-plans at a doubled d̂ and
            # re-enters the pipeline under its new code key; escalation is
            # capped, so a hopeless session still converges to failed=True
            escalated = self._escalate_exhausted()
            if not escalated:
                break
            for s in escalated:
                tracer.instant("server.degrade", sid=s.sid,
                               escalations=s.escalations)
            st["sessions_degraded"] += len(escalated)
            by_code = batch.sessions_by_code()

        # stores built during *this* run (cached ones re-upload nothing);
        # the delta ledger additionally covers the advance_epoch patches
        # applied since the previous run — the epoch they paid for is this
        # one, so zero-rebuild epochs show store_builds == 0 and only their
        # O(churn) scatter bytes (DESIGN.md §11)
        st["h2d_store_bytes"] = batch.store_upload_bytes() - prior_store_bytes
        counters = batch.counters()
        delta = self.recorder.delta_since_mark("store", counters)
        st["store_builds"] = delta["store_builds"]
        st["store_compactions"] = delta["store_compactions"]
        st["h2d_delta_bytes"] = delta["store_delta_bytes"]
        self.recorder.mark("store", counters)
        st["h2d_bytes"] = (
            st["h2d_store_bytes"] + st["h2d_round_bytes"] + st["h2d_delta_bytes"]
        )
        st["legacy_h2d_bytes"] = st["legacy_h2d_round_bytes"]
        rounds = max(1, st["rounds"])
        st["h2d_bytes_per_round"] = st["h2d_bytes"] / rounds
        st["legacy_h2d_bytes_per_round"] = st["legacy_h2d_bytes"] / rounds
        st["h2d_ratio"] = st["legacy_h2d_bytes"] / max(1, st["h2d_bytes"])
        st["total_s"] = time.perf_counter() - t_run
        st["host_s"] = st["total_s"] - st["device_s"]
        # jit traces attributed to this run: 0 once the shape buckets are
        # warm — the assertable warm-cache contract (DESIGN.md §12)
        st["retraces"] = retrace_count() - retrace_mark
        if st["rounds"] or not self._stats:
            # an idempotent re-run that did no work keeps the meaningful
            # ledger of the run that actually drove rounds
            self._stats = st
            # the freeze point is the publish point: the legacy `stats`
            # view derives back from these registry rows (DESIGN.md §14)
            self.recorder.publish("server", st)
            self.recorder.publish("store", counters)
            self.recorder.set("kernels.retraces_total", retrace_count())
            self.recorder.set("kernels.retraces_by_fn", retrace_counts())
        results = {s.sid: finalize_result(s.state, s.plan) for s in self._sessions}
        if tracer.enabled:
            # per-session attribution for trace_report: bytes/diff/rounds
            # against the plan's (n, t, d_est) for the Markov comparison
            for sid, r in results.items():
                p = self._sessions[sid].plan
                tracer.instant(
                    "session.result", sid=sid, rounds=r.rounds,
                    diff=len(r.diff), bytes=r.bytes_sent, success=r.success,
                    n=p.n, t=p.t, g=p.g, d_est=p.d_est,
                )
        return results

    def advance_epoch(
        self,
        mutations: dict | None = None,
        *,
        d_known: dict | None = None,
        fold_diff: bool = True,
    ) -> int:
        """Open the next reconciliation epoch over the same resident stores
        (continuous sync, DESIGN.md §11); returns the new epoch number.

        Per session: Alice folds the learned diff into her set (replica
        convergence, A ← A △ D̂; ``fold_diff=False`` keeps A), then both
        sides apply the caller's local churn from ``mutations`` —
        sid -> (added_a, removed_a, added_b, removed_b).  Sessions whose d
        is pinned re-plan with that d; estimator sessions re-run phase 0
        through the same batched ToW kernel sweep submit-time estimation
        uses.  ``d_known`` (sid -> int | None) *rebinds* a session's
        convention from this epoch on — an int pins d for this and later
        epochs, ``None`` returns it to estimation; unmentioned sessions
        keep their current convention (initially the submit-time one).
        Each changed side's *net* element delta is patched into the
        device-resident cohort stores in place — the next ``run`` drives
        the epoch with zero store rebuilds (``stats["store_builds"]``) and
        only O(churn) delta-H2D bytes (``stats["h2d_delta_bytes"]``).

        Requires ``ReconcileServer(continuous=True)`` — one-shot batches
        pack their stores without the mutation lanes the delta path
        patches into.
        """
        if not self._continuous:
            raise RuntimeError(
                "advance_epoch needs ReconcileServer(continuous=True)"
            )
        self._flush_phase0()
        if self._batch is None:
            self._batch = SessionBatch(
                self._sessions, mutable=True, tracer=self.tracer
            )
        muts = mutations or {}
        dk_over = d_known or {}
        unknown = (set(muts) | set(dk_over)) - set(range(len(self._sessions)))
        if unknown:
            # a typo'd sid must not silently drop the caller's churn
            raise KeyError(f"unknown sid(s) {sorted(unknown)} in epoch advance")
        self._epoch += 1
        self.tracer.instant("server.epoch_advance", epoch=self._epoch,
                            mutated=len(muts))

        new_sets: dict[int, tuple] = {}
        for s in self._sessions:
            st = s.state
            base_a = effective_set(st.a, st.diff) if fold_diff else st.a
            aa, ra, ab, rb = muts.get(s.sid, (_EMPTY,) * 4)
            new_sets[s.sid] = (
                apply_churn(base_a, aa, ra), apply_churn(st.b, ab, rb)
            )

        if dk_over:
            self._d_known.update(dk_over)
        est = [s for s in self._sessions if self._d_known[s.sid] is None]
        plans = {
            s.sid: plan_from_d_known(s.plan.cfg, self._d_known[s.sid])
            for s in self._sessions
            if self._d_known[s.sid] is not None
        }
        # cross-epoch overlap (DESIGN.md §12): dispatch the estimator ToW
        # sweep first, advance every pinned session while those kernels run
        # on the device, then collect the numerators and advance the rest.
        inflight = None
        if est:
            t0 = time.perf_counter()
            inflight = phase0_dispatch(
                [new_sets[s.sid] for s in est],
                [
                    tow_seeds(derive_seed(s.plan.cfg.seed, 0x70), s.plan.cfg.ell)
                    for s in est
                ],
                interpret=self._interpret,
            )
            self._phase0_s += time.perf_counter() - t0

        est_sids = {s.sid for s in est}
        for s in self._sessions:
            if s.sid in est_sids:
                continue
            new_a, new_b = new_sets[s.sid]
            advance_session(
                self._batch, s, plans[s.sid], new_a=new_a, new_b=new_b, rnd0=0
            )

        if est:
            t0 = time.perf_counter()
            nums = phase0_collect(inflight)
            for s, num in zip(est, nums):
                plans[s.sid] = plan_from_estimate(
                    s.plan.cfg, num, len(new_sets[s.sid][0])
                )
                check_estimate(
                    planned_d(plans[s.sid].d_est, s.plan.cfg.gamma),
                    len(new_sets[s.sid][0]) + len(new_sets[s.sid][1]),
                    self._estimate_limit, sid=s.sid,
                )
            self._phase0_s += time.perf_counter() - t0
            for s in est:
                new_a, new_b = new_sets[s.sid]
                advance_session(
                    self._batch, s, plans[s.sid], new_a=new_a, new_b=new_b, rnd0=0
                )
        return self._epoch

    def _escalate_exhausted(
        self, max_escalations: int = MAX_ESCALATIONS
    ) -> list[ReconSession]:
        """Escalate every budget-exhausted session one degradation rung
        (doubled d̂ re-plan from scratch, ``escalate_session``); returns the
        escalated sessions.  Exhausted means the round budget is spent with
        active units left — the state ``finalize_result`` would report as
        ``success=False``."""
        out = []
        for s in self._sessions:
            if s is None or s.failed or s.suspended:
                continue
            if s.escalations >= max_escalations:
                continue
            if s.state.rounds < s.plan.cfg.max_rounds:
                continue
            if not s.state.active_units():
                continue
            out.append(escalate_session(self._batch, s, rnd0=0))
        return out

    def _dispatch(self, plan: CohortRoundPlan):
        """Enqueue one cohort's fused round executor; returns device futures."""
        store = plan.store
        return execute_round(
            store.a.flat,
            store.a.start,
            store.a.cnt,
            store.b.flat,
            store.b.start,
            store.b.cnt,
            *(jnp.asarray(plan.arrays[k]) for k in (
                "row_map", "unit_valid", "seeds", "removed", "removed_cnt",
                "added", "added_cnt", "fseeds", "fbins", "fcnt",
            )),
            n=store.n,
            t=store.t,
            width_a=plan.width_a,
            width_b=plan.width_b,
            interpret=self._interpret,
        )

    def _apply_cohort(self, plan: CohortRoundPlan, out, rnd: int) -> dict:
        xors_a, xors_b, ok, pos, cnt, csum_a, csum_b, sk_diff = out
        # one vectorized unpack of the (U, t) padded position rows: valid
        # entries are left-justified, so a masked flatten + split by the
        # per-unit counts yields every unit's decoded bins at once.
        cnt = np.asarray(cnt, dtype=np.int64)
        pos = np.asarray(pos)
        positions = list(
            np.split(pos[pos >= 0].astype(np.int64), np.cumsum(cnt)[:-1])
        )
        ok = np.asarray(ok).copy()
        ext = {"parity_extensions": 0, "kernel_launches": 0}
        ext_bits = self._extend_cohort(plan, ok, positions, sk_diff, ext)

        sketch_bits = plan.store.t * plan.store.m + 1  # per-unit sketch + ok flag
        for idx, (sess, base, active, bin_seed) in enumerate(plan.members):
            k = len(active)
            rows = slice(base, base + k)
            reply_bits, _ = apply_round_outcomes(
                sess.state,
                active,
                ok[rows],
                positions[rows],
                xors_a[rows],
                xors_b[rows],
                csum_a[rows],
                csum_b[rows],
                plan=sess.plan,
                bin_seed=bin_seed,
                rnd=rnd,
            )
            round_bits = k * sketch_bits + reply_bits + ext_bits.get(idx, 0)
            sess.state.bytes_per_round.append((round_bits + 7) // 8)
            sess.state.rounds = rnd
        return ext

    def _extend_cohort(
        self, plan: CohortRoundPlan, ok, positions, sk_diff, ext
    ) -> dict[int, int]:
        """Rateless recovery ladder for one cohort round (DESIGN.md §16).

        Instead of surrendering a failed BCH decode to the 3-way split (or,
        round budget permitting none, to a from-scratch degradation re-plan),
        every failing unit of a ``rateless`` session re-decodes the *same*
        round bitmap at t' = t·2^level: ``execute_round_ext`` emits only the
        incremental syndromes S_{2t+1}..S_{2t'-1}, the host concatenates
        them onto the cached round-diff prefix, and one batched decode at t'
        recovers everything the wider code can reach — zero re-sent sketch
        bits, zero store rebuilds.  ``ok``/``positions`` are merged in place
        so the single ``apply_round_outcomes`` call downstream sees the
        post-ladder outcome (split seeds therefore still derive from this
        round, deterministically on both wire sides).  Returns per-member
        Formula-(1) ledger bits: sum over levels of U_e·(Δt_e·m + 1) —
        exactly what the ``MSG_PARITY`` frame plus its extension reply
        measure on the wire path (repro.net).
        """
        ext_bits: dict[int, int] = {}
        rateless = np.zeros(len(ok), dtype=bool)
        for sess, base, active, _ in plan.members:
            if sess.plan.cfg.rateless:
                rateless[base : base + len(active)] = True
        fail = rateless & ~ok
        if not fail.any():
            return ext_bits
        store = plan.store
        n, t, m = store.n, store.t, store.m
        arrays = tuple(
            jnp.asarray(plan.arrays[k]) for k in (
                "row_map", "unit_valid", "seeds", "removed", "removed_cnt",
                "added", "added_cnt", "fseeds", "fbins", "fcnt",
            )
        )
        acc = np.asarray(sk_diff)
        t_prev = t
        for level in range(1, MAX_PARITY_EXTENSIONS + 1):
            t_e = parity_extension_t(t, level, n)
            if t_e <= t_prev:
                break  # code cap (n-1)//2 reached: the ladder is exhausted
            inc = execute_round_ext(
                store.a.flat, store.a.start, store.a.cnt,
                store.b.flat, store.b.start, store.b.cnt,
                *arrays,
                n=n, t0=t_prev, t1=t_e,
                width_a=plan.width_a, width_b=plan.width_b,
                interpret=self._interpret,
            )
            ext["kernel_launches"] += 2  # bin rebuild + incremental matmul
            acc = np.concatenate([acc, np.asarray(jax.device_get(inc))], axis=1)
            # only failing rateless rows carry content: settled/foreign rows
            # decode trivially as zero sketches and are never touched
            masked = np.where(fail[:, None], acc, 0)
            ok_e, pos_e, _ = jax.device_get(
                bch_decode_batched(jnp.asarray(masked), n=n, t=t_e)
            )
            ok_e, pos_e = np.asarray(ok_e), np.asarray(pos_e)
            dt = t_e - t_prev
            for idx, (sess, base, active, _) in enumerate(plan.members):
                u_e = int(fail[base : base + len(active)].sum())
                if u_e:
                    ext_bits[idx] = ext_bits.get(idx, 0) + u_e * (dt * m + 1)
                    ext["parity_extensions"] += 1
                    self.tracer.instant(
                        "server.parity_extension", sid=sess.sid,
                        level=level, units=u_e, t=t_e,
                    )
            recovered = np.flatnonzero(fail & ok_e)
            for row in recovered:
                ok[row] = True
                r = pos_e[row]
                positions[row] = r[r >= 0].astype(np.int64)
            fail &= ~ok_e
            t_prev = t_e
            if not fail.any():
                break
        return ext_bits


def reconcile_batch(
    pairs,
    cfgs=None,
    d_knowns=None,
    *,
    interpret: bool | None = None,
) -> list[ReconcileResult]:
    """One-shot convenience: reconcile a list of (set_a, set_b) pairs.

    ``cfgs``/``d_knowns`` may be None, a single value applied to every pair,
    or a per-pair sequence.  Results come back in submission order.
    """
    npairs = len(pairs)

    def _broadcast(x, name):
        # scalars (None, a PBSConfig, an int d) broadcast; any sized
        # non-string container is per-pair and must match the pair count
        if x is None or isinstance(x, str) or not hasattr(x, "__len__"):
            return [x] * npairs
        if len(x) != npairs:
            raise ValueError(f"{name} has {len(x)} entries for {npairs} pairs")
        return list(x)

    server = ReconcileServer(interpret=interpret)
    for (a, b), cfg, dk in zip(
        pairs, _broadcast(cfgs, "cfgs"), _broadcast(d_knowns, "d_knowns")
    ):
        server.submit(a, b, cfg=cfg, d_known=dk)
    results = server.run()
    return [results[i] for i in range(npairs)]
