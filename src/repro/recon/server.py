"""ReconcileServer: the traffic-serving facade over the batched engine.

``submit`` any number of Alice↔Bob pairs, then ``run`` drives every session's
full PBS protocol concurrently: each global round, the SessionBatch planner
packs all live units into per-code cohorts, the jitted executor runs the
round's encode→sketch→decode on the accelerator path, and the host applies
the per-unit outcomes — recovery, fake rejection, checksum gating, and the
3-way-split re-queue — through the *same* ``core.pbs`` state-machine
functions as the single-session oracle.

Byte accounting is per session and identical to ``core.pbs.ReconcileResult``:
the sketch/flag upload counts each session's own active units, and the
Bob→Alice reply bits come from the shared ``apply_round_outcomes``, so
``run()[sid].bytes_sent`` equals what ``core.pbs.reconcile`` reports for the
same pair, seed for seed (asserted in tests/test_recon_batch.py).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.pbs import (
    PBSConfig,
    ReconcileResult,
    apply_round_outcomes,
    finalize_result,
    new_session_state,
    plan_protocol,
)

from .engine import execute_round
from .session import CohortRound, ReconSession, SessionBatch


class ReconcileServer:
    """Batched multi-session PBS reconciliation (DESIGN.md §5).

    ``interpret`` follows the kernel convention: None = derive from backend
    (interpreter off-TPU, compiled on TPU).
    """

    def __init__(self, *, interpret: bool | None = None):
        self._interpret = interpret
        self._sessions: list[ReconSession] = []

    def submit(
        self,
        set_a: np.ndarray,
        set_b: np.ndarray,
        cfg: PBSConfig | None = None,
        d_known: int | None = None,
    ) -> int:
        """Enqueue one session (Alice holds ``set_a``); returns its sid.

        Phase 0 (ToW estimate + parameter optimization) runs at submit time,
        so cohort membership is known before the first round.
        """
        cfg = cfg or PBSConfig()
        a = np.unique(np.asarray(set_a, dtype=np.uint32))
        b = np.unique(np.asarray(set_b, dtype=np.uint32))
        plan = plan_protocol(a, b, cfg, d_known)
        sid = len(self._sessions)
        self._sessions.append(
            ReconSession(sid=sid, plan=plan, state=new_session_state(a, b, plan))
        )
        return sid

    @property
    def sessions(self) -> list[ReconSession]:
        return self._sessions

    def run(self) -> dict[int, ReconcileResult]:
        """Drive every submitted session to completion; sid -> result."""
        batch = SessionBatch(self._sessions)
        rnd = 0
        while True:
            rnd += 1
            cohorts = batch.plan_round(rnd)
            if not cohorts:
                break
            for cohort in cohorts:
                self._run_cohort_round(cohort, rnd)
        return {s.sid: finalize_result(s.state, s.plan) for s in self._sessions}

    def _run_cohort_round(self, cohort: CohortRound, rnd: int) -> None:
        xors_a, xors_b, ok, pos, cnt, csum_a, csum_b = jax.device_get(
            execute_round(
                jnp.asarray(cohort.elems_a),
                jnp.asarray(cohort.valid_a),
                jnp.asarray(cohort.elems_b),
                jnp.asarray(cohort.valid_b),
                jnp.asarray(cohort.seeds),
                n=cohort.n,
                t=cohort.t,
                interpret=self._interpret,
            )
        )
        sketch_bits = cohort.t * cohort.m + 1  # per-unit sketch + ok flag
        for sess, base, active, bin_seed in cohort.members:
            k = len(active)
            rows = slice(base, base + k)
            positions = [
                pos[base + i, : cnt[base + i]].astype(np.int64) for i in range(k)
            ]
            round_bits = k * sketch_bits
            round_bits += apply_round_outcomes(
                sess.state,
                active,
                ok[rows],
                positions,
                xors_a[rows],
                xors_b[rows],
                csum_a[rows],
                csum_b[rows],
                plan=sess.plan,
                bin_seed=bin_seed,
                rnd=rnd,
            )
            sess.state.bytes_per_round.append((round_bits + 7) // 8)
            sess.state.rounds = rnd


def reconcile_batch(
    pairs,
    cfgs=None,
    d_knowns=None,
    *,
    interpret: bool | None = None,
) -> list[ReconcileResult]:
    """One-shot convenience: reconcile a list of (set_a, set_b) pairs.

    ``cfgs``/``d_knowns`` may be None, a single value applied to every pair,
    or a per-pair sequence.  Results come back in submission order.
    """
    npairs = len(pairs)

    def _broadcast(x, name):
        # scalars (None, a PBSConfig, an int d) broadcast; any sized
        # non-string container is per-pair and must match the pair count
        if x is None or isinstance(x, str) or not hasattr(x, "__len__"):
            return [x] * npairs
        if len(x) != npairs:
            raise ValueError(f"{name} has {len(x)} entries for {npairs} pairs")
        return list(x)

    server = ReconcileServer(interpret=interpret)
    for (a, b), cfg, dk in zip(
        pairs, _broadcast(cfgs, "cfgs"), _broadcast(d_knowns, "d_knowns")
    ):
        server.submit(a, b, cfg=cfg, d_known=dk)
    results = server.run()
    return [results[i] for i in range(npairs)]
