"""The fused round executor: one cohort's round as one jitted device call.

Per call (DESIGN.md §5 round dataflow), for all U packed units at once:

1. **on-device row build** — gather each unit's element row from the
   cohort's resident store (uploaded once per run), derive the valid mask
   from the store counts, apply Alice's diff overlay (drop removed = A ∩ D̂
   by value match, append added = D̂ \\ A columns), and mask both sides by
   the unit's 3-way-split filter chain with the same multiply-shift hash
   the protocol uses on the host;
2. **fused two-side encode** — Alice's and Bob's built rows stack into ONE
   ``bin_parity_xorsum_units`` launch and ONE GF(2) sketch matmul (half the
   kernel launches of encoding each side separately), with the per-unit
   wrap-around checksums folded into the same pass;
3. the sketch XOR feeds ``bch_decode_batched`` — the vmapped fixed-trip
   Berlekamp–Massey + Chien search (DESIGN.md §3) — locating each unit's
   differing bins (``ok`` False = BCH overload → the host re-queues the
   unit's 3-way split).

Shape polymorphism is confined to (U, Wa, Wb, R, X, F), all bucketed to
powers of two by the planner, so a serving loop settles into a bounded set
of compiled variants per cohort code.  On TPU the per-round overlay buffers
are donated — they are dead after the call, so XLA may reuse their memory
for outputs.

``encode_side`` is the single-side half of the same pass — one endpoint's
row build + bin/sketch/checksum without the other side or the decode — used
by the ``repro.net`` wire endpoints (DESIGN.md §9), which ship the sketches
as frames and (on Bob's end) feed the frame-decoded XOR to the batched
decoder.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.bch import bch_code
from repro.kernels.bin_xorsum import (
    bin_parity_xorsum_units,
    mix32_jnp,
    mulshift_bins,
    xor_bits_to_u32,
)
from repro.kernels.ops import bch_decode_batched, sketch_groups, sketch_groups_range
from repro.kernels.platform import count_retrace
from repro.obs.trace import NULL_TRACER

# Opt-in profiler hook (DESIGN.md §14): install a Tracer built with
# jax_profiler=True and every executor dispatch window is annotated inside
# a ``jax.profiler.trace`` capture.  The default NULL_TRACER hands back a
# shared no-op context, so the un-opted path costs one with-statement.
_DISPATCH_TRACER = NULL_TRACER


def set_dispatch_tracer(tracer) -> None:
    """Install (or, with None, remove) the tracer whose ``annotate`` wraps
    every ``execute_round``/``encode_side`` dispatch."""
    global _DISPATCH_TRACER
    _DISPATCH_TRACER = tracer if tracer is not None else NULL_TRACER


def _count_trace(name: str, probe) -> None:
    """Ledger one jit trace of this executor (DESIGN.md §12).

    The body of a jitted function runs exactly once per cache-missing
    signature; the Tracer guard keeps eager (un-jitted) calls of the same
    body — the kernel unit tests — out of the serving-loop retrace count.
    """
    if isinstance(probe, jax.core.Tracer):
        count_retrace(name)


def _wrap_csum(elems: jax.Array, valid: jax.Array) -> jax.Array:
    """Per-unit checksum c(S) = sum mod 2^32 via wrap-around uint32 adds."""
    vals = jnp.where(valid, elems.astype(jnp.uint32), jnp.uint32(0))
    return jnp.sum(vals, axis=1, dtype=jnp.uint32)


def _build_rows(flat, start, cnt, row_map, width: int):
    """Gather padded unit element rows + validity from the CSR store.

    ``width`` is the planner's per-round gather width (pow2-bucketed max row
    count among the gathered units); reads past a row's count are clamped to
    index 0 and masked invalid.
    """
    starts = start[row_map][:, None]                   # (U, 1)
    counts = cnt[row_map][:, None]
    offs = jnp.arange(width, dtype=jnp.int32)[None, :]
    valid = offs < counts
    idx = jnp.where(valid, starts + offs, 0)
    return flat[idx], valid                            # (U, W) uint32, bool


def _apply_filters(elems, valid, fseeds, fbins, fcnt):
    """Mask elements by the unit's 3-way-split filter chain (paper §3.2).

    F (the chain depth) is a static dim, so the loop unrolls; inactive
    levels (fcnt <= k) pass everything through.
    """
    for k in range(fseeds.shape[1]):
        on = (fcnt > k)[:, None]
        bins3 = mulshift_bins(mix32_jnp(elems, fseeds[:, k][:, None]), 3)
        valid = valid & (~on | (bins3 == fbins[:, k][:, None]))
    return valid


def _build_side(
    flat, start, cnt, row_map, width, removed, removed_cnt, added, added_cnt,
    unit_valid, fseeds, fbins, fcnt,
):
    """One side's full on-device unit-row build: CSR gather, diff overlay
    (drop ``removed`` by value match, append ``added`` columns — both may be
    zero-width, in which case the overlay ops vanish), split-filter chain,
    and the padding-unit mask.  Shared by the fused two-side executor and
    the single-side executor the wire endpoints drive."""
    e, v = _build_rows(flat, start, cnt, row_map, width)
    if removed.shape[1]:
        rm_on = jnp.arange(removed.shape[1])[None, :] < removed_cnt[:, None]
        hit = (e[:, :, None] == removed[:, None, :]) & rm_on[:, None, :]
        v = v & ~jnp.any(hit, axis=-1)
    if added.shape[1]:
        e = jnp.concatenate([e, added], axis=1)
        v = jnp.concatenate(
            [v, jnp.arange(added.shape[1])[None, :] < added_cnt[:, None]], axis=1
        )
    v = _apply_filters(e, v, fseeds, fbins, fcnt)
    return e, v & (unit_valid != 0)[:, None]


def _pad_width(elems, valid, width):
    pad = width - elems.shape[1]
    if pad == 0:
        return elems, valid
    return (
        jnp.pad(elems, ((0, 0), (0, pad))),
        jnp.pad(valid, ((0, 0), (0, pad))),
    )


def _execute_round(
    flat_a: jax.Array,
    start_a: jax.Array,
    cnt_a: jax.Array,
    flat_b: jax.Array,
    start_b: jax.Array,
    cnt_b: jax.Array,
    row_map: jax.Array,
    unit_valid: jax.Array,
    seeds: jax.Array,
    removed: jax.Array,
    removed_cnt: jax.Array,
    added: jax.Array,
    added_cnt: jax.Array,
    fseeds: jax.Array,
    fbins: jax.Array,
    fcnt: jax.Array,
    *,
    n: int,
    t: int,
    width_a: int,
    width_b: int,
    interpret: bool | None = None,
):
    """Run one PBS round for U packed units of one (n, t) cohort.

    Returns (xors_a, xors_b (U, n) uint32, ok (U,), positions (U, t) padded
    with -1, counts (U,), csum_a, csum_b (U,) uint32).
    """
    _count_trace("execute_round", flat_a)
    code = bch_code(n, t)
    empty_overlay = jnp.zeros((row_map.shape[0], 0), jnp.uint32)
    zero_cnt = jnp.zeros(row_map.shape[0], jnp.int32)

    # --- Alice: store row + diff overlay; Bob: store row only -----------
    ea, va = _build_side(
        flat_a, start_a, cnt_a, row_map, width_a,
        removed, removed_cnt, added, added_cnt, unit_valid, fseeds, fbins, fcnt,
    )
    eb, vb = _build_side(
        flat_b, start_b, cnt_b, row_map, width_b,
        empty_overlay, zero_cnt, empty_overlay, zero_cnt,
        unit_valid, fseeds, fbins, fcnt,
    )

    # --- fused two-side encode: one bin launch, one sketch matmul -------
    width = max(ea.shape[1], eb.shape[1])
    ea, va = _pad_width(ea, va, width)
    eb, vb = _pad_width(eb, vb, width)
    elems2 = jnp.concatenate([ea, eb], axis=0)          # (2U, W)
    valid2 = jnp.concatenate([va, vb], axis=0)
    seeds2 = jnp.concatenate([seeds, seeds], axis=0)
    parity2, xor_bits2 = bin_parity_xorsum_units(
        elems2, valid2.astype(jnp.int32), seeds2, n_bins=n, interpret=interpret
    )
    sk2 = sketch_groups(parity2, code, interpret=interpret)
    xors2 = xor_bits_to_u32(xor_bits2)
    csum2 = _wrap_csum(elems2, valid2)

    u = row_map.shape[0]
    sk_diff = sk2[:u] ^ sk2[u:]
    ok, pos, cnt = bch_decode_batched(sk_diff, n=n, t=t)
    # sk_diff rides back with the outcomes: it is the cached syndrome
    # *prefix* the rateless recovery path (DESIGN.md §16) concatenates with
    # incremental parity when a unit overloads — nothing re-encodes.
    return xors2[:u], xors2[u:], ok, pos, cnt, csum2[:u], csum2[u:], sk_diff


def _encode_side(
    flat: jax.Array,
    start: jax.Array,
    cnt: jax.Array,
    row_map: jax.Array,
    unit_valid: jax.Array,
    seeds: jax.Array,
    removed: jax.Array,
    removed_cnt: jax.Array,
    added: jax.Array,
    added_cnt: jax.Array,
    fseeds: jax.Array,
    fbins: jax.Array,
    fcnt: jax.Array,
    *,
    n: int,
    t: int,
    width: int,
    interpret: bool | None = None,
):
    """Encode ONE side's U packed units: the wire-endpoint half of the round.

    Same on-device row build + bin/sketch/checksum pass as the fused
    executor, but for a single endpoint's resident store (Bob passes
    zero-width overlays).  Returns (sketches (U, t), xors (U, n) uint32,
    csum (U,) uint32); the sketches are what ``repro.wire`` bit-packs into
    the round frames, and Bob feeds the frame-decoded XOR of both sides'
    sketches to ``bch_decode_batched``.
    """
    _count_trace("encode_side", flat)
    code = bch_code(n, t)
    e, v = _build_side(
        flat, start, cnt, row_map, width,
        removed, removed_cnt, added, added_cnt, unit_valid, fseeds, fbins, fcnt,
    )
    parity, xor_bits = bin_parity_xorsum_units(
        e, v.astype(jnp.int32), seeds, n_bins=n, interpret=interpret
    )
    sk = sketch_groups(parity, code, interpret=interpret)
    return sk, xor_bits_to_u32(xor_bits), _wrap_csum(e, v)


def _execute_round_ext(
    flat_a: jax.Array,
    start_a: jax.Array,
    cnt_a: jax.Array,
    flat_b: jax.Array,
    start_b: jax.Array,
    cnt_b: jax.Array,
    row_map: jax.Array,
    unit_valid: jax.Array,
    seeds: jax.Array,
    removed: jax.Array,
    removed_cnt: jax.Array,
    added: jax.Array,
    added_cnt: jax.Array,
    fseeds: jax.Array,
    fbins: jax.Array,
    fcnt: jax.Array,
    *,
    n: int,
    t0: int,
    t1: int,
    width_a: int,
    width_b: int,
    interpret: bool | None = None,
):
    """One rateless extension step for U packed units of one (n, t) cohort
    (DESIGN.md §16): rebuild both sides' rows for the SAME round (identical
    bin seeds → identical parity bitmaps) and emit only the XOR of the
    *incremental* syndromes S_{2*t0+1}..S_{2*t1-1} — a (U, t1-t0) array the
    host concatenates onto the cached round-diff prefix and decodes at t1.
    """
    _count_trace("execute_round_ext", flat_a)
    code = bch_code(n, t1)
    empty_overlay = jnp.zeros((row_map.shape[0], 0), jnp.uint32)
    zero_cnt = jnp.zeros(row_map.shape[0], jnp.int32)
    ea, va = _build_side(
        flat_a, start_a, cnt_a, row_map, width_a,
        removed, removed_cnt, added, added_cnt, unit_valid, fseeds, fbins, fcnt,
    )
    eb, vb = _build_side(
        flat_b, start_b, cnt_b, row_map, width_b,
        empty_overlay, zero_cnt, empty_overlay, zero_cnt,
        unit_valid, fseeds, fbins, fcnt,
    )
    width = max(ea.shape[1], eb.shape[1])
    ea, va = _pad_width(ea, va, width)
    eb, vb = _pad_width(eb, vb, width)
    elems2 = jnp.concatenate([ea, eb], axis=0)
    valid2 = jnp.concatenate([va, vb], axis=0)
    seeds2 = jnp.concatenate([seeds, seeds], axis=0)
    parity2, _ = bin_parity_xorsum_units(
        elems2, valid2.astype(jnp.int32), seeds2, n_bins=n, interpret=interpret
    )
    inc2 = sketch_groups_range(parity2, code, t0, interpret=interpret)
    u = row_map.shape[0]
    return inc2[:u] ^ inc2[u:]


def _encode_side_ext(
    flat: jax.Array,
    start: jax.Array,
    cnt: jax.Array,
    row_map: jax.Array,
    unit_valid: jax.Array,
    seeds: jax.Array,
    removed: jax.Array,
    removed_cnt: jax.Array,
    added: jax.Array,
    added_cnt: jax.Array,
    fseeds: jax.Array,
    fbins: jax.Array,
    fcnt: jax.Array,
    *,
    n: int,
    t0: int,
    t1: int,
    width: int,
    interpret: bool | None = None,
):
    """ONE side's incremental syndromes for the current round: the
    ``encode_side`` variant behind ``MSG_PARITY`` (DESIGN.md §16).  Same
    on-device row build and bin pass over the same round seeds, but the
    sketch matmul covers only syndrome columns [t0, t1) — Alice frames the
    result; Bob XORs his own against the frame and decodes at t1 with the
    cached prefix.  Returns (U, t1-t0) field elements.
    """
    _count_trace("encode_side_ext", flat)
    code = bch_code(n, t1)
    e, v = _build_side(
        flat, start, cnt, row_map, width,
        removed, removed_cnt, added, added_cnt, unit_valid, fseeds, fbins, fcnt,
    )
    parity, _ = bin_parity_xorsum_units(
        e, v.astype(jnp.int32), seeds, n_bins=n, interpret=interpret
    )
    return sketch_groups_range(parity, code, t0, interpret=interpret)


# Per-round overlay buffers are dead after the call; donating them lets XLA
# alias their device memory on TPU.  Off-TPU donation is unsupported and
# only warns, so it stays off there.
_ROUND_BUFFERS = (
    "row_map", "unit_valid", "seeds", "removed", "removed_cnt",
    "added", "added_cnt", "fseeds", "fbins", "fcnt",
)


@functools.lru_cache(maxsize=None)
def _jitted_executor(donate: bool):
    return jax.jit(
        _execute_round,
        static_argnames=("n", "t", "width_a", "width_b", "interpret"),
        donate_argnames=_ROUND_BUFFERS if donate else (),
    )


def execute_round(*args, **kwargs):
    """Jitted ``_execute_round``; the backend probe for buffer donation is
    deferred to call time so importing this module never initializes JAX."""
    with _DISPATCH_TRACER.annotate("repro.execute_round"):
        return _jitted_executor(jax.default_backend() == "tpu")(*args, **kwargs)


@functools.lru_cache(maxsize=None)
def _jitted_side_executor():
    # No donation here: a wire endpoint re-reads nothing either, but the
    # overlay buffers are tiny and the call count is one per cohort-round —
    # keep the single-side path free of backend probes.
    return jax.jit(_encode_side, static_argnames=("n", "t", "width", "interpret"))


def encode_side(*args, **kwargs):
    """Jitted ``_encode_side`` (the per-endpoint half of ``execute_round``)."""
    with _DISPATCH_TRACER.annotate("repro.encode_side"):
        return _jitted_side_executor()(*args, **kwargs)


# Extension executors stay donation-free: a cohort may extend several levels
# over the same overlay arrays, and the host re-dispatches from the numpy
# plan arrays each level anyway.  (n, t0, t1) are static — the deterministic
# t-ladder keeps the signature set bounded, so a warm serving loop extends
# with zero retraces (DESIGN.md §16).


@functools.lru_cache(maxsize=None)
def _jitted_ext_executor():
    return jax.jit(
        _execute_round_ext,
        static_argnames=("n", "t0", "t1", "width_a", "width_b", "interpret"),
    )


def execute_round_ext(*args, **kwargs):
    """Jitted ``_execute_round_ext`` (both sides' incremental syndrome XOR)."""
    with _DISPATCH_TRACER.annotate("repro.execute_round_ext"):
        return _jitted_ext_executor()(*args, **kwargs)


@functools.lru_cache(maxsize=None)
def _jitted_side_ext_executor():
    return jax.jit(
        _encode_side_ext, static_argnames=("n", "t0", "t1", "width", "interpret")
    )


def encode_side_ext(*args, **kwargs):
    """Jitted ``_encode_side_ext`` (one endpoint's ``MSG_PARITY`` payload)."""
    with _DISPATCH_TRACER.annotate("repro.encode_side_ext"):
        return _jitted_side_ext_executor()(*args, **kwargs)
