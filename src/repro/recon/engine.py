"""The jitted round executor: one cohort's round entirely on the accelerator.

Per call (DESIGN.md §5 round dataflow), for all U packed units at once:

1. ``encode_groups`` twice (Alice's effective sets, Bob's sets): the batched
   bin_xorsum Pallas kernel bins every unit with its own per-round hash and
   folds per-bin parities/XORs, then one GF(2) matmul over all parity
   bitmaps yields every unit's BCH sketch;
2. the sketch XOR feeds ``bch_decode_batched`` — the vmapped fixed-trip
   Berlekamp–Massey + Chien search (DESIGN.md §3) — locating each unit's
   differing bins (``ok`` False = BCH overload → the host re-queues the
   unit's 3-way split);
3. per-unit checksums (sum mod 2^32) come from a masked wrap-around uint32
   reduction, matching the paper's §2.2.3 gate bit-for-bit.

Everything here is shape-polymorphic only in (U, Ea, Eb); the planner aligns
those to fixed multiples so a serving loop settles into a handful of compiled
variants per cohort code.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.bch import BCHCode
from repro.kernels.ops import bch_decode_batched, encode_groups


def _wrap_csum(elems: jax.Array, valid: jax.Array) -> jax.Array:
    """Per-unit checksum c(S) = sum mod 2^32 via wrap-around uint32 adds."""
    vals = jnp.where(valid != 0, elems.astype(jnp.uint32), jnp.uint32(0))
    return jnp.sum(vals, axis=1, dtype=jnp.uint32)


@functools.partial(jax.jit, static_argnames=("n", "t", "interpret"))
def execute_round(
    elems_a: jax.Array,
    valid_a: jax.Array,
    elems_b: jax.Array,
    valid_b: jax.Array,
    seeds: jax.Array,
    *,
    n: int,
    t: int,
    interpret: bool | None = None,
):
    """Run one PBS round for U packed units of one (n, t) cohort.

    Returns (xors_a, xors_b (U, n) uint32, ok (U,), positions (U, t) padded
    with -1, counts (U,), csum_a, csum_b (U,) uint32).
    """
    code = BCHCode(n, t)
    _, xors_a, sk_a = encode_groups(elems_a, valid_a, seeds, code, interpret=interpret)
    _, xors_b, sk_b = encode_groups(elems_b, valid_b, seeds, code, interpret=interpret)
    ok, pos, cnt = bch_decode_batched(sk_a ^ sk_b, n=n, t=t)
    return (
        xors_a,
        xors_b,
        ok,
        pos,
        cnt,
        _wrap_csum(elems_a, valid_a),
        _wrap_csum(elems_b, valid_b),
    )
