"""Training-step factory: shard_map body + jit boundary with explicit shardings.

``make_train_step(cfg, mesh, opt_cfg)`` returns a jitted
``step(params, opt_state, batch) -> (params, opt_state, metrics)`` whose
in/out shardings come from the single `P`-spec source of truth
(repro.models.spec), so the multi-pod dry-run can `.lower()` it against
`ShapeDtypeStruct`s with zero allocation.

Loss/grad correctness under the mesh (see DESIGN.md §4):

* the per-rank objective is ``(ce_mean_local + coef·aux) / world`` — summing
  it over ALL ranks equals ``mean_ce + coef·mean_pods(aux)`` exactly (ce is
  replicated across 'model' by the distributed softmax, aux across the
  ('data','model') EP world), so
* the gradient of the global objective w.r.t. each leaf is the psum of local
  grads over exactly the leaf's replication axes — which is what
  `repro.optim.sync_gradient` performs (reduce-scatter under ZeRO-1).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from repro.models.backbone import ce_loss, forward, model_spec
from repro.models.config import ModelConfig
from repro.models.layers import MeshCtx
from repro.models.spec import abstract_params, init_params, pspecs, tree_map_p
from repro.optim import (
    OptConfig,
    apply_updates,
    build_plan,
    init_opt_state,
    opt_state_spec,
)
from repro.optim.compression import (
    CompressionConfig,
    error_spec,
    init_error_state,
    sync_all,
)


def mesh_ctx(mesh) -> MeshCtx:
    names = mesh.axis_names
    return MeshCtx(
        model_size=mesh.shape["model"],
        data_axes=tuple(a for a in names if a != "model"),
        data_size=mesh.shape.get("data", 1),
    )


def mesh_sizes(mesh) -> dict:
    return {a: mesh.shape[a] for a in mesh.axis_names}


def batch_axes(mesh, batch: int):
    """Mesh axes to shard the batch dim over ('pod'+'data' when divisible)."""
    dp = tuple(a for a in mesh.axis_names if a != "model")
    world = int(np.prod([mesh.shape[a] for a in dp]))
    if batch % world == 0:
        return dp
    if "data" in dp and batch % mesh.shape["data"] == 0:
        return ("data",)
    return None  # replicate (e.g. long_500k batch=1)


@dataclass(frozen=True)
class TrainBundle:
    step: callable            # jitted (params, opt, batch) -> (params, opt, metrics)
    param_spec: dict          # P tree
    opt_spec: dict            # P tree
    in_shardings: tuple
    batch_pspecs: dict
    ctx: MeshCtx

    def abstract_args(self, batch_shapes: dict):
        """ShapeDtypeStructs for .lower() — nothing allocated."""
        return (
            abstract_params(self.param_spec),
            abstract_params(self.opt_spec),
            {k: jax.ShapeDtypeStruct(*v) for k, v in batch_shapes.items()},
        )


def batch_pspec_tree(cfg: ModelConfig, mesh, batch: int) -> dict:
    ba = batch_axes(mesh, batch)
    tree = {
        "tokens": PartitionSpec(ba, "model"),
        "labels": PartitionSpec(ba, None),
    }
    if cfg.family == "encdec":
        tree["enc"] = PartitionSpec(ba, "model", None)
    if cfg.frontend == "patch_stub":
        tree["frontend"] = PartitionSpec(ba, "model", None)
    return tree


def batch_shapes(cfg: ModelConfig, batch: int, seq: int, enc_len: int = 1536) -> dict:
    shapes = {
        "tokens": ((batch, seq), jnp.int32),
        "labels": ((batch, seq), jnp.int32),
    }
    if cfg.family == "encdec":
        shapes["enc"] = ((batch, enc_len, cfg.d_model), jnp.bfloat16)
    if cfg.frontend == "patch_stub":
        shapes["frontend"] = ((batch, seq, cfg.d_model), jnp.bfloat16)
    return shapes


def make_train_step(
    cfg: ModelConfig,
    mesh,
    opt_cfg: OptConfig,
    *,
    batch: int,
    aux_coef: float = 1e-3,
    remat: bool = True,
    microbatch: int = 1,
    compression: CompressionConfig | None = None,
) -> TrainBundle:
    """microbatch > 1 = gradient accumulation: the local batch is processed
    in `microbatch` sequential slices under lax.scan, shrinking activation
    memory ~linearly at the cost of one f32 grad accumulator per leaf.
    compression = error-feedback top-k gradient compression over 'data'
    (repro.optim.compression).  Both are §Perf levers (EXPERIMENTS.md)."""
    ctx = mesh_ctx(mesh)
    sizes = mesh_sizes(mesh)
    world = int(np.prod(list(sizes.values())))
    spec = model_spec(cfg, ctx)
    plan = build_plan(spec, mesh.axis_names, sizes, opt_cfg)
    o_spec = opt_state_spec(spec, plan, sizes, opt_cfg)
    ccfg = compression or CompressionConfig()
    if ccfg.enabled:
        o_spec["err"] = error_spec(spec, plan, ccfg)
    p_ps, o_ps = pspecs(spec), pspecs(o_spec)
    b_ps = batch_pspec_tree(cfg, mesh, batch)
    ep_data = sizes.get("data", 1)

    def local_step(params, opt_state, batch_):
        def objective(params, mb):
            x, aux = forward(
                params,
                mb["tokens"],
                ctx,
                cfg,
                ep_data_size=ep_data,
                frontend_sp=mb.get("frontend"),
                enc_embeds_sp=mb.get("enc"),
                remat=remat,
            )
            ce = ce_loss(params["embed"], x, mb["labels"], ctx, cfg)
            return (ce + aux_coef * aux) / (world * microbatch), (ce, aux)

        if microbatch == 1:
            (_, (ce, aux)), grads = jax.value_and_grad(
                objective, has_aux=True)(params, batch_)
        else:
            stacked = {
                k: v.reshape((microbatch, v.shape[0] // microbatch) + v.shape[1:])
                for k, v in batch_.items()
            }

            def mb_step(carry, mb):
                acc, ce_a, aux_a = carry
                (_, (ce, aux)), g = jax.value_and_grad(
                    objective, has_aux=True)(params, mb)
                acc = jax.tree.map(lambda a, b: a + b.astype(a.dtype), acc, g)
                return (acc, ce_a + ce / microbatch, aux_a + aux / microbatch), None

            zeros = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params)
            (grads, ce, aux), _ = jax.lax.scan(
                mb_step, (zeros, jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
                stacked,
            )
        if ccfg.enabled:
            grads, new_err, _ledger = sync_all(
                grads, opt_state["err"], plan, opt_cfg, ccfg
            )
            new_params, new_opt, om = apply_updates(
                grads, params, opt_state, plan, opt_cfg, mesh.axis_names,
                presynced=True,
            )
            new_opt["err"] = new_err
        else:
            new_params, new_opt, om = apply_updates(
                grads, params, opt_state, plan, opt_cfg, mesh.axis_names
            )
        metrics = {
            "loss": jax.lax.psum(ce / world, mesh.axis_names),
            "aux": jax.lax.psum(aux / world, mesh.axis_names),
            "grad_norm": om["grad_norm"],
            "lr": om["lr"],
        }
        return new_params, new_opt, metrics

    m_ps = {k: PartitionSpec() for k in ("loss", "aux", "grad_norm", "lr")}
    body = jax.shard_map(
        local_step,
        mesh=mesh,
        in_specs=(p_ps, o_ps, b_ps),
        out_specs=(p_ps, o_ps, m_ps),
        check_vma=False,
    )
    sh = lambda tree: jax.tree.map(  # noqa: E731
        lambda ps: NamedSharding(mesh, ps), tree,
        is_leaf=lambda x: isinstance(x, PartitionSpec),
    )
    step = jax.jit(
        body,
        in_shardings=(sh(p_ps), sh(o_ps), sh(b_ps)),
        out_shardings=(sh(p_ps), sh(o_ps), sh(m_ps)),
        donate_argnums=(0, 1),
    )
    return TrainBundle(
        step=step, param_spec=spec, opt_spec=o_spec,
        in_shardings=(sh(p_ps), sh(o_ps), sh(b_ps)), batch_pspecs=b_ps, ctx=ctx,
    )


def init_train_state(bundle: TrainBundle, cfg: ModelConfig, mesh, opt_cfg: OptConfig,
                     seed=0, compression: CompressionConfig | None = None):
    """Materialize (params, opt_state) on the mesh (smoke tests / real runs).

    Params are initialized globally then sharded; the optimizer state is
    built *inside* shard_map so ZeRO-1 slices land on their owning ranks.
    """
    sizes = mesh_sizes(mesh)
    spec = bundle.param_spec
    plan = build_plan(spec, mesh.axis_names, sizes, opt_cfg)
    p_ps, o_ps = pspecs(spec), pspecs(bundle.opt_spec)
    sh = lambda tree_ps: jax.tree.map(  # noqa: E731
        lambda ps: NamedSharding(mesh, ps), tree_ps,
        is_leaf=lambda x: isinstance(x, PartitionSpec),
    )
    ccfg = compression or CompressionConfig()

    def build_opt(p):
        st = init_opt_state(p, plan, opt_cfg)
        if ccfg.enabled:
            st["err"] = init_error_state(p, plan, ccfg)
        return st

    params = jax.device_put(init_params(spec, jax.random.PRNGKey(seed)), sh(p_ps))
    opt_init = jax.jit(
        jax.shard_map(
            build_opt,
            mesh=mesh, in_specs=(p_ps,), out_specs=o_ps, check_vma=False,
        ),
        out_shardings=sh(o_ps),
    )
    return params, opt_init(params)
