"""Training loop layer: shard_map step factory + state init."""
from .step import (  # noqa: F401
    TrainBundle,
    batch_axes,
    batch_pspec_tree,
    batch_shapes,
    init_train_state,
    make_train_step,
    mesh_ctx,
    mesh_sizes,
)
