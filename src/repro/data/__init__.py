"""Deterministic data pipeline + PBS-reconciled consumption ledger."""
from .pipeline import (  # noqa: F401
    DataConfig,
    Ledger,
    global_batch,
    host_shard,
    sample_tokens,
    step_sample_ids,
)
