"""Deterministic synthetic data pipeline + PBS-reconciled consumption ledger.

The pipeline is the substrate a real deployment needs for elastic,
exactly-once data feeding at 1000-node scale:

* **Deterministic sharded batches** — sample ``i`` of the global stream is
  generated from ``mix32(i)`` alone, so any host can produce any shard of any
  step without coordination; host assignment is a pure function of
  (step, host, n_hosts).  Elastic rescale = change n_hosts; no data is
  re-shuffled through a coordinator.
* **Consumption ledger** — each host records consumed sample ids.  After a
  failure/rescale, a (re)joining host must learn exactly which samples the
  fleet already consumed this epoch.  The fleet's ledger is huge (billions)
  but the *difference* against the joiner's stale ledger is small — a set
  reconciliation problem, solved with PBS in O(d) time and ~2× optimal bytes
  (``Ledger.reconcile``), instead of shipping the full ledger.

Samples are 32-bit ids (the paper's universe); token content is derived from
the id, so reconciling ids reconciles data exactly.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.hashing import mix32
from repro.core.pbs import PBSConfig, reconcile


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0


def sample_tokens(ids: np.ndarray, cfg: DataConfig) -> np.ndarray:
    """Tokens for each sample id — pure function of the id (exactly-once safe)."""
    pos = np.arange(cfg.seq_len, dtype=np.uint32)[None, :]
    base = mix32(ids.astype(np.uint32), cfg.seed ^ 0xD474)
    toks = mix32(base[:, None] + pos * np.uint32(0x9E3779B9), cfg.seed ^ 0x70C5)
    return (toks % np.uint32(cfg.vocab)).astype(np.int32)


def step_sample_ids(step: int, cfg: DataConfig) -> np.ndarray:
    start = np.uint32(1 + step * cfg.global_batch)  # id 0 excluded (PBS universe)
    return (start + np.arange(cfg.global_batch, dtype=np.uint32)).astype(np.uint32)


def host_shard(ids: np.ndarray, host: int, n_hosts: int) -> np.ndarray:
    per = len(ids) // n_hosts
    return ids[host * per : (host + 1) * per]


def global_batch(step: int, cfg: DataConfig) -> dict:
    """The full (tokens, labels) batch for one step."""
    ids = step_sample_ids(step, cfg)
    toks = sample_tokens(ids, cfg)
    labels = np.roll(toks, -1, axis=1)
    labels[:, -1] = toks[:, 0]
    return {"tokens": toks, "labels": labels, "ids": ids}


@dataclass
class Ledger:
    """Per-host consumed-sample-id set with PBS reconciliation."""

    consumed: set = field(default_factory=set)

    def record(self, ids: np.ndarray):
        self.consumed.update(int(x) for x in np.asarray(ids).ravel())

    def as_array(self) -> np.ndarray:
        return np.fromiter(self.consumed, dtype=np.uint32, count=len(self.consumed))

    def reconcile(self, fleet: "Ledger", seed: int = 0):
        """Learn the fleet's consumed set (PBS; returns (missing_here,
        extra_here, ReconcileResult with byte ledger))."""
        res = reconcile(self.as_array(), fleet.as_array(), PBSConfig(seed=seed))
        missing = {s for s in res.diff if s not in self.consumed}
        extra = {s for s in res.diff if s in self.consumed}
        return missing, extra, res

    def merge(self, missing):
        self.consumed.update(missing)
