"""Continuous epoch reconciliation for divergent replicas (DESIGN.md §11).

``repro.sync`` is the facade over the continuous-sync machinery that lives
with each layer it extends: sets mutate between **epochs** and only deltas
move — in H2D traffic (the device-resident CSR stores are patched in
place through tombstone-reclaiming swap-remove + append lanes instead of
rebuilt) and on the wire (the ``MSG_EPOCH`` envelope carries the epoch id
plus the d̂ re-estimation handshake through the phase-0 codecs).

The pieces, in dependency order:

* ``SessionBatch(mutable=True)`` + ``apply_mutations`` / ``advance_session``
  / ``apply_churn`` (``repro.recon.session``) — delta-mutable cohort
  stores with per-row capacity lanes and compaction on overflow;
* ``ReconcileServer(continuous=True).advance_epoch`` (``repro.recon``) —
  the in-process epoch loop, re-estimating d through the batched ToW
  kernel path and folding learned diffs for replica convergence;
* ``encode_epoch`` / ``decode_epoch`` (``repro.wire``) — the epoch
  envelope, mirroring ``MSG_MUX``'s ledger rules (inner bits per Formula
  (1), envelope bytes as transport overhead);
* ``AliceEndpoint`` / ``BobEndpoint`` / ``HubEndpoint`` with
  ``continuous=True`` plus the ``run_pair_epoch`` / ``run_hub_epoch``
  drivers (``repro.net``) — epochs over real transports, reusing live
  sessions and channels with no re-admission;
* ``submit_tree`` on the endpoints + ``tree_reconcile`` (``repro.tree``,
  DESIGN.md §15) — the cold-start ramp: a brand-new or long-offline
  replica's first epoch has no sane d̂, so it routes through the tree
  front end (range digests, recurse into divergence, leaf ranges as
  known-d sessions) and from the next ``advance_epoch`` on rejoins the
  ordinary delta path above.

Locked down by tests/test_sync_properties.py (delta path ≡ from-scratch
rebuild, byte for byte) and tests/test_sync_churn.py (multi-epoch hub soak
under churn against the ``core.pbs.reconcile`` oracle).
"""
from repro.net import (
    AliceEndpoint,
    BobEndpoint,
    ChaosTransport,
    FaultPlan,
    HubEndpoint,
    run_hub_epoch,
    run_pair_epoch,
)
from repro.recon.server import ReconcileServer
from repro.tree import TreeConfig, TreeResult, partition_pair, tree_reconcile
from repro.recon.session import (
    SessionBatch,
    StoreCapacityError,
    advance_session,
    apply_churn,
)
from repro.wire import decode_epoch, encode_epoch, epoch_overhead_bytes

__all__ = [
    "AliceEndpoint",
    "BobEndpoint",
    "ChaosTransport",
    "FaultPlan",
    "HubEndpoint",
    "ReconcileServer",
    "SessionBatch",
    "StoreCapacityError",
    "TreeConfig",
    "TreeResult",
    "advance_session",
    "apply_churn",
    "decode_epoch",
    "encode_epoch",
    "epoch_overhead_bytes",
    "partition_pair",
    "run_hub_epoch",
    "run_pair_epoch",
    "tree_reconcile",
]
