"""AdamW that runs INSIDE ``shard_map``, with per-leaf gradient synchronization
and optional ZeRO-1 state sharding.

Distribution contract
---------------------
Parameters live as local shards per the `P` spec tree (repro.models.spec):
each leaf names the mesh axes that shard it ('model', or ('data','model') for
expert weights); every other mesh axis replicates it.  After backward, the
local gradient of a leaf is *partial* along exactly its replication axes, so:

* plain path: ``g = psum(g, replication_axes)`` — one all-reduce per leaf
  (XLA fuses them);
* ZeRO-1 path (``zero1=True``): the 'data'-axis reduction becomes a
  ``psum_scatter`` (half the bytes of an all-reduce), the Adam state and the
  fp32 master copy are stored only for this rank's 1/D slice, and the updated
  slice is ``all_gather``-ed back — the classic ZeRO-1 memory/collective
  trade, one of the §Perf hillclimb levers (EXPERIMENTS.md).

Global-norm clipping stays exact under both paths: every leaf contributes
``sum(g²) / n_ranks_holding_this_value`` and a single scalar psum over the
whole mesh recovers the true global norm (verified against the single-device
reference in tests/test_train_parity.py).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.spec import P, tree_map_p


@dataclass(frozen=True)
class OptConfig:
    lr_peak: float = 3e-4
    lr_min_frac: float = 0.1
    warmup: int = 100
    total_steps: int = 10_000
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: Any = jnp.float32   # m/v dtype: f32 | bf16 | "int8" (block-quantized)
    master_fp32: bool = True         # keep an fp32 master copy of bf16 params
    zero1: bool = False              # shard states + master over 'data'

    @property
    def int8_states(self) -> bool:
        return isinstance(self.state_dtype, str) and self.state_dtype == "int8"


QBLK = 256  # block size for int8 quantization of m/v


# Log-spaced (dynamic) codebook: preserves the RELATIVE precision of tiny
# entries — linear absmax int8 zeroes small v entries inside mixed-magnitude
# blocks -> rsqrt blowups (measured in EXPERIMENTS.md §Perf).  The code is a
# pure function of the index (geometric levels spanning 7 decades), so
# encoding is closed-form log arithmetic — no searchsorted (whose binary-
# search while-loop materialized multiple full-size s32/f32 temporaries on
# the 851M-element deepseek expert states; ditto §Perf).
_DECADES = 7.0


def _quantize(x: jax.Array, *, signed: bool):
    """f32 (N,) padded to QBLK multiple -> (int8 code (N,), f32 scales)."""
    blocks = x.reshape(-1, QBLK)
    s = jnp.maximum(jnp.max(jnp.abs(blocks), axis=1), 1e-30)
    y = blocks / s[:, None]
    ay = jnp.abs(y)
    levels = 126.0 if signed else 254.0
    # idx 1..levels+1 spans 10^-7..10^0 geometrically; 0 encodes zero
    mag = jnp.clip(
        jnp.round((jnp.log10(jnp.maximum(ay, 1e-30)) + _DECADES) / _DECADES * levels),
        0.0, levels,
    ) + 1.0
    mag = jnp.where(ay < 10.0 ** (-_DECADES - 0.5), 0.0, mag)
    if signed:
        q = (jnp.sign(y) * mag).astype(jnp.int8)   # ±(1..127)
    else:
        q = mag.astype(jnp.uint8)                  # 0..255
    return q.reshape(-1), s


def _dequantize(q: jax.Array, s: jax.Array, *, signed: bool):
    qi = q.astype(jnp.float32)
    mag = jnp.abs(qi)
    levels = 126.0 if signed else 254.0
    val = 10.0 ** ((mag - 1.0) / levels * _DECADES - _DECADES)
    val = jnp.where(mag == 0, 0.0, val) * (jnp.sign(qi) if signed else 1.0)
    return (val.reshape(-1, QBLK) * s[:, None]).reshape(-1)


def _pad_len(n: int) -> int:
    return -(-n // QBLK) * QBLK


# Big leaves (the 851M-element deepseek expert states) update in CHUNK-sized
# slices under lax.map so the f32 dequant/update temporaries stay ~100 MB
# instead of 4×3.2 GB (§Perf hillclimb 1, EXPERIMENTS.md).
UPDATE_CHUNK = 1 << 22


def _state_pad(n: int, cfg: OptConfig) -> int:
    base = _pad_len(n) if cfg.int8_states else n
    if base > 2 * UPDATE_CHUNK:
        return -(-base // UPDATE_CHUNK) * UPDATE_CHUNK
    return base


def lr_schedule(cfg: OptConfig, step):
    """Linear warmup -> cosine decay to lr_min_frac."""
    step = step.astype(jnp.float32)
    warm = cfg.lr_peak * (step + 1.0) / max(1, cfg.warmup)
    prog = jnp.clip(
        (step - cfg.warmup) / max(1, cfg.total_steps - cfg.warmup), 0.0, 1.0
    )
    cos = cfg.lr_min_frac + (1 - cfg.lr_min_frac) * 0.5 * (1 + jnp.cos(np.pi * prog))
    return jnp.where(step < cfg.warmup, warm, cfg.lr_peak * cos)


# ---------------------------------------------------------------------------
# per-leaf distribution plan
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LeafPlan:
    sync_axes: tuple       # plain-psum axes for this leaf's gradient
    scatter: bool          # ZeRO-1: reduce-scatter over 'data' instead
    param_axes: tuple      # mesh axes (mesh order) that shard the param leaf
    norm_weight: float     # 1 / (#ranks holding the synced value)
    chunk: int             # per-rank slice length when scatter
    local_shape: tuple     # local shard shape of the param leaf


def _leaf_axis_names(p: P) -> set:
    names = set()
    for ax in p.axes:
        if ax is None:
            continue
        if isinstance(ax, tuple):
            names.update(ax)
        else:
            names.add(ax)
    return names


def _local_shape(p: P, mesh_sizes: dict) -> tuple:
    shape = []
    for dim, ax in zip(p.shape, p.axes):
        f = 1
        for a in (ax if isinstance(ax, tuple) else (ax,)):
            if a is not None:
                f *= mesh_sizes[a]
        assert dim % f == 0, (p.shape, p.axes, dim, f)
        shape.append(dim // f)
    return tuple(shape)


def build_plan(spec_tree, mesh_axes: tuple, mesh_sizes: dict, cfg: OptConfig):
    """LeafPlan tree; mesh_axes e.g. ('data','model') or ('pod','data','model')."""

    def plan_leaf(p: P) -> LeafPlan:
        used = _leaf_axis_names(p)
        repl = tuple(a for a in mesh_axes if a not in used)
        local = _local_shape(p, mesh_sizes)
        size = int(np.prod(local))
        D = mesh_sizes.get("data", 1)
        scatter = cfg.zero1 and "data" in repl and size >= D and D > 1
        sync = tuple(a for a in repl if not (scatter and a == "data"))
        weight = 1.0 / int(np.prod([mesh_sizes[a] for a in sync])) if sync else 1.0
        chunk = -(-size // D) if scatter else size
        return LeafPlan(
            sync_axes=sync,
            scatter=scatter,
            param_axes=tuple(a for a in mesh_axes if a in used),
            norm_weight=weight,
            chunk=chunk,
            local_shape=local,
        )

    return tree_map_p(plan_leaf, spec_tree)


def _state_layout(plan: LeafPlan, mesh_sizes: dict):
    """1-D state layout per leaf: (base local length, holders, dim0 axes)."""
    base = plan.chunk if plan.scatter else int(np.prod(plan.local_shape))
    holders = int(np.prod([mesh_sizes[a] for a in plan.param_axes]))
    axes = tuple(plan.param_axes) + (("data",) if plan.scatter else ())
    dim0 = (axes if axes else None,)
    if plan.scatter:
        holders *= mesh_sizes.get("data", 1)
    return base, holders, dim0


def opt_state_spec(spec_tree, plan_tree, mesh_sizes: dict, cfg: OptConfig):
    """P tree for the optimizer state (drives abstract/pspecs/init like params).

    All states are flat 1-D per local shard; int8 m/v add per-QBLK scales."""

    def leaf(p: P, plan: LeafPlan):
        base, holders, dim0 = _state_layout(plan, mesh_sizes)
        pad = _state_pad(base, cfg)
        if cfg.int8_states:
            st = {
                "m_q": P((holders * pad,), dim0, "zeros", dtype=jnp.int8),
                "m_s": P((holders * pad // QBLK,), dim0, "zeros", dtype=jnp.float32),
                "v_q": P((holders * pad,), dim0, "zeros", dtype=jnp.uint8),
                "v_s": P((holders * pad // QBLK,), dim0, "zeros", dtype=jnp.float32),
            }
        else:
            st = {
                "m": P((holders * pad,), dim0, "zeros", dtype=cfg.state_dtype),
                "v": P((holders * pad,), dim0, "zeros", dtype=cfg.state_dtype),
            }
        if cfg.master_fp32:
            st["master"] = P((holders * pad,), dim0, "zeros", dtype=jnp.float32)
        return st

    def walk(spec, plan):
        if isinstance(spec, dict):
            return {k: walk(spec[k], plan[k]) for k in spec}
        return leaf(spec, plan)

    return {"step": P((), (), "zeros", dtype=jnp.int32), "leaves": walk(spec_tree, plan_tree)}


def init_opt_state(params, plan_tree, cfg: OptConfig):
    """Build the LOCAL optimizer state inside shard_map (or single-device)."""

    def leaf(x, plan: LeafPlan):
        base = plan.chunk if plan.scatter else int(np.prod(plan.local_shape))
        pad = _state_pad(base, cfg)
        if cfg.int8_states:
            st = {
                "m_q": jnp.zeros((pad,), jnp.int8),
                "m_s": jnp.zeros((pad // QBLK,), jnp.float32),
                "v_q": jnp.zeros((pad,), jnp.uint8),
                "v_s": jnp.zeros((pad // QBLK,), jnp.float32),
            }
        else:
            st = {
                "m": jnp.zeros((pad,), cfg.state_dtype),
                "v": jnp.zeros((pad,), cfg.state_dtype),
            }
        if cfg.master_fp32:
            ref = _my_slice(x, plan) if plan.scatter else x.reshape(-1)
            ref = jnp.pad(ref.astype(jnp.float32), (0, pad - base))
            st["master"] = ref
        return st

    def walk(par, plan):
        if isinstance(par, dict):
            return {k: walk(par[k], plan[k]) for k in par}
        return leaf(par, plan)

    return {"step": jnp.zeros((), jnp.int32), "leaves": walk(params, plan_tree)}


def _didx():
    return jax.lax.axis_index("data")


def _my_slice(x, plan: LeafPlan):
    flat = x.reshape(-1)
    pad = plan.chunk * (-(-flat.shape[0] // plan.chunk))
    D = pad // plan.chunk
    if pad != flat.shape[0]:
        flat = jnp.pad(flat, (0, pad - flat.shape[0]))
    return jax.lax.dynamic_slice_in_dim(flat, _didx() * plan.chunk, plan.chunk)


def _unslice(slice_new, plan: LeafPlan, dtype):
    full = jax.lax.all_gather(slice_new, "data", axis=0, tiled=True)
    size = int(np.prod(plan.local_shape))
    return full[:size].reshape(plan.local_shape).astype(dtype)


def sync_gradient(g, plan: LeafPlan):
    """Partial local grad -> fully-reduced grad (full shard or ZeRO-1 slice)."""
    if plan.scatter:
        flat = g.reshape(-1).astype(jnp.float32)
        pad = plan.chunk * (-(-flat.shape[0] // plan.chunk))
        if pad != flat.shape[0]:
            flat = jnp.pad(flat, (0, pad - flat.shape[0]))
        gs = jax.lax.psum_scatter(flat, "data", scatter_dimension=0, tiled=True)
        if plan.sync_axes:
            gs = jax.lax.psum(gs, plan.sync_axes)
        return gs
    g = g.astype(jnp.float32)
    return jax.lax.psum(g, plan.sync_axes) if plan.sync_axes else g


def apply_updates(grads, params, opt_state, plan_tree, cfg: OptConfig, mesh_axes,
                  *, presynced: bool = False):
    """One AdamW step inside shard_map.  Returns (params, opt_state, metrics).

    presynced=True: `grads` are already fully reduced (e.g. by the
    error-feedback top-k compressor, repro.optim.compression)."""
    flat_plans, flat_grads, flat_params, flat_states = [], [], [], []

    def collect(g, x, st, plan):
        if isinstance(plan, dict):
            for k in plan:
                collect(g[k], x[k], st[k], plan[k])
        else:
            flat_plans.append(plan)
            flat_grads.append(g)
            flat_params.append(x)
            flat_states.append(st)

    collect(grads, params, opt_state["leaves"], plan_tree)

    if presynced:
        synced = [g.astype(jnp.float32) for g in flat_grads]
    else:
        synced = [sync_gradient(g, pl) for g, pl in zip(flat_grads, flat_plans)]

    # exact global grad norm (see module docstring)
    sq = sum(
        pl.norm_weight * jnp.sum(jnp.square(g)) for g, pl in zip(synced, flat_plans)
    )
    sq = jax.lax.psum(sq, tuple(mesh_axes))
    gnorm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))

    step = opt_state["step"]
    lr = lr_schedule(cfg, step)
    t = (step + 1).astype(jnp.float32)
    bc1 = 1.0 - cfg.beta1**t
    bc2 = 1.0 - cfg.beta2**t

    def update_flat(gp, refp, st):
        """One (possibly chunked) flat update: returns (new_ref, new_state)."""
        if cfg.int8_states:
            m = _dequantize(st["m_q"], st["m_s"], signed=True) * cfg.beta1 + (1 - cfg.beta1) * gp
            v = _dequantize(st["v_q"], st["v_s"], signed=False) * cfg.beta2 + (1 - cfg.beta2) * jnp.square(gp)
            mq, ms = _quantize(m, signed=True)
            vq, vs = _quantize(v, signed=False)
            nst = {"m_q": mq, "m_s": ms, "v_q": vq, "v_s": vs}
        else:
            m = st["m"].astype(jnp.float32) * cfg.beta1 + (1 - cfg.beta1) * gp
            v = st["v"].astype(jnp.float32) * cfg.beta2 + (1 - cfg.beta2) * jnp.square(gp)
            nst = {"m": m.astype(cfg.state_dtype), "v": v.astype(cfg.state_dtype)}
        upd = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps) + cfg.weight_decay * refp
        new_ref = refp - lr * upd
        if cfg.master_fp32:
            nst["master"] = new_ref
        return new_ref, nst

    new_params, new_states = [], []
    for g, x, st, pl in zip(synced, flat_params, flat_states, flat_plans):
        g = (g * scale).reshape(-1)
        base = g.shape[0]
        pad = _state_pad(base, cfg)
        gp = jnp.pad(g, (0, pad - base)) if pad != base else g
        if cfg.master_fp32:
            ref = st["master"]
        else:
            raw = _my_slice(x, pl) if pl.scatter else x.reshape(-1)
            ref = jnp.pad(raw.astype(jnp.float32), (0, pad - base))
        state = {k: v for k, v in st.items() if k != "master"}
        if pad > UPDATE_CHUNK and pad % UPDATE_CHUNK == 0:
            nch = pad // UPDATE_CHUNK
            sh = lambda a, n=nch: a.reshape(n, -1)  # noqa: E731
            new_ref_c, nst_c = jax.lax.map(
                lambda args: update_flat(*args),
                (sh(gp), sh(ref), jax.tree.map(sh, state)),
            )
            new_ref = new_ref_c.reshape(-1)
            nst = jax.tree.map(lambda a: a.reshape(-1), nst_c)
        else:
            new_ref, nst = update_flat(gp, ref, state)
        if cfg.master_fp32:
            nst["master"] = new_ref
        out_flat = new_ref[:base]
        if pl.scatter:
            x_new = _unslice(out_flat, pl, x.dtype)
        else:
            x_new = out_flat.reshape(pl.local_shape).astype(x.dtype)
        new_params.append(x_new)
        new_states.append(nst)

    it_p = iter(new_params)
    it_s = iter(new_states)

    def rebuild2(plan, which):
        if isinstance(plan, dict):
            return {k: rebuild2(plan[k], which) for k in plan}
        return next(it_p) if which == "p" else next(it_s)

    out_params = rebuild2(plan_tree, "p")
    out_states = rebuild2(plan_tree, "s")
    new_opt = {"step": step + 1, "leaves": out_states}
    return out_params, new_opt, {"grad_norm": gnorm, "lr": lr}
