"""Gradient compression: error-feedback top-k sparsification over 'data'.

At 1000+ nodes the cross-pod gradient reduction is the bandwidth bill.  The
classic remedy (Lin et al., Deep Gradient Compression; Karimireddy et al.,
EF-SGD) is: per leaf, send only the top-k fraction of gradient magnitude,
keep the unsent residual in a local error-feedback buffer and add it back
next step — unbiased in the long run, convergence-safe thanks to the
feedback.

Mapping onto the mesh (DESIGN.md §4): compression replaces the leaf's
'data'-axis reduction (its *replication* sync) for leaves above a size
threshold.  Each data-rank selects its local top-k (indices + values,
``1/ratio``× fewer bytes), all-gathers the sparse sets over 'data', and
scatter-adds them into a dense buffer — ``2·k·(4+4)·D`` bytes vs
``2·S·(D−1)/D`` for the dense all-reduce, a win whenever
``ratio < S/(8·k·D)``-ish; the roofline’s collective term shows the swap
(all-reduce → small all-gathers).

The 'model'-axis portions of a leaf's sync (norm weights etc.) stay dense —
they are small by construction.  ZeRO-1 and compression are mutually
exclusive per leaf (both re-implement the 'data' reduction); ``build``
resolves the precedence (compression wins for eligible leaves).

Exactness is deliberately NOT preserved (that is the point); the
convergence contract is tested in tests/test_compression.py: smoke-model
loss under 10% compression tracks the dense run, and the error-feedback
buffers stay bounded.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.spec import P, tree_map_p

from .adamw import LeafPlan, OptConfig


@dataclass(frozen=True)
class CompressionConfig:
    ratio: float = 0.01           # fraction of entries sent per step
    min_leaf_size: int = 65_536   # dense sync below this
    enabled: bool = False


def eligible(plan: LeafPlan, ccfg: CompressionConfig) -> bool:
    size = int(np.prod(plan.local_shape))
    return (
        ccfg.enabled
        and "data" in plan.sync_axes
        and not plan.scatter
        and size >= ccfg.min_leaf_size
    )


def k_for(plan: LeafPlan, ccfg: CompressionConfig) -> int:
    size = int(np.prod(plan.local_shape))
    return max(1, int(size * ccfg.ratio))


def error_spec(spec_tree, plan_tree, ccfg: CompressionConfig):
    """P tree of error-feedback buffers (zeros for ineligible leaves)."""

    def walk(spec, plan):
        if isinstance(spec, dict):
            return {k: walk(spec[k], plan[k]) for k in spec}
        if eligible(plan, ccfg):
            return P(spec.shape, spec.axes, "zeros", dtype=jnp.float32)
        return P((1,), (None,), "zeros", dtype=jnp.float32)  # placeholder

    return walk(spec_tree, plan_tree)


def init_error_state(params, plan_tree, ccfg: CompressionConfig):
    def walk(par, plan):
        if isinstance(par, dict):
            return {k: walk(par[k], plan[k]) for k in par}
        if eligible(plan, ccfg):
            return jnp.zeros(par.shape, jnp.float32)
        return jnp.zeros((1,), jnp.float32)

    return walk(params, plan_tree)


def compressed_sync(g, err, plan: LeafPlan, ccfg: CompressionConfig):
    """EF-top-k reduction over 'data' (+ dense psum over remaining axes).

    Returns (g_synced ≈ mean-preserving sum over data ranks, new_err).
    """
    other = tuple(a for a in plan.sync_axes if a != "data")
    acc = g.astype(jnp.float32) + err.astype(jnp.float32)
    flat = acc.reshape(-1)
    k = k_for(plan, ccfg)
    mag = jnp.abs(flat)
    vals, idx = jax.lax.top_k(mag, k)
    sel = jnp.zeros_like(flat, dtype=bool).at[idx].set(True)
    send_vals = flat[idx]                                   # (k,)
    new_err = jnp.where(sel, 0.0, flat).reshape(g.shape)

    # exchange sparse contributions across the data axis
    all_idx = jax.lax.all_gather(idx, "data")               # (D, k)
    all_val = jax.lax.all_gather(send_vals, "data")         # (D, k)
    dense = jnp.zeros_like(flat).at[all_idx.reshape(-1)].add(all_val.reshape(-1))
    g_sync = dense.reshape(g.shape)
    if other:
        g_sync = jax.lax.psum(g_sync, other)
    return g_sync, new_err


def sync_all(grads, err_state, plan_tree, cfg: OptConfig, ccfg: CompressionConfig):
    """Per-leaf sync: compressed where eligible, dense elsewhere.

    Returns (synced grads tree (f32), new error state tree, bytes ledger).
    """
    from .adamw import sync_gradient

    sent_dense = [0]
    sent_sparse = [0]

    def walk(g, e, plan):
        if isinstance(plan, dict):
            out = {k: walk(g[k], e[k], plan[k]) for k in plan}
            return (
                {k: v[0] for k, v in out.items()},
                {k: v[1] for k, v in out.items()},
            )
        if eligible(plan, ccfg):
            gs, ne = compressed_sync(g, e, plan, ccfg)
            sent_sparse[0] += 8 * k_for(plan, ccfg)
            return gs, ne
        size = int(np.prod(plan.local_shape))
        if "data" in plan.sync_axes or plan.scatter:
            sent_dense[0] += 4 * size
        return sync_gradient(g, plan), e

    gs, ne = walk(grads, err_state, plan_tree)
    ledger = {"sparse_bytes": sent_sparse[0], "dense_bytes": sent_dense[0]}
    return gs, ne, ledger
