"""Distributed AdamW (+ ZeRO-1) and LR schedules (shard_map-resident)."""
from .adamw import (  # noqa: F401
    LeafPlan,
    OptConfig,
    apply_updates,
    build_plan,
    init_opt_state,
    lr_schedule,
    opt_state_spec,
    sync_gradient,
)
