"""Jitted wrappers tying the Pallas kernels to PBS protocol semantics.

* ``encode_group``       — parity bitmap + bin XOR folds + BCH sketch for one
                           set (bin_xorsum kernel + gf2_matmul).
* ``encode_groups``      — the batched form over U packed units with ragged
                           element counts (padded rows + valid masks) and
                           per-unit bin seeds, binning with the protocol's
                           multiply-shift hash.  The multi-session engine's
                           fused executor (DESIGN.md §5) composes the same
                           two pieces — ``bin_parity_xorsum_units`` +
                           ``sketch_groups`` — over both sides at once.
* ``bch_decode_batched`` — fully-jitted vmapped Berlekamp–Massey + Chien
                           search over all group pairs at once (fixed 2t-trip
                           ``fori_loop``; the TPU replacement for the paper's
                           serial per-group Levinson decode — DESIGN.md §3).
* ``tow_estimate``       — ToW sketches via the tow_sketch kernel.

Everything is validated against `ref.py` / `repro.core.bch` in
tests/test_kernels.py and tests/test_recon_batch.py across shape/dtype
sweeps.  ``interpret=None`` resolves per backend (kernels/platform.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bch import BCHCode, bch_code
from repro.core.gf2m import get_field

from .bin_xorsum import bin_parity_xorsum, bin_parity_xorsum_units, xor_bits_to_u32
from .gf2_matmul import gf2_matmul
from .platform import count_retrace
from .tow_sketch import tow_sketch


def _xor_reduce(x: jax.Array, axis: int) -> jax.Array:
    return jax.lax.reduce(x, np.int32(0), jax.lax.bitwise_xor, (axis,))


def pack_bits_to_field(bits: jax.Array, m: int) -> jax.Array:
    """(..., t*m) 0/1 -> (..., t) integer field elements (LSB-first)."""
    t = bits.shape[-1] // m
    b = bits.reshape(bits.shape[:-1] + (t, m)).astype(jnp.int32)
    return jnp.sum(b << jnp.arange(m, dtype=jnp.int32), axis=-1)


def sketch_groups(bitmaps: jax.Array, code: BCHCode, *, interpret: bool | None = None):
    """BCH sketches for G parity bitmaps at once: one GF(2) matmul on the MXU."""
    P = jnp.asarray(code.field.syndrome_matrix(code.t))
    bits = gf2_matmul(bitmaps.astype(jnp.int32), P, interpret=interpret)
    return pack_bits_to_field(bits, code.m)


def sketch_groups_range(
    bitmaps: jax.Array, code: BCHCode, t0: int, *, interpret: bool | None = None
):
    """Incremental BCH syndromes S_{2*t0+1}..S_{2t-1} for G parity bitmaps.

    The same one-matmul formulation as ``sketch_groups`` against the
    ``[t0*m, t*m)`` column slice of the syndrome matrix — the prefix
    property (``core.gf2m.syndrome_matrix_range``) guarantees
    ``concat(sketch at t0, this) == sketch at t`` bit for bit, which is
    what ``MSG_PARITY`` ships on rateless recovery (DESIGN.md §16).
    """
    P = jnp.asarray(code.field.syndrome_matrix_range(t0, code.t))
    bits = gf2_matmul(bitmaps.astype(jnp.int32), P, interpret=interpret)
    return pack_bits_to_field(bits, code.m)


def encode_group(elems: jax.Array, code: BCHCode, seed: int, *, interpret: bool | None = None):
    """Full PBS encode of one group: (parity bitmap, bin XOR sums, sketch)."""
    parity, xor_bits = bin_parity_xorsum(
        elems, n_bins=code.n, seed=seed, interpret=interpret
    )
    sketch = sketch_groups(parity[None, :], code, interpret=interpret)[0]
    return parity, xor_bits_to_u32(xor_bits), sketch


def encode_groups(
    elems: jax.Array,
    valid: jax.Array,
    seeds: jax.Array,
    code: BCHCode,
    *,
    interpret: bool | None = None,
):
    """Batched PBS encode of U packed units with ragged element counts.

    ``elems``/``valid``: (U, E) padded rows (``valid == 0`` marks padding);
    ``seeds``: (U,) per-unit bin seeds.  One bin_xorsum launch bins every
    unit's elements with the protocol's multiply-shift hash, then one GF(2)
    matmul sketches all parity bitmaps (DESIGN.md §5).

    Returns (parity (U, n), xors (U, n) uint32, sketches (U, t)).
    """
    parity, xor_bits = bin_parity_xorsum_units(
        elems, valid, seeds, n_bins=code.n, interpret=interpret
    )
    sketches = sketch_groups(parity, code, interpret=interpret)
    return parity, xor_bits_to_u32(xor_bits), sketches


def tow_estimate(elems_a: jax.Array, elems_b: jax.Array, seeds: jax.Array, *, interpret=None):
    ya = tow_sketch(elems_a, seeds, ell=seeds.shape[0], interpret=interpret)
    yb = tow_sketch(elems_b, seeds, ell=seeds.shape[0], interpret=interpret)
    diff = (ya - yb).astype(jnp.float32)
    return jnp.mean(diff * diff)


# ---------------------------------------------------------------------------
# Batched BCH decode, fully in JAX (jit + vmap over group pairs)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("n", "t"))
def bch_decode_batched(sketches: jax.Array, *, n: int, t: int):
    """Decode U difference sketches -> (ok (U,), positions (U, t), count (U,)).

    positions rows are padded with -1 beyond `count`.  ok=False marks BCH
    overload (paper §3.2 -> 3-way split).  GF ops run on log/exp tables in
    int32 lanes; BM is a fixed-trip fori_loop (no data-dependent control).
    """
    count_retrace("bch_decode_batched")
    code = bch_code(n, t)
    gf = code.field
    m = code.m
    exp_t = jnp.asarray(gf.exp, dtype=jnp.int32)          # (2n,)
    log_t = jnp.asarray(np.where(gf.log < 0, 0, gf.log), dtype=jnp.int32)

    def gmul(a, b):
        prod = exp_t[(log_t[a] + log_t[b]) % n]
        return jnp.where((a == 0) | (b == 0), 0, prod)

    def ginv(a):
        return exp_t[(n - log_t[a]) % n]

    sk = sketches.astype(jnp.int32)
    U = sk.shape[0]

    # S_1..S_2t with S_2k = S_k^2
    S = jnp.zeros((U, 2 * t), jnp.int32)
    S = S.at[:, 0::2].set(sk)
    for k in range(1, t + 1):  # unrolled t steps; t is static & small
        S = S.at[:, 2 * k - 1].set(gmul(S[:, k - 1], S[:, k - 1]))

    W = 2 * t + 1
    cols = jnp.arange(W)

    def bm_step(i, state):
        C, B, L, b, mshift = state
        j = jnp.arange(1, W)
        s_idx = jnp.clip(i - j, 0, 2 * t - 1)
        gath = S[:, s_idx]                                  # (U, W-1)
        mask = (j[None, :] <= i) & (j[None, :] <= L[:, None])
        d = S[:, i] ^ _xor_reduce(jnp.where(mask, gmul(C[:, 1:], gath), 0), 1)

        nz = d != 0
        grow = nz & (2 * L <= i)
        coef = jnp.where(nz, gmul(d, ginv(jnp.where(b == 0, 1, b))), 0)
        idx = cols[None, :] - mshift[:, None]
        Bsh = jnp.where(
            idx >= 0, jnp.take_along_axis(B, jnp.clip(idx, 0, W - 1), 1), 0
        )
        Cnew = C ^ gmul(jnp.broadcast_to(coef[:, None], Bsh.shape), Bsh)

        B2 = jnp.where(grow[:, None], C, B)
        C2 = jnp.where(nz[:, None], Cnew, C)
        b2 = jnp.where(grow, d, b)
        L2 = jnp.where(grow, i + 1 - L, L)
        m2 = jnp.where(grow, 1, mshift + 1)
        return (C2, B2, L2, b2, m2)

    C0 = jnp.zeros((U, W), jnp.int32).at[:, 0].set(1)
    B0 = jnp.zeros((U, W), jnp.int32).at[:, 0].set(1)
    state = (C0, B0, jnp.zeros(U, jnp.int32), jnp.ones(U, jnp.int32), jnp.ones(U, jnp.int32))
    C, B, L, b, mshift = jax.lax.fori_loop(0, 2 * t, bm_step, state)

    # Chien search: evaluate Lambda at alpha^{-i} for all i (Horner, t+1 steps)
    ii = jnp.arange(n)
    xs = exp_t[(-ii) % n]                                    # (n,)
    acc = jnp.zeros((U, n), jnp.int32)
    for k in range(t, -1, -1):
        acc = gmul(acc, xs[None, :]) ^ C[:, k : k + 1]
    is_root = acc == 0                                       # (U, n)
    count = jnp.sum(is_root, axis=1)

    # gather root positions, padded with -1
    key = jnp.where(is_root, ii[None, :], n + 1)
    pos = jnp.sort(key, axis=1)[:, :t]
    pos = jnp.where(jnp.arange(t)[None, :] < count[:, None], pos, -1)

    # verify: recompute odd syndromes from found roots
    jj = jnp.arange(t)
    powers = (jnp.maximum(pos, 0)[:, :, None] * (2 * jj + 1)[None, None, :]) % n
    vals = jnp.where((pos >= 0)[:, :, None], exp_t[powers], 0)  # (U, t, t)
    recomputed = _xor_reduce(vals, 1)                           # (U, t)

    zero_sk = ~jnp.any(sk != 0, axis=1)
    ok = (
        (L > 0)
        & (L <= t)
        & (count == L)
        & jnp.all(recomputed == sk, axis=1)
    ) | zero_sk
    # failed or empty rows expose no positions (matches core.bch semantics)
    expose = ok & ~zero_sk
    count = jnp.where(expose, count, 0)
    pos = jnp.where(expose[:, None], pos, -1)
    return ok, pos, count


def chien_eval_matmul(locator_bits: jax.Array, code: BCHCode, *, interpret=None):
    """Whole-field locator evaluation as one GF(2) matmul (kernel path).

    locator_bits: (U, (t+1)*m) -> eval bits (U, n, m); rows of zeros = roots.
    """
    Cmat = jnp.asarray(code.field.chien_matrix(code.t))
    ev = gf2_matmul(locator_bits.astype(jnp.int32), Cmat, interpret=interpret)
    return ev.reshape(ev.shape[0], code.n, code.m)
