"""Tug-of-War sketch Pallas kernel: all ℓ sketches in one pass over the set.

Per element tile, builds the (tile × ℓ) ±1 sign matrix in-registers from the
mix32 hash family (one derived seed per sketch — the TPU hash family per
DESIGN.md §3; the ±(2d²−2d)/ℓ variance contract is validated empirically in
tests/test_kernels.py) and reduces over the tile axis into an ℓ-vector VMEM
accumulator.  Communication-free, single-pass, no scatter.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .bin_xorsum import mix32_jnp
from .platform import count_retrace, resolve_interpret


def _kernel(elems_ref, valid_ref, seeds_ref, o_ref, acc_ref, *, nt: int):
    ti = pl.program_id(0)

    @pl.when(ti == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    e = elems_ref[...].astype(jnp.uint32)  # (tile,)
    valid = valid_ref[...].astype(jnp.int32)  # (tile,)
    seeds = seeds_ref[...].astype(jnp.uint32)  # (ell,)
    # two mixing rounds keyed per sketch: h = mix32(mix32(e) ^ seed_i)
    h1 = mix32_jnp(e, 0x5EED)[:, None]  # (tile, 1)
    h = mix32_jnp(h1 ^ seeds[None, :], 0x7077)  # (tile, ell)
    signs = 1 - 2 * (h & jnp.uint32(1)).astype(jnp.int32)
    signs = signs * valid[:, None]
    acc_ref[...] += jnp.sum(signs, axis=0, keepdims=True)

    @pl.when(ti == nt - 1)
    def _emit():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("ell", "tile", "interpret"))
def tow_sketch(
    elems: jax.Array,
    seeds: jax.Array,
    valid: jax.Array | None = None,
    *,
    ell: int = 128,
    tile: int = 2048,
    interpret: bool | None = None,
) -> jax.Array:
    """ℓ ToW sketches Y_i = Σ_s f_i(s) of a uint32 key set.

    ``valid`` (optional, same shape as ``elems``) marks which entries are
    real set members: callers that pad their sets to a shape bucket — the
    warm-cache phase-0 path (DESIGN.md §12) — pass an explicit 0/1 mask so
    the jit signature depends only on the padded length, not the set size.
    Omitted, every element counts (the original exact-length behavior).
    """
    count_retrace("tow_sketch")
    interpret = resolve_interpret(interpret)
    e = elems.astype(jnp.uint32)
    E = e.shape[0]
    Ep = max(tile, ((E + tile - 1) // tile) * tile)
    pad = Ep - E
    e_p = jnp.concatenate([e, jnp.zeros(pad, jnp.uint32)])
    v = jnp.ones(E, jnp.int32) if valid is None else valid.astype(jnp.int32)
    valid = jnp.concatenate([v, jnp.zeros(pad, jnp.int32)])
    nt = Ep // tile
    out = pl.pallas_call(
        functools.partial(_kernel, nt=nt),
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((ell,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, ell), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, ell), jnp.int32),
        scratch_shapes=[pltpu.VMEM((1, ell), jnp.int32)],
        interpret=interpret,
    )(e_p, valid, seeds.astype(jnp.uint32))
    return out[0]
