"""Hash-partition + parity bitmap + per-bin XOR fold, as one Pallas kernel.

The CPU algorithm scatters each element into its hash bin (sequential memory
chaos); the TPU formulation (DESIGN.md §3) makes it dense algebra: for an
element tile E, with H = one_hot(bin(E)) ∈ {0,1}^(tile × n) and
bits(E) ∈ {0,1}^(tile × 33) (32 key bits ‖ ones column for counting),

    acc(n × 33) += Hᵀ @ bits(E)        — one MXU matmul per tile,

then `acc & 1` yields per-bin XOR folds (bit-parity == XOR) and the parity
bitmap (count parity) in one shot.  The grid walks element tiles; `acc`
lives in VMEM scratch for the whole pass.

Two binning reductions are provided (both keyed by murmur-finalizer mix32):

* ``bin_parity_xorsum`` (single set) reduces with `mod n` — the historical
  kernel hash, mirrored by `ref.bin_parity_xorsum_ref`;
* ``bin_parity_xorsum_units`` (the batched multi-session path, DESIGN.md §5)
  reduces with the same multiply-shift `(h * n) >> 32` as
  `repro.core.hashing.hash_to_range`, so the kernel bins bit-for-bit like the
  numpy protocol.  The 64-bit product is synthesized from 16-bit halves
  (`mulshift_bins`) because TPU lanes are 32-bit; exact for any n < 2^16,
  which covers every field this repo instantiates (m ≤ 14).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .platform import ceil_to, resolve_interpret


def mix32_jnp(x: jax.Array, seed) -> jax.Array:
    """murmur3 fmix32 (uint32 lanes, wrap-around multiplies) — VPU-only ops.

    ``seed`` may be a python int or a traced scalar (per-unit seeds).
    """
    x = x.astype(jnp.uint32)
    x = x + (jnp.asarray(seed, dtype=jnp.uint32) * jnp.uint32(0x9E3779B9))
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> 16)
    return x


def mulshift_bins(h: jax.Array, size: int) -> jax.Array:
    """Bias-free range reduction ``(h * size) >> 32`` in 32-bit lanes.

    Splits h into 16-bit halves so every partial product stays below 2^32;
    exact match of ``core.hashing.hash_to_range`` for size < 2^16.
    """
    assert size < (1 << 16), size
    lo = h & jnp.uint32(0xFFFF)
    hi = h >> jnp.uint32(16)
    sz = jnp.uint32(size)
    return ((hi * sz + ((lo * sz) >> jnp.uint32(16))) >> jnp.uint32(16)).astype(jnp.int32)


def _kernel(elems_ref, valid_ref, o_ref, acc_ref, *, n_bins: int, seed: int, nt: int):
    ti = pl.program_id(0)

    @pl.when(ti == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    e = elems_ref[...].astype(jnp.uint32)  # (tile,)
    valid = valid_ref[...] > 0
    h = mix32_jnp(e, seed)
    bins = (h % jnp.uint32(n_bins)).astype(jnp.int32)
    # one-hot dispatch matrix (tile, n) and bit matrix (tile, 33)
    onehot = (
        (bins[:, None] == jax.lax.broadcasted_iota(jnp.int32, (1, n_bins), 1))
        & valid[:, None]
    ).astype(jnp.int32)
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (1, 32), 1)
    bits = ((e[:, None] >> shifts) & jnp.uint32(1)).astype(jnp.int32)
    bits = jnp.concatenate([bits, valid[:, None].astype(jnp.int32)], axis=1)  # ‖ ones
    acc_ref[...] += jnp.dot(onehot.T, bits, preferred_element_type=jnp.int32)

    @pl.when(ti == nt - 1)
    def _emit():
        o_ref[...] = acc_ref[...] & 1


@functools.partial(jax.jit, static_argnames=("n_bins", "seed", "tile", "interpret"))
def bin_parity_xorsum(
    elems: jax.Array,
    *,
    n_bins: int,
    seed: int,
    tile: int = 1024,
    interpret: bool | None = None,
):
    """Returns (parity_bitmap (n,), xor_bits (n, 32)) for a set of uint32 keys."""
    interpret = resolve_interpret(interpret)
    e = elems.astype(jnp.uint32)
    E = e.shape[0]
    Ep = max(tile, ((E + tile - 1) // tile) * tile)
    pad = Ep - E
    e_p = jnp.concatenate([e, jnp.zeros(pad, jnp.uint32)])
    valid = jnp.concatenate([jnp.ones(E, jnp.int32), jnp.zeros(pad, jnp.int32)])
    nt = Ep // tile
    out = pl.pallas_call(
        functools.partial(_kernel, n_bins=n_bins, seed=seed, nt=nt),
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((n_bins, 33), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_bins, 33), jnp.int32),
        scratch_shapes=[pltpu.VMEM((n_bins, 33), jnp.int32)],
        interpret=interpret,
    )(e_p, valid)
    parity = out[:, 32]
    xor_bits = out[:, :32]
    return parity, xor_bits


def _units_kernel(seeds_ref, elems_ref, valid_ref, o_ref, acc_ref, *, n_bins: int, nt: int):
    """Grid (U, nt): per unit u, walk its element tiles accumulating Hᵀ @ bits."""
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    e = elems_ref[...][0].astype(jnp.uint32)   # (tile,)
    valid = valid_ref[...][0] > 0
    seed = seeds_ref[...][0]                   # this unit's per-round bin seed
    bins = mulshift_bins(mix32_jnp(e, seed), n_bins)
    onehot = (
        (bins[:, None] == jax.lax.broadcasted_iota(jnp.int32, (1, n_bins), 1))
        & valid[:, None]
    ).astype(jnp.int32)
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (1, 32), 1)
    bits = ((e[:, None] >> shifts) & jnp.uint32(1)).astype(jnp.int32)
    bits = jnp.concatenate([bits, valid[:, None].astype(jnp.int32)], axis=1)  # ‖ ones
    acc_ref[...] += jnp.dot(onehot.T, bits, preferred_element_type=jnp.int32)

    @pl.when(ti == nt - 1)
    def _emit():
        o_ref[...] = (acc_ref[...] & 1)[None]


@functools.partial(jax.jit, static_argnames=("n_bins", "tile", "interpret"))
def bin_parity_xorsum_units(
    elems: jax.Array,
    valid: jax.Array,
    seeds: jax.Array,
    *,
    n_bins: int,
    tile: int | None = None,
    interpret: bool | None = None,
):
    """Batched bin/parity/XOR-fold over U packed units in one kernel launch.

    ``elems``/``valid``: (U, E) padded unit rows (valid == 0 marks padding);
    ``seeds``: (U,) uint32 per-unit binning seeds (sessions derive different
    seeds, so units of many sessions pack into one launch — DESIGN.md §5).
    Bins with the protocol's multiply-shift hash (``hash_to_range``).
    Returns (parity (U, n_bins) int32, xor_bits (U, n_bins, 32) int32).
    """
    interpret = resolve_interpret(interpret)
    e = elems.astype(jnp.uint32)
    U, E = e.shape
    if tile is None:  # smallest lane-aligned tile covering typical unit loads
        tile = max(128, min(1024, ceil_to(E, 128)))
    Ep = max(tile, ceil_to(E, tile))
    pad = Ep - E
    e_p = jnp.pad(e, ((0, 0), (0, pad)))
    v_p = jnp.pad(valid.astype(jnp.int32), ((0, 0), (0, pad)))
    nt = Ep // tile
    out = pl.pallas_call(
        functools.partial(_units_kernel, n_bins=n_bins, nt=nt),
        grid=(U, nt),
        in_specs=[
            pl.BlockSpec((1,), lambda u, i: (u,)),
            pl.BlockSpec((1, tile), lambda u, i: (u, i)),
            pl.BlockSpec((1, tile), lambda u, i: (u, i)),
        ],
        out_specs=pl.BlockSpec((1, n_bins, 33), lambda u, i: (u, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((U, n_bins, 33), jnp.int32),
        scratch_shapes=[pltpu.VMEM((n_bins, 33), jnp.int32)],
        interpret=interpret,
    )(seeds.astype(jnp.uint32), e_p, v_p)
    return out[:, :, 32], out[:, :, :32]


def xor_bits_to_u32(xor_bits: jax.Array) -> jax.Array:
    """(..., 32) 0/1 bit planes -> (...,) uint32 XOR-fold values."""
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(xor_bits.astype(jnp.uint32) << shifts, axis=-1, dtype=jnp.uint32)
