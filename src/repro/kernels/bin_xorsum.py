"""Hash-partition + parity bitmap + per-bin XOR fold, as one Pallas kernel.

The CPU algorithm scatters each element into its hash bin (sequential memory
chaos); the TPU formulation (DESIGN.md §3) makes it dense algebra: for an
element tile E, with H = one_hot(bin(E)) ∈ {0,1}^(tile × n) and
bits(E) ∈ {0,1}^(tile × 33) (32 key bits ‖ ones column for counting),

    acc(n × 33) += Hᵀ @ bits(E)        — one MXU matmul per tile,

then `acc & 1` yields per-bin XOR folds (bit-parity == XOR) and the parity
bitmap (count parity) in one shot.  The grid walks element tiles; `acc`
lives in VMEM scratch for the whole pass.

Binning uses murmur-finalizer mix32 followed by `mod n` (n = 2^m − 1, so a
multiply-shift range reduction would need 64-bit lanes; `mod` stays in
32-bit).  `ref.py` mirrors the exact same hash so kernel ≡ oracle bit-for-bit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def mix32_jnp(x: jax.Array, seed) -> jax.Array:
    """murmur3 fmix32 (uint32 lanes, wrap-around multiplies) — VPU-only ops."""
    x = x.astype(jnp.uint32)
    x = x + (jnp.uint32(seed) * jnp.uint32(0x9E3779B9))
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> 16)
    return x


def _kernel(elems_ref, valid_ref, o_ref, acc_ref, *, n_bins: int, seed: int, nt: int):
    ti = pl.program_id(0)

    @pl.when(ti == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    e = elems_ref[...].astype(jnp.uint32)  # (tile,)
    valid = valid_ref[...] > 0
    h = mix32_jnp(e, seed)
    bins = (h % jnp.uint32(n_bins)).astype(jnp.int32)
    # one-hot dispatch matrix (tile, n) and bit matrix (tile, 33)
    onehot = (
        (bins[:, None] == jax.lax.broadcasted_iota(jnp.int32, (1, n_bins), 1))
        & valid[:, None]
    ).astype(jnp.int32)
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (1, 32), 1)
    bits = ((e[:, None] >> shifts) & jnp.uint32(1)).astype(jnp.int32)
    bits = jnp.concatenate([bits, valid[:, None].astype(jnp.int32)], axis=1)  # ‖ ones
    acc_ref[...] += jnp.dot(onehot.T, bits, preferred_element_type=jnp.int32)

    @pl.when(ti == nt - 1)
    def _emit():
        o_ref[...] = acc_ref[...] & 1


@functools.partial(jax.jit, static_argnames=("n_bins", "seed", "tile", "interpret"))
def bin_parity_xorsum(
    elems: jax.Array,
    *,
    n_bins: int,
    seed: int,
    tile: int = 1024,
    interpret: bool = True,
):
    """Returns (parity_bitmap (n,), xor_bits (n, 32)) for a set of uint32 keys."""
    e = elems.astype(jnp.uint32)
    E = e.shape[0]
    Ep = max(tile, ((E + tile - 1) // tile) * tile)
    pad = Ep - E
    e_p = jnp.concatenate([e, jnp.zeros(pad, jnp.uint32)])
    valid = jnp.concatenate([jnp.ones(E, jnp.int32), jnp.zeros(pad, jnp.int32)])
    nt = Ep // tile
    out = pl.pallas_call(
        functools.partial(_kernel, n_bins=n_bins, seed=seed, nt=nt),
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((n_bins, 33), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_bins, 33), jnp.int32),
        scratch_shapes=[pltpu.VMEM((n_bins, 33), jnp.int32)],
        interpret=interpret,
    )(e_p, valid)
    parity = out[:, 32]
    xor_bits = out[:, :32]
    return parity, xor_bits


def xor_bits_to_u32(xor_bits: jax.Array) -> jax.Array:
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(
        xor_bits.astype(jnp.uint32) << shifts[None, :], axis=1, dtype=jnp.uint32
    )
