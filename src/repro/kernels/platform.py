"""Backend-derived execution defaults for the Pallas kernel layer.

Every kernel entry point takes ``interpret: bool | None = None``.  ``None``
resolves from the JAX backend at trace time: off-TPU (CPU/GPU) the kernel
body runs under the Pallas interpreter — bit-exact dataflow validation on
any host — while on TPU it compiles for the MXU/VPU.  Passing an explicit
bool still pins the mode (the kernel tests pin ``interpret=True`` shapes).
"""
from __future__ import annotations

import jax


def resolve_interpret(flag: bool | None = None) -> bool:
    if flag is None:
        return jax.default_backend() != "tpu"
    return bool(flag)


def ceil_to(x: int, mult: int) -> int:
    """Round ``x`` up to a multiple of ``mult`` (block/lane alignment)."""
    return ((x + mult - 1) // mult) * mult


def pow2_bucket(x: int, floor: int) -> int:
    """Round ``x`` up to a power of two, never below ``floor``.

    Shape bucketing for the serving loop (DESIGN.md §5): padding every
    dynamic dimension to a power of two above its hardware alignment bounds
    the set of compiled executor variants to O(log) per dimension instead of
    one per distinct workload size.
    """
    v = max(int(x), 1, floor)
    return 1 << (v - 1).bit_length()
