"""Backend-derived execution defaults for the Pallas kernel layer.

Every kernel entry point takes ``interpret: bool | None = None``.  ``None``
resolves from the JAX backend at trace time: off-TPU (CPU/GPU) the kernel
body runs under the Pallas interpreter — bit-exact dataflow validation on
any host — while on TPU it compiles for the MXU/VPU.  Passing an explicit
bool still pins the mode (the kernel tests pin ``interpret=True`` shapes).

This module also hosts the **retrace ledger** (DESIGN.md §12): every jitted
entry point of the serving stack calls ``count_retrace(name)`` from inside
its traced Python body.  A jit body only executes when JAX traces a new
(shape, static-arg) signature, so the counter is an exact census of
compilations — the serving loops diff it across a run and publish the delta
as ``stats["retraces"]``, turning "the shape buckets held" from a hope into
an assertable number.  ``enable_persistent_cache`` additionally wires JAX's
on-disk compilation cache so re-traced signatures at least skip XLA
compilation across processes.
"""
from __future__ import annotations

import os
import tempfile

import jax

_RETRACES: dict = {"total": 0, "by_fn": {}}


def count_retrace(name: str) -> None:
    """Record one trace of jitted entry point ``name``.

    Call this from *inside* the function handed to ``jax.jit`` — the body
    runs once per cache-missing signature, never on a cache hit — guarded
    so an eager (un-jitted) call of the same body does not count.
    """
    _RETRACES["total"] += 1
    _RETRACES["by_fn"][name] = _RETRACES["by_fn"].get(name, 0) + 1


def retrace_count() -> int:
    """Monotone total of jit traces so far; diff two reads to attribute
    traces to one run (the ``stats["retraces"]`` mechanism)."""
    return _RETRACES["total"]


def retrace_counts() -> dict:
    """Per-entry-point trace totals (diagnostic view of the same ledger)."""
    return dict(_RETRACES["by_fn"])


_CACHE_DIR: str | None = None


def enable_persistent_cache(path: str | None = None) -> str | None:
    """Point JAX's persistent compilation cache at an on-disk directory.

    Idempotent and best-effort: the first call wires the cache (default
    location under the system temp dir, overridable via ``path`` or the
    ``REPRO_JAX_CACHE_DIR`` env var; set the env var to ``off`` to disable),
    later calls return the already-wired directory.  Backends that do not
    support the cache simply ignore it — retrace *avoidance* comes from the
    pow2 shape buckets, the cache only de-duplicates XLA compilation time
    across processes.  Returns the cache dir, or None when disabled.
    """
    global _CACHE_DIR
    if _CACHE_DIR is not None:
        return _CACHE_DIR
    if path is None:
        path = os.environ.get("REPRO_JAX_CACHE_DIR")
    if path is not None and path.lower() in ("", "0", "off", "disable"):
        return None
    if path is None:
        path = os.path.join(tempfile.gettempdir(), "repro-pbs-jax-cache")
    try:
        os.makedirs(path, exist_ok=True)
        jax.config.update("jax_compilation_cache_dir", path)
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
        jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    except Exception:           # unsupported backend/config: shape buckets
        return None             # still bound compiles, so just carry on
    _CACHE_DIR = path
    return path


def resolve_interpret(flag: bool | None = None) -> bool:
    if flag is None:
        return jax.default_backend() != "tpu"
    return bool(flag)


def ceil_to(x: int, mult: int) -> int:
    """Round ``x`` up to a multiple of ``mult`` (block/lane alignment)."""
    return ((x + mult - 1) // mult) * mult


def pow2_bucket(x: int, floor: int) -> int:
    """Round ``x`` up to a power of two, never below ``floor``.

    Shape bucketing for the serving loop (DESIGN.md §5): padding every
    dynamic dimension to a power of two above its hardware alignment bounds
    the set of compiled executor variants to O(log) per dimension instead of
    one per distinct workload size.
    """
    v = max(int(x), 1, floor)
    return 1 << (v - 1).bit_length()
