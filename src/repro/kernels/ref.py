"""Pure-jnp/numpy oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def gf2_matmul_ref(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Exact GF(2) product via int64 matmul then mod 2."""
    return (np.asarray(a, dtype=np.int64) @ np.asarray(b, dtype=np.int64)) % 2


def mix32_ref(x: np.ndarray, seed: int) -> np.ndarray:
    x = np.asarray(x, dtype=np.uint32).copy()
    x += np.uint32((int(seed) * 0x9E3779B9) & 0xFFFFFFFF)
    x ^= x >> np.uint32(16)
    x *= np.uint32(0x85EBCA6B)
    x ^= x >> np.uint32(13)
    x *= np.uint32(0xC2B2AE35)
    x ^= x >> np.uint32(16)
    return x


def bin_parity_xorsum_ref(elems: np.ndarray, n_bins: int, seed: int):
    """Sequential-scatter oracle for the bin_xorsum kernel (same mod-n hash)."""
    e = np.asarray(elems, dtype=np.uint32)
    bins = (mix32_ref(e, seed) % np.uint32(n_bins)).astype(np.int64)
    counts = np.zeros(n_bins, dtype=np.int64)
    np.add.at(counts, bins, 1)
    xors = np.zeros(n_bins, dtype=np.uint32)
    np.bitwise_xor.at(xors, bins, e)
    parity = (counts & 1).astype(np.int32)
    xor_bits = ((xors[:, None] >> np.arange(32, dtype=np.uint32)) & 1).astype(np.int32)
    return parity, xor_bits, xors


def bin_parity_xorsum_units_ref(elems, valid, seeds, n_bins: int):
    """Sequential-scatter oracle for the batched units kernel.

    Bins with the protocol's multiply-shift hash ``(mix32(e) * n) >> 32``
    (``core.hashing.hash_to_range``), evaluated in uint64 as ground truth for
    the kernel's 16-bit-split formulation.
    """
    e = np.asarray(elems, dtype=np.uint32)
    v = np.asarray(valid) != 0
    U, _ = e.shape
    parity = np.zeros((U, n_bins), dtype=np.int32)
    xors = np.zeros((U, n_bins), dtype=np.uint32)
    for u in range(U):
        vals = e[u][v[u]]
        h = mix32_ref(vals, int(seeds[u]))
        bins = ((h.astype(np.uint64) * np.uint64(n_bins)) >> np.uint64(32)).astype(np.int64)
        counts = np.zeros(n_bins, dtype=np.int64)
        np.add.at(counts, bins, 1)
        np.bitwise_xor.at(xors[u], bins, vals)
        parity[u] = (counts & 1).astype(np.int32)
    return parity, xors


def tow_sketch_ref(elems: np.ndarray, seeds: np.ndarray) -> np.ndarray:
    """Oracle for the ToW kernel's two-round mix family."""
    e = np.asarray(elems, dtype=np.uint32)
    h1 = mix32_ref(e, 0x5EED)[:, None]
    h = mix32_ref(h1 ^ np.asarray(seeds, dtype=np.uint32)[None, :], 0x7077)
    signs = 1 - 2 * (h & np.uint32(1)).astype(np.int64)
    return signs.sum(axis=0).astype(np.int32)
