"""Pallas kernel layer for the PBS hot loops (DESIGN.md §3).

One module per kernel (+ ``ops.py`` protocol-level wrappers, ``ref.py``
pure-numpy oracles).  ``interpret=None`` everywhere resolves per backend:
interpreter off-TPU, compiled on TPU (see ``platform.resolve_interpret``).
"""
from .bin_xorsum import bin_parity_xorsum, bin_parity_xorsum_units, xor_bits_to_u32
from .gf2_matmul import gf2_matmul
from .ops import (
    bch_decode_batched,
    encode_group,
    encode_groups,
    sketch_groups,
    tow_estimate,
)
from .platform import resolve_interpret
from .tow_sketch import tow_sketch
from .tree_digest import tree_digest

__all__ = [
    "bch_decode_batched",
    "bin_parity_xorsum",
    "bin_parity_xorsum_units",
    "encode_group",
    "encode_groups",
    "gf2_matmul",
    "resolve_interpret",
    "sketch_groups",
    "tow_estimate",
    "tow_sketch",
    "tree_digest",
    "xor_bits_to_u32",
]
