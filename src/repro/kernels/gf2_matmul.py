"""GF(2) dense matmul Pallas kernel — the MXU workhorse for PBS coding.

C = (A @ B) mod 2 with 0/1 int32 operands.  This single kernel implements
both BCH hot loops after the DESIGN.md §3 reformulation:

* **syndromes**:  sketches = (parity_bitmaps @ syndrome_matrix) mod 2
  with A = (groups, n) bitmaps, B = (n, t*m) precomputed powers-of-alpha bits;
* **Chien search**: evals = (locator_bits @ chien_matrix) mod 2
  with A = (groups, (t+1)*m), B = ((t+1)*m, n*m).

Integer accumulation is exact (counts ≤ K < 2^31), so a single `& 1` after
the k loop gives the GF(2) product.  On a real TPU the operands are int8 with
int32 MXU accumulation; interpret mode validates the same dataflow on CPU.
Block shapes are hardware-aligned (lane dim multiples of 128, sublane of 8);
the K (reduction) grid axis is innermost so each (i, j) output tile
accumulates in a VMEM scratch across sequential k steps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .platform import ceil_to as _ceil_to
from .platform import resolve_interpret


def _kernel(a_ref, b_ref, o_ref, acc_ref, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.int32)

    @pl.when(k == nk - 1)
    def _emit():
        o_ref[...] = acc_ref[...] & 1  # sum mod 2 == XOR accumulation


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "interpret")
)
def gf2_matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    bm: int = 128,
    bn: int = 256,
    bk: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """(A @ B) mod 2 for 0/1 int32 matrices of any shape (padded internally)."""
    interpret = resolve_interpret(interpret)
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    # clamp block sizes to (padded) problem dims, keeping HW alignment
    bm_ = min(bm, _ceil_to(m, 8))
    bn_ = min(bn, _ceil_to(n, 128))
    bk_ = min(bk, _ceil_to(k, 128))
    mp, np_, kp = _ceil_to(m, bm_), _ceil_to(n, bn_), _ceil_to(k, bk_)
    a_p = jnp.zeros((mp, kp), jnp.int32).at[:m, :k].set(a.astype(jnp.int32))
    b_p = jnp.zeros((kp, np_), jnp.int32).at[:k, :n].set(b.astype(jnp.int32))
    nk = kp // bk_
    out = pl.pallas_call(
        functools.partial(_kernel, nk=nk),
        grid=(mp // bm_, np_ // bn_, nk),
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk_, bn_), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.int32)],
        interpret=interpret,
    )(a_p, b_p)
    return out[:m, :n]
