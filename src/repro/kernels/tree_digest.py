"""Batched per-range ToW digest Pallas kernel for the tree front end (§15).

One launch digests a whole tree-level frontier: the caller packs each
range's elements into one row of a padded ``(R, E)`` matrix with a 0/1
valid mask, and the kernel emits the ``(R, ell)`` sketch matrix — the
``tow_sketch`` accumulator pattern lifted to a 2-D grid ``(R, E/tile)``
where the element axis iterates fastest, so each range's VMEM accumulator
is initialized at its first tile and emitted at its last before the grid
advances to the next range.  Same hash family as phase 0
(``mix32(mix32(e, 0x5EED) ^ seed, 0x7077)``), so a single-range frontier
reproduces ``tow_sketch`` exactly; the host oracle lives in
``repro.tree.partition.level_digests_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .bin_xorsum import mix32_jnp
from .platform import count_retrace, resolve_interpret


def _kernel(elems_ref, valid_ref, seeds_ref, o_ref, acc_ref, *, nt: int):
    ti = pl.program_id(1)  # element-tile axis: minor, iterates fastest

    @pl.when(ti == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    e = elems_ref[...].reshape(-1).astype(jnp.uint32)  # (tile,)
    valid = valid_ref[...].reshape(-1).astype(jnp.int32)  # (tile,)
    seeds = seeds_ref[...].astype(jnp.uint32)  # (ell,)
    h1 = mix32_jnp(e, 0x5EED)[:, None]  # (tile, 1)
    h = mix32_jnp(h1 ^ seeds[None, :], 0x7077)  # (tile, ell)
    signs = 1 - 2 * (h & jnp.uint32(1)).astype(jnp.int32)
    signs = signs * valid[:, None]
    acc_ref[...] += jnp.sum(signs, axis=0, keepdims=True)

    @pl.when(ti == nt - 1)
    def _emit():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("ell", "tile", "interpret"))
def tree_digest(
    elems: jax.Array,
    valid: jax.Array,
    seeds: jax.Array,
    *,
    ell: int = 32,
    tile: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Per-range ToW sketches: ``(R, E)`` padded rows -> ``(R, ell)``.

    ``elems``/``valid`` must already be padded to the caller's shape
    buckets (``pow2_bucket`` rows and row length, DESIGN.md §12) so the jit
    signature depends only on the bucket, never the frontier; rows narrower
    than ``tile`` are padded up to one tile here.
    """
    count_retrace("tree_digest")
    interpret = resolve_interpret(interpret)
    e = elems.astype(jnp.uint32)
    R, E = e.shape
    Ep = max(tile, ((E + tile - 1) // tile) * tile)
    pad = Ep - E
    if pad:
        e = jnp.pad(e, ((0, 0), (0, pad)))
        valid = jnp.pad(valid.astype(jnp.int32), ((0, 0), (0, pad)))
    nt = Ep // tile
    out = pl.pallas_call(
        functools.partial(_kernel, nt=nt),
        grid=(R, nt),
        in_specs=[
            pl.BlockSpec((1, tile), lambda r, i: (r, i)),
            pl.BlockSpec((1, tile), lambda r, i: (r, i)),
            pl.BlockSpec((ell,), lambda r, i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, ell), lambda r, i: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((R, ell), jnp.int32),
        scratch_shapes=[pltpu.VMEM((1, ell), jnp.int32)],
        interpret=interpret,
    )(e, valid.astype(jnp.int32), seeds.astype(jnp.uint32))
    return out
