"""Hash families for PBS.

The paper uses xxHash; on TPU we use the murmur3/splitmix finalizer family
(multiply-xorshift), which vectorizes to pure 32-bit VPU ops (DESIGN.md §3).
Every protocol round r and purpose (grouping / binning / checksum / ToW) draws
an independent function via distinct derived seeds.
"""
from __future__ import annotations

import numpy as np

_GOLDEN = np.uint32(0x9E3779B9)
_C1 = np.uint32(0x85EBCA6B)
_C2 = np.uint32(0xC2B2AE35)

# Mersenne prime for the 4-wise independent polynomial hash (ToW).
MERSENNE_P = (1 << 31) - 1


def mix32(x: np.ndarray, seed: int) -> np.ndarray:
    """murmur3 fmix32 with additive seeding; vectorized uint32 -> uint32."""
    x = np.asarray(x, dtype=np.uint32).copy()
    x += np.uint32((int(seed) * 0x9E3779B9) & 0xFFFFFFFF)
    x ^= x >> np.uint32(16)
    x *= _C1
    x ^= x >> np.uint32(13)
    x *= _C2
    x ^= x >> np.uint32(16)
    return x


def derive_seed(master: int, *streams: int) -> int:
    """Derive an independent child seed from (master, stream ids)."""
    s = np.uint32(master)
    for st in streams:
        s = mix32(np.uint32(st), int(s))
    return int(s)


def mix32_seeded(x: np.ndarray, seeds: np.ndarray) -> np.ndarray:
    """``mix32`` with a *per-element* seed array (same wrap-around uint32
    arithmetic, so element i equals ``mix32(x[i], int(seeds[i]))`` exactly).

    This is what lets the batched planner (DESIGN.md §12) evaluate S
    sessions' independently-seeded hash functions in one numpy pass instead
    of S scalar calls."""
    x = np.asarray(x, dtype=np.uint32) + np.asarray(seeds, dtype=np.uint32) * _GOLDEN
    x ^= x >> np.uint32(16)
    x *= _C1
    x ^= x >> np.uint32(13)
    x *= _C2
    x ^= x >> np.uint32(16)
    return x


def derive_seed_seeded(masters: np.ndarray, *stream_cols: np.ndarray) -> np.ndarray:
    """Vectorized ``derive_seed``: chain ``mix32_seeded`` over per-element
    stream columns.  ``derive_seed_seeded(m, s1, s2)[i] ==
    derive_seed(int(m[i]), int(s1[i]), int(s2[i]))`` by construction."""
    s = np.asarray(masters, dtype=np.uint32)
    for col in stream_cols:
        s = mix32_seeded(np.asarray(col, dtype=np.uint32), s)
    return s


def hash_to_range_seeded(
    x: np.ndarray, sizes: np.ndarray, seeds: np.ndarray
) -> np.ndarray:
    """Vectorized ``hash_to_range`` with per-element range sizes and seeds:
    the multiply-shift reduction ``(mix32(x, seed) * size) >> 32`` element
    by element — exact match of the scalar form for every element."""
    h = mix32_seeded(x, seeds)
    return (
        (h.astype(np.uint64) * np.asarray(sizes, dtype=np.uint64)) >> np.uint64(32)
    ).astype(np.int64)


def hash_to_range(x: np.ndarray, size: int, seed: int) -> np.ndarray:
    """Uniform hash of uint32 keys into [0, size) (size need not be a power of 2)."""
    h = mix32(x, seed)
    # multiply-shift style range reduction: (h * size) >> 32, bias-free enough
    # for our sizes and avoids the slight mod bias.
    return ((h.astype(np.uint64) * np.uint64(size)) >> np.uint64(32)).astype(np.int64)


def hash_to_pm1(x: np.ndarray, seed: int) -> np.ndarray:
    """2-universal ±1 hash (not used by ToW — see poly4_pm1)."""
    return 1 - 2 * (mix32(x, seed) & np.uint32(1)).astype(np.int64)


def poly4_coeffs(seed: int) -> np.ndarray:
    """Four coefficients in [1, p) for the 4-wise independent polynomial hash."""
    c = mix32(np.arange(4, dtype=np.uint32), seed).astype(np.uint64) % np.uint64(MERSENNE_P)
    return np.maximum(c, np.uint64(1))


def poly4_pm1(x: np.ndarray, coeffs: np.ndarray) -> np.ndarray:
    """4-wise independent hash U -> {+1, -1} via degree-3 polynomial mod p.

    All arithmetic stays in uint64: operands are < 2^31 so products fit.
    """
    x = np.asarray(x, dtype=np.uint64) % np.uint64(MERSENNE_P)
    acc = np.zeros_like(x)
    for c in coeffs:  # Horner
        acc = (acc * x + np.uint64(c)) % np.uint64(MERSENNE_P)
    return 1 - 2 * (acc & np.uint64(1)).astype(np.int64)
