"""The paper's analytical framework (§4, §5, App. D/E/F/G/H).

* ``transition_matrix(n, t)`` — the Markov chain over the number of "bad balls"
  (unreconciled distinct elements) in one group, computed with the App. E
  dynamic program over sub-states (i, j, k) in O(t^3).
* ``success_prob(x, r)`` — Pr[x ⇝ 0 within r rounds] = (M^r)(x, 0).
* ``alpha(n, t, d, g, r)`` — per-group success prob under X ~ Binomial(d, 1/g),
  truncated at x ≤ t (App. F's deliberate slight underestimate).
* ``overall_lower_bound`` — 1 − 2(1 − alpha^g)  (App. F, via [29] Cor 5.11).
* ``optimize_parameters`` — §5.1: minimize (t + delta)·log2(n) s.t. bound ≥ p0.
* ``expected_round_fractions`` — §5.3 / App. G piecewise-reconciliability.
"""
from __future__ import annotations

import functools
import math

import numpy as np

N_CHOICES = (63, 127, 255, 511, 1023, 2047)


@functools.lru_cache(maxsize=None)
def _mtilde(n: int, t: int) -> np.ndarray:
    """M~(i, j, k): throwing i balls into n bins leaves j bad balls in k bad bins.

    App. E recurrence, rendered "in slow motion" one ball at a time:
      M~(i,j,k) = (i-j+1)/n * M~(i-1, j-2, k-1)     # ball joins a good-ball bin
                +  k/n      * M~(i-1, j-1, k)       # ball joins a bad bin
                + (1 - (i-1-j+k)/n) * M~(i-1, j, k) # ball lands in an empty bin
    """
    size = t + 1
    Mt = np.zeros((size + 1, size + 1, size + 1), dtype=np.float64)
    Mt[0, 0, 0] = 1.0
    for i in range(1, size + 1):
        for j in range(0, i + 1):
            for k in range(0, j // 2 + 1):
                acc = 0.0
                # joins a bin holding exactly one good ball; good balls = (i-1)-(j-2)
                if j >= 2 and k >= 1 and (i - j + 1) > 0:
                    acc += (i - j + 1) / n * Mt[i - 1, j - 2, k - 1]
                # joins one of the k existing bad bins
                if k >= 1 and j >= 1:
                    acc += k / n * Mt[i - 1, j - 1, k]
                # lands in an empty bin: empty = n - ((i-1-j) good bins + k bad bins)
                empt = 1.0 - (i - 1 - j + k) / n
                if empt > 0:
                    acc += empt * Mt[i - 1, j, k]
                Mt[i, j, k] = acc
    return Mt


@functools.lru_cache(maxsize=None)
def transition_matrix(n: int, t: int) -> np.ndarray:
    """M(i, j) = Pr[i bad balls thrown -> j remain bad], i, j in [0, t]."""
    Mt = _mtilde(n, t)
    M = Mt[: t + 1, : t + 1].sum(axis=2)
    # rows must be stochastic (within fp error) — the DP covers all j <= i
    np.testing.assert_allclose(M.sum(axis=1), 1.0, atol=1e-9)
    return M


@functools.lru_cache(maxsize=None)
def _matrix_power(n: int, t: int, r: int) -> np.ndarray:
    return np.linalg.matrix_power(transition_matrix(n, t), r)


def success_prob(n: int, t: int, x: int, r: int) -> float:
    """Pr[x ⇝ 0 within r rounds] (Eq. 2).  x > t -> 0 by App. D convention."""
    if x == 0:
        return 1.0
    if x > t:
        return 0.0
    return float(_matrix_power(n, t, r)[x, 0])


@functools.lru_cache(maxsize=None)
def success_prob_with_split(n: int, t: int, x: int, r: int) -> float:
    """Pr[x ⇝ 0 within r rounds], modeling the §3.2 3-way split for x > t.

    The paper's App. D sets Pr = 0 for x > t ("to our disadvantage") but its
    own Table 1 is inconsistent with that convention at small t (see
    EXPERIMENTS.md §Paper-validation).  This variant models the documented
    recovery mechanism instead: a BCH decoding failure consumes the round and
    hash-partitions the group 3 ways; each sub-group (Multinomial(x, 1/3))
    reconciles independently in the remaining r-1 rounds, recursively.
    """
    if x == 0:
        return 1.0
    if r <= 0:
        return 0.0
    if x <= t:
        return float(_matrix_power(n, t, r)[x, 0])
    if r == 1:
        return 0.0
    tot = 0.0
    log3 = math.log(3.0)
    for y1 in range(x + 1):
        p1 = success_prob_with_split(n, t, y1, r - 1)
        if p1 == 0.0 and y1 > 0:
            continue
        for y2 in range(x - y1 + 1):
            y3 = x - y1 - y2
            logp = (
                math.lgamma(x + 1)
                - math.lgamma(y1 + 1)
                - math.lgamma(y2 + 1)
                - math.lgamma(y3 + 1)
                - x * log3
            )
            tot += (
                math.exp(logp)
                * p1
                * success_prob_with_split(n, t, y2, r - 1)
                * success_prob_with_split(n, t, y3, r - 1)
            )
    return tot


def _binom_pmf(d: int, p: float, xs: np.ndarray) -> np.ndarray:
    """Binomial(d, p) pmf, computed stably in log space (no scipy available)."""
    xs = np.asarray(xs)
    if p >= 1.0:  # degenerate: all mass at x = d (single-group case)
        return (xs == d).astype(np.float64)
    logp = (
        np.array([math.lgamma(d + 1) - math.lgamma(x + 1) - math.lgamma(d - x + 1) for x in xs])
        + xs * math.log(p)
        + (d - xs) * math.log1p(-p)
    )
    return np.exp(logp)


def alpha(n: int, t: int, d: int, g: int, r: int, convention: str = "truncate") -> float:
    """Per-group success probability under X ~ Binomial(d, 1/g).

    convention='truncate': the paper's stated App. D/F model (x > t fails).
    convention='split':    models the §3.2 3-way split recovery for x > t.
    """
    if convention == "truncate":
        xs = np.arange(0, min(t, d) + 1)
        pmf = _binom_pmf(d, 1.0 / g, xs)
        probs = np.array([success_prob(n, t, int(x), r) for x in xs])
    elif convention == "split":
        xmax = min(d, max(3 * t, 48))
        xs = np.arange(0, xmax + 1)
        pmf = _binom_pmf(d, 1.0 / g, xs)
        probs = np.array([success_prob_with_split(n, t, int(x), r) for x in xs])
    else:
        raise ValueError(convention)
    return float(np.sum(pmf * probs))


def overall_lower_bound(
    n: int, t: int, d: int, g: int, r: int, convention: str = "truncate"
) -> float:
    """Rigorous lower bound on Pr[R <= r]: 1 - 2(1 - alpha^g)."""
    a = alpha(n, t, d, g, r, convention)
    return 1.0 - 2.0 * (1.0 - a**g)


def comm_bits_per_group(n: int, t: int, delta: float, key_bits: int = 32) -> float:
    """Formula (1): t·log n + delta·log n + delta·|key| + |key| (first round)."""
    m = int(math.log2(n + 1))
    return t * m + delta * m + delta * key_bits + key_bits


def optimize_parameters(
    d: int,
    delta: float = 5.0,
    r: int = 3,
    p0: float = 0.99,
    key_bits: int = 32,
    t_range=None,
    n_choices=N_CHOICES,
    convention: str = "split",
):
    """§5.1 grid optimization: feasible (n, t) minimizing the objective.

    Returns (n, t, bound, comm_bits_per_group).  t sweeps 1.5δ..3.5δ by
    default; widened once if the box is infeasible.  Default convention is
    'split' because the runnable protocol *does* recover via the 3-way split,
    so 'truncate' over-provisions t (see EXPERIMENTS.md §Paper-validation).
    """
    g = max(1, round(d / delta))
    widened = t_range is not None
    if t_range is None:
        t_range = range(max(1, int(1.5 * delta)), int(3.5 * delta) + 1)
    best = None
    for n in n_choices:
        m = int(math.log2(n + 1))
        for t in t_range:
            obj = (t + delta) * m
            if best is not None and obj >= best[0]:
                continue  # cannot win; skip the expensive bound
            lb = overall_lower_bound(n, t, d, g, r, convention)
            if lb >= p0:
                best = (obj, n, t, lb)
    if best is None:
        if widened:
            raise ValueError(
                f"no feasible (n, t) for d={d}, r={r}, p0={p0} ({convention})"
            )
        # Small r (e.g. r=1) needs n = Omega(d^2/group): the ideal case must
        # happen almost surely in one shot — widen both t and the bitmap sizes
        # beyond the "practical" set (the paper's r=1 point implies n = 2^19-1).
        wide_t = range(max(1, int(1.5 * delta)), int(12 * delta))
        wide_n = tuple((1 << m) - 1 for m in range(6, 21))
        return optimize_parameters(
            d, delta, r, p0, key_bits, wide_t, wide_n, convention
        )
    obj, n, t, lb = best
    return n, t, lb, comm_bits_per_group(n, t, delta, key_bits)


def bound_table(
    d: int, delta: float, r: int, t_values, n_values=N_CHOICES, convention="truncate"
):
    """Table 1: lower-bound values for a grid of (n, t)."""
    g = max(1, round(d / delta))
    return {
        (n, t): overall_lower_bound(n, t, d, g, r, convention)
        for n in n_values
        for t in t_values
    }


def expected_round_fractions(n: int, t: int, d: int, g: int, kmax: int = 4) -> list[float]:
    """§5.3: expected fraction of the d distinct elements reconciled in round k.

    E[Z_1+..+Z_k | x] = x − E[D_k | D_0 = x]; average over X ~ Binomial(d, 1/g)
    (truncated at t, matching the framework's convention), normalize by E[X].
    """
    xs = np.arange(0, min(t, d) + 1)
    pmf = _binom_pmf(d, 1.0 / g, xs)
    pmf /= pmf.sum()
    ex = float(np.sum(pmf * xs))
    cum = []
    for k in range(1, kmax + 1):
        Mk = _matrix_power(n, t, k)
        # E[D_k | D_0 = x] = sum_y y * (M^k)(x, y)
        ed = np.array([np.sum(np.arange(t + 1) * Mk[x]) for x in xs])
        cum.append(float(np.sum(pmf * (xs - ed))) / ex)
    fracs = [cum[0]] + [cum[k] - cum[k - 1] for k in range(1, kmax)]
    return fracs
