"""BCH syndrome sketches (the "parity bitmap sketch" codec).

Exactly the minisketch/PinSketch coding the paper adopts (§2.5, App. I):
the sketch of an n-bit parity bitmap is its **t odd syndromes**
``S_1, S_3, ..., S_{2t-1}`` over GF(2^m), n = 2^m − 1 — t·m bits total.
Because syndromes are GF(2)-linear in the bitmap, Bob decodes by XORing
Alice's sketch with his own and locating the ≤ t set bits of the *difference*
bitmap via Berlekamp–Massey + Chien search.

``decode`` is the numpy reference; ``kernels/`` provides the MXU formulation
(syndromes & Chien as dense GF(2) matmuls) validated against this oracle.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import numpy as np

from .gf2m import GF2m, get_field


@dataclass(frozen=True)
class BCHCode:
    n: int  # bitmap length, 2^m - 1
    t: int  # error-correction capacity

    @property
    def m(self) -> int:
        return (self.n + 1).bit_length() - 1

    @property
    def field(self) -> GF2m:
        return get_field(self.m)

    @property
    def sketch_bits(self) -> int:
        return self.t * self.m


@functools.lru_cache(maxsize=None)
def bch_code(n: int, t: int) -> BCHCode:
    """Memoized ``BCHCode`` lookup for the hot per-round paths.

    ``BCHCode`` itself is a cheap frozen dataclass, but routing every cohort
    encode/decode through one cached instance per (n, t) also keeps the
    field singleton (``get_field``) and its memoized syndrome/Chien matrices
    warm, so round planning never re-derives GF tables.
    """
    return BCHCode(n, t)


def sketch_from_positions(code: BCHCode, positions: np.ndarray) -> np.ndarray:
    """Odd syndromes S_{2j+1} = XOR_i alpha^(pos_i * (2j+1)), j = 0..t-1.

    ``positions`` are the indices of set bits in the parity bitmap — i.e. the
    bins with odd cardinality.  Empty -> all-zero sketch.
    """
    gf = code.field
    syn = np.zeros(code.t, dtype=np.int64)
    if len(positions):
        pos = np.asarray(positions, dtype=np.int64)[:, None]
        j = np.arange(code.t, dtype=np.int64)[None, :]
        vals = gf.pow_alpha(pos * (2 * j + 1))  # (npos, t)
        syn = np.bitwise_xor.reduce(vals, axis=0)
    return syn


def sketch_xor(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Sketches are linear: sketch(A) ^ sketch(B) == sketch(A xor B)."""
    return np.bitwise_xor(a, b)


def sketch_increment(code: BCHCode, positions: np.ndarray, t0: int) -> np.ndarray:
    """The incremental odd syndromes S_{2*t0+1} .. S_{2t-1} of a bitmap.

    Prefix compatibility (the rateless invariant, DESIGN.md §16): for any
    t0 < t over the same field,

        concat(sketch at t0, sketch_increment(t0)) == sketch at t

    because syndrome j never depends on the sketch capacity it ships in.
    ``MSG_PARITY`` frames carry exactly these columns.
    """
    gf = code.field
    if not 0 <= t0 <= code.t:
        raise ValueError(f"increment base t0={t0} out of range for t={code.t}")
    syn = np.zeros(code.t - t0, dtype=np.int64)
    if len(positions):
        pos = np.asarray(positions, dtype=np.int64)[:, None]
        j = np.arange(t0, code.t, dtype=np.int64)[None, :]
        vals = gf.pow_alpha(pos * (2 * j + 1))
        syn = np.bitwise_xor.reduce(vals, axis=0)
    return syn


def decode_extended(n: int, prefix: np.ndarray, increment: np.ndarray):
    """Decode a difference bitmap from a cached sketch prefix plus the
    incremental syndromes a ``MSG_PARITY`` extension delivered.

    Concatenation *is* the fresh (n, t') sketch — no re-derivation, no
    re-sent bits — so this is byte-identical to ``decode_sketch`` over a
    sketch encoded at t' from scratch (property-tested in
    tests/test_rateless.py).  Returns (ok, positions).
    """
    prefix = np.asarray(prefix, dtype=np.int64)
    increment = np.asarray(increment, dtype=np.int64)
    t2 = len(prefix) + len(increment)
    return decode_sketch(bch_code(n, t2), np.concatenate([prefix, increment]))


def _expand_syndromes(code: BCHCode, odd_syn: np.ndarray) -> np.ndarray:
    """Full S_1..S_2t from odd syndromes via S_{2k} = S_k^2 (char-2 Frobenius)."""
    gf = code.field
    full = np.zeros(2 * code.t + 1, dtype=np.int64)  # full[j] = S_j, index 0 unused
    full[1::2] = odd_syn
    for k in range(1, code.t + 1):
        full[2 * k] = int(gf.mul(full[k], full[k]))
    return full[1:]


def berlekamp_massey(code: BCHCode, syndromes: np.ndarray) -> np.ndarray:
    """Error-locator polynomial Lambda(x) from S_1..S_2t.

    Same O(t^2) class as the Levinson solver the paper uses; chosen for its
    fixed 2t-iteration structure (vmap/fori-friendly on TPU — DESIGN.md §3).
    Returns coefficients [Lambda_0=1, Lambda_1, ..., Lambda_L].
    """
    gf = code.field
    S = np.asarray(syndromes, dtype=np.int64)
    C = np.zeros(2 * code.t + 1, dtype=np.int64)
    B = np.zeros(2 * code.t + 1, dtype=np.int64)
    C[0] = B[0] = 1
    L, mshift, b = 0, 1, 1
    for i in range(2 * code.t):
        # discrepancy d = S_i + sum_{j=1..L} C_j * S_{i-j}
        d = int(S[i])
        for j in range(1, L + 1):
            d ^= int(gf.mul(C[j], S[i - j]))
        if d == 0:
            mshift += 1
        elif 2 * L <= i:
            T = C.copy()
            coef = int(gf.div(d, b))
            mult = gf.mul(coef, B)
            C[mshift:] = C[mshift:] ^ mult[: len(C) - mshift]
            L = i + 1 - L
            B = T
            b = d
            mshift = 1
        else:
            coef = int(gf.div(d, b))
            mult = gf.mul(coef, B)
            C[mshift:] = C[mshift:] ^ mult[: len(C) - mshift]
            mshift += 1
    return C[: L + 1], L


def chien_search(code: BCHCode, locator: np.ndarray) -> np.ndarray:
    """All i in [0, n) with Lambda(alpha^{-i}) == 0 — the error bit positions."""
    gf = code.field
    i = np.arange(code.n, dtype=np.int64)
    xs = gf.pow_alpha((-i) % code.n)
    vals = gf.poly_eval([int(c) for c in locator], xs)
    return np.nonzero(vals == 0)[0]


def batched_decode(code: BCHCode, sketches: np.ndarray):
    """Decode U difference sketches simultaneously (vectorized across units).

    This is the TPU-shaped formulation (DESIGN.md §3): Berlekamp–Massey has a
    fixed 2t-iteration structure, so all group pairs advance in lockstep with
    masked state updates — the numpy mirror of the vmap'd JAX/Pallas path.

    Returns (ok: (U,) bool, positions: list of U int arrays).
    """
    gf = code.field
    t = code.t
    sk = np.asarray(sketches, dtype=np.int64)
    U = sk.shape[0]
    if U == 0:
        return np.zeros(0, dtype=bool), []

    # Expand odd syndromes to S_1..S_2t via Frobenius squaring.
    S = np.zeros((U, 2 * t), dtype=np.int64)
    S[:, 0::2] = sk
    for k in range(1, t + 1):
        S[:, 2 * k - 1] = gf.mul(S[:, k - 1], S[:, k - 1])

    # ---- batched Berlekamp–Massey --------------------------------------
    width = 2 * t + 1
    C = np.zeros((U, width), dtype=np.int64)
    B = np.zeros((U, width), dtype=np.int64)
    C[:, 0] = B[:, 0] = 1
    L = np.zeros(U, dtype=np.int64)
    b = np.ones(U, dtype=np.int64)
    mshift = np.ones(U, dtype=np.int64)
    cols = np.arange(width)

    for i in range(2 * t):
        # discrepancy d_u = S[u,i] ^ XOR_j C[u,j] * S[u,i-j], j = 1..L_u
        d = S[:, i].copy()
        for j in range(1, i + 1):
            term = gf.mul(C[:, j], S[:, i - j])
            d ^= np.where(L >= j, term, 0)
        nz = d != 0
        grow = nz & (2 * L <= i)
        stay = nz & ~grow

        coef = np.where(nz, gf.mul(d, gf.inv(np.where(b == 0, 1, b))), 0)
        idx = cols[None, :] - mshift[:, None]
        Bsh = np.where(idx >= 0, np.take_along_axis(B, np.clip(idx, 0, width - 1), 1), 0)
        Cnew = C ^ gf.mul(coef[:, None], Bsh)

        B = np.where(grow[:, None], C, B)
        C = np.where(nz[:, None], Cnew, C)
        bnew = np.where(grow, d, b)
        L = np.where(grow, i + 1 - L, L)
        mshift = np.where(grow, 1, np.where(stay, mshift + 1, mshift + 1))
        b = bnew

    # ---- batched Chien search -------------------------------------------
    # vals[u, i] = Lambda_u(alpha^{-i}); roots mark error positions.
    ii = np.arange(code.n, dtype=np.int64)
    ok = np.ones(U, dtype=bool)
    positions: list[np.ndarray] = [None] * U  # type: ignore[list-item]
    zero_sketch = ~sk.any(axis=1)
    # evaluate in chunks to bound memory: (U, chunk, t+1)
    root_count = np.zeros(U, dtype=np.int64)
    roots_buf: list[list[np.ndarray]] = [[] for _ in range(U)]
    chunk = max(1, int(4e6 // max(1, U)))
    Lam = C[:, : t + 1]
    for s0 in range(0, code.n, chunk):
        xs = gf.pow_alpha((-ii[s0 : s0 + chunk]) % code.n)  # (c,)
        acc = np.zeros((U, len(xs)), dtype=np.int64)
        for k in range(t, -1, -1):
            acc = gf.mul(acc, xs[None, :]) ^ Lam[:, k : k + 1]
        zu, zi = np.nonzero(acc == 0)
        root_count += np.bincount(zu, minlength=U)
        for u, i0 in zip(zu, zi + s0):
            roots_buf[u].append(i0)

    for u in range(U):
        pos = np.array(sorted(roots_buf[u]), dtype=np.int64)
        if zero_sketch[u]:
            ok[u] = True
            positions[u] = np.zeros(0, dtype=np.int64)
            continue
        if L[u] == 0 or L[u] > t or len(pos) != L[u]:
            ok[u] = False
            positions[u] = np.zeros(0, dtype=np.int64)
            continue
        if np.any(sketch_from_positions(code, pos) != sk[u]):
            ok[u] = False
            positions[u] = np.zeros(0, dtype=np.int64)
            continue
        positions[u] = pos
    return ok, positions


def decode_sketch(code: BCHCode, diff_sketch: np.ndarray):
    """Locate the set bits of the difference bitmap from its odd syndromes.

    Returns (ok, positions).  ok=False signals a BCH decoding failure — more
    than t bits actually differ (PBS handles this with the 3-way group split,
    paper §3.2).  Failure detection: locator degree != number of roots found,
    or inconsistent syndromes.
    """
    odd = np.asarray(diff_sketch, dtype=np.int64)
    if not odd.any():
        return True, np.zeros(0, dtype=np.int64)
    full = _expand_syndromes(code, odd)
    locator, L = berlekamp_massey(code, full)
    if L == 0 or L > code.t:
        return False, np.zeros(0, dtype=np.int64)
    positions = chien_search(code, locator)
    if len(positions) != L:
        return False, np.zeros(0, dtype=np.int64)
    # Consistency: recomputing the sketch from the found positions must match.
    if np.any(sketch_from_positions(code, positions) != odd):
        return False, np.zeros(0, dtype=np.int64)
    return True, positions
