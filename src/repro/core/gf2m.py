"""GF(2^m) arithmetic for BCH sketches.

Two representations are used throughout:

* **integer form** — a field element is an int in ``[0, 2^m)`` whose bits are the
  polynomial coefficients.  Fast scalar/numpy ops via log/antilog tables
  (only for small m ≤ 14, the PBS regime where n = 2^m − 1 ≤ 16383).
* **bit-vector form** — an element is a length-m 0/1 vector.  Multiplication by a
  *constant* c is then a binary m×m matrix ``mult_matrix(c)`` over GF(2), which is
  what lets syndrome computation / Chien search become dense MXU matmuls
  (see kernels/gf2_matmul.py and DESIGN.md §3).

For the PinSketch baseline we also need GF(2^32), which is too large for tables;
``clmul_reduce`` implements vectorized carry-less multiplication + reduction.
"""
from __future__ import annotations

import functools

import numpy as np

# Primitive polynomials (including the x^m term), indexed by m.
PRIMITIVE_POLY = {
    3: 0b1011,
    4: 0b10011,
    5: 0b100101,
    6: 0b1000011,
    7: 0b10001001,
    8: 0b100011101,
    9: 0b1000010001,
    10: 0b10000001001,
    11: 0b100000000101,
    12: 0b1000001010011,
    13: 0b10000000011011,
    14: 0b100010001000011,
    # x^32 + x^22 + x^2 + x + 1 — maximal-length LFSR taps, primitive.
    32: (1 << 32) | (1 << 22) | (1 << 2) | (1 << 1) | 1,
}


class GF2m:
    """Log/antilog-table field GF(2^m) for m ≤ 14."""

    def __init__(self, m: int):
        if m not in PRIMITIVE_POLY or m > 14:
            raise ValueError(f"unsupported field GF(2^{m})")
        self.m = m
        self.n = (1 << m) - 1  # multiplicative group order == BCH length
        self.poly = PRIMITIVE_POLY[m]
        # exp table of length 2n so that exp[(a+b)] needs no mod.
        exp = np.zeros(2 * self.n, dtype=np.int64)
        log = np.zeros(self.n + 1, dtype=np.int64)
        x = 1
        for i in range(self.n):
            exp[i] = x
            log[x] = i
            x <<= 1
            if x & (1 << m):
                x ^= self.poly
        if x != 1:  # primitive polynomial sanity: alpha^n == 1
            raise AssertionError("polynomial is not primitive")
        exp[self.n:] = exp[: self.n]
        log[0] = -1  # log of 0 is undefined; sentinel
        self.exp = exp
        self.log = log

    # ---- scalar/numpy ops (arrays of integer-form elements) ------------
    def mul(self, a, b):
        a = np.asarray(a, dtype=np.int64)
        b = np.asarray(b, dtype=np.int64)
        out = self.exp[(self.log[a] + self.log[b]) % self.n]
        return np.where((a == 0) | (b == 0), 0, out)

    def inv(self, a):
        a = np.asarray(a, dtype=np.int64)
        if np.any(a == 0):
            raise ZeroDivisionError("inverse of 0 in GF(2^m)")
        return self.exp[(self.n - self.log[a]) % self.n]

    def div(self, a, b):
        return self.mul(a, self.inv(b))

    def pow_alpha(self, e):
        """alpha**e for integer exponents (vectorized)."""
        e = np.asarray(e, dtype=np.int64) % self.n
        return self.exp[e]

    def square(self, a):
        return self.mul(a, a)

    def poly_eval(self, coeffs, xs):
        """Evaluate sum_k coeffs[k] * xs**k (coeffs[0] is the constant term)."""
        xs = np.asarray(xs, dtype=np.int64)
        acc = np.zeros_like(xs)
        for c in reversed(coeffs):
            acc = self.mul(acc, xs) ^ int(c)
        return acc

    # ---- bit-vector form helpers (for the GF(2)-matmul kernel path) ----
    def to_bits(self, a) -> np.ndarray:
        """Integer form -> (..., m) 0/1 int32 bit vectors (LSB first)."""
        a = np.asarray(a, dtype=np.int64)
        shifts = np.arange(self.m, dtype=np.int64)
        return ((a[..., None] >> shifts) & 1).astype(np.int32)

    def from_bits(self, bits: np.ndarray) -> np.ndarray:
        bits = np.asarray(bits, dtype=np.int64)
        shifts = np.arange(self.m, dtype=np.int64)
        return (bits << shifts).sum(axis=-1)

    def mult_matrix(self, c: int) -> np.ndarray:
        """m x m binary matrix M with bits(c*x) = bits(x) @ M (mod 2)."""
        rows = [self.to_bits(self.mul(1 << k, c)) for k in range(self.m)]
        return np.stack(rows, axis=0).astype(np.int32)

    @functools.lru_cache(maxsize=None)
    def syndrome_matrix(self, t: int) -> np.ndarray:
        """(n, t*m) binary matrix P mapping a parity bitmap to its t odd syndromes.

        P[i, j*m:(j+1)*m] = bits(alpha^(i*(2j+1))).  A bitmap's sketch is
        (bitmap @ P) mod 2 — one dense GF(2) matmul (MXU-friendly).
        Memoized per (field, t): fields are singletons via ``get_field``, so
        repeated cohort encodes reuse one table instead of re-deriving it.
        """
        i = np.arange(self.n, dtype=np.int64)[:, None]
        j = np.arange(t, dtype=np.int64)[None, :]
        powers = self.pow_alpha(i * (2 * j + 1))  # (n, t) integer elements
        return self.to_bits(powers).reshape(self.n, t * self.m)

    @functools.lru_cache(maxsize=None)
    def syndrome_matrix_range(self, t0: int, t1: int) -> np.ndarray:
        """(n, (t1-t0)*m) column slice of ``syndrome_matrix``: syndromes
        S_{2*t0+1} .. S_{2*t1-1} only.

        Because ``syndrome_matrix(t)[:, j*m:(j+1)*m]`` depends only on j —
        never on t — the (n, t) sketch is a strict prefix of the (n, t')
        sketch for any t' > t, and
        ``hstack(syndrome_matrix(t0), syndrome_matrix_range(t0, t1)) ==
        syndrome_matrix(t1)`` exactly.  This is what lets the rateless
        recovery path (DESIGN.md §16) ship only the *incremental* syndromes
        on BCH overload and decode at t1 against the cached prefix.
        """
        if not 0 <= t0 <= t1:
            raise ValueError(f"bad syndrome range [{t0}, {t1})")
        i = np.arange(self.n, dtype=np.int64)[:, None]
        j = np.arange(t0, t1, dtype=np.int64)[None, :]
        powers = self.pow_alpha(i * (2 * j + 1))
        return self.to_bits(powers).reshape(self.n, (t1 - t0) * self.m)

    @functools.lru_cache(maxsize=None)
    def chien_matrix(self, t: int) -> np.ndarray:
        """((t+1)*m, n*m) binary matrix C for whole-field polynomial evaluation.

        With L = concat(bits(Lambda_0..Lambda_t)) (length (t+1)m),
        (L @ C) mod 2 reshaped to (n, m) gives bits(Lambda(alpha^{-i})) for
        all i — the decode convention, so all-zero rows are error positions.
        """
        out = np.zeros(((t + 1) * self.m, self.n * self.m), dtype=np.int32)
        i = np.arange(self.n, dtype=np.int64)
        for k in range(t + 1):
            consts = self.pow_alpha(-i * k)  # alpha^(-i*k) for all i
            for b in range(self.m):
                basis = 1 << b  # bits(Lambda_k)[b] contributes basis * const
                prod = self.mul(basis, consts)  # (n,)
                out[k * self.m + b] = self.to_bits(prod).reshape(-1)
        return out


@functools.lru_cache(maxsize=None)
def get_field(m: int) -> GF2m:
    return GF2m(m)


# --------------------------------------------------------------------------
# GF(2^32) via carry-less multiplication (vectorized numpy, no tables).
# --------------------------------------------------------------------------
_POLY32_LOW = np.uint64(PRIMITIVE_POLY[32] & 0xFFFFFFFF)  # reduction taps below x^32


def clmul32(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Carry-less 32x32 -> 64 bit multiply, vectorized (uint64 arrays)."""
    a = np.asarray(a, dtype=np.uint64)
    b = np.asarray(b, dtype=np.uint64)
    acc = np.zeros(np.broadcast(a, b).shape, dtype=np.uint64)
    ones = np.uint64(0xFFFFFFFFFFFFFFFF)
    for k in range(32):
        mask = ((b >> np.uint64(k)) & np.uint64(1)) * ones  # all-ones where bit set
        acc ^= (a << np.uint64(k)) & mask
    return acc


def gf32_reduce(x: np.ndarray) -> np.ndarray:
    """Reduce a 64-bit carry-less product modulo the GF(2^32) primitive poly.

    The x^22 tap means each fold can reintroduce high bits; four passes are
    enough to clear them (54 -> 44 -> 34 -> 24 bit bound).
    """
    x = np.asarray(x, dtype=np.uint64)
    for _ in range(4):
        hi = x >> np.uint64(32)
        x = (x & np.uint64(0xFFFFFFFF)) ^ clmul32(hi, _POLY32_LOW)
    return x


def gf32_mul(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    return gf32_reduce(clmul32(a, b))


def gf32_pow(a: np.ndarray, e: int) -> np.ndarray:
    """a**e in GF(2^32) by square-and-multiply (vectorized over a)."""
    a = np.asarray(a, dtype=np.uint64)
    result = np.ones_like(a)
    base = a
    while e:
        if e & 1:
            result = gf32_mul(result, base)
        base = gf32_mul(base, base)
        e >>= 1
    return result


def gf32_inv(a: np.ndarray) -> np.ndarray:
    # a^(2^32 - 2) == a^-1 for a != 0.
    return gf32_pow(a, (1 << 32) - 2)
