"""Synthetic set pairs for experiments, built the way the paper builds them
(§8 Experiment Setup): A drawn uniformly without replacement from a 32-bit
universe (0 excluded), B = A minus d random elements, so |A △ B| = d and
B ⊂ A — the same best-case-for-Graphene setup the paper uses.
"""
from __future__ import annotations

import numpy as np


def random_set(size: int, rng: np.random.Generator) -> np.ndarray:
    """`size` distinct uniform uint32 keys, 0 excluded."""
    out = np.zeros(0, dtype=np.uint32)
    while len(out) < size:
        need = int((size - len(out)) * 1.1) + 16
        cand = rng.integers(1, 1 << 32, size=need, dtype=np.uint64).astype(np.uint32)
        out = np.unique(np.concatenate([out, cand]))
    rng.shuffle(out)
    return out[:size]


def make_pair(size_a: int, d: int, rng: np.random.Generator):
    """(A, B) with |A| = size_a, B ⊂ A, |A △ B| = d."""
    a = random_set(size_a, rng)
    b = rng.permutation(a)[: size_a - d]
    return a, b


def make_pair_two_sided(size_a: int, d_a_only: int, d_b_only: int, rng: np.random.Generator):
    """General case: both A\\B and B\\A non-empty."""
    base = random_set(size_a + d_b_only, rng)
    a = base[: size_a]
    b = np.concatenate([a[: size_a - d_a_only], base[size_a :]])
    rng.shuffle(b)
    return a, b
