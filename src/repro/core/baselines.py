"""Baselines the paper evaluates against (§7/§8): PinSketch, Difference
Digest (IBF), Graphene (BF + IBF), and PinSketch/WP (PinSketch + PBS's
hash-partitioning trick).

Scope notes (documented deviations — see EXPERIMENTS.md §Paper-validation):

* PinSketch root-finding: minisketch factors the locator polynomial with
  Berlekamp trace; we locate roots by evaluating the locator on Alice's
  candidate elements, which is exact in the paper's own experimental setup
  (B ⊂ A so A △ B ⊆ A) and has the same O(d²)-dominated decode scaling.
* Graphene: Protocol I (the B ⊂ A best case the paper grants it), with the
  BF/IBF split optimized numerically and the IBF-only fallback.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .gf2m import _POLY32_LOW, gf32_mul
from .hashing import derive_seed, hash_to_range, mix32
from .tow import ELL_DEFAULT

_POLY32_INT = (1 << 32) | int(_POLY32_LOW)

# ---------------------------------------------------------------------------
# PinSketch over GF(2^32)
# ---------------------------------------------------------------------------


def pinsketch_encode(elems: np.ndarray, t: int) -> np.ndarray:
    """Odd power-sum syndromes S_j = sum_{x in S} x^j, j = 1, 3, .., 2t-1."""
    x = np.asarray(elems, dtype=np.uint64)
    out = np.zeros(t, dtype=np.uint64)
    if len(x) == 0:
        return out
    cur = x.copy()          # x^1
    sq = gf32_mul(x, x)     # x^2
    for j in range(t):
        out[j] = np.bitwise_xor.reduce(cur)
        if j + 1 < t:
            cur = gf32_mul(cur, sq)  # x^(2j+1) -> x^(2j+3)
    return out


def _gf32_mul_scalar(a: int, b: int) -> int:
    """Scalar GF(2^32) multiply on Python ints (~100x the numpy bit-loop)."""
    r = 0
    a, b = int(a), int(b)
    while b:
        lsb = b & -b
        r ^= a << (lsb.bit_length() - 1)
        b ^= lsb
    for i in range(r.bit_length() - 1, 31, -1):  # reduce mod primitive poly
        if (r >> i) & 1:
            r ^= _POLY32_INT << (i - 32)
    return r


def _gf32_inv_scalar(a: int) -> int:
    """Inverse via extended Euclid over GF(2)[x] (O(32) int steps)."""
    if a == 0:
        raise ZeroDivisionError("gf32 inverse of 0")
    r0, r1 = _POLY32_INT, int(a)
    s0, s1 = 0, 1
    while r1 != 1:
        shift = r0.bit_length() - r1.bit_length()
        if shift < 0:
            r0, r1, s0, s1 = r1, r0, s1, s0
            continue
        r0 ^= r1 << shift
        s0 ^= s1 << shift
        if r0.bit_length() < r1.bit_length():
            r0, r1, s0, s1 = r1, r0, s1, s0
    for i in range(s1.bit_length() - 1, 31, -1):
        if (s1 >> i) & 1:
            s1 ^= _POLY32_INT << (i - 32)
    return s1


def pinsketch_decode(
    sketch_diff: np.ndarray, candidates: np.ndarray, t: int
) -> tuple[bool, np.ndarray]:
    """Locate the difference set from XORed sketches.

    O(t^2) Berlekamp–Massey over GF(2^32) followed by locator evaluation on
    the candidate elements (exact under the paper's B ⊂ A setup).
    """
    odd = np.asarray(sketch_diff, dtype=np.uint64)
    if not odd.any():
        return True, np.zeros(0, dtype=np.uint64)
    # Expand syndromes: S_{2k} = S_k^2.
    S = np.zeros(2 * t, dtype=np.uint64)
    S[0::2] = odd
    for k in range(1, t + 1):
        S[2 * k - 1] = gf32_mul(S[k - 1], S[k - 1])

    width = 2 * t + 1
    C = np.zeros(width, dtype=np.uint64)
    B = np.zeros(width, dtype=np.uint64)
    C[0] = B[0] = 1
    L, mshift, b = 0, 1, 1
    for i in range(2 * t):
        d = int(S[i])
        if L > 0:
            d ^= int(np.bitwise_xor.reduce(gf32_mul(C[1 : L + 1], S[i - L : i][::-1])))
        if d == 0:
            mshift += 1
        elif 2 * L <= i:
            T = C.copy()
            coef = _gf32_mul_scalar(d, _gf32_inv_scalar(b))
            C[mshift:] ^= gf32_mul(np.uint64(coef), B[: width - mshift])
            L, B, b, mshift = i + 1 - L, T, d, 1
        else:
            coef = _gf32_mul_scalar(d, _gf32_inv_scalar(b))
            C[mshift:] ^= gf32_mul(np.uint64(coef), B[: width - mshift])
            mshift += 1
    if L == 0 or L > t:
        return False, np.zeros(0, dtype=np.uint64)
    # Evaluate locator at x^{-1} for each candidate x: roots of
    # Lambda(z) are inverses of the difference elements.  Equivalently
    # evaluate sum_k Lambda_k x^{L-k} == 0 (multiply through by x^L).
    xs = np.asarray(candidates, dtype=np.uint64)
    acc = np.zeros_like(xs)
    for k in range(0, L + 1):
        acc = gf32_mul(acc, xs) ^ C[k]
    found = xs[acc == 0]
    found = np.unique(found)
    if len(found) != L:
        return False, np.zeros(0, dtype=np.uint64)
    return True, found


@dataclass
class BaselineResult:
    diff: set
    success: bool
    bytes_sent: int
    rounds: int = 1


def pinsketch_reconcile(a: np.ndarray, b: np.ndarray, t: int) -> BaselineResult:
    """One-shot PinSketch: Bob sends his t-syndrome sketch (t * 32 bits)."""
    sk_a = pinsketch_encode(a, t)
    sk_b = pinsketch_encode(b, t)
    ok, found = pinsketch_decode(sk_a ^ sk_b, a, t)
    bytes_sent = (t * 32 + 7) // 8
    return BaselineResult(
        diff=set(int(x) for x in found), success=ok, bytes_sent=bytes_sent
    )


def pinsketch_wp_reconcile(
    a: np.ndarray, b: np.ndarray, d_plan: int, t: int, delta: float = 5.0, seed: int = 0,
    max_rounds: int = 3,
) -> BaselineResult:
    """PinSketch/WP (§8.3): hash-partition into g groups, PinSketch each pair.

    Uses the same delta and t as PBS; per-group sketch costs t * 32 bits
    (no parity bitmap, so positions cost log|U| not log n — the 3-4x safety
    margin penalty the paper highlights).  Groups whose decode fails retry
    with a fresh hash next round (checksum-gated like PBS).
    """
    g = max(1, round(d_plan / delta))
    total_bits = 0
    diff: set[int] = set()
    a_work = np.asarray(a, dtype=np.uint32)
    b_arr = np.asarray(b, dtype=np.uint32)
    pending = list(range(g))
    rounds = 0
    for rnd in range(1, max_rounds + 1):
        if not pending:
            break
        rounds = rnd
        seed_g = derive_seed(seed, 0x9A, rnd)
        ga = hash_to_range(a_work, g, seed_g)
        gb = hash_to_range(b_arr, g, seed_g)
        nxt = []
        for gi in pending:
            mem_a = a_work[ga == gi]
            mem_b = b_arr[gb == gi]
            sk = pinsketch_encode(mem_a, t) ^ pinsketch_encode(mem_b, t)
            total_bits += t * 32 + 32  # sketch + checksum
            ok, found = pinsketch_decode(sk, mem_a, t)
            if not ok:
                nxt.append(gi)
                continue
            diff.update(int(x) for x in found)
        # every group re-hashes next round; simple and conservative
        pending = nxt
    td = set(int(x) for x in a_work) ^ set(int(x) for x in b_arr)
    return BaselineResult(
        diff=diff, success=diff == td, bytes_sent=(total_bits + 7) // 8, rounds=rounds
    )


# ---------------------------------------------------------------------------
# Invertible Bloom Filter + Difference Digest
# ---------------------------------------------------------------------------


class IBF:
    """idSum/hashSum/count cells with k-hash insertion and peeling."""

    def __init__(self, cells: int, k: int, seed: int):
        self.cells = cells
        self.k = k
        self.seed = seed
        self.id_sum = np.zeros(cells, dtype=np.uint32)
        self.hash_sum = np.zeros(cells, dtype=np.uint32)
        self.count = np.zeros(cells, dtype=np.int64)

    def _cells_of(self, x: np.ndarray) -> np.ndarray:
        # k distinct hash functions -> (len(x), k) cell indices
        return np.stack(
            [hash_to_range(x, self.cells, derive_seed(self.seed, 0x1BF, j)) for j in range(self.k)],
            axis=1,
        )

    def insert_all(self, xs: np.ndarray, sign: int = 1):
        xs = np.asarray(xs, dtype=np.uint32)
        if len(xs) == 0:
            return
        idx = self._cells_of(xs)  # (N, k)
        hv = mix32(xs, derive_seed(self.seed, 0xC4EC))
        for j in range(self.k):
            np.bitwise_xor.at(self.id_sum, idx[:, j], xs)
            np.bitwise_xor.at(self.hash_sum, idx[:, j], hv)
            np.add.at(self.count, idx[:, j], sign)

    def subtract(self, other: "IBF") -> "IBF":
        out = IBF(self.cells, self.k, self.seed)
        out.id_sum = self.id_sum ^ other.id_sum
        out.hash_sum = self.hash_sum ^ other.hash_sum
        out.count = self.count - other.count
        return out

    def peel(self) -> tuple[bool, set]:
        """Recover the encoded difference by iterative peeling."""
        recovered: set[int] = set()
        check_seed = derive_seed(self.seed, 0xC4EC)
        for _ in range(self.cells * 4):
            pure = np.nonzero(
                (np.abs(self.count) == 1)
                & (self.hash_sum == mix32(self.id_sum, check_seed))
                & (self.id_sum != 0)
            )[0]
            if len(pure) == 0:
                break
            ci = int(pure[0])
            x = np.uint32(self.id_sum[ci])
            sgn = int(self.count[ci])
            xa = np.array([x], dtype=np.uint32)
            idx = self._cells_of(xa)[0]
            hv = mix32(xa, check_seed)[0]
            for j in range(self.k):
                self.id_sum[idx[j]] ^= x
                self.hash_sum[idx[j]] ^= hv
                self.count[idx[j]] -= sgn
            recovered.add(int(x))
        ok = not self.count.any() and not self.id_sum.any()
        return ok, recovered

    @property
    def bytes(self) -> int:
        # 3 words of log|U| = 32 bits per cell (paper's 6d log|U| accounting).
        return self.cells * 12


def ddigest_reconcile(
    a: np.ndarray, b: np.ndarray, d_plan: int, seed: int = 0
) -> BaselineResult:
    """Difference Digest: IBF with 2*d_hat cells (k = 3 if d_hat > 200 else 4)."""
    cells = max(8, 2 * d_plan)
    k = 3 if d_plan > 200 else 4
    ibf_a = IBF(cells, k, seed)
    ibf_a.insert_all(a)
    ibf_b = IBF(cells, k, seed)
    ibf_b.insert_all(b)
    ok, rec = ibf_a.subtract(ibf_b).peel()
    td = set(int(x) for x in np.asarray(a).ravel()) ^ set(int(x) for x in np.asarray(b).ravel())
    return BaselineResult(diff=rec, success=ok and rec == td, bytes_sent=ibf_b.bytes)


# ---------------------------------------------------------------------------
# Graphene (Protocol I, B ⊂ A)
# ---------------------------------------------------------------------------


class BloomFilter:
    def __init__(self, nbits: int, k: int, seed: int):
        self.nbits = max(8, nbits)
        self.k = max(1, k)
        self.seed = seed
        self.bits = np.zeros(self.nbits, dtype=bool)

    def add_all(self, xs: np.ndarray):
        xs = np.asarray(xs, dtype=np.uint32)
        for j in range(self.k):
            self.bits[hash_to_range(xs, self.nbits, derive_seed(self.seed, 0xBF, j))] = True

    def query_all(self, xs: np.ndarray) -> np.ndarray:
        xs = np.asarray(xs, dtype=np.uint32)
        hit = np.ones(len(xs), dtype=bool)
        for j in range(self.k):
            hit &= self.bits[hash_to_range(xs, self.nbits, derive_seed(self.seed, 0xBF, j))]
        return hit

    @property
    def bytes(self) -> int:
        return (self.nbits + 7) // 8


def graphene_plan(size_b: int, size_a: int, d_plan: int):
    """Optimize (BF fpr, IBF cells) for protocol I; IBF-only fallback.

    total(fpr) = 1.44 log2(1/fpr) |B| bits + 12 bytes * cells, with
    cells = tau * (fpr * (|A| - |B| candidates...) + slack).  Numeric sweep.
    """
    best = None
    a_minus_b = max(size_a - (size_a - d_plan), d_plan)  # |A\B| approx d
    for log2_inv in range(1, 21):
        fpr = 2.0 ** (-log2_inv)
        bf_bits = 1.44 * log2_inv * (size_a - d_plan)  # BF sized on |B|
        exp_missing = fpr * a_minus_b
        cells = int(np.ceil(1.5 * exp_missing + 12))
        total = bf_bits / 8 + cells * 12
        if best is None or total < best[0]:
            best = (total, fpr, cells, False)
    # IBF-only fallback (degenerate Graphene)
    cells_only = int(np.ceil(1.5 * d_plan + 12))
    if cells_only * 12 < best[0]:
        best = (cells_only * 12, 1.0, cells_only, True)
    return best  # (bytes, fpr, cells, ibf_only)


def graphene_reconcile(
    a: np.ndarray, b: np.ndarray, d_plan: int, seed: int = 0
) -> BaselineResult:
    """Graphene protocol I: Bob sends BF(B) + IBF(B); Alice learns A \\ B."""
    a = np.asarray(a, dtype=np.uint32)
    b = np.asarray(b, dtype=np.uint32)
    total, fpr, cells, ibf_only = graphene_plan(len(b), len(a), d_plan)
    bytes_sent = 0
    if ibf_only:
        candidates = a
    else:
        k = max(1, int(round(np.log2(1.0 / fpr))))
        bf = BloomFilter(int(np.ceil(1.44 * np.log2(1.0 / fpr) * len(b))), k, seed)
        bf.add_all(b)
        bytes_sent += bf.bytes
        hit = bf.query_all(a)
        candidates = a[hit]  # contains all of B plus fp survivors of A\B
        # definite misses are immediately known to be in A\B
    ibf_b = IBF(cells, 4 if d_plan <= 200 else 3, derive_seed(seed, 0x6F))
    ibf_b.insert_all(b)
    bytes_sent += ibf_b.bytes
    ibf_cand = IBF(cells, 4 if d_plan <= 200 else 3, derive_seed(seed, 0x6F))
    ibf_cand.insert_all(candidates)
    ok, rec = ibf_cand.subtract(ibf_b).peel()
    diff = set(int(x) for x in a[~bf.query_all(a)]) if not ibf_only else set()
    diff |= rec
    td = set(int(x) for x in a) ^ set(int(x) for x in b)
    return BaselineResult(diff=diff, success=ok and diff == td, bytes_sent=bytes_sent)
