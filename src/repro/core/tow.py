"""Tug-of-War set-difference cardinality estimator (paper §6, App. A).

d_hat = sum_i (Y_i(A) - Y_i(B))^2 / ell with ell independent ±1 hashes;
unbiased with Var = (2d^2 - 2d)/ell.  PBS then plans for d' = GAMMA * d_hat
so that Pr[d <= d'] >= 99% (paper: GAMMA = 1.38, ell = 128).

The ±1 family is the two-round murmur-finalizer mix the ToW Pallas kernel
uses (``kernels/tow_sketch.py``, mirror in ``kernels/ref.tow_sketch_ref``):
``sign_i(s) = 1 - 2 * (mix32(mix32(s, 0x5EED) ^ seed_i, 0x7077) & 1)``.
Host and device therefore produce bit-identical sketch vectors, which is
what lets ``repro.recon`` route batched phase-0 estimation through the
kernel while staying byte-identical to this numpy oracle, and lets a
``repro.net`` endpoint verify a sketch it received over the wire.  The
variance contract is validated empirically for this family in
tests/test_kernels.py and tests/test_tow_markov.py.

Byte accounting mirrors the wire codec exactly: ``sketch_bytes`` /
``dhat_bytes`` are the *framed* lengths of the ``repro.wire`` phase-0
messages (varint header + bit-packed payload), asserted equal to
``len(encode_*(...))`` in tests/test_wire.py.
"""
from __future__ import annotations

import numpy as np

from .hashing import derive_seed, mix32

ELL_DEFAULT = 128
GAMMA = 1.38

# Fraction of |A| + |B| beyond which a planned d̂ leaves the PBS operating
# regime: at d approaching the total element count, partition-and-recover
# stops paying (bytes/diff crosses the ship-the-keys baseline) while a
# ±3σ estimator error is large in absolute terms, so an underestimate
# burns the whole round budget before degradation catches it.  The tree
# front end (repro.tree) is the intended route for such pairs.
ESTIMATE_LIMIT_FRAC = 0.5


class EstimateOutOfRange(RuntimeError):
    """Planned d̂ exceeds the PBS operating regime for the pair's size.

    Raised on the *estimator* path only (``d_known`` submissions never
    raise — an operator pinning d explicitly has opted out).  Carries the
    numbers so callers can reroute the pair through the tree front end;
    ``classify_error`` maps it to ``error_kind="estimate"``.
    """

    def __init__(self, d_plan: int, total: int, limit_frac: float, sid=None):
        self.d_plan = int(d_plan)
        self.total = int(total)
        self.limit_frac = float(limit_frac)
        self.sid = sid
        at = f" (sid {sid})" if sid is not None else ""
        super().__init__(
            f"planned d̂ {self.d_plan} exceeds {limit_frac:g} of the pair's "
            f"{self.total} elements{at}: outside the PBS estimator regime — "
            f"route this pair through the tree front end (repro.tree)"
        )


def check_estimate(
    d_plan: int,
    total_elems: int,
    limit_frac: float | None = ESTIMATE_LIMIT_FRAC,
    sid=None,
) -> None:
    """Raise ``EstimateOutOfRange`` when a planned d̂ is out of regime;
    ``limit_frac=None`` disables the guard (the legacy burn-the-budget
    behavior)."""
    if limit_frac is not None and d_plan > limit_frac * total_elems:
        raise EstimateOutOfRange(d_plan, total_elems, limit_frac, sid=sid)


def tow_seeds(seed: int, ell: int = ELL_DEFAULT) -> np.ndarray:
    """The per-sketch seed vector (stream 0xE57) — shared host/kernel."""
    return np.array(
        [derive_seed(seed, 0xE57, i) for i in range(ell)], dtype=np.uint32
    )


def tow_sketches(elems: np.ndarray, seed: int, ell: int = ELL_DEFAULT) -> np.ndarray:
    """ell ToW sketches of a set: Y_i = sum_{s in S} f_i(s), f_i: U -> {±1}.

    Vectorized numpy mirror of ``kernels.tow_sketch`` — same hash family,
    same seed derivation, bit-identical output.
    """
    elems = np.asarray(elems, dtype=np.uint32)
    seeds = tow_seeds(seed, ell)
    if len(elems) == 0:
        return np.zeros(ell, dtype=np.int64)
    h1 = mix32(elems, 0x5EED)[:, None]                  # (E, 1)
    h = mix32(h1 ^ seeds[None, :], 0x7077)              # (E, ell)
    signs = 1 - 2 * (h & np.uint32(1)).astype(np.int64)
    return signs.sum(axis=0)


def estimate_numerator(sk_a: np.ndarray, sk_b: np.ndarray) -> int:
    """Integer numerator sum_i (Y_i(A) - Y_i(B))^2 — exact, and what the
    d_hat reply frame carries on the wire (d_hat = numerator / ell)."""
    diff = np.asarray(sk_a, dtype=np.int64) - np.asarray(sk_b, dtype=np.int64)
    return int(np.sum(diff * diff))


def estimate_d(sk_a: np.ndarray, sk_b: np.ndarray) -> float:
    """Unbiased estimate of |A △ B| from the two sketch vectors."""
    return estimate_numerator(sk_a, sk_b) / len(np.asarray(sk_a).ravel())


def planned_d(d_hat: float, gamma: float = GAMMA) -> int:
    return max(1, int(np.ceil(gamma * d_hat)))


# ---------------------------------------------------------------------------
# Wire-frame sizes (numpy-pure mirror of repro.wire; asserted in test_wire)
# ---------------------------------------------------------------------------


def _uvarint_len(v: int) -> int:
    n = 1
    v >>= 7
    while v:
        n += 1
        v >>= 7
    return n


def _framed_len(payload_len: int) -> int:
    # envelope: uvarint(1 + payload) + msg-type byte + payload
    return _uvarint_len(1 + payload_len) + 1 + payload_len


def sketch_value_bits(set_size: int) -> int:
    """Bits per sketch value: each Y_i is an int in [-|S|, |S|] (§6.1)."""
    return int(2 * set_size).bit_length()


def sketch_bytes(set_size: int, ell: int = ELL_DEFAULT) -> int:
    """Framed length of the A->B ToW sketch message (MSG_TOW_SKETCH)."""
    payload = (
        _uvarint_len(set_size)
        + _uvarint_len(ell)
        + (ell * sketch_value_bits(set_size) + 7) // 8
    )
    return _framed_len(payload)


def dhat_bytes(numerator: int) -> int:
    """Framed length of the B->A d_hat reply message (MSG_DHAT)."""
    return _framed_len(_uvarint_len(int(numerator)))
