"""Tug-of-War set-difference cardinality estimator (paper §6, App. A).

d_hat = sum_i (Y_i(A) - Y_i(B))^2 / ell with ell four-wise-independent ±1
hashes; unbiased with Var = (2d^2 - 2d)/ell.  PBS then plans for
d' = GAMMA * d_hat so that Pr[d <= d'] >= 99% (paper: GAMMA = 1.38, ell = 128).
"""
from __future__ import annotations

import numpy as np

from .hashing import derive_seed, poly4_coeffs, poly4_pm1

ELL_DEFAULT = 128
GAMMA = 1.38


def tow_sketches(elems: np.ndarray, seed: int, ell: int = ELL_DEFAULT) -> np.ndarray:
    """ell ToW sketches of a set: Y_i = sum_{s in S} f_i(s), f_i: U -> {±1}."""
    elems = np.asarray(elems, dtype=np.uint32)
    out = np.zeros(ell, dtype=np.int64)
    for i in range(ell):
        coeffs = poly4_coeffs(derive_seed(seed, 0xE57, i))
        out[i] = poly4_pm1(elems, coeffs).sum()
    return out


def estimate_d(sk_a: np.ndarray, sk_b: np.ndarray) -> float:
    """Unbiased estimate of |A △ B| from the two sketch vectors."""
    diff = (sk_a - sk_b).astype(np.float64)
    return float(np.mean(diff * diff))


def sketch_bytes(set_size: int, ell: int = ELL_DEFAULT) -> int:
    """Communication cost: each sketch is an int in [-|S|, |S|] (paper §6.1)."""
    bits_per = int(np.ceil(np.log2(2 * set_size + 1)))
    return (ell * bits_per + 7) // 8


def planned_d(d_hat: float, gamma: float = GAMMA) -> int:
    return max(1, int(np.ceil(gamma * d_hat)))
