"""The PBS set-reconciliation protocol (paper §2–§3), byte-accounted.

Unidirectional reconciliation: Alice learns A △ B.  Faithful to the paper:

* hash-partition into g = d/δ **groups** (fixed across rounds, §3) and, per
  round, into n **bins** with a fresh per-round hash (§2.4);
* per group, Alice sends the t·m-bit **BCH syndrome sketch** of her parity
  bitmap; Bob decodes the XOR of sketches to locate differing bins and replies
  with bin indices + his bin XOR sums + his group checksum (Procedure 2);
* Alice recovers one element per located bin via the XOR trick (Procedure 1),
  discards fakes with the sub-universe check (Procedure 3), and gates the
  group on the sum-mod-2^|key| checksum (§2.2.3);
* BCH decoding failures (> t differing bins) trigger the **3-way split**
  (§3.2); unreconciled groups re-run with fresh hashes (§2.4).

Every message is byte-accounted with the paper's accounting (Formula (1)),
so the benchmarks reproduce Fig. 1b/2b/3b directly.  All per-round bin
algebra is vectorized across *all* active units at once (segmented scatters +
the batched BM/Chien decoder) — the numpy mirror of the TPU formulation in
`repro.kernels`.

The round state machine is factored into pure pieces — ``plan_protocol`` /
``SessionState`` / ``group_view`` / ``slot_assignment`` / ``unit_tables`` /
``apply_round_outcomes`` / ``finalize_result`` — shared verbatim by the
batched multi-session engine in ``repro.recon`` (DESIGN.md §5), which swaps
only the numpy bin/sketch/decode tables for the accelerator kernels.
``reconcile`` below is the single-session composition of those pieces and is
the oracle the batched engine is validated against unit-for-unit.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace

import numpy as np

from .bch import (
    BCHCode,
    batched_decode,
    bch_code,
    decode_sketch,
    sketch_from_positions,
)
from .hashing import derive_seed, hash_to_range
from .markov import optimize_parameters
from .tow import (
    ELL_DEFAULT,
    GAMMA,
    dhat_bytes,
    estimate_numerator,
    planned_d,
    sketch_bytes,
    tow_sketches,
)

KEY_BITS = 32
_MOD = np.uint64(1) << np.uint64(KEY_BITS)

# Degradation-ladder caps (DESIGN.md §13/§16) — the single source of truth
# threaded through session/server/endpoint/hub as keyword defaults, so the
# wire-separated sides can never drift on when a session stops escalating.
#
# MAX_ESCALATIONS caps the legacy from-scratch re-plan ladder (doubled d̂
# per rung).  MAX_PARITY_EXTENSIONS caps the in-round rateless ladder:
# level e extends a unit's BCH capacity to min(t << e, (n-1)//2), so four
# levels reach 16t — enough headroom for a 10x-underestimated d̂ before
# the legacy ladder is consulted at all.
MAX_ESCALATIONS = 3
MAX_PARITY_EXTENSIONS = 4


def parity_extension_t(t: int, level: int, n: int) -> int:
    """Extended BCH capacity at rateless-extension level ``level`` (0 = the
    round's base sketch).  Deterministic from the cohort's (n, t) alone —
    both wire sides derive the identical t-ladder with zero negotiation.
    Doubling per level telescopes: a unit that decodes at level e has
    shipped exactly t_e * m syndrome bits total (prefix + increments ==
    the fresh (n, t_e) sketch), so no parity byte is ever wasted on a unit
    that eventually decodes.  Capped at (n-1)//2, where BM decoding runs
    out of syndrome equations; a level where the cap stops growth is the
    ladder's exhaustion signal.
    """
    return min(t << level, (n - 1) // 2)


def checksum(elems: np.ndarray) -> int:
    """c(S) = sum of elements mod 2^|key| (paper §2.2.3)."""
    return int(np.asarray(elems, dtype=np.uint64).sum() % _MOD)


@dataclass
class PBSConfig:
    delta: float = 5.0
    r_target: int = 3
    p0: float = 0.99
    ell: int = ELL_DEFAULT
    gamma: float = GAMMA
    max_rounds: int = 12          # hard stop far beyond the r=3 design point
    seed: int = 0
    convention: str = "split"     # parameter-optimizer convention
    n_override: int | None = None  # pin (n, t) instead of optimizing
    t_override: int | None = None
    g_override: int | None = None
    # rateless recovery (DESIGN.md §16): on BCH overload, extend the unit's
    # sketch in-round with incremental MSG_PARITY syndromes (prefix-
    # compatible, zero re-sent bits) before falling back to the 3-way
    # split.  Off by default: every success path stays byte-identical to
    # the paper's accounting, and overload handling matches §3.2 verbatim.
    rateless: bool = False


@dataclass
class Unit:
    """An active reconciliation unit: a group, or a split descendant of one."""

    uid: int
    group: int
    filters: tuple = ()  # ((seed, idx3), ...) from 3-way splits
    done: bool = False


@dataclass
class ReconcileResult:
    diff: set
    rounds: int
    success: bool
    bytes_sent: int               # protocol bytes (paper convention: sans estimator)
    estimator_bytes: int
    bytes_per_round: list = field(default_factory=list)
    n: int = 0
    t: int = 0
    g: int = 0
    d_est: float = 0.0
    decode_failures: int = 0
    fake_rejections: int = 0


# ---------------------------------------------------------------------------
# Pure protocol pieces (shared with the batched engine in repro.recon)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ProtocolPlan:
    """Everything phase 0 pins down for one Alice↔Bob session: the estimated
    difference, the optimized (n, t, g), and the derived hash seeds."""

    cfg: PBSConfig
    n: int
    t: int
    g: int
    d_est: float
    est_bytes: int
    seed_groups: int

    @property
    def code(self) -> BCHCode:
        return BCHCode(self.n, self.t)

    @property
    def m(self) -> int:
        return self.code.m


def _mk_plan(cfg: PBSConfig, d_est: float, d_plan: int, est_bytes: int) -> ProtocolPlan:
    g = cfg.g_override or max(1, round(d_plan / cfg.delta))
    if cfg.n_override is not None:
        n, t = cfg.n_override, cfg.t_override
    else:
        n, t, _, _ = optimize_parameters(
            d_plan, cfg.delta, cfg.r_target, cfg.p0, KEY_BITS, convention=cfg.convention
        )
    return ProtocolPlan(
        cfg=cfg, n=n, t=t, g=g, d_est=d_est, est_bytes=est_bytes,
        seed_groups=derive_seed(cfg.seed, 1),
    )


def plan_from_estimate(cfg: PBSConfig, numerator: int, set_size_a: int) -> ProtocolPlan:
    """Pin (n, t, g) from the phase-0 exchange: the d_hat numerator (what the
    MSG_DHAT reply carries — d_hat = numerator / ell) and Alice's set size
    (which sizes the sketch frame).  Both endpoints call this with identical
    inputs, so both derive the identical plan; est_bytes is the framed
    length of the two phase-0 messages."""
    d_est = numerator / cfg.ell
    est_bytes = sketch_bytes(set_size_a, cfg.ell) + dhat_bytes(numerator)
    return _mk_plan(cfg, d_est, planned_d(d_est, cfg.gamma), est_bytes)


def plan_from_d_known(cfg: PBSConfig, d_known: int) -> ProtocolPlan:
    """Pin (n, t, g) when d is known out-of-band (no estimator traffic)."""
    return _mk_plan(cfg, float(d_known), max(1, d_known), 0)


def escalated_plan(plan: ProtocolPlan, level: int = 1) -> ProtocolPlan:
    """Degradation-ladder rung ``level`` for a session whose round budget
    ran out with groups still undone (DESIGN.md §13): re-plan at the
    difference estimate doubled ``level`` times, with group seeds freshly
    derived per rung so the bin assignment that starved the decoder is
    reshuffled rather than replayed.  Deterministic from (plan, level) —
    both endpoints derive the identical rung with zero coordination
    traffic.  Each doubling shrinks the expected per-group difference
    d̂/g toward δ, so a rung exists where every group decodes; in the
    limit the ladder converges on the verify-everything exchange (the
    checksum/verify pass transfers any stragglers), which is why
    escalation terminates instead of looping.
    """
    if level < 1:
        raise ValueError(f"escalation level {level} out of range (must be >= 1)")
    cfg = plan.cfg
    d_est = max(float(plan.d_est), 1.0) * (1 << level)
    base = _mk_plan(cfg, d_est, planned_d(d_est, cfg.gamma), plan.est_bytes)
    return replace(base, seed_groups=derive_seed(cfg.seed, 0xE5, level))


def plan_protocol(
    a: np.ndarray, b: np.ndarray, cfg: PBSConfig, d_known: int | None = None
) -> ProtocolPlan:
    """Phase 0: estimate d with ToW unless known (§6.2), then optimize (n, t, g)."""
    if d_known is not None:
        return plan_from_d_known(cfg, d_known)
    seed_tow = derive_seed(cfg.seed, 0x70)
    sk_a = tow_sketches(a, seed_tow, cfg.ell)
    sk_b = tow_sketches(b, seed_tow, cfg.ell)
    return plan_from_estimate(cfg, estimate_numerator(sk_a, sk_b), len(a))


@dataclass
class SessionState:
    """Mutable per-session protocol state threaded through the rounds."""

    a: np.ndarray
    b: np.ndarray
    a_set: set
    diff: set
    units: list
    next_uid: int
    group_b: np.ndarray           # Bob's group ids (fixed across rounds)
    order_b: np.ndarray
    bounds_b: np.ndarray
    group_a: np.ndarray           # Alice's group ids over the *base* set A
    order_a: np.ndarray           # (fixed across rounds — grouping is round-
    bounds_a: np.ndarray          #  invariant; only diff membership changes)
    bytes_per_round: list = field(default_factory=list)
    rounds: int = 0
    decode_failures: int = 0
    fake_rejections: int = 0

    def active_units(self) -> list:
        return [u for u in self.units if not u.done]


def group_view(elems: np.ndarray, g: int, seed_groups: int):
    """Group ids + stable order + group boundaries for one element array."""
    grp = hash_to_range(elems, g, seed_groups)
    order = np.argsort(grp, kind="stable")
    bounds = np.searchsorted(grp[order], np.arange(g + 1))
    return grp, order, bounds


def new_session_state(a: np.ndarray, b: np.ndarray, plan: ProtocolPlan) -> SessionState:
    grp_b, order_b, bounds_b = group_view(b, plan.g, plan.seed_groups)
    grp_a, order_a, bounds_a = group_view(a, plan.g, plan.seed_groups)
    return SessionState(
        a=a, b=b, a_set=set(int(x) for x in a), diff=set(),
        units=[Unit(uid=i, group=i) for i in range(plan.g)], next_uid=plan.g,
        group_b=grp_b, order_b=order_b, bounds_b=bounds_b,
        group_a=grp_a, order_a=order_a, bounds_a=bounds_a,
    )


def effective_set(a: np.ndarray, diff: set) -> np.ndarray:
    """Alice's effective set A △ D̂ for the next round (§2.4)."""
    if not diff:
        return a
    diff_arr = np.fromiter(diff, dtype=np.uint32, count=len(diff))
    return np.concatenate([np.setdiff1d(a, diff_arr), np.setdiff1d(diff_arr, a)])


def diff_overlay(st: SessionState) -> tuple[np.ndarray, np.ndarray]:
    """Alice's effective set as a delta against her base set A.

    A △ D̂ = (A \\ removed) ∪ added with ``removed = A ∩ D̂`` (elements Alice
    must drop this round) and ``added = D̂ \\ A`` (recovered elements she must
    inject).  Both are tiny (≤ |D̂| ≤ d) — this is what lets the batched
    engine keep A device-resident and ship only the overlay per round
    (DESIGN.md §5) instead of materializing ``effective_set``.
    """
    if not st.diff:
        empty = np.zeros(0, dtype=np.uint32)
        return empty, empty
    d = np.fromiter(st.diff, dtype=np.uint32, count=len(st.diff))
    # membership via the session's resident a_set: same split as
    # np.isin(d, st.a) without re-sorting |A| elements every round
    in_a = np.fromiter((int(v) in st.a_set for v in d), dtype=bool, count=len(d))
    return d[in_a], d[~in_a]


def session_live(st: SessionState, cfg: PBSConfig, rnd: int) -> bool:
    """Does this session participate in round ``rnd``?  Shared by the
    batched planner and both ``repro.net`` endpoints — the two sides of the
    wire must agree on liveness to parse each other's round frames."""
    return rnd <= cfg.max_rounds and any(not u.done for u in st.units)


def queue_split(st: SessionState, u: Unit, rnd: int, cfg_seed: int) -> None:
    """BCH overload: retire ``u`` and enqueue its 3-way split (§3.2).

    The split seed and child uids are derived deterministically from
    (cfg seed, round, parent uid), so Alice and a wire-separated Bob that
    both observe the decode failure enqueue identical descendants.
    """
    st.decode_failures += 1
    split_seed = derive_seed(cfg_seed, 3, rnd, u.uid)
    u.done = True
    for k in range(3):
        st.units.append(
            Unit(uid=st.next_uid, group=u.group, filters=u.filters + ((split_seed, k),))
        )
        st.next_uid += 1


def slot_assignment(elems, group_of, units, group_order, group_bounds):
    """Map every element participating this round to its active-unit slot.

    Plain units (no filters) are resolved with one LUT gather; split units
    (rare) are resolved on their parent group's slice only.
    Returns (element_indices, slot_ids).
    """
    g = len(group_bounds) - 1
    lut = np.full(g, -1, dtype=np.int64)
    sel_idx: list[np.ndarray] = []
    sel_slot: list[np.ndarray] = []
    for slot, u in enumerate(units):
        if not u.filters:
            lut[u.group] = slot
        else:
            lo, hi = group_bounds[u.group], group_bounds[u.group + 1]
            idx = group_order[lo:hi]
            vals = elems[idx]
            mask = np.ones(len(idx), dtype=bool)
            for fs, fi in u.filters:
                mask &= hash_to_range(vals, 3, fs) == fi
            sel_idx.append(idx[mask])
            sel_slot.append(np.full(int(mask.sum()), slot, dtype=np.int64))
    plain_slot = lut[group_of]
    plain_sel = plain_slot >= 0
    sel_idx.append(np.nonzero(plain_sel)[0])
    sel_slot.append(plain_slot[plain_sel])
    return np.concatenate(sel_idx), np.concatenate(sel_slot)


def unit_tables(elems, idx, slots, n_units, n, bin_seed):
    """Per-(unit, bin) parity positions, XOR folds, and per-unit checksums.

    Returns (parity_slot, parity_pos, xors (n_units, n) uint32, csums (n_units,)).
    """
    vals = elems[idx]
    bins = hash_to_range(vals, n, bin_seed)
    flat = slots * n + bins
    counts = np.zeros(n_units * n, dtype=np.int64)
    np.add.at(counts, flat, 1)
    xors = np.zeros(n_units * n, dtype=np.uint32)
    np.bitwise_xor.at(xors, flat, vals.astype(np.uint32))
    csums = np.zeros(n_units, dtype=np.uint64)
    np.add.at(csums, slots, vals.astype(np.uint64))
    csums %= _MOD
    odd = np.nonzero(counts & 1)[0]
    return odd // n, odd % n, xors.reshape(n_units, n), csums


def segmented_sketches(code, slot_of_pos, positions, n_units):
    """BCH sketches for all units at once (segmented XOR over bit positions)."""
    out = np.zeros((n_units, code.t), dtype=np.int64)
    if len(positions):
        gf = code.field
        j = np.arange(code.t, dtype=np.int64)[None, :]
        vals = gf.pow_alpha(positions[:, None] * (2 * j + 1))  # (P, t)
        np.bitwise_xor.at(out, slot_of_pos, vals)
    return out


def segmented_sketches_range(code, t0, slot_of_pos, positions, n_units):
    """Incremental BCH syndromes S_{2*t0+1}..S_{2t-1} for all units at once.

    The ``[t0, code.t)`` column slice of ``segmented_sketches`` — the prefix
    property (``gf2m.syndrome_matrix_range``) makes concatenating this onto
    a cached ``segmented_sketches`` prefix bit-identical to sketching at
    ``code.t`` directly.  This is the oracle's ``MSG_PARITY`` payload
    (DESIGN.md §16)."""
    out = np.zeros((n_units, code.t - t0), dtype=np.int64)
    if len(positions):
        gf = code.field
        j = np.arange(t0, code.t, dtype=np.int64)[None, :]
        vals = gf.pow_alpha(positions[:, None] * (2 * j + 1))  # (P, t-t0)
        np.bitwise_xor.at(out, slot_of_pos, vals)
    return out


def rateless_extend(n, t, m, sk_diff, ok, positions, incremental):
    """In-round rateless recovery ladder (DESIGN.md §16), the shared oracle.

    Instead of surrendering every failed BCH decode to the 3-way split,
    level e = 1.. re-decodes the *same* round bitmaps at
    t_e = ``parity_extension_t(t, e, n)``: ``incremental(t0, t1)`` supplies
    the (U, t1-t0) incremental *diff* syndromes S_{2*t0+1}..S_{2*t1-1} for
    every unit, which concatenate onto the cached prefix — zero re-sent
    sketch bits.  The ladder stops when nothing fails, the level cap is
    reached, or the code cap (n-1)//2 stops t from growing.

    Returns (ok, positions, ext_bits, levels): merged outcomes plus the
    Formula-(1) ledger bits — per level, U_e failing units pay
    U_e * (Δt_e·m + 1), exactly what ``MSG_PARITY`` and its extension reply
    measure on the wire (repro.wire.parity_ledger_bits + the reply flags).
    """
    ok = np.asarray(ok, dtype=bool).copy()
    positions = list(positions)
    fail = ~ok
    if not fail.any():
        return ok, positions, 0, 0
    acc = np.asarray(sk_diff)
    ext_bits = 0
    levels = 0
    t_prev = t
    for level in range(1, MAX_PARITY_EXTENSIONS + 1):
        t_e = parity_extension_t(t, level, n)
        if t_e <= t_prev:
            break  # code cap reached: ladder exhausted, splits take over
        acc = np.concatenate([acc, incremental(t_prev, t_e)], axis=1)
        ext_bits += int(fail.sum()) * ((t_e - t_prev) * m + 1)
        levels += 1
        code_e = bch_code(n, t_e)
        for slot in np.flatnonzero(fail):
            ok_e, pos_e = decode_sketch(code_e, acc[slot])
            if ok_e:
                ok[slot] = True
                positions[slot] = pos_e.astype(np.int64)
                fail[slot] = False
        t_prev = t_e
        if not fail.any():
            break
    return ok, positions, ext_bits, levels


def apply_round_outcomes(
    st: SessionState,
    active: list,
    ok,
    positions,
    xors_a: np.ndarray,
    xors_b: np.ndarray,
    csum_a: np.ndarray,
    csum_b: np.ndarray,
    *,
    plan: ProtocolPlan,
    bin_seed: int,
    rnd: int,
) -> tuple[int, list[bool]]:
    """Alice's per-unit endgame for one round: recovery via the XOR trick
    (Procedure 1), fake rejection (Procedure 3), checksum gating (§2.2.3),
    and the 3-way-split re-queue on BCH overload (§3.2).

    All arrays are indexed by the unit's position (slot) in ``active``:
    ``positions[slot]`` is the decoded bin index array, ``xors_*[slot]`` the
    (n,) per-bin XOR folds, ``csum_*[slot]`` the unit checksums.  Mutates
    ``st`` (diff, unit queue, counters) and returns (bits, done): the
    Bob->Alice bits this round adds to Formula (1) — the caller accounts
    the Alice->Bob sketches — and the per-slot checksum-settled flags that
    the endpoint path ships to Bob as the round-outcome frame so he can
    mirror the unit queue.
    """
    cfg, n, g, m = plan.cfg, plan.n, plan.g, plan.m
    bits = 0
    done = [False] * len(active)
    for slot, u in enumerate(active):
        if not ok[slot]:
            queue_split(st, u, rnd, cfg.seed)
            continue
        pos = positions[slot]
        # Bob -> Alice: bin indices, his XOR sums, his checksum (Formula 1).
        bits += len(pos) * (m + KEY_BITS) + KEY_BITS
        delta_sum = 0
        newly = []
        for p in pos:
            s = int(xors_a[slot, int(p)] ^ xors_b[slot, int(p)])
            if s == 0:
                st.fake_rejections += 1
                continue
            sx = np.array([s], dtype=np.uint32)
            # Procedure 3: s must belong to this unit's sub-universe.
            if (
                int(hash_to_range(sx, n, bin_seed)[0]) != int(p)
                or int(hash_to_range(sx, g, plan.seed_groups)[0]) != u.group
                or any(int(hash_to_range(sx, 3, fs)[0]) != fk for fs, fk in u.filters)
            ):
                st.fake_rejections += 1
                continue
            newly.append(s)
            in_eff = (s in st.a_set) ^ (s in st.diff)
            delta_sum += -s if in_eff else s
        for s in newly:
            st.diff.symmetric_difference_update((s,))
        new_csum = int((int(csum_a[slot]) + delta_sum) % (1 << KEY_BITS))
        if new_csum == int(csum_b[slot]):
            u.done = True
            done[slot] = True
    return bits, done


def finalize_result(st: SessionState, plan: ProtocolPlan) -> ReconcileResult:
    return ReconcileResult(
        diff=st.diff,
        rounds=st.rounds,
        success=all(u.done for u in st.units),
        bytes_sent=sum(st.bytes_per_round),
        estimator_bytes=plan.est_bytes,
        bytes_per_round=st.bytes_per_round,
        n=plan.n,
        t=plan.t,
        g=plan.g,
        d_est=plan.d_est,
        decode_failures=st.decode_failures,
        fake_rejections=st.fake_rejections,
    )


# ---------------------------------------------------------------------------
# Single-session protocol loop (the numpy oracle)
# ---------------------------------------------------------------------------


def reconcile(
    set_a: np.ndarray,
    set_b: np.ndarray,
    cfg: PBSConfig | None = None,
    d_known: int | None = None,
) -> ReconcileResult:
    """Run the full PBS protocol; Alice (holding A) learns A △ B."""
    cfg = cfg or PBSConfig()
    a = np.unique(np.asarray(set_a, dtype=np.uint32))
    b = np.unique(np.asarray(set_b, dtype=np.uint32))

    plan = plan_protocol(a, b, cfg, d_known)
    code = plan.code
    n, t, g, m = plan.n, plan.t, plan.g, plan.m
    st = new_session_state(a, b, plan)

    for rnd in range(1, cfg.max_rounds + 1):
        active = st.active_units()
        if not active:
            break
        st.rounds = rnd
        bin_seed = derive_seed(cfg.seed, 2, rnd)
        n_units = len(active)

        eff_a = effective_set(a, st.diff)
        group_eff, order_a, bounds_a = group_view(eff_a, g, plan.seed_groups)

        idx_a, slot_a = slot_assignment(eff_a, group_eff, active, order_a, bounds_a)
        idx_b, slot_b = slot_assignment(b, st.group_b, active, st.order_b, st.bounds_b)

        pslot_a, ppos_a, xors_a, csum_a = unit_tables(eff_a, idx_a, slot_a, n_units, n, bin_seed)
        pslot_b, ppos_b, xors_b, csum_b = unit_tables(b, idx_b, slot_b, n_units, n, bin_seed)

        sk_a_all = segmented_sketches(code, pslot_a, ppos_a, n_units)
        sk_b_all = segmented_sketches(code, pslot_b, ppos_b, n_units)
        round_bits = n_units * (t * m + 1)  # Alice->Bob sketches + ok flags

        sk_diff = sk_a_all ^ sk_b_all
        ok, err_positions = batched_decode(code, sk_diff)
        if cfg.rateless and not np.asarray(ok, dtype=bool).all():

            def _inc(t0, t1):
                code_e = bch_code(n, t1)
                return segmented_sketches_range(
                    code_e, t0, pslot_a, ppos_a, n_units
                ) ^ segmented_sketches_range(code_e, t0, pslot_b, ppos_b, n_units)

            ok, err_positions, ext_bits, _ = rateless_extend(
                n, t, m, sk_diff, ok, err_positions, _inc
            )
            round_bits += ext_bits

        reply_bits, _ = apply_round_outcomes(
            st, active, ok, err_positions, xors_a, xors_b, csum_a, csum_b,
            plan=plan, bin_seed=bin_seed, rnd=rnd,
        )
        st.bytes_per_round.append((round_bits + reply_bits + 7) // 8)

    return finalize_result(st, plan)


def reconcile_small(
    set_a: np.ndarray, set_b: np.ndarray, n: int, t: int, seed: int = 0, max_rounds: int = 12
) -> ReconcileResult:
    """PBS-for-small-d (§2): a single group pair with pinned (n, t)."""
    cfg = PBSConfig(
        seed=seed, n_override=n, t_override=t, g_override=1, max_rounds=max_rounds
    )
    return reconcile(set_a, set_b, cfg, d_known=max(1, t // 2))


def true_diff(set_a: np.ndarray, set_b: np.ndarray) -> set:
    a = set(int(x) for x in np.asarray(set_a).ravel())
    b = set(int(x) for x in np.asarray(set_b).ravel())
    return a ^ b
