"""The PBS set-reconciliation protocol (paper §2–§3), byte-accounted.

Unidirectional reconciliation: Alice learns A △ B.  Faithful to the paper:

* hash-partition into g = d/δ **groups** (fixed across rounds, §3) and, per
  round, into n **bins** with a fresh per-round hash (§2.4);
* per group, Alice sends the t·m-bit **BCH syndrome sketch** of her parity
  bitmap; Bob decodes the XOR of sketches to locate differing bins and replies
  with bin indices + his bin XOR sums + his group checksum (Procedure 2);
* Alice recovers one element per located bin via the XOR trick (Procedure 1),
  discards fakes with the sub-universe check (Procedure 3), and gates the
  group on the sum-mod-2^|key| checksum (§2.2.3);
* BCH decoding failures (> t differing bins) trigger the **3-way split**
  (§3.2); unreconciled groups re-run with fresh hashes (§2.4).

Every message is byte-accounted with the paper's accounting (Formula (1)),
so the benchmarks reproduce Fig. 1b/2b/3b directly.  All per-round bin
algebra is vectorized across *all* active units at once (segmented scatters +
the batched BM/Chien decoder) — the numpy mirror of the TPU formulation in
`repro.kernels`, which is tested against this implementation.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .bch import BCHCode, batched_decode, sketch_from_positions
from .hashing import derive_seed, hash_to_range
from .markov import optimize_parameters
from .tow import ELL_DEFAULT, GAMMA, estimate_d, planned_d, sketch_bytes, tow_sketches

KEY_BITS = 32
_MOD = np.uint64(1) << np.uint64(KEY_BITS)


def checksum(elems: np.ndarray) -> int:
    """c(S) = sum of elements mod 2^|key| (paper §2.2.3)."""
    return int(np.asarray(elems, dtype=np.uint64).sum() % _MOD)


@dataclass
class PBSConfig:
    delta: float = 5.0
    r_target: int = 3
    p0: float = 0.99
    ell: int = ELL_DEFAULT
    gamma: float = GAMMA
    max_rounds: int = 12          # hard stop far beyond the r=3 design point
    seed: int = 0
    convention: str = "split"     # parameter-optimizer convention
    n_override: int | None = None  # pin (n, t) instead of optimizing
    t_override: int | None = None
    g_override: int | None = None


@dataclass
class Unit:
    """An active reconciliation unit: a group, or a split descendant of one."""

    uid: int
    group: int
    filters: tuple = ()  # ((seed, idx3), ...) from 3-way splits
    done: bool = False


@dataclass
class ReconcileResult:
    diff: set
    rounds: int
    success: bool
    bytes_sent: int               # protocol bytes (paper convention: sans estimator)
    estimator_bytes: int
    bytes_per_round: list = field(default_factory=list)
    n: int = 0
    t: int = 0
    g: int = 0
    d_est: float = 0.0
    decode_failures: int = 0
    fake_rejections: int = 0


def _slot_assignment(elems, group_of, units, group_order, group_bounds):
    """Map every element participating this round to its active-unit slot.

    Plain units (no filters) are resolved with one LUT gather; split units
    (rare) are resolved on their parent group's slice only.
    Returns (element_indices, slot_ids).
    """
    g = len(group_bounds) - 1
    lut = np.full(g, -1, dtype=np.int64)
    sel_idx: list[np.ndarray] = []
    sel_slot: list[np.ndarray] = []
    for slot, u in enumerate(units):
        if not u.filters:
            lut[u.group] = slot
        else:
            lo, hi = group_bounds[u.group], group_bounds[u.group + 1]
            idx = group_order[lo:hi]
            vals = elems[idx]
            mask = np.ones(len(idx), dtype=bool)
            for fs, fi in u.filters:
                mask &= hash_to_range(vals, 3, fs) == fi
            sel_idx.append(idx[mask])
            sel_slot.append(np.full(int(mask.sum()), slot, dtype=np.int64))
    plain_slot = lut[group_of]
    plain_sel = plain_slot >= 0
    sel_idx.append(np.nonzero(plain_sel)[0])
    sel_slot.append(plain_slot[plain_sel])
    return np.concatenate(sel_idx), np.concatenate(sel_slot)


def _unit_tables(elems, idx, slots, n_units, n, bin_seed):
    """Per-(unit, bin) parity positions, XOR folds, and per-unit checksums."""
    vals = elems[idx]
    bins = hash_to_range(vals, n, bin_seed)
    flat = slots * n + bins
    counts = np.zeros(n_units * n, dtype=np.int64)
    np.add.at(counts, flat, 1)
    xors = np.zeros(n_units * n, dtype=np.uint32)
    np.bitwise_xor.at(xors, flat, vals.astype(np.uint32))
    csums = np.zeros(n_units, dtype=np.uint64)
    np.add.at(csums, slots, vals.astype(np.uint64))
    csums %= _MOD
    odd = np.nonzero(counts & 1)[0]
    return odd // n, odd % n, xors, csums


def _segmented_sketches(code, slot_of_pos, positions, n_units):
    """BCH sketches for all units at once (segmented XOR over bit positions)."""
    out = np.zeros((n_units, code.t), dtype=np.int64)
    if len(positions):
        gf = code.field
        j = np.arange(code.t, dtype=np.int64)[None, :]
        vals = gf.pow_alpha(positions[:, None] * (2 * j + 1))  # (P, t)
        np.bitwise_xor.at(out, slot_of_pos, vals)
    return out


def reconcile(
    set_a: np.ndarray,
    set_b: np.ndarray,
    cfg: PBSConfig | None = None,
    d_known: int | None = None,
) -> ReconcileResult:
    """Run the full PBS protocol; Alice (holding A) learns A △ B."""
    cfg = cfg or PBSConfig()
    a = np.unique(np.asarray(set_a, dtype=np.uint32))
    b = np.unique(np.asarray(set_b, dtype=np.uint32))

    # --- Phase 0: estimate d with ToW unless known (paper §6.2) -----------
    est_bytes = 0
    if d_known is None:
        seed_tow = derive_seed(cfg.seed, 0x70)
        sk_a = tow_sketches(a, seed_tow, cfg.ell)
        sk_b = tow_sketches(b, seed_tow, cfg.ell)
        d_est = estimate_d(sk_a, sk_b)
        est_bytes = sketch_bytes(len(a), cfg.ell) + 4  # A->B sketches, B->A d_hat
        d_plan = planned_d(d_est, cfg.gamma)
    else:
        d_est = float(d_known)
        d_plan = max(1, d_known)

    g = cfg.g_override or max(1, round(d_plan / cfg.delta))
    if cfg.n_override is not None:
        n, t = cfg.n_override, cfg.t_override
    else:
        n, t, _, _ = optimize_parameters(
            d_plan, cfg.delta, cfg.r_target, cfg.p0, KEY_BITS, convention=cfg.convention
        )
    code = BCHCode(n, t)
    m = code.m

    seed_groups = derive_seed(cfg.seed, 1)
    group_b = hash_to_range(b, g, seed_groups)
    order_b = np.argsort(group_b, kind="stable")
    bounds_b = np.searchsorted(group_b[order_b], np.arange(g + 1))

    a_set = set(int(x) for x in a)
    units = [Unit(uid=i, group=i) for i in range(g)]
    next_uid = g
    diff: set[int] = set()
    bytes_per_round: list[int] = []
    decode_failures = fake_rejections = 0
    success = False
    rounds = 0

    for rnd in range(1, cfg.max_rounds + 1):
        active = [u for u in units if not u.done]
        if not active:
            success = True
            break
        rounds = rnd
        round_bits = 0
        bin_seed = derive_seed(cfg.seed, 2, rnd)
        n_units = len(active)

        # Alice's effective set is A △ D̂ (§2.4).
        if diff:
            diff_arr = np.fromiter(diff, dtype=np.uint32, count=len(diff))
            eff_a = np.concatenate(
                [np.setdiff1d(a, diff_arr), np.setdiff1d(diff_arr, a)]
            )
        else:
            eff_a = a
        group_eff = hash_to_range(eff_a, g, seed_groups)
        order_a = np.argsort(group_eff, kind="stable")
        bounds_a = np.searchsorted(group_eff[order_a], np.arange(g + 1))

        idx_a, slot_a = _slot_assignment(eff_a, group_eff, active, order_a, bounds_a)
        idx_b, slot_b = _slot_assignment(b, group_b, active, order_b, bounds_b)

        pslot_a, ppos_a, xors_a, _ = _unit_tables(eff_a, idx_a, slot_a, n_units, n, bin_seed)
        pslot_b, ppos_b, xors_b, csum_b = _unit_tables(b, idx_b, slot_b, n_units, n, bin_seed)

        sk_a_all = _segmented_sketches(code, pslot_a, ppos_a, n_units)
        sk_b_all = _segmented_sketches(code, pslot_b, ppos_b, n_units)
        round_bits += n_units * (t * m + 1)  # Alice->Bob sketches + ok flags

        ok, err_positions = batched_decode(code, sk_a_all ^ sk_b_all)

        # Per-unit outcomes.  Recovery + checksum gating is O(found elements).
        csum_a = np.zeros(n_units, dtype=np.uint64)
        np.add.at(csum_a, slot_a, eff_a[idx_a].astype(np.uint64))
        csum_a %= _MOD

        for slot, u in enumerate(active):
            if not ok[slot]:
                decode_failures += 1
                split_seed = derive_seed(cfg.seed, 3, rnd, u.uid)
                u.done = True
                for k in range(3):
                    units.append(
                        Unit(uid=next_uid, group=u.group, filters=u.filters + ((split_seed, k),))
                    )
                    next_uid += 1
                continue
            pos = err_positions[slot]
            # Bob -> Alice: bin indices, his XOR sums, his checksum (Formula 1).
            round_bits += len(pos) * (m + KEY_BITS) + KEY_BITS
            delta_sum = 0
            newly = []
            for p in pos:
                fi = slot * n + int(p)
                s = int(xors_a[fi] ^ xors_b[fi])
                if s == 0:
                    fake_rejections += 1
                    continue
                sx = np.array([s], dtype=np.uint32)
                # Procedure 3: s must belong to this unit's sub-universe.
                if (
                    int(hash_to_range(sx, n, bin_seed)[0]) != int(p)
                    or int(hash_to_range(sx, g, seed_groups)[0]) != u.group
                    or any(int(hash_to_range(sx, 3, fs)[0]) != fk for fs, fk in u.filters)
                ):
                    fake_rejections += 1
                    continue
                newly.append(s)
                in_eff = (s in a_set) ^ (s in diff)
                delta_sum += -s if in_eff else s
            for s in newly:
                diff.symmetric_difference_update((s,))
            new_csum = int((int(csum_a[slot]) + delta_sum) % (1 << KEY_BITS))
            if new_csum == int(csum_b[slot]):
                u.done = True

        bytes_per_round.append((round_bits + 7) // 8)
    else:
        success = all(u.done for u in units)

    return ReconcileResult(
        diff=diff,
        rounds=rounds,
        success=success,
        bytes_sent=sum(bytes_per_round),
        estimator_bytes=est_bytes,
        bytes_per_round=bytes_per_round,
        n=n,
        t=t,
        g=g,
        d_est=d_est,
        decode_failures=decode_failures,
        fake_rejections=fake_rejections,
    )


def reconcile_small(
    set_a: np.ndarray, set_b: np.ndarray, n: int, t: int, seed: int = 0, max_rounds: int = 12
) -> ReconcileResult:
    """PBS-for-small-d (§2): a single group pair with pinned (n, t)."""
    cfg = PBSConfig(
        seed=seed, n_override=n, t_override=t, g_override=1, max_rounds=max_rounds
    )
    return reconcile(set_a, set_b, cfg, d_known=max(1, t // 2))


def true_diff(set_a: np.ndarray, set_b: np.ndarray) -> set:
    a = set(int(x) for x in np.asarray(set_a).ravel())
    b = set(int(x) for x in np.asarray(set_b).ravel())
    return a ^ b
