"""Serving engine: prefill and one-token decode step factories.

Decode distribution (DESIGN.md §4): the residual stream is **replicated over
'model'** (a single token is tiny) while long KV/latent caches are
**sequence-sharded over 'model'** (context parallelism) and batch-sharded
over the data axes; attention partials are LSE-combined across shards
(flash-decoding).  SSM/RG-LRU caches are O(1) per token — their channel/head
dims shard over 'model' — which is why `long_500k` runs for those families.

Cache layout is declared as a `P` tree (`cache_spec`) from the same
source-of-truth system as parameters, so the dry-run lowers `decode_step`
against `ShapeDtypeStruct`s with zero allocation, and prefill's shard_map
out_specs / decode's in_specs are guaranteed consistent.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from repro.models.attention import (
    cross_decode,
    cross_fill_cache,
    gqa_apply,
    gqa_decode,
    gqa_fill_cache,
    gqa_init_cache,
    local_decode,
    local_fill_cache,
    mla_apply,
    mla_decode,
    mla_fill_cache,
    mla_init_cache,
)
from repro.models.backbone import (
    embed_tokens,
    encode,
    greedy_token,
    layer_plan,
    model_spec,
)
from repro.models.config import ModelConfig
from repro.models.ffn import mlp_apply, mlp_decode, moe_apply, moe_decode
from repro.models.layers import MeshCtx, apply_norm
from repro.models.rglru import rglru_apply, rglru_decode, rglru_init_cache
from repro.models.spec import P, abstract_params, pspecs, stack_layers
from repro.models.ssm import ssm_apply, ssm_decode, ssm_init_cache
from repro.train.step import batch_axes, mesh_ctx, mesh_sizes


# ---------------------------------------------------------------------------
# cache P-spec tree (one source of truth for shapes + shardings)
# ---------------------------------------------------------------------------


def _kind_cache_spec(cfg: ModelConfig, kind: str, ba, batch: int, max_len: int,
                     enc_len: int) -> dict:
    dh = cfg.resolved_head_dim
    i32 = jnp.int32
    if kind in ("attn", "attn_window") and kind == "attn":
        return {
            "k": P((batch, cfg.n_kv_heads, max_len, dh), (ba, None, "model", None), "zeros", dtype=jnp.bfloat16),
            "v": P((batch, cfg.n_kv_heads, max_len, dh), (ba, None, "model", None), "zeros", dtype=jnp.bfloat16),
            "len": P((), (), "zeros", dtype=i32),
        }
    if kind == "attn_window":
        w = cfg.window
        return {
            "k": P((batch, cfg.n_kv_heads, w, dh), (ba, None, None, None), "zeros", dtype=jnp.bfloat16),
            "v": P((batch, cfg.n_kv_heads, w, dh), (ba, None, None, None), "zeros", dtype=jnp.bfloat16),
            "len": P((), (), "zeros", dtype=i32),
        }
    if kind in ("mla_dense", "mla_moe"):
        return {
            "c_kv": P((batch, max_len, cfg.kv_lora), (ba, "model", None), "zeros", dtype=jnp.bfloat16),
            "k_rope": P((batch, max_len, cfg.rope_head_dim), (ba, "model", None), "zeros", dtype=jnp.bfloat16),
            "len": P((), (), "zeros", dtype=i32),
        }
    if kind == "ssm":
        d_inner = cfg.d_model * cfg.ssm_expand
        H = d_inner // cfg.ssm_headdim
        G, N, K = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_conv
        return {
            "ssd": P((batch, H, cfg.ssm_headdim, N), (ba, "model", None, None), "zeros", dtype=jnp.float32),
            "conv": {
                "x": P((batch, K - 1, d_inner), (ba, None, "model"), "zeros", dtype=jnp.bfloat16),
                "bc": P((batch, K - 1, 2 * G * N), (ba, None, None), "zeros", dtype=jnp.bfloat16),
            },
            "len": P((), (), "zeros", dtype=i32),
        }
    if kind == "rglru":
        # sequence-parallel RG-LRU: weights + decode state replicated over
        # 'model' (batch-sharded only) — see repro.models.rglru
        w = cfg.lru_width
        return {
            "h": P((batch, w), (ba, None), "zeros", dtype=jnp.float32),
            "conv": P((batch, 3, w), (ba, None, None), "zeros", dtype=jnp.bfloat16),
            "len": P((), (), "zeros", dtype=i32),
        }
    if kind == "dec":
        return {
            "self": _kind_cache_spec(cfg, "attn", ba, batch, max_len, enc_len),
            "cross": {
                "k": P((batch, cfg.n_kv_heads, enc_len, dh), (ba, None, "model", None), "zeros", dtype=jnp.bfloat16),
                "v": P((batch, cfg.n_kv_heads, enc_len, dh), (ba, None, "model", None), "zeros", dtype=jnp.bfloat16),
                "len": P((), (), "zeros", dtype=i32),
            },
        }
    raise ValueError(kind)


def cache_spec(cfg: ModelConfig, mesh, batch: int, max_len: int, enc_len: int = 1536):
    ba = batch_axes(mesh, batch)
    tree = {}
    for gi, (kind, count, scanned) in enumerate(layer_plan(cfg)):
        if count == 0:
            continue
        if kind == "hybrid_period":
            base = {
                f"b{i}": _kind_cache_spec(
                    cfg, "rglru" if k == "rglru" else "attn_window", ba, batch, max_len, enc_len
                )
                for i, k in enumerate(cfg.pattern)
            }
        else:
            base = _kind_cache_spec(cfg, kind, ba, batch, max_len, enc_len)
        tree[f"g{gi}"] = stack_layers(base, count) if scanned else (
            {f"l{i}": base for i in range(count)} if count > 1 else base
        )
    return tree


# ---------------------------------------------------------------------------
# per-kind prefill / decode block functions
# ---------------------------------------------------------------------------


def _prefill_block(cfg, ctx, kind, ep_data, max_len, batch, *, memory=None):
    def attn(p, x):
        h, (k, v) = gqa_apply(p["attn"], apply_norm(p["ln1"], x, cfg), ctx, cfg,
                              causal=True, return_kv=True)
        x = x + h
        x = x + mlp_apply(p["mlp"], apply_norm(p["ln2"], x, cfg), ctx, cfg)
        init = gqa_init_cache(cfg, ctx, batch, max_len)
        return x, gqa_fill_cache(init, k, v, ctx)

    def attn_window(p, x):
        h, (k, v) = gqa_apply(p["attn"], apply_norm(p["ln1"], x, cfg), ctx, cfg,
                              causal=True, window=cfg.window, return_kv=True)
        x = x + h
        x = x + mlp_apply(p["mlp"], apply_norm(p["ln2"], x, cfg), ctx, cfg)
        return x, local_fill_cache(None, k, v, cfg)

    def mla_dense(p, x):
        h, (c_kv, k_rope) = mla_apply(p["attn"], apply_norm(p["ln1"], x, cfg), ctx, cfg,
                                      return_latent=True)
        x = x + h
        x = x + mlp_apply(p["mlp"], apply_norm(p["ln2"], x, cfg), ctx, cfg)
        init = mla_init_cache(cfg, ctx, batch, max_len)
        return x, mla_fill_cache(init, c_kv, k_rope, ctx)

    def mla_moe(p, x):
        h, (c_kv, k_rope) = mla_apply(p["attn"], apply_norm(p["ln1"], x, cfg), ctx, cfg,
                                      return_latent=True)
        x = x + h
        y, _ = moe_apply(p["moe"], apply_norm(p["ln2"], x, cfg), ctx, cfg, ep_data)
        init = mla_init_cache(cfg, ctx, batch, max_len)
        return x + y, mla_fill_cache(init, c_kv, k_rope, ctx)

    def ssm(p, x):
        h, state = ssm_apply(p["ssm"], apply_norm(p["ln1"], x, cfg), ctx, cfg,
                             return_state=True)
        return x + h, state

    def rglru(p, x):
        h, state = rglru_apply(p["rec"], apply_norm(p["ln1"], x, cfg), ctx, cfg,
                               return_state=True)
        x = x + h
        x = x + mlp_apply(p["mlp"], apply_norm(p["ln2"], x, cfg), ctx, cfg)
        return x, state

    def dec(p, x):
        h, (k, v) = gqa_apply(p["attn"], apply_norm(p["ln1"], x, cfg), ctx, cfg,
                              causal=True, return_kv=True)
        x = x + h
        x = x + gqa_apply(p["cross"], apply_norm(p["lnx"], x, cfg), ctx, cfg,
                          causal=False, memory=memory)
        x = x + mlp_apply(p["mlp"], apply_norm(p["ln2"], x, cfg), ctx, cfg)
        init = gqa_init_cache(cfg, ctx, batch, max_len)
        cache = {
            "self": gqa_fill_cache(init, k, v, ctx),
            "cross": cross_fill_cache(p["cross"], memory, cfg, ctx),
        }
        return x, cache

    return {
        "attn": attn, "attn_window": attn_window, "mla_dense": mla_dense,
        "mla_moe": mla_moe, "ssm": ssm, "rglru": rglru, "dec": dec,
    }[kind]


def _decode_block(cfg, ctx, kind, ep_data):
    def attn(p, x, c):
        h, c2 = gqa_decode(p["attn"], apply_norm(p["ln1"], x, cfg), c, ctx, cfg)
        x = x + h
        x = x + mlp_decode(p["mlp"], apply_norm(p["ln2"], x, cfg), ctx, cfg)
        return x, c2

    def attn_window(p, x, c):
        h, c2 = local_decode(p["attn"], apply_norm(p["ln1"], x, cfg), c, ctx, cfg)
        x = x + h
        x = x + mlp_decode(p["mlp"], apply_norm(p["ln2"], x, cfg), ctx, cfg)
        return x, c2

    def mla_dense(p, x, c):
        h, c2 = mla_decode(p["attn"], apply_norm(p["ln1"], x, cfg), c, ctx, cfg)
        x = x + h
        x = x + mlp_decode(p["mlp"], apply_norm(p["ln2"], x, cfg), ctx, cfg)
        return x, c2

    def mla_moe(p, x, c):
        h, c2 = mla_decode(p["attn"], apply_norm(p["ln1"], x, cfg), c, ctx, cfg)
        x = x + h
        y, _ = moe_decode(p["moe"], apply_norm(p["ln2"], x, cfg), ctx, cfg, ep_data)
        return x + y, c2

    def ssm(p, x, c):
        h, c2 = ssm_decode(p["ssm"], apply_norm(p["ln1"], x, cfg), c, ctx, cfg)
        return x + h, c2

    def rglru(p, x, c):
        h, c2 = rglru_decode(p["rec"], apply_norm(p["ln1"], x, cfg), c, ctx, cfg)
        x = x + h
        x = x + mlp_decode(p["mlp"], apply_norm(p["ln2"], x, cfg), ctx, cfg)
        return x, c2

    def dec(p, x, c):
        h, c2self = gqa_decode(p["attn"], apply_norm(p["ln1"], x, cfg), c["self"], ctx, cfg)
        x = x + h
        x = x + cross_decode(p["cross"], apply_norm(p["lnx"], x, cfg), c["cross"], ctx, cfg)
        x = x + mlp_decode(p["mlp"], apply_norm(p["ln2"], x, cfg), ctx, cfg)
        return x, {"self": c2self, "cross": c["cross"]}

    return {
        "attn": attn, "attn_window": attn_window, "mla_dense": mla_dense,
        "mla_moe": mla_moe, "ssm": ssm, "rglru": rglru, "dec": dec,
    }[kind]


def _hybrid_kind(k: str) -> str:
    return "rglru" if k == "rglru" else "attn_window"


# ---------------------------------------------------------------------------
# step factories
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ServeBundle:
    prefill: callable | None
    decode: callable
    param_spec: dict
    cache_pspec: dict
    batch_ax: object
    ctx: MeshCtx


def _sh(mesh, tree_ps):
    return jax.tree.map(
        lambda ps: NamedSharding(mesh, ps), tree_ps,
        is_leaf=lambda x: isinstance(x, PartitionSpec),
    )


def make_serve_fns(cfg: ModelConfig, mesh, *, batch: int, max_len: int,
                   enc_len: int = 1536) -> ServeBundle:
    ctx = mesh_ctx(mesh)
    sizes = mesh_sizes(mesh)
    ep_data = sizes.get("data", 1)
    spec = model_spec(cfg, ctx)
    p_ps = pspecs(spec)
    c_spec = cache_spec(cfg, mesh, batch, max_len, enc_len)
    c_ps = pspecs(c_spec)
    ba = batch_axes(mesh, batch)
    plan = layer_plan(cfg)

    # ---------------- prefill ----------------
    def local_prefill(params, inputs):
        tokens = inputs["tokens"]                       # (B_l, T/M)
        x = embed_tokens(params["embed"], jnp.maximum(tokens, 0), ctx, cfg)
        if "frontend" in inputs:
            x = jnp.where((tokens < 0)[..., None], inputs["frontend"].astype(x.dtype), x)
        memory = (
            encode(params, inputs["enc"], ctx, cfg, remat=False)
            if cfg.family == "encdec" else None
        )
        caches = {}
        for gi, (kind, count, scanned) in enumerate(plan):
            if count == 0:
                continue
            p = params[f"g{gi}"]
            if kind == "hybrid_period":
                fns = [
                    _prefill_block(cfg, ctx, _hybrid_kind(k), ep_data, max_len, batch)
                    for k in cfg.pattern
                ]

                def period_fn(xx, pp):
                    cc = {}
                    for i, f in enumerate(fns):
                        xx, ci = f(pp[f"b{i}"], xx)
                        cc[f"b{i}"] = ci
                    return xx, cc

                x, caches[f"g{gi}"] = jax.lax.scan(period_fn, x, p)
            else:
                fn = _prefill_block(cfg, ctx, kind, ep_data, max_len, batch, memory=memory)
                if scanned:
                    x, caches[f"g{gi}"] = jax.lax.scan(lambda xx, pp: fn(pp, xx), x, p)
                elif count == 1:
                    x, caches[f"g{gi}"] = fn(p, x)
                else:
                    cc = {}
                    for i in range(count):
                        x, cc[f"l{i}"] = fn(p[f"l{i}"], x)
                    caches[f"g{gi}"] = cc
        x = apply_norm(params["final_norm"], x, cfg)
        if ctx.model_size > 1:
            lasts = jax.lax.all_gather(x[:, -1:], ctx.m)    # (M, B_l, 1, d)
            x_last = lasts[-1]
        else:
            x_last = x[:, -1:]
        token = greedy_token(params["embed"], x_last, ctx, cfg)
        return caches, token

    # ---------------- decode ----------------
    def local_decode_step(params, caches, tokens):
        # (B_l, 1, d), replicated over 'model'
        x = embed_tokens(params["embed"], tokens, ctx, cfg, seq_sharded=False)
        new_caches = {}
        for gi, (kind, count, scanned) in enumerate(plan):
            if count == 0:
                continue
            p = params[f"g{gi}"]
            c = caches[f"g{gi}"]
            if kind == "hybrid_period":
                fns = [
                    _decode_block(cfg, ctx, _hybrid_kind(k), ep_data)
                    for k in cfg.pattern
                ]

                def period_fn(xx, inp):
                    pp, cc = inp
                    c2 = {}
                    for i, f in enumerate(fns):
                        xx, ci = f(pp[f"b{i}"], xx, cc[f"b{i}"])
                        c2[f"b{i}"] = ci
                    return xx, c2

                x, new_caches[f"g{gi}"] = jax.lax.scan(period_fn, x, (p, c))
            else:
                fn = _decode_block(cfg, ctx, kind, ep_data)
                if scanned:
                    def step_fn(xx, inp):
                        pp, cc = inp
                        return fn(pp, xx, cc)

                    x, new_caches[f"g{gi}"] = jax.lax.scan(step_fn, x, (p, c))
                elif count == 1:
                    x, new_caches[f"g{gi}"] = fn(p, x, c)
                else:
                    cc2 = {}
                    for i in range(count):
                        x, cc2[f"l{i}"] = fn(p[f"l{i}"], x, c[f"l{i}"])
                    new_caches[f"g{gi}"] = cc2
        x = apply_norm(params["final_norm"], x, cfg)
        token = greedy_token(params["embed"], x, ctx, cfg)
        return token, new_caches

    # input pspecs
    in_tok_prefill = PartitionSpec(ba, "model")
    prefill_in = {"tokens": in_tok_prefill}
    if cfg.family == "encdec":
        prefill_in["enc"] = PartitionSpec(ba, "model", None)
    if cfg.frontend == "patch_stub":
        prefill_in["frontend"] = PartitionSpec(ba, "model", None)
    tok_ps = PartitionSpec(ba)

    prefill_body = jax.shard_map(
        local_prefill, mesh=mesh,
        in_specs=(p_ps, prefill_in),
        out_specs=(c_ps, tok_ps),
        check_vma=False,
    )
    prefill = jax.jit(
        prefill_body,
        in_shardings=(_sh(mesh, p_ps), _sh(mesh, prefill_in)),
        out_shardings=(_sh(mesh, c_ps), _sh(mesh, tok_ps)),
    )

    decode_body = jax.shard_map(
        local_decode_step, mesh=mesh,
        in_specs=(p_ps, c_ps, PartitionSpec(ba, None)),
        out_specs=(tok_ps, c_ps),
        check_vma=False,
    )
    decode = jax.jit(
        decode_body,
        in_shardings=(_sh(mesh, p_ps), _sh(mesh, c_ps), _sh(mesh, PartitionSpec(ba, None))),
        out_shardings=(_sh(mesh, tok_ps), _sh(mesh, c_ps)),
        donate_argnums=(1,),
    )
    return ServeBundle(
        prefill=prefill, decode=decode, param_spec=spec,
        cache_pspec=c_spec, batch_ax=ba, ctx=ctx,
    )


def abstract_cache(cfg: ModelConfig, mesh, batch: int, max_len: int, enc_len: int = 1536):
    return abstract_params(cache_spec(cfg, mesh, batch, max_len, enc_len))
