"""Serving layer: prefill/decode step factories + sharded cache specs."""
from .engine import (  # noqa: F401
    ServeBundle,
    abstract_cache,
    cache_spec,
    make_serve_fns,
)
