"""Batch scheduler for serving: bucketed prefill + decode loop.

Production inference needs a layer between raw step functions and requests:
this one buckets requests by prompt length (one compiled prefill per bucket
length — the standard bucketing trade against full continuous batching,
noted in DESIGN.md), packs them into the fixed decode batch, runs the decode
loop with a per-request done mask, and streams tokens out.  Underfull
batches are padded with a copy of the first request (masked out of results).

Throughput accounting (prefill tokens, decode steps, wall time) is returned
for the serving example / benchmarks.
"""
from __future__ import annotations

import time
from collections import defaultdict
from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.serve.engine import make_serve_fns


@dataclass
class Request:
    rid: int
    prompt: list            # token ids
    max_new: int = 16


@dataclass
class Completion:
    rid: int
    tokens: list
    finished: bool


@dataclass
class ServeStats:
    requests: int = 0
    prefill_tokens: int = 0
    decode_steps: int = 0
    wall_s: float = 0.0
    batches: int = 0

    @property
    def decode_tok_per_s(self) -> float:
        return self.decode_steps / self.wall_s if self.wall_s else 0.0


class BatchScheduler:
    def __init__(self, cfg: ModelConfig, mesh, *, batch: int, max_len: int,
                 eos_id: int = 0, enc_len: int = 32):
        self.cfg = cfg
        self.mesh = mesh
        self.batch = batch
        self.max_len = max_len
        self.eos_id = eos_id
        self.enc_len = enc_len
        self._engines = {}   # prompt_len -> ServeBundle

    def _engine(self, prompt_len: int):
        if prompt_len not in self._engines:
            self._engines[prompt_len] = make_serve_fns(
                self.cfg, self.mesh, batch=self.batch,
                max_len=self.max_len, enc_len=self.enc_len,
            )
        return self._engines[prompt_len]

    def run(self, params, requests: list[Request], *, extras=None) -> tuple[dict, ServeStats]:
        """Serve all requests; returns ({rid: Completion}, stats)."""
        stats = ServeStats(requests=len(requests))
        t0 = time.time()
        buckets: dict[int, list[Request]] = defaultdict(list)
        for r in requests:
            if len(r.prompt) >= self.max_len:
                raise ValueError(f"prompt {r.rid} longer than max_len")
            buckets[len(r.prompt)].append(r)

        out: dict[int, Completion] = {}
        for plen, reqs in sorted(buckets.items()):
            for i in range(0, len(reqs), self.batch):
                chunk = reqs[i : i + self.batch]
                out.update(self._run_batch(params, chunk, plen, stats, extras))
                stats.batches += 1
        stats.wall_s = time.time() - t0
        return out, stats

    def _run_batch(self, params, chunk: list[Request], plen: int,
                   stats: ServeStats, extras) -> dict:
        sv = self._engine(plen)
        B = self.batch
        rows = chunk + [chunk[0]] * (B - len(chunk))     # pad with a copy
        toks = np.stack([np.asarray(r.prompt, np.int32) for r in rows])
        inputs = {"tokens": jnp.asarray(toks)}
        if extras:
            inputs.update(extras)
        caches, tok = sv.prefill(params, inputs)
        stats.prefill_tokens += plen * len(chunk)

        max_new = max(r.max_new for r in chunk)
        gen = [[int(t)] for t in np.asarray(tok)]
        done = np.array([int(t) == self.eos_id for t in np.asarray(tok)])
        for _ in range(max_new - 1):
            if all(done[: len(chunk)]):
                break
            tok, caches = sv.decode(params, caches, tok[:, None])
            stats.decode_steps += int((~done[: len(chunk)]).sum())
            arr = np.asarray(tok)
            for b in range(B):
                if not done[b]:
                    gen[b].append(int(arr[b]))
                    if int(arr[b]) == self.eos_id or len(gen[b]) >= rows[b].max_new:
                        done[b] = True
        return {
            r.rid: Completion(r.rid, gen[b][: r.max_new],
                              finished=bool(done[b]))
            for b, r in enumerate(chunk)
        }
