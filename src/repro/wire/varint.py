"""Varint + bit-stream primitives for the wire codec.

LEB128 unsigned varints frame every message; zigzag maps the signed ToW
sketch values onto them.  ``BitWriter``/``BitReader`` pack the protocol's
sub-byte fields (m-bit syndromes and bin positions, 1-bit ok/done flags)
MSB-first, so a frame's payload length is exactly
``ceil(payload_bits / 8)`` — what lets measured frame sizes reconcile with
the paper's Formula-(1) bit accounting.  Dependency-free on purpose:
``core.tow`` mirrors the framed-length arithmetic without importing jax or
the frames module.
"""
from __future__ import annotations


class WireError(ValueError):
    """Malformed or corrupted wire data."""


class WireTruncated(WireError):
    """Buffer ended before the declared structure was complete."""


def encode_uvarint(v: int) -> bytes:
    if v < 0:
        raise WireError(f"uvarint of negative value {v}")
    out = bytearray()
    while True:
        b = v & 0x7F
        v >>= 7
        if v:
            out.append(b | 0x80)
        else:
            out.append(b)
            return bytes(out)


def decode_uvarint(buf: bytes, off: int = 0) -> tuple[int, int]:
    """(value, next offset); raises WireTruncated / WireError."""
    shift = 0
    v = 0
    while True:
        if off >= len(buf):
            raise WireTruncated("uvarint runs past end of buffer")
        if shift > 63:
            raise WireError("uvarint longer than 64 bits")
        b = buf[off]
        off += 1
        v |= (b & 0x7F) << shift
        if not (b & 0x80):
            return v, off
        shift += 7


def uvarint_len(v: int) -> int:
    n = 1
    v >>= 7
    while v:
        n += 1
        v >>= 7
    return n


def framed_len(payload_len: int) -> int:
    """Total frame-envelope size for a payload of ``payload_len`` bytes:
    ``uvarint(1 + payload_len) + type byte + payload`` (see frames.frame)."""
    return uvarint_len(1 + payload_len) + 1 + payload_len


def zigzag(n: int) -> int:
    return (n << 1) ^ (n >> 63) if n < 0 else n << 1


def unzigzag(z: int) -> int:
    return (z >> 1) ^ -(z & 1)


class BitWriter:
    """MSB-first bit packer; ``getvalue`` zero-pads the final byte."""

    def __init__(self) -> None:
        self._out = bytearray()
        self._acc = 0
        self._nbits = 0

    def write(self, value: int, nbits: int) -> None:
        if nbits < 0 or (nbits < 64 and value >> nbits):
            raise WireError(f"value {value} does not fit in {nbits} bits")
        self._acc = (self._acc << nbits) | value
        self._nbits += nbits
        while self._nbits >= 8:
            self._nbits -= 8
            self._out.append((self._acc >> self._nbits) & 0xFF)
        self._acc &= (1 << self._nbits) - 1

    @property
    def bit_length(self) -> int:
        return len(self._out) * 8 + self._nbits

    def getvalue(self) -> bytes:
        out = bytes(self._out)
        if self._nbits:
            out += bytes([(self._acc << (8 - self._nbits)) & 0xFF])
        return out


class BitReader:
    """MSB-first bit unpacker over a byte slice."""

    def __init__(self, buf: bytes, off: int = 0) -> None:
        self._buf = buf
        self._byte = off
        self._bit = 0

    def read(self, nbits: int) -> int:
        v = 0
        for _ in range(nbits):
            if self._byte >= len(self._buf):
                raise WireTruncated("bit field runs past end of buffer")
            v = (v << 1) | ((self._buf[self._byte] >> (7 - self._bit)) & 1)
            self._bit += 1
            if self._bit == 8:
                self._bit = 0
                self._byte += 1
        return v

    def finish(self) -> int:
        """Consume zero padding to the end; returns the next byte offset.

        Raises WireError on nonzero pad bits or leftover whole bytes —
        the corrupted/over-long frame rejection path.
        """
        if self._bit:
            pad = self._buf[self._byte] & ((1 << (8 - self._bit)) - 1)
            if pad:
                raise WireError("nonzero padding bits at end of bit stream")
            self._byte += 1
            self._bit = 0
        if self._byte != len(self._buf):
            raise WireError(
                f"{len(self._buf) - self._byte} unconsumed bytes after bit stream"
            )
        return self._byte
