"""Frame codecs for every PBS protocol message (DESIGN.md §9).

Envelope: ``uvarint(1 + len(payload)) || msg_type byte || payload``.  Each
payload is a varint header plus an MSB-first bit stream zero-padded to the
byte boundary, so framed sizes are ``header + ceil(payload_bits / 8)``.

Sub-byte field widths come from the session's BCH code — m-bit syndromes
and bin positions, 32-bit XOR folds and checksums — which is why the
round-frame decoders take a *schema* (``(n_units, t, m)`` per live session)
instead of shipping redundant structure: both endpoints derive the schema
from the same deterministic round state machine, exactly like the paper's
Formula (1) assumes.  ``*_ledger_bits`` report the protocol-information
bits of a decoded frame per that accounting; structural bits (per-unit
position counts, done flags, headers, padding) are measured separately by
the endpoints as wire overhead.

Every decoder is strict: truncated buffers, nonzero padding, trailing
bytes, out-of-range positions/counts, and unknown message types all raise
``WireError`` (property-tested in tests/test_wire.py).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .varint import (
    BitReader,
    BitWriter,
    WireError,
    WireTruncated,
    decode_uvarint,
    encode_uvarint,
    unzigzag,
    uvarint_len,
    zigzag,
)

MSG_TOW_SKETCH = 0x01     # Alice -> Bob: phase-0 ToW sketch vector
MSG_DHAT = 0x02           # Bob -> Alice: d_hat numerator (sum of squared diffs)
MSG_ROUND_SKETCHES = 0x03  # Alice -> Bob: per-unit BCH syndrome sketches
MSG_ROUND_REPLY = 0x04    # Bob -> Alice: ok flags, positions, XORs, checksums
MSG_ROUND_OUTCOME = 0x05  # Alice -> Bob: per-unit checksum-settled flags
MSG_VERIFY = 0x06         # Alice -> Bob: success + c(A xor D_hat) per session
MSG_VERIFY_ACK = 0x07     # Bob -> Alice: per-session verification verdicts
MSG_MUX = 0x08            # either direction: channel-tagged envelope (hub)
MSG_EPOCH = 0x09          # either direction: epoch-open envelope (continuous sync)

_KNOWN = frozenset(
    (MSG_TOW_SKETCH, MSG_DHAT, MSG_ROUND_SKETCHES, MSG_ROUND_REPLY,
     MSG_ROUND_OUTCOME, MSG_VERIFY, MSG_VERIFY_ACK, MSG_MUX, MSG_EPOCH)
)

KEY_BITS = 32  # element keys are 32-bit (core.pbs.KEY_BITS)


# ---------------------------------------------------------------------------
# Envelope
# ---------------------------------------------------------------------------


def frame(msg_type: int, payload: bytes) -> bytes:
    return encode_uvarint(1 + len(payload)) + bytes((msg_type,)) + payload


def split_frame(buf: bytes, off: int = 0):
    """Parse one frame at ``off``: (msg_type, payload, next_off).

    Returns None when the buffer holds only a frame prefix (stream
    transports deliver partial reads); raises WireError on malformed input.
    """
    if off >= len(buf):
        return None
    try:
        body_len, hdr_end = decode_uvarint(buf, off)
    except WireTruncated:
        return None
    if body_len < 1:
        raise WireError("frame with empty body")
    if hdr_end + body_len > len(buf):
        return None
    msg_type = buf[hdr_end]
    if msg_type not in _KNOWN:
        raise WireError(f"unknown message type 0x{msg_type:02x}")
    return msg_type, buf[hdr_end + 1 : hdr_end + body_len], hdr_end + body_len


# ---------------------------------------------------------------------------
# Multiplexing envelope (repro.net.hub, DESIGN.md §10)
# ---------------------------------------------------------------------------


def encode_mux(channel: int, inner: bytes) -> bytes:
    """Wrap one complete frame in a channel-tagged envelope.

    Payload: ``uvarint(channel) || inner frame`` where ``inner`` is a full
    frame (envelope + type + payload) — the hub demultiplexes N peers by
    this tag and rejects frames whose tag is not the peer's assigned
    channel.  Channel 0 is reserved (never assigned), so a zero tag is
    always a protocol error at the hub.
    """
    if channel < 1:
        raise WireError(f"mux channel {channel} out of range (must be >= 1)")
    return frame(MSG_MUX, encode_uvarint(channel) + inner)


def decode_mux(payload: bytes) -> tuple[int, int, bytes]:
    """(channel, inner msg_type, inner payload); strict.

    The inner frame must parse completely (no trailing bytes) and must not
    itself be a mux envelope — nesting is rejected.
    """
    channel, off = decode_uvarint(payload)
    if channel < 1:
        raise WireError(f"mux channel {channel} out of range (must be >= 1)")
    got = split_frame(payload, off)
    if got is None:
        raise WireTruncated("mux envelope holds an incomplete inner frame")
    msg_type, inner_payload, end = got
    if msg_type == MSG_MUX:
        raise WireError("nested mux envelope")
    if end != len(payload):
        raise WireError(
            f"{len(payload) - end} trailing bytes after mux inner frame"
        )
    return channel, msg_type, inner_payload


def mux_overhead_bytes(channel: int, inner_len: int) -> int:
    """Envelope bytes ``encode_mux`` adds on top of the inner frame — the
    transport-level cost of hub multiplexing (excluded from the protocol
    ledger exactly like ARQ overhead)."""
    payload_len = uvarint_len(channel) + inner_len
    return uvarint_len(1 + payload_len) + 1 + uvarint_len(channel)


# ---------------------------------------------------------------------------
# Epoch envelope (continuous sync, DESIGN.md §11)
# ---------------------------------------------------------------------------


def encode_epoch(epoch: int, inner: bytes = b"") -> bytes:
    """Wrap one continuous-sync epoch-handshake step in an epoch-tagged
    envelope.

    Payload: ``uvarint(epoch) || inner`` where ``inner`` is either empty —
    a bare epoch-open, sent when the epoch needs no d̂ re-estimation — or
    exactly one complete phase-0 frame (``MSG_TOW_SKETCH`` outbound,
    ``MSG_DHAT`` on the reply), so the d̂ handshake rides the same codecs
    admission uses.  Epoch 0 is the admission epoch (plain ``submit`` +
    phase 0), so an epoch tag below 1 is always a protocol error.  The
    ledger mirrors ``MSG_MUX``: the inner frame's bits count per Formula
    (1) (estimator bytes), the envelope's extra bytes are transport
    overhead.
    """
    if epoch < 1:
        raise WireError(f"epoch {epoch} out of range (must be >= 1)")
    return frame(MSG_EPOCH, encode_uvarint(epoch) + inner)


def decode_epoch(payload: bytes) -> tuple[int, int | None, bytes | None]:
    """(epoch, inner msg_type | None, inner payload | None); strict.

    A non-empty inner region must parse as exactly one complete frame (no
    trailing bytes) and must not itself be an envelope — nested
    ``MSG_EPOCH`` or ``MSG_MUX`` is rejected (the mux wrap, when present,
    goes *outside* the epoch envelope).
    """
    epoch, off = decode_uvarint(payload)
    if epoch < 1:
        raise WireError(f"epoch {epoch} out of range (must be >= 1)")
    if off == len(payload):
        return epoch, None, None
    got = split_frame(payload, off)
    if got is None:
        raise WireTruncated("epoch envelope holds an incomplete inner frame")
    msg_type, inner_payload, end = got
    if msg_type in (MSG_EPOCH, MSG_MUX):
        raise WireError(f"nested envelope 0x{msg_type:02x} in epoch frame")
    if end != len(payload):
        raise WireError(
            f"{len(payload) - end} trailing bytes after epoch inner frame"
        )
    return epoch, msg_type, inner_payload


def epoch_overhead_bytes(epoch: int, inner_len: int) -> int:
    """Envelope bytes ``encode_epoch`` adds on top of the inner frame —
    transport overhead, excluded from the protocol ledger like mux/ARQ."""
    payload_len = uvarint_len(epoch) + inner_len
    return uvarint_len(1 + payload_len) + 1 + uvarint_len(epoch)


# ---------------------------------------------------------------------------
# Phase 0: ToW sketch + d_hat reply
# ---------------------------------------------------------------------------


def tow_value_bits(set_size: int) -> int:
    """Bits per sketch value: Y_i in [-|S|, |S|] (ceil(log2(2|S| + 1)))."""
    return int(2 * set_size).bit_length()


def encode_tow_sketch(values, set_size: int) -> bytes:
    vals = np.asarray(values, dtype=np.int64)
    bits = tow_value_bits(set_size)
    w = BitWriter()
    for v in vals:
        z = zigzag(int(v))
        if z > 2 * set_size:
            raise WireError(f"sketch value {int(v)} exceeds set size {set_size}")
        w.write(z, bits)
    payload = encode_uvarint(set_size) + encode_uvarint(len(vals)) + w.getvalue()
    return frame(MSG_TOW_SKETCH, payload)


def decode_tow_sketch(payload: bytes) -> tuple[int, np.ndarray]:
    set_size, off = decode_uvarint(payload)
    ell, off = decode_uvarint(payload, off)
    bits = tow_value_bits(set_size)
    r = BitReader(payload, off)
    out = np.zeros(ell, dtype=np.int64)
    for i in range(ell):
        z = r.read(bits)
        if z > 2 * set_size:
            raise WireError("sketch value out of range for declared set size")
        out[i] = unzigzag(z)
    r.finish()
    return set_size, out


def encode_dhat(numerator: int) -> bytes:
    return frame(MSG_DHAT, encode_uvarint(int(numerator)))


def decode_dhat(payload: bytes) -> int:
    num, off = decode_uvarint(payload)
    if off != len(payload):
        raise WireError("trailing bytes after d_hat numerator")
    return num


# ---------------------------------------------------------------------------
# Round frames
# ---------------------------------------------------------------------------


def sketches_ledger_bits(n_units: int, t: int, m: int) -> int:
    """Formula-(1) bits of one session's sketch block: t*m per unit."""
    return n_units * t * m


def encode_round_sketches(rnd: int, blocks) -> bytes:
    """``blocks``: per live session (schema order), (sketches (U, t), m)."""
    w = BitWriter()
    for sk, m in blocks:
        sk = np.asarray(sk, dtype=np.int64)
        if np.any(sk < 0) or np.any(sk >> m):
            raise WireError(f"syndrome out of range for m={m}")
        for row in sk:
            for s in row:
                w.write(int(s), m)
    return frame(MSG_ROUND_SKETCHES, encode_uvarint(rnd) + w.getvalue())


def decode_round_sketches(payload: bytes, schema) -> tuple[int, list[np.ndarray]]:
    """``schema``: [(n_units, t, m)] per live session, both-endpoint-derived."""
    rnd, off = decode_uvarint(payload)
    r = BitReader(payload, off)
    out = []
    for n_units, t, m in schema:
        sk = np.zeros((n_units, t), dtype=np.int64)
        for u in range(n_units):
            for j in range(t):
                sk[u, j] = r.read(m)
        out.append(sk)
    r.finish()
    return rnd, out


@dataclass
class ReplyUnit:
    """Bob's per-unit decode outcome: located bins, his XOR folds, checksum."""

    positions: np.ndarray  # (k,) int64 decoded bin indices, k <= t
    xors: np.ndarray       # (k,) uint32 Bob's bin XOR fold at each position
    csum: int              # Bob's unit checksum, 32-bit

    def __eq__(self, other) -> bool:
        return (
            isinstance(other, ReplyUnit)
            and np.array_equal(self.positions, other.positions)
            and np.array_equal(self.xors, other.xors)
            and self.csum == other.csum
        )


def reply_ledger_bits(ok, units, m: int) -> int:
    """Formula-(1) bits of one session's reply: 1 ok flag per unit, plus
    k*(m + 32) + 32 per decoded unit (positions + XOR sums + checksum)."""
    bits = len(ok)
    for flag, unit in zip(ok, units):
        if flag:
            bits += len(unit.positions) * (m + KEY_BITS) + KEY_BITS
    return bits


def encode_round_reply(rnd: int, entries, schema) -> bytes:
    """``entries``: per session (ok flags, units with ``units[i] is None``
    exactly where ``ok[i]`` is False); ``schema``: [(n_units, t, m)]."""
    w = BitWriter()
    cnt_bits_total = 0
    for (ok, units), (n_units, t, m) in zip(entries, schema):
        if len(ok) != n_units or len(units) != n_units:
            raise WireError("reply entry does not match schema unit count")
        cbits = t.bit_length()
        for flag in ok:
            w.write(1 if flag else 0, 1)
        for flag, unit in zip(ok, units):
            if not flag:
                continue
            k = len(unit.positions)
            if k > t:
                raise WireError(f"{k} positions exceed t={t}")
            w.write(k, cbits)
            cnt_bits_total += cbits
            for p, x in zip(unit.positions, unit.xors):
                if not 0 <= int(p) < (1 << m) - 1:
                    raise WireError(f"bin position {int(p)} out of range for m={m}")
                w.write(int(p), m)
                w.write(int(x) & 0xFFFFFFFF, KEY_BITS)
            w.write(int(unit.csum) & 0xFFFFFFFF, KEY_BITS)
    return frame(MSG_ROUND_REPLY, encode_uvarint(rnd) + w.getvalue())


def decode_round_reply(payload: bytes, schema):
    rnd, off = decode_uvarint(payload)
    r = BitReader(payload, off)
    out = []
    for n_units, t, m in schema:
        cbits = t.bit_length()
        n = (1 << m) - 1
        ok = np.zeros(n_units, dtype=bool)
        for u in range(n_units):
            ok[u] = bool(r.read(1))
        units: list[ReplyUnit | None] = [None] * n_units
        for u in range(n_units):
            if not ok[u]:
                continue
            k = r.read(cbits)
            if k > t:
                raise WireError(f"decoded position count {k} exceeds t={t}")
            pos = np.zeros(k, dtype=np.int64)
            xor = np.zeros(k, dtype=np.uint32)
            for i in range(k):
                p = r.read(m)
                if p >= n:
                    raise WireError(f"bin position {p} out of range for n={n}")
                pos[i] = p
                xor[i] = r.read(KEY_BITS)
            units[u] = ReplyUnit(positions=pos, xors=xor, csum=r.read(KEY_BITS))
        out.append((ok, units))
    r.finish()
    return rnd, out


def encode_round_outcome(rnd: int, done_lists) -> bytes:
    """Alice's checksum verdicts: 1 settled-bit per unit per live session.
    Pure structure (0 ledger bits): it is what lets Bob mirror the unit
    queue; Formula (1) folds it into the per-unit flag already counted."""
    w = BitWriter()
    for done in done_lists:
        for flag in done:
            w.write(1 if flag else 0, 1)
    return frame(MSG_ROUND_OUTCOME, encode_uvarint(rnd) + w.getvalue())


def decode_round_outcome(payload: bytes, unit_counts) -> tuple[int, list[np.ndarray]]:
    rnd, off = decode_uvarint(payload)
    r = BitReader(payload, off)
    out = []
    for n_units in unit_counts:
        done = np.zeros(n_units, dtype=bool)
        for u in range(n_units):
            done[u] = bool(r.read(1))
        out.append(done)
    r.finish()
    return rnd, out


# ---------------------------------------------------------------------------
# Final verification exchange
# ---------------------------------------------------------------------------


def encode_verify(entries) -> bytes:
    """Per session (sid order): (success flag, c(A xor D_hat) checksum)."""
    w = BitWriter()
    for success, csum in entries:
        w.write(1 if success else 0, 1)
        w.write(int(csum) & 0xFFFFFFFF, KEY_BITS)
    return frame(MSG_VERIFY, w.getvalue())


def decode_verify(payload: bytes, n_sessions: int):
    r = BitReader(payload)
    out = []
    for _ in range(n_sessions):
        success = bool(r.read(1))
        out.append((success, r.read(KEY_BITS)))
    r.finish()
    return out


def encode_verify_ack(flags) -> bytes:
    w = BitWriter()
    for f in flags:
        w.write(1 if f else 0, 1)
    return frame(MSG_VERIFY_ACK, w.getvalue())


def decode_verify_ack(payload: bytes, n_sessions: int) -> list[bool]:
    r = BitReader(payload)
    out = [bool(r.read(1)) for _ in range(n_sessions)]
    r.finish()
    return out
