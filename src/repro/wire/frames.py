"""Frame codecs for every PBS protocol message (DESIGN.md §9).

Envelope: ``uvarint(1 + len(payload)) || msg_type byte || payload``.  Each
payload is a varint header plus an MSB-first bit stream zero-padded to the
byte boundary, so framed sizes are ``header + ceil(payload_bits / 8)``.

Sub-byte field widths come from the session's BCH code — m-bit syndromes
and bin positions, 32-bit XOR folds and checksums — which is why the
round-frame decoders take a *schema* (``(n_units, t, m)`` per live session)
instead of shipping redundant structure: both endpoints derive the schema
from the same deterministic round state machine, exactly like the paper's
Formula (1) assumes.  ``*_ledger_bits`` report the protocol-information
bits of a decoded frame per that accounting; structural bits (per-unit
position counts, done flags, headers, padding) are measured separately by
the endpoints as wire overhead.

The public codecs are **numpy-batched** (DESIGN.md §12): every fixed-width
field of a frame is packed/unpacked in whole-frame ``np.packbits`` /
``np.unpackbits`` passes (MSB-first, final-byte zero padding — exactly the
``BitWriter``/``BitReader`` stream), instead of one Python bit loop per
unit row.  The original per-bit codecs are kept under ``*_scalar`` names as
the differential oracle for tests/test_wire_batch.py, which asserts the two
are byte-for-byte interchangeable on random and adversarial frames.

Every decoder is strict: truncated buffers, nonzero padding, trailing
bytes, out-of-range positions/counts, and unknown message types all raise
``WireError`` (property-tested in tests/test_wire.py).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .varint import (
    BitReader,
    BitWriter,
    WireError,
    WireTruncated,
    decode_uvarint,
    encode_uvarint,
    unzigzag,
    uvarint_len,
    zigzag,
)

MSG_TOW_SKETCH = 0x01     # Alice -> Bob: phase-0 ToW sketch vector
MSG_DHAT = 0x02           # Bob -> Alice: d_hat numerator (sum of squared diffs)
MSG_ROUND_SKETCHES = 0x03  # Alice -> Bob: per-unit BCH syndrome sketches
MSG_ROUND_REPLY = 0x04    # Bob -> Alice: ok flags, positions, XORs, checksums
MSG_ROUND_OUTCOME = 0x05  # Alice -> Bob: per-unit checksum-settled flags
MSG_VERIFY = 0x06         # Alice -> Bob: success + c(A xor D_hat) per session
MSG_VERIFY_ACK = 0x07     # Bob -> Alice: per-session verification verdicts
MSG_MUX = 0x08            # either direction: channel-tagged envelope (hub)
MSG_EPOCH = 0x09          # either direction: epoch-open envelope (continuous sync)
MSG_RESUME = 0x0A         # either direction: session-resumption handshake (hub)
MSG_TREE = 0x0B           # either direction: tree-phase digest/verdict exchange
MSG_PARITY = 0x0C         # Alice -> Bob: incremental parity syndromes (rateless)

_KNOWN = frozenset(
    (MSG_TOW_SKETCH, MSG_DHAT, MSG_ROUND_SKETCHES, MSG_ROUND_REPLY,
     MSG_ROUND_OUTCOME, MSG_VERIFY, MSG_VERIFY_ACK, MSG_MUX, MSG_EPOCH,
     MSG_RESUME, MSG_TREE, MSG_PARITY)
)

KEY_BITS = 32  # element keys are 32-bit (core.pbs.KEY_BITS)


# ---------------------------------------------------------------------------
# Envelope
# ---------------------------------------------------------------------------


def frame(msg_type: int, payload: bytes) -> bytes:
    return encode_uvarint(1 + len(payload)) + bytes((msg_type,)) + payload


def split_frame(buf: bytes, off: int = 0):
    """Parse one frame at ``off``: (msg_type, payload, next_off).

    Returns None when the buffer holds only a frame prefix (stream
    transports deliver partial reads); raises WireError on malformed input.
    """
    if off >= len(buf):
        return None
    try:
        body_len, hdr_end = decode_uvarint(buf, off)
    except WireTruncated:
        return None
    if body_len < 1:
        raise WireError("frame with empty body")
    if hdr_end + body_len > len(buf):
        return None
    msg_type = buf[hdr_end]
    if msg_type not in _KNOWN:
        raise WireError(f"unknown message type 0x{msg_type:02x}")
    return msg_type, buf[hdr_end + 1 : hdr_end + body_len], hdr_end + body_len


# ---------------------------------------------------------------------------
# Multiplexing envelope (repro.net.hub, DESIGN.md §10)
# ---------------------------------------------------------------------------


def encode_mux(channel: int, inner: bytes) -> bytes:
    """Wrap one complete frame in a channel-tagged envelope.

    Payload: ``uvarint(channel) || inner frame`` where ``inner`` is a full
    frame (envelope + type + payload) — the hub demultiplexes N peers by
    this tag and rejects frames whose tag is not the peer's assigned
    channel.  Channel 0 is reserved (never assigned), so a zero tag is
    always a protocol error at the hub.
    """
    if channel < 1:
        raise WireError(f"mux channel {channel} out of range (must be >= 1)")
    return frame(MSG_MUX, encode_uvarint(channel) + inner)


def decode_mux(payload: bytes) -> tuple[int, int, bytes]:
    """(channel, inner msg_type, inner payload); strict.

    The inner frame must parse completely (no trailing bytes) and must not
    itself be a mux envelope — nesting is rejected.
    """
    channel, off = decode_uvarint(payload)
    if channel < 1:
        raise WireError(f"mux channel {channel} out of range (must be >= 1)")
    got = split_frame(payload, off)
    if got is None:
        raise WireTruncated("mux envelope holds an incomplete inner frame")
    msg_type, inner_payload, end = got
    if msg_type == MSG_MUX:
        raise WireError("nested mux envelope")
    if end != len(payload):
        raise WireError(
            f"{len(payload) - end} trailing bytes after mux inner frame"
        )
    return channel, msg_type, inner_payload


def mux_overhead_bytes(channel: int, inner_len: int) -> int:
    """Envelope bytes ``encode_mux`` adds on top of the inner frame — the
    transport-level cost of hub multiplexing (excluded from the protocol
    ledger exactly like ARQ overhead)."""
    payload_len = uvarint_len(channel) + inner_len
    return uvarint_len(1 + payload_len) + 1 + uvarint_len(channel)


# ---------------------------------------------------------------------------
# Epoch envelope (continuous sync, DESIGN.md §11)
# ---------------------------------------------------------------------------


def encode_epoch(epoch: int, inner: bytes = b"") -> bytes:
    """Wrap one continuous-sync epoch-handshake step in an epoch-tagged
    envelope.

    Payload: ``uvarint(epoch) || inner`` where ``inner`` is either empty —
    a bare epoch-open, sent when the epoch needs no d̂ re-estimation — or
    exactly one complete phase-0 frame (``MSG_TOW_SKETCH`` outbound,
    ``MSG_DHAT`` on the reply), so the d̂ handshake rides the same codecs
    admission uses.  Epoch 0 is the admission epoch (plain ``submit`` +
    phase 0), so an epoch tag below 1 is always a protocol error.  The
    ledger mirrors ``MSG_MUX``: the inner frame's bits count per Formula
    (1) (estimator bytes), the envelope's extra bytes are transport
    overhead.
    """
    if epoch < 1:
        raise WireError(f"epoch {epoch} out of range (must be >= 1)")
    return frame(MSG_EPOCH, encode_uvarint(epoch) + inner)


def decode_epoch(payload: bytes) -> tuple[int, int | None, bytes | None]:
    """(epoch, inner msg_type | None, inner payload | None); strict.

    A non-empty inner region must parse as exactly one complete frame (no
    trailing bytes) and must not itself be an envelope — nested
    ``MSG_EPOCH`` or ``MSG_MUX`` is rejected (the mux wrap, when present,
    goes *outside* the epoch envelope).
    """
    epoch, off = decode_uvarint(payload)
    if epoch < 1:
        raise WireError(f"epoch {epoch} out of range (must be >= 1)")
    if off == len(payload):
        return epoch, None, None
    got = split_frame(payload, off)
    if got is None:
        raise WireTruncated("epoch envelope holds an incomplete inner frame")
    msg_type, inner_payload, end = got
    if msg_type in (MSG_EPOCH, MSG_MUX):
        raise WireError(f"nested envelope 0x{msg_type:02x} in epoch frame")
    if end != len(payload):
        raise WireError(
            f"{len(payload) - end} trailing bytes after epoch inner frame"
        )
    return epoch, msg_type, inner_payload


def epoch_overhead_bytes(epoch: int, inner_len: int) -> int:
    """Envelope bytes ``encode_epoch`` adds on top of the inner frame —
    transport overhead, excluded from the protocol ledger like mux/ARQ."""
    payload_len = uvarint_len(epoch) + inner_len
    return uvarint_len(1 + payload_len) + 1 + uvarint_len(epoch)


# ---------------------------------------------------------------------------
# Session-resumption handshake (repro.net.resilience, DESIGN.md §13)
# ---------------------------------------------------------------------------

_DIGEST_BYTES = 8
_DIGEST_MASK = (1 << 64) - 1
_FNV_OFFSET = 0xCBF29CE484222325
_FNV_PRIME = 0x00000100000001B3


def transcript_digest0(epoch: int) -> int:
    """The rolling transcript digest's per-epoch starting value.

    Both sides reset to this at admission and at each epoch install, then
    fold every completed round's outcome frame via ``fold_transcript`` —
    so two transcripts agree iff both sides applied the same outcome
    frames in the same rounds of the same epoch.
    """
    return fold_transcript(_FNV_OFFSET, 0, int(epoch).to_bytes(8, "big"))


def fold_transcript(digest: int, rnd: int, frame_bytes: bytes) -> int:
    """Fold one completed round barrier into the rolling transcript digest
    (FNV-1a over the round number then the framed outcome bytes).  The
    digest is a divergence *guard* for ``MSG_RESUME``, not a proof: a peer
    whose replayed state drifted from the hub's mirror is rejected at the
    resume handshake instead of corrupting the shared cohort state.
    """
    d = digest & _DIGEST_MASK
    for b in int(rnd).to_bytes(8, "big") + bytes(frame_bytes):
        d = ((d ^ b) * _FNV_PRIME) & _DIGEST_MASK
    return d


def encode_resume(
    channel: int, epoch: int, last_round: int, digest: int, digest_prev: int
) -> bytes:
    """One side of the resumption handshake (DESIGN.md §13).

    Payload: ``uvarint(channel) || uvarint(epoch) || uvarint(last_round) ||
    digest[8] || digest_prev[8]`` — the sender's channel id, its current
    epoch, its last *completed* local round barrier, and the rolling
    transcript digests at that barrier and the one before it (the previous
    digest is what the receiver checks when it is exactly one outcome
    frame behind, i.e. the peer's last outcome frame was lost in flight).
    The reconnecting peer sends it first; the hub answers with its own
    ``MSG_RESUME`` carrying the mirror's barrier, which tells the peer
    whether to replay its buffered outcome frame.  Channel 0 is reserved,
    exactly like ``MSG_MUX``.  Resume frames are transport overhead —
    ledgered like ARQ/mux bytes, never Formula-(1) bits.
    """
    if channel < 1:
        raise WireError(f"resume channel {channel} out of range (must be >= 1)")
    if last_round < 0:
        raise WireError(f"resume round {last_round} out of range")
    return frame(
        MSG_RESUME,
        encode_uvarint(channel)
        + encode_uvarint(epoch)
        + encode_uvarint(last_round)
        + (digest & _DIGEST_MASK).to_bytes(_DIGEST_BYTES, "big")
        + (digest_prev & _DIGEST_MASK).to_bytes(_DIGEST_BYTES, "big"),
    )


def decode_resume(payload: bytes) -> tuple[int, int, int, int, int]:
    """(channel, epoch, last_round, digest, digest_prev); strict."""
    channel, off = decode_uvarint(payload)
    if channel < 1:
        raise WireError(f"resume channel {channel} out of range (must be >= 1)")
    epoch, off = decode_uvarint(payload, off)
    last_round, off = decode_uvarint(payload, off)
    if len(payload) - off != 2 * _DIGEST_BYTES:
        raise WireError(
            f"resume frame carries {len(payload) - off} digest bytes, "
            f"expected {2 * _DIGEST_BYTES}"
        )
    digest = int.from_bytes(payload[off : off + _DIGEST_BYTES], "big")
    digest_prev = int.from_bytes(payload[off + _DIGEST_BYTES :], "big")
    return channel, epoch, last_round, digest, digest_prev


def resume_overhead_bytes(channel: int, epoch: int, last_round: int) -> int:
    """Framed size of one ``MSG_RESUME`` — all of it transport overhead
    (the handshake re-establishes a channel; it carries no set data)."""
    payload_len = (
        uvarint_len(channel) + uvarint_len(epoch) + uvarint_len(last_round)
        + 2 * _DIGEST_BYTES
    )
    return uvarint_len(1 + payload_len) + 1 + payload_len


# ---------------------------------------------------------------------------
# Batched bit-stream helpers (DESIGN.md §12)
# ---------------------------------------------------------------------------

# widest fixed field the int64 weight vectors handle exactly; wider ToW
# value fields (astronomical declared set sizes) fall back to the scalar
# codec, which reads them with Python integers
_MAX_FIELD_BITS = 48


def _bit_array(payload: bytes, off: int) -> np.ndarray:
    """MSB-first 0/1 uint8 view of ``payload[off:]`` — the whole remaining
    bit stream in one ``np.unpackbits`` pass."""
    return np.unpackbits(np.frombuffer(payload, dtype=np.uint8, offset=off))


def _weights(nbits: int) -> np.ndarray:
    """MSB-first bit weights: dot a (N, nbits) 0/1 matrix to get values."""
    return np.left_shift(
        np.int64(1), np.arange(nbits - 1, -1, -1, dtype=np.int64)
    )


def _field_bits(values, nbits: int) -> np.ndarray:
    """(N,) non-negative ints -> (N*nbits,) MSB-first bits."""
    v = np.asarray(values, dtype=np.uint64).reshape(-1, 1)
    sh = np.arange(nbits - 1, -1, -1, dtype=np.uint64)
    return ((v >> sh) & np.uint64(1)).astype(np.uint8).ravel()


def _read_fields(bits: np.ndarray, offsets: np.ndarray, nbits: int) -> np.ndarray:
    """Gather one nbits-wide MSB-first value at each bit offset."""
    if nbits == 0:
        return np.zeros(len(offsets), dtype=np.int64)
    idx = np.asarray(offsets, dtype=np.int64)[:, None] + np.arange(
        nbits, dtype=np.int64
    )
    return bits[idx].astype(np.int64) @ _weights(nbits)


def _pack_payload(header: bytes, bit_segments: list) -> bytes:
    """Header + the concatenated bit segments packed MSB-first, final byte
    zero-padded — byte-identical to ``BitWriter.getvalue()``."""
    if not bit_segments:
        return header
    bits = np.concatenate(bit_segments)
    if not len(bits):
        return header
    return header + np.packbits(bits).tobytes()


def _finish_bits(bits: np.ndarray, used: int, payload: bytes, off: int) -> None:
    """``BitReader.finish`` semantics over the batched view: the payload
    must be exactly ``ceil(used / 8)`` bytes past ``off`` and every pad bit
    zero (corrupted/over-long frame rejection)."""
    avail = len(payload) - off
    need = (used + 7) // 8
    if avail > need:
        raise WireError(f"{avail - need} unconsumed bytes after bit stream")
    if used < need * 8 and np.any(bits[used : need * 8]):
        raise WireError("nonzero padding bits at end of bit stream")


# ---------------------------------------------------------------------------
# Phase 0: ToW sketch + d_hat reply
# ---------------------------------------------------------------------------


def tow_value_bits(set_size: int) -> int:
    """Bits per sketch value: Y_i in [-|S|, |S|] (ceil(log2(2|S| + 1)))."""
    return int(2 * set_size).bit_length()


def encode_tow_sketch(values, set_size: int) -> bytes:
    vals = np.asarray(values, dtype=np.int64)
    bits = tow_value_bits(set_size)
    if bits > _MAX_FIELD_BITS:
        return encode_tow_sketch_scalar(values, set_size)
    # arithmetic-shift zigzag works for both signs: n>>63 is 0 or -1
    z = (vals << 1) ^ (vals >> 63)
    bad = z > 2 * set_size
    if np.any(bad):
        v = int(vals[int(np.argmax(bad))])
        raise WireError(f"sketch value {v} exceeds set size {set_size}")
    payload = _pack_payload(
        encode_uvarint(set_size) + encode_uvarint(len(vals)),
        [_field_bits(z, bits)] if len(vals) else [],
    )
    return frame(MSG_TOW_SKETCH, payload)


def encode_tow_sketch_scalar(values, set_size: int) -> bytes:
    """Per-value ``BitWriter`` form of ``encode_tow_sketch`` (test oracle)."""
    vals = np.asarray(values, dtype=np.int64)
    bits = tow_value_bits(set_size)
    w = BitWriter()
    for v in vals:
        z = zigzag(int(v))
        if z > 2 * set_size:
            raise WireError(f"sketch value {int(v)} exceeds set size {set_size}")
        w.write(z, bits)
    payload = encode_uvarint(set_size) + encode_uvarint(len(vals)) + w.getvalue()
    return frame(MSG_TOW_SKETCH, payload)


def decode_tow_sketch(payload: bytes) -> tuple[int, np.ndarray]:
    set_size, off = decode_uvarint(payload)
    ell, off = decode_uvarint(payload, off)
    bits = tow_value_bits(set_size)
    if bits > _MAX_FIELD_BITS:
        return decode_tow_sketch_scalar(payload)
    bstream = _bit_array(payload, off)
    total = ell * bits
    if total > len(bstream):
        raise WireTruncated("bit field runs past end of buffer")
    z = (
        bstream[:total].reshape(ell, bits).astype(np.int64) @ _weights(bits)
        if ell
        else np.zeros(0, dtype=np.int64)
    )
    if np.any(z > 2 * set_size):
        raise WireError("sketch value out of range for declared set size")
    _finish_bits(bstream, total, payload, off)
    return set_size, (z >> 1) ^ -(z & 1)


def decode_tow_sketch_scalar(payload: bytes) -> tuple[int, np.ndarray]:
    """Per-value ``BitReader`` form of ``decode_tow_sketch`` (test oracle)."""
    set_size, off = decode_uvarint(payload)
    ell, off = decode_uvarint(payload, off)
    bits = tow_value_bits(set_size)
    r = BitReader(payload, off)
    out = np.zeros(ell, dtype=np.int64)
    for i in range(ell):
        z = r.read(bits)
        if z > 2 * set_size:
            raise WireError("sketch value out of range for declared set size")
        out[i] = unzigzag(z)
    r.finish()
    return set_size, out


def encode_dhat(numerator: int) -> bytes:
    return frame(MSG_DHAT, encode_uvarint(int(numerator)))


def decode_dhat(payload: bytes) -> int:
    num, off = decode_uvarint(payload)
    if off != len(payload):
        raise WireError("trailing bytes after d_hat numerator")
    return num


# ---------------------------------------------------------------------------
# Round frames
# ---------------------------------------------------------------------------


def sketches_ledger_bits(n_units: int, t: int, m: int) -> int:
    """Formula-(1) bits of one session's sketch block: t*m per unit."""
    return n_units * t * m


def encode_round_sketches(rnd: int, blocks) -> bytes:
    """``blocks``: per live session (schema order), (sketches (U, t), m).

    All of a block's m-bit syndromes bit-pack in one vectorized pass."""
    segs = []
    for sk, m in blocks:
        sk = np.asarray(sk, dtype=np.int64)
        if np.any(sk < 0) or np.any(sk >> m):
            raise WireError(f"syndrome out of range for m={m}")
        if sk.size:
            segs.append(_field_bits(sk.ravel(), m))
    return frame(MSG_ROUND_SKETCHES, _pack_payload(encode_uvarint(rnd), segs))


def encode_round_sketches_scalar(rnd: int, blocks) -> bytes:
    """Per-bit ``BitWriter`` form of ``encode_round_sketches`` (test oracle)."""
    w = BitWriter()
    for sk, m in blocks:
        sk = np.asarray(sk, dtype=np.int64)
        if np.any(sk < 0) or np.any(sk >> m):
            raise WireError(f"syndrome out of range for m={m}")
        for row in sk:
            for s in row:
                w.write(int(s), m)
    return frame(MSG_ROUND_SKETCHES, encode_uvarint(rnd) + w.getvalue())


def decode_round_sketches(payload: bytes, schema) -> tuple[int, list[np.ndarray]]:
    """``schema``: [(n_units, t, m)] per live session, both-endpoint-derived."""
    rnd, off = decode_uvarint(payload)
    bits = _bit_array(payload, off)
    total = sum(n_units * t * m for n_units, t, m in schema)
    if total > len(bits):
        raise WireTruncated("bit field runs past end of buffer")
    out = []
    pos = 0
    for n_units, t, m in schema:
        nb = n_units * t * m
        blk = (
            bits[pos : pos + nb].reshape(n_units * t, m).astype(np.int64)
            @ _weights(m)
        )
        out.append(blk.reshape(n_units, t))
        pos += nb
    _finish_bits(bits, total, payload, off)
    return rnd, out


def decode_round_sketches_scalar(
    payload: bytes, schema
) -> tuple[int, list[np.ndarray]]:
    """Per-bit ``BitReader`` form of ``decode_round_sketches`` (test oracle)."""
    rnd, off = decode_uvarint(payload)
    r = BitReader(payload, off)
    out = []
    for n_units, t, m in schema:
        sk = np.zeros((n_units, t), dtype=np.int64)
        for u in range(n_units):
            for j in range(t):
                sk[u, j] = r.read(m)
        out.append(sk)
    r.finish()
    return rnd, out


def parity_ledger_bits(n_units: int, dt: int, m: int) -> int:
    """Formula-(1) bits of one session's parity-extension block: dt
    incremental m-bit syndromes per still-overloaded unit.  Telescoping
    (DESIGN.md §16): a unit that decodes at extension level e has shipped
    exactly t_e * m total syndrome bits across the round — the prefix plus
    every increment IS the fresh (n, t_e) sketch, so nothing is re-sent."""
    return n_units * dt * m


def encode_parity(rnd: int, level: int, blocks) -> bytes:
    """``blocks``: per extending session (schema order), (inc (U, dt), m) —
    the incremental odd syndromes S_{2*t_prev+1}..S_{2*t_e-1} of each
    still-overloaded unit, slots in ascending order.

    Payload: ``uvarint(rnd) || uvarint(level)`` then one MSB-first bit
    stream of m-bit syndromes.  Which units extend at which level is
    derived deterministically by both sides from the reply's ok flags and
    the shared t-ladder, so the frame ships no unit identities — the same
    schema convention as every round frame (DESIGN.md §9).
    """
    if level < 1:
        raise WireError(f"parity level {level} out of range (must be >= 1)")
    segs = []
    for inc, m in blocks:
        inc = np.asarray(inc, dtype=np.int64)
        if np.any(inc < 0) or np.any(inc >> m):
            raise WireError(f"syndrome out of range for m={m}")
        if inc.size:
            segs.append(_field_bits(inc.ravel(), m))
    header = encode_uvarint(rnd) + encode_uvarint(level)
    return frame(MSG_PARITY, _pack_payload(header, segs))


def encode_parity_scalar(rnd: int, level: int, blocks) -> bytes:
    """Per-bit ``BitWriter`` form of ``encode_parity`` (test oracle)."""
    if level < 1:
        raise WireError(f"parity level {level} out of range (must be >= 1)")
    w = BitWriter()
    for inc, m in blocks:
        inc = np.asarray(inc, dtype=np.int64)
        if np.any(inc < 0) or np.any(inc >> m):
            raise WireError(f"syndrome out of range for m={m}")
        for row in inc:
            for s in row:
                w.write(int(s), m)
    payload = encode_uvarint(rnd) + encode_uvarint(level) + w.getvalue()
    return frame(MSG_PARITY, payload)


def decode_parity(payload: bytes, schema) -> tuple[int, int, list[np.ndarray]]:
    """``schema``: [(n_units, dt, m)] per extending session, both-endpoint-
    derived from the failing slots and the t-ladder; strict."""
    rnd, off = decode_uvarint(payload)
    level, off = decode_uvarint(payload, off)
    if level < 1:
        raise WireError(f"parity level {level} out of range (must be >= 1)")
    bits = _bit_array(payload, off)
    total = sum(n_units * dt * m for n_units, dt, m in schema)
    if total > len(bits):
        raise WireTruncated("bit field runs past end of buffer")
    out = []
    pos = 0
    for n_units, dt, m in schema:
        nb = n_units * dt * m
        blk = (
            bits[pos : pos + nb].reshape(n_units * dt, m).astype(np.int64)
            @ _weights(m)
            if nb
            else np.zeros(0, dtype=np.int64)
        )
        out.append(blk.reshape(n_units, dt))
        pos += nb
    _finish_bits(bits, total, payload, off)
    return rnd, level, out


def decode_parity_scalar(
    payload: bytes, schema
) -> tuple[int, int, list[np.ndarray]]:
    """Per-bit ``BitReader`` form of ``decode_parity`` (test oracle)."""
    rnd, off = decode_uvarint(payload)
    level, off = decode_uvarint(payload, off)
    if level < 1:
        raise WireError(f"parity level {level} out of range (must be >= 1)")
    r = BitReader(payload, off)
    out = []
    for n_units, dt, m in schema:
        inc = np.zeros((n_units, dt), dtype=np.int64)
        for u in range(n_units):
            for j in range(dt):
                inc[u, j] = r.read(m)
        out.append(inc)
    r.finish()
    return rnd, level, out


@dataclass
class ReplyUnit:
    """Bob's per-unit decode outcome: located bins, his XOR folds, checksum."""

    positions: np.ndarray  # (k,) int64 decoded bin indices, k <= t
    xors: np.ndarray       # (k,) uint32 Bob's bin XOR fold at each position
    csum: int              # Bob's unit checksum, 32-bit

    def __eq__(self, other) -> bool:
        return (
            isinstance(other, ReplyUnit)
            and np.array_equal(self.positions, other.positions)
            and np.array_equal(self.xors, other.xors)
            and self.csum == other.csum
        )


def reply_ledger_bits(ok, units, m: int) -> int:
    """Formula-(1) bits of one session's reply: 1 ok flag per unit, plus
    k*(m + 32) + 32 per decoded unit (positions + XOR sums + checksum)."""
    bits = len(ok)
    for flag, unit in zip(ok, units):
        if flag:
            bits += len(unit.positions) * (m + KEY_BITS) + KEY_BITS
    return bits


def encode_round_reply(rnd: int, entries, schema) -> bytes:
    """``entries``: per session (ok flags, units with ``units[i] is None``
    exactly where ``ok[i]`` is False); ``schema``: [(n_units, t, m)].

    Per session, every count/position/XOR/checksum field lands at a
    precomputed bit offset via vectorized scatters — no per-unit bit loop.
    """
    segs = []
    for (ok, units), (n_units, t, m) in zip(entries, schema):
        if len(ok) != n_units or len(units) != n_units:
            raise WireError("reply entry does not match schema unit count")
        cbits = t.bit_length()
        if n_units:
            segs.append(
                np.fromiter((1 if f else 0 for f in ok), np.uint8, count=n_units)
            )
        sel = [u for f, u in zip(ok, units) if f]
        if not sel:
            continue
        ks = np.fromiter((len(u.positions) for u in sel), np.int64, count=len(sel))
        bad = ks > t
        if np.any(bad):
            raise WireError(f"{int(ks[int(np.argmax(bad))])} positions exceed t={t}")
        em = m + KEY_BITS
        body_len = cbits + ks * em + KEY_BITS
        starts = np.cumsum(body_len) - body_len
        arr = np.zeros(int(body_len.sum()), dtype=np.uint8)
        cnt_idx = (starts[:, None] + np.arange(cbits, dtype=np.int64)).ravel()
        arr[cnt_idx] = _field_bits(ks, cbits)
        total_p = int(ks.sum())
        if total_p:
            pos_all = np.concatenate(
                [np.asarray(u.positions, dtype=np.int64) for u in sel]
            )
            bad_p = (pos_all < 0) | (pos_all >= (1 << m) - 1)
            if np.any(bad_p):
                p = int(pos_all[int(np.argmax(bad_p))])
                raise WireError(f"bin position {p} out of range for m={m}")
            xor_all = np.concatenate(
                [
                    np.asarray(u.xors, dtype=np.uint32).astype(np.int64)
                    for u in sel
                ]
            )
            ent_unit = np.repeat(np.arange(len(sel)), ks)
            within = np.arange(total_p) - np.repeat(np.cumsum(ks) - ks, ks)
            ent_off = starts[ent_unit] + cbits + within * em
            arr[(ent_off[:, None] + np.arange(m, dtype=np.int64)).ravel()] = (
                _field_bits(pos_all, m)
            )
            arr[
                (
                    ent_off[:, None] + m + np.arange(KEY_BITS, dtype=np.int64)
                ).ravel()
            ] = _field_bits(xor_all, KEY_BITS)
        csums = np.fromiter(
            (int(u.csum) & 0xFFFFFFFF for u in sel), np.int64, count=len(sel)
        )
        cs_off = starts + cbits + ks * em
        arr[(cs_off[:, None] + np.arange(KEY_BITS, dtype=np.int64)).ravel()] = (
            _field_bits(csums, KEY_BITS)
        )
        segs.append(arr)
    return frame(MSG_ROUND_REPLY, _pack_payload(encode_uvarint(rnd), segs))


def encode_round_reply_scalar(rnd: int, entries, schema) -> bytes:
    """Per-bit ``BitWriter`` form of ``encode_round_reply`` (test oracle)."""
    w = BitWriter()
    for (ok, units), (n_units, t, m) in zip(entries, schema):
        if len(ok) != n_units or len(units) != n_units:
            raise WireError("reply entry does not match schema unit count")
        cbits = t.bit_length()
        for flag in ok:
            w.write(1 if flag else 0, 1)
        for flag, unit in zip(ok, units):
            if not flag:
                continue
            k = len(unit.positions)
            if k > t:
                raise WireError(f"{k} positions exceed t={t}")
            w.write(k, cbits)
            for p, x in zip(unit.positions, unit.xors):
                if not 0 <= int(p) < (1 << m) - 1:
                    raise WireError(f"bin position {int(p)} out of range for m={m}")
                w.write(int(p), m)
                w.write(int(x) & 0xFFFFFFFF, KEY_BITS)
            w.write(int(unit.csum) & 0xFFFFFFFF, KEY_BITS)
    return frame(MSG_ROUND_REPLY, encode_uvarint(rnd) + w.getvalue())


def decode_round_reply(payload: bytes, schema):
    """Two-pass batched decode: a light sequential scan reads only the
    data-dependent per-unit count fields (they gate where the next unit's
    body begins), then every position/XOR/checksum field of the session is
    gathered in one vectorized pass at the scanned offsets."""
    rnd, off = decode_uvarint(payload)
    bits = _bit_array(payload, off)
    nb = len(bits)
    pos_b = 0
    out = []
    for n_units, t, m in schema:
        cbits = t.bit_length()
        n = (1 << m) - 1
        em = m + KEY_BITS
        if pos_b + n_units > nb:
            raise WireTruncated("bit field runs past end of buffer")
        ok = bits[pos_b : pos_b + n_units].astype(bool)
        pos_b += n_units
        ok_idx = np.nonzero(ok)[0]
        cw = _weights(cbits)
        ks = np.zeros(len(ok_idx), dtype=np.int64)
        body = np.zeros(len(ok_idx), dtype=np.int64)
        for i in range(len(ok_idx)):
            if pos_b + cbits > nb:
                raise WireTruncated("bit field runs past end of buffer")
            k = int(bits[pos_b : pos_b + cbits] @ cw)
            if k > t:
                raise WireError(f"decoded position count {k} exceeds t={t}")
            pos_b += cbits
            body[i] = pos_b
            ks[i] = k
            pos_b += k * em + KEY_BITS
        if pos_b > nb:
            raise WireTruncated("bit field runs past end of buffer")
        units: list[ReplyUnit | None] = [None] * n_units
        if len(ok_idx):
            total_p = int(ks.sum())
            ent_unit = np.repeat(np.arange(len(ok_idx)), ks)
            within = np.arange(total_p) - np.repeat(np.cumsum(ks) - ks, ks)
            ent_off = body[ent_unit] + within * em
            pvals = _read_fields(bits, ent_off, m)
            over = pvals >= n
            if np.any(over):
                p = int(pvals[int(np.argmax(over))])
                raise WireError(f"bin position {p} out of range for n={n}")
            xvals = _read_fields(bits, ent_off + m, KEY_BITS).astype(np.uint32)
            csums = _read_fields(bits, body + ks * em, KEY_BITS)
            bnds = np.cumsum(ks)[:-1]
            psplit = np.split(pvals, bnds)
            xsplit = np.split(xvals, bnds)
            for i, u in enumerate(ok_idx):
                units[int(u)] = ReplyUnit(
                    positions=psplit[i], xors=xsplit[i], csum=int(csums[i])
                )
        out.append((ok, units))
    _finish_bits(bits, pos_b, payload, off)
    return rnd, out


def decode_round_reply_scalar(payload: bytes, schema):
    """Per-bit ``BitReader`` form of ``decode_round_reply`` (test oracle)."""
    rnd, off = decode_uvarint(payload)
    r = BitReader(payload, off)
    out = []
    for n_units, t, m in schema:
        cbits = t.bit_length()
        n = (1 << m) - 1
        ok = np.zeros(n_units, dtype=bool)
        for u in range(n_units):
            ok[u] = bool(r.read(1))
        units: list[ReplyUnit | None] = [None] * n_units
        for u in range(n_units):
            if not ok[u]:
                continue
            k = r.read(cbits)
            if k > t:
                raise WireError(f"decoded position count {k} exceeds t={t}")
            pos = np.zeros(k, dtype=np.int64)
            xor = np.zeros(k, dtype=np.uint32)
            for i in range(k):
                p = r.read(m)
                if p >= n:
                    raise WireError(f"bin position {p} out of range for n={n}")
                pos[i] = p
                xor[i] = r.read(KEY_BITS)
            units[u] = ReplyUnit(positions=pos, xors=xor, csum=r.read(KEY_BITS))
        out.append((ok, units))
    r.finish()
    return rnd, out


def encode_round_outcome(rnd: int, done_lists) -> bytes:
    """Alice's checksum verdicts: 1 settled-bit per unit per live session.
    Pure structure (0 ledger bits): it is what lets Bob mirror the unit
    queue; Formula (1) folds it into the per-unit flag already counted."""
    segs = [
        np.asarray(done, dtype=bool).astype(np.uint8)
        for done in done_lists
        if len(done)
    ]
    return frame(MSG_ROUND_OUTCOME, _pack_payload(encode_uvarint(rnd), segs))


def encode_round_outcome_scalar(rnd: int, done_lists) -> bytes:
    """Per-bit ``BitWriter`` form of ``encode_round_outcome`` (test oracle)."""
    w = BitWriter()
    for done in done_lists:
        for flag in done:
            w.write(1 if flag else 0, 1)
    return frame(MSG_ROUND_OUTCOME, encode_uvarint(rnd) + w.getvalue())


def decode_round_outcome(payload: bytes, unit_counts) -> tuple[int, list[np.ndarray]]:
    rnd, off = decode_uvarint(payload)
    counts = list(unit_counts)
    bits = _bit_array(payload, off)
    total = sum(counts)
    if total > len(bits):
        raise WireTruncated("bit field runs past end of buffer")
    flat = bits[:total].astype(bool)
    out = []
    pos = 0
    for n_units in counts:
        out.append(flat[pos : pos + n_units])
        pos += n_units
    _finish_bits(bits, total, payload, off)
    return rnd, out


def decode_round_outcome_scalar(
    payload: bytes, unit_counts
) -> tuple[int, list[np.ndarray]]:
    """Per-bit ``BitReader`` form of ``decode_round_outcome`` (test oracle)."""
    rnd, off = decode_uvarint(payload)
    r = BitReader(payload, off)
    out = []
    for n_units in unit_counts:
        done = np.zeros(n_units, dtype=bool)
        for u in range(n_units):
            done[u] = bool(r.read(1))
        out.append(done)
    r.finish()
    return rnd, out


# ---------------------------------------------------------------------------
# Final verification exchange
# ---------------------------------------------------------------------------


def encode_verify(entries) -> bytes:
    """Per session (sid order): (success flag, c(A xor D_hat) checksum)."""
    items = list(entries)
    span = 1 + KEY_BITS
    arr = np.zeros(len(items) * span, dtype=np.uint8)
    if items:
        arr[::span] = np.fromiter(
            (1 if s else 0 for s, _ in items), np.uint8, count=len(items)
        )
        csums = np.fromiter(
            (int(c) & 0xFFFFFFFF for _, c in items), np.int64, count=len(items)
        )
        idx = (
            np.arange(len(items), dtype=np.int64)[:, None] * span
            + 1
            + np.arange(KEY_BITS, dtype=np.int64)
        ).ravel()
        arr[idx] = _field_bits(csums, KEY_BITS)
    return frame(MSG_VERIFY, _pack_payload(b"", [arr]))


def encode_verify_scalar(entries) -> bytes:
    """Per-bit ``BitWriter`` form of ``encode_verify`` (test oracle)."""
    w = BitWriter()
    for success, csum in entries:
        w.write(1 if success else 0, 1)
        w.write(int(csum) & 0xFFFFFFFF, KEY_BITS)
    return frame(MSG_VERIFY, w.getvalue())


def decode_verify(payload: bytes, n_sessions: int):
    bits = _bit_array(payload, 0)
    span = 1 + KEY_BITS
    total = n_sessions * span
    if total > len(bits):
        raise WireTruncated("bit field runs past end of buffer")
    succ = bits[0:total:span].astype(bool)
    csums = _read_fields(
        bits, np.arange(n_sessions, dtype=np.int64) * span + 1, KEY_BITS
    )
    _finish_bits(bits, total, payload, 0)
    return [(bool(s), int(c)) for s, c in zip(succ, csums)]


def decode_verify_scalar(payload: bytes, n_sessions: int):
    """Per-bit ``BitReader`` form of ``decode_verify`` (test oracle)."""
    r = BitReader(payload)
    out = []
    for _ in range(n_sessions):
        success = bool(r.read(1))
        out.append((success, r.read(KEY_BITS)))
    r.finish()
    return out


def encode_verify_ack(flags) -> bytes:
    arr = np.asarray(list(flags), dtype=bool).astype(np.uint8)
    return frame(MSG_VERIFY_ACK, _pack_payload(b"", [arr]) if len(arr) else b"")


def encode_verify_ack_scalar(flags) -> bytes:
    """Per-bit ``BitWriter`` form of ``encode_verify_ack`` (test oracle)."""
    w = BitWriter()
    for f in flags:
        w.write(1 if f else 0, 1)
    return frame(MSG_VERIFY_ACK, w.getvalue())


def decode_verify_ack(payload: bytes, n_sessions: int) -> list[bool]:
    bits = _bit_array(payload, 0)
    if n_sessions > len(bits):
        raise WireTruncated("bit field runs past end of buffer")
    out = [bool(b) for b in bits[:n_sessions]]
    _finish_bits(bits, n_sessions, payload, 0)
    return out


def decode_verify_ack_scalar(payload: bytes, n_sessions: int) -> list[bool]:
    """Per-bit ``BitReader`` form of ``decode_verify_ack`` (test oracle)."""
    r = BitReader(payload)
    out = [bool(r.read(1)) for _ in range(n_sessions)]
    r.finish()
    return out


# ---------------------------------------------------------------------------
# Tree-phase digest exchange (repro.tree, DESIGN.md §15)
# ---------------------------------------------------------------------------

# MSG_TREE payloads open with a flavor uvarint: one message type, two
# directions of the per-level barrier.
TREE_DIGEST = 0    # initiator -> responder: per-range digests for a frontier
TREE_VERDICT = 1   # responder -> initiator: per-range verdicts + leaf d̂

# per-range verdicts carried 2 bits wide in TREE_VERDICT frames
TREE_PRUNE = 0     # digests match: the range holds no symmetric difference
TREE_RECURSE = 1   # divergent and too hot for PBS: split and go deeper
TREE_LEAF = 2      # divergent with small residual d̂: hand range to PBS


def encode_tree_digest(level, counts, checksums, sketches) -> bytes:
    """One tree level's frontier digests, range order == frontier order.

    Payload: ``uvarint(TREE_DIGEST) || uvarint(level) || uvarint(ell) ||
    uvarint(R) || uvarint(count_r) x R`` then one MSB-first bit stream:
    per range a ``KEY_BITS``-bit checksum followed by ``ell`` zigzag ToW
    values at ``tow_value_bits(count_r)`` each (a range's sketch values are
    bounded by its own element count, so empty ranges cost zero sketch
    bits).  Ranges themselves are never shipped: both sides derive the
    frontier deterministically from the previous level's verdicts.
    """
    cnt = np.asarray(counts, dtype=np.int64)
    cs = np.asarray(checksums, dtype=np.int64)
    sk = np.asarray(sketches, dtype=np.int64)
    if sk.ndim != 2 or len(sk) != len(cnt):
        raise WireError("tree sketches must be one (R, ell) matrix")
    n_ranges = len(cnt)
    ell = int(sk.shape[1])
    if ell < 1:
        raise WireError("tree digest with empty sketch rows")
    header = (
        encode_uvarint(TREE_DIGEST)
        + encode_uvarint(int(level))
        + encode_uvarint(ell)
        + encode_uvarint(n_ranges)
        + b"".join(encode_uvarint(int(c)) for c in cnt)
    )
    segs = []
    for r in range(n_ranges):
        vbits = tow_value_bits(int(cnt[r]))
        z = (sk[r] << 1) ^ (sk[r] >> 63)
        if np.any(z > 2 * cnt[r]):
            v = int(sk[r][int(np.argmax(z > 2 * cnt[r]))])
            raise WireError(
                f"tree sketch value {v} exceeds range count {int(cnt[r])}"
            )
        segs.append(_field_bits([int(cs[r]) & 0xFFFFFFFF], KEY_BITS))
        if vbits:
            segs.append(_field_bits(z, vbits))
    return frame(MSG_TREE, _pack_payload(header, segs))


def decode_tree_digest(payload: bytes):
    """(level, ell, counts, checksums, sketches); strict.

    Rejects a non-``TREE_DIGEST`` flavor, truncated bit fields, sketch
    values out of range for their own range count, nonzero padding, and
    trailing bytes.
    """
    flavor, off = decode_uvarint(payload)
    if flavor != TREE_DIGEST:
        raise WireError(f"expected tree digest flavor, got {flavor}")
    level, off = decode_uvarint(payload, off)
    ell, off = decode_uvarint(payload, off)
    if ell < 1:
        raise WireError("tree digest with empty sketch rows")
    n_ranges, off = decode_uvarint(payload, off)
    counts = np.zeros(n_ranges, dtype=np.int64)
    for r in range(n_ranges):
        counts[r], off = decode_uvarint(payload, off)
    vbits = np.array(
        [tow_value_bits(int(c)) for c in counts], dtype=np.int64
    )
    total = int(np.sum(vbits) * ell) + n_ranges * KEY_BITS
    bstream = _bit_array(payload, off)
    if total > len(bstream):
        raise WireTruncated("bit field runs past end of buffer")
    csums = np.zeros(n_ranges, dtype=np.int64)
    sketches = np.zeros((n_ranges, ell), dtype=np.int64)
    pos = 0
    for r in range(n_ranges):
        csums[r] = _read_fields(bstream, [pos], KEY_BITS)[0]
        pos += KEY_BITS
        vb = int(vbits[r])
        if vb:
            offs = pos + np.arange(ell, dtype=np.int64) * vb
            z = _read_fields(bstream, offs, vb)
            if np.any(z > 2 * counts[r]):
                raise WireError(
                    "tree sketch value out of range for its range count"
                )
            sketches[r] = (z >> 1) ^ -(z & 1)
            pos += ell * vb
    _finish_bits(bstream, total, payload, off)
    return int(level), int(ell), counts, csums, sketches


def encode_tree_verdict(level, verdicts, leaf_ds) -> bytes:
    """One tree level's verdicts, range order == frontier order.

    Payload: ``uvarint(TREE_VERDICT) || uvarint(level) || uvarint(R)`` then
    R two-bit verdicts packed MSB-first (zero-padded to the byte), then one
    ``uvarint(d_plan)`` per ``TREE_LEAF`` verdict in range order — the
    planned d the matching PBS leaf session is built with on both sides.
    """
    v = np.asarray(verdicts, dtype=np.int64)
    ds = [int(d) for d in leaf_ds]
    if np.any((v < 0) | (v > TREE_LEAF)):
        raise WireError("tree verdict out of range")
    if len(ds) != int(np.sum(v == TREE_LEAF)):
        raise WireError("leaf d list does not match leaf verdict count")
    if any(d < 1 for d in ds):
        raise WireError("leaf d_plan must be >= 1")
    header = (
        encode_uvarint(TREE_VERDICT)
        + encode_uvarint(int(level))
        + encode_uvarint(len(v))
    )
    body = _pack_payload(header, [_field_bits(v, 2)] if len(v) else [])
    return frame(MSG_TREE, body + b"".join(encode_uvarint(d) for d in ds))


def decode_tree_verdict(payload: bytes):
    """(level, verdicts, leaf_ds); strict.

    Rejects a non-``TREE_VERDICT`` flavor, the reserved verdict value 3,
    nonzero verdict padding bits, zero leaf d, truncation, and trailing
    bytes after the final leaf ``uvarint``.
    """
    flavor, off = decode_uvarint(payload)
    if flavor != TREE_VERDICT:
        raise WireError(f"expected tree verdict flavor, got {flavor}")
    level, off = decode_uvarint(payload, off)
    n_ranges, off = decode_uvarint(payload, off)
    nbytes = (2 * n_ranges + 7) // 8
    if off + nbytes > len(payload):
        raise WireTruncated("bit field runs past end of buffer")
    bits = (
        np.unpackbits(
            np.frombuffer(payload, dtype=np.uint8, offset=off, count=nbytes)
        )
        if nbytes
        else np.zeros(0, dtype=np.uint8)
    )
    if np.any(bits[2 * n_ranges :]):
        raise WireError("nonzero padding bits at end of bit stream")
    verdicts = (
        _read_fields(bits, np.arange(n_ranges, dtype=np.int64) * 2, 2)
        if n_ranges
        else np.zeros(0, dtype=np.int64)
    )
    if np.any(verdicts > TREE_LEAF):
        raise WireError("tree verdict out of range")
    off += nbytes
    leaf_ds = np.zeros(int(np.sum(verdicts == TREE_LEAF)), dtype=np.int64)
    for i in range(len(leaf_ds)):
        leaf_ds[i], off = decode_uvarint(payload, off)
        if leaf_ds[i] < 1:
            raise WireError("leaf d_plan must be >= 1")
    if off != len(payload):
        raise WireError(f"{len(payload) - off} unconsumed bytes after frame")
    return int(level), verdicts, leaf_ds
