"""Byte-exact wire codec for the PBS protocol (DESIGN.md §9).

Every message the two endpoints of a PBS session exchange has a framed
binary encoding here — phase-0 ToW sketch + d_hat reply, per-round
syndrome-sketch frames, Bob's decode-outcome replies, Alice's
checksum-outcome frames, and the final verification exchange — each with
``encode``/``decode`` round-trip functions, varint length framing, and
per-frame *ledger bits*: the exact Formula-(1) protocol-information bits a
frame carries, derived from the decoded content (never from session-state
formulas).  ``repro.net`` endpoints accumulate those measured bits into the
per-session byte ledger and assert it equals ``core.pbs`` accounting
bit-for-bit (tests/test_net_endpoints.py, tests/test_recon_batch.py).
The ``MSG_MUX`` envelope (DESIGN.md §10) channel-tags complete frames for
the multi-peer hub, and the ``MSG_EPOCH`` envelope (DESIGN.md §11) opens a
continuous-sync epoch carrying the epoch id + d̂ re-estimation handshake;
both envelopes' bytes are transport overhead, never ledger bits.
``MSG_RESUME`` (DESIGN.md §13) is the session-resumption handshake: channel
id, epoch, last completed round barrier, and two rolling FNV-1a transcript
digests letting a crashed peer re-attach to the hub at its last barrier;
resume bytes are transport overhead too.  ``MSG_TREE`` (DESIGN.md §15)
carries the tree-phase per-range digest/verdict exchange the cold-start
front end runs before PBS admission; tree bytes are transport overhead,
split from PBS ledger bits exactly like the envelopes.
"""
from .frames import (
    MSG_DHAT,
    MSG_EPOCH,
    MSG_MUX,
    MSG_RESUME,
    MSG_TREE,
    MSG_ROUND_OUTCOME,
    MSG_ROUND_REPLY,
    MSG_ROUND_SKETCHES,
    MSG_TOW_SKETCH,
    MSG_VERIFY,
    MSG_VERIFY_ACK,
    ReplyUnit,
    WireError,
    WireTruncated,
    decode_dhat,
    decode_epoch,
    decode_mux,
    decode_resume,
    decode_round_outcome,
    decode_round_reply,
    decode_round_sketches,
    decode_tow_sketch,
    decode_tree_digest,
    decode_tree_verdict,
    decode_verify,
    decode_verify_ack,
    encode_dhat,
    encode_epoch,
    encode_mux,
    encode_resume,
    encode_round_outcome,
    encode_round_reply,
    encode_round_sketches,
    encode_tow_sketch,
    encode_tree_digest,
    encode_tree_verdict,
    encode_verify,
    encode_verify_ack,
    epoch_overhead_bytes,
    fold_transcript,
    frame,
    resume_overhead_bytes,
    transcript_digest0,
    mux_overhead_bytes,
    reply_ledger_bits,
    sketches_ledger_bits,
    split_frame,
)
from .varint import decode_uvarint, encode_uvarint, unzigzag, uvarint_len, zigzag

__all__ = [
    "MSG_DHAT",
    "MSG_EPOCH",
    "MSG_MUX",
    "MSG_RESUME",
    "MSG_TREE",
    "MSG_ROUND_OUTCOME",
    "MSG_ROUND_REPLY",
    "MSG_ROUND_SKETCHES",
    "MSG_TOW_SKETCH",
    "MSG_VERIFY",
    "MSG_VERIFY_ACK",
    "ReplyUnit",
    "WireError",
    "WireTruncated",
    "decode_dhat",
    "decode_epoch",
    "decode_mux",
    "decode_resume",
    "decode_round_outcome",
    "decode_round_reply",
    "decode_round_sketches",
    "decode_tow_sketch",
    "decode_tree_digest",
    "decode_tree_verdict",
    "decode_uvarint",
    "decode_verify",
    "decode_verify_ack",
    "encode_dhat",
    "encode_epoch",
    "encode_mux",
    "encode_resume",
    "encode_round_outcome",
    "encode_round_reply",
    "encode_round_sketches",
    "encode_tow_sketch",
    "encode_tree_digest",
    "encode_tree_verdict",
    "encode_uvarint",
    "encode_verify",
    "encode_verify_ack",
    "epoch_overhead_bytes",
    "fold_transcript",
    "frame",
    "resume_overhead_bytes",
    "transcript_digest0",
    "mux_overhead_bytes",
    "reply_ledger_bits",
    "sketches_ledger_bits",
    "split_frame",
    "unzigzag",
    "uvarint_len",
    "zigzag",
]
