"""Roofline analysis: HLO collective/flop/byte accounting + reports."""
from .hlo import analyze_hlo, collective_bytes  # noqa: F401
