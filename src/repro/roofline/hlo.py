"""Static roofline accounting from post-SPMD compiled HLO text.

``analyze_hlo(hlo_text, chips)`` walks the computation graph — ``while``
bodies weighted by their trip counts (``known_trip_count`` backend config,
falling back to loop-condition constants), so a collective or matmul inside
the 61-layer scan is charged 61×, unlike ``compiled.cost_analysis()`` which
charges loop bodies once — and accumulates three quantities per device:

* **flops** — every ``dot`` op: ``2 · prod(result dims) · prod(contracting
  dims)`` (operand shapes resolved through a per-computation symbol table).
  Elementwise flops are not counted; for every architecture here dots are
  >95% of compute (the SSD/RG-LRU scans' elementwise work is noted in
  EXPERIMENTS.md).
* **bytes** — HBM traffic proxy: for every *scope-level* op in fused HLO
  (fusions, dots, copies, slices, collectives), result bytes + operand bytes.
  Internals of kLoop/kInput fusions are register/VMEM-resident and excluded.
* **collective bytes** — ring-algorithm bytes per participating device:

      all-reduce(S, N)   : 2·S·(N−1)/N     all-gather -> S : S·(N−1)/N
      reduce-scatter(S_out): S_out·(N−1)   all-to-all(S, N): S·(N−1)/N
      collective-permute : S

Global = per-device × chips (uniform SPMD).  Roofline terms (DESIGN.md §6):
compute = flops_global/(chips·197e12), memory = bytes_global/(chips·819e9),
collective = coll_global/(chips·50e9).
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLL_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute")
_SHAPE_TOK = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\([^()]*\)|\S+)\s+([\w\-]+)\(")
_PARAM_RE = re.compile(r"%?([\w\.\-]+):\s*([a-z0-9]+\[[0-9,]*\])")
_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_GROUPS_LIST = re.compile(r"replica_groups=\{\{([0-9,\s]*)\}")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*"?n"?[^0-9]*(\d+)')
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply|true_computation|false_computation)=%?([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_LHS_CDIMS = re.compile(r"lhs_contracting_dims=\{([0-9,\s]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")

_NO_TRAFFIC = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}


def _dims(shape_str: str):
    m = _SHAPE_TOK.search(shape_str)
    if not m:
        return None, []
    dt, dims = m.group(1), m.group(2)
    return dt, [int(d) for d in dims.split(",") if d.strip()]


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_TOK.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _participants(line: str, chips: int) -> int:
    m = _GROUPS_IOTA.search(line)
    if m:
        return max(1, int(m.group(2)))
    m = _GROUPS_LIST.search(line)
    if m:
        return max(1, len([x for x in m.group(1).split(",") if x.strip()]))
    if "collective-permute" in line:
        return 2
    return chips


def _coll_bytes(op: str, result_bytes: int, n: int) -> float:
    if n <= 1:
        return 0.0
    if op == "all-reduce":
        return 2.0 * result_bytes * (n - 1) / n
    if op == "all-gather":
        return result_bytes * (n - 1) / n
    if op == "reduce-scatter":
        return float(result_bytes) * (n - 1)
    if op == "all-to-all":
        return result_bytes * (n - 1) / n
    if op == "collective-permute":
        return float(result_bytes)
    return 0.0


@dataclass
class Comp:
    name: str
    symbols: dict = field(default_factory=dict)    # %name -> shape string
    flops: float = 0.0
    bytes: float = 0.0
    coll: dict = field(default_factory=lambda: defaultdict(float))
    coll_counts: dict = field(default_factory=lambda: defaultdict(int))
    whiles: list = field(default_factory=list)     # (body, cond, trips or None)
    flop_calls: list = field(default_factory=list)
    constants: list = field(default_factory=list)  # integer constants (trip counts)
    unresolved_dots: int = 0


def _split(text: str) -> dict[str, Comp]:
    comps, cur = {}, None
    for line in text.splitlines():
        ls = line.rstrip()
        if ls.endswith("{") and ") -> " in ls and "=" not in ls.split("(")[0]:
            m = _HEADER_RE.match(ls.strip())
            if m:
                cur = Comp(m.group(1))
                comps[cur.name] = cur
                for pname, pshape in _PARAM_RE.findall(ls.split(") -> ")[0]):
                    cur.symbols[pname] = pshape
                # tuple-typed params: grab every dtype[…] in declaration order
                continue
        if cur is None:
            continue
        _scan_line(cur, line)
    return comps


def _scan_line(comp: Comp, line: str):
    d = _DEF_RE.match(line)
    if not d:
        return
    name, shape_str, op = d.group(1), d.group(2), d.group(3)
    comp.symbols[name] = shape_str
    base_op = op[:-6] if op.endswith("-start") else op
    if op.endswith("-done"):
        return
    if base_op == "constant":
        for c in _CONST_RE.findall(line):
            comp.constants.append(int(c))
        return
    if base_op in _COLL_OPS:
        n = _participants(line, 0) or 1
        rb = _shape_bytes(shape_str)
        comp.coll[base_op] += _coll_bytes(base_op, rb, n)
        comp.coll_counts[base_op] += 1
        comp.bytes += 2 * rb
        return
    if base_op == "while":
        body = _BODY_RE.search(line)
        cond = _COND_RE.search(line)
        trip = _TRIP_RE.search(line)
        comp.whiles.append(
            (body and body.group(1), cond and cond.group(1),
             int(trip.group(1)) if trip else None)
        )
        return
    if base_op == "dot":
        args = re.search(r"dot\(([^)]*)\)", line)
        cd = _LHS_CDIMS.search(line)
        _, rdims = _dims(shape_str)
        if args and cd is not None and rdims is not None:
            opnames = [a.strip().lstrip("%") for a in args.group(1).split(",")]
            lhs_shape = comp.symbols.get(opnames[0]) if opnames else None
            if lhs_shape:
                _, ldims = _dims(lhs_shape)
                k = 1
                for c in cd.group(1).split(","):
                    if c.strip():
                        k *= ldims[int(c)]
                rn = 1
                for x in rdims:
                    rn *= x
                comp.flops += 2.0 * rn * k
            else:
                comp.unresolved_dots += 1
        else:
            comp.unresolved_dots += 1
    if base_op in _NO_TRAFFIC:
        return
    # scope-level traffic: result + operands (fusion internals excluded)
    rb = _shape_bytes(shape_str)
    lname = name.lower()
    args = re.search(rf"{re.escape(op)}\(([^)]*)\)", line)
    op_bytes = []
    if args:
        for a in args.group(1).split(","):
            a = a.strip().lstrip("%")
            if a in comp.symbols:
                op_bytes.append(_shape_bytes(comp.symbols[a]))
    if "dynamic-update-slice" in lname or base_op == "dynamic-update-slice":
        # in-place window write: traffic ≈ 2 × the (small) update operand
        small = min([b for b in op_bytes if b > 0], default=rb)
        traffic = 2 * small
    elif "dynamic-slice" in lname or base_op == "dynamic-slice" or base_op == "slice":
        # reads only result-sized window of the (possibly huge) operand
        traffic = 2 * rb
    else:
        traffic = rb + sum(op_bytes)
    comp.bytes += traffic
    # flops inside fusions (dots occasionally fused): descend for flops only
    for callee in _CALLS_RE.findall(line):
        comp.flop_calls.append(callee)
    m = _BRANCHES_RE.search(line)
    if m:
        for callee in m.group(1).split(","):
            comp.flop_calls.append(callee.strip().lstrip("%"))


def _trip_from_cond(cond: Comp | None) -> int:
    if cond is None:
        return 1
    return max(cond.constants, default=1)


def analyze_hlo(hlo_text: str, chips: int) -> dict:
    comps = _split(hlo_text)

    memo: dict[str, dict] = {}

    def walk(name: str, depth=0) -> dict:
        if name in memo:
            return memo[name]
        comp = comps.get(name)
        if comp is None or depth > 80:
            return {"flops": 0.0, "bytes": 0.0, "coll": {}, "counts": {}, "unresolved": 0}
        memo[name] = {"flops": 0.0, "bytes": 0.0, "coll": {}, "counts": {}, "unresolved": 0}
        acc = {
            "flops": comp.flops,
            "bytes": comp.bytes,
            "coll": dict(comp.coll),
            "counts": dict(comp.coll_counts),
            "unresolved": comp.unresolved_dots,
        }

        def add(sub, mult=1.0):
            acc["flops"] += sub["flops"] * mult
            acc["bytes"] += sub["bytes"] * mult
            acc["unresolved"] += sub["unresolved"]
            for k, v in sub["coll"].items():
                acc["coll"][k] = acc["coll"].get(k, 0.0) + v * mult
            for k, v in sub["counts"].items():
                acc["counts"][k] = acc["counts"].get(k, 0) + int(v * mult)

        for callee in comp.flop_calls:
            sub = walk(callee, depth + 1)
            acc["flops"] += sub["flops"]          # flops only: fusion internals
            acc["unresolved"] += sub["unresolved"]
            for k, v in sub["coll"].items():
                acc["coll"][k] = acc["coll"].get(k, 0.0) + v
            for k, v in sub["counts"].items():
                acc["counts"][k] = acc["counts"].get(k, 0) + v
        for body, cond, trips in comp.whiles:
            t = trips if trips else _trip_from_cond(comps.get(cond))
            if body:
                add(walk(body, depth + 1), t)
            if cond:
                add(walk(cond, depth + 1), t)
        memo[name] = acc
        return acc

    entry = None
    for n in comps:
        if "main" in n:
            entry = n
            break
    if entry is None and comps:
        entry = max(comps, key=lambda n: comps[n].bytes + comps[n].flops)
    res = walk(entry) if entry else {"flops": 0, "bytes": 0, "coll": {}, "counts": {}, "unresolved": 0}
    coll_pd = sum(res["coll"].values())
    return {
        "entry": entry,
        "flops_per_device": res["flops"],
        "bytes_per_device": res["bytes"],
        "collective_per_device": coll_pd,
        "flops_global": res["flops"] * chips,
        "bytes_global": res["bytes"] * chips,
        "collective_global": coll_pd * chips,
        "collective_by_op_per_device": res["coll"],
        "collective_op_counts": res["counts"],
        "unresolved_dots": res["unresolved"],
    }


def collective_bytes(hlo_text: str, chips: int) -> dict:
    """Back-compat wrapper: collective summary only."""
    r = analyze_hlo(hlo_text, chips)
    return {
        "per_device_bytes": r["collective_per_device"],
        "global_bytes": r["collective_global"],
        "by_op_per_device": r["collective_by_op_per_device"],
        "op_counts_weighted": r["collective_op_counts"],
        "entry": r["entry"],
    }
