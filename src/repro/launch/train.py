"""Production training driver: data pipeline -> sharded train step ->
checkpoints -> (simulated) elastic events, on whatever devices exist.

This is the same loop a real deployment runs per host; on this CPU container
it drives small models end-to-end (examples/train_lm.py wraps it).  Fault
tolerance is exercised for real: checkpoints are atomic + manifest'd, resume
restores params/opt/ledger, and `--kill-at`/`--resume` simulate a failure and
a PBS-reconciled recovery.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --smoke \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt [--zero1] [--resume]
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path

import numpy as np


def build(arch: str, smoke: bool, batch: int, seq: int, zero1: bool,
          data: int = 1, model: int = 1, steps: int = 1000):
    import jax
    import jax.numpy as jnp  # noqa: F401

    from repro.configs import get_config, get_smoke_config
    from repro.optim import OptConfig
    from repro.train import init_train_state, make_train_step

    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    mesh = jax.make_mesh(
        (data, model), ("data", "model"), devices=jax.devices()[: data * model],
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )
    ocfg = OptConfig(warmup=max(5, steps // 20), total_steps=steps, zero1=zero1)
    bundle = make_train_step(cfg, mesh, ocfg, batch=batch)
    params, opt = init_train_state(bundle, cfg, mesh, ocfg)
    return cfg, mesh, ocfg, bundle, params, opt


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU-scale)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--zero1", action="store_true")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--kill-at", type=int, default=0, help="simulate failure at step N")
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args(argv)

    import jax

    from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
    from repro.data import DataConfig, Ledger, global_batch
    from repro.launch.elastic import ElasticConfig, Membership

    cfg, mesh, ocfg, bundle, params, opt = build(
        args.arch, args.smoke, args.batch, args.seq, args.zero1,
        args.data, args.model, args.steps,
    )
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch)
    ledger = Ledger()
    start = 0

    if args.resume and args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        tree, step = restore_checkpoint(args.ckpt_dir)
        params = jax.device_put(tree["params"], jax.tree.map(lambda x: x.sharding, params))
        opt = jax.device_put(tree["opt"], jax.tree.map(lambda x: x.sharding, opt))
        ledger.record(np.asarray(tree["meta"]["consumed"], np.uint32))
        start = step
        print(f"[train] resumed from step {step} "
              f"({len(ledger.consumed)} samples in ledger)", flush=True)

    membership = Membership([0], ElasticConfig())
    t_last = time.time()
    for step in range(start, args.steps):
        if args.kill_at and step == args.kill_at:
            print(f"[train] simulated failure at step {step} (rerun with --resume)")
            raise SystemExit(17)
        gb = global_batch(step, dcfg)
        batch = {
            "tokens": gb["tokens"],
            "labels": gb["labels"],
        }
        act_dt = params["final_norm"]["scale"].dtype
        if cfg.family == "encdec":
            import jax.numpy as jnp

            batch["enc"] = jnp.zeros((args.batch, 32, cfg.d_model), act_dt)
        if cfg.frontend == "patch_stub":
            import jax.numpy as jnp

            nf = min(cfg.n_frontend_tokens, args.seq // 2)
            tk = np.array(gb["tokens"], copy=True)
            tk[:, :nf] = -1  # frontend positions: embeddings come from `frontend`
            batch["tokens"] = tk
            batch["frontend"] = jnp.zeros((args.batch, args.seq, cfg.d_model), act_dt)
        params, opt, m = bundle.step(params, opt, batch)
        ledger.record(gb["ids"])
        dt = time.time() - t_last
        t_last = time.time()
        membership.heartbeat(0, step_time=dt)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"[train] step {step:5d} loss={float(m['loss']):.4f} "
                  f"gnorm={float(m['grad_norm']):.3f} lr={float(m['lr']):.2e} "
                  f"dt={dt:.2f}s", flush=True)
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            tree = {
                "params": jax.tree.map(np.asarray, params),
                "opt": jax.tree.map(np.asarray, opt),
                "meta": {"consumed": ledger.as_array()},
            }
            man = save_checkpoint(Path(args.ckpt_dir), step + 1, tree)
            print(f"[train] checkpoint @{step + 1}: {len(man.shards)} shards", flush=True)
    print("[train] done", flush=True)


if __name__ == "__main__":
    main()
