"""Launch layer: production meshes, dry-run cells, elastic runtime."""
