"""Production mesh construction (pure function — importing this module never
touches jax device state).

Single pod: (data=16, model=16) = 256 chips (one v5e pod).
Multi-pod : (pod=2, data=16, model=16) = 512 chips; the 'pod' axis carries
pure data parallelism (gradient reduction only — expert/TP collectives never
cross pods, see repro.models.ffn.EP_AXES).
"""
from __future__ import annotations

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    import jax

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, found {len(devices)} — run under "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 (launch/dryrun.py does this)"
        )
    import jax.sharding as jsh

    return jax.make_mesh(
        shape, axes,
        devices=devices[:need],
        axis_types=(jsh.AxisType.Auto,) * len(axes),
    )


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (possibly fake) devices exist — tests."""
    import jax
    import jax.sharding as jsh

    return jax.make_mesh(
        (data, model), ("data", "model"),
        devices=jax.devices()[: data * model],
        axis_types=(jsh.AxisType.Auto, jsh.AxisType.Auto),
    )
