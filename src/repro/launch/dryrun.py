import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: AOT-lower + compile every (architecture × input-shape)
cell on the production meshes, and extract the roofline terms.

The two lines above MUST precede any jax import: jax locks the device count
at first backend init, and the dry-run needs 512 placeholder host devices so
``jax.make_mesh`` can build (16,16) and (2,16,16) production meshes.  Nothing
is ever allocated: inputs are ShapeDtypeStructs and we stop at
``.lower().compile()`` + static analysis.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --arch all            # full sweep
  ... [--multi-pod] [--zero1] [--state-dtype bf16] [--no-master] [--out DIR]
"""
import argparse
import gc
import json
import time
import traceback
from pathlib import Path

PEAK_FLOPS = 197e12     # bf16 / chip (v5e)
HBM_BW = 819e9          # B/s / chip
ICI_BW = 50e9           # B/s / link

V5E_HBM_BYTES = 16 * 2**30


def model_flops(arch: str, kind: str, batch: int, seq: int) -> float:
    import jax.numpy as jnp  # noqa: F401

    from repro.configs import get_config
    from repro.models.config import n_active_params

    cfg = get_config(arch)
    n = n_active_params(cfg)
    if kind == "train":
        return 6.0 * n * batch * seq
    if kind == "prefill":
        return 2.0 * n * batch * seq
    return 2.0 * n * batch  # decode: one token per sequence


def run_cell(arch: str, shape: str, *, multi_pod: bool, out_dir: Path,
             opt_overrides: dict, remat: bool = True,
             capacity_factor: float | None = None, tag: str = "",
             attn_skip: bool = True, microbatch: int = 1) -> dict:
    import jax  # noqa: F401

    from repro.launch.cells import SHAPES, build_cell, cell_status, default_opt_cfg
    from repro.launch.mesh import make_production_mesh
    from repro.models.layers import BLOCK_SKIP_DEFAULT
    from repro.roofline.hlo import analyze_hlo

    BLOCK_SKIP_DEFAULT[0] = attn_skip

    runnable, why = cell_status(arch, shape)
    mesh_name = "multipod" if multi_pod else "pod"
    rec = {"arch": arch, "shape": shape, "mesh": mesh_name, "tag": tag}
    if not runnable:
        rec.update(status="skipped", reason=why)
        _save(rec, out_dir, tag)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    info = SHAPES[shape]
    opt_cfg = default_opt_cfg(arch, **opt_overrides) if info["kind"] == "train" else None
    t0 = time.time()
    cell = build_cell(arch, shape, mesh, opt_cfg=opt_cfg, remat=remat,
                      capacity_factor=capacity_factor, microbatch=microbatch)
    t_build = time.time() - t0

    t0 = time.time()
    lowered = cell.fn.lower(*cell.args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    roo = analyze_hlo(hlo, chips)

    mf = model_flops(arch, info["kind"], info["batch"], info["seq"])
    compute_s = roo["flops_global"] / (chips * PEAK_FLOPS)
    memory_s = roo["bytes_global"] / (chips * HBM_BW)
    coll_s = roo["collective_global"] / (chips * ICI_BW)
    terms = {"compute_s": compute_s, "memory_s": memory_s, "collective_s": coll_s}
    bound = max(terms, key=terms.get)
    step_s = max(terms.values())
    arg_b = getattr(mem, "argument_size_in_bytes", 0)
    tmp_b = getattr(mem, "temp_size_in_bytes", 0)
    out_b = getattr(mem, "output_size_in_bytes", 0)
    alias_b = getattr(mem, "alias_size_in_bytes", 0)
    peak_b = arg_b + tmp_b + out_b - alias_b

    rec.update(
        status="ok",
        kind=info["kind"], batch=info["batch"], seq=info["seq"], chips=chips,
        meta=cell.meta,
        times=dict(build=t_build, lower=t_lower, compile=t_compile),
        memory=dict(
            argument_bytes_per_device=arg_b,
            temp_bytes_per_device=tmp_b,
            output_bytes_per_device=out_b,
            alias_bytes_per_device=alias_b,
            peak_bytes_per_device=peak_b,
            fits_v5e=bool(peak_b <= V5E_HBM_BYTES),
        ),
        cost_analysis_raw=dict(
            flops=float(ca.get("flops", 0.0)),
            bytes_accessed=float(ca.get("bytes accessed", 0.0)),
        ),
        hlo=dict(
            flops_global=roo["flops_global"],
            bytes_global=roo["bytes_global"],
            collective_global=roo["collective_global"],
            collective_by_op_per_device=roo["collective_by_op_per_device"],
            collective_op_counts=roo["collective_op_counts"],
            unresolved_dots=roo["unresolved_dots"],
        ),
        roofline=dict(
            **terms, bound=bound, step_time_s=step_s,
            model_flops=mf,
            useful_flops_ratio=(mf / roo["flops_global"]) if roo["flops_global"] else 0.0,
            roofline_fraction=(mf / (chips * PEAK_FLOPS)) / step_s if step_s else 0.0,
        ),
    )
    _save(rec, out_dir, tag)
    print(
        f"[dryrun] {arch:18s} {shape:11s} {mesh_name:8s} "
        f"compile={t_compile:7.1f}s peak/dev={peak_b/2**30:7.2f}GiB "
        f"bound={bound:12s} terms(c/m/n)="
        f"{compute_s*1e3:9.3f}/{memory_s*1e3:9.3f}/{coll_s*1e3:9.3f} ms "
        f"MFU-bound={rec['roofline']['roofline_fraction']:.3f}",
        flush=True,
    )
    del compiled, lowered, cell
    gc.collect()
    return rec


def _save(rec: dict, out_dir: Path, tag: str = ""):
    out_dir.mkdir(parents=True, exist_ok=True)
    sfx = f"__{tag}" if tag else ""
    name = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}{sfx}.json"
    (out_dir / name).write_text(json.dumps(rec, indent=1, default=float))


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True, help="arch id or 'all'")
    ap.add_argument("--shape", default="all", help="shape id or 'all'")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--tag", default="")
    ap.add_argument("--zero1", action="store_true")
    ap.add_argument("--no-master", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--no-attn-skip", action="store_true",
                    help="dense chunk-pair attention (paper-faithful baseline)")
    ap.add_argument("--state-dtype", default="float32",
                    choices=["float32", "bfloat16", "int8"])
    ap.add_argument("--capacity-factor", type=float, default=None)
    ap.add_argument("--microbatch", type=int, default=1)
    args = ap.parse_args()

    import jax.numpy as jnp

    from repro.configs import ARCH_IDS
    from repro.launch.cells import SHAPES

    sd = {"float32": jnp.float32, "bfloat16": jnp.bfloat16, "int8": "int8"}[args.state_dtype]
    overrides = dict(
        zero1=args.zero1,
        master_fp32=not args.no_master,
        state_dtype=sd,
    )
    archs = list(ARCH_IDS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    out_dir = Path(args.out)
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    run_cell(
                        arch, shape, multi_pod=mp, out_dir=out_dir,
                        opt_overrides=overrides, remat=not args.no_remat,
                        capacity_factor=args.capacity_factor, tag=args.tag,
                        attn_skip=not args.no_attn_skip, microbatch=args.microbatch,
                    )
                except Exception:
                    failures += 1
                    print(f"[dryrun] FAIL {arch} {shape} multipod={mp}", flush=True)
                    traceback.print_exc()
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
