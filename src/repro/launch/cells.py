"""The (architecture × input-shape) dry-run grid: 10 archs × 4 shapes = 40 cells.

``build_cell(arch, shape, mesh)`` returns the jitted step function plus
`ShapeDtypeStruct` stand-ins for every input — `.lower(*args)` allocates
nothing.  ``cell_status`` marks the documented skips (long_500k needs
sub-quadratic attention; see DESIGN.md §8).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.models.spec import abstract_params
from repro.optim import OptConfig
from repro.serve import abstract_cache, make_serve_fns
from repro.train import batch_shapes, make_train_step

ENC_LEN = 1536  # whisper encoder positions (stub frames), divisible by model=16

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32_768, batch=32),
    "decode_32k": dict(kind="decode", seq=32_768, batch=128),
    "long_500k": dict(kind="decode", seq=524_288, batch=1),
}


def cell_status(arch: str, shape: str) -> tuple[bool, str]:
    cfg = get_config(arch)
    if shape == "long_500k" and not cfg.sub_quadratic:
        return False, "skip: pure full attention is quadratic at 500k (per assignment)"
    return True, "run"


@dataclass
class Cell:
    arch: str
    shape: str
    kind: str
    fn: object            # jitted step function
    args: tuple           # abstract args for .lower()
    meta: dict


def default_opt_cfg(arch: str, **overrides) -> OptConfig:
    base = dict(warmup=100, total_steps=10_000)
    base.update(overrides)
    return OptConfig(**base)


def build_cell(arch: str, shape: str, mesh, *, opt_cfg: OptConfig | None = None,
               remat: bool = True, capacity_factor: float | None = None,
               microbatch: int = 1) -> Cell:
    info = SHAPES[shape]
    cfg = get_config(arch)
    if capacity_factor is not None:
        cfg = cfg.scaled(capacity_factor=capacity_factor)
    kind, seq, batch = info["kind"], info["seq"], info["batch"]
    meta = dict(arch=arch, shape=shape, kind=kind, seq=seq, batch=batch,
                mesh=dict(zip(mesh.axis_names, (mesh.shape[a] for a in mesh.axis_names))))

    if kind == "train":
        ocfg = opt_cfg or default_opt_cfg(arch)
        bundle = make_train_step(cfg, mesh, ocfg, batch=batch, remat=remat,
                                 microbatch=microbatch)
        shapes = batch_shapes(cfg, batch, seq, enc_len=ENC_LEN)
        args = bundle.abstract_args(shapes)
        sd = ocfg.state_dtype if isinstance(ocfg.state_dtype, str) else str(jnp.dtype(ocfg.state_dtype))
        meta["opt"] = dict(zero1=ocfg.zero1, master_fp32=ocfg.master_fp32,
                           state_dtype=sd)
        return Cell(arch, shape, kind, bundle.step, args, meta)

    sv = make_serve_fns(cfg, mesh, batch=batch, max_len=seq, enc_len=ENC_LEN)
    params_abs = abstract_params(sv.param_spec)
    if kind == "prefill":
        inputs = {"tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32)}
        if cfg.family == "encdec":
            inputs["enc"] = jax.ShapeDtypeStruct((batch, ENC_LEN, cfg.d_model), jnp.bfloat16)
        if cfg.frontend == "patch_stub":
            inputs["frontend"] = jax.ShapeDtypeStruct((batch, seq, cfg.d_model), jnp.bfloat16)
        return Cell(arch, shape, kind, sv.prefill, (params_abs, inputs), meta)

    caches = abstract_cache(cfg, mesh, batch, seq, enc_len=ENC_LEN)
    toks = jax.ShapeDtypeStruct((batch, 1), jnp.int32)
    return Cell(arch, shape, kind, sv.decode, (params_abs, caches, toks), meta)


def all_cells():
    for arch in ARCH_IDS:
        for shape in SHAPES:
            yield arch, shape
