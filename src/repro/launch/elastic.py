"""Elastic cluster runtime: membership, failure recovery, straggler mitigation.

This module is the control-plane glue that makes the framework runnable at
1000+ nodes.  It is deliberately hardware-free (pure Python state machines +
the PBS protocol) so the same logic drives both the LocalClusterSim used in
tests/examples and a real multi-host deployment (where transports become
RPCs and `jax.distributed` restarts processes).

Design (DESIGN.md §4):

* **Membership / failure detection** — heartbeat table with a deadline;
  a missed deadline marks the node SUSPECT then DEAD; mesh re-formation is
  triggered when the alive set changes (elastic rescale to the largest
  (data × model) grid that the alive count supports).
* **Recovery via PBS** — a (re)joining node reconciles (a) its checkpoint
  manifest and (b) its data-ledger against a healthy peer with PBS —
  O(d) decode, ~2× optimal bytes — then fetches exactly the missing shards
  (`repro.checkpoint.sync_checkpoint`).  Piecewise reconciliability means
  shard fetches START while reconciliation of the remaining groups is still
  in flight (paper §1.3: the first round reconciles >95% of the diff).
* **Straggler mitigation** — per-step duration tracking; a node whose EWMA
  exceeds ``straggler_factor ×`` the fleet median is flagged; the scheduler
  first shrinks its data shard (work stealing), then evicts it from the mesh
  (the elastic path above).  Deterministic data assignment makes both safe.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from enum import Enum

import numpy as np


class NodeState(Enum):
    ALIVE = "alive"
    SUSPECT = "suspect"
    DEAD = "dead"
    JOINING = "joining"


@dataclass
class Node:
    node_id: int
    state: NodeState = NodeState.ALIVE
    last_heartbeat: float = 0.0
    step_ewma: float = 0.0
    steps_done: int = 0


@dataclass
class ElasticConfig:
    heartbeat_interval: float = 1.0
    suspect_after: float = 3.0      # missed-heartbeat seconds -> SUSPECT
    dead_after: float = 10.0        # -> DEAD, mesh re-forms
    straggler_factor: float = 1.5
    ewma: float = 0.3


def viable_grid(n: int, model: int = 16) -> tuple[int, int]:
    """Largest (data, model) grid with data*model <= n hosts*chips — data
    shrinks first (gradient accumulation keeps global batch constant)."""
    model = min(model, n)
    while n // model == 0:
        model //= 2
    return max(1, n // model), model


class Membership:
    """Heartbeat-driven membership table."""

    def __init__(self, node_ids, cfg: ElasticConfig | None = None, clock=time.monotonic):
        self.cfg = cfg or ElasticConfig()
        self.clock = clock
        now = clock()
        self.nodes = {i: Node(i, NodeState.ALIVE, now) for i in node_ids}
        self.generation = 0

    def heartbeat(self, node_id: int, step_time: float | None = None):
        n = self.nodes.setdefault(node_id, Node(node_id, NodeState.JOINING))
        n.last_heartbeat = self.clock()
        if n.state is NodeState.SUSPECT:
            n.state = NodeState.ALIVE
        elif n.state is NodeState.DEAD:
            n.state = NodeState.JOINING  # must PBS-sync state before admit()
        if step_time is not None:
            a = self.cfg.ewma
            n.step_ewma = step_time if n.step_ewma == 0 else (1 - a) * n.step_ewma + a * step_time
            n.steps_done += 1

    def sweep(self) -> bool:
        """Update states; returns True if the alive set changed (re-mesh)."""
        now = self.clock()
        changed = False
        for n in self.nodes.values():
            dt = now - n.last_heartbeat
            if n.state == NodeState.ALIVE and dt > self.cfg.suspect_after:
                n.state = NodeState.SUSPECT
            if n.state in (NodeState.ALIVE, NodeState.SUSPECT) and dt > self.cfg.dead_after:
                n.state = NodeState.DEAD
                changed = True
        if changed:
            self.generation += 1
        return changed

    def admit(self, node_id: int):
        """JOINING -> ALIVE after recovery completes (PBS sync done)."""
        n = self.nodes[node_id]
        n.state = NodeState.ALIVE
        n.last_heartbeat = self.clock()
        self.generation += 1

    def alive(self) -> list[int]:
        return sorted(i for i, n in self.nodes.items() if n.state == NodeState.ALIVE)

    def stragglers(self) -> list[int]:
        alive = [self.nodes[i] for i in self.alive() if self.nodes[i].step_ewma > 0]
        if len(alive) < 3:
            return []
        med = float(np.median([n.step_ewma for n in alive]))
        return [n.node_id for n in alive
                if n.step_ewma > self.cfg.straggler_factor * med]


@dataclass
class RecoveryPlan:
    shards_to_fetch: int
    payload_bytes: int
    pbs_bytes: int
    naive_bytes: int
    rounds: int
    samples_to_skip: int


def plan_recovery(local_ckpt_root, healthy_ckpt_root, local_ledger, fleet_ledger,
                  *, seed: int = 0) -> RecoveryPlan:
    """Everything a rejoining node needs, via two PBS reconciliations."""
    from repro.checkpoint.manager import sync_checkpoint

    rep = sync_checkpoint(healthy_ckpt_root, local_ckpt_root, seed=seed)
    missing, _extra, res = local_ledger.reconcile(fleet_ledger, seed=seed + 1)
    local_ledger.merge(missing)
    return RecoveryPlan(
        shards_to_fetch=rep.shards_fetched,
        payload_bytes=rep.payload_bytes,
        pbs_bytes=rep.pbs_bytes + res.bytes_sent + res.estimator_bytes,
        naive_bytes=rep.naive_bytes + 4 * max(1, len(fleet_ledger.consumed)),
        rounds=max(rep.rounds, res.rounds),
        samples_to_skip=len(missing),
    )
