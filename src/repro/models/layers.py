"""Core transformer layers, written to run INSIDE ``shard_map``.

Distribution contract (DESIGN.md §4):

* mesh axes: ('data', 'model') — plus an optional leading 'pod' axis that is
  pure data parallelism handled at the step level.
* the residual stream is **sequence-sharded over 'model'** between blocks
  (Megatron-SP): every block does all-gather(seq) on entry and
  reduce-scatter(seq) on exit, which costs exactly one all-reduce equivalent —
  the same bytes as classic TP, but leaves the stream sharded for MoE
  dispatch, LayerNorms, and residual adds.
* attention Q/O projections are head-sharded over 'model' with heads padded
  to a multiple of the axis size (zero-init pads are exact at init); K/V
  projections are replicated (GQA keeps them small) so every rank can serve
  any of its query heads' groups.
* embeddings/logits are vocab-sharded; the softmax/CE runs distributed with
  scalar psums only.

All code is pure JAX (no Pallas) so the multi-pod dry-run lowers on any
backend.  Collectives are explicit (`psum`/`all_gather`/`psum_scatter`) so
the roofline's collective term is fully controlled by this file.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .spec import P


@dataclass(frozen=True)
class MeshCtx:
    """Axis context passed through every layer."""

    model_axis: str = "model"
    model_size: int = 16
    data_axes: tuple = ("data",)
    data_size: int = 1          # size of the 'data' axis (EP world = data×model)

    @property
    def m(self):
        return self.model_axis

    def midx(self):
        return jax.lax.axis_index(self.model_axis)


def pad_to(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


# --------------------------------------------------------------------------
# sequence-parallel plumbing
# --------------------------------------------------------------------------


def ag_seq(x: jax.Array, ctx: MeshCtx) -> jax.Array:
    """(B, T/M, d) -> (B, T, d): gather the sequence shards."""
    if ctx.model_size == 1:
        return x
    return jax.lax.all_gather(x, ctx.m, axis=1, tiled=True)


def rs_seq(x: jax.Array, ctx: MeshCtx) -> jax.Array:
    """(B, T, d) partial sums -> (B, T/M, d) reduced shard (psum_scatter)."""
    if ctx.model_size == 1:
        return x
    return jax.lax.psum_scatter(x, ctx.m, scatter_dimension=1, tiled=True)


def psum_model(x: jax.Array, ctx: MeshCtx) -> jax.Array:
    if ctx.model_size == 1:
        return x
    return jax.lax.psum(x, ctx.m)


@functools.partial(jax.custom_jvp, nondiff_argnums=(1,))
def pmax_const(x, axis_name):
    """pmax treated as a constant under differentiation (softmax max-shift)."""
    return jax.lax.pmax(x, axis_name)


@pmax_const.defjvp
def _pmax_const_jvp(axis_name, primals, tangents):
    (x,) = primals
    return jax.lax.pmax(x, axis_name), jnp.zeros_like(x)


# --------------------------------------------------------------------------
# norms / activations / rope
# --------------------------------------------------------------------------


def norm_spec(cfg: ModelConfig, d: int | None = None):
    d = d or cfg.d_model
    if cfg.norm_type == "layernorm":
        return {"scale": P((d,), (None,), "ones"), "bias": P((d,), (None,), "zeros")}
    return {"scale": P((d,), (None,), "ones")}


def apply_norm(p, x, cfg: ModelConfig):
    xf = x.astype(jnp.float32)
    if cfg.norm_type == "layernorm":
        mu = xf.mean(-1, keepdims=True)
        xf = xf - mu
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + 1e-6) * p["scale"].astype(jnp.float32)
    if cfg.norm_type == "layernorm":
        y = y + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_head_norm(scale, x):
    """qk-norm: RMS over the head_dim with a learned per-dim scale."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + 1e-6) * scale.astype(jnp.float32)).astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding; x (..., T, Dh), positions (..., T)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = 1.0 / (theta ** (np.arange(0, half, dtype=np.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., T, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def act_fn(cfg: ModelConfig, gate, up):
    if cfg.act == "swiglu":
        return jax.nn.silu(gate) * up
    return jax.nn.gelu(gate) * up  # gated GeLU


# --------------------------------------------------------------------------
# blockwise (flash-style) attention — pure JAX, O(chunk^2) memory
# --------------------------------------------------------------------------

# §Perf baseline switch: [True] = causal block skipping on (the optimized
# default); launch/dryrun.py --no-attn-skip flips it for before/after runs.
BLOCK_SKIP_DEFAULT = [True]


def blockwise_attention(
    q: jax.Array,          # (B, Hl, Tq, Dh)
    k: jax.Array,          # (B, Hkv, Tk, Dh)
    v: jax.Array,          # (B, Hkv, Tk, Dv)
    kv_for_q: jax.Array,   # (Hl,) int32 — kv head per local q head
    *,
    causal: bool,
    q_offset=0,
    k_offset=0,
    window: int | None = None,
    q_chunk: int = 1024,
    k_chunk: int = 1024,
    kv_valid_len=None,     # mask k positions >= this (ragged caches)
    block_skip: bool | None = None,
) -> jax.Array:
    """Online-softmax attention over a STATIC list of (q-chunk, k-chunk)
    pairs (memory-bounded for 32k+, reverse-differentiable).

    With ``block_skip`` (the §Perf "causal block skipping" optimization,
    EXPERIMENTS.md): fully-masked chunk pairs are dropped from the pair list
    at trace time — causal attention does nq(nq+1)/2 instead of nq·nk chunk
    matmuls (~2× FLOPs), sliding windows only touch their diagonal band, and
    no (Tq × Tk) mask is ever materialized (the per-pair mask depends on the
    scanned pair indices, so XLA cannot hoist it out of the loop — the
    baseline nested-loop form got its masks precomputed into 100s-of-MB
    loop carries).  ``block_skip=False`` reproduces the dense pair grid
    (the paper-faithful baseline used for before/after measurements).
    """
    if block_skip is None:
        block_skip = BLOCK_SKIP_DEFAULT[0]
    B, Hl, Tq, Dh = q.shape
    Dv = v.shape[-1]
    Tk = k.shape[2]
    scale = 1.0 / np.sqrt(Dh)
    kg = jnp.take(k, kv_for_q, axis=1)  # (B, Hl, Tk, Dh) — broadcast gather
    vg = jnp.take(v, kv_for_q, axis=1)

    q_chunk = min(q_chunk, Tq)
    k_chunk = min(k_chunk, Tk)
    nq = (Tq + q_chunk - 1) // q_chunk
    nk = (Tk + k_chunk - 1) // k_chunk
    Tq_p, Tk_p = nq * q_chunk, nk * k_chunk
    if Tq_p != Tq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, Tq_p - Tq), (0, 0)))
    if Tk_p != Tk:
        kg = jnp.pad(kg, ((0, 0), (0, 0), (0, Tk_p - Tk), (0, 0)))
        vg = jnp.pad(vg, ((0, 0), (0, 0), (0, Tk_p - Tk), (0, 0)))
    kv_len = kv_valid_len if kv_valid_len is not None else Tk

    # ---- static pair list (trace-time; offsets are static in our callers) --
    qo = int(q_offset) if not hasattr(q_offset, "aval") else None
    ko = int(k_offset) if not hasattr(k_offset, "aval") else None
    pairs = []
    for qi in range(nq):
        for kj in range(nk):
            if block_skip and qo is not None and ko is not None:
                q_lo = qo + qi * q_chunk
                q_hi = qo + (qi + 1) * q_chunk - 1
                k_lo = ko + kj * k_chunk
                k_hi = ko + (kj + 1) * k_chunk - 1
                if causal and k_lo > q_hi:
                    continue                       # fully above the diagonal
                if window is not None and k_hi <= q_lo - window:
                    continue                       # fully left of the band
            pairs.append((qi, kj))
    pair_arr = jnp.asarray(np.array(pairs, dtype=np.int32))  # (P, 2)

    def step(carry, pair):
        m_all, l_all, acc_all = carry              # (nq,B,H,qc) ×2, (nq,B,H,qc,Dv)
        qi, kj = pair[0], pair[1]
        qc = jax.lax.dynamic_index_in_dim(q_st, qi, axis=0, keepdims=False)
        ks = jax.lax.dynamic_slice_in_dim(kg, kj * k_chunk, k_chunk, axis=2)
        vs = jax.lax.dynamic_slice_in_dim(vg, kj * k_chunk, k_chunk, axis=2)
        q_pos = q_offset + qi * q_chunk + jnp.arange(q_chunk)
        k_pos = k_offset + kj * k_chunk + jnp.arange(k_chunk)
        s = jnp.einsum("bhqd,bhkd->bhqk", qc, ks).astype(jnp.float32) * scale
        mask = k_pos[None, :] < (k_offset + kv_len)
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if window is not None:
            mask &= k_pos[None, :] > (q_pos[:, None] - window)
        s = jnp.where(mask[None, None], s, -1e30)
        m_prev = jax.lax.dynamic_index_in_dim(m_all, qi, 0, keepdims=False)
        l_prev = jax.lax.dynamic_index_in_dim(l_all, qi, 0, keepdims=False)
        acc_prev = jax.lax.dynamic_index_in_dim(acc_all, qi, 0, keepdims=False)
        m_new = jnp.maximum(m_prev, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + p.sum(-1)
        acc_new = acc_prev * corr[..., None] + jnp.einsum(
            "bhqk,bhkd->bhqd", p.astype(vs.dtype), vs
        ).astype(jnp.float32)
        m_all = jax.lax.dynamic_update_index_in_dim(m_all, m_new, qi, 0)
        l_all = jax.lax.dynamic_update_index_in_dim(l_all, l_new, qi, 0)
        acc_all = jax.lax.dynamic_update_index_in_dim(acc_all, acc_new, qi, 0)
        return (m_all, l_all, acc_all), None

    q_st = q.reshape(B, Hl, nq, q_chunk, Dh).transpose(2, 0, 1, 3, 4)  # (nq,B,H,qc,Dh)
    init = (
        jnp.full((nq, B, Hl, q_chunk), -1e30, jnp.float32),
        jnp.zeros((nq, B, Hl, q_chunk), jnp.float32),
        jnp.zeros((nq, B, Hl, q_chunk, Dv), jnp.float32),
    )
    (m, l, acc), _ = jax.lax.scan(step, init, pair_arr)
    out = acc / jnp.maximum(l[..., None], 1e-30)               # (nq,B,H,qc,Dv)
    out = out.transpose(1, 2, 0, 3, 4).reshape(B, Hl, Tq_p, Dv)
    return out[:, :, :Tq].astype(q.dtype)


def attention_partial_lse(q, k, v, kv_for_q, *, k_offset, kv_valid_len, q_pos):
    """Decode-side partial attention over a local KV chunk.

    Returns (numerator (B,H,1,Dv) f32, max (B,H,1) f32, denom (B,H,1) f32) for
    LSE-combination across the model axis (flash-decoding over shards).
    """
    scale = 1.0 / np.sqrt(q.shape[-1])
    kg = jnp.take(k, kv_for_q, axis=1)
    vg = jnp.take(v, kv_for_q, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, kg).astype(jnp.float32) * scale
    k_pos = k_offset + jnp.arange(k.shape[2])
    mask = (k_pos[None, :] < kv_valid_len) & (k_pos[None, :] <= q_pos[:, None])
    s = jnp.where(mask[None, None], s, -1e30)
    m = s.max(-1)
    p = jnp.exp(s - m[..., None])
    l = p.sum(-1)
    num = jnp.einsum("bhqk,bhkd->bhqd", p.astype(vg.dtype), vg).astype(jnp.float32)
    return num, m, l


def combine_partials(num, m, l, ctx: MeshCtx):
    """LSE-combine per-shard partial attention across the model axis."""
    if ctx.model_size == 1:
        return (num / jnp.maximum(l[..., None], 1e-30)).astype(jnp.bfloat16)
    m_all = jax.lax.pmax(m, ctx.m)
    corr = jnp.exp(m - m_all)
    num = jax.lax.psum(num * corr[..., None], ctx.m)
    l = jax.lax.psum(l * corr, ctx.m)
    return (num / jnp.maximum(l[..., None], 1e-30)).astype(jnp.bfloat16)
