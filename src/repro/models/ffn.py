"""Dense FFN (tensor-parallel) and MoE (expert-parallel) blocks.

Dense: classic Megatron column/row split over 'model' wrapped in the
sequence-parallel AG/RS pair.

MoE: experts are sharded over the **combined ('data','model') axis** — the
only placement that fits deepseek-v3's ~0.6T expert parameters on a 256-chip
pod (DESIGN.md §4); the 'pod' axis replicates experts so EP all-to-alls never
cross pods.  Tokens enter uniquely-owned (sequence-sharded for train/prefill,
round-robin batch ownership for decode), are routed with a capacity-bounded
single-shot ``all_to_all`` over the combined axis, processed by the owning
expert, and returned by the inverse ``all_to_all``; top-k combination happens
at the source rank where the router weights live.  Shared experts ride the
dense TP path.  Router runs in fp32; the switch-style aux loss is returned.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .layers import MeshCtx, act_fn, ag_seq, rs_seq
from .spec import P

EP_AXES = ("data", "model")  # expert-parallel world (never includes 'pod')


# --------------------------------------------------------------------------
# dense (TP) FFN
# --------------------------------------------------------------------------


def mlp_spec(cfg: ModelConfig, d_ff: int | None = None) -> dict:
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    return {
        "w_gate": P((d, ff), (None, "model")),
        "w_up": P((d, ff), (None, "model")),
        "w_down": P((ff, d), ("model", None)),
    }


def mlp_apply(p, x_sp, ctx: MeshCtx, cfg: ModelConfig):
    xg = ag_seq(x_sp, ctx)
    h = act_fn(cfg, xg @ p["w_gate"], xg @ p["w_up"])
    return rs_seq(h @ p["w_down"], ctx)


def mlp_decode(p, x, ctx: MeshCtx, cfg: ModelConfig):
    """Decode-mode TP FFN: x (B, 1, d) replicated; plain psum combine."""
    h = act_fn(cfg, x @ p["w_gate"], x @ p["w_up"]) @ p["w_down"]
    if ctx.model_size > 1:
        h = jax.lax.psum(h, ctx.m)
    return h


# --------------------------------------------------------------------------
# MoE
# --------------------------------------------------------------------------


def ep_world(ctx: MeshCtx) -> int:
    return ctx.data_size * ctx.model_size


def padded_experts(cfg: ModelConfig, ctx: MeshCtx) -> int:
    """Experts padded to a multiple of the EP world (deepseek-v2: 160 -> 256
    on a 256-chip pod).  Pad experts own no tokens — zero compute, and the
    router never scores them — they only cost their (sharded) storage."""
    from .layers import pad_to

    return pad_to(cfg.n_experts, ep_world(ctx))


def moe_spec(cfg: ModelConfig, ctx: MeshCtx) -> dict:
    d, ffm = cfg.d_model, cfg.moe_d_ff
    e_pad = padded_experts(cfg, ctx)
    spec = {
        "router": P((d, cfg.n_experts), (None, None), dtype=jnp.float32),
        "we_gate": P((e_pad, d, ffm), (EP_AXES, None, None)),
        "we_up": P((e_pad, d, ffm), (EP_AXES, None, None)),
        "we_down": P((e_pad, ffm, d), (EP_AXES, None, None)),
    }
    if cfg.n_shared_experts:
        spec.update(
            {
                "ws_gate": P((d, cfg.n_shared_experts * ffm), (None, "model")),
                "ws_up": P((d, cfg.n_shared_experts * ffm), (None, "model")),
                "ws_down": P((cfg.n_shared_experts * ffm, d), ("model", None)),
            }
        )
    return spec


def _moe_core(p, x, owned, cfg: ModelConfig, ctx: MeshCtx, ep_data_size: int):
    """Route owned tokens through the EP world and bring outputs home.

    x: (Nt, d) local tokens; owned: (Nt,) bool — exactly one rank owns each
    logical token.  Returns (y (Nt, d) — valid where owned, aux loss).
    """
    Nt, d = x.shape
    E, k = cfg.n_experts, cfg.moe_top_k
    world = ep_data_size * ctx.model_size
    ep_axes = EP_AXES if world > 1 else EP_AXES  # names exist even at size 1

    logits = x.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

    ownf = owned.astype(jnp.float32)
    n_owned = jnp.maximum(jax.lax.psum(ownf.sum(), ep_axes), 1.0)
    frac = (
        jnp.zeros(E, jnp.float32)
        .at[topi.reshape(-1)]
        .add(jnp.repeat(ownf, k))
    )
    frac = jax.lax.psum(frac, ep_axes) / (n_owned * k)
    pbar = jax.lax.psum((probs * ownf[:, None]).sum(0), ep_axes) / n_owned
    aux = E * jnp.sum(frac * pbar)

    cap = int(np.ceil(Nt * k / world * cfg.capacity_factor)) + 4
    flat_e = topi.reshape(-1)
    valid = jnp.repeat(owned, k)
    dest = flat_e % world
    onehot = jax.nn.one_hot(dest, world, dtype=jnp.int32) * valid[:, None].astype(jnp.int32)
    pos = ((jnp.cumsum(onehot, axis=0) - 1) * onehot).sum(-1)
    keep = valid & (pos < cap)
    pos_safe = jnp.where(keep, pos, cap)  # OOB scatter updates are dropped
    tok_idx = jnp.arange(Nt * k) // k

    send = jnp.zeros((world, cap, d), x.dtype)
    send = send.at[dest, pos_safe].add(jnp.where(keep[:, None], x[tok_idx], 0))
    meta = jnp.full((world, cap), -1, jnp.int32).at[dest, pos_safe].set(
        jnp.where(keep, flat_e, -1)
    )

    recv = jax.lax.all_to_all(send, ep_axes, split_axis=0, concat_axis=0, tiled=True)
    recv_e = jax.lax.all_to_all(meta, ep_axes, split_axis=0, concat_axis=0, tiled=True)

    rk = jax.lax.axis_index(ep_axes)
    toks = recv.reshape(world * cap, d)
    texp = recv_e.reshape(world * cap)
    lidx = jnp.where((texp >= 0) & (texp % world == rk), texp // world, -1)

    n_local = p["we_gate"].shape[0]  # padded experts / world (dsv3 16x16: 1)
    out = jnp.zeros_like(toks)
    for le in range(n_local):
        sel = (lidx == le)[:, None]
        xe = jnp.where(sel, toks, 0)
        h = act_fn(cfg, xe @ p["we_gate"][le], xe @ p["we_up"][le])
        out = out + jnp.where(sel, h @ p["we_down"][le], 0)

    back = jax.lax.all_to_all(
        out.reshape(world, cap, d), ep_axes, split_axis=0, concat_axis=0, tiled=True
    )
    y_flat = back[dest, jnp.minimum(pos_safe, cap - 1)] * keep[:, None]
    y = (y_flat.reshape(Nt, k, d) * topw[..., None].astype(x.dtype)).sum(1)
    return y, aux


def moe_apply(p, x_sp, ctx: MeshCtx, cfg: ModelConfig, ep_data_size: int):
    """Train/prefill path: x_sp (B, T/M, d) sequence-sharded (unique owners)."""
    B, Ts, d = x_sp.shape
    x = x_sp.reshape(B * Ts, d)
    y, aux = _moe_core(p, x, jnp.ones(B * Ts, bool), cfg, ctx, ep_data_size)
    y = y.reshape(B, Ts, d)
    if cfg.n_shared_experts:
        xg = ag_seq(x_sp, ctx)
        hs = act_fn(cfg, xg @ p["ws_gate"], xg @ p["ws_up"])
        y = y + rs_seq(hs @ p["ws_down"], ctx)
    return y, aux


def moe_decode(p, x, ctx: MeshCtx, cfg: ModelConfig, ep_data_size: int):
    """Decode path: x (B, 1, d) replicated over 'model'; batch entries are
    round-robin owned by model ranks, outputs psum'd back to everyone."""
    B, _, d = x.shape
    xt = x.reshape(B, d)
    owned = (jnp.arange(B) % ctx.model_size) == (
        ctx.midx() if ctx.model_size > 1 else 0
    )
    y, aux = _moe_core(p, xt, owned, cfg, ctx, ep_data_size)
    y = jnp.where(owned[:, None], y, 0)
    if ctx.model_size > 1:
        y = jax.lax.psum(y, ctx.m)
    y = y.reshape(B, 1, d)
    if cfg.n_shared_experts:
        hs = act_fn(cfg, x @ p["ws_gate"], x @ p["ws_up"])
        hs = hs @ p["ws_down"]
        if ctx.model_size > 1:
            hs = jax.lax.psum(hs, ctx.m)
        y = y + hs
    return y, aux
