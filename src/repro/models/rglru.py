"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t^2) ⊙ (i_t ⊙ x_t),
a_t = exp(c · r_t · log σ(Λ)),  c = 8,
with sigmoid input/recurrence gates (diagonal — see DESIGN.md §8).

Distribution (§Perf hillclimb 2, EXPERIMENTS.md): the block is
**sequence-parallel**, not Megatron-TP.  The recurrence is elementwise over
channels, so instead of gathering the full (B, T, d) stream per block
(2 all-gather + 2 reduce-scatter like the MLP), each rank keeps its T/M
sequence chunk with FULL width, runs a local ``associative_scan``, and
composes chunks across ranks with one all-gather of (B, w) segment
summaries (an affine map (A_seg, B_seg) per chunk) + a 3-step conv halo
``ppermute`` — O(B·w·M) bytes instead of O(B·T·d).  Weights are replicated
over 'model' (grad psum over 'model' comes from the leaf-axes complement
rule automatically).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .layers import MeshCtx
from .spec import P

_C = 8.0


def rglru_spec(cfg: ModelConfig, ctx: MeshCtx) -> dict:
    d, w = cfg.d_model, cfg.lru_width
    return {
        "w_gate_branch": P((d, w), (None, None)),
        "w_rec_branch": P((d, w), (None, None)),
        "conv_w": P((4, w), (None, None)),
        "conv_b": P((w,), (None,), "zeros"),
        "lam": P((w,), (None,), "ones"),      # Λ (softplus-domain init)
        "gx_w": P((w,), (None,), "ones"),     # diagonal input gate
        "gx_b": P((w,), (None,), "zeros"),
        "ga_w": P((w,), (None,), "ones"),     # diagonal recurrence gate
        "ga_b": P((w,), (None,), "zeros"),
        "wout": P((w, d), (None, None)),
    }


def _branch_in(p, x):
    gate = jax.nn.gelu(x @ p["w_gate_branch"])
    rec = x @ p["w_rec_branch"]
    return gate, rec


def _conv_with_halo(rec, halo, p):
    """Causal depthwise conv over the local chunk with a 3-position halo
    from the previous rank (zeros on rank 0)."""
    K = p["conv_w"].shape[0]
    xp = jnp.concatenate([halo, rec], axis=1)  # (B, T/M + 3, w)
    out = sum(xp[:, i : i + rec.shape[1]] * p["conv_w"][i] for i in range(K))
    return out + p["conv_b"]


def _gates(p, x):
    i_t = jax.nn.sigmoid(x * p["gx_w"] + p["gx_b"])
    r_t = jax.nn.sigmoid(x * p["ga_w"] + p["ga_b"])
    log_a = _C * r_t * jax.nn.log_sigmoid(p["lam"].astype(jnp.float32) + 4.0)
    a_t = jnp.exp(log_a)
    b_t = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-8)) * (i_t * x)
    return log_a.astype(jnp.float32), a_t.astype(jnp.float32), b_t.astype(jnp.float32)


def _combine(l, r):
    al, bl = l
    ar, br = r
    return al * ar, bl * ar + br


def rglru_apply(p, x_sp, ctx: MeshCtx, cfg: ModelConfig, *, return_state=False):
    """Sequence-parallel forward: x_sp (B, T/M, d) in, (B, T/M, d) out."""
    B, Tc, _ = x_sp.shape
    gate, rec = _branch_in(p, x_sp)

    if ctx.model_size > 1:
        perm = [(i, i + 1) for i in range(ctx.model_size - 1)]
        halo = jax.lax.ppermute(rec[:, -3:], ctx.m, perm)  # rank r-1 -> r
    else:
        halo = jnp.zeros_like(rec[:, :3])
    rec = _conv_with_halo(rec, halo, p)
    log_a, a, b = _gates(p, rec)

    a_prefix = jnp.exp(jnp.cumsum(log_a, axis=1))          # (B, Tc, w)
    _, h_local = jax.lax.associative_scan(_combine, (a, b), axis=1)

    if ctx.model_size > 1:
        A_seg = a_prefix[:, -1]                            # (B, w)
        B_seg = h_local[:, -1]
        A_all = jax.lax.all_gather(A_seg, ctx.m)           # (M, B, w)
        B_all = jax.lax.all_gather(B_seg, ctx.m)
        _, Bcum = jax.lax.associative_scan(_combine, (A_all, B_all), axis=0)
        r = ctx.midx()
        h_prev = jax.lax.dynamic_index_in_dim(
            Bcum, jnp.maximum(r - 1, 0), 0, keepdims=False
        )
        h_in = jnp.where(r > 0, h_prev, 0.0)               # (B, w)
        h = a_prefix * h_in[:, None] + h_local
        h_last_global = jax.lax.dynamic_index_in_dim(
            Bcum, ctx.model_size - 1, 0, keepdims=False
        )
        rec_tail_all = jax.lax.all_gather(rec[:, -3:], ctx.m)  # (M, B, 3, w)
        rec_tail = rec_tail_all[-1]
    else:
        h = h_local
        h_last_global = h[:, -1]
        rec_tail = rec[:, -3:]

    out = (h.astype(x_sp.dtype) * gate) @ p["wout"]        # local — no collective
    if return_state:
        return out, {
            "h": h_last_global,
            "conv": rec_tail.astype(jnp.bfloat16),
            "len": jnp.int32(Tc * ctx.model_size),
        }
    return out


def rglru_init_cache(cfg: ModelConfig, ctx: MeshCtx, batch: int):
    w = cfg.lru_width
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, 3, w), jnp.bfloat16),
        "len": jnp.zeros((), jnp.int32),
    }


def rglru_decode(p, x, cache, ctx: MeshCtx, cfg: ModelConfig):
    """x (B, 1, d) replicated over 'model'; weights replicated -> no psum."""
    gate, rec = _branch_in(p, x)                       # (B, 1, w)
    window = jnp.concatenate([cache["conv"].astype(rec.dtype), rec], axis=1)
    rec1 = jnp.einsum("bkc,kc->bc", window, p["conv_w"]) + p["conv_b"]
    _, a, b = _gates(p, rec1)
    h = a * cache["h"] + b
    out = (h.astype(x.dtype) * gate[:, 0]) @ p["wout"]
    return out[:, None], {
        "h": h,
        "conv": window[:, 1:].astype(jnp.bfloat16),
        "len": cache["len"] + 1,
    }
