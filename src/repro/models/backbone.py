"""Model assembly: embeddings, blocks, scan-over-layers, losses, caches.

Everything here runs inside shard_map (axes 'data'/'model', optional 'pod').
The residual stream between blocks is sequence-sharded (Megatron-SP).  The
layer stack is a `lax.scan` over stacked parameters (+ `jax.checkpoint` for
training) so HLO size is depth-independent — essential for compiling 61-layer
models on this container's single CPU core (DESIGN.md §4).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .attention import (
    gqa_apply,
    gqa_decode,
    gqa_fill_cache,
    gqa_init_cache,
    gqa_spec,
    local_decode,
    local_fill_cache,
    local_init_cache,
    mla_apply,
    mla_decode,
    mla_fill_cache,
    mla_init_cache,
    mla_spec,
)
from .config import ModelConfig
from .ffn import mlp_apply, mlp_spec, moe_apply, moe_decode, moe_spec
from .layers import MeshCtx, ag_seq, apply_norm, norm_spec, pad_to, pmax_const
from .rglru import rglru_apply, rglru_decode, rglru_init_cache, rglru_spec
from .spec import P, stack_layers
from .ssm import ssm_apply, ssm_decode, ssm_init_cache, ssm_spec


def vocab_pad(cfg: ModelConfig) -> int:
    return pad_to(cfg.vocab, 16)


# --------------------------------------------------------------------------
# embedding & losses (vocab-sharded over 'model')
# --------------------------------------------------------------------------


def embed_spec(cfg: ModelConfig) -> dict:
    v, d = vocab_pad(cfg), cfg.d_model
    spec = {"tok": P((v, d), ("model", None), scale=0.02)}
    if not cfg.tie_embeddings:
        spec["unembed"] = P((d, v), (None, "model"), scale=0.02)
    return spec


def embed_tokens(p, tokens, ctx: MeshCtx, cfg: ModelConfig, *, seq_sharded: bool = True):
    """Vocab-parallel embedding lookup (Megatron-style).

    seq_sharded=True (train/prefill): tokens (B, T/M) is this rank's seq
    chunk.  Each rank can only resolve ids inside its vocab shard, and ranks
    hold *different* tokens, so: all-gather the (tiny, int32) token ids over
    'model', do the partial lookup over the full T, and reduce-scatter the
    partial embeddings back to (B, T/M, d).

    seq_sharded=False (decode): tokens (B, 1) replicated; plain psum keeps
    the output replicated.
    """
    v = vocab_pad(cfg)
    vl = v // ctx.model_size
    v0 = ctx.midx() * vl if ctx.model_size > 1 else 0
    if seq_sharded and ctx.model_size > 1:
        tokens = jax.lax.all_gather(tokens, ctx.m, axis=1, tiled=True)  # (B, T)
    loc = tokens - v0
    ok = (loc >= 0) & (loc < vl)
    emb = jnp.take(p["tok"], jnp.clip(loc, 0, vl - 1), axis=0)
    emb = jnp.where(ok[..., None], emb, 0)
    if ctx.model_size > 1:
        if seq_sharded:
            emb = jax.lax.psum_scatter(emb, ctx.m, scatter_dimension=1, tiled=True)
        else:
            emb = jax.lax.psum(emb, ctx.m)
    return emb.astype(p["tok"].dtype)  # activation dtype follows the params


def _unembed_weight(p, cfg: ModelConfig):
    return p["tok"].T if cfg.tie_embeddings else p["unembed"]


def _mask_vocab_pad(logits, v0, cfg: ModelConfig):
    """-inf the vocab-padding columns so they never enter softmax/argmax."""
    v = vocab_pad(cfg)
    if v == cfg.vocab:
        return logits
    gcol = v0 + jnp.arange(logits.shape[-1])
    return jnp.where(gcol < cfg.vocab, logits, -1e30)


def ce_loss(p, x_sp, targets, ctx: MeshCtx, cfg: ModelConfig, t_chunk: int = 512):
    """Cross-entropy with vocab-sharded logits, chunked over T.

    x_sp (B, T/M, d) seq-sharded; targets (B, T) global.  Gathers the stream
    once (the standard final all-gather), then per T-chunk computes local
    logits (B, c, V/M) and reduces the softmax with scalar-sized psums.
    """
    xg = ag_seq(x_sp, ctx)  # (B, T, d)
    B, T, d = xg.shape
    w = _unembed_weight(p, cfg)
    v = vocab_pad(cfg)
    vl = v // ctx.model_size
    v0 = ctx.midx() * vl if ctx.model_size > 1 else 0
    t_chunk = min(t_chunk, T)
    nc = T // t_chunk

    def chunk_loss(carry, i):
        total, cnt = carry
        xs = jax.lax.dynamic_slice_in_dim(xg, i * t_chunk, t_chunk, axis=1)
        ys = jax.lax.dynamic_slice_in_dim(targets, i * t_chunk, t_chunk, axis=1)
        logits = (xs @ w).astype(jnp.float32)
        if cfg.logit_softcap:
            logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
        logits = _mask_vocab_pad(logits, v0, cfg)
        m = jax.lax.stop_gradient(logits.max(-1))
        if ctx.model_size > 1:
            m = pmax_const(m, ctx.m)  # constant shift; plain pmax has no JVP rule
        se = jnp.exp(logits - m[..., None]).sum(-1)
        if ctx.model_size > 1:
            se = jax.lax.psum(se, ctx.m)
        valid = ys >= 0  # negative labels (frontend/pad positions) don't count
        loc = jnp.where(valid, ys, 0) - v0
        ok = (loc >= 0) & (loc < vl)
        lab = jnp.take_along_axis(
            logits, jnp.clip(loc, 0, vl - 1)[..., None], axis=-1
        )[..., 0]
        lab = jnp.where(ok, lab, 0.0)
        if ctx.model_size > 1:
            lab = jax.lax.psum(lab, ctx.m)
        nll = jnp.where(valid, (jnp.log(se) + m) - lab, 0.0)
        return (total + nll.sum(), cnt + valid.sum()), None

    (total, cnt), _ = jax.lax.scan(
        chunk_loss, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32)), jnp.arange(nc)
    )
    return total / jnp.maximum(cnt, 1).astype(jnp.float32)


def greedy_token(p, x, ctx: MeshCtx, cfg: ModelConfig):
    """Distributed argmax over vocab-sharded logits; x (B, 1, d)."""
    w = _unembed_weight(p, cfg)
    v = vocab_pad(cfg)
    vl = v // ctx.model_size
    v0 = ctx.midx() * vl if ctx.model_size > 1 else 0
    logits = (x[:, 0] @ w).astype(jnp.float32)  # (B, V/M)
    if cfg.logit_softcap:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    logits = _mask_vocab_pad(logits, v0, cfg)
    val = logits.max(-1)
    idx = logits.argmax(-1) + v0
    if ctx.model_size > 1:
        vals = jax.lax.all_gather(val, ctx.m)        # (M, B)
        idxs = jax.lax.all_gather(idx, ctx.m)
        best = vals.argmax(0)
        return jnp.take_along_axis(idxs, best[None], axis=0)[0]
    return idx


# --------------------------------------------------------------------------
# block kinds
# --------------------------------------------------------------------------


def block_spec(cfg: ModelConfig, ctx: MeshCtx, kind: str) -> dict:
    if kind == "attn":
        return {"ln1": norm_spec(cfg), "attn": gqa_spec(cfg, ctx), "ln2": norm_spec(cfg), "mlp": mlp_spec(cfg)}
    if kind == "attn_window":
        return {"ln1": norm_spec(cfg), "attn": gqa_spec(cfg, ctx), "ln2": norm_spec(cfg), "mlp": mlp_spec(cfg)}
    if kind == "mla_dense":
        return {"ln1": norm_spec(cfg), "attn": mla_spec(cfg, ctx), "ln2": norm_spec(cfg), "mlp": mlp_spec(cfg)}
    if kind == "mla_moe":
        return {"ln1": norm_spec(cfg), "attn": mla_spec(cfg, ctx), "ln2": norm_spec(cfg), "moe": moe_spec(cfg, ctx)}
    if kind == "ssm":
        return {"ln1": norm_spec(cfg), "ssm": ssm_spec(cfg, ctx)}
    if kind == "rglru":
        return {"ln1": norm_spec(cfg), "rec": rglru_spec(cfg, ctx), "ln2": norm_spec(cfg), "mlp": mlp_spec(cfg)}
    if kind == "dec":  # enc-dec decoder block: self-attn + cross-attn + mlp
        return {
            "ln1": norm_spec(cfg),
            "attn": gqa_spec(cfg, ctx),
            "lnx": norm_spec(cfg),
            "cross": gqa_spec(cfg, ctx),
            "ln2": norm_spec(cfg),
            "mlp": mlp_spec(cfg),
        }
    raise ValueError(kind)


def make_block_fn(
    cfg: ModelConfig, ctx: MeshCtx, kind: str, ep_data_size: int,
    *, memory=None, causal: bool = True,
):
    """Returns f(params, x_sp) -> (x_sp, aux) for train/prefill."""

    def attn_block(p, x):
        w = cfg.window if kind == "attn_window" else None
        h = gqa_apply(p["attn"], apply_norm(p["ln1"], x, cfg), ctx, cfg,
                      causal=causal, window=w)
        x = x + h
        x = x + mlp_apply(p["mlp"], apply_norm(p["ln2"], x, cfg), ctx, cfg)
        return x, jnp.zeros((), jnp.float32)

    def dec_block(p, x):
        x = x + gqa_apply(p["attn"], apply_norm(p["ln1"], x, cfg), ctx, cfg)
        x = x + gqa_apply(p["cross"], apply_norm(p["lnx"], x, cfg), ctx, cfg,
                          causal=False, memory=memory)
        x = x + mlp_apply(p["mlp"], apply_norm(p["ln2"], x, cfg), ctx, cfg)
        return x, jnp.zeros((), jnp.float32)

    def mla_dense_block(p, x):
        x = x + mla_apply(p["attn"], apply_norm(p["ln1"], x, cfg), ctx, cfg)
        x = x + mlp_apply(p["mlp"], apply_norm(p["ln2"], x, cfg), ctx, cfg)
        return x, jnp.zeros((), jnp.float32)

    def mla_moe_block(p, x):
        x = x + mla_apply(p["attn"], apply_norm(p["ln1"], x, cfg), ctx, cfg)
        y, aux = moe_apply(p["moe"], apply_norm(p["ln2"], x, cfg), ctx, cfg, ep_data_size)
        return x + y, aux

    def ssm_block(p, x):
        x = x + ssm_apply(p["ssm"], apply_norm(p["ln1"], x, cfg), ctx, cfg)
        return x, jnp.zeros((), jnp.float32)

    def rglru_block(p, x):
        x = x + rglru_apply(p["rec"], apply_norm(p["ln1"], x, cfg), ctx, cfg)
        x = x + mlp_apply(p["mlp"], apply_norm(p["ln2"], x, cfg), ctx, cfg)
        return x, jnp.zeros((), jnp.float32)

    table = {
        "attn": attn_block,
        "attn_window": attn_block,
        "mla_dense": mla_dense_block,
        "mla_moe": mla_moe_block,
        "ssm": ssm_block,
        "rglru": rglru_block,
        "dec": dec_block,
    }
    return table[kind]


# --------------------------------------------------------------------------
# layer plans per family
# --------------------------------------------------------------------------


def layer_plan(cfg: ModelConfig):
    """[(kind, count, scanned)] — scanned groups share stacked params."""
    if cfg.family in ("dense", "vlm"):
        return [("attn", cfg.n_layers, True)]
    if cfg.family == "moe":
        return [
            ("mla_dense", cfg.n_dense_layers, False),
            ("mla_moe", cfg.n_layers - cfg.n_dense_layers, True),
        ]
    if cfg.family == "ssm":
        return [("ssm", cfg.n_layers, True)]
    if cfg.family == "hybrid":
        period = len(cfg.pattern)
        full = cfg.n_layers // period
        rem = cfg.n_layers - full * period
        plan = [("hybrid_period", full, True)]
        for i in range(rem):
            kind = "rglru" if cfg.pattern[i] == "rglru" else "attn_window"
            plan.append((kind, 1, False))
        return plan
    if cfg.family == "encdec":
        return [("dec", cfg.n_layers, True)]
    raise ValueError(cfg.family)


def hybrid_period_spec(cfg, ctx):
    return {
        f"b{i}": block_spec(
            cfg, ctx, "rglru" if k == "rglru" else "attn_window"
        )
        for i, k in enumerate(cfg.pattern)
    }


def model_spec(cfg: ModelConfig, ctx: MeshCtx) -> dict:
    spec = {"embed": embed_spec(cfg), "final_norm": norm_spec(cfg)}
    for gi, (kind, count, scanned) in enumerate(layer_plan(cfg)):
        if count == 0:
            continue
        base = (
            hybrid_period_spec(cfg, ctx)
            if kind == "hybrid_period"
            else block_spec(cfg, ctx, kind)
        )
        spec[f"g{gi}"] = stack_layers(base, count) if scanned else (
            {f"l{i}": base for i in range(count)} if count > 1 else base
        )
    if cfg.family == "encdec":
        spec["enc"] = {
            "layers": stack_layers(block_spec(cfg, ctx, "attn"), cfg.n_enc_layers),
            "norm": norm_spec(cfg),
        }
    return spec


def _scan_group(fn, params_stack, x, count, remat=True):
    body = jax.checkpoint(fn) if remat else fn

    def step(carry, p):
        x, aux = carry
        x2, a = body(p, x)
        return (x2, aux + a), None

    (x, aux), _ = jax.lax.scan(step, (x, jnp.zeros((), jnp.float32)), params_stack)
    return x, aux


def encode(params, enc_embeds_sp, ctx: MeshCtx, cfg: ModelConfig, remat=True):
    """Encoder stack over stub frame embeddings -> gathered memory (B, Te, d)."""
    fn = make_block_fn(cfg, ctx, "attn", 1, causal=False)
    act_dt = params["enc"]["norm"]["scale"].dtype  # follow the param dtype
    x, _ = _scan_group(fn, params["enc"]["layers"], enc_embeds_sp.astype(act_dt),
                       cfg.n_enc_layers, remat)
    x = apply_norm(params["enc"]["norm"], x, cfg)
    return ag_seq(x, ctx)


def forward(params, tokens_sp, ctx: MeshCtx, cfg: ModelConfig, *,
            ep_data_size: int, frontend_sp=None, enc_embeds_sp=None, remat=True):
    """Sequence-sharded forward to the final norm.

    tokens_sp (B, T/M) — this rank's chunk; frontend_sp (B, T/M, d) optional
    stub embeddings with a mask convention: positions where frontend feeds
    are marked by token id == -1 (replaced by the provided embeddings);
    enc_embeds_sp (B, Te/M, d) drives the encoder for enc-dec models.
    """
    x = embed_tokens(params["embed"], jnp.maximum(tokens_sp, 0), ctx, cfg)
    if frontend_sp is not None:
        x = jnp.where((tokens_sp < 0)[..., None], frontend_sp.astype(x.dtype), x)
    memory = (
        encode(params, enc_embeds_sp, ctx, cfg, remat)
        if cfg.family == "encdec"
        else None
    )
    aux = jnp.zeros((), jnp.float32)
    plan = layer_plan(cfg)
    for gi, (kind, count, scanned) in enumerate(plan):
        if count == 0:
            continue
        p = params[f"g{gi}"]
        if kind == "hybrid_period":
            fns = [
                make_block_fn(cfg, ctx, "rglru" if k == "rglru" else "attn_window", ep_data_size)
                for k in cfg.pattern
            ]

            def period_fn(pp, xx):
                a = jnp.zeros((), jnp.float32)
                for i, f in enumerate(fns):
                    xx, ai = f(pp[f"b{i}"], xx)
                    a = a + ai
                return xx, a

            x, a = _scan_group(period_fn, p, x, count, remat)
            aux += a
        else:
            fn = make_block_fn(cfg, ctx, kind, ep_data_size, memory=memory)
            if scanned:
                x, a = _scan_group(fn, p, x, count, remat)
                aux += a
            else:
                items = [p] if count == 1 else [p[f"l{i}"] for i in range(count)]
                for item in items:
                    x, a = (jax.checkpoint(fn) if remat else fn)(item, x)
                    aux += a
    x = apply_norm(params["final_norm"], x, cfg)
    return x, aux
