"""Parameter-spec system: one source of truth for shapes, shardings and init.

A model is described as a nested dict of `P` leaves.  From that single tree we
derive (a) materialized parameters for smoke tests / real training, (b)
`ShapeDtypeStruct` stand-ins for the AOT dry-run (nothing allocated), and (c)
`PartitionSpec`s for both the `shard_map` body and the jit boundary.

Sharding axes are *logical* names ('model', 'data', None); `resolve_pspec`
maps them onto the active mesh (the 'pod' axis, when present, is folded into
data parallelism at the step level, not in parameter specs).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec


@dataclass(frozen=True)
class P:
    """A parameter leaf: shape + logical sharding + init law.

    `logical` (optional): the unpadded shape — init draws random values at
    this shape and zero-pads to `shape`, so the SAME seed yields the SAME
    model regardless of mesh size (head-padding makes `shape` mesh-
    dependent; tests/test_mesh_parity.py relies on this invariance)."""

    shape: tuple
    axes: tuple            # logical axis per dim: 'model' | None
    init: str = "normal"   # normal | zeros | ones | scaled
    scale: float | None = None
    dtype: Any = jnp.bfloat16
    logical: tuple | None = None


def tree_map_p(fn, tree):
    if isinstance(tree, dict):
        return {k: tree_map_p(fn, v) for k, v in tree.items()}
    assert isinstance(tree, P), type(tree)
    return fn(tree)


def abstract_params(tree):
    """ShapeDtypeStructs for .lower() — no memory is touched."""
    return tree_map_p(lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype), tree)


def pspecs(tree):
    return tree_map_p(lambda p: PartitionSpec(*p.axes), tree)


def init_params(tree, key):
    """Materialize parameters (smoke tests / examples / real training)."""
    leaves = []

    def collect(p):
        leaves.append(p)
        return p

    tree_map_p(collect, tree)
    keys = jax.random.split(key, max(1, len(leaves)))
    it = iter(range(len(leaves)))

    def build(p: P):
        i = next(it)
        if p.init == "zeros":
            return jnp.zeros(p.shape, p.dtype)
        if p.init == "ones":
            return jnp.ones(p.shape, p.dtype)
        draw = p.logical or p.shape
        fan_in = draw[-2] if len(draw) >= 2 else draw[-1]
        scale = p.scale if p.scale is not None else 1.0 / np.sqrt(max(1, fan_in))
        x = (jax.random.normal(keys[i], draw, jnp.float32) * scale).astype(p.dtype)
        if p.logical is not None and p.logical != p.shape:
            x = jnp.pad(x, [(0, a - b) for a, b in zip(p.shape, p.logical)])
        return x

    return tree_map_p(build, tree)


def stack_layers(tree, n_layers: int):
    """Add a leading scan axis to every leaf (never sharded)."""
    return tree_map_p(
        lambda p: P(
            (n_layers,) + p.shape, (None,) + p.axes, p.init, p.scale, p.dtype,
            logical=((n_layers,) + p.logical) if p.logical is not None else None,
        ),
        tree,
    )


def count_params(tree) -> int:
    total = 0

    def add(p):
        nonlocal total
        total += int(np.prod(p.shape))
        return p

    tree_map_p(add, tree)
    return total


def shard_info(tree, axis_size: int) -> dict:
    """Bytes per device for a given model-axis size (for memory budgeting)."""
    per_dev = 0

    def add(p):
        nonlocal per_dev
        n = int(np.prod(p.shape)) * jnp.dtype(p.dtype).itemsize
        if "model" in p.axes:
            n //= axis_size
        per_dev += n
        return p

    tree_map_p(add, tree)
    return {"bytes_per_device": per_dev}
