"""Mamba-2 / SSD block (state-space duality, arXiv:2405.21060), shard_map-
resident.  Heads (= d_inner/headdim) are sharded over 'model'; the shared
B/C projections (ngroups=1) are replicated (small); output row-sharded with
sequence-parallel reduce-scatter.

Train/prefill uses the chunked SSD algorithm: quadratic attention-like
within-chunk term + an inter-chunk state recurrence (lax.scan over chunks).
Decode is the O(1) recurrent step — why `long_500k` runs for this family.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .layers import MeshCtx, ag_seq, rs_seq
from .spec import P


def _dims(cfg: ModelConfig, ctx: MeshCtx):
    d_inner = cfg.d_model * cfg.ssm_expand
    H = d_inner // cfg.ssm_headdim
    return d_inner, H, cfg.ssm_headdim, cfg.ssm_ngroups, cfg.ssm_state


def ssm_spec(cfg: ModelConfig, ctx: MeshCtx) -> dict:
    d = cfg.d_model
    d_inner, H, hp, G, N = _dims(cfg, ctx)
    return {
        "wz": P((d, d_inner), (None, "model")),
        "wx": P((d, d_inner), (None, "model")),
        "wbc": P((d, 2 * G * N), (None, None)),
        "wdt": P((d, H), (None, "model")),
        "dt_bias": P((H,), ("model",), "zeros"),
        "a_log": P((H,), ("model",), "ones"),
        "dskip": P((H,), ("model",), "ones"),
        "conv_x": P((cfg.ssm_conv, d_inner), (None, "model")),
        "conv_bc": P((cfg.ssm_conv, 2 * G * N), (None, None)),
        "gate_norm": P((d_inner,), ("model",), "ones"),
        "wout": P((d_inner, d), ("model", None)),
    }


def _causal_conv(x, w):
    """Depthwise causal conv: x (B, T, C), w (K, C)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(K))
    return jax.nn.silu(out)


def _ssd_chunked(xh, dt, A, B, C, cfg: ModelConfig, init_state=None):
    """Chunked SSD: xh (B, T, H, P), dt (B, T, H), B/C (B, T, G, N).

    Returns (y (B, T, H, P), final_state (B, H, P, N)).
    """
    Bsz, T, H, Pd = xh.shape
    G = B.shape[2]
    N = B.shape[3]
    L = min(cfg.ssm_chunk, T)
    T_pad = -(-T // L) * L
    if T_pad != T:  # ragged tail: dt=0 pads are exact no-ops in the SSD math
        pad = ((0, 0), (0, T_pad - T), (0, 0), (0, 0))
        xh = jnp.pad(xh, pad)
        dt = jnp.pad(dt, pad[:3])
        B = jnp.pad(B, pad)
        C = jnp.pad(C, pad)
    T_eff = T_pad
    nC = T_eff // L
    rep = H // G

    xc = xh.reshape(Bsz, nC, L, H, Pd)
    dtc = dt.reshape(Bsz, nC, L, H)
    Bc = B.reshape(Bsz, nC, L, G, N)
    Cc = C.reshape(Bsz, nC, L, G, N)
    dA = dtc * (-jnp.exp(A))[None, None, None, :]      # (B, nC, L, H) negative
    cum = jnp.cumsum(dA, axis=2)                        # within-chunk cumulative

    # within-chunk (quadratic) term; mask BEFORE exp (where-after-exp makes
    # inf·0 = NaN gradients on the q<k entries)
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nC,Lq,Lk,H)
    tri = jnp.tril(jnp.ones((L, L), bool))
    decay = jnp.exp(jnp.where(tri[None, None, :, :, None], seg, -1e30))
    Bg = jnp.repeat(Bc, rep, axis=3)
    Cg = jnp.repeat(Cc, rep, axis=3)
    scores = jnp.einsum("bclhn,bckhn->bclkh", Cg, Bg)   # (B,nC,Lq,Lk,H)
    M = scores * decay * dtc[:, :, None, :, :]
    y_diag = jnp.einsum("bclkh,bckhp->bclhp", M.astype(xc.dtype), xc)

    # chunk-boundary states
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)     # (B,nC,L,H)
    state_chunk = jnp.einsum(
        "bclhn,bclh,bclhp->bchpn",
        Bg,
        (dtc * decay_to_end).astype(xc.dtype),
        xc,
    )                                                    # (B,nC,H,P,N)
    chunk_decay = jnp.exp(jnp.sum(dA, axis=2))           # (B,nC,H)

    def scan_fn(h, inp):
        st, dec = inp                                    # (B,H,P,N), (B,H)
        h_new = h * dec[..., None, None] + st
        return h_new, h                                  # emit state BEFORE chunk

    h0 = init_state if init_state is not None else jnp.zeros(
        (Bsz, H, Pd, N), jnp.float32
    )
    final, h_prevs = jax.lax.scan(
        scan_fn,
        h0,
        (
            state_chunk.transpose(1, 0, 2, 3, 4).astype(jnp.float32),
            chunk_decay.transpose(1, 0, 2),
        ),
    )
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)           # (B,nC,H,P,N)

    # inter-chunk contribution: y_off = C · (decay_in · h_prev)
    decay_in = jnp.exp(cum)                              # (B,nC,L,H)
    y_off = jnp.einsum(
        "bclhn,bchpn,bclh->bclhp", Cg, h_prevs.astype(Cg.dtype), decay_in.astype(Cg.dtype)
    )
    y = (y_diag + y_off).reshape(Bsz, T_eff, H, Pd)[:, :T]
    return y, final


def _gated_rmsnorm(y, scale, cfg: ModelConfig, ctx: MeshCtx):
    d_inner = cfg.d_model * cfg.ssm_expand
    ss = jnp.sum(jnp.square(y.astype(jnp.float32)), -1, keepdims=True)
    if ctx.model_size > 1:
        ss = jax.lax.psum(ss, ctx.m)
    var = ss / d_inner
    return (y.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-6)).astype(y.dtype) * scale


def _proj(p, xg, cfg: ModelConfig):
    z = xg @ p["wz"]
    xin = xg @ p["wx"]
    bc = xg @ p["wbc"]
    dt = jax.nn.softplus(xg @ p["wdt"] + p["dt_bias"])
    return z, xin, bc, dt


def ssm_apply(p, x_sp, ctx: MeshCtx, cfg: ModelConfig, *, return_state=False):
    xg = ag_seq(x_sp, ctx)
    Bsz, T, d = xg.shape
    _, H, hp, G, N = _dims(cfg, ctx)
    z, xin, bc, dt = _proj(p, xg, cfg)
    xin = _causal_conv(xin, p["conv_x"])
    bc = _causal_conv(bc, p["conv_bc"])
    Bm = bc[..., : G * N].reshape(Bsz, T, G, N)
    Cm = bc[..., G * N :].reshape(Bsz, T, G, N)
    Hl = xin.shape[-1] // hp
    xh = xin.reshape(Bsz, T, Hl, hp)
    y, state = _ssd_chunked(xh, dt, p["a_log"].astype(jnp.float32), Bm, Cm, cfg)
    y = y + xh * p["dskip"][None, None, :, None]
    y = y.reshape(Bsz, T, Hl * hp)
    # gated RMSNorm (mamba2's norm-before-out) — variance over the FULL
    # d_inner (channels are model-sharded: psum the local sum of squares)
    y = y * jax.nn.silu(z)
    y = _gated_rmsnorm(y, p["gate_norm"], cfg, ctx)
    out = rs_seq(y @ p["wout"], ctx)
    if return_state:
        conv_tail_x = xg @ p["wx"]
        conv_state = {
            "x": jax.lax.dynamic_slice_in_dim(conv_tail_x, T - (cfg.ssm_conv - 1), cfg.ssm_conv - 1, 1),
            "bc": jax.lax.dynamic_slice_in_dim(xg @ p["wbc"], T - (cfg.ssm_conv - 1), cfg.ssm_conv - 1, 1),
        }
        return out, {"ssd": state, "conv": conv_state, "len": jnp.int32(T)}
    return out


def ssm_init_cache(cfg: ModelConfig, ctx: MeshCtx, batch: int):
    d_inner, H, hp, G, N = _dims(cfg, ctx)
    Hl = max(1, H // ctx.model_size)
    dl = Hl * hp
    return {
        "ssd": jnp.zeros((batch, Hl, hp, N), jnp.float32),
        "conv": {
            "x": jnp.zeros((batch, cfg.ssm_conv - 1, dl), jnp.bfloat16),
            "bc": jnp.zeros((batch, cfg.ssm_conv - 1, 2 * G * N), jnp.bfloat16),
        },
        "len": jnp.zeros((), jnp.int32),
    }


def ssm_decode(p, x, cache, ctx: MeshCtx, cfg: ModelConfig):
    """O(1) recurrent step: x (B, 1, d) replicated over 'model'."""
    Bsz = x.shape[0]
    _, H, hp, G, N = _dims(cfg, ctx)
    z, xin, bc, dt = _proj(p, x, cfg)                    # (B, 1, ·)
    # conv step over ring of last K-1 raw inputs
    cx = jnp.concatenate([cache["conv"]["x"], xin], axis=1)   # (B, K, dl)
    cbc = jnp.concatenate([cache["conv"]["bc"], bc], axis=1)
    xin = jax.nn.silu(jnp.einsum("bkc,kc->bc", cx, p["conv_x"]))[:, None]
    bcv = jax.nn.silu(jnp.einsum("bkc,kc->bc", cbc, p["conv_bc"]))[:, None]
    Bm = bcv[..., : G * N].reshape(Bsz, G, N)
    Cm = bcv[..., G * N :].reshape(Bsz, G, N)
    Hl = xin.shape[-1] // hp
    rep = Hl // G if Hl >= G else 1
    xh = xin.reshape(Bsz, Hl, hp)
    dA = (dt[:, 0] * (-jnp.exp(p["a_log"].astype(jnp.float32))))  # (B, Hl)
    Bg = jnp.repeat(Bm, rep, axis=1)[:, :Hl]
    Cg = jnp.repeat(Cm, rep, axis=1)[:, :Hl]
    h = cache["ssd"] * jnp.exp(dA)[..., None, None] + jnp.einsum(
        "bh,bhp,bhn->bhpn", dt[:, 0], xh.astype(jnp.float32), Bg.astype(jnp.float32)
    )
    y = jnp.einsum("bhpn,bhn->bhp", h, Cg.astype(jnp.float32)).astype(x.dtype)
    y = y + xh * p["dskip"][None, :, None]
    y = y.reshape(Bsz, 1, Hl * hp)
    y = y * jax.nn.silu(z)
    y = _gated_rmsnorm(y, p["gate_norm"], cfg, ctx)
    out = y @ p["wout"]
    if ctx.model_size > 1:
        out = jax.lax.psum(out, ctx.m)
    new_cache = {
        "ssd": h,
        "conv": {"x": cx[:, 1:], "bc": cbc[:, 1:]},
        "len": cache["len"] + 1,
    }
    return out, new_cache
