"""Unified model configuration covering all assigned architecture families."""
from __future__ import annotations

from dataclasses import dataclass, field, replace


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None          # default d_model // n_heads
    # attention details
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    window: int | None = None            # sliding-window size (local attention)
    # MLA (deepseek)
    use_mla: bool = False
    kv_lora: int = 0
    q_lora: int = 0
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128
    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    n_dense_layers: int = 0              # leading dense layers (deepseek)
    capacity_factor: float = 1.25
    moe_token_chunk: int = 16384         # dispatch-buffer chunking knob (§Perf)
    # SSM (mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_ngroups: int = 1
    ssm_conv: int = 4
    ssm_chunk: int = 256
    # hybrid (recurrentgemma): repeating temporal pattern, e.g. ("rglru","rglru","attn")
    pattern: tuple = ()
    lru_width: int = 0
    # encoder-decoder (whisper)
    n_enc_layers: int = 0
    frontend: str = "none"               # none | audio_stub | patch_stub
    n_frontend_tokens: int = 0           # patch/frame positions fed as embeddings
    norm_type: str = "rmsnorm"           # rmsnorm | layernorm
    act: str = "swiglu"                  # swiglu | gelu
    tie_embeddings: bool = False
    logit_softcap: float = 0.0
    # numerics
    sub_quadratic: bool = False          # eligible for long_500k

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def scaled(self, **kw) -> "ModelConfig":
        return replace(self, **kw)


def n_params_dense(cfg: ModelConfig) -> int:
    """Rough parameter count (reported next to MODEL_FLOPS in the roofline)."""
    d, h = cfg.d_model, cfg.resolved_head_dim
    emb = cfg.vocab * d * (1 if cfg.tie_embeddings else 2)
    per_layer = 0
    if cfg.use_mla:
        per_layer += d * (cfg.kv_lora + cfg.rope_head_dim)
        per_layer += cfg.kv_lora * cfg.n_heads * (cfg.nope_head_dim + cfg.v_head_dim)
        q_in = cfg.q_lora or d
        per_layer += (d * cfg.q_lora if cfg.q_lora else 0)
        per_layer += q_in * cfg.n_heads * (cfg.nope_head_dim + cfg.rope_head_dim)
        per_layer += cfg.n_heads * cfg.v_head_dim * d
    else:
        per_layer += d * cfg.n_heads * h + 2 * d * cfg.n_kv_heads * h + cfg.n_heads * h * d
    if cfg.n_experts:
        shared = cfg.n_shared_experts * 3 * d * cfg.moe_d_ff
        routed = cfg.n_experts * 3 * d * cfg.moe_d_ff
        router = d * cfg.n_experts
        moe_layers = cfg.n_layers - cfg.n_dense_layers
        dense_part = cfg.n_dense_layers * 3 * d * cfg.d_ff
        return emb + cfg.n_layers * per_layer + moe_layers * (shared + routed + router) + dense_part
    ff_mult = 3 if cfg.act == "swiglu" else 2
    return emb + cfg.n_layers * (per_layer + ff_mult * d * cfg.d_ff)


def n_active_params(cfg: ModelConfig) -> int:
    """Activated parameters per token (MoE: top-k + shared only)."""
    if not cfg.n_experts:
        return n_params_dense(cfg)
    full = n_params_dense(cfg)
    moe_layers = cfg.n_layers - cfg.n_dense_layers
    inactive = moe_layers * (cfg.n_experts - cfg.moe_top_k) * 3 * cfg.d_model * cfg.moe_d_ff
    return full - inactive
