"""GQA and MLA attention blocks (shard_map-resident, sequence-parallel I/O).

Head sharding: query/out projections are sharded over 'model' with Hq padded
to a multiple of the axis size (zero-init pads are exact); K/V projections
are replicated (small under GQA) so any rank can serve its query heads'
groups.  MLA shards the per-head `wkv_b`/`wq_b` expansions (128 heads divide
every mesh we use) and caches only the latent, decoded in absorbed form.

Decode uses **context parallelism**: the KV (or latent) cache is sharded over
'model' along the sequence; each rank computes partial attention for ALL
heads over its chunk and the partials are LSE-combined with two psums
(flash-decoding across shards) — this is what makes 32k×128 caches fit
(EXPERIMENTS.md §Roofline).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .layers import (
    MeshCtx,
    ag_seq,
    attention_partial_lse,
    blockwise_attention,
    combine_partials,
    pad_to,
    rms_head_norm,
    rope,
    rs_seq,
)
from .spec import P


def _hq_pad(cfg: ModelConfig, ctx: MeshCtx) -> int:
    return pad_to(cfg.n_heads, ctx.model_size)


def kv_map(cfg: ModelConfig, ctx: MeshCtx) -> jnp.ndarray:
    """Global (padded) q-head -> kv-head index map."""
    group = max(1, cfg.n_heads // cfg.n_kv_heads)
    full = np.minimum(np.arange(_hq_pad(cfg, ctx)) // group, cfg.n_kv_heads - 1)
    return jnp.asarray(full, dtype=jnp.int32)


def local_kv_map(cfg: ModelConfig, ctx: MeshCtx) -> jnp.ndarray:
    qpr = _hq_pad(cfg, ctx) // ctx.model_size
    return jax.lax.dynamic_slice_in_dim(kv_map(cfg, ctx), ctx.midx() * qpr, qpr)


def _mask_pad_heads(out, cfg: ModelConfig, ctx: MeshCtx, *, local: bool = True):
    """Zero the outputs of padding query heads (Hq padded to the axis size).

    Without this, the random-init pad heads contribute through wo and receive
    gradients, so models trained on different mesh sizes would diverge; with
    it, pad head wq/wo slices get zero gradients and stay inert — mesh-size
    parity is exact (tests/test_mesh_parity.py)."""
    hq = _hq_pad(cfg, ctx)
    if hq == cfg.n_heads:
        return out
    Hl = out.shape[1]
    start = ctx.midx() * Hl if (local and ctx.model_size > 1) else 0
    gid = start + jnp.arange(Hl)
    return out * (gid < cfg.n_heads)[None, :, None, None].astype(out.dtype)


# --------------------------------------------------------------------------
# GQA
# --------------------------------------------------------------------------


def gqa_spec(cfg: ModelConfig, ctx: MeshCtx) -> dict:
    d, dh = cfg.d_model, cfg.resolved_head_dim
    hq = _hq_pad(cfg, ctx)
    hl = cfg.n_heads * dh  # logical (unpadded) head dim — mesh-invariant init
    spec = {
        "wq": P((d, hq * dh), (None, "model"), logical=(d, hl)),
        "wk": P((d, cfg.n_kv_heads * dh), (None, None)),
        "wv": P((d, cfg.n_kv_heads * dh), (None, None)),
        "wo": P((hq * dh, d), ("model", None), logical=(hl, d)),
    }
    if cfg.qkv_bias:
        spec["bq"] = P((hq * dh,), ("model",), "zeros")
        spec["bk"] = P((cfg.n_kv_heads * dh,), (None,), "zeros")
        spec["bv"] = P((cfg.n_kv_heads * dh,), (None,), "zeros")
    if cfg.qk_norm:
        spec["q_norm"] = P((dh,), (None,), "ones")
        spec["k_norm"] = P((dh,), (None,), "ones")
    return spec


def _qkv(p, xg, cfg: ModelConfig, ctx: MeshCtx, positions, *, apply_rope=True):
    """xg (B, T, d) -> q (B, Hl, T, Dh), k/v (B, Hkv, T, Dh)."""
    B, T, _ = xg.shape
    dh = cfg.resolved_head_dim
    q = xg @ p["wq"]
    k = xg @ p["wk"]
    v = xg @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, T, -1, dh).transpose(0, 2, 1, 3)
    k = k.reshape(B, T, cfg.n_kv_heads, dh).transpose(0, 2, 1, 3)
    v = v.reshape(B, T, cfg.n_kv_heads, dh).transpose(0, 2, 1, 3)
    if cfg.qk_norm:
        q = rms_head_norm(p["q_norm"], q)
        k = rms_head_norm(p["k_norm"], k)
    if apply_rope:
        q = rope(q, positions[:, None, :], cfg.rope_theta)
        k = rope(k, positions[:, None, :], cfg.rope_theta)
    return q, k, v


def gqa_apply(
    p,
    x_sp,                 # (B, T/M, d) sequence-sharded residual stream
    ctx: MeshCtx,
    cfg: ModelConfig,
    *,
    causal: bool = True,
    window: int | None = None,
    memory=None,          # (B, Tm, d) for cross-attention (already gathered)
    return_kv: bool = False,
):
    xg = ag_seq(x_sp, ctx)
    B, T, _ = xg.shape
    positions = jnp.broadcast_to(jnp.arange(T), (B, T))
    if memory is None:
        q, k, v = _qkv(p, xg, cfg, ctx, positions)
    else:
        q, _, _ = _qkv(p, xg, cfg, ctx, positions, apply_rope=False)
        Tm = memory.shape[1]
        mpos = jnp.broadcast_to(jnp.arange(Tm), (B, Tm))
        _, k, v = _qkv(p, memory, cfg, ctx, mpos, apply_rope=False)
    out = blockwise_attention(
        q, k, v, local_kv_map(cfg, ctx), causal=causal, window=window
    )
    out = _mask_pad_heads(out, cfg, ctx)
    B, Hl, T, dh = out.shape
    o = out.transpose(0, 2, 1, 3).reshape(B, T, Hl * dh) @ p["wo"]
    o = rs_seq(o, ctx)
    if return_kv:
        return o, (k, v)
    return o


def gqa_init_cache(cfg: ModelConfig, ctx: MeshCtx, batch: int, max_len: int):
    """Sequence-sharded KV cache: each rank owns max_len/M positions."""
    dh = cfg.resolved_head_dim
    tc = max_len // ctx.model_size
    return {
        "k": jnp.zeros((batch, cfg.n_kv_heads, tc, dh), jnp.bfloat16),
        "v": jnp.zeros((batch, cfg.n_kv_heads, tc, dh), jnp.bfloat16),
        "len": jnp.zeros((), jnp.int32),
    }


def gqa_fill_cache(cache, k, v, ctx: MeshCtx):
    """Keep this rank's sequence chunk of freshly-computed prefill K/V.

    Prompts shorter than the cache capacity are right-padded (decode masks
    positions >= len via kv_valid_len)."""
    tc = cache["k"].shape[2]
    t = k.shape[2]
    cap = tc * ctx.model_size
    if t < cap:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, cap - t), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, cap - t), (0, 0)))
    start = ctx.midx() * tc
    kc = jax.lax.dynamic_slice_in_dim(k, start, tc, axis=2)
    vc = jax.lax.dynamic_slice_in_dim(v, start, tc, axis=2)
    return {"k": kc.astype(jnp.bfloat16), "v": vc.astype(jnp.bfloat16), "len": jnp.int32(t)}


def gqa_decode(p, x, cache, ctx: MeshCtx, cfg: ModelConfig, *, window=None):
    """One-token decode against the sequence-sharded cache.

    x: (B, 1, d) replicated over 'model'.  New K/V are computed redundantly;
    the rank owning the current position writes them into its chunk; partial
    attention is LSE-combined across ranks; output projection stays
    head-sharded (each rank multiplies its head slice, then psum via rs/ag
    equivalence — here a plain psum since T=1).
    """
    B = x.shape[0]
    dh = cfg.resolved_head_dim
    pos = cache["len"]
    positions = jnp.broadcast_to(pos[None, None], (B, 1))
    q, k_new, v_new = _qkv(p, x, cfg, ctx, positions)
    # all heads everywhere for decode: gather the head shards (tiny: 1 token)
    q_all = jax.lax.all_gather(q, ctx.m, axis=1, tiled=True) if ctx.model_size > 1 else q

    tc = cache["k"].shape[2]
    owner = pos // tc
    local_pos = pos - owner * tc
    is_owner = (owner == ctx.midx()) if ctx.model_size > 1 else True
    k_upd = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new.astype(jnp.bfloat16), local_pos, axis=2)
    v_upd = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new.astype(jnp.bfloat16), local_pos, axis=2)
    k_c = jnp.where(is_owner, k_upd, cache["k"])
    v_c = jnp.where(is_owner, v_upd, cache["v"])

    kvm = kv_map(cfg, ctx)
    k_off = (ctx.midx() * tc) if ctx.model_size > 1 else 0
    q_pos = jnp.broadcast_to(pos[None], (1,))
    num, m, l = attention_partial_lse(
        q_all, k_c, v_c, kvm, k_offset=k_off, kv_valid_len=pos + 1, q_pos=q_pos
    )
    if window is not None:
        pass  # window handled by kv_valid via masks in partial (see local_decode)
    out = combine_partials(num, m, l, ctx)  # (B, Hq_pad, 1, dh)
    out = _mask_pad_heads(out, cfg, ctx, local=False)

    # local head-slice out-projection + psum
    hq = out.shape[1]
    qpr = hq // ctx.model_size
    o_loc = jax.lax.dynamic_slice_in_dim(out, ctx.midx() * qpr, qpr, axis=1)
    o = o_loc.transpose(0, 2, 1, 3).reshape(B, 1, qpr * dh) @ p["wo"]
    if ctx.model_size > 1:
        o = jax.lax.psum(o, ctx.m)
    new_cache = {"k": k_c, "v": v_c, "len": pos + 1}
    return o, new_cache


# ---- local (sliding-window) attention decode: replicated ring cache -------


def local_init_cache(cfg: ModelConfig, batch: int):
    dh = cfg.resolved_head_dim
    w = cfg.window
    return {
        "k": jnp.zeros((batch, cfg.n_kv_heads, w, dh), jnp.bfloat16),
        "v": jnp.zeros((batch, cfg.n_kv_heads, w, dh), jnp.bfloat16),
        "len": jnp.zeros((), jnp.int32),
    }


def local_fill_cache(cache, k, v, cfg: ModelConfig):
    """Keep the last `window` positions in ring layout slot = pos % window
    (the layout `local_decode` updates and reads)."""
    w = cfg.window
    t = k.shape[2]
    if t < w:  # positions 0..t-1 land at slots 0..t-1; tail slots unused
        kc = jnp.pad(k, ((0, 0), (0, 0), (0, w - t), (0, 0)))
        vc = jnp.pad(v, ((0, 0), (0, 0), (0, w - t), (0, 0)))
    else:  # last w positions: position p -> slot p % w == roll by (t - w) % w
        kc = jnp.roll(k[:, :, t - w :], (t - w) % w, axis=2)
        vc = jnp.roll(v[:, :, t - w :], (t - w) % w, axis=2)
    return {"k": kc.astype(jnp.bfloat16), "v": vc.astype(jnp.bfloat16), "len": jnp.int32(t)}


def local_decode(p, x, cache, ctx: MeshCtx, cfg: ModelConfig):
    """Sliding-window decode with a replicated ring buffer (window is small).

    Ring layout: slot = pos % window.  RoPE positions are absolute.
    """
    B = x.shape[0]
    dh = cfg.resolved_head_dim
    w = cfg.window
    pos = cache["len"]
    positions = jnp.broadcast_to(pos[None, None], (B, 1))
    q, k_new, v_new = _qkv(p, x, cfg, ctx, positions)
    slot = pos % w
    k_c = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new.astype(jnp.bfloat16), slot, axis=2)
    v_c = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new.astype(jnp.bfloat16), slot, axis=2)

    # positions of ring slots: pos - ((slot - i) mod w)
    i = jnp.arange(w)
    age = (slot - i) % w
    k_pos = pos - age
    valid = (k_pos >= jnp.maximum(pos - w + 1, 0)) & (k_pos <= pos)
    kvm_local = local_kv_map(cfg, ctx)
    kg = jnp.take(k_c, kvm_local, axis=1)
    vg = jnp.take(v_c, kvm_local, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, kg).astype(jnp.float32) / np.sqrt(dh)
    s = jnp.where(valid[None, None, None, :], s, -1e30)
    pattn = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", pattn.astype(vg.dtype), vg)
    out = _mask_pad_heads(out, cfg, ctx)
    qpr = out.shape[1]
    o = out.transpose(0, 2, 1, 3).reshape(B, 1, qpr * dh) @ p["wo"]
    if ctx.model_size > 1:
        o = jax.lax.psum(o, ctx.m)
    return o, {"k": k_c, "v": v_c, "len": pos + 1}


def cross_fill_cache(p, memory, cfg: ModelConfig, ctx: MeshCtx):
    """Precompute the cross-attention K/V cache from encoder memory
    (B, Tm, d), sequence-sharded over 'model'."""
    B, Tm, _ = memory.shape
    mpos = jnp.broadcast_to(jnp.arange(Tm), (B, Tm))
    _, k, v = _qkv(p, memory, cfg, ctx, mpos, apply_rope=False)
    tc = Tm // ctx.model_size
    start = (ctx.midx() * tc) if ctx.model_size > 1 else 0
    return {
        "k": jax.lax.dynamic_slice_in_dim(k, start, tc, axis=2).astype(jnp.bfloat16),
        "v": jax.lax.dynamic_slice_in_dim(v, start, tc, axis=2).astype(jnp.bfloat16),
        "len": jnp.int32(Tm),
    }


def cross_decode(p, x, cache, ctx: MeshCtx, cfg: ModelConfig):
    """Decoder cross-attention against the (static, seq-sharded) memory cache."""
    B = x.shape[0]
    dh = cfg.resolved_head_dim
    positions = jnp.zeros((B, 1), jnp.int32)
    q, _, _ = _qkv(p, x, cfg, ctx, positions, apply_rope=False)
    q_all = jax.lax.all_gather(q, ctx.m, axis=1, tiled=True) if ctx.model_size > 1 else q
    tc = cache["k"].shape[2]
    k_off = (ctx.midx() * tc) if ctx.model_size > 1 else 0
    num, m, l = attention_partial_lse(
        q_all, cache["k"], cache["v"], kv_map(cfg, ctx),
        k_offset=k_off, kv_valid_len=cache["len"],
        q_pos=jnp.full((1,), 1 << 30),  # non-causal: attend to all memory
    )
    out = combine_partials(num, m, l, ctx)
    out = _mask_pad_heads(out, cfg, ctx, local=False)
    hq = out.shape[1]
    qpr = hq // ctx.model_size
    o_loc = jax.lax.dynamic_slice_in_dim(out, ctx.midx() * qpr, qpr, axis=1)
    o = o_loc.transpose(0, 2, 1, 3).reshape(B, 1, qpr * dh) @ p["wo"]
    if ctx.model_size > 1:
        o = jax.lax.psum(o, ctx.m)
    return o


# --------------------------------------------------------------------------
# MLA (deepseek multi-head latent attention)
# --------------------------------------------------------------------------


def mla_spec(cfg: ModelConfig, ctx: MeshCtx) -> dict:
    d = cfg.d_model
    h = pad_to(cfg.n_heads, ctx.model_size)  # 128 divides every mesh we use
    hn = cfg.n_heads
    nope, rpe, vd = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    spec = {
        "wkv_a": P((d, cfg.kv_lora + rpe), (None, None)),
        "kv_a_norm": P((cfg.kv_lora,), (None,), "ones"),
        "wkv_b": P((cfg.kv_lora, h * (nope + vd)), (None, "model"),
                   logical=(cfg.kv_lora, hn * (nope + vd))),
        "wo": P((h * vd, d), ("model", None), logical=(hn * vd, d)),
    }
    if cfg.q_lora:
        spec["wq_a"] = P((d, cfg.q_lora), (None, None))
        spec["q_a_norm"] = P((cfg.q_lora,), (None,), "ones")
        spec["wq_b"] = P((cfg.q_lora, h * (nope + rpe)), (None, "model"),
                         logical=(cfg.q_lora, hn * (nope + rpe)))
    else:
        spec["wq"] = P((d, h * (nope + rpe)), (None, "model"),
                       logical=(d, hn * (nope + rpe)))
    return spec


def _mla_q(p, xg, cfg: ModelConfig, positions):
    B, T, _ = xg.shape
    nope, rpe = cfg.nope_head_dim, cfg.rope_head_dim
    if cfg.q_lora:
        qa = xg @ p["wq_a"]
        qa = rms_head_norm(p["q_a_norm"], qa)
        q = qa @ p["wq_b"]
    else:
        q = xg @ p["wq"]
    q = q.reshape(B, T, -1, nope + rpe).transpose(0, 2, 1, 3)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = rope(q_rope, positions[:, None, :], cfg.rope_theta)
    return q_nope, q_rope


def _mla_latent(p, xg, cfg: ModelConfig, positions):
    kv_a = xg @ p["wkv_a"]                         # (B, T, lora + rpe)
    c_kv = rms_head_norm(p["kv_a_norm"], kv_a[..., : cfg.kv_lora])
    k_rope = rope(
        kv_a[..., cfg.kv_lora :][:, None], positions[:, None, :], cfg.rope_theta
    )[:, 0]
    return c_kv, k_rope


def mla_apply(p, x_sp, ctx: MeshCtx, cfg: ModelConfig, *, return_latent=False):
    """Prefill/train path: expand latent to per-head K/V for local heads."""
    xg = ag_seq(x_sp, ctx)
    B, T, _ = xg.shape
    nope, rpe, vd = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    positions = jnp.broadcast_to(jnp.arange(T), (B, T))
    q_nope, q_rope = _mla_q(p, xg, cfg, positions)           # local heads
    c_kv, k_rope = _mla_latent(p, xg, cfg, positions)        # replicated
    kvb = p["wkv_b"].reshape(cfg.kv_lora, -1, nope + vd)     # (lora, Hl, nope+vd)
    kv = jnp.einsum("btl,lhe->bhte", c_kv, kvb)
    k_nope, v = kv[..., :nope], kv[..., nope:]
    Hl = k_nope.shape[1]
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, None], (B, Hl, T, rpe))], axis=-1
    )
    ident = jnp.arange(Hl, dtype=jnp.int32)
    out = blockwise_attention(q, k, v, ident, causal=True)
    o = out.transpose(0, 2, 1, 3).reshape(B, T, -1) @ p["wo"]
    o = rs_seq(o, ctx)
    if return_latent:
        return o, (c_kv, k_rope)
    return o


def mla_init_cache(cfg: ModelConfig, ctx: MeshCtx, batch: int, max_len: int):
    tc = max_len // ctx.model_size
    return {
        "c_kv": jnp.zeros((batch, tc, cfg.kv_lora), jnp.bfloat16),
        "k_rope": jnp.zeros((batch, tc, cfg.rope_head_dim), jnp.bfloat16),
        "len": jnp.zeros((), jnp.int32),
    }


def mla_fill_cache(cache, c_kv, k_rope, ctx: MeshCtx):
    tc = cache["c_kv"].shape[1]
    t = c_kv.shape[1]
    cap = tc * ctx.model_size
    if t < cap:
        c_kv = jnp.pad(c_kv, ((0, 0), (0, cap - t), (0, 0)))
        k_rope = jnp.pad(k_rope, ((0, 0), (0, cap - t), (0, 0)))
    start = ctx.midx() * tc
    return {
        "c_kv": jax.lax.dynamic_slice_in_dim(c_kv, start, tc, axis=1).astype(jnp.bfloat16),
        "k_rope": jax.lax.dynamic_slice_in_dim(k_rope, start, tc, axis=1).astype(jnp.bfloat16),
        "len": jnp.int32(t),
    }


def mla_decode(p, x, cache, ctx: MeshCtx, cfg: ModelConfig):
    """Absorbed MLA decode: attention runs entirely in the latent space.

    q_eff = q_nope @ wkv_b[:, :, :nope]  (per head)  -> scores vs latent cache;
    output latent -> expand with wkv_b[:, :, nope:] -> head-sharded wo.
    The latent cache is sequence-sharded; partials are LSE-combined (2 psums
    of (B, H, lora)-sized tensors — the big win vs. expanded K/V).
    """
    B = x.shape[0]
    nope, rpe, vd, lora = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim, cfg.kv_lora
    pos = cache["len"]
    positions = jnp.broadcast_to(pos[None, None], (B, 1))
    q_nope, q_rope = _mla_q(p, x, cfg, positions)            # (B, Hl, 1, ·) local heads
    c_new, kr_new = _mla_latent(p, x, cfg, positions)        # replicated

    kvb = p["wkv_b"].reshape(lora, -1, nope + vd)
    wb_k, wb_v = kvb[..., :nope], kvb[..., nope:]            # (lora, Hl, ·)
    q_lat = jnp.einsum("bhqe,lhe->bhql", q_nope, wb_k)       # (B, Hl, 1, lora)

    # all heads for context-parallel attention (tiny gathers: single token)
    if ctx.model_size > 1:
        q_lat = jax.lax.all_gather(q_lat, ctx.m, axis=1, tiled=True)
        q_rope = jax.lax.all_gather(q_rope, ctx.m, axis=1, tiled=True)

    tc = cache["c_kv"].shape[1]
    owner = pos // tc
    local_pos = pos - owner * tc
    is_owner = (owner == ctx.midx()) if ctx.model_size > 1 else True
    c_upd = jax.lax.dynamic_update_slice_in_dim(cache["c_kv"], c_new.astype(jnp.bfloat16), local_pos, axis=1)
    r_upd = jax.lax.dynamic_update_slice_in_dim(cache["k_rope"], kr_new.astype(jnp.bfloat16), local_pos, axis=1)
    c_c = jnp.where(is_owner, c_upd, cache["c_kv"])
    r_c = jnp.where(is_owner, r_upd, cache["k_rope"])

    k_off = (ctx.midx() * tc) if ctx.model_size > 1 else 0
    scale = 1.0 / np.sqrt(nope + rpe)
    s = (
        jnp.einsum("bhql,btl->bhqt", q_lat, c_c)
        + jnp.einsum("bhqr,btr->bhqt", q_rope, r_c)
    ).astype(jnp.float32) * scale
    k_pos = k_off + jnp.arange(tc)
    mask = k_pos[None, :] <= pos
    s = jnp.where(mask[None, None], s, -1e30)
    m = s.max(-1)
    pw = jnp.exp(s - m[..., None])
    l = pw.sum(-1)
    num = jnp.einsum("bhqt,btl->bhql", pw.astype(c_c.dtype), c_c).astype(jnp.float32)
    out_lat = combine_partials(num, m, l, ctx)               # (B, H, 1, lora)

    H = out_lat.shape[1]
    hpr = H // ctx.model_size
    ol = jax.lax.dynamic_slice_in_dim(out_lat, ctx.midx() * hpr, hpr, axis=1)
    v_out = jnp.einsum("bhql,lhe->bhqe", ol, wb_v)           # (B, Hl, 1, vd)
    o = v_out.transpose(0, 2, 1, 3).reshape(B, 1, hpr * vd) @ p["wo"]
    if ctx.model_size > 1:
        o = jax.lax.psum(o, ctx.m)
    return o, {"c_kv": c_c, "k_rope": r_c, "len": pos + 1}
