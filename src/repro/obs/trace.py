"""Structured span tracing for the reconciliation stack (DESIGN.md §14).

Zero-dependency, monotonic-clock, thread-aware tracing around the natural
phase boundaries of the serving stack: the phase-0 ToW sweep, per-cohort
plan/dispatch/collect, round barriers, epoch advances, ARQ
send/recv/retransmit, and resume/degrade transitions — each span carrying
per-peer / per-session attribution in its ``args``.  The PR-6 overlap
pipeline and the hub's straggler behavior become *visible* timelines
instead of inferred numbers.

Two exports of the same event list:

* ``export_jsonl`` — one event dict per line, the machine-friendly form
  ``tools/trace_report.py`` summarizes;
* ``export_chrome`` — Chrome trace format (a ``{"traceEvents": [...]}``
  JSON document) loadable directly in ``chrome://tracing`` or Perfetto
  (https://ui.perfetto.dev), with thread-name metadata so each endpoint /
  hub / peer thread renders as its own labeled track.

Tracing is **disabled by default and off the hot path**: every traced call
site holds a ``Tracer`` reference that defaults to the module-level
``NULL_TRACER`` singleton, whose ``span`` returns one shared no-op context
manager and whose ``instant``/``counter`` are pass statements — no event
list, no lock, no clock read.  Hot loops additionally guard per-datagram
instrumentation behind ``tracer.enabled`` so the disabled path costs a
single attribute read (the warm S=1024 bench gate runs with tracing
disabled and is asserted unchanged).

``Tracer(jax_profiler=True)`` opt-in: ``annotate(name)`` then returns a
``jax.profiler.TraceAnnotation`` so kernel dispatch windows show up inside
a ``jax.profiler.trace`` capture alongside the host spans; without the
opt-in (or without a profiler-capable jax) it is a no-op context.
"""
from __future__ import annotations

import json
import threading
import time


class _NullSpan:
    """The shared no-op context manager disabled tracing hands out."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class NullTracer:
    """The disabled tracer: every operation is a no-op (DESIGN.md §14)."""

    __slots__ = ()
    enabled = False

    def span(self, name, cat="host", **args):
        return _NULL_SPAN

    def instant(self, name, cat="host", **args):
        pass

    def counter(self, name, value, cat="host"):
        pass

    def annotate(self, name):
        return _NULL_SPAN


NULL_TRACER = NullTracer()


class _Span:
    """One live span: records a Chrome 'X' (complete) event on exit."""

    __slots__ = ("_tracer", "_ev", "_t0")

    def __init__(self, tracer: "Tracer", ev: dict):
        self._tracer = tracer
        self._ev = ev

    def __enter__(self):
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter_ns()
        ev = self._ev
        ev["ts"] = (self._t0 - self._tracer._origin_ns) / 1e3
        ev["dur"] = (t1 - self._t0) / 1e3
        self._tracer._emit(ev)
        return False


class Tracer:
    """Collects trace events; timestamps are µs from tracer creation.

    Thread-aware: every event carries the OS thread id and the first event
    from each thread also emits a ``thread_name`` metadata record, so
    Perfetto lays the hub, each peer endpoint, and any transport worker
    out as separate named tracks.
    """

    enabled = True

    def __init__(self, *, jax_profiler: bool = False):
        self._origin_ns = time.perf_counter_ns()
        self._lock = threading.Lock()
        self._events: list[dict] = []
        self._named_tids: set[int] = set()
        self._jax_profiler = jax_profiler

    # -- event creation --------------------------------------------------

    def _emit(self, ev: dict) -> None:
        tid = threading.get_ident()
        ev["tid"] = tid
        with self._lock:
            if tid not in self._named_tids:
                self._named_tids.add(tid)
                self._events.append({
                    "name": "thread_name",
                    "ph": "M",
                    "pid": 1,
                    "tid": tid,
                    "args": {"name": threading.current_thread().name},
                })
            self._events.append(ev)

    def span(self, name: str, cat: str = "host", **args) -> _Span:
        """A timed region: ``with tracer.span("cohort.collect", rnd=3):``.

        ``cat`` buckets spans for occupancy accounting — ``device`` marks
        time blocked on device readback, everything else is host time.
        ``args`` carry attribution (peer/channel/sid/round/cohort).
        """
        return _Span(self, {"name": name, "cat": cat, "ph": "X", "pid": 1,
                            "args": args})

    def instant(self, name: str, cat: str = "host", **args) -> None:
        """A point event (retransmit, eviction, degrade rung, ...)."""
        self._emit({
            "name": name, "cat": cat, "ph": "i", "s": "t", "pid": 1,
            "ts": (time.perf_counter_ns() - self._origin_ns) / 1e3,
            "args": args,
        })

    def counter(self, name: str, value, cat: str = "host") -> None:
        """A Chrome counter-track sample (rto_ms over time, bytes, ...)."""
        self._emit({
            "name": name, "cat": cat, "ph": "C", "pid": 1,
            "ts": (time.perf_counter_ns() - self._origin_ns) / 1e3,
            "args": {"value": value},
        })

    def annotate(self, name: str):
        """Opt-in ``jax.profiler`` hook around kernel dispatch: inside a
        ``jax.profiler.trace`` capture the dispatch window shows up under
        ``name``; a no-op unless the tracer was built with
        ``jax_profiler=True`` (and jax exposes the annotation API)."""
        if self._jax_profiler:
            try:
                from jax.profiler import TraceAnnotation
                return TraceAnnotation(name)
            except Exception:
                pass
        return _NULL_SPAN

    # -- reads / export --------------------------------------------------

    def events(self) -> list[dict]:
        """A snapshot copy of every event recorded so far."""
        with self._lock:
            return [dict(ev) for ev in self._events]

    def export_jsonl(self, path) -> int:
        """One JSON event per line; returns the event count."""
        evs = self.events()
        with open(path, "w") as f:
            for ev in evs:
                f.write(json.dumps(ev) + "\n")
        return len(evs)

    def export_chrome(self, path) -> int:
        """Chrome trace format: load the file as-is in ``chrome://tracing``
        or Perfetto.  Returns the event count."""
        evs = self.events()
        doc = {"traceEvents": evs, "displayTimeUnit": "ms"}
        with open(path, "w") as f:
            json.dump(doc, f)
        return len(evs)


def load_events(path) -> list[dict]:
    """Read a trace back: either export format (Chrome JSON or JSONL)."""
    with open(path) as f:
        text = f.read()
    try:
        doc = json.loads(text)
    except json.JSONDecodeError:
        # not a single document: one event object per line (JSONL)
        return [json.loads(line) for line in text.splitlines() if line.strip()]
    return doc["traceEvents"] if isinstance(doc, dict) else doc
