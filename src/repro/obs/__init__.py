"""repro.obs — unified telemetry for the reconciliation stack.

One typed metrics registry (``Recorder`` + ``SCHEMA``, DESIGN.md §14)
absorbing every layer's ad-hoc stats ledger behind derived snapshots, and
one zero-dep span tracer (``Tracer``/``NULL_TRACER``) exporting JSONL and
Chrome-trace timelines of the whole serving stack.
"""
from repro.obs.metrics import SCHEMA, MetricSpec, MetricsError, Recorder
from repro.obs.trace import NULL_TRACER, NullTracer, Tracer, load_events

__all__ = [
    "SCHEMA",
    "MetricSpec",
    "MetricsError",
    "Recorder",
    "Tracer",
    "NullTracer",
    "NULL_TRACER",
    "load_events",
]
