"""The unified metrics registry (DESIGN.md §14).

Before this module, the serving stack verified the paper's quantitative
claims through five ad-hoc, mutually inconsistent stats surfaces —
``ReconcileServer._stats``, the hub ``PeerOutcome``/``HubEndpoint.stats``
ledgers, per-stream ``wire_stats``, the ``count_retrace`` census, and the
per-epoch sync counters — each with its own spelling, units, and reset
semantics, stitched together by hand in every bench and test.

This module replaces the *contract*, not the plumbing: every stats key any
layer publishes is declared once in ``SCHEMA`` as a typed ``MetricSpec``
(name, kind, unit, owner), and each layer hands its ledger dict to a shared
``Recorder`` at the same points it used to freeze its ad-hoc dict.  The
legacy views (``ReconcileServer.stats``, ``HubEndpoint.stats``, endpoint
``wire_stats``) are now *derived snapshots* of the recorder — built back
from the registry values, byte/count-identical to their pre-obs shapes —
so no caller changes semantics, while every metric gains a single
discoverable schema row and an enforced no-undeclared-keys rule: a
``publish`` of an unknown key raises ``MetricsError`` instead of silently
minting a new counter (the schema test pins the DESIGN.md §14 table to
``SCHEMA`` exactly).

The recorder also owns the *mark* mechanism the per-run store ledgers are
derived from: cumulative counters (``SessionBatch.counters()``) are
published as ``store.*`` metrics and a named mark snapshots them at the end
of each run, so the next run's per-epoch view is ``delta_since_mark``.
Discarding a batch (``ReconcileServer.submit`` after a run) must drop the
mark along with the batch — a stale mark would subtract a dead batch's
counters from the fresh batch's zeros and leak negative deltas into the
ledger (the submit-after-run regression test).
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field


class MetricsError(KeyError):
    """An undeclared metric name reached the registry (add it to SCHEMA
    and the DESIGN.md §14 table, or fix the typo)."""


@dataclass(frozen=True)
class MetricSpec:
    """One declared metric: the schema row the DESIGN.md §14 table mirrors."""

    name: str           # full dotted name: "<owner>.<key>"
    kind: str           # counter | gauge | labeled_counter | histogram
    unit: str           # bytes | count | seconds | ms | ratio | rounds | 1
    owner: str          # server | hub | wire | endpoint | store | kernels
    desc: str = ""

    @property
    def key(self) -> str:
        """The legacy dict key: the name without its owner prefix."""
        return self.name.split(".", 1)[1]


_KINDS = ("counter", "gauge", "labeled_counter", "histogram")
_UNITS = ("bytes", "count", "seconds", "ms", "ratio", "rounds", "1")


def _specs() -> list[MetricSpec]:
    M = MetricSpec
    return [
        # -- server: ReconcileServer.run's per-run ledger (DESIGN.md §5/§11/§12)
        M("server.epoch", "gauge", "count", "server", "epoch the run served"),
        M("server.phase0_s", "gauge", "seconds", "server", "batched ToW estimation wall time"),
        M("server.rounds", "gauge", "rounds", "server", "global rounds driven"),
        M("server.cohort_rounds", "counter", "rounds", "server", "per-cohort round executions"),
        M("server.h2d_round_bytes", "counter", "bytes", "server", "per-round overlay H2D bytes"),
        M("server.legacy_h2d_round_bytes", "counter", "bytes", "server", "re-pack-per-round H2D equivalent"),
        M("server.kernel_launches", "counter", "count", "server", "fused executor launches"),
        M("server.legacy_kernel_launches", "counter", "count", "server", "pre-fusion launch equivalent"),
        M("server.sessions_degraded", "counter", "count", "server", "degradation-ladder escalations"),
        M("server.parity_extensions", "counter", "count", "server", "rateless MSG_PARITY-equivalent extensions applied"),
        M("server.device_s", "gauge", "seconds", "server", "device wait inside the round loop"),
        M("server.host_s", "gauge", "seconds", "server", "run wall minus device wait"),
        M("server.total_s", "gauge", "seconds", "server", "run wall time"),
        M("server.h2d_store_bytes", "counter", "bytes", "server", "cohort-store builds this run"),
        M("server.store_builds", "counter", "count", "server", "store (re)builds this run"),
        M("server.store_compactions", "counter", "count", "server", "capacity-overflow rebuilds this run"),
        M("server.h2d_delta_bytes", "counter", "bytes", "server", "O(churn) delta-patch H2D this run"),
        M("server.h2d_bytes", "counter", "bytes", "server", "total H2D this run"),
        M("server.legacy_h2d_bytes", "counter", "bytes", "server", "legacy total H2D equivalent"),
        M("server.h2d_bytes_per_round", "gauge", "bytes", "server", "H2D bytes per round"),
        M("server.legacy_h2d_bytes_per_round", "gauge", "bytes", "server", "legacy H2D bytes per round"),
        M("server.h2d_ratio", "gauge", "ratio", "server", "legacy/actual H2D win"),
        M("server.retraces", "counter", "count", "server", "jit traces attributed to the run"),
        M("server.tree_levels", "gauge", "count", "server", "tree levels walked by the front end"),
        M("server.tree_digest_bytes", "counter", "bytes", "server", "framed MSG_TREE exchange bytes"),
        M("server.tree_leaves", "gauge", "count", "server", "divergent ranges handed to PBS"),
        M("server.tree_bytes_per_diff", "gauge", "ratio", "server", "(tree + PBS bytes) per recovered diff"),
        # -- hub: HubEndpoint.serve's fusion/resilience ledger (DESIGN.md §10/§13)
        M("hub.epoch", "gauge", "count", "hub", "epoch the serve drove"),
        M("hub.rounds", "gauge", "rounds", "hub", "global rounds driven"),
        M("hub.cohort_rounds", "counter", "rounds", "hub", "per-cohort round executions"),
        M("hub.kernel_launches", "counter", "count", "hub", "fused encode launches (2/cohort-round)"),
        M("hub.decode_launches", "counter", "count", "hub", "batched BCH decode launches (1/cohort-round)"),
        M("hub.h2d_round_bytes", "counter", "bytes", "hub", "per-round overlay H2D bytes"),
        M("hub.peers", "counter", "count", "hub", "peers ever admitted (cumulative)"),
        M("hub.peers_failed", "counter", "count", "hub", "peers evicted (cumulative)"),
        M("hub.peers_failed_by_kind", "labeled_counter", "count", "hub", "evictions by classify_error kind"),
        M("hub.peers_resumed", "counter", "count", "hub", "MSG_RESUME re-attachments (cumulative)"),
        M("hub.resume_replay_bytes", "counter", "bytes", "hub", "replayed outcome frames (transport overhead)"),
        M("hub.sessions_degraded", "counter", "count", "hub", "degradation-ladder escalations (cumulative)"),
        M("hub.parity_extensions", "counter", "count", "hub", "rateless MSG_PARITY extensions served (cumulative)"),
        M("hub.store_uploads", "counter", "count", "hub", "cohort-store builds (cumulative)"),
        M("hub.h2d_store_bytes", "counter", "bytes", "hub", "store-build H2D this serve"),
        M("hub.store_builds", "counter", "count", "hub", "store (re)builds this serve"),
        M("hub.store_compactions", "counter", "count", "hub", "capacity-overflow rebuilds this serve"),
        M("hub.h2d_delta_bytes", "counter", "bytes", "hub", "O(churn) delta-patch H2D this serve"),
        M("hub.h2d_bytes", "counter", "bytes", "hub", "total H2D this serve"),
        M("hub.retraces", "counter", "count", "hub", "jit traces attributed to the serve"),
        M("hub.tree_levels", "gauge", "count", "hub", "deepest tree phase driven this serve"),
        M("hub.tree_digest_bytes", "counter", "bytes", "hub", "framed MSG_TREE exchange bytes this serve"),
        M("hub.tree_leaves", "counter", "count", "hub", "tree leaf sessions admitted this serve"),
        # -- wire: per-stream measured traffic (DESIGN.md §9/§13)
        M("wire.frames_out", "counter", "count", "wire", "protocol frames sent"),
        M("wire.frames_in", "counter", "count", "wire", "protocol frames received"),
        M("wire.frame_bytes_out", "counter", "bytes", "wire", "framed bytes sent (inner, sans mux)"),
        M("wire.frame_bytes_in", "counter", "bytes", "wire", "framed bytes received (inner, sans mux)"),
        M("wire.transport_bytes_out", "counter", "bytes", "wire", "raw transport bytes out incl. ARQ"),
        M("wire.transport_bytes_in", "counter", "bytes", "wire", "raw transport bytes in incl. ARQ"),
        M("wire.mux_bytes_out", "counter", "bytes", "wire", "MSG_MUX envelope overhead out"),
        M("wire.mux_bytes_in", "counter", "bytes", "wire", "MSG_MUX envelope overhead in"),
        M("wire.estimator_frame_bytes", "counter", "bytes", "wire", "phase-0 exchange bytes"),
        M("wire.protocol_frame_bytes", "counter", "bytes", "wire", "round sketch/reply/outcome bytes"),
        M("wire.verify_frame_bytes", "counter", "bytes", "wire", "final verify exchange bytes"),
        M("wire.epoch_envelope_bytes", "counter", "bytes", "wire", "MSG_EPOCH envelope overhead"),
        M("wire.resume_frame_bytes", "counter", "bytes", "wire", "resume handshake/replay/rollback bytes"),
        M("wire.tree_frame_bytes", "counter", "bytes", "wire", "tree digest/verdict exchange bytes"),
        M("wire.retransmits", "counter", "count", "wire", "ARQ retransmissions"),
        M("wire.rto_ms", "gauge", "ms", "wire", "live adaptive retransmit timeout"),
        # -- endpoint: per-endpoint recovery state (DESIGN.md §13)
        M("endpoint.resumes", "counter", "count", "endpoint", "MSG_RESUME reconnects driven"),
        M("endpoint.sessions_degraded", "counter", "count", "endpoint", "degradation-ladder escalations"),
        M("endpoint.parity_extensions", "counter", "count", "endpoint", "rateless MSG_PARITY extensions applied"),
        # -- store: SessionBatch cumulative counters (DESIGN.md §11)
        M("store.store_builds", "counter", "count", "store", "cohort-store builds incl. rebuilds"),
        M("store.store_compactions", "counter", "count", "store", "capacity overflows -> forced rebuilds"),
        M("store.store_delta_bytes", "counter", "bytes", "store", "cumulative delta-patch H2D bytes"),
        M("store.store_build_bytes", "counter", "bytes", "store", "cumulative store-build H2D bytes"),
        # -- kernels: the jit retrace census (DESIGN.md §12)
        M("kernels.retraces_total", "counter", "count", "kernels", "jit traces across every entry point"),
        M("kernels.retraces_by_fn", "labeled_counter", "count", "kernels", "jit traces per entry point"),
    ]


SCHEMA: dict[str, MetricSpec] = {s.name: s for s in _specs()}

for _s in SCHEMA.values():      # the schema must be self-consistent
    assert _s.kind in _KINDS, _s
    assert _s.unit in _UNITS, _s
    assert _s.name.startswith(_s.owner + "."), _s


@dataclass
class Recorder:
    """The one typed sink every layer's ledger lands in (DESIGN.md §14).

    Thread-safe; values live under their full dotted names.  Layers keep
    computing their dicts exactly as before and ``publish`` them whole; the
    legacy surfaces rebuild their dict shapes with ``view``.  ``mark`` /
    ``delta_since_mark`` / ``drop_mark`` carry the per-run derivation of
    cumulative counters (the old ``_counter_mark`` mechanism, now owned by
    the recorder so batch-discard resets cannot drift from it).
    """

    schema: dict[str, MetricSpec] = field(default_factory=lambda: SCHEMA)

    def __post_init__(self) -> None:
        self._lock = threading.Lock()
        self._values: dict[str, object] = {}
        self._hists: dict[str, list] = {}
        self._marks: dict[str, dict] = {}

    # -- writes ----------------------------------------------------------

    def _spec(self, name: str) -> MetricSpec:
        spec = self.schema.get(name)
        if spec is None:
            raise MetricsError(
                f"undeclared metric {name!r}: declare it in repro.obs SCHEMA "
                "and the DESIGN.md §14 table"
            )
        return spec

    def set(self, name: str, value, label: str | None = None) -> None:
        """Record ``name``'s current value (counters included: the layers'
        dicts already carry the correct cumulative/per-run semantics)."""
        spec = self._spec(name)
        with self._lock:
            if label is not None or spec.kind == "labeled_counter":
                if spec.kind != "labeled_counter" and label is not None:
                    raise MetricsError(f"{name} is {spec.kind}, not labeled")
                slot = self._values.setdefault(name, {})
                if label is None:       # whole label-dict publish
                    self._values[name] = dict(value)
                else:
                    slot[label] = value
            else:
                self._values[name] = value

    def inc(self, name: str, value=1, label: str | None = None) -> None:
        spec = self._spec(name)
        if spec.kind not in ("counter", "labeled_counter"):
            raise MetricsError(f"inc on non-counter metric {name}")
        with self._lock:
            if spec.kind == "labeled_counter":
                slot = self._values.setdefault(name, {})
                slot[label] = slot.get(label, 0) + value
            else:
                self._values[name] = self._values.get(name, 0) + value

    def observe(self, name: str, value) -> None:
        """Append one sample to a histogram metric."""
        spec = self._spec(name)
        if spec.kind != "histogram":
            raise MetricsError(f"observe on non-histogram metric {name}")
        with self._lock:
            self._hists.setdefault(name, []).append(value)

    def publish(self, owner: str, mapping: dict) -> None:
        """Record a whole legacy ledger dict under ``owner.*`` names.

        Every key must be declared — the enforcement point that keeps new
        counters from shipping un-schema'd.
        """
        for key, value in mapping.items():
            self.set(f"{owner}.{key}", value)

    # -- marks (per-run derivation of cumulative counters) ---------------

    def mark(self, name: str, counters: dict) -> None:
        """Snapshot ``counters`` under mark ``name`` (end-of-run)."""
        with self._lock:
            self._marks[name] = dict(counters)

    def delta_since_mark(self, name: str, counters: dict) -> dict:
        """Per-run view: ``counters`` minus the named mark (0 when unset)."""
        with self._lock:
            base = self._marks.get(name, {})
            return {k: v - base.get(k, 0) for k, v in counters.items()}

    def drop_mark(self, name: str) -> None:
        """Forget a mark — the batch it described was discarded, so the
        next run's delta must diff against zero, not a dead batch."""
        with self._lock:
            self._marks.pop(name, None)

    # -- reads -----------------------------------------------------------

    def value(self, name: str, label: str | None = None, default=None):
        self._spec(name)
        with self._lock:
            v = self._values.get(name, default)
            if label is not None:
                return v.get(label, default) if isinstance(v, dict) else default
            return dict(v) if isinstance(v, dict) else v

    def view(self, owner: str) -> dict:
        """The legacy dict shape, derived back from the registry: every
        recorded ``owner.*`` metric keyed by its un-prefixed name."""
        prefix = owner + "."
        with self._lock:
            return {
                name[len(prefix):]: (dict(v) if isinstance(v, dict) else v)
                for name, v in self._values.items()
                if name.startswith(prefix)
            }

    def snapshot(self) -> dict:
        """Full registry dump: name -> value (histograms as lists)."""
        with self._lock:
            out = {
                n: (dict(v) if isinstance(v, dict) else v)
                for n, v in self._values.items()
            }
            out.update({n: list(v) for n, v in self._hists.items()})
            return out
