"""Tree-partitioned cold-start front end over PBS (DESIGN.md §15).

``partition_pair`` walks a binary range tree over the 32-bit key space
with batched per-range ToW digests (one ``tree_digest`` kernel sweep per
level), prunes converged ranges, and hands each divergent range with a
small residual d̂ to PBS as an ordinary known-d session;
``tree_reconcile`` is the one-call in-process form.  The wire flow — a
cold-start peer exchanging ``MSG_TREE`` digest/verdict frames with a pair
endpoint or the hub before PBS admission — lives in ``repro.net``.
"""
from .partition import (
    SPAN,
    TreeConfig,
    TreeLeaf,
    TreeResult,
    TreeStats,
    leaf_slices,
    level_digests,
    level_digests_ref,
    level_verdicts,
    partition_pair,
    range_bounds,
    split_ranges,
    tree_reconcile,
    tree_seeds,
)

__all__ = [
    "SPAN",
    "TreeConfig",
    "TreeLeaf",
    "TreeResult",
    "TreeStats",
    "leaf_slices",
    "level_digests",
    "level_digests_ref",
    "level_verdicts",
    "partition_pair",
    "range_bounds",
    "split_ranges",
    "tree_reconcile",
    "tree_seeds",
]
